"""End-to-end behaviour tests for the paper's system: the integrated
controller (Kalman → fair-share → AIMD → billing) reproduces the paper's
qualitative claims on the §V.A workload suite."""

import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.types import ControlParams
from repro.sim import SimConfig, paper_schedule, run
from repro.sim.runner import total_cost

PARAMS = ControlParams(monitor_dt=300.0)


def _run(policy, predictor="kalman", ttc=7500.0, **kw):
    cfg = SimConfig(ctrl=ControllerConfig(policy=policy, predictor=predictor,
                                          params=PARAMS, **kw), ticks=130)
    return run(paper_schedule(ttc=ttc, arrival_gap_ticks=1), cfg)


@pytest.fixture(scope="module")
def results():
    out = {p: _run(p, as_step=10.0)
           for p in ("aimd", "reactive", "mwa", "lr", "autoscale")}
    return out


def test_headline_claim_aimd_vs_autoscale(results):
    """Paper: 38-69% billing reduction vs Amazon Autoscale."""
    a = total_cost(results["aimd"])
    s = total_cost(results["autoscale"])
    assert (s - a) / s > 0.38


def test_aimd_within_2x_of_lower_bound(results):
    """Paper: AIMD lands 86% above LB while others are 132-364% above."""
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    lb = sched.total_cus / 3600 * 0.0081
    a = total_cost(results["aimd"])
    assert a < 2.5 * lb
    assert total_cost(results["autoscale"]) > 3.0 * lb


def test_aimd_ttc_abiding(results):
    """Paper: every AIMD workload finished within its confirmed TTC."""
    assert int(results["aimd"].violations) == 0


def test_autoscale_uses_most_instances(results):
    n_as = float(results["autoscale"].n_committed.max())
    for p in ("aimd", "reactive", "mwa", "lr"):
        assert n_as > float(results[p].n_committed.max())


def test_kalman_faster_than_adhoc():
    """Paper Table II: Kalman reaches a reliable prediction >20% sooner on
    average than the fixed-gain estimator."""
    times = {}
    for pred in ("kalman", "adhoc"):
        tr = _run("aimd", predictor=pred)
        rel = np.asarray(tr.reliable[:, :, 0])          # (T, W)
        sub = np.asarray(tr.work_final.t_submit)
        t_rel = np.argmax(rel, axis=0).astype(float)    # first True
        ok = rel.any(axis=0)
        times[pred] = float(np.mean(t_rel[ok] - sub[ok]))
    assert times["kalman"] < times["adhoc"]
