"""The unified SweepSpec entry point: validation, bit-parity of every
execution path (facade vs deprecated shims, chunked, streamed, sharded),
padding containment, and kill-and-resume semantics.

The sharding-parity test launches a subprocess because
``XLA_FLAGS=--xla_force_host_platform_device_count`` must be set before
the first jax import; the multi-device CI job additionally runs this
whole module under 4 forced host CPU devices.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, SweepStream,
                       TenantSet, TenantSpec, make_axes, paper_schedule,
                       tenants)
from repro.sim import scenarios as scen
from repro.sim import sweep as sweep_mod
from repro.sim.sweep import sweep

SEEDS = (0, 1, 2)


def _cfg(**spot_kw) -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=300.0),
                              billing=BillingParams(terminate="immediate")),
        ticks=130, spot=SpotConfig(enabled=True, **spot_kw))


SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
# A prime grid size: 13 points never divide any chunk or device count, so
# every chunked/sharded path below exercises `_pad_axes` padding.
PRIME_AXES = make_axes(range(13), [1.1])
assert int(PRIME_AXES.seed.shape[0]) == 13


def _assert_same(a, b, exact=True):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.shape == y.shape
        if exact:
            np.testing.assert_array_equal(x, y)
        else:
            np.testing.assert_allclose(x, y, rtol=1e-6)


# ---------------------------------------------------------------- validation

def test_spec_rejects_bad_chunk_size():
    with pytest.raises(ValueError, match="chunk_size"):
        SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=0)


def test_spec_rejects_bad_devices():
    with pytest.raises(ValueError, match="devices"):
        SweepSpec(axes=PRIME_AXES, workload=SCHED, devices=0)


def test_spec_rejects_devices_and_mesh():
    from repro.launch import mesh as mesh_lib
    with pytest.raises(ValueError, match="not both"):
        SweepSpec(axes=PRIME_AXES, workload=SCHED, devices=1,
                  mesh=mesh_lib.make_sweep_mesh(1))


def test_spec_rejects_multi_axis_mesh():
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("a", "b"))
    with pytest.raises(ValueError, match="one .batch. axis"):
        SweepSpec(axes=PRIME_AXES, workload=SCHED, mesh=mesh)


def test_spec_rejects_file_stream_dir(tmp_path):
    f = tmp_path / "not_a_dir"
    f.write_text("x")
    with pytest.raises(ValueError, match="is a file"):
        SweepSpec(axes=PRIME_AXES, workload=SCHED, stream_dir=str(f))


def test_spec_rejects_non_axes():
    with pytest.raises(TypeError, match="SweepAxes"):
        SweepSpec(axes=np.arange(3), workload=SCHED)


def test_spec_options_are_keyword_only():
    with pytest.raises(TypeError):
        SweepSpec(PRIME_AXES, SCHED, None, 4)  # chunk_size positionally


def test_sweep_requires_spot_enabled():
    cfg = SimConfig(ticks=130, spot=SpotConfig(enabled=False))
    with pytest.raises(ValueError, match="spot.enabled"):
        sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED), cfg)


def test_runner_options_are_keyword_only():
    from repro.sim import runner
    with pytest.raises(TypeError):
        runner.scan_run(SCHED, _cfg(), 0)  # seed positionally


# ------------------------------------------------- facade vs deprecated shims

def test_run_sweep_shim_warns_and_matches_facade(monkeypatch):
    # The deprecation fires once per process — rearm it so this test
    # passes regardless of which earlier test file hit the shim first.
    monkeypatch.setattr(sweep_mod, "_WARNED_RUN_SWEEP", False)
    cfg = _cfg()
    ref = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED), cfg)
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        legacy = sweep_mod.run_sweep(SCHED, cfg, PRIME_AXES)
    _assert_same(ref, legacy)


def test_tenant_sweep_shim_warns_and_matches_facade(monkeypatch):
    monkeypatch.setattr(tenants, "_WARNED_TENANT_SWEEP", False)
    cfg = _cfg()
    sset = scen.default_set()
    tset = TenantSet(tuple(TenantSpec(scenario=s, name=f"t{i}")
                           for i, s in enumerate(sset.specs[:2])))
    axes = make_axes(list(SEEDS), [1.0])
    ref = sweep(SweepSpec(axes=axes, workload=tset), cfg)
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        legacy = tenants.tenant_sweep(tset, cfg, SEEDS)
    _assert_same(ref, legacy)
    one = tenants.run_tenants(tset, cfg, SEEDS[1])
    _assert_same(one, jax.tree.map(lambda x: x[1], ref))


def test_scenario_set_rides_the_facade():
    cfg = _cfg()
    sset = scen.default_set()
    axes = make_axes(list(SEEDS), [1.0], scenarios=sset)
    ref = sweep(SweepSpec(axes=axes, workload=sset), cfg)
    chunked = sweep(SweepSpec(axes=axes, workload=sset, chunk_size=4), cfg)
    _assert_same(ref, chunked)


# --------------------------------------------- padding containment (streamed)

def test_prime_grid_stream_chunks_hold_no_padding(tmp_path):
    """ISSUE 7 bugfix satellite: `_pad_axes` repeats the last grid row up
    to the padded chunk shape — no written chunk file may contain those
    rows.  B=13 (prime) with chunk 4 pads the last chunk 13→16."""
    cfg = _cfg()
    d = str(tmp_path / "stream")
    handle = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                             stream_dir=d), cfg)
    assert isinstance(handle, SweepStream)
    assert handle.n_chunks == 4 and handle.completed() == [0, 1, 2, 3]
    rows = [handle.rows(i) for i in range(4)]
    assert rows == [4, 4, 4, 1]  # last chunk sliced to its single live row
    for i, r in enumerate(rows):
        chunk = handle.load_chunk(i)
        for leaf in jax.tree.leaves(chunk):
            assert np.asarray(leaf).shape[0] == r
    ref = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED), cfg)
    _assert_same(ref, handle.load())


def test_take_rows_asserts_on_shape_drift():
    with pytest.raises(AssertionError, match="padded points would leak"):
        sweep_mod._take_rows({"x": np.zeros((5,))}, rows=3, chunk=4,
                             where="the summary")


# ----------------------------------------------------------- kill-and-resume

def test_kill_and_resume_is_bit_identical(tmp_path):
    cfg = _cfg()
    d = str(tmp_path / "stream")
    spec = SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                     stream_dir=d)
    ref = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED), cfg)
    handle = sweep(spec, cfg)
    uninterrupted = handle.load()

    # Kill after k=2 chunks: drop the last two commits, and leave chunk 1
    # as a torn, uncommitted write (renamed dir, no .done marker) — the
    # crash-mid-save shape the checkpointer's commit protocol must mask.
    import shutil
    for i in (2, 3):
        shutil.rmtree(os.path.join(d, f"step_{i:08d}"))
        os.remove(os.path.join(d, f"step_{i:08d}.done"))
    os.remove(os.path.join(d, "step_00000001.done"))
    assert sweep_mod.checkpointer.committed_steps(d) == [0]

    mtime0 = os.path.getmtime(os.path.join(d, "step_00000000"))
    resumed = sweep(spec, cfg)
    assert resumed.completed() == [0, 1, 2, 3]
    # Chunk 0 was reused, not recomputed.
    assert os.path.getmtime(os.path.join(d, "step_00000000")) == mtime0
    _assert_same(uninterrupted, resumed.load())
    _assert_same(ref, resumed.load())


def test_stream_dir_refuses_a_different_sweep(tmp_path):
    cfg = _cfg()
    d = str(tmp_path / "stream")
    sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                    stream_dir=d), cfg)
    other = make_axes(range(7), [1.1])
    with pytest.raises(ValueError, match="different sweep"):
        sweep(SweepSpec(axes=other, workload=SCHED, chunk_size=4,
                        stream_dir=d), cfg)
    # resume=False discards the old stream instead.
    h = sweep(SweepSpec(axes=other, workload=SCHED, chunk_size=4,
                        stream_dir=d, resume=False), cfg)
    assert h.n_points == 7
    ref = sweep(SweepSpec(axes=other, workload=SCHED), cfg)
    _assert_same(ref, h.load())


def test_streamed_tenant_run_round_trip(tmp_path):
    cfg = _cfg()
    sset = scen.default_set()
    tset = TenantSet(tuple(TenantSpec(scenario=s, name=f"t{i}")
                           for i, s in enumerate(sset.specs[:2])))
    axes = make_axes(list(SEEDS), [1.0])
    ref = sweep(SweepSpec(axes=axes, workload=tset), cfg)
    h = sweep(SweepSpec(axes=axes, workload=tset, chunk_size=2,
                        stream_dir=str(tmp_path / "t")), cfg)
    back = h.load()
    assert type(back).__name__ == "TenantRun"
    _assert_same(ref, back)


# ------------------------------------------------------------- mesh sharding

_SHARD_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
import numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import SimConfig, SpotConfig, SweepSpec, make_axes, paper_schedule
from repro.sim.sweep import sweep

cfg = SimConfig(
    ctrl=ControllerConfig(params=ControlParams(monitor_dt=300.0),
                          billing=BillingParams(terminate="immediate")),
    ticks=130, spot=SpotConfig(enabled=True))
sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
axes = make_axes(range(13), [1.1])  # prime: pads 13 -> 16 on 4 devices
r1 = sweep(SweepSpec(axes=axes, workload=sched, devices=1), cfg)
r4 = sweep(SweepSpec(axes=axes, workload=sched), cfg)
for name, a, b in zip(type(r1)._fields, r1, r4):
    if a is None and b is None:   # e.g. alerts without obs.detect
        continue
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape == (13,), (name, a.shape, b.shape)
    assert np.array_equal(a, b), name
print("SHARD_PARITY_OK")
"""


def test_shard_map_matches_single_device_forced_4cpu():
    """Bit-parity of the shard_map path on a forced 4-device CPU host.

    Runs in a subprocess: the device-count flag only takes effect before
    jax initializes, so it cannot be set inside this process."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    out = subprocess.run([sys.executable, "-c", _SHARD_PARITY_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_PARITY_OK" in out.stdout


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs a multi-device host (CI forces 4 CPU "
                           "devices for this)")
def test_sharded_streamed_resume_in_process(tmp_path):
    """On a genuinely multi-device host (the dedicated CI job), the whole
    stack composes: shard_map × chunking × streaming × resume."""
    cfg = _cfg()
    d = str(tmp_path / "stream")
    spec = SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=5,
                     stream_dir=d)
    ref = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED, devices=1), cfg)
    h = sweep(spec, cfg)
    # chunk 5 is padded up to the device multiple; live rows still 13
    assert sum(h.rows(i) for i in range(h.n_chunks)) == 13
    _assert_same(ref, h.load())
    last = h.completed()[-1]
    import shutil
    shutil.rmtree(os.path.join(d, f"step_{last:08d}"))
    os.remove(os.path.join(d, f"step_{last:08d}.done"))
    _assert_same(ref, sweep(spec, cfg).load())
