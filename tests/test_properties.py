"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core import billing, kalman
from repro.core.types import BillingParams, ControlParams
from repro.models.layers import cross_entropy
from repro.models.ssm import ssd_chunked, ssd_reference

P = ControlParams()


@given(st.lists(st.floats(0.5, 500.0), min_size=3, max_size=40))
@settings(max_examples=40, deadline=None)
def test_kalman_estimate_stays_in_measurement_hull(meas):
    """b̂ is a convex combination of past measurements: never leaves
    [min(meas), max(meas)] after bootstrap."""
    stt = kalman.init(1, 1)
    lo, hi = min(meas), max(meas)
    for m in meas:
        stt = kalman.step(stt, jnp.full((1, 1), m), jnp.ones((1, 1), bool), P)
        b = float(stt.b_hat[0, 0])
        assert lo - 1e-4 <= b <= hi + 1e-4


@given(st.floats(0.01, 10.0), st.floats(0.01, 10.0))
@settings(max_examples=30, deadline=None)
def test_kalman_gain_in_unit_interval(sz, sv):
    import dataclasses
    p = dataclasses.replace(P, sigma_z2=sz, sigma_v2=sv)
    stt = kalman.init(1, 1)
    for _ in range(20):
        stt = kalman.step(stt, jnp.ones((1, 1)), jnp.ones((1, 1), bool), p)
        pi = float(stt.pi[0, 0])
        assert 0.0 <= pi <= sz + sv + 1.0


@given(st.integers(1, 3), st.integers(2, 5), st.integers(1, 4),
       st.sampled_from([16, 32]), st.sampled_from([8, 16]),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_ssd_chunked_equals_sequential(b, nc, h, p_, n, seed):
    """State-space duality: the chunked matmul form equals the sequential
    recurrence for any shape/chunking."""
    chunk = 16
    s = nc * chunk
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p_), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y1, s1 = ssd_chunked(x, dt, a_log, bb, cc, chunk)
    y2, s2 = ssd_reference(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(y1, y2, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-3, rtol=2e-3)


@given(st.integers(2, 6), st.integers(3, 30), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_cross_entropy_matches_naive(b, v, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    logits = jax.random.normal(ks[0], (b, 4, v), jnp.float32)
    labels = jax.random.randint(ks[1], (b, 4), 0, v)
    got = float(cross_entropy(logits, labels))
    # naive
    p = jax.nn.log_softmax(logits, -1)
    want = float(-jnp.mean(jnp.take_along_axis(p, labels[..., None],
                                               -1)[..., 0]))
    assert abs(got - want) < 1e-4


@given(st.lists(st.tuples(st.integers(0, 14), st.floats(30.0, 900.0)),
                min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_billing_no_free_capacity(steps):
    """Paid quanta always cover the capacity-time delivered: you can never
    have used more instance-seconds than you paid for."""
    bp = BillingParams(boot_delay=0.0)
    c = billing.init(16)
    used = 0.0
    for target, dt in steps:
        c = billing.scale_to(c, jnp.asarray(float(target)), bp)
        used += float(billing.capacity(c)) * dt
        c = billing.advance(c, dt, bp)
        paid = float(c.cum_cost) / bp.price_per_quantum * bp.quantum
        assert used <= paid + 1e-3


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_data_pipeline_deterministic_and_in_range(step):
    from repro.data.pipeline import DataConfig, batch_at
    cfg = DataConfig(vocab=977, seq_len=32, global_batch=4, seed=1)
    a = batch_at(cfg, step)
    b = batch_at(cfg, step)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert int(a["tokens"].max()) < 977 and int(a["tokens"].min()) >= 0
