"""Flash-decode Pallas kernel: shape/dtype/quantization sweeps vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import flash_decode
from repro.kernels.decode_attention.ops import gqa_flash_decode
from repro.kernels.decode_attention.ref import KV_SCALE, decode_ref

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("bk,g,s,hd,block_s", [
    (2, 1, 512, 64, 256),
    (4, 4, 512, 128, 128),
    (1, 8, 1024, 64, 256),
])
def test_flash_decode_shapes(bk, g, s, hd, block_s):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (bk, g, hd), jnp.float32)
    k = jax.random.normal(ks[1], (bk, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (bk, s, hd), jnp.float32)
    lengths = jax.random.randint(ks[3], (bk,), 1, s)
    out = flash_decode(q, k, v, lengths, block_s=block_s)
    for b in range(bk):
        for gi in range(g):
            ref = decode_ref(q[b, gi], k[b], v[b], lengths[b])
            np.testing.assert_allclose(out[b, gi], ref, atol=3e-5, rtol=3e-5)


def test_flash_decode_int8_fused_dequant():
    bk, g, s, hd = 2, 2, 512, 64
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (bk, g, hd), jnp.float32)
    kq = jnp.clip(jnp.round(
        jax.random.normal(ks[1], (bk, s, hd)) * KV_SCALE), -127, 127
    ).astype(jnp.int8)
    vq = jnp.clip(jnp.round(
        jax.random.normal(ks[2], (bk, s, hd)) * KV_SCALE), -127, 127
    ).astype(jnp.int8)
    lengths = jnp.asarray([s, s // 3])
    out = flash_decode(q, kq, vq, lengths)
    for b in range(bk):
        for gi in range(g):
            ref = decode_ref(q[b, gi], kq[b], vq[b], lengths[b])
            np.testing.assert_allclose(out[b, gi], ref, atol=5e-5, rtol=5e-5)


def test_gqa_wrapper_matches_model_decode():
    """The kernel wrapper agrees with the model's jnp decode attention."""
    from repro.models.attention import AttnSpec, decode_attention

    b, h, kv, hd, s = 2, 8, 2, 64, 256
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    pos = 100
    out_k = gqa_flash_decode(q, ck, cv, jnp.full((b,), pos))
    spec = AttnSpec(n_heads=h, n_kv=kv, hd=hd)
    out_m = decode_attention(q[:, None], ck, cv, jnp.asarray(pos), spec)
    np.testing.assert_allclose(out_k, out_m[:, 0], atol=1e-4, rtol=1e-4)
