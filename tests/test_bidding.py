"""Correlated multi-type market, dynamic bid policies, mixed fleets."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import billing
from repro.core.types import BillingParams, ControlParams
from repro.core.controller import ControllerConfig
from repro.sim import (SimConfig, SpotConfig, make_axes, paper_schedule,
                       run, run_single, run_sweep, spot)

PARAMS = ControlParams(monitor_dt=300.0)
BILL = BillingParams(terminate="immediate")
ALL_TYPES = spot.INSTANCE_NAMES


def _spot_cfg(**kw):
    return SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL),
        ticks=130, spot=SpotConfig(enabled=True, **kw))


# ----------------------------------------------------- correlated process --

def test_marginal_distribution_invariant_to_corr():
    """Each type's marginal must be the single-type process regardless of
    the factor loading: stationary log-price std matches vol/sqrt(1-rho²)
    at every corr (satellite: invariance test)."""
    for corr in (0.0, 0.6, 0.9):
        cfg = SpotConfig(p_spike_per_core=0.0, corr=corr)
        tr = spot.price_traces(spot.make_runtime(cfg), 8000,
                               jax.random.PRNGKey(1), cfg)
        x = np.log(np.asarray(tr) / np.asarray(spot.SPOT_BASE_TABLE))
        emp = x[500:].std(axis=0)
        vol = np.asarray(cfg.vol0
                         + cfg.vol_scale * np.log2(
                             np.asarray(spot.CORES_TABLE) + 1.0))
        theory = vol / np.sqrt(1.0 - cfg.rho ** 2)
        np.testing.assert_allclose(emp, theory, rtol=0.12,
                                   err_msg=f"corr={corr}")


def test_cross_type_increment_correlation_matches_loading():
    """Log-price increments correlate across types at the configured
    factor loading (the AR(1) algebra makes plain first differences
    inherit exactly ``corr``)."""
    for corr in (0.3, 0.6):
        cfg = SpotConfig(p_spike_per_core=0.0, corr=corr)
        tr = spot.price_traces(spot.make_runtime(cfg), 6000,
                               jax.random.PRNGKey(0), cfg)
        d = np.diff(np.log(np.asarray(tr)), axis=0)
        cc = np.corrcoef(d.T)
        off = cc[np.triu_indices(spot.N_TYPES, 1)]
        assert np.all(off > 0.0)
        np.testing.assert_allclose(off.mean(), corr, atol=0.05)


def test_corr_zero_types_independent():
    cfg = SpotConfig(p_spike_per_core=0.0, corr=0.0)
    tr = spot.price_traces(spot.make_runtime(cfg), 6000,
                           jax.random.PRNGKey(2), cfg)
    d = np.diff(np.log(np.asarray(tr)), axis=0)
    off = np.corrcoef(d.T)[np.triu_indices(spot.N_TYPES, 1)]
    assert np.all(np.abs(off) < 0.08)


def test_primary_trace_slices_full_system():
    cfg = SpotConfig(instance="m3.xlarge")
    rt = spot.make_runtime(cfg)
    key = jax.random.PRNGKey(5)
    full = spot.price_traces(rt, 64, key, cfg)
    one = spot.price_trace(rt, 64, key, cfg)
    assert full.shape == (64, spot.N_TYPES)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(full)[:, 2])


def test_mean_spike_duration_scales_with_spike_hours():
    """At a sub-hourly step a spike survives each tick with probability
    1 - h/spike_hours, so longer spike_hours → more spiked steps."""
    counts = {}
    for sh in (1.0, 4.0):
        cfg = SpotConfig(p_spike_per_core=0.05, spike_hours=sh)
        tr = spot.price_traces(spot.make_runtime(cfg), 4000,
                               jax.random.PRNGKey(3), cfg, dt=300.0)
        x = np.asarray(tr)[:, 0] / spot.INSTANCE_TYPES["m3.medium"][2]
        counts[sh] = int((x > 1.8).sum())
    assert counts[4.0] > 2 * counts[1.0] > 0


# ------------------------------------------------------ config validation --

def test_spotconfig_rejects_unknown_instance_with_valueerror():
    with pytest.raises(ValueError, match="m3.medium"):
        SpotConfig(instance="x1.32xlarge")


def test_spotconfig_rejects_unknown_bid_policy_with_valueerror():
    with pytest.raises(ValueError, match="multiple"):
        SpotConfig(bid_policy="tcp_vegas")


def test_spotconfig_rejects_bad_fleet_and_corr():
    with pytest.raises(ValueError, match="Table V"):
        SpotConfig(fleet=("m3.medium", "nope"))
    with pytest.raises(ValueError, match="corr"):
        SpotConfig(corr=1.0)
    with pytest.raises(ValueError, match="spike_hours"):
        SpotConfig(spike_hours=0.0)


# ------------------------------------------------------------ bid policies --

def _rt_state(policy, bid_mult=1.5, instance="m3.medium"):
    cfg = SpotConfig(bid_policy=policy, bid_mult=bid_mult, instance=instance)
    rt = spot.make_runtime(cfg)
    return cfg, rt, spot.init(rt, jax.random.PRNGKey(0))


def test_ttc_policy_interpolates_static_to_cap():
    cfg, rt, st = _rt_state("ttc", bid_mult=1.2)
    lo = np.asarray(spot.current_bids(cfg, rt, st, urgency=0.0))
    hi = np.asarray(spot.current_bids(cfg, rt, st, urgency=1.0))
    static = 1.2 * np.asarray(spot.SPOT_BASE_TABLE)
    cap = np.maximum(np.asarray(spot.ON_DEMAND_TABLE), static)
    np.testing.assert_allclose(lo, static, rtol=1e-6)
    np.testing.assert_allclose(hi, cap, rtol=1e-6)
    mid = np.asarray(spot.current_bids(cfg, rt, st, urgency=0.5))
    assert np.all(mid >= lo) and np.all(mid <= hi)


def test_ema_policy_tracks_ema_capped_at_on_demand():
    cfg, rt, st = _rt_state("ema", bid_mult=2.0)
    # Baseline EMA = base prices.
    np.testing.assert_allclose(
        np.asarray(spot.current_bids(cfg, rt, st)),
        np.minimum(2.0 * np.asarray(spot.SPOT_BASE_TABLE),
                   np.asarray(spot.ON_DEMAND_TABLE)), rtol=1e-6)
    # A hot market lifts the EMA and the bid with it, still capped.
    hot = st._replace(ema=st.ema * 100.0)
    np.testing.assert_allclose(
        np.asarray(spot.current_bids(cfg, rt, hot)),
        np.asarray(spot.ON_DEMAND_TABLE), rtol=1e-6)


def test_on_demand_policy_bids_table_prices():
    cfg, rt, st = _rt_state("on_demand")
    np.testing.assert_allclose(np.asarray(spot.current_bids(cfg, rt, st)),
                               np.asarray(spot.ON_DEMAND_TABLE), rtol=1e-6)


def test_select_type_cheapest_per_cu_among_available():
    prices = spot.SPOT_BASE_TABLE * 1.0
    bids = spot.ON_DEMAND_TABLE * 1.0
    # At base prices m4.4xlarge is the cheapest per CU of the full table.
    it, ok = spot.select_type(prices, bids, jnp.ones((spot.N_TYPES,)))
    assert bool(ok) and spot.INSTANCE_NAMES[int(it)] == "m4.4xlarge"
    # Restrict the mix: medium wins over 10xlarge on per-CU price.
    mix = spot.fleet_mask(("m3.medium", "m4.10xlarge"))
    it, ok = spot.select_type(prices, bids, mix)
    assert bool(ok) and spot.INSTANCE_NAMES[int(it)] == "m3.medium"
    # Outbid everywhere: nothing available.
    _, ok = spot.select_type(prices, jnp.zeros_like(bids), mix)
    assert not bool(ok)


# ------------------------------------------------------- fleet-aware billing --

def test_scale_to_cu_mode_starts_enough_coarse_instances():
    bp = BillingParams(boot_delay=0.0, terminate="immediate")
    c = billing.init(8)
    # Target 90 CUs out of 40-CU instances: 3 starts (120 CUs committed).
    c = billing.scale_to(c, jnp.asarray(90.0), bp, price=0.5655, bid=1.0,
                         itype=5, cores=jnp.full((8,), 40.0))
    cores = jnp.full((8,), 40.0)
    assert float(billing.committed(c, cores)) == 120.0
    assert float(c.cum_cost) == pytest.approx(3 * 0.5655)
    assert np.all(np.asarray(c.itype)[np.asarray(c.phase) > 0] == 5)
    # Shrinking to 40 CUs drains two instances' worth of CUs.
    c = billing.scale_to(c, jnp.asarray(40.0), bp, cores=cores)
    assert float(billing.committed(c, cores)) == 40.0


def test_scale_to_cu_mode_mixed_slot_weights():
    """Shrink sheds just enough CUs when slots have unequal weights."""
    bp = BillingParams(boot_delay=0.0, terminate="immediate")
    c = billing.init(4)
    c = billing.scale_to(c, jnp.asarray(2.0), bp, price=0.01, bid=0.02,
                         itype=0)          # two 1-CU slots (legacy mode)
    cores = jnp.asarray([1.0, 1.0, 16.0, 16.0])
    c = billing.scale_to(c, jnp.asarray(34.0), bp, price=0.11, bid=0.2,
                         itype=4, cores=cores)  # + two 16-CU slots
    assert float(billing.committed(c, cores)) == 34.0
    # Dropping to 20 CUs sheds a 14-CU budget in §IV order (smallest
    # remaining time first; equal times break by slot index): both 1-CU
    # slots fit the budget, a 16-CU slot does not — the fleet stays at or
    # above its target rather than forfeiting a paid coarse instance.
    c = billing.scale_to(c, jnp.asarray(20.0), bp, cores=cores)
    assert float(billing.committed(c, cores)) == 32.0
    # Once the excess covers a whole coarse instance, it goes.
    c = billing.scale_to(c, jnp.asarray(16.0), bp, cores=cores)
    assert float(billing.committed(c, cores)) == 16.0


def test_scale_to_cu_mode_sub_instance_excess_never_sheds():
    """Regression: a 39-CU target on a 40-CU instance must keep the
    instance — shedding it would forfeit the paid quantum and re-buy a
    fresh one next tick (cost churn the instance-count semantics never
    had)."""
    bp = BillingParams(boot_delay=0.0, terminate="immediate")
    cores = jnp.full((4,), 40.0)
    c = billing.init(4)
    c = billing.scale_to(c, jnp.asarray(40.0), bp, price=0.5655, bid=1.0,
                         itype=5, cores=cores)
    assert float(c.cum_cost) == pytest.approx(0.5655)
    c = billing.scale_to(c, jnp.asarray(39.0), bp, price=0.5655, bid=1.0,
                         itype=5, cores=cores)
    assert float(billing.committed(c, cores)) == 40.0
    assert float(c.cum_cost) == pytest.approx(0.5655)


def test_legacy_scale_to_unchanged_without_cores():
    bp = BillingParams(boot_delay=0.0)
    c = billing.scale_to(billing.init(4), jnp.asarray(3.0), bp)
    assert float(billing.committed(c)) == 3.0


# ----------------------------------------------------------- end-to-end sim --

SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)


def test_fleet_sweep_matches_run_single_over_policies_and_mixes():
    """One jitted vmap over policies × mixes == looping single runs."""
    cfg = _spot_cfg()
    mixes = ["m3.medium", ("m3.medium", "m4.4xlarge")]
    policies = ["multiple", "ttc", "ema", "on_demand"]
    axes = make_axes(seeds=[0], bid_mults=[1.5], instances=mixes,
                     policies=policies)
    batched = run_sweep(SCHED, cfg, axes)
    i = 0
    for policy in policies:
        for mix in mixes:
            single = run_single(SCHED, cfg, seed=0, bid_mult=1.5,
                                instance=mix, policy=policy)
            for field in single._fields:
                if getattr(single, field) is None:
                    continue   # e.g. alerts without obs.detect
                np.testing.assert_allclose(
                    np.asarray(getattr(batched, field))[i],
                    np.asarray(getattr(single, field)),
                    rtol=1e-5, err_msg=f"{field} @ {policy}/{mix}")
            i += 1


def test_mixed_fleet_completes_and_holds_multiple_types():
    """A heterogeneous fleet on the correlated market finishes the suite;
    acquisitions actually use more than one Table-V type."""
    cfg = _spot_cfg(fleet=ALL_TYPES, bid_policy="on_demand")
    tr = run(SCHED, cfg, seed=0)
    assert float(tr.n_usable.max()) > 0
    work = tr.work_final
    assert int((work.t_done >= 0).sum()) == SCHED.n
    # The cheapest-per-CU choice at baseline prices is m4.4xlarge, so a
    # mixed fleet must not be pure m3.medium.
    assert float(tr.n_committed.max()) >= 16.0


def test_dynamic_policies_run_end_to_end_and_bid_dynamically():
    cfg = _spot_cfg(bid_policy="ttc", bid_mult=1.02, instance="m3.xlarge",
                    p_spike_per_core=0.02, spike_hours=3.0)
    tr = run(SCHED, cfg, seed=3)
    bids = np.asarray(tr.spot_bid)
    floor = 1.02 * spot.INSTANCE_TYPES["m3.xlarge"][2]
    assert bids.min() >= floor * (1 - 1e-6)
    assert bids.max() > bids.min()          # escalated at least once
    assert bids.max() <= spot.INSTANCE_TYPES["m3.xlarge"][1] * (1 + 1e-6)


def test_ttc_policy_cuts_violations_vs_static_at_same_floor():
    """On a spiky market the TTC-aware policy must strictly reduce
    violations vs the same static floor bid (the ISSUE 2 story)."""
    seeds = [0, 1, 2, 3]
    market = dict(instance="m3.xlarge", p_spike_per_core=0.02,
                  spike_hours=3.0)
    cfg = _spot_cfg(**market)
    axes = make_axes(seeds=seeds, bid_mults=[1.2],
                     instances=["m3.xlarge"],
                     policies=["multiple", "ttc"])
    s = run_sweep(SCHED, cfg, axes)
    vio = np.asarray(s.violations).reshape(len(seeds), 2)
    assert vio[:, 1].sum() < vio[:, 0].sum()


def test_spot_disabled_trace_has_infinite_bid():
    cfg = SimConfig(ctrl=ControllerConfig(params=PARAMS, billing=BILL),
                    ticks=40)
    tr = run(SCHED, cfg)
    assert np.all(np.isinf(np.asarray(tr.spot_bid)))


def test_spotconfig_fleet_is_hashable_static_config():
    cfg = _spot_cfg(fleet=("m3.medium", "m3.large"))
    assert isinstance(hash(dataclasses.astuple(cfg.spot)), int)
