"""Tuner subsystem (ISSUE 5): traced PolicyParams + in-jit CEM/ES tuning.

The contract under test:

  * promoting the policy coefficients to a traced pytree changed nothing —
    a run at the default ``PolicyParams`` is bit-identical to a run that
    never mentions them, across the scan, the cached entry points and
    ``run_sweep``;
  * a whole candidate population evaluates under one ``vmap`` with a
    single trace of the objective (no per-candidate recompiles);
  * same key ⇒ bit-identical tuning outcome (CEM and ES);
  * tuning strictly beats the hand-set defaults on MMPP and FlashCrowd;
  * the adversarial search respects the generator's parameter bounds and
    never reports a world milder than the nominal one.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import opt
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams, PolicyParams
from repro.sim import (
    SimConfig,
    SpotConfig,
    default_params,
    default_set,
    make_axes,
    make_policy_params,
    run_single,
    run_sweep,
    runner,
    spot,
    sweep,
)
from repro.sim.scenarios import FlashCrowd, MMPP

SEEDS = (0, 1, 2)


def _cfg(policy="aimd", bid_policy="ttc", ticks=60) -> SimConfig:
    """A market where every tuned coefficient can matter: spiky m3.xlarge
    prices, TTC-aware bidding at a floor the market clears above."""
    return SimConfig(
        ctrl=ControllerConfig(
            policy=policy,
            params=ControlParams(monitor_dt=300.0),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=ticks,
        spot=SpotConfig(
            enabled=True,
            instance="m3.xlarge",
            bid_policy=bid_policy,
            bid_mult=1.5,
            p_spike_per_core=0.02,
            spike_hours=3.0,
        ),
    )


# --------------------------------------------- default-params bit-identity --


def test_default_params_bit_identical_across_entry_points():
    """params=None and an explicitly passed default pytree must be the same
    program — summaries equal bit for bit (the refactor's no-op proof)."""
    cfg = _cfg()
    sset = default_set()
    for scenario in (0, 1):
        for seed in SEEDS:
            plain = run_single(sset, cfg, seed=seed, bid_mult=1.5,
                               instance="m3.xlarge", scenario=scenario)
            explicit = run_single(sset, cfg, seed=seed, bid_mult=1.5,
                                  instance="m3.xlarge", scenario=scenario,
                                  params=default_params(cfg))
            for f in sweep.RunSummary._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(plain, f)),
                    np.asarray(getattr(explicit, f)),
                    err_msg=f"{f} @ seed={seed} scenario={scenario}")


def test_default_params_bit_identical_in_run_sweep():
    cfg = _cfg()
    sset = default_set()
    axes = make_axes(seeds=list(SEEDS), bid_mults=[1.2, 1.5],
                     instances=["m3.xlarge"], scenarios=sset)
    plain = run_sweep(sset, cfg, axes)
    explicit = run_sweep(sset, cfg, axes, params=default_params(cfg))
    for f in sweep.RunSummary._fields:
        np.testing.assert_array_equal(np.asarray(getattr(plain, f)),
                                      np.asarray(getattr(explicit, f)),
                                      err_msg=f)


def test_configs_differing_only_in_tuned_leaves_share_compile():
    """strip_tuned keys the caches: a config with different AIMD gains must
    reuse the compiled scan and still produce its own (different) result."""
    cfg_a = _cfg()
    params_b = dataclasses.replace(cfg_a.ctrl.params, alpha=9.0, beta=0.7)
    cfg_b = dataclasses.replace(
        cfg_a, ctrl=dataclasses.replace(cfg_a.ctrl, params=params_b))
    sched = default_set()[0].sample(jax.random.PRNGKey(0))
    f_a = runner.cached_scan(sched, cfg_a, trace=False, with_rt=True)
    f_b = runner.cached_scan(sched, cfg_b, trace=False, with_rt=True)
    assert f_a is f_b, "tuned leaves leaked into the compilation cache key"
    # Same compiled callable, different default params → different runs.
    rt = spot.make_runtime(cfg_a.spot)
    out_a, _ = f_a(sched, 0, rt, default_params(cfg_a))
    out_b, _ = f_b(sched, 0, rt, default_params(cfg_b))
    assert float(out_a.cluster.cum_cost) != float(out_b.cluster.cum_cost)
    # And the shared-cache result must equal a *fresh* (uncached) run of
    # cfg_b bit for bit — i.e. no cfg_b coefficient is still baked into
    # the compiled scan as cfg_a's trace-time constant (the fairshare
    # guard band once was).
    fresh_b, _ = runner.scan_run(sched, cfg_b, seed=0, spot_rt=rt,
                                 trace=False,
                                 params=default_params(cfg_b))
    np.testing.assert_array_equal(np.asarray(out_b.cluster.cum_cost),
                                  np.asarray(fresh_b.cluster.cum_cost))
    np.testing.assert_array_equal(np.asarray(out_b.summ.max_committed),
                                  np.asarray(fresh_b.summ.max_committed))


def test_population_single_trace_under_vmap():
    """64 candidate PolicyParams through one vmapped objective = exactly one
    trace of the sweep objective (the no-recompile tentpole claim)."""
    cfg = _cfg()
    obj = opt.PolicyObjective(cfg, default_set(), seeds=(0, 1),
                              scenarios=[1], space=opt.policy_space())
    space = opt.policy_space()
    pop = jax.vmap(space.from_unit)(
        jax.random.uniform(jax.random.PRNGKey(0), (64, space.dim)))
    scores = jax.jit(jax.vmap(obj))(pop)
    assert scores.shape == (64,)
    assert obj.n_traces == 1
    assert bool(np.all(np.isfinite(np.asarray(scores))))


# ----------------------------------------------------------- determinism --


@pytest.mark.parametrize("method", ["cem", "es"])
def test_same_seed_tuning_is_bit_deterministic(method):
    cfg = _cfg()
    kw = dict(scenarios=[1], method=method, pop_size=6, generations=2)
    a = opt.tune_policy(cfg, default_set(), seeds=(0, 1),
                        key=jax.random.PRNGKey(7), **kw)
    b = opt.tune_policy(cfg, default_set(), seeds=(0, 1),
                        key=jax.random.PRNGKey(7), **kw)
    np.testing.assert_array_equal(np.asarray(a.result.best_vec),
                                  np.asarray(b.result.best_vec))
    np.testing.assert_array_equal(np.asarray(a.result.best_score),
                                  np.asarray(b.result.best_score))
    np.testing.assert_array_equal(np.asarray(a.result.history_best),
                                  np.asarray(b.result.history_best))
    # A different key explores differently (not a constant function).
    c = opt.tune_policy(cfg, default_set(), seeds=(0, 1),
                        key=jax.random.PRNGKey(8), **kw)
    assert not np.array_equal(np.asarray(a.result.best_vec),
                              np.asarray(c.result.best_vec))


# ------------------------------------------------- tuned beats defaults --


@pytest.mark.parametrize("spec_idx,name", [(1, "mmpp"), (3, "flash")])
def test_tuned_params_beat_defaults(spec_idx, name):
    """CEM with the default injected can never lose to it in-sample, and
    on these scenarios a modest budget finds a strict improvement."""
    cfg = _cfg()
    tuning = opt.tune_policy(cfg, default_set(), seeds=(0, 1, 2),
                             key=jax.random.PRNGKey(0),
                             scenarios=[spec_idx], pop_size=12,
                             generations=4)
    tuned, default = (float(tuning.result.best_score),
                      float(tuning.default_score))
    assert tuned <= default, f"{name}: tuned {tuned} worse than {default}"
    assert tuned < default, f"{name}: no strict improvement over default"
    assert tuning.objective.n_traces == 1
    # The tuned vector respects the policy box.
    assert opt.policy_space().contains(tuning.result.best_vec)


# ------------------------------------------------------------ adversarial --


def test_adversarial_search_respects_bounds():
    cfg = _cfg()
    spec = MMPP(horizon=30, max_w=48)
    att = opt.attack_policy(cfg, spec, None, seeds=(0, 1),
                            key=jax.random.PRNGKey(3), pop_size=8,
                            generations=3)
    space = opt.scenario_space(spec)
    assert space.contains(att.worst_vec)
    assert set(att.worst_params) == set(space.names)
    # Injecting the nominal world makes the attack's result ≥ nominal.
    assert float(att.worst_score) >= float(att.nominal_score)
    assert att.damage >= 0.0


def test_adversarial_finds_worse_world_than_nominal():
    cfg = _cfg()
    att = opt.attack_policy(cfg, FlashCrowd(horizon=30, max_w=48),
                            None, seeds=(0, 1),
                            key=jax.random.PRNGKey(4), pop_size=12,
                            generations=4)
    assert float(att.worst_score) > float(att.nominal_score)


def test_replay_scenarios_are_not_attackable():
    from repro.sim.scenarios import paper_scenario

    with pytest.raises(ValueError, match="not attackable|no tunable"):
        opt.scenario_space(paper_scenario())


def test_scenario_param_overrides_change_sampling():
    """The with-params sampling hook actually moves the generator, and the
    no-override path is bit-identical to the legacy signature."""
    spec = MMPP(horizon=40, max_w=96)
    key = jax.random.PRNGKey(5)
    base = spec.sample(key)
    again = spec.sample(key, params=None)
    for f, a, b in zip(base._fields, base, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    hot = spec.sample(key, params={
        "rate_lo": jnp.asarray(1.2, jnp.float32),
        "rate_hi": jnp.asarray(12.0, jnp.float32)})
    assert int(jnp.sum(hot.valid)) > int(jnp.sum(base.valid))


# ------------------------------------------------------- robust min–max --


def test_robust_tune_runs_and_tracks_worst_case():
    cfg = _cfg()
    rob = opt.robust_tune(cfg, MMPP(horizon=30, max_w=48), seeds=(0, 1),
                          key=jax.random.PRNGKey(6), rounds=1, pop_size=6,
                          generations=2)
    assert isinstance(rob.params, PolicyParams)
    assert opt.policy_space().contains(rob.vec)
    assert len(rob.rounds) == 1
    assert rob.pool.shape[0] == 2  # nominal + one attack world
    assert float(rob.worst_score) >= 0.0


# ------------------------------------------------------- vector plumbing --


def test_policy_vector_round_trip():
    pp = make_policy_params(alpha=7.0, beta=0.8, bid_mult=1.3,
                            ttc_gain=2.0, ema_alpha=0.5)
    vec = opt.params_to_vector(pp)
    back = opt.vector_to_params(vec)
    for f in PolicyParams._fields:
        np.testing.assert_array_equal(np.asarray(getattr(pp, f)),
                                      np.asarray(getattr(back, f)))


def test_box_space_unit_round_trip():
    space = opt.policy_space()
    vec = opt.default_vector(_cfg())
    np.testing.assert_allclose(np.asarray(space.from_unit(space.to_unit(vec))),
                               np.asarray(vec), rtol=1e-6)
    assert space.contains(vec)
