"""Chaos engine: injection correctness, conservation invariants, hardening.

The load-bearing invariants of ``sim.faults``:

  * a neutral ``FaultSpec`` under the engine is bit-identical to the
    engine compiled out (on a market where the hardened backoff has
    nothing to react to — on-demand bids, no spikes);
  * attributed billing sums exactly to the fleet bill *through* storm
    and Poisson hard-kill ticks (the mid-quantum-preemption billing
    path);
  * padded tenants/rows can neither fail nor bill;
  * killed tasks re-enter the queue exactly once: remaining work is
    non-increasing between arrival and completion, never negative;
  * the hardened control plane's primitives (missing-measurement Kalman
    update, bounded backoff, hedged type selection) behave as specified.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aimd, kalman
from repro.core.controller import ControllerConfig
from repro.core.types import ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, faults, runner,
                       spot, sweep, tenants as tnt, workloads)

PARAMS = ControlParams(monitor_dt=300.0)


def _cfg(fault_cfg=None, **kw):
    return SimConfig(ctrl=ControllerConfig(params=PARAMS), ticks=80,
                     spot=SpotConfig(enabled=True, **kw), faults=fault_cfg)


SCHED = workloads.paper_schedule()


# ------------------------------------------------- neutral spec == off --

def test_neutral_spec_is_bit_identical_to_engine_off():
    """With nothing to inject and nothing for the hardening to react to
    (on-demand bids never fail acquisition), the chaos engine's compiled
    step produces the exact fault-free bits."""
    base = _cfg(bid_policy="on_demand", p_spike_per_core=0.0)
    chaos = dataclasses.replace(base, faults=faults.FaultConfig())
    a = runner.scan_run(SCHED, base, seed=3, trace=False)[0]
    b = runner.scan_run(SCHED, chaos, seed=3, trace=False)[0]
    sa = sweep.summarize(a, SCHED, base)
    sb = sweep.summarize(b, SCHED, chaos)
    for f in sweep.RunSummary._fields:
        va, vb = getattr(sa, f), getattr(sb, f)
        if va is None and vb is None:   # e.g. alerts without obs.detect
            continue
        assert jnp.array_equal(va, vb), f
    # ...and no fault register ever fired.
    fs = b.faults
    for name in ("n_killed", "n_dropped", "n_delayed", "n_shed",
                 "unavail_ticks", "fail_streak"):
        assert float(getattr(fs, name)) == 0.0, name


def test_fault_prng_does_not_perturb_market_or_execution():
    """Enabling the engine must not shift the market/execution PRNG
    chains: the faulted run's *price* statistics match the fault-free
    run's exactly (the fault chain is salted separately)."""
    base = _cfg()
    chaos = dataclasses.replace(base, faults=faults.FaultConfig())
    spec = faults.make_fault_spec(p_meas_drop=0.5)  # telemetry-only chaos
    a = sweep.summarize(runner.scan_run(SCHED, base, seed=11,
                                        trace=False)[0], SCHED, base)
    b = sweep.summarize(runner.scan_run(SCHED, chaos, seed=11, trace=False,
                                        fspec=spec)[0], SCHED, chaos)
    assert jnp.array_equal(a.mean_price, b.mean_price)
    assert jnp.array_equal(a.max_price, b.max_price)


# ------------------------------------------------------- conservation --

def _tenant_pair():
    from repro.sim import scenarios as scen
    sset = scen.default_set(max_w=32, horizon=20)
    return tnt.TenantSet((tnt.TenantSpec(sset[0], weight=1.0),
                          tnt.TenantSpec(sset[1], weight=2.0)))


def test_attribution_exact_under_storms_and_kills():
    """Attributed per-tenant cost telescopes to the fleet bill at every
    tick — through preemption storms and Poisson mid-quantum hard-kills,
    which bill exactly like market preemptions."""
    ts = _tenant_pair()
    cfg = _cfg(faults.FaultConfig(), instance="m3.medium")
    scfg = ts.sim_config(cfg)
    sched = ts.sample(3)
    pp = runner.default_params(scfg)
    spec = faults.make_fault_spec(p_slot_fail=4.0, p_storm=2.0,
                                  storm_frac=0.6)
    step = jax.jit(runner.make_step(sched, scfg, trace=False, params=pp,
                                    fspec=spec))
    state = runner.init_state(sched, scfg, seed=3)
    for _ in range(40):
        state, _ = step(state, None)
        total = int(jnp.sum(state.summ.tenant.cost_u))
        fleet = int(jnp.round(state.cluster.cum_cost * runner._COST_UNIT))
        assert total == fleet
    # The scenario must actually have killed slots, or this test waters
    # down to the calm case.
    assert float(state.faults.n_killed) > 0


def test_padded_tenant_never_fails_nor_bills_under_chaos():
    """A hollowed-out tenant block attracts no cost, violations or
    finishes even while storms kill slots fleet-wide."""
    ts = _tenant_pair()
    cfg = _cfg(faults.FaultConfig())
    scfg = ts.sim_config(cfg)
    sched = ts.sample(5)
    w = ts.max_w
    dead = jnp.arange(sched.valid.shape[0]) >= w
    sched = sched._replace(
        valid=jnp.where(dead, False, sched.valid),
        t_arrive=jnp.where(dead, -1, sched.t_arrive))
    spec = faults.make_fault_spec(p_slot_fail=3.0, p_meas_drop=0.3)
    final, _ = runner.scan_run(sched, scfg, seed=5, trace=False,
                               fspec=spec)
    out = tnt.summarize_tenants(final, sched, scfg)
    assert int(out.cost_units[1]) == 0
    assert int(out.violations[1]) == 0
    assert int(out.finished[1]) == 0
    assert int(out.cost_units[0]) == int(
        np.round(float(final.cluster.cum_cost) * runner._COST_UNIT))


def test_killed_work_reenters_queue_exactly_once():
    """Work in flight on a killed slot returns to the queue: remaining
    items are non-increasing tick-over-tick after submission (a kill can
    only *undo* this tick's progress, never add items) and never drop
    below zero."""
    cfg = _cfg(faults.FaultConfig(), instance="m3.medium")
    spec = faults.make_fault_spec(p_slot_fail=6.0)
    pp = runner.default_params(cfg)
    sched = workloads.as_jax_schedule(SCHED)
    step = jax.jit(runner.make_step(sched, cfg, trace=False, params=pp,
                                    fspec=spec))
    state = runner.init_state(sched, cfg, seed=7)
    prev_m = np.asarray(state.work.m)
    prev_active = np.asarray(state.work.active)
    for _ in range(cfg.ticks):
        state, _ = step(state, None)
        m = np.asarray(state.work.m)
        active = np.asarray(state.work.active)
        cont = prev_active & active  # no (re)arrival in between
        assert np.all(m[cont] <= prev_m[cont] + 1e-4)
        assert np.all(m >= -1e-5)
        prev_m, prev_active = m, active
    assert float(state.faults.n_killed) > 0


# ------------------------------------------------------ fault families --

def test_deterministic_outage_blocks_unhardened_acquisition():
    """During an all-types outage window the unhardened plane cannot
    acquire: committed CUs never grow inside the window."""
    cfg = _cfg(faults.FaultConfig(hardened=False))
    spec = faults.make_fault_spec(outage_start=10.0, outage_ticks=30.0)
    pp = runner.default_params(cfg)
    sched = workloads.as_jax_schedule(SCHED)
    step = jax.jit(runner.make_step(sched, cfg, trace=False, params=pp,
                                    fspec=spec))
    state = runner.init_state(sched, cfg, seed=0)
    committed = []
    from repro.core import billing
    for _ in range(50):
        state, _ = step(state, None)
        committed.append(float(billing.committed(state.cluster, 1.0)))
    # After the outage registers (tick >= start), commitments are frozen
    # or shrinking until the window clears.
    inside = committed[11:40]
    assert all(b <= a + 1e-6 for a, b in zip(inside, inside[1:]))
    assert float(state.faults.unavail_ticks) >= 30.0 * spot.N_TYPES - 1e-6


def test_telemetry_dropout_and_delay_counters():
    cfg = _cfg(faults.FaultConfig())
    spec = faults.make_fault_spec(p_meas_drop=0.3, p_meas_delay=0.3)
    final, _ = runner.scan_run(SCHED, cfg, seed=2, trace=False, fspec=spec)
    assert float(final.faults.n_dropped) > 0
    assert float(final.faults.n_delayed) > 0


def test_straggler_slows_completion():
    cfg_off = _cfg(bid_policy="on_demand", p_spike_per_core=0.0)
    cfg_on = dataclasses.replace(cfg_off, faults=faults.FaultConfig())
    spec = faults.make_fault_spec(p_straggle=8.0, straggle_ticks=6.0,
                                  straggle_factor=4.0)
    a = sweep.summarize(runner.scan_run(SCHED, cfg_off, seed=4,
                                        trace=False)[0], SCHED, cfg_off)
    b = sweep.summarize(runner.scan_run(SCHED, cfg_on, seed=4, trace=False,
                                        fspec=spec)[0], SCHED, cfg_on)
    # Slowed service must not *reduce* the bill-to-completion and must
    # not magically finish more work.
    assert float(b.cost) >= float(a.cost) - 1e-6
    assert int(b.finished) <= int(a.finished)


# ----------------------------------------------- hardened primitives --

def test_kalman_dropped_inflates_covariance_only():
    p = ControlParams()
    kf = kalman.init(2, 1)
    meas = jnp.ones((2, 1), jnp.float32)
    mask = jnp.ones((2, 1), bool)
    kf = kalman.step(kf, meas, mask, p)  # bootstrap both filters
    dropped = jnp.asarray([[True], [False]])
    kf2 = kalman.step(kf, jnp.zeros((2, 1)), jnp.zeros((2, 1), bool), p,
                      dropped=dropped)
    # Dropped filter coasts (prediction unchanged) with inflated variance.
    assert jnp.array_equal(kf2.b_hat, kf.b_hat)
    assert float(kf2.pi[0, 0]) == pytest.approx(
        float(kf.pi[0, 0]) + p.sigma_z2)
    assert float(kf2.pi[1, 0]) == pytest.approx(float(kf.pi[1, 0]))


def test_select_type_hedges_around_unavailable():
    prices = jnp.asarray(spot.SPOT_BASE_TABLE)
    bids = prices * 10.0
    mix = jnp.ones((spot.N_TYPES,), jnp.float32)
    best, ok = spot.select_type(prices, bids, mix)
    assert bool(ok)
    avail = jnp.ones((spot.N_TYPES,), bool).at[best].set(False)
    alt, ok2 = spot.select_type(prices, bids, mix, avail=avail)
    assert bool(ok2) and int(alt) != int(best)
    none_left = jnp.zeros((spot.N_TYPES,), bool)
    _, ok3 = spot.select_type(prices, bids, mix, avail=none_left)
    assert not bool(ok3)


def test_backoff_bounded_and_jittered():
    cap = 8.0
    for streak in (1.0, 3.0, 10.0, 1e6):
        for u in (0.0, 0.5, 0.999):
            d = float(aimd.backoff_delay(jnp.asarray(streak), cap,
                                         jnp.asarray(u)))
            assert 0.5 * 2.0 <= d + 1e-6  # streak >= 1 waits >= 1 tick
            assert d <= cap * 1.5 + 1e-6  # bounded even at huge streaks
    # Monotone in the streak at fixed jitter (until the cap).
    d1 = float(aimd.backoff_delay(jnp.asarray(1.0), cap, jnp.asarray(0.5)))
    d2 = float(aimd.backoff_delay(jnp.asarray(2.0), cap, jnp.asarray(0.5)))
    assert d2 > d1


# ------------------------------------------------------- sweep surface --

def test_sweepspec_fault_axis_validation():
    axes = sweep.make_axes(seeds=[0, 1], bid_mults=[1.0])
    bad = faults.make_fault_spec()._replace(
        p_outage=jnp.zeros((3,), jnp.float32))  # B=2 grid, (3,) leaf
    with pytest.raises(ValueError):
        SweepSpec(axes=axes, workload=SCHED, faults=bad)
    with pytest.raises(TypeError):
        SweepSpec(axes=axes, workload=SCHED, faults=(1.0,) * 12)
    ok = SweepSpec(axes=axes, workload=SCHED,
                   faults=faults.make_fault_spec(p_slot_fail=1.0))
    with pytest.raises(ValueError):
        sweep.sweep(ok, _cfg())  # spec.faults without cfg.faults


def test_fault_axis_sweep_matches_single_runs():
    """A (B,)-leaved fault axis reproduces per-point single runs."""
    cfg = _cfg(faults.FaultConfig())
    axes = sweep.make_axes(seeds=[5, 5], bid_mults=[1.0])
    rates = jnp.asarray([0.0, 5.0], jnp.float32)
    fsb = faults.FaultSpec(*(
        jnp.broadcast_to(jnp.asarray(x, jnp.float32), (2,))
        for x in faults.make_fault_spec()))._replace(p_slot_fail=rates)
    batch = sweep.sweep(SweepSpec(axes=axes, workload=SCHED, faults=fsb),
                        cfg)
    for i, r in enumerate([0.0, 5.0]):
        one = sweep.sweep(
            SweepSpec(axes=sweep.make_axes(seeds=[5], bid_mults=[1.0]),
                      workload=SCHED,
                      faults=faults.make_fault_spec(p_slot_fail=r)), cfg)
        assert float(batch.cost[i]) == float(one.cost[0]), i


# ----------------------------------------------------------- ft shim --

def test_ft_injector_rides_the_shared_engine():
    from repro.ft.failures import FailureConfig, FailureInjector
    inj = FailureInjector(FailureConfig(p_fail=0.05, p_straggle=0.2,
                                        straggle_factor=5.0, seed=1),
                          horizon_steps=64)
    reps = list(range(8))
    seen_fail = seen_straggle = False
    for step_i in range(64):
        failed, stragglers, _ = inj.step_events(step_i, 0.0, reps)
        seen_fail |= bool(failed)
        seen_straggle |= bool(stragglers)
        for r in stragglers:
            assert inj.slowdown(r, step_i) == 5.0
    assert seen_fail and seen_straggle
    # Determinism: the same seed replays the same timeline.
    inj2 = FailureInjector(FailureConfig(p_fail=0.05, p_straggle=0.2,
                                         straggle_factor=5.0, seed=1),
                           horizon_steps=64)
    assert np.array_equal(inj._kill, inj2._kill)
    assert np.array_equal(inj._straggling, inj2._straggling)
