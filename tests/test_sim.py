"""End-to-end behaviour of the §V testbed."""

import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import SimConfig, paper_schedule, run, uniform_schedule
from repro.sim.runner import total_cost
from repro.sim.workloads import FACE

PARAMS = ControlParams(monitor_dt=300.0)
BILL = BillingParams(terminate="immediate")   # paper-faithful semantics


def _cfg(policy="aimd", **kw):
    return SimConfig(ctrl=ControllerConfig(policy=policy, params=PARAMS,
                                           billing=BILL, **kw), ticks=130)


@pytest.fixture(scope="module")
def aimd_trace():
    return run(paper_schedule(ttc=7500.0, arrival_gap_ticks=1), _cfg())


def test_all_workloads_complete(aimd_trace):
    assert int((aimd_trace.work_final.t_done >= 0).sum()) == 30


def test_no_ttc_violations(aimd_trace):
    assert int(aimd_trace.violations) == 0


def test_work_conservation(aimd_trace):
    assert float(aimd_trace.work_final.m.sum()) == pytest.approx(0.0)


def test_cost_above_lower_bound(aimd_trace):
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    lb = sched.total_cus / 3600 * 0.0081
    assert total_cost(aimd_trace) > lb


def test_fleet_within_bounds(aimd_trace):
    n = np.asarray(aimd_trace.n_committed)
    assert n.max() <= PARAMS.n_max and n.min() >= 0


def test_autoscale_costs_more_than_aimd(aimd_trace):
    tr_as = run(paper_schedule(ttc=7500.0, arrival_gap_ticks=1),
                _cfg("autoscale", as_step=10.0))
    assert total_cost(tr_as) > 1.5 * total_cost(aimd_trace)


def test_aimd_cheaper_than_reactive(aimd_trace):
    tr = run(paper_schedule(ttc=7500.0, arrival_gap_ticks=1),
             _cfg("reactive"))
    assert total_cost(aimd_trace) < total_cost(tr) * 1.05


def test_kalman_reaches_reliability():
    tr = run(paper_schedule(ttc=7500.0, arrival_gap_ticks=1), _cfg())
    rel = np.asarray(tr.reliable[-1, :, 0])
    # Small workloads legitimately finish on the bootstrap trickle before
    # enough measurements exist (at 5-min monitoring ~1/3 of the suite);
    # the substantial workloads must all reach a reliable prediction.
    assert rel.mean() >= 0.6
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    total = sched.m0[:, 0] * sched.b_true[:, 0]
    assert rel[total > 2000].all()


def test_single_workload_completes():
    sched = uniform_schedule(1, FACE, items=200, item_cus=2.0, ttc=3000.0)
    tr = run(sched, _cfg())
    assert int(tr.work_final.t_done[0]) >= 0


def test_deterministic_given_seed():
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    a = total_cost(run(sched, _cfg()))
    b = total_cost(run(sched, _cfg()))
    assert a == b
