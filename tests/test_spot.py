"""Spot-market subsystem: price process, preemptive billing, vmapped sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import billing
from repro.core.types import BillingParams, ControlParams
from repro.core.controller import ControllerConfig
from repro.sim import (SimConfig, SpotConfig, make_axes,
                       paper_schedule, run, run_single, run_sweep, spot)

PARAMS = ControlParams(monitor_dt=300.0)
BILL = BillingParams(terminate="immediate")


def _spot_cfg(**kw):
    return SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL),
        ticks=130, spot=SpotConfig(enabled=True, **kw))


# ---------------------------------------------------------------- process --

def test_price_trace_constant_without_noise():
    cfg = SpotConfig(vol0=0.0, vol_scale=0.0, p_spike_per_core=0.0)
    rt = spot.make_runtime(cfg)
    tr = spot.price_trace(rt, 24, jax.random.PRNGKey(0), cfg)
    np.testing.assert_allclose(np.asarray(tr),
                               spot.INSTANCE_TYPES["m3.medium"][2],
                               rtol=1e-6)


def test_runtime_resolves_table_v():
    rt = spot.make_runtime(SpotConfig(instance="m4.10xlarge"))
    cores, on_demand, base = spot.INSTANCE_TYPES["m4.10xlarge"]
    assert float(rt.cores) == cores
    assert float(rt.on_demand) == pytest.approx(on_demand)
    assert float(rt.base_price) == pytest.approx(base)
    assert float(rt.bid) == pytest.approx(1.5 * base)


def test_on_demand_bid_policy():
    rt = spot.make_runtime(SpotConfig(bid_policy="on_demand"))
    assert float(rt.bid) == pytest.approx(
        spot.INSTANCE_TYPES["m3.medium"][1])


def test_trace_preemption_mask_monotone_in_bid():
    """For a fixed price path, raising the bid can only shrink the set of
    outbid steps."""
    rt = spot.make_runtime(SpotConfig(instance="m4.10xlarge"))
    tr = spot.price_trace(rt, 500, jax.random.PRNGKey(3))
    base = float(rt.base_price)
    counts = [int(spot.preemptions(tr, b * base).sum())
              for b in (0.9, 1.0, 1.2, 1.5, 3.0, 10.0)]
    assert counts == sorted(counts, reverse=True)
    assert counts[0] > 0


def test_price_trace_deterministic_and_preemption_bounds():
    """The guarantees the old numpy ``market`` facade pinned, now on the
    JAX process directly (``ft.failures`` draws its reclaim hours from
    exactly this trace): seed-determinism, positivity, and the preemption
    mask hitting its bounds at infinite / zero bids."""
    rt = spot.make_runtime(SpotConfig(instance="m3.large"))
    tr = np.asarray(spot.price_trace(rt, 48, jax.random.PRNGKey(7)))
    assert tr.shape == (48,) and (tr > 0).all()
    np.testing.assert_array_equal(
        tr, np.asarray(spot.price_trace(rt, 48, jax.random.PRNGKey(7))))
    assert int(np.asarray(spot.preemptions(tr, np.inf)).sum()) == 0
    assert int(np.asarray(spot.preemptions(tr, 0.0)).sum()) == 48


# ---------------------------------------------------------------- billing --

def test_spot_cost_accounting_hand_trace():
    """Start, renew and preempt at known prices; compare $ by hand."""
    bp = BillingParams(boot_delay=0.0, terminate="immediate")
    c = billing.init(4)
    # Start 2 instances at $0.010/quantum each.
    c = billing.scale_to(c, jnp.asarray(2.0), bp, price=0.010, bid=0.012)
    assert float(c.cum_cost) == pytest.approx(0.020)
    # Cross one quantum boundary while the price sits at $0.015: both renew.
    c = billing.advance(c, bp.quantum + 1.0, bp, price=0.015)
    assert float(c.cum_cost) == pytest.approx(0.020 + 2 * 0.015)
    # Market clears above the recorded bid: both slots are taken, no charge,
    # no refund for the just-renewed quanta.
    c, n = billing.preempt(c, jnp.asarray(0.013))
    assert float(n) == 2 and float(c.n_preempt) == 2
    assert float(billing.capacity(c)) == 0
    assert float(c.cum_cost) == pytest.approx(0.020 + 2 * 0.015)


def test_preempt_spares_bids_above_price():
    bp = BillingParams(boot_delay=0.0)
    c = billing.init(4)
    c = billing.scale_to(c, jnp.asarray(3.0), bp, price=0.01, bid=0.02)
    c, n = billing.preempt(c, jnp.asarray(0.015))
    assert float(n) == 0 and float(billing.committed(c)) == 3


def test_outbid_requests_not_fulfilled():
    bp = BillingParams(boot_delay=0.0)
    c = billing.init(4)
    c = billing.scale_to(c, jnp.asarray(3.0), bp, price=0.03, bid=0.02,
                         allow_start=jnp.asarray(False))
    assert float(billing.committed(c)) == 0
    assert float(c.cum_cost) == 0.0


def test_cores_scale_cu_accounting():
    bp = BillingParams(boot_delay=0.0)
    c = billing.scale_to(billing.init(4), jnp.asarray(2.0), bp)
    c = billing.advance(c, 1.0, bp)
    assert float(billing.capacity(c, 40.0)) == 80.0
    assert float(billing.usable(c, 40.0)) == 80.0


# ------------------------------------------------------------- simulation --

SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)


def test_sim_outage_monotone_and_low_bid_preempts():
    """End-to-end monotonicity: the market's price path depends only on the
    seed, so the number of ticks the fleet is *outbid* can only shrink as
    the bid rises.  (Preemption *event* counts are not per-seed monotone:
    a rock-bottom bid annihilates the fleet at the first spike and an empty
    fleet has nothing left to preempt — so the guaranteed quantity is
    outage time, with event counts compared at the bid extremes.)"""
    cfg = _spot_cfg()
    bids = [1.02, 1.5, 8.0]
    base = spot.INSTANCE_TYPES["m3.medium"][2]
    for seed in (0, 1):
        outages = []
        for b in bids:
            rt = spot.make_runtime(cfg.spot, bid_mult=b)
            tr = run(SCHED, cfg, seed=seed, spot_rt=rt)
            outages.append(int((np.asarray(tr.spot_price) > b * base).sum()))
        assert outages == sorted(outages, reverse=True)
    axes = make_axes(seeds=[0, 1, 2, 3], bid_mults=bids)
    s = run_sweep(SCHED, cfg, axes)
    pre = np.asarray(s.preemptions).reshape(4, 3)
    assert pre[:, 0].sum() > 0             # lowest bid actually gets hit
    assert pre[:, 0].sum() > pre[:, -1].sum()
    assert pre[:, -1].sum() == 0           # 8x base is never outbid here


def test_sim_completes_despite_preemptions():
    """AIMD re-grows the fleet after market reclamations: the full suite
    still finishes inside its SLA at a bid barely above base price."""
    r = run_single(SCHED, _spot_cfg(), seed=1, bid_mult=1.02)
    assert float(r.preemptions) > 0
    assert int(r.finished) == SCHED.n
    assert int(r.violations) == 0


def test_sim_hopeless_bid_reads_as_broken_not_cheap():
    """A bid the market immediately clears above kills the fleet for the
    spike's whole duration; the run must surface that as violations and a
    full-horizon bill, not as a cheap success (total_cost satellite fix)."""
    r = run_single(SCHED, _spot_cfg(), seed=0, bid_mult=0.5)
    assert int(r.finished) < SCHED.n
    assert int(r.violations) > 0
    assert float(r.cost) == pytest.approx(float(r.cost_horizon))


def test_vmapped_sweep_equals_python_loop():
    """One jitted vmap over the grid == looping single jitted runs."""
    cfg = _spot_cfg()
    seeds, bids = [0, 1], [1.02, 2.0]
    axes = make_axes(seeds=seeds, bid_mults=bids)
    batched = run_sweep(SCHED, cfg, axes)
    i = 0
    for seed in seeds:
        for bid in bids:
            single = run_single(SCHED, cfg, seed=seed, bid_mult=bid)
            for field in single._fields:
                if getattr(single, field) is None:
                    continue   # e.g. alerts without obs.detect
                np.testing.assert_allclose(
                    np.asarray(getattr(batched, field))[i],
                    np.asarray(getattr(single, field)),
                    rtol=1e-5, err_msg=f"{field} @ seed={seed} bid={bid}")
            i += 1


def test_spot_disabled_path_never_preempts():
    cfg = SimConfig(ctrl=ControllerConfig(params=PARAMS, billing=BILL),
                    ticks=130)
    tr = run(SCHED, cfg)
    assert float(tr.n_preempted[-1]) == 0.0
    np.testing.assert_allclose(np.asarray(tr.spot_price),
                               BILL.price_per_quantum, rtol=1e-6)


def test_granularity_large_instances_cost_more():
    """Appendix A Table V: per-CU spot price and volatility grow with
    instance size, so coarse fleets are strictly worse on this schedule."""
    cfg = _spot_cfg(bid_policy="on_demand")
    axes = make_axes(seeds=[0], bid_mults=[1.5],
                     instances=["m3.medium", "m4.10xlarge"])
    s = run_sweep(SCHED, cfg, axes)
    cost = np.asarray(s.cost)
    assert cost[1] > 1.5 * cost[0]
