"""Host-side observability tooling: cross-run attribution
(``repro.obs.compare``), OpenMetrics exposition + live sweep tailing
(``repro.obs.metrics``), the labelled Perfetto tracks, the pandas-free
export paths, and the CI gate's first-divergence attribution hookup.

Everything here is pure host-side plumbing — no scans compile — so the
file doubles as the place the export/report schema is pinned.
"""

import json
import pathlib
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.obs import (Divergence, ObsReport, attribution, diff_bench,
                       diff_reports, export, to_openmetrics)
from repro.obs import ledger as ledger_lib
from repro.obs import metrics

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(REPO))
from benchmarks import check_bench_regression as cbr  # noqa: E402
from benchmarks import run as bench_run  # noqa: E402

BASELINE_OBS = REPO / "benchmarks" / "baselines" / "BENCH_obs.json"


def _rec(tick, kind, tenant=ledger_lib.NO_TENANT, value=1.0, severity=0):
    return ledger_lib.LedgerRecord(
        tick=tick, kind=kind, kind_name=ledger_lib.KIND_NAMES[kind],
        tenant=tenant, value=value, severity=severity)


def _report(**kw):
    base = dict(spec=None, counters={"preemptions": 2.0}, kalman=None,
                preempt_by_type=np.array([1.0, 1.0]), kill_by_type=None,
                rejects=None, queue_hist=None,
                queue_percentiles={0.5: 3.0, 0.9: 7.0},
                ledger=[_rec(1, ledger_lib.KIND_PREEMPT),
                        _rec(4, ledger_lib.KIND_ADM_REJECT, tenant=2)],
                ledger_dropped=0, detect=None)
    base.update(kw)
    return ObsReport(**base)


# ------------------------------------------------------------------ compare

def test_diff_reports_identical_is_empty():
    assert diff_reports(_report(), _report()) == []


def test_diff_reports_localizes_family_and_tick():
    """The first divergence is the *earliest probe family* in canonical
    order, then the earliest tick inside it — a perturbed per-type
    preempt register outranks a later ledger drift."""
    cur = _report(preempt_by_type=np.array([1.0, 9.0]),
                  ledger=[_rec(1, ledger_lib.KIND_PREEMPT),
                          _rec(3, ledger_lib.KIND_KILL)])
    divs = diff_reports(cur, _report())
    assert divs, "expected divergences"
    first = divs[0]
    assert isinstance(first, Divergence)
    assert first.family == "preempt_by_type"
    assert first.tick == 1
    d = first.to_dict()
    assert d["current"] != d["baseline"]
    assert {"family", "path", "tick"} <= set(d)
    assert any(v.family == "ledger" and v.tick == 3 for v in divs)


def test_diff_bench_splits_signal_from_noise():
    """Wall-clock leaves are noise, deterministic leaves are signal, and
    digests rank ahead of numeric drift."""
    base = {"neutrality": {"digest": "aaa", "sweep_exact": True},
            "overhead": {"steady_s": 0.5},
            "exports": {"total_s": 1.0, "ledger_events": 3}}
    cur = json.loads(json.dumps(base))
    cur["neutrality"]["digest"] = "bbb"
    cur["overhead"]["steady_s"] = 0.9          # noise: _s suffix
    cur["exports"]["total_s"] = 2.0            # noise
    cur["exports"]["ledger_events"] = 5        # signal
    signal, noise = diff_bench(cur, base)
    assert signal[0].path == "neutrality.digest"
    assert {s.path for s in signal if "ledger" in s.path} == {
        "exports.ledger_events"}
    assert {n.path for n in noise} == {"overhead.steady_s",
                                       "exports.total_s"}
    rep = attribution(cur, base, gate_errors=["digest changed"])
    assert rep["first_divergence"]["path"] == "neutrality.digest"
    assert rep["n_noise"] == 2 and rep["gate_errors"] == ["digest changed"]


# ---------------------------------------------------- CI gate + attribution

def test_gate_errors_dispatches_by_kind():
    baseline = json.loads(BASELINE_OBS.read_text())
    assert cbr.gate_errors(baseline, baseline) == []
    assert "kind mismatch" in cbr.gate_errors({"kind": "chaos"},
                                              baseline)[0]


def test_induced_gate_failure_prints_attribution(tmp_path, capsys):
    """ISSUE acceptance: tamper a BENCH artifact, run the gate, and the
    failure comes with a first-divergence localization on stderr plus a
    written attribution report."""
    tampered = json.loads(BASELINE_OBS.read_text())
    tampered["neutrality"]["digest"] = "deadbeef"
    cur = tmp_path / "BENCH_obs.json"
    cur.write_text(json.dumps(tampered))

    attributions = []
    rc = cbr.check_pair(str(cur), str(BASELINE_OBS), attributions)
    err = capsys.readouterr().err
    assert rc == 1
    assert "REGRESSION" in err
    assert "ATTRIBUTION: first divergence at neutrality.digest" in err
    assert len(attributions) == 1
    assert attributions[0]["first_divergence"]["path"] == "neutrality.digest"

    out = tmp_path / "attr.json"
    cbr.write_attribution(attributions, str(out))
    written = json.loads(out.read_text())
    assert written["attributions"][0]["baseline"] == "BENCH_obs.json"


def test_obs_gate_catches_calibration_regressions():
    baseline = json.loads(BASELINE_OBS.read_text())
    broken = json.loads(BASELINE_OBS.read_text())
    broken["calibration"]["clean"]["alerts"] = 3
    broken["calibration"]["scenarios"]["blackout"]["alerts_per_seed"] = [0, 0]
    errs = "\n".join(cbr.check_obs(broken, baseline))
    assert "clean paper replay fired 3 alert(s)" in errs
    assert "missed the injected fault" in errs


def test_run_json_gate_status(tmp_path, monkeypatch):
    """run.py's --json report carries the per-suite regression-gate
    verdict for every artifact with a committed baseline."""
    monkeypatch.chdir(tmp_path)
    results = tmp_path / "results"
    results.mkdir()
    artifact = results / "BENCH_obs.json"
    artifact.write_text(BASELINE_OBS.read_text())
    verdict, errors = bench_run._suite_gate(started=0.0)
    assert verdict is True and errors == []

    tampered = json.loads(BASELINE_OBS.read_text())
    tampered["acceptance"]["overhead_bounded"] = False
    artifact.write_text(json.dumps(tampered))
    verdict, errors = bench_run._suite_gate(started=0.0)
    assert verdict is False
    assert any("overhead" in e for e in errors)

    (results / "BENCH_nobaseline.json").write_text("{}")
    artifact.unlink()
    assert bench_run._suite_gate(started=0.0) == (None, [])


# -------------------------------------------------------------- openmetrics

def test_openmetrics_exposition_format():
    report = _report(
        counters={"preemptions": 2.0, "alerts_total": 3.0,
                  "ledger_events": 2.0},
        detect={"alerts_total": 3,
                "alerts_by_family": {"cusum": 1, "burn": 2},
                "first_tick_by_family": {"cusum": 19, "burn": 22,
                                         "ewma": -1}})
    text = to_openmetrics(report, prefix="repro")
    assert text.endswith("# EOF\n")
    assert "repro_preemptions 2" in text
    assert 'repro_alerts{family="burn"} 2' in text
    assert 'repro_alert_first_tick{family="cusum"} 19' in text
    assert 'repro_ledger_events{kind="preempt"} 1' in text
    # Mirrored counters must not duplicate the labelled families.
    assert "repro_alerts_total 3\n# EOF" not in text
    assert text.count("# TYPE repro_alerts gauge") == 1
    # One TYPE declaration per metric family, no duplicates.
    types = [ln for ln in text.splitlines() if ln.startswith("# TYPE")]
    assert len(types) == len(set(types))


def test_write_openmetrics_is_atomic(tmp_path):
    path = tmp_path / "metrics.prom"
    metrics.write_openmetrics(_report(), str(path))
    assert path.read_text().endswith("# EOF\n")
    assert not (tmp_path / "metrics.prom.tmp").exists()


# ------------------------------------------------------------- sweep tailing

def _fake_stream(root: pathlib.Path, n_chunks=3, chunk=2, n_points=5,
                 committed=None):
    root.mkdir(parents=True, exist_ok=True)
    (root / "sweep_manifest.json").write_text(json.dumps(
        {"schema": 1, "digest": "d", "n_points": n_points, "chunk": chunk,
         "n_chunks": n_chunks}))
    for i in committed if committed is not None else range(n_chunks):
        rows = min(chunk, n_points - i * chunk)
        step = root / f"step_{i:08d}"
        step.mkdir()
        leaves = {}
        for name, fill in (("violations", 1.0), ("alerts", 2.0)):
            fname = f"{name}.npy"
            np.save(step / fname, np.full((rows,), fill))
            leaves[name] = {"file": fname, "shape": [rows],
                            "dtype": "float64", "sha256": "x"}
        (step / "manifest.json").write_text(json.dumps(
            {"step": i, "leaves": leaves}))
        (root / f"step_{i:08d}.done").write_text("")
        time.sleep(0.01)   # distinct mtimes give the ETA a rate


def test_snapshot_progress_totals_and_eta(tmp_path):
    _fake_stream(tmp_path / "s", committed=[0, 1])
    s = metrics.snapshot(str(tmp_path / "s"))
    assert (s["chunks_done"], s["n_chunks"]) == (2, 3)
    assert s["rows_done"] == 4 and not s["complete"]
    assert s["totals"] == {"violations": 4.0, "alerts": 8.0}
    assert s["eta_s"] is not None and s["eta_s"] >= 0.0
    line = metrics.format_snapshot(s)
    assert "[2/3 chunks]" in line and "alerts=8" in line


def test_watch_returns_when_complete(tmp_path):
    _fake_stream(tmp_path / "s")
    lines = []
    s = metrics.watch(str(tmp_path / "s"), interval=0.0,
                      emit=lines.append)
    assert s["complete"] and s["rows_done"] == 5
    assert lines and "[3/3 chunks]" in lines[-1]
    # The last committed chunk is short (5 rows / chunks of 2).
    assert s["totals"]["violations"] == 5.0


def test_watch_honors_max_updates_on_a_stalled_sweep(tmp_path):
    _fake_stream(tmp_path / "s", committed=[0])
    lines = []
    s = metrics.watch(str(tmp_path / "s"), interval=0.0,
                      emit=lines.append, max_updates=2)
    assert not s["complete"] and len(lines) == 2


# ------------------------------------------------------- pandas-free exports

def _hide_pandas(monkeypatch):
    # pandas IS installed in this environment; make `import pandas`
    # raise to prove the dependency really is optional.
    monkeypatch.setitem(sys.modules, "pandas", None)


def test_to_dataframe_without_pandas_raises_naming_it(monkeypatch):
    _hide_pandas(monkeypatch)
    with pytest.raises(ImportError, match="pandas"):
        _report().to_dataframe()


def test_to_jsonl_is_pandas_free(monkeypatch, tmp_path):
    _hide_pandas(monkeypatch)
    path = tmp_path / "run.jsonl"
    _report(ledger_dropped=1).to_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[0]["record"] == "counters"
    assert lines[0]["ledger_dropped"] == 1
    events = lines[1:]
    assert [e["tick"] for e in events] == [1, 4]
    assert events[1]["kind_name"] == "adm_reject"
    assert events[1]["tenant"] == 2


# ------------------------------------------------------- trace-event labels

def test_trace_tracks_carry_process_and_thread_names():
    """Perfetto metadata (ISSUE satellite): a process_name record, one
    thread_name per track, and tenant-/subject-scoped events fanned out
    onto labelled sub-tracks."""
    report = _report(ledger=[
        _rec(1, ledger_lib.KIND_PREEMPT),
        _rec(4, ledger_lib.KIND_ADM_REJECT, tenant=2),
        _rec(19, ledger_lib.KIND_ALERT_CUSUM, tenant=6,
             severity=ledger_lib.SEV_PAGE),          # market_unavail
        _rec(22, ledger_lib.KIND_ALERT_BURN, tenant=3,
             severity=ledger_lib.SEV_WARN),          # unavail window
    ])
    events = export.run_trace_events(report, dt=300.0)
    procs = [e for e in events
             if e["ph"] == "M" and e["name"] == "process_name"]
    assert [p["args"]["name"] for p in procs] == ["sim-run"]
    threads = {e["tid"]: e["args"]["name"] for e in events
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert set(threads.values()) == {
        "preempt", "adm_reject/tenant2",
        "alert_cusum/market_unavail", "alert_burn/unavail"}

    inst = {e["name"]: e for e in events if e["ph"] == "i"}
    cusum = inst["alert_cusum"]
    assert cusum["args"]["subject"] == "market_unavail"
    assert cusum["args"]["severity"] == "page"
    assert cusum["ts"] == 19 * 300.0 * 1e6
    assert threads[cusum["tid"]] == "alert_cusum/market_unavail"
    burn = inst["alert_burn"]
    assert burn["args"]["severity"] == "warn"
    # Fleet-level events stay on the plain per-kind track.
    assert inst["preempt"]["tid"] == ledger_lib.KIND_PREEMPT
    assert inst["preempt"]["args"]["severity"] == "info"


# ------------------------------------------------------------ ledger drain

def test_drain_is_chronological_with_severity_after_wrap():
    """Satellite (a): drain() returns push order even across a wrap, so
    ticks are monotonically non-decreasing and the alert metadata
    (severity, subject) survives the ring."""
    import jax.numpy as jnp

    led = ledger_lib.init(4)
    for t, kind, sev in ((0, ledger_lib.KIND_PREEMPT, 0),
                         (2, ledger_lib.KIND_ALERT_CUSUM, 2),
                         (2, ledger_lib.KIND_ALERT_BURN, 1),
                         (5, ledger_lib.KIND_KILL, 0),
                         (7, ledger_lib.KIND_ALERT_EWMA, 1),
                         (9, ledger_lib.KIND_SHED, 0)):
        led = ledger_lib.push(led, jnp.asarray(True), t, kind, 1.0,
                              severity=sev)
    recs, dropped = ledger_lib.drain(led)
    assert dropped == 2
    ticks = [r.tick for r in recs]
    assert ticks == sorted(ticks) == [2, 5, 7, 9]
    assert [r.severity for r in recs] == [1, 0, 1, 0]
    assert recs[0].kind_name == "alert_burn"


def test_check_regression_cli_auto_smoke():
    """The --auto CLI form CI runs: against the committed baselines with
    current results absent it must fail loudly, not crash."""
    p = subprocess.run(
        [sys.executable, "benchmarks/check_bench_regression.py", "--auto",
         "--results-dir", "does_not_exist"],
        cwd=REPO, capture_output=True, text=True)
    assert p.returncode == 1
    assert "missing" in p.stderr
