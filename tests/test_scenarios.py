"""Scenario engine: stochastic workload generators as a sweep axis (ISSUE 4).

The contract under test: generators emit padded, masked schedules whose
statistics match their specs (Poisson rate, MMPP burst lengths, Pareto
tail index), the ``paper`` replay is exactly the static §V.A schedule,
padding can neither bill nor violate, and a seeds × bids × policies ×
scenarios grid through ``run_sweep`` equals the loop of single runs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, strategies as st
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (
    ScenarioSet,
    SimConfig,
    SpotConfig,
    default_set,
    make_axes,
    paper_schedule,
    run_single,
    run_sweep,
)
from repro.sim import runner, scenarios, sweep
from repro.sim import workloads as wl
from repro.sim.scenarios import (
    MMPP,
    Diurnal,
    FlashCrowd,
    Poisson,
    Replay,
    TaskModel,
    heavy_tail,
)

PARAMS = ControlParams(monitor_dt=300.0)
BILL = BillingParams(terminate="immediate")


def _spot_cfg(ticks=60, **kw):
    return SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL),
        ticks=ticks,
        spot=SpotConfig(enabled=True, **kw),
    )


# ------------------------------------------------------------ generators --


def test_poisson_empirical_rate_matches_lambda():
    spec = Poisson(rate=0.4, horizon=60, max_w=96)
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    scheds = jax.vmap(spec.sample)(keys)
    counts = np.asarray(jnp.sum(scheds.valid, axis=-1))
    rate_hat = counts.mean() / spec.horizon
    # 200 × Poisson(24): std of the mean ≈ 0.35 arrivals → ~4σ tolerance.
    assert rate_hat == pytest.approx(spec.rate, rel=0.06)
    # Arrivals land inside the horizon, padding is marked.
    t = np.asarray(scheds.t_arrive)
    v = np.asarray(scheds.valid)
    assert ((t >= 0) & (t < spec.horizon))[v].all()
    assert (t[~v] == -1).all()


def test_mmpp_burst_lengths_and_burstiness():
    spec = MMPP(rate_lo=0.05, rate_hi=2.0, p_up=0.05, p_down=0.2, horizon=4000)
    rates = np.asarray(spec.rate_path(jax.random.PRNGKey(1)))
    hi = rates > spec.rate_lo
    # Mean sojourn in the burst state is geometric: 1 / p_down ticks.
    runs, cur = [], 0
    for x in hi:
        if x:
            cur += 1
        elif cur:
            runs.append(cur)
            cur = 0
    if cur:
        runs.append(cur)
    assert len(runs) > 50
    assert np.mean(runs) == pytest.approx(1.0 / spec.p_down, rel=0.25)
    # Burst-time fraction ≈ p_up / (p_up + p_down).
    frac = spec.p_up / (spec.p_up + spec.p_down)
    assert hi.mean() == pytest.approx(frac, rel=0.3)
    # Arrival counts are over-dispersed vs Poisson (index of dispersion > 1).
    keys = jax.random.split(jax.random.PRNGKey(2), 200)
    small = dataclasses.replace(spec, horizon=60, max_w=256)
    counts = np.asarray(jnp.sum(jax.vmap(small.sample)(keys).valid, -1))
    assert counts.var() / counts.mean() > 1.5


def test_pareto_tail_index_hill_estimator():
    tm = TaskModel(size_dist="pareto", pareto_alpha=1.6)
    raw = scenarios.sample_size_mult(jax.random.PRNGKey(3), (20000,), tm)
    x = np.sort(np.asarray(raw))[::-1]
    k = 2000  # top-10% order statistics
    hill = 1.0 / np.mean(np.log(x[:k] / x[k]))
    assert hill == pytest.approx(tm.pareto_alpha, rel=0.1)
    # Heavier than any lognormal the default model would produce.
    assert x.max() > 20.0


def test_diurnal_rate_modulation():
    spec = Diurnal(rate=1.0, amp=0.8, period=24, horizon=48, random_phase=False)
    rates = np.asarray(spec.rate_path(jax.random.PRNGKey(0)))
    assert rates.min() == pytest.approx(1.0 - spec.amp, abs=1e-5)
    assert rates.max() == pytest.approx(1.0 + spec.amp, abs=1e-5)
    assert rates.mean() == pytest.approx(1.0, abs=0.01)


def test_flash_crowd_spike_present_once():
    spec = FlashCrowd(rate=0.1, spike_rate=5.0, spike_ticks=4, horizon=60)
    rates = np.asarray(spec.rate_path(jax.random.PRNGKey(7)))
    spiked = rates > spec.rate
    assert spiked.sum() == spec.spike_ticks
    # Contiguous block.
    idx = np.flatnonzero(spiked)
    assert (np.diff(idx) == 1).all()


def test_paper_replay_bit_exact_against_static_schedule():
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    spec = Replay(sched, name="paper")
    out = spec.sample(jax.random.PRNGKey(0))
    ref = sched.as_jax()
    for f in wl.JaxSchedule._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(out, f)), np.asarray(getattr(ref, f)), err_msg=f
        )
    assert bool(np.asarray(out.valid).all())


@given(st.floats(min_value=0.05, max_value=2.0))
@settings(max_examples=20, deadline=None)
def test_poisson_valid_counts_bounded_property(rate):
    spec = Poisson(rate=float(rate), horizon=40, max_w=128)
    sj = spec.sample(jax.random.PRNGKey(11))
    n = int(np.asarray(sj.valid).sum())
    assert 0 <= n <= 128
    t = np.asarray(sj.t_arrive)
    assert (t[np.asarray(sj.valid)] < 40).all()


# --------------------------------------------------------------- masking --


def test_count_violations_and_cost_honor_valid_mask():
    """Padding that *looks* submitted-but-unfinished must not count."""
    base = wl.uniform_schedule(2, 0, items=10, item_cus=1.0, ttc=600.0)
    sched = wl.pad_schedule(base.as_jax(), 4)
    w = sched.n
    work = runner.WorkloadState(
        active=jnp.zeros((w,), bool),
        m=jnp.zeros((w, 1)),
        m0=sched.m0,
        b_true=sched.b_true,
        d=sched.d_requested,
        d_requested=sched.d_requested,
        confirmed=jnp.zeros((w,), bool),
        t_submit=jnp.asarray([0, 0, 5, 5]),  # padding rows claim submission
        t_done=jnp.asarray([3, -1, -1, -1]),  # ... and look unfinished
    )
    cfg = _spot_cfg()
    # Row 1 (real, unfinished) counts; rows 2-3 are padding and must not.
    assert int(runner.count_violations(work, sched, cfg)) == 1
    # An explicit mask overrides the schedule's own.
    mask_all = jnp.ones((w,), bool)
    assert int(runner.count_violations(work, sched, cfg, valid=mask_all)) == 3
    # cost_at_completion: with the mask, the last *real* completion (t=5)
    # is the endpoint; without it the padding keeps the run "unfinished"
    # and the bill runs to the full horizon.
    cum = jnp.arange(10.0)
    work_done = work._replace(t_done=jnp.asarray([3, 5, -1, -1]))
    got = runner.cost_at_completion(work_done, cum, valid=sched.valid)
    assert float(got) == 6.0
    assert float(runner.cost_at_completion(work_done, cum)) == 9.0


def test_padded_run_bills_and_violates_nothing_extra():
    """A schedule padded with inert rows completes, and its padded rows
    never arrive, never bill, never violate."""
    cfg = _spot_cfg(ticks=130)
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    padded = wl.pad_schedule(sched.as_jax(), sched.n + 10)
    r = run_single(padded, cfg, seed=0, bid_mult=1.5)
    assert int(r.finished) == sched.n  # not n + 10
    assert int(r.violations) == 0
    assert float(r.cost) > 0.0
    assert float(r.cost) < float(r.cost_horizon)


# ------------------------------------------------------------ sweep axis --


def test_scenario_grid_single_call_matches_run_single():
    """seeds × bids × policies × scenarios in ONE jitted run_sweep call,
    equal to the loop of standalone runs."""
    tm = TaskModel(ttc=3000.0)
    sset = ScenarioSet(
        (
            Poisson(rate=0.6, horizon=20, max_w=24, tasks=tm),
            MMPP(rate_lo=0.2, rate_hi=2.0, horizon=20, max_w=24, tasks=tm),
        )
    )
    cfg = _spot_cfg(ticks=40)
    seeds, bids, policies = [0, 1], [1.2, 2.0], ["multiple", "ttc"]
    axes = make_axes(seeds=seeds, bid_mults=bids, policies=policies, scenarios=sset)
    batched = run_sweep(sset, cfg, axes)
    i = 0
    for seed in seeds:
        for bid in bids:
            for pol in policies:
                for scen in range(len(sset)):
                    single = run_single(
                        sset, cfg, seed=seed, bid_mult=bid, policy=pol, scenario=scen
                    )
                    for f in single._fields:
                        if getattr(single, f) is None:
                            continue  # e.g. alerts without obs.detect
                        np.testing.assert_allclose(
                            np.asarray(getattr(batched, f))[i],
                            np.asarray(getattr(single, f)),
                            rtol=1e-5,
                            err_msg=f"{f} @ {seed}/{bid}/{pol}/{scen}",
                        )
                    i += 1
    assert i == len(np.asarray(batched.cost))


def test_scenario_sweep_chunked_equals_unchunked():
    sset = default_set(max_w=32, horizon=15)
    cfg = _spot_cfg(ticks=40)
    axes = make_axes(seeds=[0, 1], bid_mults=[1.5], scenarios=sset)
    whole = run_sweep(sset, cfg, axes)
    parts = run_sweep(sset, cfg, axes, chunk_size=3)
    for f in whole._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(whole, f)), np.asarray(getattr(parts, f)), err_msg=f
        )


def test_chunked_prime_grid_drops_all_padding():
    """A prime-sized grid against every chunking relationship: dividing,
    non-dividing (padded final chunk) and oversized chunks must all return
    exactly B unpadded rows equal to the unchunked sweep — padded points
    can never leak into the summary."""
    sset = default_set(max_w=32, horizon=15)
    cfg = _spot_cfg(ticks=40)
    # 13 grid points: a prime B so only chunk_size ∈ {1, 13} divides it.
    axes = make_axes(seeds=[0], bid_mults=[1.5, 2.0],
                     scenarios=sset)  # 1 × 2 × 5 = 10 … plus 3 more below
    extra = make_axes(seeds=[1], bid_mults=[1.5], scenarios=[0, 1, 2])
    axes = type(axes)(*(jnp.concatenate([a, b])
                        for a, b in zip(axes, extra)))
    b = int(axes.seed.shape[0])
    assert b == 13
    whole = run_sweep(sset, cfg, axes)
    for chunk in (1, 4, 13, 64):
        parts = run_sweep(sset, cfg, axes, chunk_size=chunk)
        assert np.asarray(parts.cost).shape[0] == b
        for f in whole._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(whole, f)), np.asarray(getattr(parts, f)),
                err_msg=f"{f} @ chunk={chunk}")


def test_run_sweep_rejects_out_of_range_scenario():
    cfg = _spot_cfg()
    sset = ScenarioSet((Poisson(horizon=10, max_w=8),))
    axes = make_axes(seeds=[0], bid_mults=[1.5], scenarios=2)
    with pytest.raises(ValueError, match="scenario"):
        run_sweep(sset, cfg, axes)
    # A plain schedule provides exactly one scenario.
    with pytest.raises(ValueError, match="scenario"):
        run_sweep(paper_schedule(), cfg, axes)
    # run_single (the loop-of-one reference) must reject the same mistakes
    # instead of letting lax.switch clamp to the last branch.
    with pytest.raises(ValueError, match="out of range"):
        run_single(sset, cfg, seed=0, bid_mult=1.5, scenario=5)
    with pytest.raises(ValueError, match="scenario 0"):
        run_single(paper_schedule(), cfg, seed=0, bid_mult=1.5, scenario=1)


def test_mmpp_rejects_negative_burst_rate():
    with pytest.raises(ValueError, match="non-negative"):
        MMPP(rate_lo=0.1, rate_hi=-2.0)


def test_make_axes_scenario_grid_order():
    axes = make_axes(seeds=[0, 1], bid_mults=[1.0], scenarios=3)
    assert axes.scenario.shape == (6,)
    np.testing.assert_array_equal(np.asarray(axes.scenario), [0, 1, 2, 0, 1, 2])
    np.testing.assert_array_equal(np.asarray(axes.seed), [0, 0, 0, 1, 1, 1])


def test_scenario_set_validation():
    with pytest.raises(ValueError, match="max_w"):
        ScenarioSet((Poisson(max_w=8), Poisson(max_w=16, name="p2")))
    with pytest.raises(ValueError, match="unique"):
        ScenarioSet((Poisson(), Poisson()))
    with pytest.raises(ValueError, match="at least one"):
        ScenarioSet(())
    with pytest.raises(ValueError, match="size_dist"):
        TaskModel(size_dist="cauchy")


def test_same_shape_scenarios_share_one_sweep_compile():
    """The sweep compile is keyed on scenario shape, not schedule bytes:
    two different same-shape schedules hit one cache entry."""
    cfg = _spot_cfg(ticks=40)
    a = paper_schedule(ttc=7500.0, arrival_gap_ticks=1, seed=0)
    b = paper_schedule(ttc=7500.0, arrival_gap_ticks=1, seed=1)
    f1 = sweep._sweep_callable(a, cfg, None)
    f2 = sweep._sweep_callable(b, cfg, None)
    assert f1 is f2
    # ... and the two sweeps still see their own bytes.
    axes = make_axes(seeds=[0], bid_mults=[1.5])
    ra = run_sweep(a, cfg, axes)
    rb = run_sweep(b, cfg, axes)
    assert float(ra.cost[0]) != float(rb.cost[0])


def test_heavy_tail_factory_swaps_size_dist():
    spec = heavy_tail(alpha=1.4)
    assert spec.tasks.size_dist == "pareto"
    assert spec.tasks.pareto_alpha == 1.4
    assert spec.name == "heavy_tail"


def test_hypothesis_shim_importable():
    # The suite must collect with or without hypothesis installed.
    assert HAVE_HYPOTHESIS in (True, False)
