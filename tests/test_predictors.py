"""Ad-hoc + ARMA baseline estimators (paper §V.B)."""

import jax.numpy as jnp
import pytest

from repro.core import predictors
from repro.core.types import ControlParams

P = ControlParams()


def test_adhoc_fixed_gain():
    st = predictors.adhoc_init(1, 1)
    st = predictors.adhoc_step(st, jnp.full((1, 1), 10.0),
                               jnp.ones((1, 1), bool), P)
    st = predictors.adhoc_step(st, jnp.full((1, 1), 20.0),
                               jnp.ones((1, 1), bool), P)
    st = predictors.adhoc_step(st, jnp.full((1, 1), 20.0),
                               jnp.ones((1, 1), bool), P)
    # second update moves toward lagged 20 with κ=0.1 from 10
    assert float(st.b_hat[0, 0]) == pytest.approx(11.0)


def test_adhoc_slower_than_kalman():
    from repro.core import kalman
    ka = kalman.init(1, 1)
    ah = predictors.adhoc_init(1, 1)
    for m in [10.0, 10.0, 10.0, 10.0]:
        mm = jnp.full((1, 1), m)
        ones = jnp.ones((1, 1), bool)
        ka = kalman.step(ka, mm, ones, P)
        ah = predictors.adhoc_step(ah, mm, ones, P)
    # both bootstrap at 10; inject a drop and see who tracks faster
    for m in [2.0, 2.0, 2.0]:
        mm = jnp.full((1, 1), m)
        ones = jnp.ones((1, 1), bool)
        ka = kalman.step(ka, mm, ones, P)
        ah = predictors.adhoc_step(ah, mm, ones, P)
    assert abs(float(ka.b_hat[0, 0]) - 2.0) < abs(float(ah.b_hat[0, 0]) - 2.0)


def test_arma_eq15_weights():
    st = predictors.arma_init(1, 1)
    m0 = jnp.asarray([[10.0]])
    # three ticks, each completing 1 of 10 items in 4/5/6 seconds
    for t_exec in [4.0, 5.0, 6.0]:
        st = predictors.arma_step(st, jnp.asarray([[t_exec]]),
                                  jnp.asarray([[1.0]]), m0, P)
    # b_norm values (per item): after t3: total=15, frac=0.3 -> 5.0;
    # after t2: total=9, frac=0.2 -> 4.5; after t1: 4.0
    exp = 0.8 * 5.0 + 0.15 * 4.5 + 0.05 * 4.0
    assert float(st.b_hat[0, 0]) == pytest.approx(exp, rel=1e-5)


def test_arma_reliability_window():
    st = predictors.arma_init(1, 1)
    m0 = jnp.asarray([[100.0]])
    for _ in range(6):
        st = predictors.arma_step(st, jnp.asarray([[5.0]]),
                                  jnp.asarray([[1.0]]), m0, P)
    assert bool(st.reliable[0, 0])      # flat history is within 20%


def test_arma_no_reliability_when_volatile():
    st = predictors.arma_init(1, 1)
    m0 = jnp.asarray([[100.0]])
    for t_exec in [1.0, 30.0, 2.0, 40.0]:
        st = predictors.arma_step(st, jnp.asarray([[t_exec]]),
                                  jnp.asarray([[1.0]]), m0, P)
    assert not bool(st.reliable[0, 0])
