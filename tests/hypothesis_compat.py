"""Optional-``hypothesis`` shim.

The property tests use ``hypothesis`` when it is installed; without it the
suite must still collect and run green (the plain example-based tests carry
the load).  Importing ``given``/``settings``/``strategies`` from here gives
each test module that behaviour: with hypothesis present these are the real
objects, otherwise ``@given(...)`` turns the test into a skip and the
strategy expressions evaluate to inert placeholders.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Absorbs any strategy construction (st.floats(...), st.lists(...))
        at module-import time; the values are never drawn because ``given``
        skips the test."""

        def __getattr__(self, _name):
            def _placeholder(*_args, **_kwargs):
                return None
            return _placeholder

    strategies = _Strategies()
