"""Instance lifecycle + quantized billing (paper §II.C/§IV, Appendix A)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import billing
from repro.core.types import BillingParams

B = BillingParams(boot_delay=120.0, terminate="boundary")
BI = dataclasses.replace(B, terminate="immediate")


def test_start_pays_full_quantum():
    c = billing.init(8)
    c = billing.scale_to(c, jnp.asarray(3.0), B)
    assert float(c.cum_cost) == pytest.approx(3 * B.price_per_quantum)
    assert float(billing.committed(c)) == 3


def test_boot_completes_then_usable():
    c = billing.scale_to(billing.init(8), jnp.asarray(2.0), B)
    assert float(billing.usable(c)) == 0
    c = billing.advance(c, 120.0, B)
    assert float(billing.usable(c)) == 2


def test_renewal_charges_next_quantum():
    c = billing.scale_to(billing.init(4), jnp.asarray(1.0), B)
    c0 = float(c.cum_cost)
    c = billing.advance(c, B.quantum + 1.0, B)
    assert float(c.cum_cost) == pytest.approx(c0 + B.price_per_quantum)


def test_boundary_drain_never_renews():
    c = billing.scale_to(billing.init(4), jnp.asarray(2.0), B)
    c = billing.advance(c, 120.0, B)
    c = billing.scale_to(c, jnp.asarray(1.0), B)      # mark one for drain
    cost_before = float(c.cum_cost)
    c = billing.advance(c, B.quantum + 1.0, B)
    # drained instance reclaimed (no charge); survivor renewed (one charge)
    assert float(c.cum_cost) == pytest.approx(
        cost_before + B.price_per_quantum)
    assert float(billing.committed(c)) == 1


def test_drained_instance_still_executes():
    c = billing.scale_to(billing.init(4), jnp.asarray(2.0), B)
    c = billing.advance(c, 120.0, B)
    c = billing.scale_to(c, jnp.asarray(1.0), B)
    assert float(billing.usable(c)) == 1          # control-plane view
    assert float(billing.capacity(c)) == 2        # execution-plane view


def test_undrain_is_free():
    c = billing.scale_to(billing.init(4), jnp.asarray(2.0), B)
    c = billing.advance(c, 120.0, B)
    c = billing.scale_to(c, jnp.asarray(1.0), B)
    cost = float(c.cum_cost)
    c = billing.scale_to(c, jnp.asarray(2.0), B)  # cancel the drain
    assert float(c.cum_cost) == pytest.approx(cost)
    assert float(billing.committed(c)) == 2


def test_immediate_termination_forfeits():
    c = billing.scale_to(billing.init(4), jnp.asarray(2.0), BI)
    c = billing.advance(c, 120.0, BI)
    c = billing.scale_to(c, jnp.asarray(1.0), BI)
    assert float(billing.capacity(c)) == 1        # gone now
    # money stays spent
    assert float(c.cum_cost) == pytest.approx(2 * B.price_per_quantum)


def test_shrink_picks_smallest_remaining():
    c = billing.scale_to(billing.init(4), jnp.asarray(1.0), BI)
    c = billing.advance(c, 1800.0, BI)            # 30 min used
    c = billing.scale_to(c, jnp.asarray(2.0), BI)  # add a fresh one
    c = billing.advance(c, 120.0, BI)
    # shrink: the old instance (less remaining) should go, not the fresh one
    c = billing.scale_to(c, jnp.asarray(1.0), BI)
    on = np.asarray(c.phase) >= 1
    assert on.sum() == 1
    assert float(c.a[np.nonzero(on)[0][0]]) > B.quantum - 1000


def test_lower_bound():
    lb = billing.lower_bound_cost(jnp.asarray(97_000.0), B)
    assert float(lb) == pytest.approx(np.ceil(97_000 / 3600) * 0.0081)


@given(st.lists(st.integers(0, 12), min_size=1, max_size=24),
       st.sampled_from(["boundary", "immediate"]))
@settings(max_examples=40, deadline=None)
def test_lifecycle_invariants(targets, mode):
    """Cost is non-decreasing; committed tracks targets within pool; no
    negative remaining time on live instances."""
    bp = dataclasses.replace(B, terminate=mode)
    c = billing.init(16)
    prev_cost = 0.0
    for t in targets:
        c = billing.advance(c, 60.0, bp)
        c = billing.scale_to(c, jnp.asarray(float(t)), bp)
        cost = float(c.cum_cost)
        assert cost >= prev_cost - 1e-9
        prev_cost = cost
        live = np.asarray(c.phase) >= 1
        assert 0 <= live.sum() <= 16
        assert (np.asarray(c.a)[live] > -60.0).all()
        assert float(billing.committed(c)) == pytest.approx(
            min(float(t), 16), abs=0)
