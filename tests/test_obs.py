"""The observability plane (``repro.obs``): probe neutrality, family
gating, the decision-ledger ring, drain/export, sweep profiling, and the
once-per-process deprecation / fallback warnings.

The load-bearing contract: ``SimConfig.obs=None`` compiles the exact
probe-free program (its sweep digest is pinned by the committed
``benchmarks/baselines/BENCH_obs.json``), and every probe is read-only —
switching any family subset on cannot move one result bit.
"""

import json
import pathlib
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.obs import ObsSpec, export, hist_percentile
from repro.obs import ledger as ledger_lib
from repro.sim import (SimConfig, SpotConfig, SweepSpec, SweepStream,
                       TenantSet, TenantSpec, make_axes, paper_schedule,
                       runner, tenants)
from repro.sim import scenarios as scen
from repro.sim import sweep as sweep_mod
from repro.sim.sweep import sweep

REPO = pathlib.Path(__file__).resolve().parent.parent

SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
# Prime grid (as in test_sweepspec): never divides a chunk or device
# count, so the profiled chunked/sharded paths below exercise padding.
PRIME_AXES = make_axes(range(13), [1.1])


def _cfg(obs: ObsSpec | None = None) -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=300.0),
                              billing=BillingParams(terminate="immediate")),
        ticks=130, spot=SpotConfig(enabled=True), obs=obs)


def _assert_same(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ spec validation

def test_obsspec_with_every_family_off_is_rejected():
    with pytest.raises(ValueError, match="observes nothing"):
        ObsSpec(aimd=False, kalman=False, preempt=False, fairshare=False)


def test_obsspec_rejects_bad_bins_and_ledger():
    with pytest.raises(ValueError, match="queue_bins"):
        ObsSpec(queue_bins=0)
    with pytest.raises(ValueError, match="ledger"):
        ObsSpec(ledger=-1)


def test_obsspec_is_static_and_hashable():
    # Part of the jit cache key via SimConfig — must hash and compare.
    assert hash(ObsSpec.full()) == hash(ObsSpec.full())
    assert ObsSpec() != ObsSpec.full()
    # The ledger needs the AIMD/preempt signals even with those metric
    # families off; the emission hooks key on want_*.
    s = ObsSpec(aimd=False, kalman=False, preempt=False, fairshare=True,
                ledger=8)
    assert s.want_aimd and s.want_preempt


# --------------------------------------------------------- ledger ring buffer

def _push_n(led, n, kind=ledger_lib.KIND_PREEMPT):
    for t in range(n):
        led = ledger_lib.push(led, jnp.asarray(True), t, kind, float(t))
    return led


def test_ledger_without_wrap_keeps_push_order():
    recs, dropped = ledger_lib.records(_push_n(ledger_lib.init(4), 3))
    assert dropped == 0
    assert [r.tick for r in recs] == [0, 1, 2]
    assert all(r.kind_name == "preempt" for r in recs)
    assert all(r.tenant == ledger_lib.NO_TENANT for r in recs)


def test_ledger_overflow_drops_exactly_the_oldest():
    """ISSUE acceptance: oldest-dropped semantics with the exact count —
    7 pushes into a 4-slot ring keep [3..6] and report 3 dropped."""
    recs, dropped = ledger_lib.records(_push_n(ledger_lib.init(4), 7))
    assert dropped == 3
    assert [r.tick for r in recs] == [3, 4, 5, 6]
    assert [r.value for r in recs] == [3.0, 4.0, 5.0, 6.0]


def test_ledger_exactly_full_is_not_a_wrap():
    recs, dropped = ledger_lib.records(_push_n(ledger_lib.init(4), 4))
    assert dropped == 0
    assert [r.tick for r in recs] == [0, 1, 2, 3]


def test_ledger_false_condition_is_a_noop():
    led = _push_n(ledger_lib.init(4), 2)
    same = ledger_lib.push(led, jnp.asarray(False), 99,
                           ledger_lib.KIND_KILL, 123.0)
    assert int(same.head) == int(led.head) == 2
    _assert_same(led, same)


def test_ledger_push_compiles_under_jit():
    @jax.jit
    def f(led):
        return ledger_lib.push(led, jnp.asarray(True), 5,
                               ledger_lib.KIND_SHED, 2.0)

    recs, _ = ledger_lib.records(f(ledger_lib.init(3)))
    assert [(r.tick, r.kind_name, r.value) for r in recs] == [(5, "shed", 2.0)]


# --------------------------------------------------- neutrality & family gating

def test_full_probe_catalog_leaves_the_run_bit_identical():
    """Plane-i acceptance: every family on + ledger + histogram, and the
    per-tick trace still matches the probe-free program bit for bit."""
    ref = runner.run(SCHED, _cfg(), seed=0)
    tr, report = runner.run_obs(SCHED, _cfg(ObsSpec.full(ledger=64)), seed=0)
    _assert_same(ref, tr)
    # The probes actually observed something while changing nothing.
    assert report.counters["aimd_incr_ticks"] > 0
    assert report.counters["queue_depth_max"] > 0
    assert report.queue_percentiles is not None


def test_probe_families_are_independent():
    """Enabling a family never perturbs another: the aimd/kalman counters
    drained from a minimal spec equal the full-catalog ones, and the run
    itself stays bit-identical under every subset."""
    ref = runner.run(SCHED, _cfg(), seed=3)
    _, full = runner.run_obs(SCHED, _cfg(ObsSpec.full(ledger=64)), seed=3)
    subsets = (
        ObsSpec(aimd=True, kalman=False, preempt=False, fairshare=False),
        ObsSpec(aimd=False, kalman=True, preempt=False, fairshare=False),
        ObsSpec(aimd=False, kalman=False, preempt=True, fairshare=True),
    )
    for spec in subsets:
        tr, rep = runner.run_obs(SCHED, _cfg(spec), seed=3)
        _assert_same(ref, tr)
        for name, val in rep.counters.items():
            assert full.counters[name] == pytest.approx(val, nan_ok=True), \
                name


def test_sweep_digest_matches_committed_baseline():
    """The obs=None program is digest-pinned: recompute the baseline's
    smoke neutrality sweep and compare sha256s — any drift in the
    probe-free simulator (or a probe that leaks into it) fails here
    before the bench gate ever runs."""
    path = REPO / "benchmarks" / "baselines" / "BENCH_obs.json"
    baseline = json.loads(path.read_text())
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import bench_obs
    finally:
        sys.path.remove(str(REPO))
    cfgrec = baseline["config"]
    axes = bench_obs._axes(cfgrec["seeds"], cfgrec["bid_mults"])
    off = sweep(SweepSpec(axes=axes, workload=bench_obs._sched()),
                bench_obs._cfg())
    assert bench_obs._summary_digest(off) == baseline["neutrality"]["digest"]


def test_obs_report_requires_probes():
    with pytest.raises(ValueError, match="no observability"):
        runner.run_obs(SCHED, _cfg(), seed=0)


# ----------------------------------------------------------- drain & exports

def test_hist_percentile_bin_midpoints():
    # 4 bins over depths [0, 7]: width 2, midpoints 1/3/5/7.
    counts = np.asarray([5, 5, 0, 0])
    assert hist_percentile(counts, 0.5, q_cap=7) == pytest.approx(1.0)
    assert hist_percentile(counts, 0.9, q_cap=7) == pytest.approx(3.0)
    assert np.isnan(hist_percentile(np.zeros(4), 0.5, q_cap=7))


@pytest.fixture(scope="module")
def full_report():
    _, report = runner.run_obs(SCHED, _cfg(ObsSpec.full(ledger=64)), seed=0)
    return report


def test_report_dataframe_and_jsonl(full_report, tmp_path):
    rows = full_report.to_dataframe()
    n = len(full_report.ledger)
    assert len(rows) == n
    path = tmp_path / "ledger.jsonl"
    full_report.to_jsonl(path)
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert lines[0]["record"] == "counters"
    assert lines[0]["ledger_dropped"] == full_report.ledger_dropped
    events = [line for line in lines[1:] if line["record"] == "event"]
    assert len(events) == n
    for ev, rec in zip(events, full_report.ledger):
        assert ev["tick"] == rec.tick and ev["kind_name"] == rec.kind_name


def test_run_trace_events_one_instant_per_ledger_record(full_report,
                                                        tmp_path):
    events = export.run_trace_events(full_report, dt=300.0)
    instants = [e for e in events if e.get("ph") == "i"]
    assert len(instants) == len(full_report.ledger)
    for ev, rec in zip(instants, full_report.ledger):
        assert ev["ts"] == pytest.approx(rec.tick * 300.0 * 1e6)
        assert ev["name"] == rec.kind_name
    path = tmp_path / "trace.json"
    export.write_trace(path, events)
    env = json.loads(path.read_text())
    assert set(env) == {"traceEvents", "displayTimeUnit"}
    assert len(env["traceEvents"]) == len(events)


def test_sweep_trace_events_lay_chunks_end_to_end():
    chunks = [
        sweep_mod.ChunkProfile(chunk=0, rows=4, compile_s=1.0,
                               execute_s=0.5, peak_bytes=10),
        sweep_mod.ChunkProfile(chunk=1, rows=4, execute_s=0.25,
                               write_s=0.25),
        sweep_mod.ChunkProfile(chunk=2, rows=1, resumed=True),
    ]
    spans = [e for e in export.sweep_trace_events(chunks)
             if e.get("ph") == "X"]
    assert [e["ts"] for e in spans] == [0.0, 1.5e6, 2.0e6]
    assert [e["dur"] for e in spans] == [1.5e6, 0.5e6, 0.0]
    assert spans[0]["args"]["peak_bytes"] == 10
    assert spans[2]["args"]["resumed"] is True
    # The manifest's "profile" record (plain dicts) renders identically.
    import dataclasses
    dicts = [dataclasses.asdict(c) for c in chunks]
    assert export.sweep_trace_events(dicts) == export.sweep_trace_events(
        chunks)


# ----------------------------------------------------------- sweep profiling

def test_profiled_sweep_wraps_the_unchanged_result():
    """SweepSpec.profile wraps, never alters: same summaries, plus one
    ChunkProfile per chunk with the compile cost on the first chunk only.
    Under the multi-device CI job (4 forced CPU devices) this exercises
    the shard_map path — `devices` defaults to every local device."""
    cfg = _cfg()
    ref = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED), cfg)
    rep = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                          profile=True), cfg)
    assert isinstance(rep, sweep_mod.SweepReport)
    _assert_same(ref, rep.result)
    assert [c.chunk for c in rep.chunks] == [0, 1, 2, 3]
    assert sum(c.rows for c in rep.chunks) == 13
    assert rep.chunks[0].compile_s > 0.0
    assert all(c.compile_s == 0.0 for c in rep.chunks[1:])
    assert all(c.execute_s > 0.0 for c in rep.chunks)
    assert rep.total_s >= sum(c.compile_s + c.execute_s for c in rep.chunks)


def test_profiled_streamed_sweep_manifest_trace_and_resume(tmp_path):
    cfg = _cfg()
    d = str(tmp_path / "stream")
    spec = SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                     stream_dir=d, profile=True)
    rep = sweep(spec, cfg)
    assert isinstance(rep, sweep_mod.SweepReport)
    assert isinstance(rep.result, SweepStream)
    assert all(c.write_s > 0.0 for c in rep.chunks)
    # The profile persists in the stream manifest, and the Perfetto export
    # carries exactly one complete span per chunk.
    assert len(rep.result.manifest["profile"]) == len(rep.chunks) == 4
    trace = tmp_path / "sweep_trace.json"
    rep.write_trace(trace)
    spans = [e for e in json.loads(trace.read_text())["traceEvents"]
             if e.get("ph") == "X"]
    assert len(spans) == 4
    assert all({"compile_s", "execute_s", "write_s"} <= set(e["args"])
               for e in spans)
    # Re-running resumes every committed chunk as a zero-length span...
    again = sweep(spec, cfg)
    assert all(c.resumed for c in again.chunks)
    _assert_same(rep.result.load(), again.result.load())
    # ...and an unprofiled re-run still resumes the same directory (the
    # manifest identity strips the profile record).
    plain = sweep(SweepSpec(axes=PRIME_AXES, workload=SCHED, chunk_size=4,
                            stream_dir=d), cfg)
    assert isinstance(plain, SweepStream)
    _assert_same(rep.result.load(), plain.load())


# ----------------------------------------------- once-per-process warnings

def test_run_sweep_deprecation_fires_once_per_process(monkeypatch):
    monkeypatch.setattr(sweep_mod, "_WARNED_RUN_SWEEP", False)
    axes = make_axes([0], [1.1])
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        sweep_mod.run_sweep(SCHED, _cfg(), axes)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sweep_mod.run_sweep(SCHED, _cfg(), axes)


def test_tenant_sweep_deprecation_fires_once_per_process(monkeypatch):
    monkeypatch.setattr(tenants, "_WARNED_TENANT_SWEEP", False)
    sset = scen.default_set()
    tset = TenantSet(tuple(TenantSpec(scenario=s, name=f"t{i}")
                           for i, s in enumerate(sset.specs[:2])))
    with pytest.warns(DeprecationWarning, match="SweepSpec"):
        tenants.tenant_sweep(tset, _cfg(), [0])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tenants.tenant_sweep(tset, _cfg(), [0])


def test_kernel_interpret_fallback_warns_once_with_platform(monkeypatch):
    from repro.kernels.kalman_update import kernel
    if jax.default_backend() == "tpu":
        pytest.skip("the interpret fallback never fires on TPU")
    monkeypatch.setattr(kernel, "_WARNED_INTERPRET", False)
    with pytest.warns(UserWarning, match="interpret mode") as rec:
        assert kernel.resolve_interpret(None) is True
    assert jax.default_backend() in str(rec[0].message)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        assert kernel.resolve_interpret(None) is True
        # An explicit choice is honored silently either way.
        assert kernel.resolve_interpret(False) is False
