"""Optimizer telemetry (``repro.opt``): per-generation probes and
improve/stall events on the CEM/ES minimizers behind the same static
opt-in contract as ``SimConfig.obs`` — ``telemetry=False`` (the default)
compiles the exact historical program and returns ``telemetry=None``;
``telemetry=True`` moves no result bit and drains into the standard
``ObsReport`` so every exporter works on tuning runs unchanged.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import ledger as ledger_lib
from repro.obs import to_openmetrics
from repro.opt import BoxSpace, cem_minimize, es_minimize, tuner
from repro.opt.cem import STALL_GENS, OptTelemetry

SPACE = BoxSpace(names=("a", "b"), lo=(0.0, 0.0), hi=(1.0, 1.0))
GENS = 8


def _quadratic(vec):
    return jnp.sum((vec - jnp.asarray([0.3, 0.7])) ** 2)


def _constant(vec):
    return jnp.asarray(1.0, jnp.float32)


RESULT_FIELDS = ("best_vec", "best_score", "final_mean", "history_best",
                 "history_mean")


def _assert_results_equal(a, b):
    for field in RESULT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)


@pytest.mark.parametrize("minimize", [cem_minimize, es_minimize],
                         ids=["cem", "es"])
def test_telemetry_defaults_off_and_moves_no_bit(minimize):
    """The tuning-neutrality contract: telemetry defaults to None, and
    arming it leaves every optimizer result leaf bit-identical (committed
    tuning baselines cannot move)."""
    key = jax.random.PRNGKey(0)
    off = minimize(_quadratic, SPACE, key, generations=GENS)
    assert off.telemetry is None
    on = minimize(_quadratic, SPACE, key, generations=GENS, telemetry=True)
    _assert_results_equal(off, on)
    assert isinstance(on.telemetry, OptTelemetry)


@pytest.mark.parametrize("minimize", [cem_minimize, es_minimize],
                         ids=["cem", "es"])
def test_telemetry_shapes_and_event_stream(minimize):
    res = minimize(_quadratic, SPACE, jax.random.PRNGKey(1),
                   generations=GENS, telemetry=True)
    tel = res.telemetry
    for leaf in (tel.elite_mean, tel.score_std, tel.sigma_mean):
        assert leaf.shape == (GENS,)
    assert tel.stalled.shape == ()

    records, dropped = ledger_lib.drain(tel.ledger)
    assert dropped == 0
    kinds = {r.kind for r in records}
    assert kinds <= {ledger_lib.KIND_OPT_IMPROVE, ledger_lib.KIND_OPT_STALL}
    # On a smooth quadratic the incumbent improves at least once, and
    # the tick column is the (nondecreasing) generation index.
    assert ledger_lib.KIND_OPT_IMPROVE in kinds
    ticks = [r.tick for r in records]
    assert ticks == sorted(ticks)
    assert all(0 <= t < GENS for t in ticks)


@pytest.mark.parametrize("minimize", [cem_minimize, es_minimize],
                         ids=["cem", "es"])
def test_constant_objective_fires_one_stall_event(minimize):
    """A flat landscape never improves after generation 0, so the stall
    detector fires exactly once — on the transition at STALL_GENS — and
    the final stalled counter covers every stale generation."""
    res = minimize(_constant, SPACE, jax.random.PRNGKey(2),
                   generations=GENS, telemetry=True)
    records, _ = ledger_lib.drain(res.telemetry.ledger)
    stalls = [r for r in records if r.kind == ledger_lib.KIND_OPT_STALL]
    assert len(stalls) == 1
    assert stalls[0].tick == STALL_GENS
    assert int(res.telemetry.stalled) == GENS - 1


def test_telemetry_report_counters_and_exports():
    """tuner.telemetry_report turns a telemetry run into a standard
    ObsReport the OpenMetrics/JSONL exporters consume unchanged."""
    res = cem_minimize(_quadratic, SPACE, jax.random.PRNGKey(3),
                       generations=GENS, telemetry=True)
    report = tuner.telemetry_report(res)
    c = report.counters
    assert c["generations"] == float(GENS)
    assert c["opt_improvements"] >= 1.0
    assert c["opt_improvements"] == float(
        sum(r.kind == ledger_lib.KIND_OPT_IMPROVE for r in report.ledger))
    assert c["best_score"] == pytest.approx(float(res.best_score))
    assert c["final_elite_mean"] == pytest.approx(
        float(res.telemetry.elite_mean[-1]))

    text = to_openmetrics(report, prefix="tune")
    assert text.endswith("# EOF\n")
    assert "tune_opt_improvements" in text
    assert 'tune_ledger_events{kind="opt_improve"}' in text


def test_telemetry_report_jsonl_round_trip(tmp_path):
    import json

    res = es_minimize(_quadratic, SPACE, jax.random.PRNGKey(4),
                      generations=GENS, telemetry=True)
    path = tmp_path / "tune.jsonl"
    tuner.telemetry_report(res).to_jsonl(str(path))
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[0]["record"] == "counters"
    assert lines[0]["generations"] == float(GENS)
    events = lines[1:]
    assert all(e["record"] == "event" for e in events)
    assert all(e["kind_name"] in ("opt_improve", "opt_stall")
               for e in events)


def test_telemetry_report_requires_telemetry():
    res = cem_minimize(_quadratic, SPACE, jax.random.PRNGKey(5),
                       generations=GENS)
    with pytest.raises(ValueError, match="telemetry=True"):
        tuner.telemetry_report(res)
