"""Summary-mode scan + sharded/chunked sweep engine (ISSUE 3).

The contract under test: sweeps in summary mode (statistics accumulated in
the scan carry, no per-tick ``ys``) are bit-identical to what the trace
produces, chunked/sharded execution changes nothing, trace mode keeps the
PR-2 schema, and the cached jitted entry points actually cache.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kalman
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, make_axes, paper_schedule,
                       run, run_single, run_sweep, spot)
from repro.sim import runner, sweep

PARAMS = ControlParams(monitor_dt=300.0)
BILL = BillingParams(terminate="immediate")
SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)

# mean_price is the one summary field whose reduction order differs between
# the sequential carry accumulation and the trace's parallel jnp.mean; every
# other field must match bit for bit.
EXACT_FIELDS = tuple(f for f in sweep.RunSummary._fields
                     if f != "mean_price")


def _spot_cfg(**kw):
    return SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL),
        ticks=130, spot=SpotConfig(enabled=True, **kw))


def _trace_summary(cfg, seed, bid_mult, instance="m3.medium", policy=None):
    """The independent reference: a trace-mode run collapsed after the
    fact from its stacked per-tick outputs (the pre-refactor semantics)."""
    itype, mask = sweep._as_mix(instance)
    if policy is None:
        policy = spot.bid_policy_index(cfg.spot.bid_policy)
    rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                           policy=policy, mix=jnp.asarray(mask))
    sched = SCHED.as_jax()
    final, ys = runner.cached_scan(sched, cfg, trace=True, with_rt=True)(
        sched, seed, rt, runner.default_params(cfg))
    return sweep.summarize_trace(final, ys, SCHED, cfg)


# ------------------------------------------------------- summary == trace --

@pytest.mark.parametrize("seed,bid_mult", [(0, 1.02), (1, 1.5), (2, 8.0)])
def test_summary_carry_bit_identical_to_trace(seed, bid_mult):
    ref = _trace_summary(_spot_cfg(), seed, bid_mult)
    got = run_single(SCHED, _spot_cfg(), seed=seed, bid_mult=bid_mult)
    for f in EXACT_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f"{f} @ seed={seed} bid={bid_mult}")
    np.testing.assert_allclose(np.asarray(got.mean_price),
                               np.asarray(ref.mean_price), rtol=1e-5)


def test_summary_matches_trace_across_policies_and_mixes():
    cfg = _spot_cfg(instance="m3.xlarge", p_spike_per_core=0.02,
                    spike_hours=3.0)
    mixes = ["m3.xlarge", ("m3.medium", "m3.xlarge", "m4.4xlarge")]
    for policy in ("multiple", "ttc", "ema", "on_demand"):
        for mix in mixes:
            ref = _trace_summary(cfg, 3, 1.2, instance=mix, policy=policy)
            got = run_single(SCHED, cfg, seed=3, bid_mult=1.2,
                             instance=mix, policy=policy)
            for f in EXACT_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, f)),
                    np.asarray(getattr(ref, f)),
                    err_msg=f"{f} @ {policy}/{mix}")


def test_unfinished_run_bills_to_horizon_in_summary_mode():
    """The cost register only counts when everything finished — a hopeless
    bid must still read as a full-horizon bill (trace-mode semantics)."""
    r = run_single(SCHED, _spot_cfg(), seed=0, bid_mult=0.5)
    ref = _trace_summary(_spot_cfg(), 0, 0.5)
    assert int(r.finished) < SCHED.n
    np.testing.assert_array_equal(np.asarray(r.cost), np.asarray(ref.cost))
    np.testing.assert_array_equal(np.asarray(r.cost),
                                  np.asarray(r.cost_horizon))


def test_scan_run_summary_mode_emits_no_ys():
    final, ys = runner.scan_run(SCHED, _spot_cfg(), seed=0, trace=False)
    assert ys is None
    assert float(final.summ.max_committed) > 0


# --------------------------------------------------- chunking and sharding --

def test_chunked_sweep_equals_unchunked():
    cfg = _spot_cfg()
    axes = make_axes(seeds=[0, 1, 2], bid_mults=[1.02, 1.5],
                     policies=["multiple", "ttc"])   # B = 12
    whole = run_sweep(SCHED, cfg, axes)
    for chunk in (5, 4, 12, 64):   # padding, exact, single, oversized
        parts = run_sweep(SCHED, cfg, axes, chunk_size=chunk)
        for f in whole._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(whole, f)), np.asarray(getattr(parts, f)),
                err_msg=f"{f} @ chunk_size={chunk}")


def test_explicit_single_device_matches_default():
    cfg = _spot_cfg()
    axes = make_axes(seeds=[0, 1], bid_mults=[1.02, 1.5])
    a = run_sweep(SCHED, cfg, axes)
    b = run_sweep(SCHED, cfg, axes, devices=1, chunk_size=3)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def test_axes_survive_chunked_sweep():
    """The chunked path donates buffers — but only per-chunk copies; the
    caller's axes must remain usable for a second sweep."""
    cfg = _spot_cfg()
    axes = make_axes(seeds=[0, 1], bid_mults=[1.02])
    first = run_sweep(SCHED, cfg, axes, chunk_size=1)
    second = run_sweep(SCHED, cfg, axes, chunk_size=1)
    np.testing.assert_array_equal(np.asarray(first.cost),
                                  np.asarray(second.cost))


def test_run_sweep_rejects_disabled_spot_with_valueerror():
    cfg = SimConfig(ctrl=ControllerConfig(params=PARAMS, billing=BILL),
                    ticks=40)
    with pytest.raises(ValueError, match="spot.enabled"):
        run_sweep(SCHED, cfg, make_axes(seeds=[0], bid_mults=[1.5]))


def test_run_sweep_rejects_bad_chunk_size_with_valueerror():
    axes = make_axes(seeds=[0], bid_mults=[1.5])
    for bad in (0, -4):
        with pytest.raises(ValueError, match="chunk_size"):
            run_sweep(SCHED, _spot_cfg(), axes, chunk_size=bad)


def test_kernel_rejects_unaligned_bank_with_valueerror():
    from repro.kernels.kalman_update.kernel import kalman_fused
    x = jnp.zeros((300, 1))   # 300 % 256 != 0: must error, never truncate
    with pytest.raises(ValueError, match="divisible"):
        kalman_fused(x, x, x, jnp.ones((300, 1), bool), 0.5, 0.5)


# ----------------------------------------------------- trace-mode schema --

def test_trace_mode_schema_unchanged():
    """``trace=True`` still yields the full PR-2 SimTrace: same fields,
    same shapes, same dtypes of the per-tick arrays."""
    cfg = _spot_cfg()
    tr = run(SCHED, cfg, seed=0)
    t, w, k = cfg.ticks, SCHED.n, SCHED.m0.shape[1]
    expected = {
        "cum_cost": (t,), "n_usable": (t,), "n_committed": (t,),
        "n_star": (t,), "n_target": (t,), "util": (t,),
        "b_hat": (t, w, k), "b_meas": (t, w, k), "reliable": (t, w, k),
        "confirmed": (t, w), "active": (t, w), "remaining": (t, w),
        "spot_price": (t,), "spot_bid": (t,), "n_preempted": (t,),
        "t_done": (w,), "violations": (),
    }
    for name, shape in expected.items():
        assert getattr(tr, name).shape == shape, name
    assert set(runner.SimTrace._fields) == set(expected) | {"work_final"}


# --------------------------------------------------------- cached compile --

def test_cached_scan_reuses_compiled_entry():
    cfg = _spot_cfg()
    f1 = runner.cached_scan(SCHED, cfg, trace=False, with_rt=True)
    f2 = runner.cached_scan(SCHED, cfg, trace=False, with_rt=True)
    assert f1 is f2
    # A different static config is a different entry.
    f3 = runner.cached_scan(SCHED, dataclasses.replace(cfg, ticks=131),
                            trace=False, with_rt=True)
    assert f3 is not f1
    # A different schedule with the same *shape* shares the compile — the
    # schedule is a traced input, keyed on scenario shape, not bytes.
    other = paper_schedule(ttc=7500.0, arrival_gap_ticks=1, seed=1)
    f4 = runner.cached_scan(other, cfg, trace=False, with_rt=True)
    assert f4 is f1
    # ... while a different shape (padded capacity) is a new entry.
    from repro.sim import workloads as wl
    padded = wl.pad_schedule(SCHED.as_jax(), SCHED.n + 8)
    f5 = runner.cached_scan(padded, cfg, trace=False, with_rt=True)
    assert f5 is not f1


def test_repeated_run_hits_cache(monkeypatch):
    cfg = _spot_cfg()
    run(SCHED, cfg, seed=0)          # warm
    calls = []
    orig = jax.jit

    def counting_jit(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(jax, "jit", counting_jit)
    run(SCHED, cfg, seed=1)          # same static key, new seed
    assert not calls


# ------------------------------------------------- controller validation --

def test_controllerconfig_rejects_unknown_predictor_with_valueerror():
    with pytest.raises(ValueError, match="kalman"):
        ControllerConfig(predictor="oracle")


def test_controllerconfig_rejects_unknown_policy_with_valueerror():
    with pytest.raises(ValueError, match="aimd"):
        ControllerConfig(policy="pid")


def test_controllerconfig_rejects_unknown_aimd_base_with_valueerror():
    with pytest.raises(ValueError, match="committed"):
        ControllerConfig(aimd_base="usable")


# ------------------------------------------------------- Pallas predictor --

def test_kalman_step_kernel_bit_identical():
    w, k = 30, 1
    key = jax.random.PRNGKey(11)
    st = kalman.init(w, k)
    p = ControlParams()
    for i in range(4):
        ks = jax.random.split(jax.random.fold_in(key, i), 2)
        meas = jax.random.normal(ks[0], (w, k)) ** 2 + 0.5
        mask = jax.random.bernoulli(ks[1], 0.6, (w, k))
        st_ref = kalman.step(st, meas, mask, p)
        st_ker = kalman.step(st, meas, mask, p, use_kernel=True)
        for f in st_ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(st_ker, f)),
                np.asarray(getattr(st_ref, f)), err_msg=f"{f} @ step {i}")
        st = st_ref


def test_full_run_with_kalman_kernel_matches_default():
    cfg = _spot_cfg()
    cfg_k = SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL,
                              kalman_kernel=True),
        ticks=130, spot=SpotConfig(enabled=True))
    a = run(SCHED, cfg, seed=1)
    b = run(SCHED, cfg_k, seed=1)
    np.testing.assert_array_equal(np.asarray(a.cum_cost),
                                  np.asarray(b.cum_cost))
    np.testing.assert_array_equal(np.asarray(a.b_hat), np.asarray(b.b_hat))
    np.testing.assert_array_equal(np.asarray(a.reliable),
                                  np.asarray(b.reliable))


def test_kalman_kernel_inside_vmapped_sweep_matches_default():
    """The kernel's batch rule folds the sweep's vmap axis into its row
    grid; the whole vmapped sweep must still match the jnp path bit for
    bit."""
    cfg = _spot_cfg()
    cfg_k = SimConfig(
        ctrl=ControllerConfig(params=PARAMS, billing=BILL,
                              kalman_kernel=True),
        ticks=130, spot=SpotConfig(enabled=True))
    axes = make_axes(seeds=[0, 1], bid_mults=[1.0, 1.5])
    a = run_sweep(SCHED, cfg, axes)
    b = run_sweep(SCHED, cfg_k, axes)
    for f in sweep.RunSummary._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)
