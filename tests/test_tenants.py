"""Multi-tenant shared fleet: attribution, admission, fair-share (ISSUE 6).

The contracts under test:

  * a one-tenant set is the single-owner simulation, bit for bit, across
    every committed scenario family;
  * attributed per-tenant cost sums EXACTLY to the fleet bill at *every*
    tick, including ticks with mid-quantum market preemptions;
  * tenants with no valid workload rows can neither bill nor violate;
  * the hierarchical allocator degenerates to the classic per-task
    allocator for one tenant, and respects weights for many;
  * admission control (``adm_frac``, budgets) rejects instead of
    violating;
  * the tuning-space plumbing round-trips the extended ``PolicyParams``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fairshare
from repro.core.controller import ControllerConfig
from repro.core.types import (ControlParams, TenantConfig,
                              make_policy_params)
from repro.opt import space as opt_space
from repro.sim import (ScenarioSet, SimConfig, SpotConfig, TenantSet,
                       TenantSpec, run_single, run_tenants, tenant_sweep)
from repro.sim import runner, scenarios as scen, sweep, tenants as tnt

PARAMS = ControlParams(monitor_dt=300.0)
SSET = scen.default_set(max_w=32, horizon=20)


def _cfg(**kw):
    return SimConfig(ctrl=ControllerConfig(params=PARAMS),
                     ticks=80, spot=SpotConfig(enabled=True, **kw))


def _two_tenants():
    return TenantSet((TenantSpec(SSET[0], weight=1.0),
                      TenantSpec(SSET[1], weight=2.0)))


# -------------------------------------------------- N=1 == single-owner --

@pytest.mark.parametrize("scenario_id", [0, 1, 3])
def test_one_tenant_bit_identical_to_single_owner(scenario_id):
    """A singleton TenantSet replays ``run_single`` exactly: same sampled
    schedule (scenario-id keying), same dynamics (the allocator and the
    admission gate provably pass through), same summary bits."""
    cfg = _cfg()
    spec = SSET[scenario_id]
    shared = run_tenants(TenantSet((TenantSpec(spec),)), cfg, seed=7)
    alone = run_single(ScenarioSet((spec,)), cfg, seed=7, bid_mult=1.0)
    # mean_price is the one field the repo never promises bit for bit
    # (accumulation order differs under vmap) — same carve-out as
    # test_throughput's EXACT_FIELDS.
    for f in sweep.RunSummary._fields:
        a, b = getattr(shared.fleet, f), getattr(alone, f)
        if a is None and b is None:     # e.g. alerts without obs.detect
            continue
        if f == "mean_price":
            assert jnp.allclose(a, b, rtol=1e-6), (f, a, b)
        else:
            assert jnp.array_equal(a, b), (f, a, b)
    # ...and the whole fleet bill lands on the only tenant, exactly.
    assert int(shared.tenants.cost_units[0]) == int(
        np.round(float(alone.cost_horizon) * runner._COST_UNIT))


def test_tenant_blocks_replay_isolated_scenarios():
    """Tenant i's block of the shared schedule is exactly scenario i's
    sample — the isolated-fleet baseline runs identical workloads."""
    ts = _two_tenants()
    sched = ts.sample(11)
    for i in range(ts.n):
        block = jax.tree.map(
            lambda x: x[i * ts.max_w:(i + 1) * ts.max_w], sched)
        solo = ts.sample_one(11, i)
        for name in type(solo)._fields:
            assert jnp.array_equal(getattr(block, name),
                                   getattr(solo, name)), (i, name)


# ------------------------------------------------------ exact attribution --

def test_attribution_sums_to_fleet_bill_every_tick():
    """Per-tenant attributed cost telescopes to the fleet bill at every
    tick — through market preemption ticks included."""
    cfg = _cfg(instance="m3.xlarge", p_spike_per_core=0.02)
    ts = _two_tenants()
    scfg = ts.sim_config(cfg)
    sched = ts.sample(3)
    pp = runner.default_params(scfg)
    step = jax.jit(runner.make_step(sched, scfg, trace=False, params=pp))
    state = runner.init_state(sched, scfg, seed=3)
    for _ in range(40):
        state, _ = step(state, None)
        total = int(jnp.sum(state.summ.tenant.cost_u))
        fleet = int(jnp.round(state.cluster.cum_cost * runner._COST_UNIT))
        assert total == fleet
    # The config is spiky enough that mid-quantum preemptions happened —
    # otherwise this test waters down to the calm-market case.
    assert int(state.cluster.n_preempt) > 0


def test_padded_tenant_never_bills_nor_violates():
    """A tenant whose whole block is padding attracts no cost, no
    violations, no finishes — even though idle cost is being split."""
    cfg = _cfg()
    ts = _two_tenants()
    scfg = ts.sim_config(cfg)
    sched = ts.sample(5)
    # Hollow out tenant 1's block: nothing there ever arrives.
    w = ts.max_w
    dead = jnp.arange(sched.valid.shape[0]) >= w
    sched = sched._replace(
        valid=jnp.where(dead, False, sched.valid),
        t_arrive=jnp.where(dead, -1, sched.t_arrive))
    final, _ = runner.scan_run(sched, scfg, seed=5, trace=False)
    out = tnt.summarize_tenants(final, sched, scfg)
    assert int(out.cost_units[1]) == 0
    assert int(out.violations[1]) == 0
    assert int(out.finished[1]) == 0
    # The live tenant carries the entire bill, still exactly.
    assert int(out.cost_units[0]) == int(
        np.round(float(final.cluster.cum_cost) * runner._COST_UNIT))


# ------------------------------------------------------------- allocator --

def test_allocate_tenants_single_tenant_is_allocate():
    key = jax.random.PRNGKey(0)
    w = 16
    r = jax.random.uniform(key, (w,)) * 40.0
    d = jax.random.uniform(jax.random.fold_in(key, 1), (w,)) * 3000.0 + 300.0
    active = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.7, (w,))
    p = ControlParams()
    pp = make_policy_params(alpha=p.alpha, beta=p.beta, tenant_wg=1.3)
    a = fairshare.allocate(r, d, active, 20.0, p, pp=pp)
    b = fairshare.allocate_tenants(r, d, active, 20.0, p,
                                   jnp.zeros((w,), jnp.int32), 1,
                                   jnp.ones((1,)), pp=pp)
    for f in type(a)._fields:
        assert jnp.array_equal(getattr(a, f), getattr(b, f)), f


def test_allocate_tenants_respects_weights():
    """With two identical demand blocks and a 3:1 weight split, the
    heavier tenant's granted rate dominates under contention."""
    w = 8
    r = jnp.full((2 * w,), 10.0)
    d = jnp.full((2 * w,), 600.0)
    active = jnp.ones((2 * w,), bool)
    tid = jnp.repeat(jnp.arange(2, dtype=jnp.int32), w)
    p = ControlParams()
    alloc = fairshare.allocate_tenants(r, d, active, 8.0, p, tid, 2,
                                       jnp.asarray([3.0, 1.0]))
    s = jax.ops.segment_sum(alloc.s, tid, num_segments=2)
    assert float(s[0]) > 1.5 * float(s[1])


# ------------------------------------------------------------- admission --

def test_adm_frac_rejects_instead_of_violating():
    cfg = _cfg()
    ts = _two_tenants()
    open_door = run_tenants(ts, cfg, seed=9)
    squeezed = run_tenants(ts, cfg, seed=9,
                           params=runner.default_params(
                               ts.sim_config(cfg))._replace(
                                   adm_frac=jnp.asarray(0.125)))
    assert int(jnp.sum(open_door.tenants.rejected)) == 0
    assert int(jnp.sum(squeezed.tenants.rejected)) > 0
    # Rejected arrivals never submit, so they cannot be violations.
    arrived = (squeezed.tenants.submitted + squeezed.tenants.rejected)
    assert jnp.array_equal(arrived, open_door.tenants.submitted)


def test_budget_cap_stops_admission():
    cfg = _cfg()
    capped = TenantSet((TenantSpec(SSET[0], budget=0.001),
                        TenantSpec(SSET[1], weight=2.0)))
    out = run_tenants(capped, cfg, seed=9)
    assert int(out.tenants.rejected[0]) > 0
    free = run_tenants(_two_tenants(), cfg, seed=9)
    assert int(free.tenants.rejected[0]) == 0


# ------------------------------------------------------- sweep + batching --

def test_tenant_sweep_matches_run_tenants():
    cfg = _cfg()
    ts = _two_tenants()
    batch = tenant_sweep(ts, cfg, seeds=[2, 4])
    for s, seed in enumerate([2, 4]):
        one = run_tenants(ts, cfg, seed=seed)
        assert jnp.array_equal(batch.fleet.cost_horizon[s],
                               one.fleet.cost_horizon)
        assert jnp.array_equal(batch.tenants.cost_units[s],
                               one.tenants.cost_units)


def test_schedule_shape_mismatch_raises():
    cfg = _cfg()
    scfg = dataclasses.replace(cfg, tenants=TenantConfig(n=2, max_w=32))
    sched = SSET[0].sample(jax.random.PRNGKey(0))  # 32 rows, not 64
    with pytest.raises(ValueError, match="workload rows"):
        runner.scan_run(sched, scfg, seed=0, trace=False)


# ----------------------------------------------------------- space plumbing --

def test_policy_space_default_excludes_tenant_knobs():
    sp = opt_space.policy_space()
    assert sp.names == opt_space.TUNED_FIELDS


def test_bounds_opt_in_tenant_knob():
    sp = opt_space.policy_space(bounds={"tenant_wg": (-2.0, 2.0)})
    assert "tenant_wg" in sp.names
    assert sp.dim == len(opt_space.TUNED_FIELDS) + 1


def test_vector_round_trips_full_and_classic():
    pp = make_policy_params(alpha=3.0, beta=0.8, tenant_wg=0.7,
                            adm_frac=0.5, price_mult=1.4)
    full = opt_space.params_to_vector(pp)
    back = opt_space.vector_to_params(full)
    for f in type(pp)._fields:
        assert jnp.allclose(getattr(back, f), getattr(pp, f)), f
    classic = opt_space.vector_to_params(
        jnp.asarray([4.0, 0.9, 1.0, 3.0, 0.3]))
    assert float(classic.adm_frac) == 1.0  # neutral default
    assert float(classic.alpha) == 4.0
    with pytest.raises(ValueError, match="names"):
        opt_space.vector_to_params(jnp.zeros((3,)))


def test_tenant_set_validation():
    with pytest.raises(ValueError, match="max_w"):
        TenantSet((TenantSpec(scen.default_set(max_w=32)[0]),
                   TenantSpec(scen.default_set(max_w=64)[0])))
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(SSET[0], weight=0.0)
    with pytest.raises(ValueError, match="budgets"):
        TenantConfig(n=2, max_w=4, budgets=(1.0,))
