import os
import sys

# Tests run on the single host CPU device (the dry-run, and only the
# dry-run, forces 512 devices in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
