"""Sharding rules: model-axis assignment, divisibility guards, ZeRO."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import shardings as shr


class FakeMesh:
    """Duck-typed mesh: axis names + shape, no devices needed."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as np
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))


def _spec(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {"/".join(str(getattr(p, "key", p)) for p in path):
            shr.param_spec(path, leaf, MESH) for path, leaf in flat}


def test_attention_param_specs():
    tree = {"blocks": {"attn": {
        "wq": jax.ShapeDtypeStruct((48, 2048, 32, 64), jnp.bfloat16),
        "wo": jax.ShapeDtypeStruct((48, 32, 64, 2048), jnp.bfloat16),
    }}}
    s = _spec(tree)
    assert s["blocks/attn/wq"] == P(None, None, "model", None)
    assert s["blocks/attn/wo"] == P(None, "model", None, None)


def test_divisibility_guard_falls_back():
    tree = {"attn": {"wq": jax.ShapeDtypeStruct((2048, 56, 128),
                                                jnp.bfloat16)}}
    s = _spec(tree)
    assert s["attn/wq"] == P(None, None, None)     # 56 % 16 != 0 -> replicate


def test_moe_expert_fsdp():
    tree = {"blocks": {"moe": {
        "w_gate": jax.ShapeDtypeStruct((48, 16, 5120, 8192), jnp.bfloat16),
        "w_down": jax.ShapeDtypeStruct((48, 16, 8192, 5120), jnp.bfloat16),
    }}}
    s = _spec(tree)
    assert s["blocks/moe/w_gate"] == P(None, "data", None, "model")
    assert s["blocks/moe/w_down"] == P(None, "data", "model", None)


def test_moe_expert_fsdp_fallback_to_dmodel():
    # 8 experts don't divide data=16 -> shard d_model instead
    tree = {"blocks": {"moe": {
        "w_gate": jax.ShapeDtypeStruct((32, 8, 4096, 14336), jnp.bfloat16),
    }}}
    s = _spec(tree)
    assert s["blocks/moe/w_gate"] == P(None, None, "data", "model")


def test_zero_opt_sharding_adds_data_axis():
    path_tree = {"mu": {"blocks": {"mlp": {
        "w_up": jax.ShapeDtypeStruct((48, 2048, 8192), jnp.float32)}}}}
    flat, _ = jax.tree_util.tree_flatten_with_path(path_tree)
    (path, leaf), = flat
    spec = shr.opt_spec(path, leaf, MESH)
    assert spec == P("data", None, "model")


def test_small_leaves_not_zero_sharded():
    path_tree = {"mu": {"ln": {
        "scale": jax.ShapeDtypeStruct((2048,), jnp.float32)}}}
    flat, _ = jax.tree_util.tree_flatten_with_path(path_tree)
    (path, leaf), = flat
    assert shr.opt_spec(path, leaf, MESH) == P(None)


def test_cache_specs():
    tree = {"k": jax.ShapeDtypeStruct((48, 128, 32768, 16, 128),
                                      jnp.bfloat16)}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    (path, leaf), = flat
    assert shr.cache_spec(path, leaf, MESH) == \
        P(None, ("data",), None, "model", None)
    assert shr.cache_spec(path, leaf, MESH, seq_shard=True) == \
        P(None, ("data",), "data", "model", None)


def test_batch_specs():
    tree = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
            "token": jax.ShapeDtypeStruct((1,), jnp.int32)}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {str(path[0].key): shr.batch_spec(path, leaf, MESH)
           for path, leaf in flat}
    assert out["tokens"] == P(("data",), None)
    assert out["token"] == P(None)      # batch 1 cannot shard -> guard
