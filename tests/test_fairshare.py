"""Proportional-fair allocation (paper §III, eqs. 10-14)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import fairshare
from repro.core.types import ControlParams

P = ControlParams()


def test_eq11_is_argmax_of_eq10():
    r, d = 120.0, 40.0
    s_star = r / d
    def f(s):
        return r * np.log(s) - d * s

    grid = np.linspace(0.1, 10.0, 2000)
    assert f(s_star) >= f(grid).max() - 1e-9


@given(st.floats(1.0, 1e4), st.floats(10.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_optimal_rate_property(r, d):
    """f'(s*) == 0 and f''(s*) < 0 for every (r, d)."""
    s = r / d
    grad = r / s - d
    assert abs(grad) < 1e-6 * max(d, 1.0)


def test_band_scaling_down_eq13():
    r = jnp.asarray([100.0, 200.0])
    d = jnp.asarray([10.0, 10.0])
    active = jnp.ones(2, bool)
    n_tot = jnp.asarray(10.0)           # demand 30 > 10 + α
    a = fairshare.allocate(r, d, active, n_tot, P)
    # every rate scaled by (N+α)/N*
    np.testing.assert_allclose(
        np.asarray(a.s), np.asarray([10.0, 20.0]) * (15.0 / 30.0), rtol=1e-6)


def test_band_scaling_up_eq14():
    r = jnp.asarray([10.0])
    d = jnp.asarray([10.0])
    active = jnp.ones(1, bool)
    n_tot = jnp.asarray(10.0)           # demand 1 < β·10
    a = fairshare.allocate(r, d, active, n_tot, P)
    assert float(a.s[0]) == pytest.approx(1.0 * (9.0 / 1.0), rel=1e-6)


def test_inside_band_unscaled():
    r = jnp.asarray([100.0])
    d = jnp.asarray([10.0])
    a = fairshare.allocate(r, d, jnp.ones(1, bool), jnp.asarray(10.0), P)
    assert float(a.s[0]) == pytest.approx(10.0, rel=1e-6)


def test_per_workload_cap():
    r = jnp.asarray([1e6])
    d = jnp.asarray([1.0])
    a = fairshare.allocate(r, d, jnp.ones(1, bool), jnp.asarray(100.0), P)
    assert float(a.s[0]) <= P.n_w_max + 1e-6


def test_surge_ceiling_bounds_demand():
    r = jnp.asarray([1e9])
    d = jnp.asarray([1e-3])
    a = fairshare.allocate(r, d, jnp.ones(1, bool), jnp.asarray(10.0), P)
    assert float(a.n_star) <= P.surge_mult * P.n_w_max + 1e-6


def test_confirm_ttc_extends_infeasible():
    r = jnp.asarray([1000.0])
    d_req = jnp.asarray([10.0])         # would need s = 100 > N_w_max
    out = fairshare.confirm_ttc(r, d_req, jnp.ones(1, bool), P)
    assert float(out[0]) == pytest.approx(100.0)


@given(st.lists(st.tuples(st.floats(1.0, 1e4), st.floats(60.0, 1e4)),
                min_size=1, max_size=8),
       st.floats(1.0, 200.0))
@settings(max_examples=50, deadline=None)
def test_allocation_invariants(pairs, n_tot):
    """Rates are non-negative, capped, zero for inactive workloads."""
    r = jnp.asarray([p[0] for p in pairs])
    d = jnp.asarray([p[1] for p in pairs])
    active = jnp.arange(len(pairs)) % 2 == 0
    a = fairshare.allocate(r, d, active, jnp.asarray(n_tot), P)
    s = np.asarray(a.s)
    assert (s >= 0).all() and (s <= P.n_w_max + 1e-5).all()
    assert (s[~np.asarray(active)] == 0).all()
