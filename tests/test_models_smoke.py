"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU, asserting shapes + no NaNs.
The FULL configs are exercised only via the dry-run (deliverable e/f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from repro.configs import ARCHS, SHAPES
from repro.models import Model

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(red):
    if red.family == "vlm":
        p = red.n_patches
        return {"tokens": jnp.ones((B, S - p), jnp.int32),
                "labels": jnp.ones((B, S - p), jnp.int32),
                "patch_embeds": jnp.ones((B, p, red.d_model), jnp.bfloat16)}
    if red.family == "audio":
        return {"tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32),
                "frames": jnp.ones((B, red.enc_len, red.d_model),
                                   jnp.bfloat16)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_loss(name):
    red = ARCHS[name].reduced()
    m = Model(red)
    params = m.init_params(KEY)
    batch = _batch(red)
    logits = m.forward(params, batch, remat=False)
    n_text = batch["tokens"].shape[1]
    exp_s = n_text + (red.n_patches if red.family == "vlm" else 0)
    assert logits.shape[0] == B and logits.shape[1] == exp_s
    assert logits.shape[2] >= red.vocab
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    red = ARCHS[name].reduced()
    m = Model(red)
    from repro.training import optimizer
    from repro.training.train_loop import init_state, make_train_step
    state = init_state(m, KEY)
    step = jax.jit(make_train_step(m, optimizer.OptConfig(lr=1e-3)))
    state2, metrics = step(state, _batch(red))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # the optimizer actually stepped: f32 first moments are non-zero
    # (bf16 params may not move visibly at warmup-scale learning rates)
    assert int(state2.opt.step) == 1
    mu_norm = sum(float(jnp.sum(jnp.abs(m)))
                  for m in jax.tree.leaves(state2.opt.mu))
    assert mu_norm > 0.0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_decode_step(name):
    red = ARCHS[name].reduced()
    m = Model(red)
    params = m.init_params(KEY)
    batch = _batch(red)
    cache = m.init_decode_state(params, batch, max_len=128)
    logits, cache2 = jax.jit(m.decode_step)(
        params, batch["tokens"][:, 0], cache, jnp.asarray(3))
    assert logits.shape[0] == B
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache actually updated
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed


@pytest.mark.parametrize("name", ["qwen1.5-0.5b", "mamba2-780m",
                                  "mixtral-8x7b"])
def test_prefill_decode_consistency(name):
    """Greedy decode logits match teacher-forced forward logits."""
    red = ARCHS[name].reduced()
    m = Model(red)
    params = m.init_params(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, red.vocab)
    full = m.forward(params, {"tokens": toks}, remat=False)
    cache = m.init_decode_state(params, {"tokens": toks}, max_len=16)
    outs = []
    for i in range(8):
        lg, cache = m.decode_step(params, toks[:, i], cache, jnp.asarray(i))
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - full.astype(jnp.float32))))
    assert err < 0.25, err     # bf16 accumulation tolerance


def test_shape_applicability():
    long = SHAPES["long_500k"]
    for name, cfg in ARCHS.items():
        m = Model(cfg)
        if cfg.family in ("ssm", "hybrid") or cfg.sliding_window \
                or cfg.attn_chunk:
            assert m.supports(long), name
        else:
            assert not m.supports(long), name
