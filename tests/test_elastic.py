"""Elastic runtime: AIMD-driven resizing, failure/straggler handling,
checkpoint-restart continuity (integration test on a tiny real model)."""

import jax
import pytest

from repro.configs import ARCHS
from repro.core.types import ControlParams
from repro.data.pipeline import DataConfig, batch_at
from repro.ft.elastic import ElasticConfig, ElasticTrainer
from repro.ft.failures import FailureConfig, FailureInjector
from repro.models import Model
from repro.training import optimizer
from repro.training.train_loop import init_state, make_train_step


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    red = ARCHS["qwen1.5-0.5b"].reduced()
    model = Model(red)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, optimizer.OptConfig(lr=1e-3)))
    data = DataConfig(vocab=red.vocab, seq_len=32, global_batch=4)
    ckdir = str(tmp_path_factory.mktemp("ck"))
    return model, state, step, data, ckdir


def test_elastic_run(setup):
    model, state, step, data, ckdir = setup
    cfg = ElasticConfig(total_steps=40, ttc_seconds=20.0,
                        min_replicas=1, max_replicas=8,
                        checkpoint_every=10, checkpoint_dir=ckdir,
                        control=ControlParams(alpha=2.0, beta=0.9,
                                              n_min=1.0, n_max=8.0),
                        sim_base_step=1.0)
    inj = FailureInjector(FailureConfig(p_fail=2e-2, p_straggle=5e-2,
                                        seed=3))
    trainer = ElasticTrainer(cfg, step, state,
                             lambda s: batch_at(data, s), failures=inj)
    records = trainer.run()
    assert len(records) == 40
    sizes = {r.replicas for r in records}
    assert len(sizes) > 1, "AIMD never resized"
    assert int(trainer.state.opt.step) == 40, "steps lost across resizes"
    events = [r.event for r in records if r.event]
    assert any("resize" in e for e in events)
    # Kalman tracked per-step chip-seconds to a sane value
    assert 0.0 < records[-1].b_hat < 10.0


def test_straggler_replacement(setup):
    model, state, step, data, ckdir = setup
    cfg = ElasticConfig(total_steps=15, ttc_seconds=60.0, min_replicas=4,
                        max_replicas=4, checkpoint_every=100,
                        checkpoint_dir=ckdir,
                        control=ControlParams(alpha=1.0, beta=0.9,
                                              n_min=4.0, n_max=4.0))
    inj = FailureInjector(FailureConfig(p_fail=0.0, p_straggle=0.3,
                                        straggle_factor=5.0, seed=1))
    tr = ElasticTrainer(cfg, step, state, lambda s: batch_at(data, s),
                        failures=inj)
    records = tr.run()
    assert any("straggle" in r.event for r in records)
    # replaced replicas get fresh ids
    assert tr._next_id > 4
