"""Unit tests for the Kalman CUS predictor (paper §II.A, eqs. 4-9)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kalman
from repro.core.types import ControlParams

P = ControlParams()


def _run(meas, w=1, k=1, params=P):
    st = kalman.init(w, k)
    hist = []
    for m in meas:
        st = kalman.step(st, jnp.full((w, k), m),
                         jnp.ones((w, k), bool), params)
        hist.append(float(st.b_hat[0, 0]))
    return st, hist


def test_bootstrap_uses_first_measurement():
    st, hist = _run([42.0])
    assert hist[0] == pytest.approx(42.0)


def test_converges_to_constant_signal():
    st, hist = _run([10.0] * 30)
    assert hist[-1] == pytest.approx(10.0, rel=1e-3)


def test_gain_reaches_golden_fixed_point():
    # π* solves π = (1-κ)(π+σz²) with κ = (π+σz²)/(π+σz²+σv²);
    # for σz²=σv²=0.5 the stationary gain is (√5-1)/2 ≈ 0.618.
    st = kalman.init(1, 1)
    for i in range(200):
        st = kalman.step(st, jnp.ones((1, 1)), jnp.ones((1, 1), bool), P)
    pi_minus = float(st.pi[0, 0]) + P.sigma_z2
    kappa = pi_minus / (pi_minus + P.sigma_v2)
    assert kappa == pytest.approx((np.sqrt(5) - 1) / 2, abs=1e-3)


def test_eq8_uses_lagged_measurement():
    # After bootstrap at m0, the next update moves toward m0 (the lagged
    # measurement), not toward the new m1.
    st = kalman.init(1, 1)
    st = kalman.step(st, jnp.full((1, 1), 10.0), jnp.ones((1, 1), bool), P)
    st = kalman.step(st, jnp.full((1, 1), 99.0), jnp.ones((1, 1), bool), P)
    assert float(st.b_hat[0, 0]) == pytest.approx(10.0)


def test_masked_rows_frozen():
    st = kalman.init(2, 1)
    st = kalman.step(st, jnp.full((2, 1), 5.0), jnp.ones((2, 1), bool), P)
    mask = jnp.asarray([[True], [False]])
    st2 = kalman.step(st, jnp.full((2, 1), 50.0), mask, P)
    assert float(st2.b_hat[1, 0]) == float(st.b_hat[1, 0])
    assert float(st2.pi[1, 0]) == float(st.pi[1, 0])


def test_reliable_on_first_negative_slope():
    # Rising measurements keep slope positive; a drop flips reliability.
    st = kalman.init(1, 1)
    for m in [1.0, 2.0, 3.0, 4.0]:
        st = kalman.step(st, jnp.full((1, 1), m), jnp.ones((1, 1), bool), P)
        assert not bool(st.reliable[0, 0])
    for m in [4.0, 1.0, 1.0]:   # eq. 8 lag: the drop lands two steps later
        st = kalman.step(st, jnp.full((1, 1), m), jnp.ones((1, 1), bool), P)
    assert bool(st.reliable[0, 0])


def test_reset_rows_clears_state():
    st, _ = _run([10.0] * 5, w=2)
    st = kalman.reset_rows(st, jnp.asarray([True, False]))
    assert float(st.b_hat[0, 0]) == 0.0 and not bool(st.has_meas[0, 0])
    assert float(st.b_hat[1, 0]) == pytest.approx(10.0, rel=1e-2)
