"""Serving engine: continuous batching, TTC-aware admission, drain."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import Model
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    red = ARCHS["granite-3-2b"].reduced()
    model = Model(red)
    params = model.init_params(jax.random.PRNGKey(0))
    return ServingEngine(model, params, slots=4, max_len=64, eos_id=-1)


def test_drains_all_requests(engine):
    reqs = [Request(rid=i, prompt=np.asarray([3, 5]), max_new_tokens=8,
                    ttc=60.0) for i in range(6)]
    for r in reqs:
        engine.submit(r)
    stats = engine.run_until_drained(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.generated) >= 1 for r in reqs)
    assert stats[-1]["active"] == 0


def test_admission_prefers_tight_deadlines():
    red = ARCHS["granite-3-2b"].reduced()
    model = Model(red)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=1, max_len=32, eos_id=-1)
    loose = Request(rid=1, prompt=np.asarray([1]), max_new_tokens=4,
                    ttc=1000.0)
    tight = Request(rid=2, prompt=np.asarray([1]), max_new_tokens=4,
                    ttc=1.0)
    eng.submit(loose)
    eng.submit(tight)
    eng.step()
    assert 2 in eng.slot_of or (tight.done and not loose.done) \
        or 2 not in eng.active and len(tight.generated) > 0


def test_per_token_cost_tracked(engine):
    engine.submit(Request(rid=99, prompt=np.asarray([2]), max_new_tokens=4,
                          ttc=30.0))
    s = engine.step()
    assert s["per_token_cost"] > 0.0
    engine.run_until_drained()
