"""In-scan anomaly detection (``repro.obs.detect``): static gating,
jit compatibility, calibration against the committed chaos scenarios,
and the sweep summary's ``alerts`` field.

The load-bearing contracts: ``ObsSpec.detect=None`` (the default)
compiles the exact detector-free program (its sweep digest is pinned by
``benchmarks/baselines/BENCH_obs.json``); armed detectors are read-only
— they perturb nothing but the summary's ``alerts`` count; and their
thresholds are *calibrated*, not decorative — zero alerts on clean
replays, at least one correctly-localized alert under every committed
chaos scenario.
"""

import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.obs import BURN_NAMES, SIGNAL_NAMES, DetectSpec, ObsSpec
from repro.obs import detect as detect_lib
from repro.obs import ledger as ledger_lib
from repro.sim import (SimConfig, SpotConfig, SweepSpec, faults, make_axes,
                       paper_schedule, runner)
from repro.sim.sweep import sweep

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:          # benchmarks/ is a namespace package
    sys.path.insert(0, str(REPO))
from benchmarks import bench_chaos, bench_obs  # noqa: E402

SCHED = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)


def _cfg(obs: ObsSpec | None = None, **market) -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=300.0),
                              billing=BillingParams(terminate="immediate")),
        ticks=130, spot=SpotConfig(enabled=True, **market), obs=obs)


def _alerts(report) -> list:
    return [r for r in report.ledger if r.kind in ledger_lib.ALERT_KINDS]


def _assert_same(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------ spec

def test_detectspec_is_static_hashable_and_validated():
    # Rides ObsSpec.detect and therefore every jit cache key.
    assert hash(DetectSpec()) == hash(DetectSpec())
    assert DetectSpec() != DetectSpec(cusum=False)
    assert hash(ObsSpec.full(detect=True)) == hash(ObsSpec.full(detect=True))
    with pytest.raises(ValueError):
        DetectSpec(slo_viol_per_tick=0.0)
    with pytest.raises(ValueError):
        DetectSpec(burn_warn_mult=10.0, burn_page_mult=2.0)


def test_obsspec_detect_flag_builds_a_spec():
    assert ObsSpec.full().detect is None
    assert ObsSpec.full(detect=True).detect == DetectSpec()
    custom = DetectSpec(nis=False)
    assert ObsSpec.full(detect=custom).detect is custom


# ------------------------------------------------------------ jit + firing

def test_change_point_detectors_fire_under_jit_on_a_step():
    """CUSUM/EWMA compiled into a scan: silent on a flat signal, firing —
    with the right subject and a localized first tick — after a level
    step in spot_price (signal 1)."""
    spec = DetectSpec(nis=False)   # NIS needs the KalmanProbe; the
                                   # end-to-end tests below arm it

    @jax.jit
    def run(level):
        dc = detect_lib.init(spec, w=1, k=1)
        led = ledger_lib.init(64)

        def body(carry, t):
            dc, led = carry
            sig = jnp.zeros((detect_lib.N_SIGNALS,), jnp.float32)
            sig = sig.at[1].set(jnp.where(t < 40, 1.0, level))
            dc, led = detect_lib.update(dc, spec, t, signals=sig,
                                        kalman=None,
                                        cost_delta=jnp.asarray(0.0), led=led)
            return (dc, led), None

        return jax.lax.scan(body, (dc, led), jnp.arange(80))[0]

    dc, led = run(jnp.asarray(1.0))          # no step: stays silent
    assert float(jnp.sum(dc.n_alerts)) == 0.0
    assert all(int(t) == -1 for t in dc.first_tick)

    dc, led = run(jnp.asarray(5.0))          # 4-unit step at t=40
    recs, _ = ledger_lib.drain(led)
    fired = {r.kind_name for r in recs}
    assert {"alert_cusum", "alert_ewma"} <= fired
    for fam in (0, 1):                       # cusum, ewma
        assert int(dc.n_alerts[fam]) >= 1
        assert 40 <= int(dc.first_tick[fam]) <= 50
    # The subject column carries the monitored signal that fired.
    assert all(SIGNAL_NAMES[r.tenant] == "spot_price" for r in recs)


def test_burn_rate_warns_then_pages_on_budget_overrun():
    """SLO burn: a sustained violation rate far over budget pages on the
    fast window; events fire on level transitions only — a steady burn
    is one page, not eighty."""
    spec = DetectSpec(cusum=False, ewma=False, nis=False,
                      slo_viol_per_tick=0.01)

    @jax.jit
    def run():
        dc = detect_lib.init(spec, w=1, k=1)
        led = ledger_lib.init(64)

        def body(carry, t):
            dc, led = carry
            sig = jnp.zeros((detect_lib.N_SIGNALS,), jnp.float32)
            sig = sig.at[2].set(jnp.where(t >= 30, 1.0, 0.0))  # viol_rate
            dc, led = detect_lib.update(dc, spec, t, signals=sig,
                                        kalman=None,
                                        cost_delta=jnp.asarray(0.0), led=led)
            return (dc, led), None

        return jax.lax.scan(body, (dc, led), jnp.arange(80))[0]

    dc, led = run()
    recs, _ = ledger_lib.drain(led)
    burn = [r for r in recs if r.kind_name == "alert_burn"]
    assert burn and all(BURN_NAMES[r.tenant] == "viol" for r in burn)
    assert any(r.severity == ledger_lib.SEV_PAGE for r in burn)
    assert int(dc.first_tick[3]) >= 30
    # Transition-fired: far fewer events than over-budget ticks.
    assert len(burn) <= 4


# ------------------------------------------------- static gating / neutrality

def test_armed_detectors_leave_the_run_bit_identical():
    """Detectors are read-only: arming the full detector catalog on top
    of the full probe catalog moves no result bit, and the probe report
    differs only by its ``detect`` section."""
    tr_probes, rep_probes = runner.run_obs(
        SCHED, _cfg(ObsSpec.full(ledger=64)), seed=0)
    tr_det, rep_det = runner.run_obs(
        SCHED, _cfg(ObsSpec.full(ledger=64, detect=True)), seed=0)
    _assert_same(tr_probes, tr_det)
    assert rep_probes.detect is None
    assert isinstance(rep_det.detect, dict)
    assert rep_probes.counters == {
        k: v for k, v in rep_det.counters.items()
        if not k.startswith("alerts_")}


def test_sweep_summary_alerts_field_gates_on_detect():
    """The sweep summary gains an ``alerts`` leaf only when detectors
    are armed; every other field stays bit-identical (the leafless-None
    contract that keeps detector-free digests and chunk files stable)."""
    axes = make_axes(range(3), [1.1])
    spec_off = SweepSpec(axes=axes, workload=SCHED)
    off = sweep(spec_off, _cfg(ObsSpec.full(ledger=32)))
    on = sweep(spec_off, _cfg(ObsSpec.full(ledger=32, detect=True)))
    assert off.alerts is None
    assert on.alerts is not None and on.alerts.shape == (3,)
    assert on.alerts.dtype == jnp.int32
    _assert_same(on._replace(alerts=None), off)
    # And with obs off entirely the field stays leafless too.
    assert sweep(spec_off, _cfg()).alerts is None


# ------------------------------------------------------------- calibration

def test_clean_paper_replay_fires_zero_alerts():
    """False-positive gate (ISSUE acceptance): the spike-free paper
    replay with every detector armed stays silent, and the report's
    detect section agrees with the ledger."""
    cfg = _cfg(ObsSpec.full(ledger=128, detect=True),
               **dict(bench_obs.MARKET, p_spike_per_core=0.0))
    _, report = runner.run_obs(SCHED, cfg, seed=0)
    det = report.detect
    assert det["alerts_total"] == 0
    assert _alerts(report) == []
    assert all(v == 0 for v in det["alerts_by_family"].values())
    assert all(t == -1 for t in det["first_tick_by_family"].values())


@pytest.mark.parametrize("name", sorted(bench_chaos.SCENARIOS))
def test_chaos_scenarios_fire_localized_alerts(name):
    """True-positive gate (ISSUE acceptance): every committed chaos
    scenario fires at least one alert whose first tick lands inside the
    injected fault window."""
    sc = bench_chaos.SCENARIOS[name]
    det = ObsSpec.full(ledger=256, detect=True)
    cfg = bench_obs._chaos_cfg(det, faults.FaultConfig(hardened=True),
                               **sc["market"])
    fs = faults.make_fault_spec(**sc["spec"])
    _, report = runner.run_obs(bench_chaos._sched(), cfg, seed=0, fspec=fs)
    recs = _alerts(report)
    assert recs, f"{name}: detectors missed the injected fault"
    lo, hi = bench_obs.ALERT_WINDOWS.get(name, (0, bench_chaos.TICKS))
    first = min(r.tick for r in recs)
    assert lo <= first <= hi, (
        f"{name}: first alert at tick {first} outside window ({lo}, {hi})")
    # Counters, ledger and first-tick registers tell one story.
    assert report.detect["alerts_total"] == len(recs)
    firsts = [t for t in report.detect["first_tick_by_family"].values()
              if t >= 0]
    assert firsts and min(firsts) == first
