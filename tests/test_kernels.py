"""Pallas kernel validation: shape/dtype sweeps against pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import gqa_flash_attention, gqa_reference
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kalman_update.ops import kalman_update
from repro.kernels.kalman_update.ref import kalman_fused_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.models.ssm import ssd_chunked, ssd_reference

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("bh,sq,sk,hd,causal", [
    (1, 128, 128, 64, True),
    (4, 256, 256, 64, True),
    (2, 128, 384, 128, False),
    (3, 384, 128, 128, True),
    (1, 512, 512, 256, True),
])
def test_flash_kernel_shapes(bh, sq, sk, hd, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, sq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (bh, sk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (bh, sk, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = jax.vmap(lambda a, b, c: attention_ref(a, b, c, causal))(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 128), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (2, 128, 128), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (2, 128, 128), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = jax.vmap(attention_ref)(q, k, v)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=atol, rtol=0.05)


def test_flash_gqa_wrapper():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 128, 2, 64), jnp.float32)
    out = gqa_flash_attention(q, k, v, causal=True)
    ref = gqa_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (128, 2, 64, 64, 32),
    (256, 4, 64, 128, 64),
    (256, 1, 128, 64, 128),
    (512, 2, 64, 128, 256),
])
def test_ssd_kernel_shapes(s, h, p, n, chunk):
    ks = jax.random.split(KEY, 5)
    b = 2
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    y_k = ssd(x, dt, a_log, bb, cc, chunk=chunk, interpret=True)
    y_ref, _ = ssd_reference(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(y_k, y_ref, atol=5e-4, rtol=5e-4)


def test_ssd_model_impl_matches_reference():
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, s, n))
    cc = jax.random.normal(ks[4], (b, s, n))
    for chunk in (16, 32, 64):
        y1, s1 = ssd_chunked(x, dt, a_log, bb, cc, chunk)
        y2, s2 = ssd_reference(x, dt, a_log, bb, cc)
        np.testing.assert_allclose(y1, y2, atol=1e-3)
        np.testing.assert_allclose(s1, s2, atol=1e-3)


@pytest.mark.parametrize("w,k", [(256, 128), (512, 256), (1024, 128)])
def test_kalman_kernel_shapes(w, k):
    ks = jax.random.split(KEY, 4)
    b_hat = jax.random.normal(ks[0], (w, k)) ** 2
    pi = jax.random.normal(ks[1], (w, k)) ** 2
    meas = jax.random.normal(ks[2], (w, k)) ** 2
    mask = jax.random.bernoulli(ks[3], 0.5, (w, k))
    b1, p1 = kalman_update(b_hat, pi, meas, mask)
    b2, p2 = kalman_fused_ref(b_hat, pi, meas, mask, 0.5, 0.5)
    np.testing.assert_allclose(b1, b2, atol=1e-6)
    np.testing.assert_allclose(p1, p2, atol=1e-6)


def test_kalman_kernel_matches_controller_step():
    """The fused kernel implements exactly core.kalman.step's update path."""
    import jax.numpy as jnp
    from repro.core import kalman
    from repro.core.types import ControlParams

    w, k = 256, 128
    ks = jax.random.split(KEY, 2)
    st = kalman.init(w, k)
    meas = jax.random.normal(ks[0], (w, k)) ** 2 + 1.0
    ones = jnp.ones((w, k), bool)
    p = ControlParams()
    st = kalman.step(st, meas, ones, p)              # bootstrap
    st2 = kalman.step(st, meas * 1.1, ones, p)       # regular update

    b_k, pi_k = kalman_update(st.b_hat, st.pi, st.b_meas_prev, ones,
                              p.sigma_z2, p.sigma_v2)
    np.testing.assert_allclose(b_k, st2.b_hat, atol=1e-5)
    np.testing.assert_allclose(pi_k, st2.pi, atol=1e-5)
