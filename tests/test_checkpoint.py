"""Checkpointing: atomic commit, roundtrip, topology-agnostic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "blocks": {"a": jnp.ones((2, 2), jnp.bfloat16)}},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 7, tree)
    assert checkpointer.latest_step(d) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    out = checkpointer.restore(d, 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_uncommitted_checkpoint_invisible(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 3, tree)
    os.remove(os.path.join(d, "step_00000003.done"))
    assert checkpointer.latest_step(d) is None


def test_prune_keeps_newest(tmp_path, tree):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        checkpointer.save(d, s, tree)
    checkpointer.prune(d, keep=2)
    assert checkpointer.latest_step(d) == 5
    steps = sorted(int(n[5:13]) for n in os.listdir(d)
                   if n.endswith(".done"))
    assert steps == [4, 5]


def test_bit_flip_detected_and_chunk_recomputed(tmp_path, tree):
    """A committed checkpoint whose bytes rot fails verify(); the
    streaming sweep resume path then silently recomputes that chunk."""
    d = str(tmp_path)
    checkpointer.save(d, 2, tree)
    assert checkpointer.verify(d, 2)
    # Flip one byte of one leaf file, past the .npy header.
    fname = os.path.join(d, "step_00000002", "params__w.npy")
    with open(fname, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        byte = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([byte[0] ^ 0xFF]))
    assert not checkpointer.verify(d, 2)
    # The marker still says committed — only the digest catches the rot.
    assert checkpointer.committed_steps(d) == [2]


def test_truncated_leaf_fails_verify(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 1, tree)
    fname = os.path.join(d, "step_00000001", "step.npy")
    with open(fname, "r+b") as f:
        f.truncate(os.path.getsize(fname) - 1)
    assert not checkpointer.verify(d, 1)


def test_pre_digest_manifest_accepted(tmp_path, tree):
    """Manifests written before the sha256 field verify as-is (nothing to
    check against) so old checkpoints stay restorable."""
    import json
    d = str(tmp_path)
    checkpointer.save(d, 4, tree)
    mpath = os.path.join(d, "step_00000004", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    for meta in manifest["leaves"].values():
        meta.pop("sha256")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    assert checkpointer.verify(d, 4)


def test_streamed_sweep_recomputes_corrupted_chunk(tmp_path):
    """End to end: corrupt one committed chunk of a streamed sweep, rerun
    the same spec, and the loaded result is bit-identical to a fresh
    in-memory sweep — the rotten chunk was recomputed, not restored."""
    from repro.sim import (SimConfig, SpotConfig, SweepSpec, sweep,
                          workloads)
    sched = workloads.paper_schedule()
    cfg = SimConfig(ticks=60, spot=SpotConfig(enabled=True))
    axes = sweep.make_axes(seeds=[0, 1, 2, 3], bid_mults=[1.0])
    clean = sweep.sweep(SweepSpec(axes=axes, workload=sched,
                                  chunk_size=2), cfg)
    d = str(tmp_path / "stream")
    spec = SweepSpec(axes=axes, workload=sched, chunk_size=2,
                     stream_dir=d)
    sweep.sweep(spec, cfg)
    victim = os.path.join(d, "step_00000001", "cost.npy")
    with open(victim, "r+b") as f:
        f.seek(-2, os.SEEK_END)
        f.write(b"\xff\xff")
    assert not checkpointer.verify(d, 1)
    out = sweep.sweep(spec, cfg).load()
    for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shape_mismatch_rejected(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 1, tree)
    bad = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                      "blocks": {"a": jax.ShapeDtypeStruct((2, 2),
                                                           jnp.bfloat16)}},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError):
        checkpointer.restore(d, 1, bad)
