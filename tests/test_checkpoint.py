"""Checkpointing: atomic commit, roundtrip, topology-agnostic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "blocks": {"a": jnp.ones((2, 2), jnp.bfloat16)}},
            "step": jnp.asarray(7)}


def test_roundtrip(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 7, tree)
    assert checkpointer.latest_step(d) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    out = checkpointer.restore(d, 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_uncommitted_checkpoint_invisible(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 3, tree)
    os.remove(os.path.join(d, "step_00000003.done"))
    assert checkpointer.latest_step(d) is None


def test_prune_keeps_newest(tmp_path, tree):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        checkpointer.save(d, s, tree)
    checkpointer.prune(d, keep=2)
    assert checkpointer.latest_step(d) == 5
    steps = sorted(int(n[5:13]) for n in os.listdir(d)
                   if n.endswith(".done"))
    assert steps == [4, 5]


def test_shape_mismatch_rejected(tmp_path, tree):
    d = str(tmp_path)
    checkpointer.save(d, 1, tree)
    bad = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                      "blocks": {"a": jax.ShapeDtypeStruct((2, 2),
                                                           jnp.bfloat16)}},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError):
        checkpointer.restore(d, 1, bad)
