"""AIMD scaling + baseline policies (paper §IV Fig. 1, §V.C)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import aimd
from repro.core.types import ControlParams

P = ControlParams()


def test_additive_increase():
    s = aimd.aimd_init(10.0)
    s = aimd.aimd_step(s, jnp.asarray(10.0), jnp.asarray(20.0), P)
    assert float(s.n_target) == pytest.approx(15.0)


def test_multiplicative_decrease():
    s = aimd.aimd_init(50.0)
    s = aimd.aimd_step(s, jnp.asarray(50.0), jnp.asarray(10.0), P)
    assert float(s.n_target) == pytest.approx(45.0)


def test_bounds():
    s = aimd.aimd_step(aimd.aimd_init(10.0), jnp.asarray(99.0),
                       jnp.asarray(1e9), P)
    assert float(s.n_target) == P.n_max
    s = aimd.aimd_step(aimd.aimd_init(10.0), jnp.asarray(10.5),
                       jnp.asarray(0.0), P)
    assert float(s.n_target) == P.n_min


@given(st.floats(1.0, 100.0), st.floats(0.0, 200.0))
@settings(max_examples=100, deadline=None)
def test_fig1_invariant(n, n_star):
    """One AIMD step moves N by at most +α or shrinks by exactly ×β
    (within [N_min, N_max])."""
    s = aimd.aimd_step(aimd.aimd_init(n), jnp.asarray(n), jnp.asarray(n_star), P)
    t = float(s.n_target)
    if n <= n_star:
        assert t == pytest.approx(min(n + P.alpha, P.n_max))
    else:
        assert t == pytest.approx(max(P.beta * n, P.n_min))


def test_mwa_is_mean_of_history():
    s = aimd.policy_init()
    for v in [10.0, 20.0, 30.0]:
        s = aimd.policy_push(s, jnp.asarray(v))
    assert float(aimd.mwa_target(s, P)) == pytest.approx(20.0)


def test_lr_extrapolates_line():
    s = aimd.policy_init()
    for v in [10.0, 12.0, 14.0, 16.0, 18.0, 20.0]:  # slope +2/tick
        s = aimd.policy_push(s, jnp.asarray(v))
    assert float(aimd.lr_target(s, P)) == pytest.approx(22.0, abs=1e-3)


def test_reactive_follows_latest():
    s = aimd.policy_init()
    s = aimd.policy_push(s, jnp.asarray(33.0))
    assert float(aimd.reactive_target(s, P)) == pytest.approx(33.0)


def test_termination_order_smallest_remaining_first():
    a = jnp.asarray([300.0, 10.0, 2000.0, 50.0])
    active = jnp.asarray([True, True, False, True])
    order = np.asarray(aimd.termination_order(a, active))
    assert list(order[:3]) == [1, 3, 0]
