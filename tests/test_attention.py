"""Model attention paths: blocked flash vs O(S²) reference, all mask
variants, GQA grouping, decode-vs-full consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (AttnSpec, decode_attention,
                                    flash_attention, reference_attention,
                                    update_cache)

KEY = jax.random.PRNGKey(11)


def _qkv(b, s, h, kv, hd):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    return q, k, v


SPECS = [
    AttnSpec(n_heads=4, n_kv=4, hd=32),                       # MHA causal
    AttnSpec(n_heads=8, n_kv=2, hd=32),                       # GQA
    AttnSpec(n_heads=4, n_kv=4, hd=32, window=24),            # SWA
    AttnSpec(n_heads=4, n_kv=2, hd=32, chunk=32),             # chunked local
    AttnSpec(n_heads=4, n_kv=4, hd=32, causal=False),         # bidirectional
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"kv{s.n_kv}"
                         f"_w{s.window}_c{s.chunk}_{s.causal}")
@pytest.mark.parametrize("s,k_block", [(96, 32), (128, 128), (160, 64)])
def test_flash_matches_reference(spec, s, k_block):
    q, k, v = _qkv(2, s, spec.n_heads, spec.n_kv, spec.hd)
    out = flash_attention(q, k, v, spec, k_block=k_block)
    ref = reference_attention(q, k, v, spec)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_is_global_lifts_chunk_mask():
    spec = AttnSpec(n_heads=2, n_kv=2, hd=16, chunk=16)
    q, k, v = _qkv(1, 64, 2, 2, 16)
    local = flash_attention(q, k, v, spec, is_global=jnp.asarray(False))
    glob = flash_attention(q, k, v, spec, is_global=jnp.asarray(True))
    causal = reference_attention(q, k, v, AttnSpec(n_heads=2, n_kv=2, hd=16))
    np.testing.assert_allclose(glob, causal, atol=3e-5, rtol=3e-5)
    assert not np.allclose(local, glob)


def test_decode_matches_full_attention():
    spec = AttnSpec(n_heads=4, n_kv=2, hd=32)
    s = 16
    q, k, v = _qkv(1, s, 4, 2, 32)
    full = reference_attention(q, k, v, spec)
    ck = jnp.zeros((1, s, 2, 32))
    cv = jnp.zeros((1, s, 2, 32))
    for i in range(s):
        ck, cv = update_cache(ck, cv, k[:, i:i + 1], v[:, i:i + 1],
                              jnp.asarray(i))
    out_last = decode_attention(q[:, -1:], ck, cv, jnp.asarray(s), spec)
    np.testing.assert_allclose(out_last[:, 0], full[:, -1], atol=3e-5,
                               rtol=3e-5)


def test_ring_cache_window_semantics():
    w = 8
    spec = AttnSpec(n_heads=2, n_kv=2, hd=16, window=w)
    s = 24
    q, k, v = _qkv(1, s, 2, 2, 16)
    full = reference_attention(q, k, v, spec)
    ck = jnp.zeros((1, w, 2, 16))
    cv = jnp.zeros((1, w, 2, 16))
    for i in range(s):
        ck, cv = update_cache(ck, cv, k[:, i:i + 1], v[:, i:i + 1],
                              jnp.asarray(i), ring_size=w)
    out = decode_attention(q[:, -1:], ck, cv, jnp.asarray(s), spec,
                           ring=True)
    np.testing.assert_allclose(out[:, 0], full[:, -1], atol=3e-5, rtol=3e-5)
