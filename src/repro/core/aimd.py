"""AIMD compute-unit scaling (paper §IV, Fig. 1) and scaling baselines (§V.C).

The AIMD rule, verbatim from Fig. 1:

    if N_tot[t] <= N*_tot[t]:   N_tot[t+1] = min(N_tot[t] + α, N_max)
    else:                        N_tot[t+1] = max(β N_tot[t], N_min)

Baselines (all consume the same N*_tot history, eq. 12):
  * Reactive:  N_tot[t+1] = N*_tot[t]
  * MWA (eq. 16):  mean of the last 6 values of N*_tot
  * LR:  extrapolate a least-squares line through the last 6 values of N*_tot

Instance termination (§IV): always terminate the instances with the smallest
remaining paid time a_{i,j} — they are about to incur another billing quantum.
"""

from __future__ import annotations

import jax.numpy as jnp

from .types import AimdState, ControlParams, PolicyParams, PolicyState

HIST = 6  # MWA / LR look-back (current + five previous, §V.C)


def aimd_init(n0: float) -> AimdState:
    return AimdState(n_target=jnp.asarray(n0, jnp.float32))


def increase_branch(n_tot: jnp.ndarray, n_star: jnp.ndarray) -> jnp.ndarray:
    """Fig. 1's branch predicate: True = additive increase, False =
    multiplicative backoff.  Split out as the probe-emission hook for the
    observability layer (``repro.obs``): the AIMD branch counters and the
    ledger's backoff-transition events are *defined* as this predicate —
    the same compiled op ``aimd_step`` takes its branch on — so a probe
    can never disagree with the decision it observes."""
    return n_tot <= n_star


def aimd_step(state: AimdState, n_tot: jnp.ndarray, n_star: jnp.ndarray,
              params: ControlParams,
              pp: PolicyParams | None = None) -> AimdState:
    """Fig. 1: one AIMD update of the CU target.

    ``pp`` supplies the gains as *traced* values (``PolicyParams``) so a
    tuner can vmap candidate (α, β) pairs through one compiled simulation;
    without it the static config gains apply (bit-identical: the config
    floats enter the same f32 arithmetic either way).  The N_min/N_max
    band always comes from the static ``params`` — platform limits are not
    a policy knob.
    """
    alpha = params.alpha if pp is None else pp.alpha
    beta = params.beta if pp is None else pp.beta
    incr = increase_branch(n_tot, n_star)
    up = jnp.minimum(n_tot + alpha, params.n_max)
    down = jnp.maximum(beta * n_tot, params.n_min)
    return AimdState(n_target=jnp.where(incr, up, down))


def backoff_delay(streak: jnp.ndarray, cap, jitter_u: jnp.ndarray) -> jnp.ndarray:
    """Bounded exponential backoff with jitter, in monitoring ticks.

    After the k-th consecutive failed re-acquisition the next retry waits
    ``min(2**k, cap)`` ticks, scaled by a uniform jitter in [0.5, 1.5) so
    recovering controllers do not hammer a returning market in lockstep.
    ``streak`` is clipped before exponentiation to keep f32 finite.
    """
    base = jnp.minimum(2.0 ** jnp.minimum(streak, 30.0), cap)
    return base * (0.5 + jitter_u)


def anti_windup(state: AimdState, ceiling: jnp.ndarray,
                failing: jnp.ndarray) -> AimdState:
    """Clamp the stored AIMD target while acquisition keeps failing.

    During a capacity outage the additive-increase branch would integrate
    the target to N_max with nothing to show for it; on recovery the fleet
    would then thundering-herd to the windup peak at whatever the spot
    price is.  Holding the stored target within one additive step of what
    is actually committed keeps the post-outage ramp at the normal AIMD
    pace.  No-op when ``failing`` is False.
    """
    clamped = jnp.minimum(state.n_target, ceiling)
    return AimdState(n_target=jnp.where(failing, clamped, state.n_target))


def policy_init() -> PolicyState:
    return PolicyState(n_star_hist=jnp.zeros((HIST,), jnp.float32),
                       hist_len=jnp.asarray(0, jnp.int32))


def policy_push(state: PolicyState, n_star: jnp.ndarray) -> PolicyState:
    hist = jnp.concatenate([n_star[None].astype(jnp.float32),
                            state.n_star_hist[:-1]])
    return PolicyState(n_star_hist=hist,
                       hist_len=jnp.minimum(state.hist_len + 1, HIST))


# N_min/N_max are platform-wide CU limits (Table I: "lower/upper limits for
# CUSs in Dithen"), so every scaling policy is clipped to the same band —
# which is why the paper's Reactive/MWA/LR costs cluster tightly while the
# differences come from peak/churn behaviour above the floor.


def reactive_target(state: PolicyState, params: ControlParams) -> jnp.ndarray:
    """N_tot[t+1] = N*_tot[t]."""
    return jnp.clip(state.n_star_hist[0], params.n_min, params.n_max)


def mwa_target(state: PolicyState, params: ControlParams) -> jnp.ndarray:
    """Eq. 16 — mean-weighted average over the last HIST instants."""
    n = jnp.maximum(state.hist_len, 1)
    idx = jnp.arange(HIST)
    valid = (idx < state.hist_len).astype(jnp.float32)
    mean = jnp.sum(state.n_star_hist * valid) / n.astype(jnp.float32)
    return jnp.clip(mean, params.n_min, params.n_max)


def lr_target(state: PolicyState, params: ControlParams) -> jnp.ndarray:
    """Least-squares line through {N*[t-5..t]} extrapolated one step ahead.

    hist[0] is the newest sample at x=0, hist[i] at x=-i; predict x=+1.
    """
    x = -jnp.arange(HIST, dtype=jnp.float32)
    y = state.n_star_hist
    valid = (jnp.arange(HIST) < state.hist_len).astype(jnp.float32)
    n = jnp.maximum(jnp.sum(valid), 1.0)
    xm = jnp.sum(x * valid) / n
    ym = jnp.sum(y * valid) / n
    cov = jnp.sum(valid * (x - xm) * (y - ym))
    var = jnp.sum(valid * (x - xm) ** 2)
    slope = jnp.where(var > 0, cov / jnp.maximum(var, 1e-9), 0.0)
    pred = ym + slope * (1.0 - xm)
    # Degenerate history (<2 samples): behave reactively.
    pred = jnp.where(state.hist_len >= 2, pred, state.n_star_hist[0])
    return jnp.clip(pred, params.n_min, params.n_max)


def termination_order(a: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Indices of active instances sorted by remaining paid time (ascending).

    Implements §IV's rule: kill the instances closest to their billing
    renewal first.  Inactive instances sort to the back.
    """
    key = jnp.where(active, a, jnp.inf)
    return jnp.argsort(key)
