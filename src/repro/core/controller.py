"""The integrated CaaS control plane (paper §II-§IV, one fused step).

Per monitoring instant the platform:
  1. absorbs CUS measurements into the configured predictor (Kalman §II.A,
     or the ad-hoc / ARMA baselines of §V.B),
  2. computes r_w = Σ_k m b̂ (eq. 1), detects t_init and confirms TTCs,
  3. allocates proportional-fair service rates (eqs. 11-14),
  4. updates the CU target with the configured scaling policy (AIMD Fig. 1,
     or Reactive / MWA / LR of §V.C, or utilization-driven Autoscale),
  5. starts/terminates instances (termination = smallest a_{i,j} first).

The step is pure and fixed-shape: the surrounding environment (simulator or
the elastic TPU runtime in ``repro.ft``) drives it under ``lax.scan``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from . import aimd as aimd_lib
from . import billing as billing_lib
from . import fairshare, kalman, predictors
from .types import (AimdState, ArmaState, BillingParams, ClusterState,
                    ControlParams, KalmanState, PolicyParams, PolicyState,
                    WorkloadState, required_cus)

PREDICTORS = ("kalman", "adhoc", "arma")
POLICIES = ("aimd", "reactive", "mwa", "lr", "autoscale")


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    predictor: str = "kalman"
    policy: str = "aimd"
    params: ControlParams = ControlParams()
    billing: BillingParams = BillingParams()
    # Pre-confirmation probe rate: the platform runs one task at a time per
    # unconfirmed workload to build the initial CUS estimate — a fraction of
    # one CU on average, not a dedicated instance.
    bootstrap_rate: float = 0.3
    # Autoscale baseline (§V.C): step instances on mean-CPU threshold.
    as_threshold: float = 0.20
    as_step: float = 1.0
    # AIMD base: 'committed' (booting+active; avoids double-request during
    # boot) or 'active' (paper-literal eq. 2).
    aimd_base: str = "committed"
    # Route the Kalman bank's fused eqs. 6-9 update through the Pallas
    # kernel (``repro.kernels.kalman_update``): compiled on TPU,
    # interpreter-emulated elsewhere, bit-comparable to ``kalman.step``.
    # Off by default — vmapped sweeps keep the plain jnp path.
    kalman_kernel: bool = False

    def __post_init__(self):
        # ValueError (not assert) so a misconfigured controller fails
        # identically under ``python -O`` — same path as SpotConfig.
        if self.predictor not in PREDICTORS:
            raise ValueError(f"unknown predictor {self.predictor!r}; "
                             f"choose one of {PREDICTORS}")
        if self.policy not in POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"choose one of {POLICIES}")
        if self.aimd_base not in ("committed", "active"):
            raise ValueError(f"unknown aimd_base {self.aimd_base!r}; "
                             "choose 'committed' or 'active'")


class ControllerState(NamedTuple):
    kf: KalmanState          # Kalman or ad-hoc filter bank (shape-shared)
    arma: ArmaState
    pol: PolicyState
    aimd: AimdState


class ControlProbe(NamedTuple):
    """Per-tick control-plane diagnostics (``repro.obs`` emission hook).

    Populated only when the caller passes an ``ObsSpec`` (each field
    further gated by its probe family — ``None`` when unwanted), so a
    probe-free controller step carries a leafless ``None`` here and
    compiles unchanged.
    """

    aimd_incr: jnp.ndarray | None = None    # () bool Fig. 1 branch taken
    water_scale: jnp.ndarray | None = None  # () f32 eqs. 13-14 rescale
    kalman: "kalman.KalmanProbe | None" = None  # innovation diagnostics


class ControlDecision(NamedTuple):
    s: jnp.ndarray           # (W,) service rates for [t, t+1)
    n_star: jnp.ndarray      # ()   N*_tot (eq. 12)
    n_target: jnp.ndarray    # ()   CU count requested for t+1
    b_hat: jnp.ndarray       # (W, K) current predictions
    reliable: jnp.ndarray    # (W, K) predictor reliability flags
    probe: ControlProbe | None = None  # obs diagnostics (None = off)


def init(w: int, k: int, cfg: ControllerConfig) -> ControllerState:
    return ControllerState(
        kf=kalman.init(w, k),
        arma=predictors.arma_init(w, k),
        pol=aimd_lib.policy_init(),
        aimd=aimd_lib.aimd_init(cfg.params.n_min),
    )


def reset_rows(state: ControllerState, rows: jnp.ndarray) -> ControllerState:
    """Clear predictor state for newly (re)submitted workload rows."""
    return state._replace(
        kf=kalman.reset_rows(state.kf, rows),
        arma=predictors.arma_reset_rows(state.arma, rows),
    )


def step(state: ControllerState,
         work: WorkloadState,
         cluster: ClusterState,
         b_meas: jnp.ndarray,        # (W, K) fresh CUS measurements
         meas_mask: jnp.ndarray,     # (W, K) bool
         exec_time: jnp.ndarray,     # (W, K) CU-seconds consumed in window
         items_done: jnp.ndarray,    # (W, K) completions in window
         cfg: ControllerConfig,
         cores: jnp.ndarray | float | None = None,  # CUs per instance/slot
         pp: PolicyParams | None = None,  # traced policy gains (tuning)
         tenants: tuple | None = None,    # (tenant_id (W,), n, base_w (N,))
         meas_dropped: jnp.ndarray | None = None,  # (W, K) lost telemetry
         obs=None,  # static ObsSpec (repro.obs): emit ControlDecision.probe
         ) -> tuple[ControllerState, WorkloadState, ControlDecision]:
    p = cfg.params
    # CUs per instance — a traced scalar when the spot fleet's granularity
    # is a sweep axis (sim.sweep vmaps over it), or a per-slot (I,) vector
    # for mixed-granularity fleets; the caller owns keeping it consistent
    # with the execution and scaling planes.  All control arithmetic below
    # is in CU space, so a preemption that knocks out one m4.10xlarge is
    # seen as a 40-CU capacity loss and AIMD re-grows the fleet additively,
    # exactly as it reacts to any shortfall — possibly with instances of a
    # *different* type, if that is what the market now sells cheapest.
    if cores is None:
        cores = 1.0

    # -- 1. predictor update ------------------------------------------------
    # ``meas_dropped`` marks filters whose fresh measurement was lost to a
    # telemetry dropout (chaos engine, hardened mode): the Kalman bank coasts
    # there with inflated covariance instead of silently standing still.
    k_probe = None
    if cfg.predictor == "kalman":
        if obs is not None and obs.want_kalman:
            # Innovation/NIS from the *pre-update* bank — the residual
            # eq. 8 is about to correct with (trace-time gated: probe-free
            # configs compile the exact historical update).
            k_probe = kalman.probe(state.kf, meas_mask, p)
        kf = kalman.step(state.kf, b_meas, meas_mask, p,
                         use_kernel=cfg.kalman_kernel,
                         dropped=meas_dropped)
        arma = state.arma
        b_hat, reliable = kf.b_hat, kf.reliable
    elif cfg.predictor == "adhoc":
        kf = predictors.adhoc_step(state.kf, b_meas, meas_mask, p)
        arma = state.arma
        b_hat, reliable = kf.b_hat, kf.reliable
    else:  # arma
        kf = state.kf
        arma = predictors.arma_step(state.arma, exec_time, items_done,
                                    work.m0, p)
        b_hat, reliable = arma.b_hat, arma.reliable

    # -- 2. demand + TTC confirmation (§II.B) --------------------------------
    r = required_cus(work.m, b_hat)                        # eq. 1
    w_reliable = jnp.all(reliable | (work.m0 == 0), axis=-1) & jnp.any(
        work.m0 > 0, axis=-1)
    newly_conf = work.active & w_reliable & ~work.confirmed
    d_conf = fairshare.confirm_ttc(r, work.d, newly_conf, p)
    d = jnp.where(newly_conf, d_conf, work.d)
    confirmed = work.confirmed | newly_conf
    work = work._replace(d=d, confirmed=confirmed)

    # -- 3. proportional-fair service rates (eqs. 11-14) ---------------------
    n_usable = billing_lib.usable(cluster, cores)
    sched = work.active & confirmed
    if tenants is None:
        alloc = fairshare.allocate(r, d, sched, n_usable, p, pp=pp)
    else:
        # Multi-tenant shared fleet: the allocation is hierarchical (fleet
        # → tenant weight → per-task eqs. 13-14).  A single tenant routes
        # back through ``allocate`` inside, bit-identically.
        tid, n_tenants, base_w = tenants
        alloc = fairshare.allocate_tenants(r, d, sched, n_usable, p,
                                           tid, n_tenants, base_w, pp=pp)
    # Pre-confirmation bootstrap: run a trickle so measurements arrive.
    boot = work.active & ~confirmed
    s = jnp.where(boot, cfg.bootstrap_rate, alloc.s)
    # Demand seen by the scaler includes the bootstrap trickle.
    n_star = alloc.n_star + jnp.sum(jnp.where(boot, cfg.bootstrap_rate, 0.0))

    # -- 4. scaling policy ---------------------------------------------------
    pol = aimd_lib.policy_push(state.pol, n_star)
    n_base = (billing_lib.committed(cluster, cores)
              if cfg.aimd_base == "committed" else n_usable)
    aimd_state = aimd_lib.aimd_step(state.aimd, n_base, n_star, p, pp=pp)
    if cfg.policy == "aimd":
        n_target = aimd_state.n_target
    elif cfg.policy == "reactive":
        n_target = aimd_lib.reactive_target(pol, p)
    elif cfg.policy == "mwa":
        n_target = aimd_lib.mwa_target(pol, p)
    elif cfg.policy == "lr":
        n_target = aimd_lib.lr_target(pol, p)
    else:  # autoscale: ±step instances on mean CPU utilization (§V.C)
        active_mask = (cluster.phase == billing_lib.ACTIVE)
        n_act = jnp.maximum(jnp.sum(active_mask.astype(jnp.float32)), 1.0)
        util = jnp.sum(cluster.busy_frac * active_mask) / n_act
        n_now = billing_lib.committed(cluster, cores)
        any_work = jnp.any(work.active)
        n_target = jnp.where(util > cfg.as_threshold,
                             n_now + cfg.as_step, n_now - cfg.as_step)
        n_target = jnp.where(any_work, n_target, n_now - cfg.as_step)
        n_target = jnp.clip(n_target, 1.0, p.n_max)

    # -- 5. observability probe (repro.obs) ----------------------------------
    # Assembled only under an ObsSpec; each field further gated by its
    # family so an enabled probe subset compiles exactly its own ops.
    probe = None
    if obs is not None:
        probe = ControlProbe(
            aimd_incr=(aimd_lib.increase_branch(n_base, n_star)
                       if obs.want_aimd else None),
            water_scale=(alloc.scale if obs.want_fairshare else None),
            kalman=k_probe)

    new_state = ControllerState(kf=kf, arma=arma, pol=pol, aimd=aimd_state)
    return new_state, work, ControlDecision(
        s=s, n_star=n_star, n_target=n_target, b_hat=b_hat,
        reliable=reliable, probe=probe)
