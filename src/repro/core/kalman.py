"""Kalman-filter CUS prediction (paper §II.A, eqs. 4-9).

Each (workload, data-type) pair carries an independent scalar Kalman filter
over the random-walk model

    b̃[t] = b̂[t] + v[t],      v ~ N(0, σ_v²)       (eq. 4, measurement)
    b̂[t] = b̂[t-1] + z[t],    z ~ N(0, σ_z²)       (eq. 5, process)

The whole fleet of filters updates as one fused, vectorized step — (W, K)
arrays in, (W, K) arrays out — so a platform tracking millions of
(workload, type) pairs runs the update as a single TPU program.  A Pallas
kernel for the fused update lives in ``repro.kernels.kalman_update``.

t_init detection (§V.B): the Kalman estimate is underdamped; the first
monitoring instant at which the prediction slope turns negative marks the
estimate as *reliable*, which triggers TTC confirmation for the workload.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from .types import ControlParams, KalmanState


class KalmanProbe(NamedTuple):
    """One tick's innovation diagnostics (observability hook).

    ``innov`` is the eq. 8 residual ``b̃[t-1] - b̂⁻`` for filters that
    absorb a regular measurement update this tick (0 elsewhere), ``nis``
    the normalized innovation squared ``innov² / S`` with the innovation
    covariance ``S = π⁻ + σ_v²`` — the classic filter-consistency
    statistic (a healthy bank hovers near E[NIS] = 1; sustained excess
    means the noise model underestimates the world).  ``upd`` marks the
    filters the diagnostics refer to.
    """

    innov: jnp.ndarray  # (W, K) f32 residual, 0 where no update
    nis: jnp.ndarray    # (W, K) f32 innovation² / S, 0 where no update
    upd: jnp.ndarray    # (W, K) bool regular-update mask


def probe(state: KalmanState, meas_mask: jnp.ndarray,
          params: ControlParams) -> KalmanProbe:
    """Innovation/NIS of this tick's update, from the *pre-update* state.

    Reads exactly the quantities :func:`step` is about to consume — the
    lagged measurement ``b_meas_prev``, the prior ``b_hat`` and the
    predicted covariance ``π⁻ = π + σ_z²`` — so the probe observes the
    very residual eq. 8 corrects with, at zero effect on the update
    itself (bootstrap ticks have a zero residual by construction and are
    excluded via the regular-update mask).
    """
    upd = meas_mask & state.has_meas
    pi_minus = state.pi + params.sigma_z2
    s_cov = pi_minus + params.sigma_v2
    innov = jnp.where(upd, state.b_meas_prev - state.b_hat, 0.0)
    nis = jnp.where(upd, innov * innov / jnp.maximum(s_cov, 1e-12), 0.0)
    return KalmanProbe(innov=innov, nis=nis, upd=upd)


def init(w: int, k: int, dtype=jnp.float32) -> KalmanState:
    """Paper init: b̂[0] = π[0] = 0."""
    z = jnp.zeros((w, k), dtype)
    f = jnp.zeros((w, k), dtype=bool)
    return KalmanState(b_hat=z, pi=z, b_meas_prev=z, has_meas=f,
                       b_hat_prev=z, reliable=f)


def step(state: KalmanState,
         b_meas: jnp.ndarray,
         meas_mask: jnp.ndarray,
         params: ControlParams,
         use_kernel: bool = False,
         dropped: jnp.ndarray | None = None) -> KalmanState:
    """One monitoring-instant update for every (w, k) filter.

    Args:
      state:      current filter bank.
      b_meas:     (W, K) new CUS measurements b̃_{w,k}[t] (junk where unmasked).
      meas_mask:  (W, K) bool — True where a fresh measurement exists this tick.
      params:     σ_z², σ_v².
      use_kernel: route the fused eqs. 6-9 masked update through the Pallas
                  kernel (``repro.kernels.kalman_update``) — bit-comparable
                  to the jnp path; compiled on TPU, interpreted elsewhere.
      dropped:    optional (W, K) bool — filters whose fresh measurement was
                  *lost* this tick (telemetry dropout, not mere absence).  The
                  missing-measurement update skips the correction but inflates
                  covariance by σ_z² so the prediction coasts on the process
                  model and the next real measurement earns a larger gain.
                  ``None`` (the default) compiles the exact historical update.

    Filters with no fresh measurement keep their state unchanged (their clock
    only advances on measurement arrival, matching the platform: a type that
    completed no tasks in [t-1, t) produced no b̃).
    """
    # First-ever measurement bootstraps the filter: b̂[0] := b̃ (the paper
    # "initializes each estimator with b̂_{w,k}[0], established via the
    # initial measurement").
    first = meas_mask & ~state.has_meas
    b_hat0 = jnp.where(first, b_meas, state.b_hat)
    prev_meas0 = jnp.where(first, b_meas, state.b_meas_prev)

    upd = meas_mask & state.has_meas          # regular (non-bootstrap) update
    if use_kernel:
        # One fused HBM pass: eqs. 6-9 plus the ``where(upd, ...)`` blend.
        from ..kernels.kalman_update.ops import kalman_update

        b_hat, pi = kalman_update(b_hat0, state.pi, prev_meas0, upd,
                                  float(params.sigma_z2),
                                  float(params.sigma_v2))
    else:
        # Time update (eqs. 6-7).
        pi_minus = state.pi + params.sigma_z2
        kappa = pi_minus / (pi_minus + params.sigma_v2)

        # Measurement update (eqs. 8-9) — eq. 8 uses the *lagged* measurement.
        b_hat_new = b_hat0 + kappa * (prev_meas0 - b_hat0)
        pi_new = (1.0 - kappa) * pi_minus

        b_hat = jnp.where(upd, b_hat_new, b_hat0)
        pi = jnp.where(upd, pi_new, state.pi)
    if dropped is not None:
        # Missing-measurement update: prediction coasts (b̂ unchanged) while
        # uncertainty grows by one process-noise step, exactly the eq. 6 time
        # update without the eq. 9 contraction.
        pi = jnp.where(dropped & state.has_meas, pi + params.sigma_z2, pi)
    b_meas_prev = jnp.where(meas_mask, b_meas, prev_meas0)
    has_meas = state.has_meas | meas_mask

    # t_init detection: first negative slope of the prediction trajectory.
    slope = b_hat - state.b_hat
    newly_reliable = upd & (slope < 0.0)
    reliable = state.reliable | newly_reliable

    return KalmanState(b_hat=b_hat, pi=pi, b_meas_prev=b_meas_prev,
                       has_meas=has_meas, b_hat_prev=state.b_hat,
                       reliable=reliable)


def reset_rows(state: KalmanState, rows: jnp.ndarray) -> KalmanState:
    """Zero the filters of (re)submitted workloads. ``rows``: (W,) bool."""
    r = rows[:, None]
    z = jnp.zeros_like(state.b_hat)
    f = jnp.zeros_like(state.has_meas)
    return KalmanState(
        b_hat=jnp.where(r, z, state.b_hat),
        pi=jnp.where(r, z, state.pi),
        b_meas_prev=jnp.where(r, z, state.b_meas_prev),
        has_meas=jnp.where(r, f, state.has_meas),
        b_hat_prev=jnp.where(r, z, state.b_hat_prev),
        reliable=jnp.where(r, f, state.reliable),
    )
