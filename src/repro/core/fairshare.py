"""Proportional-fair service-rate allocation under TTC constraints (paper §III).

Per workload the platform maximizes  f(s_w) = r_w ln(s_w) − d_w s_w  (eq. 10),
whose optimum is  s*_w = r_w / d_w  (eq. 11).  When aggregate demand
N* = Σ s*_w (eq. 12) drifts outside the AIMD guard band
[β N_tot, N_tot + α], every rate is rescaled multiplicatively (eqs. 13-14)
so that the allocation matches what AIMD can deliver next instant.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import ControlParams, PolicyParams

_EPS = 1e-9


class Allocation(NamedTuple):
    s: jnp.ndarray        # (W,) service rates actually granted
    s_star: jnp.ndarray   # (W,) unconstrained optimum r/d
    n_star: jnp.ndarray   # ()   N*_tot = Σ s*   (eq. 12)
    # The eqs. 13-14 multiplicative rescale actually applied — the
    # "water level" the observability layer gauges (< 1: demand throttled
    # to the band, > 1: rates lifted toward it, 1: in band).  For the
    # hierarchical allocator this is the most-throttled demanding
    # tenant's factor.  Emitted unconditionally (it is an intermediate
    # the allocator computes anyway); unread, it is dead code XLA
    # eliminates, so probe-free programs are unchanged.
    scale: jnp.ndarray = jnp.nan  # () f32


def optimal_rates(r: jnp.ndarray, d: jnp.ndarray,
                  active: jnp.ndarray) -> jnp.ndarray:
    """Eq. 11: s*_w = r_w / d_w for active workloads (0 otherwise)."""
    s = r / jnp.maximum(d, _EPS)
    return jnp.where(active, s, 0.0)


def allocate(r: jnp.ndarray,
             d: jnp.ndarray,
             active: jnp.ndarray,
             n_tot: jnp.ndarray,
             params: ControlParams,
             pp: PolicyParams | None = None) -> Allocation:
    """Service rates for the interval [t, t+1) (eqs. 11-14 + per-w cap).

    Args:
      r:       (W,) predicted CUS to completion (eq. 1).
      d:       (W,) remaining TTC seconds (already confirmed workloads).
      active:  (W,) bool mask of schedulable workloads.
      n_tot:   ()   currently usable CUs (eq. 2).
      pp:      traced AIMD gains for the eq. 13-14 guard band (tuning);
               None = the static config gains.  The same α/β the AIMD
               update uses must bound the band, so this mirrors
               ``aimd.aimd_step``'s override exactly.
    """
    alpha = params.alpha if pp is None else pp.alpha
    beta = params.beta if pp is None else pp.beta
    s_star = optimal_rates(r, d, active)
    # Eq. 12: N* = Σ s*_w.  The per-workload cap N_{w,max} only extends d_w
    # once, at TTC confirmation (§II.B) — a later prediction overshoot
    # therefore spikes N* well beyond the confirmed plan, and how a scaling
    # policy reacts to those impulses is what §V.C compares.  Each
    # workload's contribution is bounded by the surge ceiling (see
    # ControlParams.surge_mult) because demand beyond what the platform can
    # physically deliver to one workload is not actionable.
    n_star = jnp.sum(jnp.minimum(s_star, params.surge_mult * params.n_w_max))

    over = n_star > n_tot + alpha                        # demand exceeds band
    under = n_star < beta * n_tot                        # demand below band
    scale_down = (n_tot + alpha) / jnp.maximum(n_star, _EPS)          # eq. 13
    scale_up = (beta * n_tot) / jnp.maximum(n_star, _EPS)             # eq. 14
    scale = jnp.where(over, scale_down, jnp.where(under, scale_up, 1.0))

    # Granted rates are physically capped at N_{w,max} CUs per workload.
    s = jnp.minimum(s_star * scale, params.n_w_max)
    s = jnp.where(active, s, 0.0)
    # Gauge an idle instant (no demand to rescale) as 1.0 — the raw eq. 14
    # factor divides by ~0 there and would swamp the water-level statistic.
    gauge = jnp.where(n_star > _EPS, scale, 1.0)
    return Allocation(s=s, s_star=s_star, n_star=n_star, scale=gauge)


def allocate_tenants(r: jnp.ndarray,
                     d: jnp.ndarray,
                     active: jnp.ndarray,
                     n_tot: jnp.ndarray,
                     params: ControlParams,
                     tenant_id: jnp.ndarray,
                     n_tenants: int,
                     base_w: jnp.ndarray,
                     pp: PolicyParams | None = None) -> Allocation:
    """Hierarchical cross-tenant allocation: fleet → tenant → per-task.

    The single-owner ``allocate`` rescales every workload against one
    fleet-wide AIMD band; with tenants sharing the fleet the band is first
    split *between* tenants.  Each tenant's demand D_i (its workloads'
    surge-capped Σ s*, eq. 12 restricted to the tenant) competes for a CU
    budget proportional to its share weight; the eq. 13-14 multiplicative
    rescale then runs per tenant against its own budget and band slice, and
    the per-task N_{w,max} cap applies unchanged.  Weights are the
    contracted ``base_w`` tilted by ``pp.tenant_wg`` toward high-demand
    tenants (``exp(wg · demand_share)``; wg = 0 — the default — keeps pure
    contracted weights) and tenants with no demand cede their budget.

    ``n_tenants == 1`` routes through ``allocate`` itself — a trace-time
    branch, so a single-tenant shared fleet is *bit-identical* to the
    single-owner path by construction, not by numerical luck.

    Reported ``n_star`` stays the fleet-wide Σ D_i, so the AIMD scaler sees
    aggregate demand exactly as in the single-owner case.
    """
    if n_tenants == 1:
        return allocate(r, d, active, n_tot, params, pp=pp)
    alpha = params.alpha if pp is None else pp.alpha
    beta = params.beta if pp is None else pp.beta
    wg = jnp.asarray(0.0) if pp is None else pp.tenant_wg

    s_star = optimal_rates(r, d, active)
    contrib = jnp.minimum(s_star, params.surge_mult * params.n_w_max)
    demand = jax.ops.segment_sum(contrib, tenant_id,
                                 num_segments=n_tenants)          # (N,) D_i
    n_star = jnp.sum(demand)

    d_share = demand / jnp.maximum(n_star, _EPS)
    w = base_w * jnp.exp(wg * d_share)
    w = jnp.where(demand > 0.0, w, 0.0)
    frac = w / jnp.maximum(jnp.sum(w), _EPS)      # budget fractions, Σ ≤ 1
    budget = n_tot * frac
    alpha_i = alpha * frac                        # each tenant's band slice

    over = demand > budget + alpha_i
    under = demand < beta * budget
    scale_down = (budget + alpha_i) / jnp.maximum(demand, _EPS)   # eq. 13
    scale_up = (beta * budget) / jnp.maximum(demand, _EPS)        # eq. 14
    scale = jnp.where(over, scale_down, jnp.where(under, scale_up, 1.0))

    s = jnp.minimum(s_star * scale[tenant_id], params.n_w_max)
    s = jnp.where(active, s, 0.0)
    # Fleet-level water gauge: the most-throttled tenant with any demand
    # (1.0 when the fleet is idle — nothing was rescaled).
    any_demand = jnp.any(demand > 0.0)
    fleet_scale = jnp.where(
        any_demand,
        jnp.min(jnp.where(demand > 0.0, scale, jnp.inf)), 1.0)
    return Allocation(s=s, s_star=s_star, n_star=n_star, scale=fleet_scale)


def confirm_ttc(r: jnp.ndarray,
                d_requested: jnp.ndarray,
                newly_reliable: jnp.ndarray,
                params: ControlParams) -> jnp.ndarray:
    """TTC confirmation at t_init (§II.B).

    If the requested TTC would need s* > N_{w,max}, extend it to the minimum
    feasible value r / N_{w,max}; otherwise confirm as requested.  Returns the
    confirmed TTC for rows in ``newly_reliable`` (junk elsewhere).
    """
    d_min = r / params.n_w_max
    d_conf = jnp.maximum(d_requested, d_min)
    return jnp.where(newly_reliable, d_conf, d_requested)
