"""Baseline CUS predictors the paper compares against (§V.B).

* Ad-hoc: the Kalman measurement update (eq. 8) with a fixed gain κ = 0.1 —
  the best fixed setting per the paper.
* ARMA: the second-order autoregressive moving average of Roy et al. (eq. 15)
  over *normalized* cumulative cost  b_norm[t] = total_exec_time / fraction_done,
  divided by total items (so it predicts per-item CUS on the same scale as the
  Kalman filter).  Reliability: prediction deviation within the last-3 window
  stays within ±20% of the window mean (§V.B).

Both are vectorized over the (W, K) filter bank exactly like ``kalman.step``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .types import ArmaState, ControlParams, KalmanState


# ---------------------------------------------------------------------------
# Ad-hoc estimator (fixed-gain exponential smoother).
# ---------------------------------------------------------------------------

def adhoc_init(w: int, k: int, dtype=jnp.float32) -> KalmanState:
    return KalmanState(
        b_hat=jnp.zeros((w, k), dtype), pi=jnp.zeros((w, k), dtype),
        b_meas_prev=jnp.zeros((w, k), dtype),
        has_meas=jnp.zeros((w, k), dtype=bool),
        b_hat_prev=jnp.zeros((w, k), dtype),
        reliable=jnp.zeros((w, k), dtype=bool))


def adhoc_step(state: KalmanState, b_meas: jnp.ndarray, meas_mask: jnp.ndarray,
               params: ControlParams) -> KalmanState:
    """Eq. 8 with κ fixed; shares KalmanState (π is carried but unused)."""
    first = meas_mask & ~state.has_meas
    b_hat0 = jnp.where(first, b_meas, state.b_hat)
    prev_meas0 = jnp.where(first, b_meas, state.b_meas_prev)

    b_hat_new = b_hat0 + params.adhoc_kappa * (prev_meas0 - b_hat0)

    upd = meas_mask & state.has_meas
    b_hat = jnp.where(upd, b_hat_new, b_hat0)
    b_meas_prev = jnp.where(meas_mask, b_meas, prev_meas0)
    has_meas = state.has_meas | meas_mask

    slope = b_hat - state.b_hat
    reliable = state.reliable | (upd & (slope < 0.0))
    return KalmanState(b_hat=b_hat, pi=state.pi, b_meas_prev=b_meas_prev,
                       has_meas=has_meas, b_hat_prev=state.b_hat,
                       reliable=reliable)


# ---------------------------------------------------------------------------
# ARMA estimator (Roy et al.).
# ---------------------------------------------------------------------------

WINDOW_DEPTH = 10   # reliability window capacity (paper: 3 at 5-min
                    # monitoring, 10 at 1-min — ControlParams.arma_window)


def arma_init(w: int, k: int, dtype=jnp.float32) -> ArmaState:
    z3 = jnp.zeros((w, k, 3), dtype)
    zw = jnp.zeros((w, k, WINDOW_DEPTH), dtype)
    z = jnp.zeros((w, k), dtype)
    return ArmaState(b_norm=z3, n_meas=z, b_hat=z, window=zw,
                     reliable=jnp.zeros((w, k), dtype=bool),
                     total_time=z, total_done=z)


def arma_step(state: ArmaState,
              exec_time: jnp.ndarray,     # (W, K) seconds spent on type k in [t-1,t)
              items_done: jnp.ndarray,    # (W, K) items completed in [t-1,t)
              m0: jnp.ndarray,            # (W, K) total items at submission
              params: ControlParams) -> ArmaState:
    """One ARMA tick.  b_norm[t] = (Σ exec time) / (completed fraction) / m0
    == per-item CUS implied by cumulative progress (eq. 15 context)."""
    meas_mask = items_done > 0
    total_time = state.total_time + exec_time
    total_done = state.total_done + items_done

    frac = jnp.where(m0 > 0, total_done / jnp.maximum(m0, 1.0), 0.0)
    b_norm_now = jnp.where(
        frac > 0,
        total_time / jnp.maximum(frac, 1e-9) / jnp.maximum(m0, 1.0),
        0.0)

    # Shift the 3-deep lag buffer where a fresh measurement arrived.
    shifted = jnp.concatenate(
        [b_norm_now[..., None], state.b_norm[..., :2]], axis=-1)
    b_norm = jnp.where(meas_mask[..., None], shifted, state.b_norm)
    n_meas = state.n_meas + meas_mask.astype(state.n_meas.dtype)

    d, g = params.arma_delta, params.arma_gamma
    pred3 = d * b_norm[..., 0] + g * b_norm[..., 1] + (1 - d - g) * b_norm[..., 2]
    # Until 3 lags exist, fall back to the freshest normalized estimate.
    b_hat = jnp.where(n_meas >= 3, pred3,
                      jnp.where(n_meas >= 1, b_norm[..., 0], state.b_hat))

    window = jnp.where(meas_mask[..., None],
                       jnp.concatenate([b_hat[..., None],
                                        state.window[..., :-1]], axis=-1),
                       state.window)
    nw = min(max(int(params.arma_window), 1), WINDOW_DEPTH)
    win = window[..., :nw]                    # newest-first slice
    wmean = jnp.mean(win, axis=-1)
    dev = jnp.max(jnp.abs(win - wmean[..., None]), axis=-1)
    ok = (n_meas >= nw) & (dev <= params.arma_tol * jnp.maximum(wmean, 1e-9))
    reliable = state.reliable | (ok & meas_mask)

    return ArmaState(b_norm=b_norm, n_meas=n_meas, b_hat=b_hat, window=window,
                     reliable=reliable, total_time=total_time,
                     total_done=total_done)


def arma_reset_rows(state: ArmaState, rows: jnp.ndarray) -> ArmaState:
    r2 = rows[:, None]
    r3 = rows[:, None, None]
    return ArmaState(
        b_norm=jnp.where(r3, 0.0, state.b_norm),
        n_meas=jnp.where(r2, 0.0, state.n_meas),
        b_hat=jnp.where(r2, 0.0, state.b_hat),
        window=jnp.where(r3, 0.0, state.window),
        reliable=jnp.where(r2, False, state.reliable),
        total_time=jnp.where(r2, 0.0, state.total_time),
        total_done=jnp.where(r2, 0.0, state.total_done),
    )
