"""Instance lifecycle + quantized billing (paper §II.C, §IV, Appendix A).

The fleet is a fixed pool of ``I`` potential instances (``I`` ≥ N_max) whose
lifecycle is driven by two pure functions:

  * ``advance``   — one monitoring interval of wall-clock: boot progress and
                    billing-quantum renewal (a_{i,j} countdown, eq. 3).
  * ``scale_to``  — start/drain instances to hit a target count.

Billing model (Appendix A): a CU is billed ``price_per_quantum`` for each
*started* ``quantum`` (EC2 2015: $0.0081/hour for m3.medium spot), beginning
at the start request (boot time is paid, as on EC2).  There are no refunds.

Termination (§IV): "the prudent action is always to terminate spot instances
with the smallest remaining time before renewal" — i.e. AWS's
``ClosestToNextInstanceHour`` policy.  Scaling down therefore *drains*: the
instance is marked, keeps executing the work it has already been paid for,
and is reclaimed exactly at its quantum boundary instead of renewing.
Scaling up first cancels pending drains (free capacity) before paying for
new starts.  The control plane counts only non-draining instances; the
execution plane happily uses draining ones — they are paid for.
"""

from __future__ import annotations

import jax.numpy as jnp

from .types import BillingParams, ClusterState

OFF, BOOTING, ACTIVE = 0, 1, 2


def init(pool: int) -> ClusterState:
    return ClusterState(
        phase=jnp.zeros((pool,), jnp.int8),
        a=jnp.zeros((pool,), jnp.float32),
        boot_left=jnp.zeros((pool,), jnp.float32),
        draining=jnp.zeros((pool,), bool),
        cum_cost=jnp.asarray(0.0, jnp.float32),
        busy_frac=jnp.zeros((pool,), jnp.float32),
    )


def committed(cluster: ClusterState) -> jnp.ndarray:
    """Control-plane fleet size: paid-for instances not marked to drain."""
    on = (cluster.phase >= BOOTING) & ~cluster.draining
    return jnp.sum(on.astype(jnp.float32))


def usable(cluster: ClusterState) -> jnp.ndarray:
    """Control-plane usable CUs (paper N_tot, eq. 2): active, not draining."""
    on = (cluster.phase == ACTIVE) & ~cluster.draining
    return jnp.sum(on.astype(jnp.float32))


def capacity(cluster: ClusterState) -> jnp.ndarray:
    """Execution capacity: every booted instance, drained or not, is paid
    for and is given tasks until its quantum expires."""
    return jnp.sum((cluster.phase == ACTIVE).astype(jnp.float32))


def advance(cluster: ClusterState, dt: float,
            billing: BillingParams) -> ClusterState:
    """Advance wall-clock ``dt`` seconds: boots finish, quanta renew, and
    draining instances are reclaimed at their billing boundary."""
    on = cluster.phase >= BOOTING
    boot_left = jnp.where(on, jnp.maximum(cluster.boot_left - dt, 0.0),
                          cluster.boot_left)
    phase = jnp.where(on & (boot_left <= 0.0), jnp.int8(ACTIVE),
                      cluster.phase)

    a = jnp.where(on, cluster.a - dt, cluster.a)
    hit_boundary = on & (a <= 0.0)
    renew = hit_boundary & ~cluster.draining
    reclaim = hit_boundary & cluster.draining

    # A monitoring interval can span several billing quanta (per-second /
    # per-minute billing): charge as many as the clock crossed.
    k = jnp.where(renew, jnp.floor(-a / billing.quantum) + 1.0, 0.0)
    a = a + k * billing.quantum
    cum_cost = cluster.cum_cost + jnp.sum(k) * billing.price_per_quantum

    phase = jnp.where(reclaim, jnp.int8(OFF), phase)
    a = jnp.where(reclaim, 0.0, a)
    draining = cluster.draining & ~reclaim

    return ClusterState(phase=phase, a=a, boot_left=boot_left,
                        draining=draining, cum_cost=cum_cost,
                        busy_frac=cluster.busy_frac)


def scale_to(cluster: ClusterState, n_target: jnp.ndarray,
             billing: BillingParams) -> ClusterState:
    """Drive the control-plane fleet size toward ``n_target``.

    Growth: cancel drains first (the capacity is already paid for), then
    start OFF slots, paying a full quantum each.  Shrink: mark the instances
    with the *smallest remaining paid time* (§IV) as draining.
    """
    pool = cluster.phase.shape[0]
    n_target = jnp.round(n_target)
    n_live = committed(cluster)
    delta = n_target - n_live

    # ---- grow: undrain cheapest-to-keep first (largest remaining time) ----
    n_grow = jnp.maximum(delta, 0.0)
    drain_key = jnp.where(cluster.draining, -cluster.a, jnp.inf)
    undrain_rank = _rank(drain_key)
    do_undrain = cluster.draining & (undrain_rank <= n_grow)
    n_undrained = jnp.sum(do_undrain.astype(jnp.float32))
    draining = cluster.draining & ~do_undrain

    n_start = jnp.maximum(n_grow - n_undrained, 0.0)
    off = cluster.phase == OFF
    start_rank = _rank(jnp.where(off, jnp.arange(pool, dtype=jnp.float32),
                                 jnp.inf))
    do_start = off & (start_rank <= n_start)
    n_started = jnp.sum(do_start.astype(jnp.float32))

    phase = jnp.where(do_start, jnp.int8(BOOTING), cluster.phase)
    a = jnp.where(do_start, billing.quantum, cluster.a)
    boot_left = jnp.where(do_start, billing.boot_delay, cluster.boot_left)
    cum_cost = cluster.cum_cost + n_started * billing.price_per_quantum

    # ---- shrink: smallest-remaining-time instances first (§IV) -----------
    n_shrink = jnp.maximum(-delta, 0.0)
    live = (phase >= BOOTING) & ~draining
    # Active instances by remaining paid time ascending; booting ones last.
    shrink_key = jnp.where(live & (phase == ACTIVE), a,
                           jnp.where(live, a + 2.0 * billing.quantum,
                                     jnp.inf))
    shrink_rank = _rank(shrink_key)
    do_shed = live & (shrink_rank <= n_shrink)

    if billing.terminate == "immediate":
        # Paper semantics: release now, forfeit the rest of the quantum.
        phase = jnp.where(do_shed, jnp.int8(OFF), phase)
        a = jnp.where(do_shed, 0.0, a)
        boot_left = jnp.where(do_shed, 0.0, boot_left)
    else:
        # Beyond-paper: drain and reclaim at the billing boundary.
        draining = draining | do_shed

    return ClusterState(phase=phase, a=a, boot_left=boot_left,
                        draining=draining, cum_cost=cum_cost,
                        busy_frac=cluster.busy_frac)


def _rank(key: jnp.ndarray) -> jnp.ndarray:
    """1-based rank of each element under ascending sort of ``key``."""
    pool = key.shape[0]
    order = jnp.argsort(key)
    return jnp.zeros((pool,), jnp.float32).at[order].set(
        jnp.arange(1, pool + 1, dtype=jnp.float32))


def lower_bound_cost(total_cus: jnp.ndarray,
                     billing: BillingParams) -> jnp.ndarray:
    """Paper 'LB': the bill if every paid CU-second were used at 100%."""
    quanta = jnp.ceil(total_cus / billing.quantum)
    return quanta * billing.price_per_quantum
