"""Instance lifecycle + quantized billing (paper §II.C, §IV, Appendix A).

The fleet is a fixed pool of ``I`` potential instances (``I`` ≥ N_max) whose
lifecycle is driven by three pure functions:

  * ``advance``   — one monitoring interval of wall-clock: boot progress and
                    billing-quantum renewal (a_{i,j} countdown, eq. 3).
  * ``scale_to``  — start/drain instances to hit a target count (or, for
                    mixed-granularity spot fleets, a target *CU* capacity:
                    pass per-slot ``cores`` weights and the chosen start
                    type; see the function docstring).
  * ``preempt``   — spot-market reclamation: slots whose recorded bid is
                    below the current spot price are lost immediately.

Billing model (Appendix A): a CU is billed one quantum's price for each
*started* ``quantum`` (EC2 2015: $0.0081/hour for m3.medium spot), beginning
at the start request (boot time is paid, as on EC2).  There are no refunds.
The price may be the static ``BillingParams.price_per_quantum`` or, when the
spot market is live (``sim.spot``), the *current* spot price — pass it as
the ``price`` argument of ``advance``/``scale_to`` (scalar, or per-slot for
heterogeneous fleets).

Termination (§IV): "the prudent action is always to terminate spot instances
with the smallest remaining time before renewal" — i.e. AWS's
``ClosestToNextInstanceHour`` policy.  Scaling down therefore *drains*: the
instance is marked, keeps executing the work it has already been paid for,
and is reclaimed exactly at its quantum boundary instead of renewing.
Scaling up first cancels pending drains (free capacity) before paying for
new starts.  The control plane counts only non-draining instances; the
execution plane happily uses draining ones — they are paid for.

Preemption (Appendix A) is the involuntary counterpart: the market, not the
controller, takes the instance *now*, mid-quantum, and the already-billed
remainder is forfeited.  ``scale_to`` also refuses to start new slots while
``allow_start`` is False — on EC2 a request bidding below the clearing
price is simply not fulfilled.
"""

from __future__ import annotations

import jax.numpy as jnp

from .types import BillingParams, ClusterState

OFF, BOOTING, ACTIVE = 0, 1, 2


def init(pool: int) -> ClusterState:
    return ClusterState(
        phase=jnp.zeros((pool,), jnp.int8),
        a=jnp.zeros((pool,), jnp.float32),
        boot_left=jnp.zeros((pool,), jnp.float32),
        draining=jnp.zeros((pool,), bool),
        cum_cost=jnp.asarray(0.0, jnp.float32),
        busy_frac=jnp.zeros((pool,), jnp.float32),
        itype=jnp.zeros((pool,), jnp.int32),
        bid=jnp.full((pool,), jnp.inf, jnp.float32),
        n_preempt=jnp.asarray(0.0, jnp.float32),
    )


def committed(cluster: ClusterState, cores: float | jnp.ndarray = 1.0
              ) -> jnp.ndarray:
    """Control-plane fleet size in CUs: paid-for, not marked to drain."""
    on = (cluster.phase >= BOOTING) & ~cluster.draining
    return jnp.sum(on.astype(jnp.float32) * cores)


def usable(cluster: ClusterState, cores: float | jnp.ndarray = 1.0
           ) -> jnp.ndarray:
    """Control-plane usable CUs (paper N_tot, eq. 2): active, not draining."""
    on = (cluster.phase == ACTIVE) & ~cluster.draining
    return jnp.sum(on.astype(jnp.float32) * cores)


def capacity(cluster: ClusterState, cores: float | jnp.ndarray = 1.0
             ) -> jnp.ndarray:
    """Execution capacity in CUs: every booted instance, drained or not, is
    paid for and is given tasks until its quantum expires."""
    return jnp.sum((cluster.phase == ACTIVE).astype(jnp.float32) * cores)


def advance(cluster: ClusterState, dt: float, billing: BillingParams,
            price: jnp.ndarray | None = None) -> ClusterState:
    """Advance wall-clock ``dt`` seconds: boots finish, quanta renew, and
    draining instances are reclaimed at their billing boundary.

    ``price`` is the $/quantum charged for renewals crossed in this window —
    scalar or per-slot; defaults to the static ``billing.price_per_quantum``.
    """
    if price is None:
        price = billing.price_per_quantum
    price = jnp.broadcast_to(jnp.asarray(price, jnp.float32),
                             cluster.a.shape)

    on = cluster.phase >= BOOTING
    boot_left = jnp.where(on, jnp.maximum(cluster.boot_left - dt, 0.0),
                          cluster.boot_left)
    phase = jnp.where(on & (boot_left <= 0.0), jnp.int8(ACTIVE),
                      cluster.phase)

    a = jnp.where(on, cluster.a - dt, cluster.a)
    hit_boundary = on & (a <= 0.0)
    renew = hit_boundary & ~cluster.draining
    reclaim = hit_boundary & cluster.draining

    # A monitoring interval can span several billing quanta (per-second /
    # per-minute billing): charge as many as the clock crossed.
    k = jnp.where(renew, jnp.floor(-a / billing.quantum) + 1.0, 0.0)
    a = a + k * billing.quantum
    cum_cost = cluster.cum_cost + jnp.sum(k * price)

    phase = jnp.where(reclaim, jnp.int8(OFF), phase)
    a = jnp.where(reclaim, 0.0, a)
    draining = cluster.draining & ~reclaim
    bid = jnp.where(reclaim, jnp.inf, cluster.bid)

    return cluster._replace(phase=phase, a=a, boot_left=boot_left,
                            draining=draining, cum_cost=cum_cost, bid=bid)


def preempt(cluster: ClusterState, price: jnp.ndarray
            ) -> tuple[ClusterState, jnp.ndarray]:
    """Spot reclamation: the market takes every slot outbid by ``price``.

    Unlike the controller's polite drain, this is involuntary and immediate:
    the slot goes OFF mid-quantum and the rest of its paid time is forfeited
    (no refunds on EC2).  Returns the new state and the number of instances
    lost — the capacity-loss signal the controller's AIMD loop reacts to on
    its next step, and the event ``ft.elastic`` treats as a node failure.
    """
    price = jnp.broadcast_to(jnp.asarray(price, jnp.float32),
                             cluster.bid.shape)
    on = cluster.phase >= BOOTING
    hit = on & (price > cluster.bid)
    n_hit = jnp.sum(hit.astype(jnp.float32))
    return cluster._replace(
        phase=jnp.where(hit, jnp.int8(OFF), cluster.phase),
        a=jnp.where(hit, 0.0, cluster.a),
        boot_left=jnp.where(hit, 0.0, cluster.boot_left),
        draining=cluster.draining & ~hit,
        bid=jnp.where(hit, jnp.inf, cluster.bid),
        n_preempt=cluster.n_preempt + n_hit,
    ), n_hit


def scale_to(cluster: ClusterState, n_target: jnp.ndarray,
             billing: BillingParams,
             price: jnp.ndarray | None = None,
             bid: jnp.ndarray | None = None,
             itype: jnp.ndarray | None = None,
             allow_start: jnp.ndarray | bool = True,
             cores: jnp.ndarray | None = None) -> ClusterState:
    """Drive the control-plane fleet size toward ``n_target``.

    ``n_target`` is an instance count for homogeneous fleets (``cores``
    omitted).  For heterogeneous spot fleets, pass ``cores`` — per-slot CU
    weights, with OFF slots carrying the CUs of the type a new start would
    use (the caller's ``itype``) — and ``n_target`` becomes a *CU* target:
    growth starts just enough instances of the chosen type to cover the
    missing CUs, shrink sheds only whole instances that fit within the CU
    excess (the fleet stays at or above its target, as under the
    instance-count ``ceil`` semantics — a sub-instance excess never
    forfeits a paid coarse instance).

    Growth: cancel drains first (the capacity is already paid for), then
    start OFF slots, paying a full quantum each at ``price`` ($/quantum;
    defaults to the static list price).  New slots record ``bid`` and
    ``itype`` for the spot market's ``preempt``; ``allow_start=False``
    models an unfulfilled spot request (price above our bid) — growth by
    undraining still works, new money does not enter the market.
    Shrink: mark the instances with the *smallest remaining paid time*
    (§IV) as draining.
    """
    if price is None:
        price = billing.price_per_quantum
    pool = cluster.phase.shape[0]
    slot_cores = (jnp.ones((pool,), jnp.float32) if cores is None
                  else jnp.broadcast_to(jnp.asarray(cores, jnp.float32),
                                        (pool,)))
    n_target = jnp.round(n_target)
    n_live = committed(cluster, slot_cores)
    delta = n_target - n_live

    # ---- grow: undrain cheapest-to-keep first (largest remaining time) ----
    n_grow = jnp.maximum(delta, 0.0)
    drain_key = jnp.where(cluster.draining, -cluster.a, jnp.inf)
    do_undrain = cluster.draining & _take(drain_key, slot_cores, n_grow)
    n_undrained = jnp.sum(jnp.where(do_undrain, slot_cores, 0.0))
    draining = cluster.draining & ~do_undrain

    n_start = jnp.maximum(n_grow - n_undrained, 0.0)
    n_start = jnp.where(jnp.asarray(allow_start), n_start, 0.0)
    off = cluster.phase == OFF
    start_key = jnp.where(off, jnp.arange(pool, dtype=jnp.float32), jnp.inf)
    do_start = off & _take(start_key, slot_cores, n_start)

    phase = jnp.where(do_start, jnp.int8(BOOTING), cluster.phase)
    a = jnp.where(do_start, billing.quantum, cluster.a)
    boot_left = jnp.where(do_start, billing.boot_delay, cluster.boot_left)
    start_price = jnp.broadcast_to(jnp.asarray(price, jnp.float32),
                                   cluster.a.shape)
    cum_cost = cluster.cum_cost + jnp.sum(
        jnp.where(do_start, start_price, 0.0))
    new_bid = (jnp.full_like(cluster.bid, jnp.inf) if bid is None
               else jnp.broadcast_to(jnp.asarray(bid, jnp.float32),
                                     cluster.bid.shape))
    bid_arr = jnp.where(do_start, new_bid, cluster.bid)
    itype_arr = cluster.itype
    if itype is not None:
        itype_arr = jnp.where(
            do_start,
            jnp.broadcast_to(jnp.asarray(itype, jnp.int32),
                             cluster.itype.shape),
            cluster.itype)

    # ---- shrink: smallest-remaining-time instances first (§IV) -----------
    n_shrink = jnp.maximum(-delta, 0.0)
    live = (phase >= BOOTING) & ~draining
    # Active instances by remaining paid time ascending; booting ones last.
    shrink_key = jnp.where(live & (phase == ACTIVE), a,
                           jnp.where(live, a + 2.0 * billing.quantum,
                                     jnp.inf))
    do_shed = live & _take(shrink_key, slot_cores, n_shrink, cover=False)

    if billing.terminate == "immediate":
        # Paper semantics: release now, forfeit the rest of the quantum.
        phase = jnp.where(do_shed, jnp.int8(OFF), phase)
        a = jnp.where(do_shed, 0.0, a)
        boot_left = jnp.where(do_shed, 0.0, boot_left)
        bid_arr = jnp.where(do_shed, jnp.inf, bid_arr)
    else:
        # Beyond-paper: drain and reclaim at the billing boundary.
        draining = draining | do_shed

    return cluster._replace(phase=phase, a=a, boot_left=boot_left,
                            draining=draining, cum_cost=cum_cost,
                            bid=bid_arr, itype=itype_arr)


def _take(key: jnp.ndarray, weights: jnp.ndarray, budget: jnp.ndarray,
          cover: bool = True) -> jnp.ndarray:
    """Mark slots in ascending-``key`` order against a weight ``budget``.

    ``cover=True`` (growth): take while the weight marked *before* each
    slot stays below the budget — just enough slots to cover it,
    overshooting by at most one (the CU analogue of ``ceil``).
    ``cover=False`` (shrink): take only slots that fit *entirely* within
    the budget, so the fleet never dips below its target — a sub-instance
    CU excess must not shed (and forfeit) a whole coarse instance.
    With unit weights and an integer budget both modes are exactly
    ``rank ≤ budget``.  Callers mask the result: slots keyed ``inf`` sort
    last but can still be marked once the budget exceeds the eligible
    weight.
    """
    pool = key.shape[0]
    order = jnp.argsort(key)
    w_sorted = weights[order]
    incl = jnp.cumsum(w_sorted)
    taken = (incl - w_sorted) < budget if cover else incl <= budget
    return jnp.zeros((pool,), bool).at[order].set(taken)


def lower_bound_cost(total_cus: jnp.ndarray,
                     billing: BillingParams) -> jnp.ndarray:
    """Paper 'LB': the bill if every paid CU-second were used at 100%."""
    quanta = jnp.ceil(total_cus / billing.quantum)
    return quanta * billing.price_per_quantum
