# The paper's primary contribution: Kalman CUS prediction (§II.A),
# proportional-fair TTC scheduling (§III), AIMD instance scaling (§IV),
# plus the comparison baselines (§V) — all as pure-JAX state machines.
from . import aimd, billing, controller, fairshare, kalman, predictors, types
from .controller import ControllerConfig, ControllerState, step as control_step
from .types import (BillingParams, ControlParams, PolicyParams,
                    make_policy_params)

__all__ = [
    "aimd", "billing", "controller", "fairshare", "kalman", "predictors",
    "types", "ControllerConfig", "ControllerState", "control_step",
    "BillingParams", "ControlParams", "PolicyParams", "make_policy_params",
]
