"""Core state pytrees and static parameters for the Dithen CaaS control plane.

Every *state* is a NamedTuple of fixed-shape jnp arrays so the whole control
loop (and the cloud simulator around it) can run under ``jax.lax.scan``.
Static knobs live in frozen dataclasses that are closed over at trace time.

Notation follows Table I of the paper:
  t        monitoring instant
  W        max workloads tracked (fixed; ``active`` masks real ones)
  K        data types per workload
  m[w,k]   remaining items of type k in workload w
  b_hat    CUS prediction per item           (paper: b̂_{w,k}[t])
  b_meas   latest CUS measurement per item   (paper: b̃_{w,k}[t])
  r[w]     CUS to complete workload w        (eq. 1)
  d[w]     remaining time-to-completion
  s[w]     service rate (CUs granted to w for [t, t+1))
  N_tot    active compute units              (eq. 2)
  c_tot    billed-and-available CUS          (eq. 3)
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ControlParams:
    """Static parameters of the control plane (paper §IV–§V defaults)."""

    # AIMD (Fig. 1)
    alpha: float = 5.0          # additive increase (CUs per monitoring instant)
    beta: float = 0.9           # multiplicative decrease
    n_min: float = 10.0         # lower bound for N_tot
    n_max: float = 100.0        # upper bound for N_tot
    n_w_max: float = 10.0       # per-workload service-rate cap  (N_{w,max})
    # Kalman (§II.A)
    sigma_z2: float = 0.5       # process-noise variance  σ_z²
    sigma_v2: float = 0.5       # measurement-noise variance  σ_v²
    # Ad-hoc estimator (§V.B)
    adhoc_kappa: float = 0.1
    # ARMA (eq. 15), weights per Roy et al. second-order ARMA
    arma_delta: float = 0.8
    arma_gamma: float = 0.15
    # ARMA reliability: window deviation threshold (§V.B)
    arma_window: int = 3
    arma_tol: float = 0.20
    # Monitoring
    monitor_dt: float = 60.0    # seconds between monitoring instants
    # Surge ceiling on each workload's eq-12 demand contribution: near/past
    # its deadline a workload's r/d diverges, but the platform can never
    # deliver more than N_{w,max} CUs to it, so provisioning demand is
    # bounded at surge_mult × N_{w,max} per workload (implementation choice;
    # the paper's eq. 12 is silent on the divergence).
    surge_mult: float = 2.0


@dataclasses.dataclass(frozen=True)
class BillingParams:
    """IaaS billing model (paper Appendix A: m3.medium spot, hourly quanta)."""

    price_per_quantum: float = 0.0081   # $ per billing quantum per CU
    quantum: float = 3600.0             # seconds per billing quantum
    boot_delay: float = 300.0           # spot request → usable CU (§II.C:
                                        # "in the order of minutes" in 2015)
    cores_per_instance: int = 1         # p_i; paper uses single-CU instances
    # Termination semantics.  "immediate" releases the instance now and
    # forfeits the rest of its paid quantum (§IV's smallest-remaining-time
    # rule minimizes the forfeit).  "boundary" (default) is the limiting
    # case of the same rule: mark-and-drain, reclaiming exactly at the
    # quantum boundary so nothing paid is ever forfeited (AWS's
    # ClosestToNextInstanceHour).  Both are benchmarked.
    terminate: str = "boundary"


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Static multi-tenant layout of a shared-fleet simulation (hashable —
    part of the compile-cache key via ``SimConfig``).

    The workload axis of a multi-tenant schedule is the concatenation of
    ``n`` per-tenant blocks of ``max_w`` rows (``sim.tenants`` builds it),
    so row ``w`` belongs to tenant ``w // max_w``.  ``weights`` are the
    contracted fair-share weights the hierarchical allocator
    (``fairshare.allocate_tenants``) and the idle-cost attribution split
    by; empty means uniform.
    """

    n: int                              # tenants sharing the fleet
    max_w: int                          # workload rows per tenant
    weights: tuple[float, ...] = ()     # per-tenant share weights (uniform
                                        # when empty)
    budgets: tuple[float, ...] = ()     # per-tenant $ caps: arrivals are
                                        # refused once the tenant's
                                        # attributed bill reaches its cap
                                        # (empty = uncapped)

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"need at least one tenant, got n={self.n}")
        if self.max_w < 1:
            raise ValueError(f"need max_w >= 1, got {self.max_w}")
        if self.weights and len(self.weights) != self.n:
            raise ValueError(
                f"{len(self.weights)} weights for {self.n} tenants")
        if any(w <= 0.0 for w in self.weights):
            raise ValueError("tenant weights must be positive")
        object.__setattr__(self, "weights",
                           tuple(float(w) for w in self.weights))
        if self.budgets and len(self.budgets) != self.n:
            raise ValueError(
                f"{len(self.budgets)} budgets for {self.n} tenants")
        if any(b <= 0.0 for b in self.budgets):
            raise ValueError("tenant budgets must be positive")
        object.__setattr__(self, "budgets",
                           tuple(float(b) for b in self.budgets))

    @property
    def w_total(self) -> int:
        """Total workload rows of the concatenated schedule."""
        return self.n * self.max_w

    def weight_vec(self) -> jnp.ndarray:
        if self.weights:
            return jnp.asarray(self.weights, jnp.float32)
        return jnp.ones((self.n,), jnp.float32)

    def budget_vec(self) -> jnp.ndarray:
        if self.budgets:
            return jnp.asarray(self.budgets, jnp.float32)
        return jnp.full((self.n,), jnp.inf, jnp.float32)

    def tenant_ids(self) -> jnp.ndarray:
        """(n·max_w,) int32 tenant id of every workload row."""
        return jnp.repeat(jnp.arange(self.n, dtype=jnp.int32), self.max_w)


class KalmanState(NamedTuple):
    """Per-(workload, type) scalar Kalman filter (eqs. 4-9)."""

    b_hat: jnp.ndarray        # (W, K)  b̂_{w,k}[t]
    pi: jnp.ndarray           # (W, K)  error covariance π_{w,k}[t]
    b_meas_prev: jnp.ndarray  # (W, K)  b̃_{w,k}[t-1] (eq. 8 uses the lagged meas.)
    has_meas: jnp.ndarray     # (W, K)  bool: at least one measurement absorbed
    b_hat_prev: jnp.ndarray   # (W, K)  b̂_{w,k}[t-1], for slope / t_init detection
    reliable: jnp.ndarray     # (W, K)  bool: t_init reached (first negative slope)


class ArmaState(NamedTuple):
    """Second-order ARMA estimator of Roy et al. (eq. 15) + §V.B reliability."""

    b_norm: jnp.ndarray       # (W, K, 3)  b_norm at t, t-1, t-2
    n_meas: jnp.ndarray       # (W, K)     measurements absorbed so far
    b_hat: jnp.ndarray        # (W, K)     current prediction
    window: jnp.ndarray       # (W, K, 3)  last predictions, reliability window
    reliable: jnp.ndarray     # (W, K)     bool
    total_time: jnp.ndarray   # (W, K)     cumulative execution seconds
    total_done: jnp.ndarray   # (W, K)     cumulative completed items


class WorkloadState(NamedTuple):
    """Submitted workloads and their SLA bookkeeping."""

    active: jnp.ndarray       # (W,)   bool: submitted and not finished
    m: jnp.ndarray            # (W, K) remaining items per type
    m0: jnp.ndarray           # (W, K) items at submission (for completion %)
    b_true: jnp.ndarray       # (W, K) ground-truth mean CUS per item (sim only)
    d: jnp.ndarray            # (W,)   remaining TTC (s); counts down once confirmed
    d_requested: jnp.ndarray  # (W,)   SLA TTC requested at submission
    confirmed: jnp.ndarray    # (W,)   bool: TTC confirmed (t_init reached)
    t_submit: jnp.ndarray     # (W,)   submission instant (monitoring ticks)
    t_done: jnp.ndarray       # (W,)   completion instant (-1 while running)


class ClusterState(NamedTuple):
    """Fixed pool of potential instances; ``phase`` drives the lifecycle.

    phase: 0 = off, 1 = booting, 2 = active.
    ``a`` is the paper's a_{i,j}[t]: seconds left in the current paid quantum.

    Spot-market fields (Appendix A; see ``sim.spot``): each slot records the
    instance type it was started as and the $/quantum bid attached to its
    spot request — the bid is fixed at request time (EC2 semantics), even
    under a dynamic bid policy.  A slot whose bid falls below its *type's*
    current spot price is reclaimed by ``billing.preempt`` — the same event
    the elastic runtime in ``repro.ft`` treats as a node failure.  Slots of
    a mixed-granularity fleet carry different ``itype`` values and are
    billed/preempted each at their own type's price.  On-demand fleets keep
    the defaults (bid = +inf: never preempted).
    """

    phase: jnp.ndarray        # (I,) int8
    a: jnp.ndarray            # (I,) remaining paid seconds in current quantum
    boot_left: jnp.ndarray    # (I,) seconds of boot remaining (phase==1)
    draining: jnp.ndarray     # (I,) bool: reclaim at next quantum boundary
    cum_cost: jnp.ndarray     # ()   cumulative $ billed
    busy_frac: jnp.ndarray    # (I,) fraction of last interval spent computing
    itype: jnp.ndarray        # (I,) int32: instance-type id (sim.spot table)
    bid: jnp.ndarray          # (I,) $ / quantum bid of the slot's request
    n_preempt: jnp.ndarray    # ()   cumulative instances lost involuntarily:
                              #      market reclaims (billing.preempt) plus,
                              #      with the chaos engine on, preemption
                              #      storms and Poisson hard-kills
                              #      (sim.faults.kill_slots — which also
                              #      counts them separately in
                              #      FaultState.n_killed)


class PolicyParams(NamedTuple):
    """Tunable policy coefficients as a *traced* pytree.

    These five scalars used to be static config fields (``ControlParams.
    alpha``/``beta``, ``SpotConfig.bid_mult``/``ttc_gain``/``ema_alpha``)
    baked into the compiled simulation at trace time — so evaluating a new
    candidate setting meant a fresh XLA compile.  Promoted to a pytree that
    flows through ``controller.step`` → ``aimd_step`` and the simulator
    scan (``sim.runner``), they become runtime *inputs* of one compiled
    simulation: ``repro.opt`` vmaps a whole tuner population over them
    without recompiling.  Configs keep their values as the defaults
    (``sim.runner.default_params``), and the compilation caches key on
    configs with these leaves struck out (``sim.runner.strip_tuned``).

    ``bid_mult`` is *relative*: it multiplies the configured (or swept)
    bid multiple, so 1.0 — the default — leaves the bid axis untouched and
    a tuner candidate of ``b`` bids ``b ×`` the config/axis multiple.

    The three trailing multi-tenant leaves (``tenant_wg``, ``adm_frac``,
    ``price_mult``) are neutral at their defaults — zero demand tilt,
    admit-everything, list pricing — and are only consumed on the
    ``SimConfig.tenants`` code path (plus provider-revenue scoring), so
    single-owner simulations are bit-for-bit unchanged by their presence.
    """

    alpha: jnp.ndarray      # () AIMD additive increase (CUs per instant)
    beta: jnp.ndarray       # () AIMD multiplicative decrease
    bid_mult: jnp.ndarray   # () multiplier on the configured bid multiple
    ttc_gain: jnp.ndarray   # () TTC-aware bid-escalation gain
    ema_alpha: jnp.ndarray  # () per-hour weight of the EMA bid policy
    tenant_wg: jnp.ndarray  # () cross-tenant demand-tilt exponent (0 = pure
                            #    contracted weights)
    adm_frac: jnp.ndarray   # () admission: reject a tenant's arrivals while
                            #    its active rows ≥ adm_frac × max_w (1 =
                            #    admit everything)
    price_mult: jnp.ndarray # () provider price multiplier on per-tenant
                            #    list prices (revenue knob; 1 = list price)


def make_policy_params(alpha: float = 5.0, beta: float = 0.9,
                       bid_mult: float = 1.0, ttc_gain: float = 4.0,
                       ema_alpha: float = 0.3, tenant_wg: float = 0.0,
                       adm_frac: float = 1.0,
                       price_mult: float = 1.0) -> PolicyParams:
    """Build a ``PolicyParams`` pytree of f32 scalars (args may be traced)."""
    as_f32 = lambda v: jnp.asarray(v, jnp.float32)  # noqa: E731
    return PolicyParams(alpha=as_f32(alpha), beta=as_f32(beta),
                        bid_mult=as_f32(bid_mult), ttc_gain=as_f32(ttc_gain),
                        ema_alpha=as_f32(ema_alpha),
                        tenant_wg=as_f32(tenant_wg),
                        adm_frac=as_f32(adm_frac),
                        price_mult=as_f32(price_mult))


class AimdState(NamedTuple):
    n_target: jnp.ndarray     # () target N_tot for the next instant


class PolicyState(NamedTuple):
    """Shared scratch for the scaling baselines (MWA/LR need a history)."""

    n_star_hist: jnp.ndarray  # (H,) ring buffer of N*_tot
    hist_len: jnp.ndarray     # ()   valid entries


def n_tot(cluster: ClusterState, cores_per_instance: int = 1) -> jnp.ndarray:
    """Paper eq. (2): active CUs (booting instances are not usable yet)."""
    return jnp.sum((cluster.phase == 2).astype(jnp.float32)) * cores_per_instance


def c_tot(cluster: ClusterState, cores_per_instance: int = 1) -> jnp.ndarray:
    """Paper eq. (3): already-billed CUS available across the fleet."""
    usable = (cluster.phase == 2).astype(jnp.float32)
    return jnp.sum(usable * cluster.a) * cores_per_instance


def required_cus(m: jnp.ndarray, b_hat: jnp.ndarray) -> jnp.ndarray:
    """Paper eq. (1): r_w[t] = Σ_k m_{w,k}[t] · b̂_{w,k}[t]."""
    return jnp.sum(m * b_hat, axis=-1)
