"""Pure-jnp oracle for the SSD chunk kernel: the *intra-chunk* dense form.

One program instance of the kernel computes, for a single (batch, head) and
one chunk of length Q:
    Y[i] = Σ_{j<=i} (C_i·B_j) exp(Σ_{j<m<=i} a_m) dt_j X[j]   (+ state I/O)
This oracle mirrors exactly that contraction.
"""

from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, dt, a, b, c, s_in):
    """x: (Q,P); dt,a: (Q,); b,c: (Q,N); s_in: (N,P).
    Returns y (Q,P), s_out (N,P)."""
    q = x.shape[0]
    cs = jnp.cumsum(a)
    diff = cs[:, None] - cs[None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    ltri = jnp.where(mask, jnp.exp(diff), 0.0)
    w = (c @ b.T) * ltri * dt[None, :]
    y_intra = w @ x
    y_inter = (c @ s_in) * jnp.exp(cs)[:, None]
    decay_to_end = jnp.exp(cs[-1] - cs)
    s_out = s_in * jnp.exp(cs[-1]) + (b * (dt * decay_to_end)[:, None]).T @ x
    return y_intra + y_inter, s_out
