"""Jit'd wrapper mapping model-layout SSD tensors onto the Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan as _kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a_log, b, c, chunk: int = 128, interpret: bool = True):
    """Model layout: x (B,S,H,P), dt (B,S,H), a_log (H,), b/c (B,S,N).
    Broadcasts shared B/C across heads and flattens (B,H) into the grid."""
    bsz, s, h, p_ = x.shape
    n = b.shape[-1]
    a = (-jnp.exp(a_log))[None, None, :] * dt          # (B,S,H)

    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p_)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    af = a.transpose(0, 2, 1).reshape(bsz * h, s)
    bf = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    cf = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)

    y = _kernel(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    return y.reshape(bsz, h, s, p_).transpose(0, 2, 1, 3)
