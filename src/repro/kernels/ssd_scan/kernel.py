"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (batch·heads, n_chunks) with the chunk dimension innermost and
*sequential* — the (N, P) inter-chunk state lives in a VMEM scratch that
carries across grid steps (the TPU grid is executed in order per core,
which is exactly what the SSD recurrence needs; on GPU this would be a
cross-block dependency requiring a separate kernel launch per chunk).

Per program: the intra-chunk dense contraction (two (Q,N)×(N,P)-shaped
matmuls + one (Q,Q) masked matmul — all MXU work), then the state update.
Block shapes: Q×P and Q×N tiles, Q a multiple of 8, P/N multiples of 128
where the config allows (P=64 for mamba2 — padded by the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_scr,
                *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, 1)... stored (Q,1)
    a = a_ref[0].astype(jnp.float32)        # (Q, 1)
    b = b_ref[0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0].astype(jnp.float32)        # (Q, N)

    dtv = dt[:, 0]
    av = a[:, 0]
    cs = jnp.cumsum(av)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    ltri = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = cb * ltri * dtv[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from carried state
    s_in = s_scr[...]                        # (N, P)
    y += jax.lax.dot_general(c, s_in, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cs)[:, None]

    # state update: S = S·exp(Σa) + (B ⊙ dt·decay)^T X
    decay = (dtv * jnp.exp(cs[-1] - cs))[:, None]
    s_scr[...] = s_in * jnp.exp(cs[-1]) + jax.lax.dot_general(
        b * decay, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, a, b, c, chunk: int, interpret: bool = True):
    """x: (BH, S, P); dt/a: (BH, S); b/c: (BH, S, N).  a = A·dt ≤ 0 per step.
    Returns y: (BH, S, P)."""
    bh, s, p_ = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_ssd_kernel, q=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p_), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p_), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p_), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p_), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], a[..., None], b, c)
