"""Fused Kalman fleet update (paper eqs. 6-9) as a Pallas TPU kernel.

This is the control plane's hot loop at fleet scale: a platform tracking
millions of (workload, data-type) estimators updates them all every
monitoring instant.  The update is purely elementwise (memory-bound,
arithmetic intensity ≈ 7 flops / 16 bytes), so the kernel's job is a single
fused HBM→VMEM→HBM pass over (8,128)-aligned VPU tiles — one read and one
write per operand instead of the ~6 intermediate arrays a naive jnp chain
materializes.
"""

from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R, BLOCK_C = 256, 128

_WARNED_INTERPRET = False  # the fallback notice fires once per process


def resolve_interpret(interpret: bool | None) -> bool:
    """Platform-aware default: compile the kernel for real on TPU, run the
    Pallas interpreter (plain XLA ops — jittable, scannable) elsewhere.

    The implicit fallback is announced once per process (a UserWarning
    naming the resolved platform): interpreter emulation is bit-compatible
    but carries none of the kernel's fusion benefit, so a benchmark that
    silently landed on it would report meaningless kernel numbers.
    """
    global _WARNED_INTERPRET
    if interpret is None:
        platform = jax.default_backend()
        fallback = platform != "tpu"
        if fallback and not _WARNED_INTERPRET:
            _WARNED_INTERPRET = True
            warnings.warn(
                f"kalman_update: no TPU — resolved platform is "
                f"{platform!r}, running the Pallas kernel in interpret "
                "mode (plain XLA ops; numerically identical, not a "
                "kernel-performance measurement). Pass interpret=False "
                "to require the compiled kernel.", UserWarning,
                stacklevel=3)
        return fallback
    return bool(interpret)


def _kalman_kernel(b_ref, pi_ref, meas_ref, mask_ref, b_out, pi_out,
                   *, sigma_z2: float, sigma_v2: float):
    b = b_ref[...]
    pi = pi_ref[...]
    meas = meas_ref[...]
    mask = mask_ref[...] != 0

    pi_minus = pi + sigma_z2                       # eq. 6
    kappa = pi_minus / (pi_minus + sigma_v2)       # eq. 7
    b_new = b + kappa * (meas - b)                 # eq. 8
    pi_new = (1.0 - kappa) * pi_minus              # eq. 9

    b_out[...] = jnp.where(mask, b_new, b)
    pi_out[...] = jnp.where(mask, pi_new, pi)


def kalman_fused(b_hat, pi, b_meas_prev, mask,
                 sigma_z2: float, sigma_v2: float,
                 interpret: bool | None = None):
    """All inputs (W, K) f32; mask int8/bool.  Returns (b_hat', pi').

    ``interpret=None`` resolves platform-aware: compiled on TPU, emulated
    elsewhere (the interpreter lowers to plain XLA ops, so it jits and
    scans fine on CPU).
    """
    interpret = resolve_interpret(interpret)
    w, k = b_hat.shape
    br, bc = min(BLOCK_R, w), min(BLOCK_C, k)
    if w % br != 0 or k % bc != 0:
        # ValueError, not assert: under ``python -O`` a stripped assert
        # would let a partial grid silently skip the trailing rows.
        raise ValueError(
            f"kalman_fused needs (W, K)=({w}, {k}) divisible by the "
            f"({br}, {bc}) block — pad the filter bank to a multiple")
    kernel = functools.partial(_kalman_kernel, sigma_z2=sigma_z2,
                               sigma_v2=sigma_v2)
    grid = (w // br, k // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((w, k), b_hat.dtype)] * 2,
        interpret=interpret,
    )(b_hat, pi, b_meas_prev, mask.astype(jnp.int8))
