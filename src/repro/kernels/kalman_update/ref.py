"""Oracle for the fused Kalman fleet update: eqs. 6-9 over a (W, K) bank."""

from __future__ import annotations

import jax.numpy as jnp


def kalman_fused_ref(b_hat, pi, b_meas_prev, mask, sigma_z2, sigma_v2):
    pi_minus = pi + sigma_z2
    kappa = pi_minus / (pi_minus + sigma_v2)
    b_new = b_hat + kappa * (b_meas_prev - b_hat)
    pi_new = (1.0 - kappa) * pi_minus
    b_out = jnp.where(mask, b_new, b_hat)
    pi_out = jnp.where(mask, pi_new, pi)
    return b_out, pi_out
