"""Jit'd wrapper for the fused Kalman fleet update.

``interpret`` defaults to *platform-aware* (None): the Pallas kernel is
compiled for real on TPU and emulated with the interpreter everywhere else
(CPU CI, tests) — callers no longer have to remember that the previous
hard-coded ``interpret=True`` silently ran the emulator even under jit on
TPU hosts.

``kalman_update`` is also explicitly **batchable**: a ``custom_vmap`` rule
merges any leading batch axis into the kernel's row grid (one ``(B·W, K)``
launch, rows padded to the block multiple with masked no-op rows) instead
of letting each ``vmap`` level prepend another grid dimension to the
``pallas_call``.  That is what lets ``ControllerConfig.kalman_kernel=True``
run inside the vmapped sweep engine — and inside ``jit(vmap(vmap(...)))``
tuning stacks — with the same single-pass memory behavior the unbatched
kernel was written for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import BLOCK_R, kalman_fused as _kernel
from .kernel import resolve_interpret

__all__ = ["kalman_update", "resolve_interpret"]


@functools.lru_cache(maxsize=None)
def _batchable(sigma_z2: float, sigma_v2: float, interpret: bool):
    """The fused update for fixed statics, with an explicit batch rule."""

    @jax.custom_batching.custom_vmap
    def update(b_hat, pi, b_meas_prev, mask):
        return _kernel(b_hat, pi, b_meas_prev, mask, sigma_z2, sigma_v2,
                       interpret=interpret)

    @update.def_vmap
    def _batched(axis_size, in_batched, b_hat, pi, b_meas_prev, mask):
        def bcast(x, b):
            return x if b else jnp.broadcast_to(
                x, (axis_size,) + tuple(x.shape))

        b_hat, pi, b_meas_prev, mask = (
            bcast(x, b) for x, b in zip((b_hat, pi, b_meas_prev, mask),
                                        in_batched))
        bsz, w, k = b_hat.shape
        rows = bsz * w
        # The update is elementwise, so the batch axis folds into the row
        # axis: one (B·W, K) launch.  Pad the fold to the kernel's row
        # block with mask-0 rows (a masked row is a no-op pass-through),
        # then slice the padding back off.
        pad = -rows % min(BLOCK_R, rows)
        mask = mask.astype(jnp.int8)

        def fold(x):
            x = x.reshape((rows, k))
            return jnp.pad(x, ((0, pad), (0, 0))) if pad else x

        b2, p2 = _kernel(fold(b_hat), fold(pi), fold(b_meas_prev),
                         fold(mask), sigma_z2, sigma_v2,
                         interpret=interpret)
        if pad:
            b2, p2 = b2[:rows], p2[:rows]
        out = (b2.reshape((bsz, w, k)), p2.reshape((bsz, w, k)))
        return out, (True, True)

    return update


@functools.partial(jax.jit,
                   static_argnames=("sigma_z2", "sigma_v2", "interpret"))
def kalman_update(b_hat, pi, b_meas_prev, mask,
                  sigma_z2: float = 0.5, sigma_v2: float = 0.5,
                  interpret: bool | None = None):
    fn = _batchable(float(sigma_z2), float(sigma_v2),
                    resolve_interpret(interpret))
    return fn(b_hat, pi, b_meas_prev, mask)
