"""Jit'd wrapper for the fused Kalman fleet update.

``interpret`` defaults to *platform-aware* (None): the Pallas kernel is
compiled for real on TPU and emulated with the interpreter everywhere else
(CPU CI, tests) — callers no longer have to remember that the previous
hard-coded ``interpret=True`` silently ran the emulator even under jit on
TPU hosts.
"""

from __future__ import annotations

import functools

import jax

from .kernel import kalman_fused as _kernel
from .kernel import resolve_interpret

__all__ = ["kalman_update", "resolve_interpret"]


@functools.partial(jax.jit,
                   static_argnames=("sigma_z2", "sigma_v2", "interpret"))
def kalman_update(b_hat, pi, b_meas_prev, mask,
                  sigma_z2: float = 0.5, sigma_v2: float = 0.5,
                  interpret: bool | None = None):
    return _kernel(b_hat, pi, b_meas_prev, mask, sigma_z2, sigma_v2,
                   interpret=resolve_interpret(interpret))
