"""Jit'd wrapper for the fused Kalman fleet update."""

from __future__ import annotations

import functools

import jax

from .kernel import kalman_fused as _kernel


@functools.partial(jax.jit,
                   static_argnames=("sigma_z2", "sigma_v2", "interpret"))
def kalman_update(b_hat, pi, b_meas_prev, mask,
                  sigma_z2: float = 0.5, sigma_v2: float = 0.5,
                  interpret: bool = True):
    return _kernel(b_hat, pi, b_meas_prev, mask, sigma_z2, sigma_v2,
                   interpret=interpret)
