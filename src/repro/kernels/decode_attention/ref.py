"""Oracle for the flash-decode kernel: one query against a (possibly
int8-quantized) KV cache with a valid-length mask."""

from __future__ import annotations

import jax
import jax.numpy as jnp

KV_SCALE = 32.0


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               length: jnp.ndarray) -> jnp.ndarray:
    """q: (hd,); k/v: (S, hd) bf16/f32 or int8; length: () valid entries."""
    if k.dtype == jnp.int8:
        k = k.astype(jnp.float32) / KV_SCALE
        v = v.astype(jnp.float32) / KV_SCALE
    s = (k.astype(jnp.float32) @ q.astype(jnp.float32)) * q.shape[-1] ** -0.5
    mask = jnp.arange(k.shape[0]) < length
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s)
    return (p @ v.astype(jnp.float32)).astype(jnp.float32)
