"""Flash-decode Pallas kernel: one query token against a long KV cache,
with *fused int8 dequantization* (§Perf iteration 2's follow-up: the
quantized cache is dequantized in VMEM registers inside the QK/PV matmuls,
so HBM traffic is the int8 bytes — the full −50% wire win, which the
pure-JAX path cannot express because XLA materializes the dequantized
copy).

Grid: (batch·kv-heads, cache blocks); the cache block index is innermost
and sequential, carrying the streaming-softmax state (m, l, acc) in VMEM
scratch.  The group dimension (q heads per kv head) rides along as rows of
a (G, hd) tile so the matmuls stay MXU-shaped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 256
NEG_INF = -1e30
KV_SCALE = 32.0


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr,
                   *, block_s: int, n_blocks: int, quantized: bool):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (G, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bs, hd)
    v = v_ref[0].astype(jnp.float32)
    if quantized:
        k = k * (1.0 / KV_SCALE)
        v = v * (1.0 / KV_SCALE)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)                      # (G, bs)

    valid_len = len_ref[0, 0]
    idx = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < valid_len, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(idx < valid_len, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray,
                 block_s: int = DEFAULT_BLOCK_S,
                 interpret: bool = True) -> jnp.ndarray:
    """q: (BK, G, hd); k/v: (BK, S, hd) [bf16/f32 or int8];
    lengths: (BK,) int32 valid cache entries.  Returns (BK, G, hd) f32."""
    bk, g, hd = q.shape
    s = k.shape[1]
    assert s % block_s == 0, (s, block_s)
    n_blocks = s // block_s
    quantized = k.dtype == jnp.int8

    kernel = functools.partial(_decode_kernel, block_s=block_s,
                               n_blocks=n_blocks, quantized=quantized)
    return pl.pallas_call(
        kernel,
        grid=(bk, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.reshape(bk, 1).astype(jnp.int32), q, k, v)
