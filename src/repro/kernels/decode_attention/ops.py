"""Jit'd wrapper: GQA decode attention over a (possibly int8) KV cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_decode as _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def gqa_flash_decode(q, cache_k, cache_v, lengths, interpret: bool = True):
    """q: (B, H, hd); cache_k/v: (B, S, KV, hd); lengths: (B,).
    Returns (B, H, hd) f32."""
    b, h, hd = q.shape
    s, kv = cache_k.shape[1], cache_k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd).transpose(0, 1, 2, 3).reshape(b * kv, g, hd)
    kf = cache_k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = cache_v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    lf = jnp.repeat(lengths, kv)
    o = _kernel(qg, kf, vf, lf, interpret=interpret)
    return o.reshape(b, kv, g, hd).reshape(b, h, hd)
