"""Flash attention as a Pallas TPU kernel.

TPU adaptation (vs. the CUDA original): the grid is (batch·heads, q-blocks);
each program streams K/V blocks HBM→VMEM via the innermost grid dimension
and keeps a (BLOCK_Q, hd) accumulator plus running max/denominator in VMEM
scratch.  Block shapes are MXU-aligned (multiples of (8,128) for f32 tiles;
BLOCK_Q×hd and BLOCK_K×hd matmuls land on the 128×128 systolic array).

Causal masking is tile-level: tiles entirely above the diagonal are masked
via the position arithmetic (Pallas grids execute all tiles; the mask makes
them no-ops numerically).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, causal: bool, block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)          # q-block index
    ki = pl.program_id(2)          # k-block index (innermost: streams K/V)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (bq, hd)
    k = k_ref[0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, hd); k/v: (BH, Sk, hd) — heads pre-flattened, MHA tile.

    GQA is handled by the caller (repeat/reshape); this kernel is the
    per-(batch·head) attention primitive.
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    n_q, n_k = sq // block_q, sk // block_k

    kernel = functools.partial(_flash_kernel, causal=causal,
                               block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
