"""Pure-jnp oracle for the flash-attention kernel (single head-group tile).

Semantics: causal (optional) softmax attention over one (batch·head) slice —
q (S_q, hd), k/v (S_k, hd) — matching the Pallas kernel's per-program tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    sq, hd = q.shape
    sk = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * hd ** -0.5
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
