"""Jit'd public wrapper: GQA-aware flash attention on the Pallas kernel.

On TPU this pads/reshapes (B, S, H, hd) GQA tensors into the kernel's
(batch·head, S, hd) tiles; on CPU it runs the kernel in interpret mode
(tests) — production dry-runs lower the pure-JAX flash path instead, so the
roofline sees real dots (see DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _kernel
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def gqa_flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        interpret: bool = True) -> jnp.ndarray:
    """q: (B,S,H,hd); k/v: (B,S,KV,hd) with H % KV == 0."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # Expand KV heads to H (GQA) then flatten (B,H) into the kernel grid.
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o = _kernel(qf, kf, vf, causal=causal, interpret=interpret)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


def gqa_reference(q, k, v, causal=True):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    out = jax.vmap(jax.vmap(
        lambda qq, kk, vv: attention_ref(qq, kk, vv, causal),
        in_axes=1, out_axes=1), in_axes=0)(q, k, v)
    return out
