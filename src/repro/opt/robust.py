"""Robust min–max tuning: alternate policy tuning and scenario attack.

A policy tuned on a scenario family's *nominal* parameters can be great on
average and terrible in the family's corners.  ``robust_tune`` plays the
classic iterative min–max game over a growing pool of worlds:

  1. **min** — tune the policy against the worst case over the current
     world pool (starting pool: the nominal world) — the inner objective
     is ``max`` over pool worlds of the mean seeds-batch score;
  2. **max** — run the adversarial search against the tuned policy and
     append the worst world it finds to the pool;
  3. repeat.

Each half-step is itself one jitted CEM run (the pool is a traced stack of
world vectors), but the pool grows between rounds, so each *round*
compiles its tuning objective afresh — rounds are few and small by
design.  The result is a policy whose worst case over the discovered
worlds is as good as the tuner can make it, plus the audit trail of
worst-case scores per round (the benchmark's gap-closure metric).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams
from ..sim import runner, sweep
from ..sim import scenarios as scen_lib
from .adversarial import AttackResult, attack_policy
from .cem import cem_minimize
from .objective import DEFAULT_PENALTY, run_env, score_summary
from .space import (BoxSpace, default_vector, nominal_scenario_vector,
                    policy_space, scenario_space, vector_to_params)


class _PoolObjective:
    """Worst case over a fixed pool of worlds, as a function of the policy
    vector: ``max_w mean_seeds score(policy, world_w)``.  The pool is a
    traced ``(R, d_scenario)`` stack, the policy vector the argument."""

    def __init__(self, cfg: runner.SimConfig, spec, sspace: BoxSpace,
                 pspace: BoxSpace, worlds: jnp.ndarray, seeds,
                 penalty: float, scenario_id: int):
        self.cfg = cfg
        self.spec = spec
        self.sspace = sspace
        self.pspace = pspace
        self.worlds = jnp.asarray(worlds, jnp.float32)
        self.seeds = jnp.asarray(list(seeds), jnp.int32)
        self.penalty = float(penalty)
        self.scenario_id = int(scenario_id)
        self._base = sweep._point_sched(cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        pp = vector_to_params(self.pspace.clip(vec),
                              names=self.pspace.names)

        def world(wvec):
            gen = self.sspace.to_dict(wvec)

            def one(seed):
                key = scen_lib.schedule_key(seed, self.scenario_id)
                sched = self.spec.sample(key, params=gen)
                return self._base(sched, seed, self._bid, self._itype,
                                  self._pol, self._mix, pp)

            return jnp.mean(score_summary(jax.vmap(one)(self.seeds),
                                          self.penalty))

        return jnp.max(jax.vmap(world)(self.worlds))


class RobustResult(NamedTuple):
    """Outcome of the alternating min–max game."""

    params: PolicyParams        # the robust policy
    vec: jnp.ndarray            # (d,) same, as a policy-space vector
    worst_score: jnp.ndarray    # () final attack's score vs the robust policy
    pool: jnp.ndarray           # (R, d_s) worlds the game accumulated
    rounds: tuple               # per-round dicts (tuned/worst scores, world)
    final_attack: AttackResult


def robust_tune(cfg: runner.SimConfig, spec, seeds, key: jax.Array,
                rounds: int = 2, pop_size: int = 24, generations: int = 6,
                penalty: float = DEFAULT_PENALTY,
                bounds: dict | None = None,
                scenario_id: int = 0,
                initial_worlds=None) -> RobustResult:
    """Alternate ``tune-vs-pool`` and ``attack-tuned`` for ``rounds``
    rounds over one stochastic scenario family.  Deterministic per key.
    ``scenario_id`` seeds the world-sampling keys (see ``attack_policy``).
    ``initial_worlds`` (iterable of scenario-space vectors) seeds the pool
    beyond the nominal world — e.g. a worst world already found against
    the default policy.  Every round injects both the hand-set default and
    the current incumbent into the tuner's populations, so the tuned
    *pool-max* can never exceed either's pool-max.  (On any single pool
    world the robust policy can still score worse than the default when a
    different pool world dominates its max — the guarantee is on the
    worst case over the pool, not per world.)"""
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    pspace = policy_space(bounds)
    sspace = scenario_space(spec)
    d0 = pspace.clip(default_vector(cfg, names=pspace.names))
    pol_vec = d0
    pool = [nominal_scenario_vector(spec, sspace)]
    for world in initial_worlds or ():
        pool.append(sspace.clip(jnp.asarray(world, jnp.float32)))
    history = []
    att = None
    for _ in range(rounds):
        key, k_tune, k_att = jax.random.split(key, 3)
        obj = _PoolObjective(cfg, spec, sspace, pspace,
                             jnp.stack(pool), seeds, penalty, scenario_id)
        inject = jnp.stack([d0, pol_vec])
        tuned = jax.jit(lambda k, o=obj, v=pol_vec, i=inject: cem_minimize(
            o, pspace, k, pop_size=pop_size, generations=generations,
            init=v, inject=i))(k_tune)
        pol_vec = pspace.clip(jnp.asarray(tuned.best_vec))
        att = attack_policy(cfg, spec,
                            vector_to_params(pol_vec, names=pspace.names),
                            seeds,
                            k_att, pop_size=pop_size,
                            generations=generations, penalty=penalty,
                            scenario_id=scenario_id)
        pool.append(att.worst_vec)
        history.append({
            "tuned_pool_score": float(tuned.best_score),
            "worst_score": float(att.worst_score),
            "worst_params": att.worst_params,
        })
    return RobustResult(params=vector_to_params(pol_vec,
                                                names=pspace.names),
                        vec=pol_vec,
                        worst_score=att.worst_score,
                        pool=jnp.stack(pool), rounds=tuple(history),
                        final_attack=att)
