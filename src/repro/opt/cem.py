"""Cross-entropy method: gradient-free minimization, one jitted call.

Classic CEM over a ``BoxSpace``: keep a Gaussian sampling distribution in
the unit cube, draw a population per generation, score it with a batched
objective, refit mean/std to the elite fraction, repeat.  Everything is
pure ``jax.random`` + ``lax.scan`` over generations, so an entire tuning
run — populations, full-simulation scoring, distribution updates, best-so-
far tracking — is a single traceable function: jit it once and the whole
``generations × pop_size × (seeds × scenarios)`` stack of simulations
compiles exactly once and runs as one device program.

Same key ⇒ bit-identical result (the benchmark gate and the determinism
test rely on this).

``inject`` plants a known incumbent (e.g. the hand-set default policy) as
candidate 0 of every generation: the returned best can then never be worse
than the incumbent, and any strict improvement is a genuine win over it.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..obs import ledger as ledger_lib
from .space import BoxSpace

SIGMA_FLOOR = 0.02  # keeps the elite refit from collapsing to a point

# A stall event fires when the incumbent has not improved for this many
# consecutive generations (once per episode, on the transition).
STALL_GENS = 3


class OptTelemetry(NamedTuple):
    """Per-generation optimizer probes + the decision-ledger ring.

    Static opt-in (``telemetry=True`` on the minimizers): off, the field
    is ``None`` on :class:`TuneResult` and the compiled program is the
    exact historical one — the same leafless-carry contract as
    ``SimConfig.obs``.  The ledger's tick column is the *generation*
    index; ``opt.tuner.telemetry_report`` drains it into an ObsReport so
    every downstream exporter (JSONL, Perfetto, OpenMetrics) works on
    tuning runs unchanged.
    """

    ledger: Any                  # obs.ledger.Ledger; tick = generation
    elite_mean: jnp.ndarray      # (G,) mean elite score (ES: incumbent)
    score_std: jnp.ndarray       # (G,) population score spread
    sigma_mean: jnp.ndarray      # (G,) mean sampling scale
    stalled: jnp.ndarray         # ()  consecutive stale gens at the end


class TuneResult(NamedTuple):
    """Outcome of one CEM/ES run (vectors in *real* parameter space)."""

    best_vec: jnp.ndarray      # (d,) argmin over every candidate evaluated
    best_score: jnp.ndarray    # ()  its score
    final_mean: jnp.ndarray    # (d,) final sampling-distribution mean
    history_best: jnp.ndarray  # (G,) per-generation best score
    history_mean: jnp.ndarray  # (G,) per-generation population mean score
    telemetry: OptTelemetry | None = None  # probes (None = off, compiled out)


def cem_minimize(f: Callable, space: BoxSpace, key: jax.Array,
                 pop_size: int = 32, generations: int = 8,
                 elite_frac: float = 0.25, init: jnp.ndarray | None = None,
                 inject: jnp.ndarray | None = None,
                 init_sigma: float = 0.3,
                 telemetry: bool = False) -> TuneResult:
    """Minimize ``f`` (a scalar function of a ``(space.dim,)`` vector) —
    traceable end to end; wrap in ``jax.jit`` for the one-compile path.

    ``init`` centres the first generation (default: mid-box).  ``inject``
    is one ``(dim,)`` vector — or a ``(k, dim)`` stack of them — evaluated
    as the first candidate(s) of *every* generation (see module doc).
    ``telemetry`` statically opts the per-generation probes and the
    incumbent-replacement / stall event ledger into the scan (see
    :class:`OptTelemetry`); off (default) compiles the probe-free run and
    the result is bit-identical either way — probes only observe.
    """
    if pop_size < 2:
        raise ValueError(f"pop_size must be >= 2, got {pop_size}")
    if generations < 1:
        raise ValueError(f"generations must be >= 1, got {generations}")
    n_elite = max(int(round(elite_frac * pop_size)), 2)
    if n_elite > pop_size:
        raise ValueError(
            f"elite_frac {elite_frac} yields {n_elite} elites for a "
            f"population of {pop_size}")
    d = space.dim
    batch_f = jax.vmap(f)
    mu0 = (jnp.full((d,), 0.5, jnp.float32) if init is None
           else space.to_unit(init))
    inject_u = None
    if inject is not None:
        inject_u = jnp.atleast_2d(space.to_unit(inject))
        if inject_u.shape[0] >= pop_size:
            raise ValueError(
                f"{inject_u.shape[0]} injected incumbents leave no room "
                f"to explore in a population of {pop_size}")

    def gen(carry, xs):
        if telemetry:
            (mu, sigma, best_u, best_score, led, stall), (k, g) = carry, xs
        else:
            (mu, sigma, best_u, best_score), k = carry, xs
        pop = mu + sigma * jax.random.normal(k, (pop_size, d))
        pop = jnp.clip(pop, 0.0, 1.0)
        if inject_u is not None:
            pop = pop.at[: inject_u.shape[0]].set(inject_u)
        scores = batch_f(space.from_unit(pop))
        order = jnp.argsort(scores)
        elite = pop[order[:n_elite]]
        new_mu = jnp.mean(elite, axis=0)
        new_sigma = jnp.maximum(jnp.std(elite, axis=0), SIGMA_FLOOR)
        gen_best = scores[order[0]]
        better = gen_best < best_score
        best_u = jnp.where(better, pop[order[0]], best_u)
        best_score = jnp.minimum(best_score, gen_best)
        if telemetry:
            led = ledger_lib.push(led, better, g,
                                  ledger_lib.KIND_OPT_IMPROVE, gen_best)
            stall = jnp.where(better, 0, stall + 1)
            led = ledger_lib.push(led, stall == STALL_GENS, g,
                                  ledger_lib.KIND_OPT_STALL,
                                  stall.astype(jnp.float32))
            return ((new_mu, new_sigma, best_u, best_score, led, stall),
                    (gen_best, jnp.mean(scores),
                     jnp.mean(scores[order[:n_elite]]), jnp.std(scores),
                     jnp.mean(new_sigma)))
        return ((new_mu, new_sigma, best_u, best_score),
                (gen_best, jnp.mean(scores)))

    carry0 = (mu0, jnp.full((d,), init_sigma, jnp.float32), mu0,
              jnp.asarray(jnp.inf, jnp.float32))
    keys = jax.random.split(key, generations)
    if telemetry:
        carry0 = carry0 + (ledger_lib.init(2 * generations),
                           jnp.asarray(0, jnp.int32))
        final, ys = jax.lax.scan(gen, carry0,
                                 (keys, jnp.arange(generations)))
        mu, _, best_u, best_score, led, stall = final
        tel = OptTelemetry(ledger=led, elite_mean=ys[2], score_std=ys[3],
                           sigma_mean=ys[4], stalled=stall)
        hist_best, hist_mean = ys[0], ys[1]
    else:
        (mu, _, best_u, best_score), (hist_best, hist_mean) = jax.lax.scan(
            gen, carry0, keys)
        tel = None
    return TuneResult(best_vec=space.from_unit(best_u),
                      best_score=best_score,
                      final_mean=space.from_unit(mu),
                      history_best=hist_best, history_mean=hist_mean,
                      telemetry=tel)
