"""Bounded continuous search spaces for the gradient-free tuners.

A ``BoxSpace`` names an ordered set of scalar parameters with per-parameter
``[lo, hi]`` bounds and maps between three representations:

  * the flat **vector** the optimizers move through (f32, shape ``(dim,)``);
  * the **unit cube** the CEM/ES internals sample in (every optimizer step
    works on ``to_unit``-mapped vectors, so step sizes are comparable
    across parameters of very different scales);
  * the named **dict** the simulator-side hooks consume
    (``scenarios._gen_param`` overrides, reporting).

Two concrete spaces ship here:

  * ``policy_space()`` — the five ``core.types.PolicyParams`` leaves
    (AIMD α/β, relative bid multiple, TTC-escalation gain, EMA weight)
    with platform-sensible default bounds;
  * ``scenario_space(spec)`` — whatever a ``sim.scenarios`` spec exposes
    through its ``param_bounds()`` hook (the adversarial search space).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.types import PolicyParams
from ..sim import runner

# Default tuning box for the policy coefficients.  The AIMD band keeps the
# additive gain within the N_min..N_max head-room and the multiplicative
# decrease a genuine decrease; the relative bid multiple spans cautious
# (0.4×) to aggressive (2.5×) versions of the configured bid; the EMA
# weight covers sluggish to near-instant market tracking.
POLICY_BOUNDS: dict[str, tuple[float, float]] = {
    "alpha": (1.0, 20.0),
    "beta": (0.5, 0.99),
    "bid_mult": (0.4, 2.5),
    "ttc_gain": (0.5, 12.0),
    "ema_alpha": (0.05, 0.9),
}


@dataclasses.dataclass(frozen=True)
class BoxSpace:
    """An ordered, bounded box of named scalar parameters (hashable)."""

    names: tuple[str, ...]
    lo: tuple[float, ...]
    hi: tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "names", tuple(self.names))
        object.__setattr__(self, "lo", tuple(float(v) for v in self.lo))
        object.__setattr__(self, "hi", tuple(float(v) for v in self.hi))
        if not self.names:
            raise ValueError("a BoxSpace needs at least one parameter")
        if not len(self.names) == len(self.lo) == len(self.hi):
            raise ValueError(
                f"names/lo/hi lengths differ: {len(self.names)}/"
                f"{len(self.lo)}/{len(self.hi)}"
            )
        for name, lo, hi in zip(self.names, self.lo, self.hi):
            if not lo < hi:
                raise ValueError(f"{name}: need lo < hi, got [{lo}, {hi}]")

    @property
    def dim(self) -> int:
        return len(self.names)

    @property
    def lo_vec(self) -> jnp.ndarray:
        return jnp.asarray(self.lo, jnp.float32)

    @property
    def hi_vec(self) -> jnp.ndarray:
        return jnp.asarray(self.hi, jnp.float32)

    def clip(self, vec: jnp.ndarray) -> jnp.ndarray:
        return jnp.clip(vec, self.lo_vec, self.hi_vec)

    def to_unit(self, vec: jnp.ndarray) -> jnp.ndarray:
        """Real-space vector → unit cube (clipped into [0, 1])."""
        u = (jnp.asarray(vec, jnp.float32) - self.lo_vec) / (
            self.hi_vec - self.lo_vec
        )
        return jnp.clip(u, 0.0, 1.0)

    def from_unit(self, u: jnp.ndarray) -> jnp.ndarray:
        """Unit cube → real-space vector (in-bounds by construction)."""
        u = jnp.clip(jnp.asarray(u, jnp.float32), 0.0, 1.0)
        return self.lo_vec + u * (self.hi_vec - self.lo_vec)

    def to_dict(self, vec: jnp.ndarray) -> dict:
        vec = jnp.asarray(vec, jnp.float32)
        return {name: vec[i] for i, name in enumerate(self.names)}

    def from_dict(self, d: dict) -> jnp.ndarray:
        missing = [n for n in self.names if n not in d]
        if missing:
            raise KeyError(f"missing parameters {missing} for {self.names}")
        return jnp.asarray([d[n] for n in self.names], jnp.float32)

    def contains(self, vec, atol: float = 1e-5) -> bool:
        """Every component within its bounds (small float tolerance)."""
        v = np.asarray(vec, dtype=np.float64)
        lo = np.asarray(self.lo) - atol
        hi = np.asarray(self.hi) + atol
        return bool(np.all(v >= lo) and np.all(v <= hi))


def policy_space(bounds: dict[str, tuple[float, float]] | None = None) -> BoxSpace:
    """The ``PolicyParams`` tuning box, leaves in field order.  ``bounds``
    overrides individual parameter boxes (e.g. pin one by a tight box)."""
    merged = dict(POLICY_BOUNDS)
    if bounds:
        unknown = set(bounds) - set(PolicyParams._fields)
        if unknown:
            raise ValueError(
                f"unknown PolicyParams bounds {sorted(unknown)}; "
                f"fields are {PolicyParams._fields}"
            )
        merged.update(bounds)
    names = PolicyParams._fields
    return BoxSpace(
        names=names,
        lo=tuple(merged[n][0] for n in names),
        hi=tuple(merged[n][1] for n in names),
    )


def params_to_vector(pp: PolicyParams) -> jnp.ndarray:
    """PolicyParams pytree → flat (5,) f32 vector, field order."""
    return jnp.stack([jnp.asarray(v, jnp.float32) for v in pp])


def vector_to_params(vec: jnp.ndarray) -> PolicyParams:
    """Flat (5,) vector → PolicyParams pytree (vec may be traced)."""
    vec = jnp.asarray(vec, jnp.float32)
    return PolicyParams(*(vec[i] for i in range(len(PolicyParams._fields))))


def default_vector(cfg) -> jnp.ndarray:
    """The config's hand-set coefficients as a policy vector — the tuners'
    init / injected incumbent, and the baseline tuned runs must beat."""
    return params_to_vector(runner.default_params(cfg))


def scenario_space(spec) -> BoxSpace:
    """The adversarial search box a scenario spec exposes via its
    ``param_bounds()`` hook (names sorted for a stable vector order)."""
    bounds = spec.param_bounds()
    if not bounds:
        raise ValueError(
            f"scenario {getattr(spec, 'name', spec)!r} exposes no tunable "
            "generator parameters (deterministic replays are not attackable)"
        )
    names = tuple(sorted(bounds))
    return BoxSpace(
        names=names,
        lo=tuple(bounds[n][0] for n in names),
        hi=tuple(bounds[n][1] for n in names),
    )


def nominal_scenario_vector(spec, space: BoxSpace | None = None) -> jnp.ndarray:
    """The spec's own generator parameters as a vector in its space."""
    space = scenario_space(spec) if space is None else space
    return space.clip(space.from_dict(spec.params_pytree()))
