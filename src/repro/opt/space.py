"""Bounded continuous search spaces for the gradient-free tuners.

A ``BoxSpace`` names an ordered set of scalar parameters with per-parameter
``[lo, hi]`` bounds and maps between three representations:

  * the flat **vector** the optimizers move through (f32, shape ``(dim,)``);
  * the **unit cube** the CEM/ES internals sample in (every optimizer step
    works on ``to_unit``-mapped vectors, so step sizes are comparable
    across parameters of very different scales);
  * the named **dict** the simulator-side hooks consume
    (``scenarios._gen_param`` overrides, reporting).

Two concrete spaces ship here:

  * ``policy_space()`` — the five ``core.types.PolicyParams`` leaves
    (AIMD α/β, relative bid multiple, TTC-escalation gain, EMA weight)
    with platform-sensible default bounds;
  * ``scenario_space(spec)`` — whatever a ``sim.scenarios`` spec exposes
    through its ``param_bounds()`` hook (the adversarial search space).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.types import PolicyParams, make_policy_params
from ..sim import runner

# Default tuning box for the policy coefficients.  The AIMD band keeps the
# additive gain within the N_min..N_max head-room and the multiplicative
# decrease a genuine decrease; the relative bid multiple spans cautious
# (0.4×) to aggressive (2.5×) versions of the configured bid; the EMA
# weight covers sluggish to near-instant market tracking.  The three
# multi-tenant leaves span strong anti- to pro-demand weight tilt, a real
# admission squeeze up to admit-all, and quarter- to triple-list pricing.
POLICY_BOUNDS: dict[str, tuple[float, float]] = {
    "alpha": (1.0, 20.0),
    "beta": (0.5, 0.99),
    "bid_mult": (0.4, 2.5),
    "ttc_gain": (0.5, 12.0),
    "ema_alpha": (0.05, 0.9),
    "tenant_wg": (-4.0, 4.0),
    "adm_frac": (0.05, 1.0),
    "price_mult": (0.25, 3.0),
}

# The classic five-coefficient tuning subset — the default ``policy_space``
# and the exact space every pre-tenant benchmark/tuning baseline ran in.
# The multi-tenant leaves join a space only when explicitly named (or given
# bounds), so committed tuning baselines stay byte-identical.
TUNED_FIELDS: tuple[str, ...] = ("alpha", "beta", "bid_mult", "ttc_gain",
                                 "ema_alpha")


@dataclasses.dataclass(frozen=True)
class BoxSpace:
    """An ordered, bounded box of named scalar parameters (hashable)."""

    names: tuple[str, ...]
    lo: tuple[float, ...]
    hi: tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "names", tuple(self.names))
        object.__setattr__(self, "lo", tuple(float(v) for v in self.lo))
        object.__setattr__(self, "hi", tuple(float(v) for v in self.hi))
        if not self.names:
            raise ValueError("a BoxSpace needs at least one parameter")
        if not len(self.names) == len(self.lo) == len(self.hi):
            raise ValueError(
                f"names/lo/hi lengths differ: {len(self.names)}/"
                f"{len(self.lo)}/{len(self.hi)}"
            )
        for name, lo, hi in zip(self.names, self.lo, self.hi):
            if not lo < hi:
                raise ValueError(f"{name}: need lo < hi, got [{lo}, {hi}]")

    @property
    def dim(self) -> int:
        return len(self.names)

    @property
    def lo_vec(self) -> jnp.ndarray:
        return jnp.asarray(self.lo, jnp.float32)

    @property
    def hi_vec(self) -> jnp.ndarray:
        return jnp.asarray(self.hi, jnp.float32)

    def clip(self, vec: jnp.ndarray) -> jnp.ndarray:
        return jnp.clip(vec, self.lo_vec, self.hi_vec)

    def to_unit(self, vec: jnp.ndarray) -> jnp.ndarray:
        """Real-space vector → unit cube (clipped into [0, 1])."""
        u = (jnp.asarray(vec, jnp.float32) - self.lo_vec) / (
            self.hi_vec - self.lo_vec
        )
        return jnp.clip(u, 0.0, 1.0)

    def from_unit(self, u: jnp.ndarray) -> jnp.ndarray:
        """Unit cube → real-space vector (in-bounds by construction)."""
        u = jnp.clip(jnp.asarray(u, jnp.float32), 0.0, 1.0)
        return self.lo_vec + u * (self.hi_vec - self.lo_vec)

    def to_dict(self, vec: jnp.ndarray) -> dict:
        vec = jnp.asarray(vec, jnp.float32)
        return {name: vec[i] for i, name in enumerate(self.names)}

    def from_dict(self, d: dict) -> jnp.ndarray:
        missing = [n for n in self.names if n not in d]
        if missing:
            raise KeyError(f"missing parameters {missing} for {self.names}")
        return jnp.asarray([d[n] for n in self.names], jnp.float32)

    def contains(self, vec, atol: float = 1e-5) -> bool:
        """Every component within its bounds (small float tolerance)."""
        v = np.asarray(vec, dtype=np.float64)
        lo = np.asarray(self.lo) - atol
        hi = np.asarray(self.hi) + atol
        return bool(np.all(v >= lo) and np.all(v <= hi))


def _check_names(names) -> tuple[str, ...]:
    names = tuple(names)
    unknown = set(names) - set(PolicyParams._fields)
    if unknown:
        raise ValueError(
            f"unknown PolicyParams fields {sorted(unknown)}; "
            f"fields are {PolicyParams._fields}"
        )
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate fields in {names}")
    # Field order keeps vectors comparable regardless of how a caller
    # spelled the subset.
    return tuple(f for f in PolicyParams._fields if f in set(names))


def policy_space(bounds: dict[str, tuple[float, float]] | None = None,
                 names=None) -> BoxSpace:
    """A ``PolicyParams`` tuning box, leaves in field order.

    ``names`` selects which leaves are tuned (default: the classic
    ``TUNED_FIELDS`` five, *plus* any field given explicit ``bounds`` — so
    ``policy_space(bounds={"tenant_wg": (-2, 2)})`` opts the tenant knob
    into the space without touching the default baseline space).
    ``bounds`` overrides individual parameter boxes.
    """
    merged = dict(POLICY_BOUNDS)
    if bounds:
        unknown = set(bounds) - set(PolicyParams._fields)
        if unknown:
            raise ValueError(
                f"unknown PolicyParams bounds {sorted(unknown)}; "
                f"fields are {PolicyParams._fields}"
            )
        merged.update(bounds)
    if names is None:
        names = set(TUNED_FIELDS) | set(bounds or {})
    names = _check_names(names)
    return BoxSpace(
        names=names,
        lo=tuple(merged[n][0] for n in names),
        hi=tuple(merged[n][1] for n in names),
    )


def params_to_vector(pp: PolicyParams, names=None) -> jnp.ndarray:
    """PolicyParams pytree → flat f32 vector (``names`` order; default:
    every field)."""
    names = PolicyParams._fields if names is None else _check_names(names)
    return jnp.stack([jnp.asarray(getattr(pp, n), jnp.float32)
                      for n in names])


def vector_to_params(vec: jnp.ndarray, names=None) -> PolicyParams:
    """Flat vector → PolicyParams pytree (vec may be traced).

    ``names`` says which fields the vector's components are (field order);
    the rest take their neutral defaults.  With ``names=None`` the length
    disambiguates: a full-width vector maps every field, a
    ``len(TUNED_FIELDS)`` vector maps the classic tuned subset.
    """
    vec = jnp.asarray(vec, jnp.float32)
    if names is None:
        if vec.shape[0] == len(PolicyParams._fields):
            names = PolicyParams._fields
        elif vec.shape[0] == len(TUNED_FIELDS):
            names = TUNED_FIELDS
        else:
            raise ValueError(
                f"cannot infer fields for a {vec.shape[0]}-vector; pass "
                "names=")
    else:
        names = _check_names(names)
        if vec.shape[0] != len(names):
            raise ValueError(
                f"{vec.shape[0]}-vector for {len(names)} names {names}")
    kwargs = {n: vec[i] for i, n in enumerate(names)}
    return make_policy_params(**kwargs)


def default_vector(cfg, names=None) -> jnp.ndarray:
    """The config's hand-set coefficients as a policy vector — the tuners'
    init / injected incumbent, and the baseline tuned runs must beat.
    ``names`` defaults to the classic ``TUNED_FIELDS`` subset (the default
    ``policy_space``)."""
    return params_to_vector(runner.default_params(cfg),
                            names=TUNED_FIELDS if names is None else names)


def scenario_space(spec) -> BoxSpace:
    """The adversarial search box a scenario spec exposes via its
    ``param_bounds()`` hook (names sorted for a stable vector order)."""
    bounds = spec.param_bounds()
    if not bounds:
        raise ValueError(
            f"scenario {getattr(spec, 'name', spec)!r} exposes no tunable "
            "generator parameters (deterministic replays are not attackable)"
        )
    names = tuple(sorted(bounds))
    return BoxSpace(
        names=names,
        lo=tuple(bounds[n][0] for n in names),
        hi=tuple(bounds[n][1] for n in names),
    )


def nominal_scenario_vector(spec, space: BoxSpace | None = None) -> jnp.ndarray:
    """The spec's own generator parameters as a vector in its space."""
    space = scenario_space(spec) if space is None else space
    return space.clip(space.from_dict(spec.params_pytree()))
