"""Adversarial scenario search: the worst workload world for a policy.

The same machinery as policy tuning, run in the other direction: freeze a
policy (a ``PolicyParams`` pytree — hand-set defaults or a tuner's output)
and search the *scenario generator's* bounded parameter space for the
world that maximizes its mean cost + violation penalty.  The generators'
``sample(key, params)`` hooks take the candidate parameters as traced
inputs, so the whole attack — populations of worlds × seeds of full
simulations × generations — is again one jitted CEM run, one compile.

The nominal world is injected as candidate 0 of every generation, so the
reported worst case is never milder than the spec's own setting and the
``damage`` (worst − nominal) is non-negative by construction.

Chaos attacks: wrap the generator in a ``sim.faults.ChaosScenario`` (a
``FaultModel`` with ``bounds``) and run under a config with
``cfg.faults=FaultConfig()``.  The fault model's ``fault_``-prefixed
bounds merge into ``param_bounds()``, so ``scenario_space`` exposes them
here unchanged and the adversary searches *when the outage hits and how
hard* jointly with the workload shape — ``ScenarioObjective`` threads the
attacked ``FaultSpec`` into the fault-aware point program.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams
from ..sim import runner
from .cem import TuneResult, cem_minimize
from .objective import DEFAULT_PENALTY, ScenarioObjective
from .space import BoxSpace, nominal_scenario_vector, scenario_space


class AttackResult(NamedTuple):
    """Worst-case world found for one (policy, scenario spec) pair."""

    worst_vec: jnp.ndarray      # (d,) generator parameters of the worst world
    worst_score: jnp.ndarray    # ()  mean cost + penalty there
    nominal_vec: jnp.ndarray    # (d,) the spec's own parameters
    nominal_score: jnp.ndarray  # ()  score of the nominal world
    space: BoxSpace             # the bounded search box (names the vectors)
    result: TuneResult          # raw maximizer output (scores negated)
    objective: ScenarioObjective

    @property
    def worst_params(self) -> dict:
        """The worst world as {generator parameter: value} floats."""
        return {n: float(self.worst_vec[i])
                for i, n in enumerate(self.space.names)}

    @property
    def damage(self) -> float:
        """Score surplus of the worst world over the nominal one (≥ 0)."""
        return float(self.worst_score - self.nominal_score)


def attack_policy(cfg: runner.SimConfig, spec, params: PolicyParams | None,
                  seeds, key: jax.Array, pop_size: int = 32,
                  generations: int = 8,
                  penalty: float = DEFAULT_PENALTY,
                  scenario_id: int = 0) -> AttackResult:
    """Find the worst-case world of ``spec``'s family for this policy.

    ``spec`` is a stochastic ``sim.scenarios`` generator (replays expose no
    parameters and are rejected).  ``params=None`` attacks the config's
    hand-set defaults.  ``scenario_id`` seeds the per-seed sampling keys —
    pass the spec's index in its ``ScenarioSet`` so the nominal world here
    is the very world a sweep over that set evaluates.  Same ``key`` ⇒
    bit-identical outcome; the returned world always respects the spec's
    ``param_bounds()`` box.
    """
    pp = runner.default_params(cfg) if params is None else params
    space = scenario_space(spec)
    obj = ScenarioObjective(cfg, spec, pp, space, seeds, penalty=penalty,
                            scenario_id=scenario_id)
    nominal = nominal_scenario_vector(spec, space)
    # CEM minimizes; attack by minimizing the negated damage score.  The
    # sampling distribution starts at mid-box — the damage landscape's
    # interesting corners are usually far from the nominal world, and the
    # injected nominal already guarantees the result is never milder than
    # the spec's own setting.
    run = jax.jit(lambda k: cem_minimize(
        lambda v: -obj(v), space, k, pop_size=pop_size,
        generations=generations, inject=nominal))
    result = jax.tree.map(jnp.asarray, run(key))
    nominal_summary = obj.evaluate(nominal)
    nominal_score = jnp.mean(
        nominal_summary.cost
        + penalty * nominal_summary.violations.astype(jnp.float32))
    # Deliberately *not* re-clipped: CEM's ``from_unit`` keeps candidates
    # in-bounds by construction, and returning the raw optimizer output is
    # what lets the bench/test bounds check catch a future search path
    # that leaks outside the box instead of silently laundering it.
    return AttackResult(worst_vec=result.best_vec,
                        worst_score=-result.best_score,
                        nominal_vec=nominal, nominal_score=nominal_score,
                        space=space, result=result, objective=obj)
