"""High-level policy auto-tuning: one call, one compile, tuned params.

``tune_policy`` wires the pieces together: a ``PolicyObjective`` (mean
cost + violation penalty over a seeds × scenarios batch of full
simulations), the bounded ``policy_space``, and a CEM or ES minimizer —
then jits the *entire* tuning run so populations, generations and every
underlying simulation compile once and execute as a single device program.

The config's hand-set coefficients are both the starting point and the
injected incumbent, so the returned parameters can never score worse than
the defaults on the tuning batch — any strict improvement is real.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams
from ..obs import ledger as ledger_lib
from ..obs import probes
from ..sim import runner
from .cem import TuneResult, cem_minimize
from .es import es_minimize
from .objective import DEFAULT_PENALTY, PolicyObjective
from .space import default_vector, policy_space, vector_to_params

METHODS = ("cem", "es")


class PolicyTuning(NamedTuple):
    """A finished tuning run, defaults scored on the same batch."""

    result: TuneResult          # best vector / score / per-gen history
    params: PolicyParams        # best vector as the pytree the sim consumes
    default_vec: jnp.ndarray    # the hand-set coefficients (the incumbent)
    default_score: jnp.ndarray  # their score on the same batch
    objective: PolicyObjective  # for ``evaluate`` / ``n_traces``

    @property
    def improvement_pct(self) -> float:
        """Score improvement of tuned over default, in percent."""
        d = float(self.default_score)
        return 100.0 * (d - float(self.result.best_score)) / max(d, 1e-9)


def tune_policy(cfg: runner.SimConfig, schedule, seeds, key: jax.Array,
                scenarios=None, method: str = "cem", pop_size: int = 32,
                generations: int = 8, penalty: float = DEFAULT_PENALTY,
                bounds: dict | None = None,
                objective=None, space=None,
                telemetry: bool = False) -> PolicyTuning:
    """Tune the ``PolicyParams`` coefficients for this config on this
    workload batch.  ``schedule`` is anything ``run_sweep`` accepts — a
    static schedule or a ``ScenarioSet`` with ``scenarios`` selecting ids
    (default: all).  Returns tuned params plus the default's score on the
    identical batch; same ``key`` ⇒ bit-identical outcome.

    The default objective is the classic cost+penalty ``PolicyObjective``
    over ``TUNED_FIELDS`` (``bounds`` opts further fields in, e.g. the
    multi-tenant knobs).  Pass ``objective`` — any callable of a vector
    with ``space``/``default_score`` attributes, e.g. a provider
    ``ProfitObjective`` — to tune a different score through the identical
    CEM/ES machinery; ``schedule``/``seeds``/``scenarios``/``penalty`` are
    then the objective's business and ignored here.

    ``telemetry=True`` statically opts the per-generation optimizer probes
    and the improvement/stall event ledger into the minimizer's scan
    (``result.telemetry``; see ``telemetry_report``); the tuned outcome is
    bit-identical either way.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; choose one of {METHODS}")
    if objective is None:
        space = policy_space(bounds) if space is None else space
        obj = PolicyObjective(cfg, schedule, seeds, scenarios=scenarios,
                              penalty=penalty, space=space)
    else:
        obj = objective
        space = obj.space if space is None else space
        if space is None:
            raise ValueError("a custom objective needs a space (obj.space "
                             "or the space= argument)")
    d0 = space.clip(default_vector(cfg, names=space.names))
    if method == "cem":
        run = jax.jit(lambda k: cem_minimize(
            obj, space, k, pop_size=pop_size, generations=generations,
            init=d0, inject=d0, telemetry=telemetry))
    else:
        # The (1+λ) ES's incumbent *is* the init, giving the same
        # never-worse-than-default guarantee without a separate inject.
        run = jax.jit(lambda k: es_minimize(
            obj, space, k, pop_size=pop_size, generations=generations,
            init=d0, telemetry=telemetry))
    result = jax.tree.map(jnp.asarray, run(key))
    # Score the default at the vector the optimizer *actually* evaluated:
    # the incumbent rides through the unit-cube mapping, whose f32
    # round-trip can be one ulp off the raw config vector — scoring the
    # raw vector instead could make "tuned ≥ default" fail spuriously on
    # a discretely sensitive objective (a flipped violation).
    d0_eval = space.from_unit(space.to_unit(d0))
    if objective is None:
        default_score = obj.evaluate(d0_eval)
        default_score = jnp.mean(
            default_score.cost
            + penalty * default_score.violations.astype(jnp.float32))
    else:
        default_score = jnp.asarray(obj.default_score(d0_eval))
    return PolicyTuning(result=result,
                        params=vector_to_params(result.best_vec,
                                                names=space.names),
                        default_vec=d0_eval, default_score=default_score,
                        objective=obj)


def telemetry_report(run) -> probes.ObsReport:
    """Drain a ``telemetry=True`` tuning run into an :class:`ObsReport`.

    Accepts a :class:`PolicyTuning` or a raw :class:`TuneResult`; the
    report's ledger holds the improvement/stall events with the tick
    column meaning *generation*, so every downstream exporter — JSONL,
    Perfetto traces, OpenMetrics — works on optimizer runs unchanged.
    """
    result = run.result if isinstance(run, PolicyTuning) else run
    tel = result.telemetry
    if tel is None:
        raise ValueError(
            "this tuning run has no telemetry — pass telemetry=True to "
            "tune_policy / cem_minimize / es_minimize")
    records, dropped = ledger_lib.drain(tel.ledger)
    counters = {
        "generations": float(tel.elite_mean.shape[0]),
        "opt_improvements": float(
            sum(r.kind == ledger_lib.KIND_OPT_IMPROVE for r in records)),
        "opt_stalls": float(
            sum(r.kind == ledger_lib.KIND_OPT_STALL for r in records)),
        "best_score": float(result.best_score),
        "final_elite_mean": float(tel.elite_mean[-1]),
        "final_score_std": float(tel.score_std[-1]),
        "final_sigma_mean": float(tel.sigma_mean[-1]),
        "stalled_gens_final": float(tel.stalled),
    }
    return probes.ObsReport(
        spec=None, counters=counters, kalman=None, preempt_by_type=None,
        kill_by_type=None, rejects=None, queue_hist=None,
        queue_percentiles=None, ledger=records, ledger_dropped=dropped)
