"""Sweep-backed objectives: score a candidate against the simulator.

Both tuners and the adversarial search optimize the same quantity the
benchmarks report: **mean cost plus a violation penalty** over a seeds ×
scenarios batch of full simulations.  The batch runs through
``sim.sweep.point_fn`` (or ``sim.tenants.point_fn`` for provider-profit
tuning) — the exact per-point program ``sweep(SweepSpec(...), cfg)``
executes, summary mode, schedule sampled per (seed, scenario) inside the
trace — so one tuning run *is* one big sweep and compiles once: the
candidate's ``PolicyParams`` (or the attacked generator's parameters) are
traced inputs of that single compile, never retrace triggers.

``PolicyObjective`` counts how many times its Python body is traced
(``n_traces``).  Under ``jit(vmap(...))``/``lax.scan`` the body runs once
per *compile*, not once per candidate, so the counter is the benchmark's
proof that an entire population × generations tuning run compiled the
sweep objective exactly once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams
from ..sim import runner, spot, sweep
from ..sim import scenarios as scen_lib
from ..sim import tenants as tenants_lib
from .space import BoxSpace, policy_space, vector_to_params

DEFAULT_PENALTY = 1.0  # $ charged per TTC violation in the score


def score_summary(summary: sweep.RunSummary, penalty: float) -> jnp.ndarray:
    """Scalar score of one run: dollars billed plus the violation fine."""
    return summary.cost + penalty * summary.violations.astype(jnp.float32)


def run_env(cfg: runner.SimConfig) -> tuple:
    """The non-swept runtime constants every objective's runs share:
    ``(itype, mix, bid_mult, policy_id)`` — the config's primary fleet mix
    at the config's bid multiple (``PolicyParams.bid_mult`` scales it) and
    the config's own bid policy."""
    itype, mix = sweep._as_mix(cfg.spot.fleet or cfg.spot.instance)
    return (jnp.asarray(itype, jnp.int32),
            jnp.asarray(mix, jnp.float32),
            jnp.asarray(cfg.spot.bid_mult, jnp.float32),
            jnp.asarray(spot.bid_policy_index(cfg.spot.bid_policy),
                        jnp.int32))


def _seed_scenario_grid(seeds, scenarios) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Flattened (seed, scenario) cartesian product as two (G,) arrays."""
    s = jnp.asarray(list(seeds), jnp.int32)
    c = jnp.asarray(list(scenarios), jnp.int32)
    return (jnp.repeat(s, c.shape[0]), jnp.tile(c, s.shape[0]))


class PolicyObjective:
    """Score a policy-parameter vector over a seeds × scenarios batch.

    Calling the objective with a ``(dim,)`` vector (traced or concrete)
    returns the scalar score; the tuners ``vmap`` it over populations.
    ``evaluate(vec)`` returns the underlying per-(seed, scenario)
    ``RunSummary`` grid for reporting — same machinery, own jit.

    The candidate's ``bid_mult`` leaf is *relative*: every run bids
    ``bid_mult ×`` the config's own multiple (``cfg.spot.bid_mult``), so
    the default vector reproduces the hand-set config bit for bit.
    """

    def __init__(self, cfg: runner.SimConfig, schedule, seeds,
                 scenarios=None, penalty: float = DEFAULT_PENALTY,
                 space: BoxSpace | None = None):
        if isinstance(schedule, scen_lib.ScenarioSet):
            scen_ids = (range(len(schedule)) if scenarios is None
                        else scenarios)
        else:
            scen_ids = [0] if scenarios is None else scenarios
        self.cfg = cfg
        self.schedule = schedule
        self.penalty = float(penalty)
        self.space = space
        self.seeds, self.scenarios = _seed_scenario_grid(seeds, scen_ids)
        self._point = sweep.point_fn(schedule, cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)
        self._traces = 0
        self._eval = jax.jit(self._grid)

    @property
    def n_traces(self) -> int:
        """How often the objective body was traced — 1 after any number of
        candidates/generations means the sweep objective compiled once."""
        return self._traces

    def params_of(self, vec: jnp.ndarray) -> PolicyParams:
        if self.space is not None:
            return vector_to_params(self.space.clip(vec),
                                    names=self.space.names)
        return vector_to_params(vec)

    def _grid(self, vec: jnp.ndarray) -> sweep.RunSummary:
        pp = self.params_of(vec)

        def one(seed, scenario):
            return self._point(seed, self._bid, self._itype, self._pol,
                               self._mix, scenario, pp)

        return jax.vmap(one)(self.seeds, self.scenarios)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        self._traces += 1
        grid = self._grid(vec)
        return jnp.mean(score_summary(grid, self.penalty))

    def evaluate(self, vec: jnp.ndarray) -> sweep.RunSummary:
        """Per-(seed, scenario) summaries of one candidate (host-jitted)."""
        return self._eval(jnp.asarray(vec, jnp.float32))


# Which policy leaves a provider tunes by default: the cross-tenant weight
# tilt, the admission squeeze, and the list-price multiple.
PROVIDER_FIELDS: tuple[str, ...] = ("tenant_wg", "adm_frac", "price_mult")


class ProfitObjective:
    """Provider profit over a seeds batch of shared-fleet runs, negated
    (the tuners minimize).

    Profit of one run = Σ_i revenue_i − fleet spot bill − Σ_i
    ``slo_penalty_i`` · violations_i, where tenant ``i``'s revenue is
    their contracted $/CU-hour price × the candidate's ``price_mult`` ×
    the service they actually received.  Raising the list price sheds
    demand: delivered service is scaled by ``max(0, 1 − elasticity ·
    (price_mult − 1))`` — the linear-demand model under which the
    revenue-optimal multiple sits at ``(1 + elasticity) / (2 ·
    elasticity)`` rather than at either bound.  ``tenant_wg`` and
    ``adm_frac`` act inside the simulation itself (allocation tilt,
    admission control); ``price_mult`` only reprices.

    Drop-in for ``tune_policy(objective=...)``: exposes ``space`` (default
    ``PROVIDER_FIELDS``), ``default_score``, ``n_traces`` and
    ``evaluate``, and compiles its seeds batch exactly once.
    """

    def __init__(self, cfg: runner.SimConfig, tset, seeds,
                 elasticity: float = 0.5, space: BoxSpace | None = None):
        if not 0.0 <= elasticity <= 1.0:
            raise ValueError(
                f"elasticity must be in [0, 1], got {elasticity}")
        self.cfg = cfg
        self.tset = tset
        self.elasticity = float(elasticity)
        self.space = (policy_space(names=PROVIDER_FIELDS) if space is None
                      else space)
        self.seeds = jnp.asarray(list(seeds), jnp.int32)
        self.scfg = tset.sim_config(cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)
        self._prices = jnp.asarray([s.price for s in tset.specs],
                                   jnp.float32)
        self._pens = jnp.asarray([s.slo_penalty for s in tset.specs],
                                 jnp.float32)
        self._point = tenants_lib.point_fn(tset, cfg)
        self._traces = 0
        self._eval = jax.jit(self._runs)
        self._score = jax.jit(self._profit)

    @property
    def n_traces(self) -> int:
        return self._traces

    def params_of(self, vec: jnp.ndarray) -> PolicyParams:
        return vector_to_params(self.space.clip(vec),
                                names=self.space.names)

    def _runs(self, vec: jnp.ndarray) -> tenants_lib.TenantRun:
        # The per-seed body IS ``tenants.point_fn`` — the same program the
        # unified sweep executor vmaps, so the objective and the reported
        # benchmarks can never drift apart.
        pp = self.params_of(vec)

        def one(seed):
            return self._point(seed, self._bid, self._itype, self._pol,
                               self._mix, jnp.int32(0), pp)

        return jax.vmap(one)(self.seeds)

    def _profit(self, vec: jnp.ndarray) -> jnp.ndarray:
        """Mean provider profit ($ per run) of one candidate."""
        pm = self.params_of(vec).price_mult
        runs = self._runs(vec)
        shed = jnp.maximum(0.0, 1.0 - self.elasticity * (pm - 1.0))
        revenue = jnp.sum(runs.tenants.service / 3600.0 * self._prices
                          * pm * shed, axis=-1)
        fines = jnp.sum(
            runs.tenants.violations.astype(jnp.float32) * self._pens,
            axis=-1)
        return jnp.mean(revenue - runs.fleet.cost_horizon - fines)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        self._traces += 1
        return -self._profit(vec)

    def default_score(self, vec: jnp.ndarray) -> jnp.ndarray:
        """The (negated) profit of the incumbent vector, own jit."""
        return -self._score(jnp.asarray(vec, jnp.float32))

    def profit(self, vec: jnp.ndarray) -> jnp.ndarray:
        """Mean profit ($, positive-good) of a vector, host-jitted."""
        return self._score(jnp.asarray(vec, jnp.float32))

    def evaluate(self, vec: jnp.ndarray) -> tenants_lib.TenantRun:
        """Per-seed ``TenantRun`` batch of one candidate (host-jitted)."""
        return self._eval(jnp.asarray(vec, jnp.float32))


class ScenarioObjective:
    """Score a scenario-generator parameter vector against a *fixed*
    policy: how badly does the world drawn from these parameters hurt it?

    Every seed draws its schedule from the attacked spec's ``sample(key,
    params)`` hook under ``scenarios.schedule_key(seed, scenario_id)`` —
    pass the spec's id in its ``ScenarioSet`` so the sampled worlds line
    up with what a sweep/``PolicyObjective`` over that set evaluates —
    then runs the full simulation at the frozen ``PolicyParams``.  Higher
    score = worse world; ``opt.adversarial`` maximizes it.
    """

    def __init__(self, cfg: runner.SimConfig, spec, params: PolicyParams,
                 space: BoxSpace, seeds,
                 penalty: float = DEFAULT_PENALTY,
                 scenario_id: int = 0):
        if not spec.param_bounds():
            raise ValueError(
                f"scenario {getattr(spec, 'name', spec)!r} has no tunable "
                "generator parameters to attack")
        if hasattr(spec, "fault_spec") and cfg.faults is None:
            raise ValueError(
                f"scenario {getattr(spec, 'name', spec)!r} carries a fault "
                "model but SimConfig.faults is None — the chaos engine "
                "must be compiled in (cfg.faults=FaultConfig()) for the "
                "adversary's fault parameters to act")
        self.cfg = cfg
        self.spec = spec
        self.space = space
        self.pp = params
        self.penalty = float(penalty)
        self.scenario_id = int(scenario_id)
        self.seeds = jnp.asarray(list(seeds), jnp.int32)
        self._base = sweep._point_sched(cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)
        self._traces = 0
        self._eval = jax.jit(self._grid)

    @property
    def n_traces(self) -> int:
        return self._traces

    def _grid(self, vec: jnp.ndarray) -> sweep.RunSummary:
        gen_params = self.space.to_dict(self.space.clip(vec))
        # A chaos scenario (``sim.faults.ChaosScenario``) routes its
        # ``fault_``-prefixed attacked parameters into a traced FaultSpec:
        # the adversary then searches fault timing/intensity jointly with
        # the workload shape, through the same CEM loop.
        tail = ((self.spec.fault_spec(gen_params),)
                if hasattr(self.spec, "fault_spec") else ())

        def one(seed):
            key = scen_lib.schedule_key(seed, self.scenario_id)
            sched = self.spec.sample(key, params=gen_params)
            return self._base(sched, seed, self._bid, self._itype,
                              self._pol, self._mix, self.pp, *tail)

        return jax.vmap(one)(self.seeds)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        self._traces += 1
        grid = self._grid(vec)
        return jnp.mean(score_summary(grid, self.penalty))

    def evaluate(self, vec: jnp.ndarray) -> sweep.RunSummary:
        """Per-seed summaries of one world (host-jitted)."""
        return self._eval(jnp.asarray(vec, jnp.float32))
