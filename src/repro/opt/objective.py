"""Sweep-backed objectives: score a candidate against the simulator.

Both tuners and the adversarial search optimize the same quantity the
benchmarks report: **mean cost plus a violation penalty** over a seeds ×
scenarios batch of full simulations.  The batch runs through
``sim.sweep.point_fn`` — the exact per-point program ``run_sweep``
executes, summary mode, schedule sampled per (seed, scenario) inside the
trace — so one tuning run *is* one big sweep and compiles once: the
candidate's ``PolicyParams`` (or the attacked generator's parameters) are
traced inputs of that single compile, never retrace triggers.

``PolicyObjective`` counts how many times its Python body is traced
(``n_traces``).  Under ``jit(vmap(...))``/``lax.scan`` the body runs once
per *compile*, not once per candidate, so the counter is the benchmark's
proof that an entire population × generations tuning run compiled the
sweep objective exactly once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams
from ..sim import runner, spot, sweep
from ..sim import scenarios as scen_lib
from .space import BoxSpace, vector_to_params

DEFAULT_PENALTY = 1.0  # $ charged per TTC violation in the score


def score_summary(summary: sweep.RunSummary, penalty: float) -> jnp.ndarray:
    """Scalar score of one run: dollars billed plus the violation fine."""
    return summary.cost + penalty * summary.violations.astype(jnp.float32)


def run_env(cfg: runner.SimConfig) -> tuple:
    """The non-swept runtime constants every objective's runs share:
    ``(itype, mix, bid_mult, policy_id)`` — the config's primary fleet mix
    at the config's bid multiple (``PolicyParams.bid_mult`` scales it) and
    the config's own bid policy."""
    itype, mix = sweep._as_mix(cfg.spot.fleet or cfg.spot.instance)
    return (jnp.asarray(itype, jnp.int32),
            jnp.asarray(mix, jnp.float32),
            jnp.asarray(cfg.spot.bid_mult, jnp.float32),
            jnp.asarray(spot.bid_policy_index(cfg.spot.bid_policy),
                        jnp.int32))


def _seed_scenario_grid(seeds, scenarios) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Flattened (seed, scenario) cartesian product as two (G,) arrays."""
    s = jnp.asarray(list(seeds), jnp.int32)
    c = jnp.asarray(list(scenarios), jnp.int32)
    return (jnp.repeat(s, c.shape[0]), jnp.tile(c, s.shape[0]))


class PolicyObjective:
    """Score a policy-parameter vector over a seeds × scenarios batch.

    Calling the objective with a ``(dim,)`` vector (traced or concrete)
    returns the scalar score; the tuners ``vmap`` it over populations.
    ``evaluate(vec)`` returns the underlying per-(seed, scenario)
    ``RunSummary`` grid for reporting — same machinery, own jit.

    The candidate's ``bid_mult`` leaf is *relative*: every run bids
    ``bid_mult ×`` the config's own multiple (``cfg.spot.bid_mult``), so
    the default vector reproduces the hand-set config bit for bit.
    """

    def __init__(self, cfg: runner.SimConfig, schedule, seeds,
                 scenarios=None, penalty: float = DEFAULT_PENALTY,
                 space: BoxSpace | None = None):
        if isinstance(schedule, scen_lib.ScenarioSet):
            scen_ids = (range(len(schedule)) if scenarios is None
                        else scenarios)
        else:
            scen_ids = [0] if scenarios is None else scenarios
        self.cfg = cfg
        self.schedule = schedule
        self.penalty = float(penalty)
        self.space = space
        self.seeds, self.scenarios = _seed_scenario_grid(seeds, scen_ids)
        self._point = sweep.point_fn(schedule, cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)
        self._traces = 0
        self._eval = jax.jit(self._grid)

    @property
    def n_traces(self) -> int:
        """How often the objective body was traced — 1 after any number of
        candidates/generations means the sweep objective compiled once."""
        return self._traces

    def params_of(self, vec: jnp.ndarray) -> PolicyParams:
        return vector_to_params(self.space.clip(vec) if self.space is not None
                                else vec)

    def _grid(self, vec: jnp.ndarray) -> sweep.RunSummary:
        pp = self.params_of(vec)

        def one(seed, scenario):
            return self._point(seed, self._bid, self._itype, self._pol,
                               self._mix, scenario, pp)

        return jax.vmap(one)(self.seeds, self.scenarios)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        self._traces += 1
        grid = self._grid(vec)
        return jnp.mean(score_summary(grid, self.penalty))

    def evaluate(self, vec: jnp.ndarray) -> sweep.RunSummary:
        """Per-(seed, scenario) summaries of one candidate (host-jitted)."""
        return self._eval(jnp.asarray(vec, jnp.float32))


class ScenarioObjective:
    """Score a scenario-generator parameter vector against a *fixed*
    policy: how badly does the world drawn from these parameters hurt it?

    Every seed draws its schedule from the attacked spec's ``sample(key,
    params)`` hook under ``scenarios.schedule_key(seed, scenario_id)`` —
    pass the spec's id in its ``ScenarioSet`` so the sampled worlds line
    up with what a sweep/``PolicyObjective`` over that set evaluates —
    then runs the full simulation at the frozen ``PolicyParams``.  Higher
    score = worse world; ``opt.adversarial`` maximizes it.
    """

    def __init__(self, cfg: runner.SimConfig, spec, params: PolicyParams,
                 space: BoxSpace, seeds,
                 penalty: float = DEFAULT_PENALTY,
                 scenario_id: int = 0):
        if not spec.param_bounds():
            raise ValueError(
                f"scenario {getattr(spec, 'name', spec)!r} has no tunable "
                "generator parameters to attack")
        self.cfg = cfg
        self.spec = spec
        self.space = space
        self.pp = params
        self.penalty = float(penalty)
        self.scenario_id = int(scenario_id)
        self.seeds = jnp.asarray(list(seeds), jnp.int32)
        self._base = sweep._point_sched(cfg)
        self._itype, self._mix, self._bid, self._pol = run_env(cfg)
        self._traces = 0
        self._eval = jax.jit(self._grid)

    @property
    def n_traces(self) -> int:
        return self._traces

    def _grid(self, vec: jnp.ndarray) -> sweep.RunSummary:
        gen_params = self.space.to_dict(self.space.clip(vec))

        def one(seed):
            key = scen_lib.schedule_key(seed, self.scenario_id)
            sched = self.spec.sample(key, params=gen_params)
            return self._base(sched, seed, self._bid, self._itype,
                              self._pol, self._mix, self.pp)

        return jax.vmap(one)(self.seeds)

    def __call__(self, vec: jnp.ndarray) -> jnp.ndarray:
        self._traces += 1
        grid = self._grid(vec)
        return jnp.mean(score_summary(grid, self.penalty))

    def evaluate(self, vec: jnp.ndarray) -> sweep.RunSummary:
        """Per-seed summaries of one world (host-jitted)."""
        return self._eval(jnp.asarray(vec, jnp.float32))
