"""In-jit policy auto-tuning and adversarial scenario search (ISSUE 5).

Gradient-free optimization over the sweep engine: candidates are
``core.types.PolicyParams`` vectors (AIMD gains, relative bid multiple,
TTC-escalation and EMA coefficients) scored by mean cost + violation
penalty over a seeds × scenarios batch of full simulations.  Because the
policy coefficients and the scenario-generator parameters are *traced*
inputs of one compiled simulation, an entire CEM/ES tuning run — every
generation, every candidate, every seed and scenario — is a single jitted
call with a single compile of the sweep objective.

  * ``tune_policy``    — tune the policy for a config + workload batch;
  * ``attack_policy``  — find the worst-case world of a scenario family
                         for a fixed policy (bounded generator search);
  * ``robust_tune``    — alternate the two for a min–max robust policy;
  * ``cem_minimize`` / ``es_minimize`` — the bare optimizers over any
                         ``BoxSpace`` objective.
"""

from . import adversarial, cem, es, objective, robust, space, tuner
from .adversarial import AttackResult, attack_policy
from .cem import TuneResult, cem_minimize
from .es import es_minimize
from .objective import (PolicyObjective, ProfitObjective, ScenarioObjective,
                        score_summary)
from .robust import RobustResult, robust_tune
from .space import (TUNED_FIELDS, BoxSpace, default_vector,
                    nominal_scenario_vector, params_to_vector, policy_space,
                    scenario_space, vector_to_params)
from .tuner import PolicyTuning, tune_policy

__all__ = [
    "adversarial", "cem", "es", "objective", "robust", "space", "tuner",
    "AttackResult", "attack_policy", "TuneResult", "cem_minimize",
    "es_minimize", "PolicyObjective", "ProfitObjective", "ScenarioObjective",
    "score_summary", "RobustResult", "robust_tune", "BoxSpace",
    "TUNED_FIELDS", "default_vector", "nominal_scenario_vector",
    "params_to_vector", "policy_space", "scenario_space",
    "vector_to_params", "PolicyTuning", "tune_policy",
]
