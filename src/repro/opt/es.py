"""A simple (1+λ) evolution strategy — the second gradient-free tuner.

Per generation the incumbent spawns ``pop_size`` Gaussian mutations in the
unit cube (the incumbent itself rides along as candidate 0, so it is
re-scored under the same compile and can never be silently lost); the best
candidate becomes the new incumbent if it improves, and the mutation scale
adapts by a 1/5th-success-style rule: grow on improvement, shrink on
stagnation.  Like ``cem_minimize`` the whole run is pure ``jax.random`` +
``lax.scan`` over generations — one jitted call, one compile of the
objective, bit-reproducible per key.

CEM refits a distribution to an elite set and moves in big, smooth steps;
the ES is a hill-climber with an adaptive step.  On the policy-tuning
objectives both land in the same basin; the ES is the cheaper choice when
the population must stay small, CEM the more robust one on multi-modal
scenario landscapes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..obs import ledger as ledger_lib
from .cem import STALL_GENS, OptTelemetry, TuneResult
from .space import BoxSpace

SIGMA_MIN = 0.01
SIGMA_MAX = 0.6
SIGMA_UP = 1.5
SIGMA_DOWN = 0.85


def es_minimize(f: Callable, space: BoxSpace, key: jax.Array,
                pop_size: int = 32, generations: int = 8,
                init: jnp.ndarray | None = None,
                init_sigma: float = 0.25,
                telemetry: bool = False) -> TuneResult:
    """Minimize ``f`` over ``space`` with a (1+λ) ES — traceable end to
    end; wrap in ``jax.jit`` for the one-compile path.  ``init`` seeds the
    incumbent (default: mid-box).  ``telemetry`` statically opts the
    per-generation probes / event ledger into the scan (see
    ``cem.OptTelemetry``); the minimization itself is bit-identical
    either way."""
    if pop_size < 2:
        raise ValueError(f"pop_size must be >= 2, got {pop_size}")
    if generations < 1:
        raise ValueError(f"generations must be >= 1, got {generations}")
    d = space.dim
    batch_f = jax.vmap(f)
    parent0 = (jnp.full((d,), 0.5, jnp.float32) if init is None
               else space.to_unit(init))

    def gen(carry, xs):
        if telemetry:
            (parent, parent_score, sigma, led, stall), (k, g) = carry, xs
        else:
            (parent, parent_score, sigma), k = carry, xs
        pop = parent + sigma * jax.random.normal(k, (pop_size, d))
        pop = jnp.clip(pop, 0.0, 1.0)
        # Candidate 0 is the incumbent: its score refreshes every
        # generation inside the same compile (first generation scores it
        # for the first time — parent_score starts at +inf).
        pop = pop.at[0].set(parent)
        scores = batch_f(space.from_unit(pop))
        i = jnp.argmin(scores)
        child, child_score = pop[i], scores[i]
        improved = child_score < parent_score
        parent = jnp.where(improved, child, parent)
        parent_score = jnp.minimum(parent_score, child_score)
        sigma = jnp.clip(jnp.where(improved, sigma * SIGMA_UP,
                                   sigma * SIGMA_DOWN),
                         SIGMA_MIN, SIGMA_MAX)
        if telemetry:
            led = ledger_lib.push(led, improved, g,
                                  ledger_lib.KIND_OPT_IMPROVE, child_score)
            stall = jnp.where(improved, 0, stall + 1)
            led = ledger_lib.push(led, stall == STALL_GENS, g,
                                  ledger_lib.KIND_OPT_STALL,
                                  stall.astype(jnp.float32))
            # The (1+λ) "elite" is the incumbent itself; sigma is scalar.
            return ((parent, parent_score, sigma, led, stall),
                    (child_score, jnp.mean(scores), parent_score,
                     jnp.std(scores), sigma))
        return ((parent, parent_score, sigma),
                (child_score, jnp.mean(scores)))

    carry0 = (parent0, jnp.asarray(jnp.inf, jnp.float32),
              jnp.asarray(init_sigma, jnp.float32))
    keys = jax.random.split(key, generations)
    if telemetry:
        carry0 = carry0 + (ledger_lib.init(2 * generations),
                           jnp.asarray(0, jnp.int32))
        final, ys = jax.lax.scan(gen, carry0,
                                 (keys, jnp.arange(generations)))
        parent, parent_score, _, led, stall = final
        tel = OptTelemetry(ledger=led, elite_mean=ys[2], score_std=ys[3],
                           sigma_mean=ys[4], stalled=stall)
        hist_best, hist_mean = ys[0], ys[1]
    else:
        (parent, parent_score, _), (hist_best, hist_mean) = jax.lax.scan(
            gen, carry0, keys)
        tel = None
    return TuneResult(best_vec=space.from_unit(parent),
                      best_score=parent_score,
                      final_mean=space.from_unit(parent),
                      history_best=hist_best, history_mean=hist_mean,
                      telemetry=tel)
