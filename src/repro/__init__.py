"""Dithen-JAX: CaaS control plane (Kalman + proportional fairness + AIMD,
IC2E'16) as the elastic runtime of a multi-pod JAX training/serving
framework."""

__version__ = "1.0.0"
