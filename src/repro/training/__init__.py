from . import optimizer
from .train_loop import TrainState, init_state, make_train_step

__all__ = ["optimizer", "TrainState", "init_state", "make_train_step"]
