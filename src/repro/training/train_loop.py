"""Training step factory: loss → grad → clip → AdamW, with optional
gradient accumulation (scan over microbatches) — the unit the dry-run
lowers and the elastic runtime drives.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from . import optimizer


class TrainState(NamedTuple):
    params: dict
    opt: optimizer.OptState


def init_state(model: Model, key, opt_cfg=None) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=optimizer.init(params))


def make_train_step(model: Model, opt_cfg: optimizer.OptConfig,
                    grad_accum: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    With grad_accum > 1 the global batch is split along axis 0 into
    microbatches consumed by a lax.scan (activation memory ∝ 1/grad_accum,
    gradients accumulated in f32).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch: dict):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            # Microbatches via dynamic_slice on the (data-sharded) batch
            # axis — a reshape would re-layout the sharded axis and insert
            # collectives.  Gradients accumulate in the param dtype.
            def micro(carry, i):
                acc, loss_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // grad_accum),
                        x.shape[0] // grad_accum, 0), batch)
                lval, g = jax.value_and_grad(loss_fn)(state.params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                   acc, g)
                return (acc, loss_acc + lval), None

            from ..models import sharding as sh
            zero = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zero, 0.0), jnp.arange(grad_accum),
                unroll=sh.scan_unroll())
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        params, opt, metrics = optimizer.update(
            grads, state.opt, state.params, opt_cfg)
        metrics["loss"] = loss
        return TrainState(params=params, opt=opt), metrics

    return train_step
