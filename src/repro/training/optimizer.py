"""AdamW with cosine schedule + global-norm clipping (pure pytree impl).

Optimizer state is sharded like the parameters (ZeRO-1 style sharding over
the data axis is applied by the launcher's sharding rules, not here).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def schedule(step, cfg: OptConfig):
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: OptState, params, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu), \
        {"grad_norm": gnorm, "lr": lr}
