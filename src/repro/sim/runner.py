"""Discrete-time cloud simulator reproducing the paper's §V testbed.

One `lax.scan` step = one monitoring instant:

  arrivals → spot-price step → wall-clock advance (boot/billing at the
  current price) → market preemption of outbid slots → task execution with
  the rates decided last instant → workload/SLA bookkeeping → controller
  step (predict, confirm, allocate, scale) → instance start/terminate
  (spot requests go unfulfilled while the fleet is outbid).

Everything is fixed-shape and jitted; a full 30-workload × 300-tick
experiment runs in milliseconds, so the benchmark suite sweeps predictors,
policies and monitoring intervals cheaply — and ``sim.sweep`` vmaps the
*whole* run over seeds × bid levels × bid policies × fleet mixes ×
workload scenarios in one call.  The schedule is a traced
``workloads.JaxSchedule`` pytree input (padded rows masked by ``valid``),
so ``sim.scenarios`` generators can hand every grid point its own sampled
workload world without recompiling.  With the spot market live, all
Table-V instance types evolve as one
correlated price system and the fleet may be mixed-granularity: each slot
is billed/preempted at its own type's price, and every acquisition picks
the cheapest-per-CU type currently available under the bid policy.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core import aimd as aimd_lib
from ..core import billing as billing_lib
from ..core import controller as ctrl
from ..core.types import (ClusterState, ControlParams, PolicyParams,
                          TenantConfig, WorkloadState, make_policy_params)
from ..obs import probes as obs_lib
from . import faults as faults_lib
from . import spot as spot_lib
from . import workloads as wl


@dataclasses.dataclass(frozen=True)
class SimConfig:
    ctrl: ctrl.ControllerConfig = ctrl.ControllerConfig()
    ticks: int = 400
    pool: int = 160               # instance slots (> N_max)
    # CUS accounting is *occupancy* (download + compute), as in the paper:
    # the per-item b_true already includes the non-compute share, so a
    # granted CU-second is consumed one-for-one.
    efficiency: float = 1.0
    exec_noise: float = 0.08      # window-level execution-time noise
    seed: int = 0
    # Appendix-A spot market; disabled by default (static list price,
    # nothing is ever preempted) so the paper's §V experiments are
    # untouched.  Enable to bill at the live spot price and lose slots
    # whose bid the market clears above.
    spot: spot_lib.SpotConfig = spot_lib.SpotConfig()
    # Multi-tenant shared fleet (``sim.tenants``): the schedule's workload
    # axis becomes ``n`` concatenated per-tenant blocks of ``max_w`` rows,
    # the allocator arbitrates hierarchically across tenants, arrivals pass
    # an admission gate, and billing is attributed per tenant in the scan
    # carry.  None (default) is the single-owner path, byte-identical to
    # every pre-tenant simulation.
    tenants: TenantConfig | None = None
    # Chaos engine (``sim.faults``): outages, storms, slot hard-kills,
    # telemetry dropouts/delays, stragglers, driven by a traced
    # ``FaultSpec`` input.  None (default) compiles the exact fault-free
    # step — zero-fault runs stay bit-identical to every pre-chaos
    # baseline.  ``FaultConfig(hardened=False)`` suffers the same faults
    # with the graceful-degradation responses switched off.
    faults: "faults_lib.FaultConfig | None" = None
    # Observability (``repro.obs``): in-scan metric probes, the decision
    # ledger, per-family counters/gauges/histograms accumulated in the
    # scan carry.  Static (hashable, part of every jit cache key, probes
    # selected per family).  None (default) compiles the exact probe-free
    # step — runs stay bit-identical to every committed baseline, the
    # same contract as ``faults=None``.
    obs: "obs_lib.ObsSpec | None" = None

    @property
    def dt(self) -> float:
        return self.ctrl.params.monitor_dt


def default_params(cfg: SimConfig) -> PolicyParams:
    """The config's hand-set policy coefficients as a ``PolicyParams``
    pytree — what every run uses when no tuner supplies candidates.
    ``bid_mult`` is the *relative* multiplier (1.0 = keep the configured /
    swept bid multiple untouched)."""
    return make_policy_params(alpha=cfg.ctrl.params.alpha,
                              beta=cfg.ctrl.params.beta,
                              bid_mult=1.0,
                              ttc_gain=cfg.spot.ttc_gain,
                              ema_alpha=cfg.spot.ema_alpha)


# The tuned-leaf defaults strip_tuned resets cache keys to.
_PARAMS0 = ControlParams()
_SPOT0 = spot_lib.SpotConfig()


def strip_tuned(cfg: SimConfig) -> SimConfig:
    """``cfg`` with the ``PolicyParams``-traced leaves struck out.

    Compilation caches key on this: the tuned coefficients (AIMD α/β, TTC
    escalation gain, EMA weight) flow through the compiled scan as traced
    inputs, so two configs that differ only there must share one compile —
    which is what lets a tuner population evaluate under one ``vmap``
    without retracing.  ``SpotConfig.bid_mult`` stays in the key: like
    ``instance``/``fleet`` it seeds the *static* runtime construction, and
    the traced counterpart is the relative ``PolicyParams.bid_mult``
    (applied on top of the runtime/axis multiple inside the scan).
    """
    params = dataclasses.replace(cfg.ctrl.params, alpha=_PARAMS0.alpha,
                                 beta=_PARAMS0.beta)
    spot = dataclasses.replace(cfg.spot, ttc_gain=_SPOT0.ttc_gain,
                               ema_alpha=_SPOT0.ema_alpha)
    return dataclasses.replace(cfg, ctrl=dataclasses.replace(
        cfg.ctrl, params=params), spot=spot)


class SummaryCarry(NamedTuple):
    """Per-run summary registers, accumulated *inside* the scan carry.

    These are the scalars ``sim.sweep.summarize`` reads out, maintained
    online so a sweep never has to materialize the O(T·W·K) per-tick trace:
    a B-point grid moves O(B) floats instead of O(B·T·W·K).  ``cum_cost``
    and ``n_preempt`` already live in ``ClusterState``; everything else the
    old trace-mode summary recomputed from ``ys`` is registered here.
    """

    max_committed: jnp.ndarray  # () running max of control-plane CUs
    price_sum: jnp.ndarray      # () Σ_t spot price of the primary type
    price_max: jnp.ndarray      # () running max of that price
    cost_at_done: jnp.ndarray   # () cum_cost registered on the tick *after*
                                #    any completion — the latest write is
                                #    exactly ``cum_cost[t_end + 1]`` of the
                                #    trace
    fire: jnp.ndarray           # () bool: a completion happened this tick,
                                #    so next tick's cum_cost is a completion
                                #    endpoint (cheap re-use of the step's
                                #    own ``done_now`` predicate instead of a
                                #    per-tick W-wide max over ``t_done``)
    # Per-tenant attribution registers (``SimConfig.tenants``); None in
    # single-owner mode, so the carry — and the compiled scan — of every
    # existing run is untouched.
    tenant: "TenantCarry | None" = None


class TenantCarry(NamedTuple):
    """Per-tenant billing-attribution registers (O(N) per run).

    Costs are integers in ``_COST_UNIT``-ths of a dollar so the conservation
    invariant — per-tick attributed cost sums *exactly* to the fleet's
    billed cost — holds in integer arithmetic, immune to float rounding.
    """

    cost_u: jnp.ndarray   # (N,) int32 attributed cost, units of 1/_COST_UNIT $
    service: jnp.ndarray  # (N,) f32 delivered CU-seconds
    q_prev: jnp.ndarray   # ()  int32 fleet cum_cost already attributed, units


# Attribution cost quantum: 0.1 milli-dollar.  f32 dollars convert to exact
# int32 units up to ~$200k cumulative — far beyond any simulated bill.
_COST_UNIT = 1e4


def summary_init(n_tenants: int | None = None) -> SummaryCarry:
    z = jnp.asarray(0.0, jnp.float32)
    tenant = None
    if n_tenants is not None:
        tenant = TenantCarry(
            cost_u=jnp.zeros((n_tenants,), jnp.int32),
            service=jnp.zeros((n_tenants,), jnp.float32),
            q_prev=jnp.asarray(0, jnp.int32))
    return SummaryCarry(max_committed=z, price_sum=z, price_max=z,
                        cost_at_done=z, fire=jnp.asarray(False),
                        tenant=tenant)


def _attribute(tc: TenantCarry, cum_cost: jnp.ndarray,
               exec_time: jnp.ndarray, valid: jnp.ndarray,
               tid: jnp.ndarray, base_w: jnp.ndarray,
               n: int) -> TenantCarry:
    """One tick of exact cost attribution (tentpole billing invariant).

    The tick's newly billed fleet cost — quantized to ``_COST_UNIT`` integer
    units — is split across tenants in proportion to delivered service
    (CU-seconds executed this tick).  On idle ticks (no service anywhere:
    warm-up, drain-out) the cost is shared base-fleet overhead, split by
    contracted weight over tenants that have any valid workload rows — so a
    tenant whose rows are all padding can never be billed.  Integer units
    are apportioned by largest-remainder rounding, which sums to the tick's
    delta exactly: Σ_i cost_u_i telescopes to the quantized fleet bill.
    """
    serv = jax.ops.segment_sum(jnp.sum(exec_time, -1), tid, num_segments=n)
    tot = jnp.sum(serv)
    elig = jax.ops.segment_sum(valid.astype(jnp.float32), tid,
                               num_segments=n) > 0.0
    w_fall = base_w * elig
    w_tot = jnp.sum(w_fall)
    fallback = jnp.where(w_tot > 0.0, w_fall / jnp.maximum(w_tot, 1e-9),
                         1.0 / n)
    share = jnp.where(tot > 0.0, serv / jnp.maximum(tot, 1e-9), fallback)

    q_now = jnp.round(cum_cost * _COST_UNIT).astype(jnp.int32)
    delta_q = q_now - tc.q_prev
    raw = delta_q.astype(jnp.float32) * share
    base = jnp.floor(raw).astype(jnp.int32)
    rem = delta_q - jnp.sum(base)
    # rem = q·n + r with 0 ≤ r < n: every tenant absorbs q units and the
    # r leftover units go to the largest fractional shares — exact for any
    # rem, including the (f32 round-up) case where Σ base overshoots.
    frac = raw - base.astype(jnp.float32)
    order = jnp.argsort(-frac)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    adj = (rem // n) + (rank < (rem % n)).astype(jnp.int32)
    return TenantCarry(cost_u=tc.cost_u + base + adj,
                       service=tc.service + serv,
                       q_prev=q_now)


class SimState(NamedTuple):
    c: ctrl.ControllerState
    work: WorkloadState
    cluster: ClusterState
    s: jnp.ndarray          # (W,) service rates decided last instant
    done_acc: jnp.ndarray   # (W,) cumulative (fractional) completions
    key: jax.Array
    t: jnp.ndarray          # () tick counter
    spot: spot_lib.SpotState
    summ: SummaryCarry
    # Chaos-engine registers; None whenever ``SimConfig.faults`` is None,
    # so the carry — and the compiled scan — of a fault-free run is
    # untouched.
    faults: "faults_lib.FaultState | None" = None
    # Observability registers (``repro.obs``); None whenever
    # ``SimConfig.obs`` is None — the same leafless-carry contract.
    obs: "obs_lib.ObsCarry | None" = None


class SimTrace(NamedTuple):
    cum_cost: jnp.ndarray    # (T,)
    n_usable: jnp.ndarray    # (T,)
    n_committed: jnp.ndarray # (T,)
    n_star: jnp.ndarray      # (T,)
    n_target: jnp.ndarray    # (T,)
    util: jnp.ndarray        # (T,) fleet CPU utilization
    b_hat: jnp.ndarray       # (T, W, K)
    b_meas: jnp.ndarray      # (T, W, K)
    reliable: jnp.ndarray    # (T, W, K)
    confirmed: jnp.ndarray   # (T, W)
    active: jnp.ndarray      # (T, W)
    remaining: jnp.ndarray   # (T, W)  Σ_k m
    spot_price: jnp.ndarray  # (T,)  $/quantum of the primary instance type
    spot_bid: jnp.ndarray    # (T,)  $/quantum new requests bid this tick
                             #       (primary type; +inf off the market)
    n_preempted: jnp.ndarray # (T,)  cumulative instances lost to the market
    t_done: jnp.ndarray      # (W,)  completion tick (final)
    work_final: WorkloadState
    violations: jnp.ndarray  # ()  TTC violations (final)


def _execute(work: WorkloadState, sched: wl.JaxSchedule, s: jnp.ndarray,
             cluster: ClusterState, done_acc: jnp.ndarray,
             cfg: SimConfig, key: jax.Array, cores):
    """Consume CUS on the fleet for one interval; emit measurements."""
    dt = cfg.dt
    n_act = billing_lib.capacity(cluster, cores)  # paid CUs incl. draining
    # Grants beyond physical capacity are scaled back proportionally.
    want = jnp.sum(s)
    cap = n_act * 1.0
    scale = jnp.where(want > cap, cap / jnp.maximum(want, 1e-9), 1.0)
    granted = s * scale * dt * cfg.efficiency           # CUS per workload

    m = work.m[:, 0]
    m0 = jnp.maximum(work.m0[:, 0], 1.0)
    p = 1.0 - m / m0                                     # completed fraction
    bias = wl.ramp(p, sched.c0, sched.p_r, sched.overshoot)
    k_exec, k_meas = jax.random.split(key)
    noise = jnp.exp(cfg.exec_noise * jax.random.normal(k_exec, m.shape))
    b_exec = work.b_true[:, 0] * bias * noise            # cost of *current* items

    possible = granted / jnp.maximum(b_exec, 1e-9)
    items_done = jnp.minimum(m, possible)
    items_done = jnp.where(work.active, items_done, 0.0)
    exec_time = items_done * b_exec

    # Window measurement: mean CUS of completed tasks.  Tasks are atomic —
    # a measurement only exists once at least one task *finished* in the
    # window, i.e. when the cumulative completion count crosses an integer.
    # Item costs are heavy-tailed (video lengths, image sizes), so the
    # window average concentrates far slower than 1/sqrt(n): we cap the
    # averaging benefit at 4 effective samples.
    done_acc_new = done_acc + items_done
    meas_mask = jnp.floor(done_acc_new) > jnp.floor(done_acc)
    meas_sigma = sched.sigma / jnp.sqrt(jnp.clip(items_done, 1.0, 4.0))
    b_meas = b_exec * jnp.exp(meas_sigma * jax.random.normal(k_meas, m.shape))

    new_m = jnp.maximum(m - items_done, 0.0)
    # Utilization: executed CUS over paid capacity this window.
    util = jnp.sum(exec_time) / jnp.maximum(n_act * dt, 1e-9)
    return (new_m[:, None], b_meas[:, None], meas_mask[:, None],
            exec_time[:, None], items_done[:, None], util, done_acc_new)


def make_step(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
              trace: bool = True,
              params: PolicyParams | None = None,
              fspec: "faults_lib.FaultSpec | None" = None) -> Callable:
    """One monitoring instant as a ``lax.scan`` step.

    ``schedule`` may be a *traced* ``JaxSchedule`` pytree — the simulator no
    longer closes over static numpy arrays, so one compiled scan serves
    every schedule of the same shape and ``sim.sweep`` can feed a different
    generated scenario to every grid point.  Padded rows (``valid=False``)
    never arrive, so they execute nothing, bill nothing and violate nothing.

    ``params`` are the tunable policy coefficients, likewise a (possibly
    traced) pytree input rather than trace-time constants: AIMD gains reach
    ``controller.step``, the TTC-escalation gain scales the urgency signal,
    and the EMA weight reaches ``spot.step`` — so ``repro.opt`` evaluates a
    whole candidate population through one compile.  ``None`` means the
    config's own values (``default_params``).

    ``trace=True`` emits the full per-tick ``ys`` dict (six (T,) series plus
    three (T, W, K) arrays once stacked) — what ``run`` and the plotting
    helpers consume.  ``trace=False`` emits nothing: the summary statistics
    accumulate in ``SimState.summ`` and the scan is ``ys``-free, which is
    what lets ``sim.sweep`` batch 10⁴–10⁵-point grids without streaming
    O(B·T·W·K) floats through memory.

    ``fspec`` carries the traced fault intensities when the config enables
    the chaos engine (``cfg.faults``); it defaults to the fault-free spec.
    Every fault branch below is a *trace-time* conditional on
    ``cfg.faults``, so a ``faults=None`` config compiles a step
    structurally identical to the pre-chaos simulator.
    """
    sched = wl.as_jax_schedule(schedule)
    use_spot = cfg.spot.enabled
    pp = default_params(cfg) if params is None else params
    tcfg = cfg.tenants
    fcfg = cfg.faults
    ocfg = cfg.obs
    hardened = fcfg is not None and fcfg.hardened
    if fcfg is not None and fspec is None:
        fspec = faults_lib.make_fault_spec()
    if tcfg is not None:
        w_rows = sched.t_arrive.shape[0]
        if w_rows != tcfg.w_total:
            raise ValueError(
                f"schedule has {w_rows} workload rows but TenantConfig "
                f"(n={tcfg.n}, max_w={tcfg.max_w}) expects {tcfg.w_total} — "
                "build the schedule with sim.tenants")
        tid = tcfg.tenant_ids()
        base_w = tcfg.weight_vec()

    def step(state: SimState, _):
        t = state.t
        key, k_exec = jax.random.split(state.key)
        # Observability signal slots — assigned below where the matching
        # plane exists under this config (tenant gate, spot market, chaos
        # engine), None otherwise.  All trace-time.
        obs_rej = obs_pre = obs_kill = None

        # --- arrivals ------------------------------------------------------
        arrive = (sched.t_arrive == t) & sched.valid
        n_shed_now = 0.0
        if hardened:
            # Deadline-aware shedding: during a sustained outage (the
            # acquisition fail-streak from last tick), refuse arrivals whose
            # requested deadline is tighter than ``shed_slack`` monitoring
            # intervals per streak tick — the platform cannot finish them
            # and admitting them would only convert them into violations.
            streak_prev = state.faults.fail_streak
            tight = sched.d_requested < fcfg.shed_slack * streak_prev * cfg.dt
            shed = (streak_prev >= fcfg.shed_after) & tight
            n_shed_now = jnp.sum((arrive & shed).astype(jnp.float32))
            arrive = arrive & ~shed
        if tcfg is not None:
            # Admission gate: a tenant already occupying ≥ adm_frac of its
            # row budget has new arrivals rejected outright (they never
            # submit, so they neither execute nor count as violations).
            # The default adm_frac = 1.0 admits everything: an arriving row
            # is itself inactive, so occupancy is at most max_w - 1.
            occ = jax.ops.segment_sum(state.work.active.astype(jnp.float32),
                                      tid, num_segments=tcfg.n)
            admit = occ < pp.adm_frac * tcfg.max_w
            # Budget cap: a tenant whose attributed bill has reached its
            # contracted cap stops admitting work (default: uncapped).
            spent = state.summ.tenant.cost_u.astype(jnp.float32) / _COST_UNIT
            admit = admit & (spent < tcfg.budget_vec())
            if ocfg is not None and (ocfg.fairshare or ocfg.ledger > 0):
                # Rejected arrivals per tenant, read off the gate before it
                # filters them (observability: fairshare family + ledger).
                obs_rej = jax.ops.segment_sum(
                    (arrive & ~admit[tid]).astype(jnp.float32), tid,
                    num_segments=tcfg.n)
            arrive = arrive & admit[tid]
        work = state.work._replace(
            active=state.work.active | arrive,
            m=jnp.where(arrive[:, None], sched.m0, state.work.m),
            d=jnp.where(arrive, sched.d_requested, state.work.d),
            t_submit=jnp.where(arrive, t, state.work.t_submit),
        )
        c_state = ctrl.reset_rows(state.c, arrive)

        # --- spot market: new clearing prices for [t, t+1) ------------------
        # All Table-V types advance together (correlated log-AR(1)); slots
        # are billed and preempted at *their own type's* price, so one run
        # can hold a mixed-granularity fleet.
        cluster = state.cluster
        if use_spot:
            spot_state = spot_lib.step(state.spot, cfg.spot, cfg.dt,
                                       ema_alpha=pp.ema_alpha)
            slot_price = spot_state.prices[cluster.itype]   # (I,)
            cores = spot_lib.CORES_TABLE[cluster.itype]     # (I,) CUs/slot
        else:
            spot_state = state.spot
            slot_price = None
            cores = 1.0

        # --- chaos engine: this tick's fault draws --------------------------
        # One call on a dedicated PRNG chain, so enabling faults never
        # perturbs the workload, market or execution-noise streams.
        if fcfg is not None:
            ftick, fstate = faults_lib.tick(state.faults, fspec, cfg.dt, t)
            # Stragglers: the slot stays billed at full price but delivers a
            # fraction of its nominal CU capacity while the episode lasts.
            exec_cores = cores * ftick.slow
        else:
            exec_cores = cores

        # --- market preemption: outbid slots are taken the instant the new
        # price clears above their bid — *before* billing advances, so a
        # reclaimed slot never renews a quantum at the very price that
        # killed it ---------------------------------------------------------
        if use_spot:
            if ocfg is not None and ocfg.want_preempt:
                # Per-type preemption counts: the same hit mask
                # ``billing.preempt`` is about to apply (phase >= BOOTING
                # and the clearing price strictly above the slot's bid),
                # bucketed by instance type before the phases are wiped.
                pb = jnp.broadcast_to(
                    jnp.asarray(slot_price, jnp.float32), cluster.bid.shape)
                p_hit = (cluster.phase >= billing_lib.BOOTING) & (
                    pb > cluster.bid)
                obs_pre = jax.ops.segment_sum(
                    p_hit.astype(jnp.float32), cluster.itype,
                    num_segments=spot_lib.N_TYPES)
            cluster, _ = billing_lib.preempt(cluster, slot_price)
        # --- wall clock: boots complete, billing quanta renew ---------------
        cluster = billing_lib.advance(cluster, cfg.dt, cfg.ctrl.billing,
                                      price=slot_price)

        # --- execute with last instant's rates ------------------------------
        (new_m, b_meas, meas_mask, exec_time, items_done, util,
         done_acc) = _execute(
            work, sched, state.s, cluster, state.done_acc, cfg, k_exec,
            exec_cores)
        if fcfg is not None:
            # Slot hard-kills (storms + Poisson failures) land mid-window:
            # the killed slots were billed at the last quantum renewal and
            # burned capacity this window — exactly mid-quantum preemption
            # billing — but their in-flight work is lost.  The lost items
            # re-enter the queue exactly once: the rollback is capped at
            # this window's completions by construction (lost ≤ items_done).
            act = cluster.phase == billing_lib.ACTIVE
            slot_cu = act.astype(jnp.float32) * exec_cores
            tot_cu = jnp.sum(slot_cu)
            lost_cu = jnp.sum(jnp.where(ftick.kill, slot_cu, 0.0))
            lost_frac = jnp.where(tot_cu > 0.0,
                                  lost_cu / jnp.maximum(tot_cu, 1e-9), 0.0)
            lost = items_done * lost_frac
            new_m = new_m + lost
            done_acc = done_acc - jnp.sum(lost, -1)
            if ocfg is not None and ocfg.want_preempt:
                # Chaos hard-kills per type, mirroring kill_slots' hit mask.
                k_hit = (cluster.phase >= billing_lib.BOOTING) & ftick.kill
                obs_kill = jax.ops.segment_sum(
                    k_hit.astype(jnp.float32), cluster.itype,
                    num_segments=spot_lib.N_TYPES)
            cluster, n_hit = faults_lib.kill_slots(cluster, ftick.kill)
            fstate = fstate._replace(n_killed=fstate.n_killed + n_hit)
        done_acc = jnp.where(arrive, 0.0, done_acc)
        work = work._replace(m=new_m)
        busy = jnp.where(cluster.phase == billing_lib.ACTIVE, util, 0.0)
        cluster = cluster._replace(busy_frac=busy)

        # --- completions + SLA clock ----------------------------------------
        done_now = work.active & (jnp.sum(work.m, -1) <= 0.0)
        work = work._replace(
            active=work.active & ~done_now,
            t_done=jnp.where(done_now, t, work.t_done),
            d=jnp.where(work.active & ~done_now,
                        work.d - cfg.dt, work.d),
        )

        # --- telemetry faults: dropouts lose fresh measurements, delays hold
        # them one instant and deliver them stale (eq. 8's lagged form makes
        # a one-tick-stale value well-formed) ---------------------------------
        meas_dropped = None
        if fcfg is not None:
            b_meas, meas_mask, dropped, fstate = faults_lib.filter_telemetry(
                fstate, ftick, fspec, b_meas, meas_mask, arrive)
            if hardened:
                meas_dropped = dropped

        # --- control --------------------------------------------------------
        c_state, work, dec = ctrl.step(
            c_state, work, cluster, b_meas, meas_mask, exec_time, items_done,
            cfg.ctrl, cores=cores, pp=pp,
            tenants=(None if tcfg is None else (tid, tcfg.n, base_w)),
            meas_dropped=meas_dropped, obs=ocfg)
        if use_spot:
            rt = spot_state.rt
            # Dynamic bid policy: the TTC-aware signal is how far the most
            # behind-schedule active workload has fallen — time fraction of
            # its deadline used minus work fraction done.  On-track runs
            # keep the cheap floor bid; runs knocked behind (preemptions,
            # outages) escalate toward the on-demand cap.
            frac_time = 1.0 - work.d / jnp.maximum(work.d_requested, 1e-9)
            frac_done = 1.0 - (jnp.sum(work.m, -1)
                               / jnp.maximum(jnp.sum(work.m0, -1), 1e-9))
            behind = jnp.where(work.active, frac_time - frac_done, -jnp.inf)
            urgency = jnp.clip(pp.ttc_gain * jnp.max(behind), 0.0, 1.0)
            bids = spot_lib.current_bids(cfg.spot, rt, spot_state, urgency)
            # Acquisitions pick the cheapest-per-CU currently-available
            # type of the fleet mix; requests are only fulfilled while the
            # market clears at or below our bid for that type.  Under the
            # chaos engine a dried-up type has no capacity at any bid: the
            # hardened controller hedges by selecting around it, the
            # unhardened one picks blind and simply fails to start.
            if fcfg is None:
                itype_new, can_start = spot_lib.select_type(
                    spot_state.prices, bids, rt.mix)
            elif hardened:
                itype_new, can_start = spot_lib.select_type(
                    spot_state.prices, bids, rt.mix, avail=ftick.avail)
            else:
                itype_new, can_start = spot_lib.select_type(
                    spot_state.prices, bids, rt.mix)
                can_start = can_start & ftick.avail[itype_new]
            allow = can_start
            if hardened:
                # Bounded-backoff gate: after repeated failed acquisitions
                # the controller waits out a jittered exponential delay
                # before retrying instead of hammering the market.
                trying = state.faults.backoff_left <= 0.0
                allow = can_start & trying
            scale_cores = jnp.where(cluster.phase == billing_lib.OFF,
                                    spot_lib.CORES_TABLE[itype_new], cores)
            cluster = billing_lib.scale_to(
                cluster, dec.n_target, cfg.ctrl.billing,
                price=spot_state.prices[itype_new], bid=bids[itype_new],
                itype=itype_new, allow_start=allow, cores=scale_cores)
        else:
            cluster = billing_lib.scale_to(cluster, dec.n_target,
                                           cfg.ctrl.billing)

        # Slots started this tick carry their new type; refresh the CU
        # weights before reporting control-plane sizes.
        out_cores = (spot_lib.CORES_TABLE[cluster.itype] if use_spot
                     else cores)
        n_committed = billing_lib.committed(cluster, out_cores)
        if fcfg is not None and use_spot:
            # Fail-streak / backoff bookkeeping.  The streak counts
            # *consecutive ticks of unmet demand* — the controller wants to
            # grow the committed fleet and the market (outbid or dried up)
            # cannot fulfil it — independent of whether the backoff gate let
            # this tick's request out.  Counting ticks rather than attempts
            # matters: the shed gate and the anti-windup clamp key on the
            # streak as an outage-duration signal, and a streak that only
            # grew on try-ticks would let the backoff suppress its own
            # outage detector.
            fs_prev = state.faults
            want_grow = dec.n_target > n_committed + 0.5
            unmet = want_grow & ~can_start
            streak = jnp.where(unmet, fs_prev.fail_streak + 1.0, 0.0)
            if hardened:
                tried = fs_prev.backoff_left <= 0.0
                delay = aimd_lib.backoff_delay(streak, fcfg.backoff_cap,
                                               ftick.jitter_u)
                # A new delay starts only when a request actually went out
                # and failed; the moment the market observably clears
                # (``can_start`` — published prices and availability are
                # free to read) the residual wait is void, so recovery is
                # never stalled by a backoff scheduled during the outage.
                backoff_left = jnp.where(
                    unmet & tried, delay,
                    jnp.where(can_start, 0.0,
                              jnp.maximum(fs_prev.backoff_left - 1.0, 0.0)))
                # Anti-windup: while acquisition keeps failing, hold the
                # stored AIMD target within one additive step of what is
                # actually committed, so recovery ramps at the normal AIMD
                # pace instead of thundering-herd to the windup peak.
                c_state = c_state._replace(aimd=aimd_lib.anti_windup(
                    c_state.aimd, n_committed + pp.alpha, streak > 0.0))
            else:
                backoff_left = fs_prev.backoff_left
            fstate = fstate._replace(
                fail_streak=streak, backoff_left=backoff_left,
                n_shed=fstate.n_shed + n_shed_now)
        elif fcfg is not None:
            fstate = fstate._replace(n_shed=fstate.n_shed + n_shed_now)
        else:
            fstate = None
        spot_price = (spot_state.price if use_spot
                      else jnp.asarray(cfg.ctrl.billing.price_per_quantum,
                                       jnp.float32))

        # Summary registers (see SummaryCarry).  The cost register fires on
        # the tick *after* a completion — the trace index
        # ``cost_at_completion`` reads — and is overwritten whenever a later
        # completion moves that endpoint, so its final value is
        # ``cum_cost[max(t_done) + 1]``.  The fire flag re-uses this tick's
        # ``done_now`` instead of re-deriving the endpoint from a W-wide
        # ``max(t_done)`` every tick (summary-mode hot-loop cost).
        summ = SummaryCarry(
            max_committed=jnp.maximum(state.summ.max_committed, n_committed),
            price_sum=state.summ.price_sum + spot_price,
            price_max=jnp.maximum(state.summ.price_max, spot_price),
            cost_at_done=jnp.where(state.summ.fire, cluster.cum_cost,
                                   state.summ.cost_at_done),
            fire=jnp.any(done_now),
            tenant=(None if tcfg is None else _attribute(
                state.summ.tenant, cluster.cum_cost, exec_time, sched.valid,
                tid, base_w, tcfg.n)),
        )

        # --- observability: accumulate this tick's probe registers ----------
        # Strictly read-only — every signal is a value computed above, no
        # PRNG is consumed, nothing flows back into the simulation, so an
        # obs=None config compiles this block away entirely.
        obs_c = state.obs
        if ocfg is not None:
            pr = dec.probe
            obs_viol = obs_cost = None
            if ocfg.detect is not None:
                # Detector inputs, still read-only: TTC violations judged
                # at completion time (the same lateness rule as
                # ``violation_rows``; never-finished work is only judged
                # at the horizon) and this tick's billed spend.
                ticks_allowed = jnp.ceil(sched.d_requested / cfg.dt)
                late = (t - work.t_submit) - ticks_allowed
                obs_viol = jnp.sum(
                    (done_now & sched.valid & (late > 1))
                    .astype(jnp.float32))
                obs_cost = cluster.cum_cost - state.cluster.cum_cost
            sig = obs_lib.TickSignals(
                aimd_incr=pr.aimd_incr,
                water_scale=pr.water_scale,
                kalman=pr.kalman,
                n_target=dec.n_target,
                preempt_by_type=obs_pre,
                kill_by_type=obs_kill,
                adm_rejects=obs_rej,
                queue_depth=jnp.sum(work.active.astype(jnp.float32)),
                fail_streak=(fstate.fail_streak
                             if (fcfg is not None and use_spot) else None),
                n_shed=(n_shed_now if hardened else None),
                spot_price=spot_price,
                viol_now=obs_viol,
                n_committed=n_committed,
                n_unavail=(jnp.sum((~ftick.avail).astype(jnp.float32))
                           if fcfg is not None else None),
                cost_delta=obs_cost)
            obs_c = obs_lib.update(state.obs, ocfg, t, sig,
                                   q_cap=sched.t_arrive.shape[0])

        new_state = SimState(c=c_state, work=work, cluster=cluster, s=dec.s,
                             done_acc=done_acc, key=key, t=t + 1,
                             spot=spot_state, summ=summ, faults=fstate,
                             obs=obs_c)
        if not trace:
            return new_state, None
        out = dict(
            cum_cost=cluster.cum_cost,
            n_usable=billing_lib.usable(cluster, out_cores),
            n_committed=n_committed,
            n_star=dec.n_star,
            n_target=dec.n_target,
            util=util,
            b_hat=dec.b_hat,
            b_meas=b_meas,
            reliable=dec.reliable,
            confirmed=work.confirmed,
            active=work.active,
            remaining=jnp.sum(work.m, -1),
            spot_price=spot_price,
            spot_bid=(bids[spot_state.rt.itype] if use_spot
                      else jnp.asarray(jnp.inf, jnp.float32)),
            n_preempted=cluster.n_preempt,
        )
        return new_state, out

    return step


def init_state(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
               seed: jnp.ndarray | int | None = None,
               spot_rt: spot_lib.SpotRuntime | None = None) -> SimState:
    """Build the t=0 state.  ``seed``, ``spot_rt`` and the schedule itself
    may be traced values — the axes ``sim.sweep`` vmaps the whole
    simulation over."""
    if seed is None:
        seed = cfg.seed
    sched = wl.as_jax_schedule(schedule)
    w, k = sched.m0.shape
    work = WorkloadState(
        active=jnp.zeros((w,), bool),
        m=jnp.zeros((w, k)),
        m0=sched.m0,
        b_true=sched.b_true,
        d=sched.d_requested,
        d_requested=sched.d_requested,
        confirmed=jnp.zeros((w,), bool),
        t_submit=jnp.full((w,), -1),
        t_done=jnp.full((w,), -1),
    )
    if spot_rt is None:
        spot_rt = spot_lib.make_runtime(cfg.spot)
    # The market gets its own PRNG chain so enabling it never perturbs the
    # execution-noise stream of the workload simulator.
    spot_state = spot_lib.init(
        spot_rt, jax.random.PRNGKey(jnp.asarray(seed) + 7919))

    cluster = billing_lib.init(cfg.pool)
    # The platform idles at N_min pre-warmed CUs (paper: N_min = 10).
    if cfg.spot.enabled:
        # Baseline market (prices = Table-V base, EMA = base, no urgency):
        # acquire the cheapest-per-CU type of the fleet mix.
        bids0 = spot_lib.current_bids(cfg.spot, spot_rt, spot_state, 0.0)
        itype0, can0 = spot_lib.select_type(spot_state.prices, bids0,
                                            spot_rt.mix)
        cluster = billing_lib.scale_to(
            cluster, jnp.asarray(cfg.ctrl.params.n_min), cfg.ctrl.billing,
            price=spot_state.prices[itype0], bid=bids0[itype0],
            itype=itype0, allow_start=can0,
            cores=spot_lib.CORES_TABLE[itype0])
    else:
        cluster = billing_lib.scale_to(
            cluster, jnp.asarray(cfg.ctrl.params.n_min), cfg.ctrl.billing)
    cluster = cluster._replace(
        boot_left=jnp.zeros_like(cluster.boot_left),
        phase=jnp.where(cluster.phase > 0, jnp.int8(billing_lib.ACTIVE),
                        cluster.phase))
    return SimState(
        c=ctrl.init(w, k, cfg.ctrl),
        work=work,
        cluster=cluster,
        s=jnp.zeros((w,)),
        done_acc=jnp.zeros((w,)),
        key=jax.random.PRNGKey(seed),
        t=jnp.asarray(0),
        spot=spot_state,
        summ=summary_init(None if cfg.tenants is None else cfg.tenants.n),
        # Measurement telemetry is (W, 1)-shaped (see ``_execute``), so the
        # pending-delivery registers match that, not the schedule's K.
        faults=(None if cfg.faults is None else faults_lib.init_state(
            seed, spot_lib.N_TYPES, w, 1, cfg.pool)),
        obs=(None if cfg.obs is None else obs_lib.init_carry(
            cfg.obs, w=w, k=sched.m0.shape[1], n_types=spot_lib.N_TYPES,
            n_tenants=(1 if cfg.tenants is None else cfg.tenants.n))),
    )


def scan_run(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
             seed: jnp.ndarray | int | None = None,
             spot_rt: spot_lib.SpotRuntime | None = None,
             trace: bool = True,
             params: PolicyParams | None = None,
             fspec: "faults_lib.FaultSpec | None" = None):
    """The raw jittable simulation: (final state, per-tick outputs).

    No ``jax.jit`` inside — callers decide the compilation boundary, which
    lets ``sim.sweep`` vmap this whole function over batched seeds, bids,
    granularities, schedules *and policy parameters* in a single compile.
    ``params`` (default: the config's values) carries the tunable policy
    coefficients as a traced pytree; its relative ``bid_mult`` scales the
    runtime's bid multiple here, so a tuner candidate bids
    ``params.bid_mult ×`` whatever the config/axis set.  With
    ``trace=False`` the scan emits no per-tick outputs (``ys`` is None):
    the run summary lives in the final state's ``summ`` carry — the
    memory-lean mode sweeps use.
    """
    sched = wl.as_jax_schedule(schedule)
    pp = default_params(cfg) if params is None else params
    if spot_rt is None:
        spot_rt = spot_lib.make_runtime(cfg.spot)
    # ``rt.bid`` (the informational static bid) is left untouched: nothing
    # in the simulation reads it — live bidding goes through current_bids,
    # which uses ``bid_mult``.
    spot_rt = spot_rt._replace(bid_mult=spot_rt.bid_mult * pp.bid_mult)
    step = make_step(sched, cfg, trace=trace, params=pp, fspec=fspec)
    state = init_state(sched, cfg, seed=seed, spot_rt=spot_rt)
    # Summary mode keeps no per-tick outputs, so unrolling pairs of steps
    # costs no memory and buys back the loop overhead that otherwise
    # leaves the register-carry scan slower than the traced one.
    unroll = 1 if trace else 2
    return jax.lax.scan(step, state, None, length=cfg.ticks, unroll=unroll)


# --------------------------------------------------------------------------
# Cached compilation: ``run``/``run_single`` used to build and jit a fresh
# closure per call, recompiling the whole simulation across repeated
# benchmark invocations.  The entry points below key one compiled callable
# on (schedule *shape*, static config, trace mode, runtime structure): the
# schedule itself is a traced input, so every schedule — and every
# generated scenario — of one shape shares a single compile.

_JIT_CACHE: dict = {}
_JIT_CACHE_MAX = 128


def _cache_put(key, fn) -> None:
    """Insert with LRU-ish eviction so a long-lived process iterating over
    many schedules/configs cannot grow the cache without bound."""
    if len(_JIT_CACHE) >= _JIT_CACHE_MAX:
        _JIT_CACHE.pop(next(iter(_JIT_CACHE)))
    _JIT_CACHE[key] = fn


def cached_scan(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
                trace: bool, with_rt: bool) -> Callable:
    """The jitted ``scan_run`` entry point for this (schedule shape, cfg,
    mode).  ``schedule`` is consulted only for its *scenario shape*
    (``workloads.schedule_shape``) — the returned callable takes the
    schedule pytree as its first argument, so same-shape schedules with
    different contents (e.g. generated scenarios) reuse one compile.  The
    cache keys on ``strip_tuned(cfg)``: the tunable policy coefficients
    are the callable's trailing ``PolicyParams`` argument, never part of
    the key, so tuner candidates share one compile too.

    ``with_rt=True`` returns ``f(sched, seed, spot_rt, params)``;
    otherwise ``f(sched, seed, params)`` (the runtime then derives from
    the config — note ``cfg.spot.bid_mult`` stays in the key for exactly
    that reason).  When the chaos engine is on (``cfg.faults`` — itself
    part of the cache key through ``strip_tuned``), the callable takes a
    trailing traced ``FaultSpec`` argument.
    """
    key = (wl.schedule_shape(schedule), strip_tuned(cfg), bool(trace),
           bool(with_rt))
    fn = _JIT_CACHE.get(key)
    if fn is None:
        if cfg.faults is not None:
            if with_rt:
                fn = jax.jit(lambda sched, seed, rt, pp, fs: scan_run(
                    sched, cfg, seed=seed, spot_rt=rt, trace=trace,
                    params=pp, fspec=fs))
            else:
                fn = jax.jit(lambda sched, seed, pp, fs: scan_run(
                    sched, cfg, seed=seed, trace=trace, params=pp, fspec=fs))
        elif with_rt:
            fn = jax.jit(lambda sched, seed, rt, pp: scan_run(
                sched, cfg, seed=seed, spot_rt=rt, trace=trace, params=pp))
        else:
            fn = jax.jit(lambda sched, seed, pp: scan_run(
                sched, cfg, seed=seed, trace=trace, params=pp))
        _cache_put(key, fn)
    return fn


def cost_at_completion(work_final: WorkloadState, cum_cost: jnp.ndarray,
                       valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """$ billed when the last workload completes, jnp-pure (shared by
    ``total_cost`` and ``sim.sweep``).  A run in which submitted work never
    finishes has no such endpoint: it is billed to the full horizon, so an
    incomplete run can never masquerade as a cheap one.  ``valid`` masks
    out padded workload rows (they can neither finish nor stay
    unfinished)."""
    submitted = work_final.t_submit >= 0
    finished = work_final.t_done >= 0
    t_done = work_final.t_done
    if valid is not None:
        submitted = submitted & valid
        t_done = jnp.where(valid, t_done, -1)
    unfinished = jnp.any(submitted & ~finished)
    t_end = jnp.max(t_done)
    idx = jnp.clip(t_end + 1, 0, cum_cost.shape[0] - 1)
    return jnp.where(unfinished | (t_end < 0), cum_cost[-1], cum_cost[idx])


def violation_rows(work_final: WorkloadState,
                   schedule: wl.Schedule | wl.JaxSchedule,
                   cfg: SimConfig,
                   valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """(W,) bool: which workload rows violated their TTC.

    ``valid`` is the explicit workload-valid mask; it defaults to the
    schedule's own mask, so padded rows never count as violations even if a
    caller hands in a hand-built final state with garbage in the padding.
    """
    sched = wl.as_jax_schedule(schedule)
    if valid is None:
        valid = sched.valid
    ticks_allowed = jnp.ceil(sched.d_requested / cfg.dt)
    submitted = (work_final.t_submit >= 0) & valid
    finished = work_final.t_done >= 0
    # Judged against the TTC *requested* at submission (with one tick of
    # grace).  A confirmed-but-extended deadline (infeasible request) is
    # therefore still counted as a violation of the original ask.
    lateness = (work_final.t_done - work_final.t_submit) - ticks_allowed
    return ((submitted & finished & (lateness > 1)) |
            (submitted & ~finished))


def count_violations(work_final: WorkloadState,
                     schedule: wl.Schedule | wl.JaxSchedule,
                     cfg: SimConfig,
                     valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """TTC violations, jnp-pure (shared by ``run`` and ``sim.sweep``)."""
    return jnp.sum(violation_rows(work_final, schedule, cfg, valid=valid))


def run(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
        seed: int | None = None,
        spot_rt: spot_lib.SpotRuntime | None = None,
        params: PolicyParams | None = None,
        fspec: "faults_lib.FaultSpec | None" = None) -> SimTrace:
    s = cfg.seed if seed is None else seed
    sched = wl.as_jax_schedule(schedule)
    pp = default_params(cfg) if params is None else params
    tail: tuple = ()
    if cfg.faults is not None:
        tail = (faults_lib.make_fault_spec() if fspec is None else fspec,)
    if spot_rt is None:
        final, ys = cached_scan(sched, cfg, trace=True,
                                with_rt=False)(sched, s, pp, *tail)
    else:
        final, ys = cached_scan(sched, cfg, trace=True,
                                with_rt=True)(sched, s, spot_rt, pp, *tail)

    violations = count_violations(final.work, sched, cfg)
    return SimTrace(t_done=final.work.t_done, work_final=final.work,
                    violations=violations, **{k: ys[k] for k in ys})


def obs_report(final: SimState, cfg: SimConfig,
               schedule: wl.Schedule | wl.JaxSchedule) -> "obs_lib.ObsReport":
    """Drain a finished run's observability registers into an ObsReport.

    ``final`` is the scan's final carry (``scan_run``/``cached_scan``
    return it as the first element); the schedule supplies the queue-depth
    histogram's static bin span.  Raises if the run was probe-free.
    """
    if cfg.obs is None or final.obs is None:
        raise ValueError("run had no observability enabled — set "
                         "SimConfig.obs to an ObsSpec")
    sched = wl.as_jax_schedule(schedule)
    return obs_lib.drain(final.obs, cfg.obs, q_cap=sched.t_arrive.shape[0])


def run_obs(schedule: wl.Schedule | wl.JaxSchedule, cfg: SimConfig, *,
            seed: int | None = None,
            params: PolicyParams | None = None,
            fspec: "faults_lib.FaultSpec | None" = None,
            ) -> "tuple[SimTrace, obs_lib.ObsReport]":
    """``run`` plus the drained ObsReport, in one cached compile."""
    s = cfg.seed if seed is None else seed
    sched = wl.as_jax_schedule(schedule)
    pp = default_params(cfg) if params is None else params
    tail: tuple = ()
    if cfg.faults is not None:
        tail = (faults_lib.make_fault_spec() if fspec is None else fspec,)
    final, ys = cached_scan(sched, cfg, trace=True,
                            with_rt=False)(sched, s, pp, *tail)
    violations = count_violations(final.work, sched, cfg)
    trace = SimTrace(t_done=final.work.t_done, work_final=final.work,
                     violations=violations, **{k: ys[k] for k in ys})
    return trace, obs_report(final, cfg, sched)


def total_cost(trace: SimTrace) -> float:
    """Cumulative bill at the instant the last workload completes.

    The paper's Figs. 4-5 track cost over the experiment; the experiment
    ends when all workloads are done (the platform then sheds to N_min and
    would otherwise keep renewing idle base instances forever).  Incomplete
    runs bill to the full horizon (see ``cost_at_completion``) — check
    ``trace.violations`` alongside this number.
    """
    return float(cost_at_completion(trace.work_final, trace.cum_cost))
