"""Stochastic workload scenarios: generators as a first-class sweep axis.

Every result of the reproduction used to be conditioned on the single
deterministic §V.A schedule (30 workloads, one every 5 minutes).  The real
Dithen platform faces bursty, heterogeneous multimedia arrivals, and
profit-optimal provisioning is known to hinge on the *arrival process* as
much as on the price process — so "which workload world are we in" should
be an experiment axis, not a constant.

This module provides a library of JAX-native workload generators.  Each
scenario spec is a small frozen (hashable) dataclass whose ``sample(key)``
emits a padded, masked ``workloads.JaxSchedule`` of a fixed row capacity
``max_w``: real workloads occupy the ``valid`` rows, padding rows carry
``t_arrive = -1`` and never arrive, bill, or violate.  Sampling is pure
``jax.random`` on fixed shapes, so generation composes with ``jit`` and
``vmap`` — ``sim.sweep`` calls it *inside* the jitted sweep, handing every
(seed, scenario) grid point its own freshly sampled workload world.

Scenario families:

  * ``Replay``     — deterministic trace replay of a static ``Schedule``;
                     the paper's §V.A suite becomes the named ``paper``
                     scenario (bit-for-bit identical to running the static
                     schedule directly);
  * ``Poisson``    — homogeneous Poisson arrivals at ``rate`` per tick;
  * ``MMPP``       — Markov-modulated Poisson: a two-state (calm/burst)
                     chain switches the arrival rate, giving geometric
                     burst lengths with mean ``1 / p_down`` ticks;
  * ``Diurnal``    — sinusoidally modulated rate (a compressed day), with
                     an optionally random phase per seed;
  * ``FlashCrowd`` — baseline Poisson plus one intense arrival spike at a
                     random instant (the Slashdot/retweet moment);
  * heavy tails    — any of the above with ``TaskModel(size_dist="pareto")``
                     draws per-workload item costs from a Pareto law with
                     tail index ``pareto_alpha`` (``heavy_tail(...)`` is
                     the packaged Poisson variant).

Arrival machinery shared by the stochastic families: the spec builds a
per-tick intensity path ``rates`` (H,), per-tick counts are Poisson draws,
and workload slot *i* arrives at the first tick where the cumulative count
exceeds *i* (``searchsorted``) — arrivals beyond ``max_w`` are dropped, so
pick ``max_w`` with headroom over ``rate × horizon``.

A ``ScenarioSet`` bundles specs of one shape into a sweep axis:
``sweep.make_axes(..., scenarios=sset)`` enumerates it and
``sweep.sweep(SweepSpec(axes=axes, workload=sset), cfg)`` evaluates
seeds × bids × policies × fleets × scenarios in one jitted call via
``lax.switch`` over the samplers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import workloads as wl

# Per-family calibration tables as jnp constants, indexable by a traced
# family id (rows ordered as workloads.FAMILIES).
_FAM = [wl.FAMILY_PARAMS[f] for f in range(len(wl.FAMILIES))]
MEAN_CUS_TABLE = jnp.asarray([p["mean_cus"] for p in _FAM], jnp.float32)
SIGMA_TABLE = jnp.asarray([p["sigma"] for p in _FAM], jnp.float32)
C0_TABLE = jnp.asarray([p["c0"] for p in _FAM], jnp.float32)
P_R_TABLE = jnp.asarray([p["p_r"] for p in _FAM], jnp.float32)
OVERSHOOT_TABLE = jnp.asarray([p["overshoot"] for p in _FAM], jnp.float32)

# Salt separating the schedule-sampling PRNG chain from the simulator's
# execution-noise chain (PRNGKey(seed)) and the market chain
# (PRNGKey(seed + 7919)).
_SCHEDULE_SALT = 104729


def schedule_key(seed, scenario_id) -> jax.Array:
    """The PRNG key scenario ``scenario_id`` samples its schedule from for
    Monte-Carlo replication ``seed`` (both may be traced)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), _SCHEDULE_SALT)
    return jax.random.fold_in(key, scenario_id)


def _gen_param(spec, params: dict | None, name: str) -> jnp.ndarray:
    """One generator parameter as an f32 scalar: the override from the
    (possibly traced) ``params`` dict when present, else the spec's own
    static field.  This is the hook that lets ``repro.opt.adversarial``
    search a generator's parameter space *inside* one compiled sweep —
    the spec stays the static recipe, the worlds it draws become runtime
    inputs."""
    if params is not None and name in params:
        return jnp.asarray(params[name], jnp.float32)
    return jnp.asarray(getattr(spec, name), jnp.float32)


def _rel_bounds(value: float, lo_mult: float = 0.25, hi_mult: float = 4.0,
                cap: float | None = None) -> tuple[float, float]:
    """Default search box around a nominal generator parameter."""
    lo, hi = lo_mult * value, hi_mult * value
    if cap is not None:
        hi = min(hi, cap)
    return (lo, max(hi, lo + 1e-6))


@dataclasses.dataclass(frozen=True)
class TaskModel:
    """What one arriving workload looks like (family mix and task sizes).

    Families reuse the §V.A calibration (``workloads.FAMILY_PARAMS``) for
    the measurement-ramp parameters; this model only chooses the family,
    the item count, and the per-workload mean item cost around the family
    mean.  ``size_dist="pareto"`` swaps the lognormal cost jitter for a
    Pareto multiplier with tail index ``pareto_alpha`` — the heavy-tailed
    world where a rare workload is 10-100× costlier per item.
    """

    family_weights: tuple = (0.35, 0.20, 0.25, 0.20)  # face/transc/brisk/sift
    mean_items: tuple = (300.0, 20.0, 200.0, 150.0)  # typical item counts
    items_sigma: float = 0.9  # lognormal spread of item counts
    max_items: float = 1200.0
    size_dist: str = "lognormal"  # or "pareto"
    size_jitter: float = 0.15  # lognormal σ of the per-workload cost mult
    pareto_alpha: float = 1.8  # tail index of the Pareto cost mult
    ttc: float = 7500.0  # requested TTC (s) per workload

    def __post_init__(self):
        if self.size_dist not in ("lognormal", "pareto"):
            raise ValueError(
                f"unknown size_dist {self.size_dist!r}; "
                "choose 'lognormal' or 'pareto'"
            )
        n_fam = len(wl.FAMILIES)
        if len(self.family_weights) != n_fam or len(self.mean_items) != n_fam:
            raise ValueError(
                "family_weights and mean_items need one entry per family "
                f"{wl.FAMILIES}"
            )
        if not self.pareto_alpha > 1.0:
            raise ValueError(f"pareto_alpha must exceed 1, got {self.pareto_alpha}")


def sample_size_mult(key: jax.Array, shape: tuple, tm: TaskModel) -> jnp.ndarray:
    """Per-workload item-cost multiplier around the family mean CUS."""
    if tm.size_dist == "lognormal":
        return jnp.exp(tm.size_jitter * jax.random.normal(key, shape))
    # Pareto(alpha) with unit scale via inversion: scale * U^(-1/alpha).
    u = jax.random.uniform(key, shape, minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
    return u ** (-1.0 / tm.pareto_alpha)


def sample_tasks(key: jax.Array, n: int, tm: TaskModel):
    """(family, items, b_true) for ``n`` workload slots."""
    k_fam, k_cnt, k_size = jax.random.split(key, 3)
    weights = jnp.asarray(tm.family_weights, jnp.float32)
    fam = jax.random.choice(
        k_fam, len(wl.FAMILIES), (n,), p=weights / jnp.sum(weights)
    ).astype(jnp.int32)
    mean_items = jnp.asarray(tm.mean_items, jnp.float32)[fam]
    jitter = jnp.exp(tm.items_sigma * jax.random.normal(k_cnt, (n,)))
    counts = jnp.clip(jnp.round(mean_items * jitter), 1.0, tm.max_items)
    b_true = MEAN_CUS_TABLE[fam] * sample_size_mult(k_size, (n,), tm)
    return fam, counts, b_true


def _schedule_from_rates(
    key: jax.Array, rates: jnp.ndarray, max_w: int, tm: TaskModel
) -> wl.JaxSchedule:
    """Arrivals from a per-tick intensity path → padded, masked schedule."""
    k_arr, k_tasks = jax.random.split(key)
    counts_t = jax.random.poisson(k_arr, rates)  # (H,) arrivals per tick
    cum = jnp.cumsum(counts_t)
    idx = jnp.arange(max_w)
    # Slot i arrives at the first tick whose cumulative count exceeds i;
    # slots beyond the total are padding.
    t_arrive = jnp.searchsorted(cum, idx, side="right").astype(jnp.int32)
    valid = idx < cum[-1]
    fam, m0, b_true = sample_tasks(k_tasks, max_w, tm)
    return wl.JaxSchedule(
        t_arrive=jnp.where(valid, t_arrive, -1),
        family=fam,
        m0=jnp.where(valid, m0, 0.0)[:, None].astype(jnp.float32),
        b_true=jnp.where(valid, b_true, 0.0)[:, None].astype(jnp.float32),
        sigma=SIGMA_TABLE[fam],
        c0=C0_TABLE[fam],
        p_r=P_R_TABLE[fam],
        overshoot=OVERSHOOT_TABLE[fam],
        d_requested=jnp.full((max_w,), tm.ttc, jnp.float32),
        valid=valid,
    )


def _check_arrival_spec(spec) -> None:
    if spec.horizon <= 0:
        raise ValueError(f"horizon must be positive, got {spec.horizon}")
    if spec.max_w <= 0:
        raise ValueError(f"max_w must be positive, got {spec.max_w}")
    rates = [
        getattr(spec, field)
        for field in ("rate", "rate_lo", "rate_hi")
        if hasattr(spec, field)
    ]
    if min(rates) < 0.0:
        raise ValueError(f"arrival rates must be non-negative, got {min(rates)}")


@dataclasses.dataclass(frozen=True)
class Poisson:
    """Homogeneous Poisson arrivals: ``rate`` expected workloads per tick
    over the first ``horizon`` ticks."""

    rate: float = 0.35
    horizon: int = 90
    max_w: int = 64
    tasks: TaskModel = TaskModel()
    name: str = "poisson"

    def __post_init__(self):
        _check_arrival_spec(self)

    def params_pytree(self) -> dict:
        return {"rate": jnp.asarray(self.rate, jnp.float32)}

    def param_bounds(self) -> dict:
        return {"rate": _rel_bounds(self.rate)}

    def rate_path(self, key: jax.Array, params: dict | None = None) -> jnp.ndarray:
        del key
        return jnp.full((self.horizon,), _gen_param(self, params, "rate"))

    def sample(self, key: jax.Array, params: dict | None = None) -> wl.JaxSchedule:
        k_rate, k_sched = jax.random.split(key)
        return _schedule_from_rates(
            k_sched, self.rate_path(k_rate, params), self.max_w, self.tasks
        )


@dataclasses.dataclass(frozen=True)
class MMPP:
    """Markov-modulated Poisson (bursty) arrivals.

    A two-state chain switches the rate between ``rate_lo`` (calm) and
    ``rate_hi`` (burst); per tick it enters a burst with probability
    ``p_up`` and leaves with ``p_down``, so burst lengths are geometric
    with mean ``1 / p_down`` ticks and the long-run burst-time fraction is
    ``p_up / (p_up + p_down)``.
    """

    rate_lo: float = 0.1
    rate_hi: float = 1.2
    p_up: float = 0.05
    p_down: float = 0.2
    horizon: int = 90
    max_w: int = 64
    tasks: TaskModel = TaskModel()
    name: str = "mmpp"

    def __post_init__(self):
        _check_arrival_spec(self)
        for field in ("p_up", "p_down"):
            v = getattr(self, field)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{field} must be in (0, 1], got {v}")

    def params_pytree(self) -> dict:
        return {
            name: jnp.asarray(getattr(self, name), jnp.float32)
            for name in ("rate_lo", "rate_hi", "p_up", "p_down")
        }

    def param_bounds(self) -> dict:
        return {
            "rate_lo": _rel_bounds(self.rate_lo),
            "rate_hi": _rel_bounds(self.rate_hi),
            "p_up": _rel_bounds(self.p_up, cap=1.0),
            "p_down": _rel_bounds(self.p_down, cap=1.0),
        }

    def rate_path(self, key: jax.Array, params: dict | None = None) -> jnp.ndarray:
        p_up = _gen_param(self, params, "p_up")
        p_down = _gen_param(self, params, "p_down")

        def flip(burst, k):
            u = jax.random.uniform(k)
            burst = jnp.where(burst, u >= p_down, u < p_up)
            return burst, burst

        keys = jax.random.split(key, self.horizon)
        _, bursts = jax.lax.scan(flip, jnp.asarray(False), keys)
        return jnp.where(bursts, _gen_param(self, params, "rate_hi"),
                         _gen_param(self, params, "rate_lo"))

    def sample(self, key: jax.Array, params: dict | None = None) -> wl.JaxSchedule:
        k_rate, k_sched = jax.random.split(key)
        return _schedule_from_rates(
            k_sched, self.rate_path(k_rate, params), self.max_w, self.tasks
        )


@dataclasses.dataclass(frozen=True)
class Diurnal:
    """Sinusoidally modulated arrivals — a (compressed) day/night cycle:
    ``rate × (1 + amp·sin(2π t / period + phase))``, phase drawn per seed
    when ``random_phase`` (so the sweep averages over times of day)."""

    rate: float = 0.35
    amp: float = 0.8
    period: int = 48
    random_phase: bool = True
    horizon: int = 90
    max_w: int = 64
    tasks: TaskModel = TaskModel()
    name: str = "diurnal"

    def __post_init__(self):
        _check_arrival_spec(self)
        if not 0.0 <= self.amp <= 1.0:
            raise ValueError(f"amp must be in [0, 1], got {self.amp}")
        if self.period <= 0:
            raise ValueError(f"period must be positive, got {self.period}")

    def params_pytree(self) -> dict:
        return {
            "rate": jnp.asarray(self.rate, jnp.float32),
            "amp": jnp.asarray(self.amp, jnp.float32),
        }

    def param_bounds(self) -> dict:
        return {"rate": _rel_bounds(self.rate), "amp": (0.0, 1.0)}

    def rate_path(self, key: jax.Array, params: dict | None = None) -> jnp.ndarray:
        phase = 0.0
        if self.random_phase:
            phase = jax.random.uniform(key, maxval=2.0 * jnp.pi)
        t = jnp.arange(self.horizon, dtype=jnp.float32)
        amp = _gen_param(self, params, "amp")
        mod = 1.0 + amp * jnp.sin(2.0 * jnp.pi * t / self.period + phase)
        rate = _gen_param(self, params, "rate")
        return jnp.maximum(rate * mod, 0.0).astype(jnp.float32)

    def sample(self, key: jax.Array, params: dict | None = None) -> wl.JaxSchedule:
        k_rate, k_sched = jax.random.split(key)
        return _schedule_from_rates(
            k_sched, self.rate_path(k_rate, params), self.max_w, self.tasks
        )


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """Baseline Poisson plus one flash-crowd spike: at a random tick in the
    first ``spike_window`` fraction of the horizon the rate jumps by
    ``spike_rate`` for ``spike_ticks`` ticks (the viral-link moment)."""

    rate: float = 0.15
    spike_rate: float = 3.0
    spike_ticks: int = 6
    spike_window: float = 0.5
    horizon: int = 90
    max_w: int = 64
    tasks: TaskModel = TaskModel()
    name: str = "flash"

    def __post_init__(self):
        _check_arrival_spec(self)
        if not 0.0 < self.spike_window <= 1.0:
            raise ValueError(f"spike_window must be in (0, 1], got {self.spike_window}")
        if self.spike_ticks <= 0 or self.spike_rate < 0.0:
            raise ValueError(
                f"bad spike: ticks={self.spike_ticks} rate={self.spike_rate}"
            )

    def params_pytree(self) -> dict:
        return {
            "rate": jnp.asarray(self.rate, jnp.float32),
            "spike_rate": jnp.asarray(self.spike_rate, jnp.float32),
        }

    def param_bounds(self) -> dict:
        return {
            "rate": _rel_bounds(self.rate),
            "spike_rate": _rel_bounds(self.spike_rate),
        }

    def rate_path(self, key: jax.Array, params: dict | None = None) -> jnp.ndarray:
        hi = max(int(self.horizon * self.spike_window), 1)
        tau = jax.random.randint(key, (), 0, hi)
        t = jnp.arange(self.horizon)
        in_spike = (t >= tau) & (t < tau + self.spike_ticks)
        rate = _gen_param(self, params, "rate")
        spike_rate = _gen_param(self, params, "spike_rate")
        return (rate + spike_rate * in_spike).astype(jnp.float32)

    def sample(self, key: jax.Array, params: dict | None = None) -> wl.JaxSchedule:
        k_rate, k_sched = jax.random.split(key)
        return _schedule_from_rates(
            k_sched, self.rate_path(k_rate, params), self.max_w, self.tasks
        )


def heavy_tail(
    alpha: float = 1.6,
    rate: float = 0.35,
    horizon: int = 90,
    max_w: int = 64,
    name: str = "heavy_tail",
    tasks: TaskModel | None = None,
) -> Poisson:
    """Poisson arrivals whose per-workload item costs are Pareto(``alpha``)
    — the heavy-tailed-size world (video lengths, raw image dumps)."""
    tm = tasks if tasks is not None else TaskModel()
    tm = dataclasses.replace(tm, size_dist="pareto", pareto_alpha=alpha)
    return Poisson(rate=rate, horizon=horizon, max_w=max_w, tasks=tm, name=name)


@dataclasses.dataclass(frozen=True, eq=False)
class Replay:
    """Deterministic trace replay of a static ``Schedule`` (``sample``
    ignores its key).  ``pad_to`` pads the row capacity so a replay can
    share a ``ScenarioSet`` with stochastic generators; left ``None`` the
    emitted schedule is bit-for-bit the static one, which is what keeps
    the ``paper`` scenario's results exactly equal to the legacy path."""

    schedule: wl.Schedule
    name: str = "replay"
    pad_to: int | None = None

    def __post_init__(self):
        if self.pad_to is not None and self.pad_to < self.schedule.n:
            raise ValueError(
                f"pad_to={self.pad_to} is below the schedule's "
                f"{self.schedule.n} workloads"
            )

    @property
    def max_w(self) -> int:
        return self.schedule.n if self.pad_to is None else self.pad_to

    def params_pytree(self) -> dict:
        # A deterministic replay has no generator knobs — an adversarial
        # search has nothing to move, and ``opt.adversarial`` rejects it.
        return {}

    def param_bounds(self) -> dict:
        return {}

    def sample(self, key: jax.Array, params: dict | None = None) -> wl.JaxSchedule:
        del key, params
        return wl.pad_schedule(self.schedule.as_jax(), self.max_w)

    # Frozen dataclasses hash by field values, but numpy arrays aren't
    # hashable — identify a replay by its schedule's content digest instead
    # (the compilation caches key on scenario specs).
    def _key(self) -> tuple:
        return (type(self), self.name, self.pad_to, wl.schedule_digest(self.schedule))

    def __hash__(self) -> int:
        return hash(self._key())

    def __eq__(self, other) -> bool:
        return isinstance(other, Replay) and self._key() == other._key()


def paper_scenario(
    ttc: float = 7500.0,
    arrival_gap_ticks: int = 1,
    seed: int = 0,
    pad_to: int | None = None,
) -> Replay:
    """The §V.A paper suite as a named replay scenario."""
    sched = wl.paper_schedule(ttc=ttc, arrival_gap_ticks=arrival_gap_ticks, seed=seed)
    return Replay(sched, name="paper", pad_to=pad_to)


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """An ordered bundle of same-shape scenario specs — the sweep axis.

    All members must emit schedules of one row capacity (``max_w``) so a
    traced scenario id can ``lax.switch`` between their samplers inside a
    single compiled sweep.  Hashable (specs are), so compilation caches can
    key on it directly.
    """

    specs: tuple

    def __post_init__(self):
        specs = tuple(self.specs)
        object.__setattr__(self, "specs", specs)
        if not specs:
            raise ValueError("a ScenarioSet needs at least one scenario")
        widths = {s.max_w for s in specs}
        if len(widths) > 1:
            raise ValueError(
                "all scenarios in a set must share one max_w so a traced "
                f"id can switch between them; got {sorted(widths)} — pad "
                "replays / set max_w to the common capacity"
            )
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"scenario names must be unique, got {names}")

    @property
    def names(self) -> tuple:
        return tuple(s.name for s in self.specs)

    @property
    def max_w(self) -> int:
        return self.specs[0].max_w

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __getitem__(self, i):
        return self.specs[i]

    def index(self, name: str) -> int:
        return self.names.index(name)

    def sample(self, scenario_id, key: jax.Array) -> wl.JaxSchedule:
        """Sample scenario ``scenario_id`` (traced ok) under ``key``."""
        return jax.lax.switch(scenario_id, [s.sample for s in self.specs], key)


def default_set(max_w: int = 64, horizon: int = 30, ttc: float = 4500.0) -> ScenarioSet:
    """The benchmarked scenario families (one of each stochastic kind).

    Calibrated so provisioning actually matters: arrivals are compressed
    into ``horizon`` ticks (the paper's §V.A suite compresses likewise)
    and the task mix is heavy enough that aggregate demand repeatedly
    pushes the fleet well above the N_min floor — which is where AIMD's
    measured growth and Reactive's churn separate.  Lighter settings leave
    every policy idling at N_min and the cost frontier degenerate.
    """
    tm = TaskModel(
        family_weights=(0.3, 0.3, 0.2, 0.2),
        mean_items=(400.0, 40.0, 250.0, 200.0),
        items_sigma=1.0,
        ttc=ttc,
    )
    common = dict(horizon=horizon, max_w=max_w, tasks=tm)
    return ScenarioSet(
        (
            Poisson(rate=1.0, **common),
            MMPP(rate_lo=0.3, rate_hi=3.0, p_up=0.1, p_down=0.25, **common),
            Diurnal(rate=1.0, amp=0.8, period=24, **common),
            FlashCrowd(rate=0.5, spike_rate=6.0, spike_ticks=4, **common),
            heavy_tail(alpha=1.6, rate=1.0, horizon=horizon, max_w=max_w, tasks=tm),
        )
    )
