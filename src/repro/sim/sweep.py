"""Vmapped Monte-Carlo experiment harness over the spot-market simulator.

The entire simulation — correlated multi-type market process, billing,
preemption, controller, workload execution — is one pure ``lax.scan``
(``runner.scan_run``), so a cost sweep over seeds × bid levels × bid
policies × fleet mixes × workload scenarios is a single
``jax.jit(jax.vmap(...))`` call: one compile, one device dispatch, every
grid point in parallel.  A 3 × 5 × 4 × 2 grid of full 130-tick
experiments costs about as much wall-clock as three sequential runs.

Sweeps run the scan in **summary mode** (``runner.scan_run(trace=False)``):
the eight per-run scalars accumulate inside the scan carry and the scan
emits no per-tick outputs, so a B-point grid moves O(B) floats instead of
the O(B·T·W·K) a stacked trace would — which is what makes 10⁴–10⁵-point
grids affordable on one host.  Two scaling knobs on ``run_sweep``:

  * ``chunk_size`` — micro-batch the B axis: every chunk is padded to the
    same shape and pushed through one cached, donated-buffer compiled
    callable (one compile for any grid size, bounded live memory);
  * device sharding — with more than one local device the B axis is padded
    to a device multiple and ``pmap``-sharded, each device vmapping its
    shard (``devices=1`` forces single-device; the default uses all).

Axes:
  * ``seed``      — Monte-Carlo replication (market + execution noise +
                    scenario sampling);
  * ``bid_mult``  — bid as a multiple of the base spot price (the 'ema'
                    policy's EMA multiple and the 'ttc' policy's floor;
                    ignored under 'on_demand');
  * ``policy``    — bid policy (``spot.BID_POLICIES``): static multiple,
                    on-demand cap, TTC-aware, market-aware EMA.  The
                    sentinel -1 defers to ``cfg.spot.bid_policy``;
  * ``itype`` / ``mix`` — fleet mix over the Appendix-A Table V types:
                    ``mix`` is the (T,)-mask of allowed types,  ``itype``
                    the mix's primary type (reported in the trace).  A
                    one-type mask is the classic granularity axis (many
                    m3.medium vs few m4.10xlarge); a wider mask lets every
                    acquisition pick the cheapest-per-CU available type;
  * ``scenario``  — which workload world the run lives in.  With a
                    ``scenarios.ScenarioSet`` the id picks the generator
                    (``lax.switch``) and each grid point samples its own
                    schedule from (seed, scenario); with a plain
                    ``Schedule`` the axis must be all-zero.

Schedules are *traced pytree inputs* of the compiled sweep, not constants
closed over at trace time: compilation caches key on the schedule's shape
(``workloads.schedule_shape``) or on the scenario specs, so two schedules
of one shape — or any number of generated scenarios — share one compile.

Summaries are per-run scalars, so the sweep output is a struct of
(B,)-shaped arrays — ready for the policy/granularity frontier plots in
``benchmarks.bench_spot``, ``benchmarks.bench_bidding`` and the
per-scenario frontiers in ``benchmarks.bench_scenarios``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.types import PolicyParams
from . import runner, spot
from . import scenarios as scen_lib
from . import workloads as wl

FleetMix = Sequence[str | int] | str | int
ScheduleLike = "wl.Schedule | wl.JaxSchedule | scen_lib.ScenarioSet"


class SweepAxes(NamedTuple):
    """The flattened experiment grid (B = len of every field)."""

    seed: jnp.ndarray      # (B,) int32
    bid_mult: jnp.ndarray  # (B,) float32
    itype: jnp.ndarray     # (B,) int32 primary type per fleet mix
    policy: jnp.ndarray    # (B,) int32 BID_POLICIES id (-1: use config's)
    mix: jnp.ndarray       # (B, T) float32 fleet-membership masks
    scenario: jnp.ndarray  # (B,) int32 scenario id (0 = first/only)


class RunSummary(NamedTuple):
    """Per-run scalars (each (B,)-shaped after the vmap)."""

    cost: jnp.ndarray          # $ at last completion; full horizon if
                               # submitted work never finished
    cost_horizon: jnp.ndarray  # $ at the end of the simulation window
    violations: jnp.ndarray    # TTC violations (incl. unfinished workloads)
    preemptions: jnp.ndarray   # instances reclaimed by the market
    finished: jnp.ndarray      # workloads completed
    max_committed: jnp.ndarray # peak control-plane fleet, in CUs
    mean_price: jnp.ndarray    # mean $/quantum of the primary type
    max_price: jnp.ndarray     # worst $/quantum seen (primary type)


def summarize(final, schedule: wl.Schedule | wl.JaxSchedule,
              cfg: runner.SimConfig,
              valid: jnp.ndarray | None = None) -> RunSummary:
    """Read one run's summary out of the final scan carry, jnp-pure.

    Every statistic was accumulated inside the scan (``runner.SummaryCarry``
    plus the cost/preemption registers ``ClusterState`` already carries), so
    this needs no per-tick trace — it is the read-out both trace- and
    summary-mode runs share, which is what makes the two modes bit-identical
    by construction.

    ``valid`` is the explicit workload-valid mask (default: the schedule's
    own): padded rows are excluded from the finished count, the violation
    count and the cost-at-completion endpoint, so a generated scenario's
    padding can never inflate — or deflate — a summary.
    """
    sched = wl.as_jax_schedule(schedule)
    if valid is None:
        valid = sched.valid
    work = final.work
    submitted = (work.t_submit >= 0) & valid
    finished = (work.t_done >= 0) & valid
    unfinished = jnp.any(submitted & ~finished)
    t_end = jnp.max(jnp.where(valid, work.t_done, -1))
    # ``cost_at_done`` is the trace's ``cum_cost[t_end + 1]``; the register
    # never fired when nothing finished, a completion landed on the last
    # tick, or submitted work is still running — all cases the trace-mode
    # ``cost_at_completion`` resolves to the full-horizon bill.  The
    # register tracks the *unmasked* last completion, so if an explicit
    # ``valid`` hides a later-finishing row it holds the wrong endpoint —
    # bill to the horizon then too (conservative; never under-reports).
    # With the default mask this never triggers: padding cannot finish.
    register_stale = t_end != jnp.max(work.t_done)
    use_horizon = (unfinished | (t_end < 0) | (t_end + 1 > cfg.ticks - 1)
                   | register_stale)
    cost = jnp.where(use_horizon, final.cluster.cum_cost,
                     final.summ.cost_at_done)
    return RunSummary(
        cost=cost,
        cost_horizon=final.cluster.cum_cost,
        violations=runner.count_violations(work, sched, cfg, valid=valid),
        preemptions=final.cluster.n_preempt,
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=final.summ.max_committed,
        mean_price=final.summ.price_sum / cfg.ticks,
        max_price=final.summ.price_max,
    )


def summarize_trace(final, ys, schedule: wl.Schedule | wl.JaxSchedule,
                    cfg: runner.SimConfig,
                    valid: jnp.ndarray | None = None) -> RunSummary:
    """Collapse a *trace-mode* run's stacked scan outputs to scalars.

    The pre-summary-mode implementation, kept as the independent reference
    the carry registers are tested against (``tests/test_throughput.py``).
    ``mean_price`` is the only field whose reduction order differs from the
    in-carry accumulation (parallel vs sequential float sum); everything
    else is bit-identical.
    """
    sched = wl.as_jax_schedule(schedule)
    if valid is None:
        valid = sched.valid
    work = final.work
    finished = (work.t_done >= 0) & valid
    return RunSummary(
        cost=runner.cost_at_completion(work, ys["cum_cost"], valid=valid),
        cost_horizon=ys["cum_cost"][-1],
        violations=runner.count_violations(work, sched, cfg, valid=valid),
        preemptions=ys["n_preempted"][-1],
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=jnp.max(ys["n_committed"]),
        mean_price=jnp.mean(ys["spot_price"]),
        max_price=jnp.max(ys["spot_price"]),
    )


def _as_mix(entry: FleetMix) -> tuple[int, np.ndarray]:
    """Normalize one fleet-mix spec to (primary itype, (T,) mask)."""
    if isinstance(entry, (str, int)):
        entry = (entry,)
    members = [spot.instance_index(m) if isinstance(m, str) else int(m)
               for m in entry]
    if not members:
        raise ValueError("a fleet mix needs at least one instance type")
    mask = np.zeros((spot.N_TYPES,), np.float32)
    mask[members] = 1.0
    return members[0], mask


def _scenario_ids(scenarios) -> list[int]:
    """Normalize the ``scenarios`` argument of ``make_axes`` to id list."""
    if scenarios is None:
        return [0]
    if isinstance(scenarios, int):
        return list(range(scenarios))
    if isinstance(scenarios, scen_lib.ScenarioSet):
        return list(range(len(scenarios)))
    return [int(s) for s in scenarios]


def make_axes(seeds: Sequence[int],
              bid_mults: Sequence[float],
              instances: Sequence[FleetMix] = ("m3.medium",),
              policies: Sequence[str | int] | None = None,
              scenarios=None) -> SweepAxes:
    """Cartesian-product grid, flattened to (B,) arrays.

    ``instances`` entries are fleet mixes: a single type name/id (the
    classic granularity axis) or a sequence of them (a heterogeneous
    fleet).  ``policies`` are ``spot.BID_POLICIES`` names/ids; the default
    defers to ``cfg.spot.bid_policy`` at sweep time.  ``scenarios`` is the
    workload-world axis: a ``scenarios.ScenarioSet`` (enumerated), a count,
    or explicit ids; the default is the single scenario 0.  Grid order is
    seeds × bid_mults × policies × mixes × scenarios, so reshaping a
    summary field to ``(len(seeds), len(bid_mults), len(policies),
    len(instances), n_scenarios)`` recovers the axes.
    """
    primaries, masks = zip(*(_as_mix(e) for e in instances))
    if policies is None:
        pol_ids = [-1]
    else:
        pol_ids = [spot.bid_policy_index(p) if isinstance(p, str) else int(p)
                   for p in policies]
    scen_ids = _scenario_ids(scenarios)
    s, b, p, m, c = np.meshgrid(np.asarray(seeds),
                                np.asarray(bid_mults, float),
                                np.asarray(pol_ids),
                                np.arange(len(masks)),
                                np.asarray(scen_ids), indexing="ij")
    mix = np.stack(masks)[m.ravel()]
    return SweepAxes(seed=jnp.asarray(s.ravel(), jnp.int32),
                     bid_mult=jnp.asarray(b.ravel(), jnp.float32),
                     itype=jnp.asarray(np.asarray(primaries)[m.ravel()],
                                       jnp.int32),
                     policy=jnp.asarray(p.ravel(), jnp.int32),
                     mix=jnp.asarray(mix, jnp.float32),
                     scenario=jnp.asarray(c.ravel(), jnp.int32))


def _check_axes(cfg: runner.SimConfig, axes: SweepAxes,
                schedule=None) -> None:
    """Shared run_sweep input validation."""
    if not cfg.spot.enabled:
        raise ValueError("run_sweep needs SimConfig.spot.enabled=True")
    # Guard a silent trap: a config that names a non-default instance while
    # the axes (which win) never visit it almost certainly means make_axes
    # was left at its m3.medium default.
    cfg_itype = spot.instance_index(cfg.spot.instance)
    if cfg_itype != 0 and not np.any(np.asarray(axes.mix)[:, cfg_itype] > 0):
        raise ValueError(
            f"SpotConfig.instance={cfg.spot.instance!r} never appears in "
            "the sweep axes, which override the config — pass "
            "instances=[...] to make_axes")
    n_scen = (len(schedule)
              if isinstance(schedule, scen_lib.ScenarioSet) else 1)
    scen = np.asarray(axes.scenario)
    if scen.size and (scen.min() < 0 or scen.max() >= n_scen):
        raise ValueError(
            f"scenario axis references id {int(scen.max())} but the "
            f"schedule provides {n_scen} scenario(s) — pass a ScenarioSet "
            "and scenarios=... to make_axes")


def _point_sched(cfg: runner.SimConfig, trace: bool = False):
    """One grid point with the schedule as an explicit (traced) argument —
    the single definition of what a sweep runs per point (policy-sentinel
    resolution, runtime construction, scan, masked summary).  ``params``
    is the traced ``PolicyParams`` pytree every run consumes (its relative
    ``bid_mult`` multiplies this point's bid-multiple axis)."""
    cfg_policy = spot.bid_policy_index(cfg.spot.bid_policy)

    def one(sched, seed, bid_mult, itype, policy, mix, params):
        policy = jnp.where(policy < 0, cfg_policy, policy)
        rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                               policy=policy, mix=mix)
        final, ys = runner.scan_run(sched, cfg, seed=seed, spot_rt=rt,
                                    trace=trace, params=params)
        summary = summarize(final, sched, cfg)
        return (summary, ys) if trace else summary

    return one


def point_fn(schedule: ScheduleLike, cfg: runner.SimConfig,
             trace: bool = False):
    """One grid point as a vmappable closure of (seed, bid_mult, itype,
    policy, mix, scenario, params).  With a ``ScenarioSet`` the scenario
    id picks the generator and the schedule is sampled per (seed,
    scenario) inside the trace; with a plain schedule the id is ignored.
    ``params`` is the (traced) ``PolicyParams`` pytree — the tuner in
    ``repro.opt`` vmaps candidate populations over exactly this argument.
    ``trace=True`` additionally returns the per-tick ``ys`` (what
    ``benchmarks.bench_throughput`` sizes the trace-mode baseline with)."""
    base = _point_sched(cfg, trace=trace)
    if isinstance(schedule, scen_lib.ScenarioSet):
        sset = schedule

        def one(seed, bid_mult, itype, policy, mix, scenario, params):
            sched = sset.sample(scenario,
                                scen_lib.schedule_key(seed, scenario))
            return base(sched, seed, bid_mult, itype, policy, mix, params)

        return one

    sj = wl.as_jax_schedule(schedule)

    def one(seed, bid_mult, itype, policy, mix, scenario, params):
        del scenario
        return base(sj, seed, bid_mult, itype, policy, mix, params)

    return one


def _sweep_callable(schedule: ScheduleLike, cfg: runner.SimConfig,
                    n_dev: int, donate: bool = False):
    """Cached compiled sweep over a fixed-shape batch of axes.

    One entry per (scenario set | schedule shape, cfg, device count,
    donation): chunked sweeps reuse it for every micro-batch and *every
    same-shape schedule*, so a 10⁵-point grid — or a loop over many
    schedules — compiles exactly once.  The returned callable takes
    ``(*axes_fields, sched)`` (``sched`` ignored under a ScenarioSet,
    whose generators are compiled in).  With ``donate=True`` the axis
    buffers are donated — each chunk's inputs are freed the moment the
    device is done with them (the chunked path passes per-chunk copies,
    never the caller's arrays; donation is a no-op on CPU, where XLA
    ignores it, so it is requested only on accelerator backends); the
    schedule argument is never donated.  With ``n_dev > 1`` the leading
    axis is the device axis (``pmap``), each device vmapping its shard
    with the schedule broadcast.
    """
    donate = donate and jax.default_backend() != "cpu"
    # Key on the config with the PolicyParams-traced leaves struck out:
    # the params pytree is a broadcast *argument* of the compiled sweep,
    # so sweeps at different tuned coefficients share one compile.
    cfg_key = runner.strip_tuned(cfg)
    if isinstance(schedule, scen_lib.ScenarioSet):
        key = ("sweep", schedule, cfg_key, n_dev, donate)
        sched_key_fn = point_fn(schedule, cfg)

        def pt(seed, bid_mult, itype, policy, mix, scenario, sched, params):
            del sched
            return sched_key_fn(seed, bid_mult, itype, policy, mix, scenario,
                                params)
    else:
        key = ("sweep", wl.schedule_shape(schedule), cfg_key, n_dev, donate)
        base = _point_sched(cfg)

        def pt(seed, bid_mult, itype, policy, mix, scenario, sched, params):
            del scenario
            return base(sched, seed, bid_mult, itype, policy, mix, params)

    fn = runner._JIT_CACHE.get(key)
    if fn is not None:
        return fn
    in_axes = (0, 0, 0, 0, 0, 0, None, None)
    batched = jax.vmap(pt, in_axes=in_axes)
    donate_kw = dict(donate_argnums=(0, 1, 2, 3, 4, 5)) if donate else {}
    if n_dev > 1:
        fn = jax.pmap(batched, in_axes=in_axes, **donate_kw)
    else:
        fn = jax.jit(batched, **donate_kw)
    runner._cache_put(key, fn)
    return fn


def _pad_axes(axes: SweepAxes, n: int) -> SweepAxes:
    """Pad the B axis up to ``n`` rows by repeating the last row (the
    padded results are sliced off before returning)."""
    b = axes.seed.shape[0]
    if b == n:
        return axes
    return SweepAxes(*(jnp.pad(f, [(0, n - b)] + [(0, 0)] * (f.ndim - 1),
                               mode="edge") for f in axes))


def _slice_axes(axes: SweepAxes, lo: int, hi: int,
                copy: bool = True) -> SweepAxes:
    # With ``copy`` (accelerator backends) the slices are fresh buffers,
    # never views of the caller's arrays: the chunked path donates its
    # input buffers to the compiled sweep.  On CPU donation is off, so the
    # defensive copy would be pure waste — plain slices suffice.
    if not copy:
        return SweepAxes(*(f[lo:hi] for f in axes))
    return SweepAxes(*(jnp.array(f[lo:hi], copy=True) for f in axes))


def _device_fold(axes: SweepAxes, n_dev: int) -> SweepAxes:
    """(B,) → (n_dev, B // n_dev) leading device axis for pmap."""
    return SweepAxes(*(f.reshape((n_dev, f.shape[0] // n_dev)
                                 + f.shape[1:]) for f in axes))


def run_sweep(schedule: ScheduleLike, cfg: runner.SimConfig,
              axes: SweepAxes,
              chunk_size: int | None = None,
              devices: int | None = None,
              params: PolicyParams | None = None) -> RunSummary:
    """Every grid point of the axes, summary-mode, sharded and chunked.

    ``schedule`` is either one workload schedule (static ``Schedule`` or
    ``JaxSchedule`` pytree — passed to the compiled sweep as a traced
    input) or a ``scenarios.ScenarioSet``, in which case the ``scenario``
    axis picks the generator and every grid point samples its own schedule
    from (seed, scenario) inside the jitted call.

    The *axes* choose each run's fleet mix, bid policy, bid multiple and
    scenario; ``cfg.spot.instance``/``fleet``/``bid_mult`` are not
    consulted (they only apply to single, non-swept runs).
    ``cfg.spot.bid_policy`` *is* the policy of every grid point whose
    ``policy`` axis is the -1 sentinel (the ``make_axes`` default).

    ``chunk_size`` bounds the live batch: the grid is processed in
    micro-batches of that many runs, every chunk padded to the same shape
    so one cached compiled callable (donated input buffers) serves them
    all — no per-chunk recompiles, results concatenated on host.
    ``devices`` caps the local devices sharded over (default: all); each
    chunk is padded to a device multiple and ``pmap``-sharded.

    ``params`` is one ``PolicyParams`` setting broadcast to every grid
    point (default: the config's own values) — the per-point *bid* axis
    still comes from ``axes.bid_mult``, which ``params.bid_mult`` scales.
    """
    _check_axes(cfg, axes, schedule)
    if chunk_size is not None and int(chunk_size) < 1:
        raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
    pp = runner.default_params(cfg) if params is None else params
    is_set = isinstance(schedule, scen_lib.ScenarioSet)
    # The dummy stands in for the (unused) schedule argument when the
    # scenario set generates schedules internally.
    sched = (jnp.zeros((0,)) if is_set else wl.as_jax_schedule(schedule))
    b = int(axes.seed.shape[0])
    avail = len(jax.devices())
    n_dev = avail if devices is None else max(int(devices), 1)
    n_dev = min(n_dev, avail, b)

    if chunk_size is None and n_dev == 1:
        return _sweep_callable(schedule, cfg, 1)(*axes, sched, pp)

    chunk = b if chunk_size is None else min(int(chunk_size), b)
    # Each compiled chunk covers a device multiple of runs.
    chunk = -(-chunk // n_dev) * n_dev
    donating = jax.default_backend() != "cpu"
    fn = _sweep_callable(schedule, cfg, n_dev, donate=True)

    outs = []
    for lo in range(0, b, chunk):
        part = _pad_axes(_slice_axes(axes, lo, min(lo + chunk, b),
                                     copy=donating), chunk)
        if n_dev > 1:
            res = fn(*_device_fold(part, n_dev), sched, pp)
            res = jax.tree.map(
                lambda x: x.reshape((chunk,) + x.shape[2:]), res)
        else:
            res = fn(*part, sched, pp)
        # Off-device before the next chunk so live bytes stay O(chunk).
        outs.append(jax.tree.map(np.asarray, res))

    # Only the *last* chunk can carry padding (`_pad_axes` repeats its
    # final row up to the chunk shape); when the grid divides the chunk
    # size evenly there is none, and the concat/slice round-trip is
    # skipped entirely.
    n_pad = -b % chunk
    fields = []
    for name in RunSummary._fields:
        arrs = [getattr(o, name) for o in outs]
        cat = arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
        if cat.shape[0] != b + n_pad:
            raise AssertionError(
                f"chunked sweep produced {cat.shape[0]} rows for {b} grid "
                f"points (+{n_pad} padding) — padded points would leak "
                "into the summary")
        fields.append(cat[:b] if n_pad else cat)
    return RunSummary(*(jnp.asarray(f) for f in fields))


def run_single(schedule: ScheduleLike, cfg: runner.SimConfig,
               seed: int, bid_mult: float,
               instance: FleetMix = "m3.medium",
               policy: str | int | None = None,
               scenario: int = 0,
               params: PolicyParams | None = None) -> RunSummary:
    """One grid point as a standalone jitted run — the reference the
    vmapped sweep is tested against (and a handy debug entry point).
    With a ``ScenarioSet`` the point's schedule is sampled exactly as the
    sweep would (same per-(seed, scenario) key).  Runs through the cached
    summary-mode entry point: repeated calls with different seeds / bids /
    mixes / same-shape schedules reuse one compiled simulation."""
    itype, mask = _as_mix(instance)
    if policy is None:
        policy = spot.bid_policy_index(cfg.spot.bid_policy)
    if isinstance(schedule, scen_lib.ScenarioSet):
        if not 0 <= int(scenario) < len(schedule):
            raise ValueError(
                f"scenario id {scenario} out of range for the "
                f"{len(schedule)}-scenario set {schedule.names}")
        sched = schedule.sample(scenario,
                                scen_lib.schedule_key(seed, scenario))
    else:
        if int(scenario) != 0:
            raise ValueError(
                f"scenario id {scenario} given, but a plain schedule "
                "provides only scenario 0 — pass a ScenarioSet")
        sched = wl.as_jax_schedule(schedule)
    rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                           policy=policy, mix=jnp.asarray(mask))
    pp = runner.default_params(cfg) if params is None else params
    final, _ = runner.cached_scan(sched, cfg, trace=False,
                                  with_rt=True)(sched, seed, rt, pp)
    return summarize(final, sched, cfg)
