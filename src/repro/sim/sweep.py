"""Vmapped Monte-Carlo experiment harness over the spot-market simulator.

The entire simulation — correlated multi-type market process, billing,
preemption, controller, workload execution — is one pure ``lax.scan``
(``runner.scan_run``), so a cost sweep over seeds × bid levels × bid
policies × fleet mixes is a single ``jax.jit(jax.vmap(...))`` call: one
compile, one device dispatch, every grid point in parallel.  A
3 × 5 × 4 × 2 grid of full 130-tick experiments costs about as much
wall-clock as three sequential runs.

Axes:
  * ``seed``      — Monte-Carlo replication (market + execution noise);
  * ``bid_mult``  — bid as a multiple of the base spot price (the 'ema'
                    policy's EMA multiple and the 'ttc' policy's floor;
                    ignored under 'on_demand');
  * ``policy``    — bid policy (``spot.BID_POLICIES``): static multiple,
                    on-demand cap, TTC-aware, market-aware EMA.  The
                    sentinel -1 defers to ``cfg.spot.bid_policy``;
  * ``itype`` / ``mix`` — fleet mix over the Appendix-A Table V types:
                    ``mix`` is the (T,)-mask of allowed types,  ``itype``
                    the mix's primary type (reported in the trace).  A
                    one-type mask is the classic granularity axis (many
                    m3.medium vs few m4.10xlarge); a wider mask lets every
                    acquisition pick the cheapest-per-CU available type.

Summaries are per-run scalars, so the vmapped output is a struct of
(B,)-shaped arrays — ready for the policy/granularity frontier plots in
``benchmarks.bench_spot`` and ``benchmarks.bench_bidding``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import runner, spot
from . import workloads as wl

FleetMix = Sequence[str | int] | str | int


class SweepAxes(NamedTuple):
    """The flattened experiment grid (B = len of every field)."""

    seed: jnp.ndarray      # (B,) int32
    bid_mult: jnp.ndarray  # (B,) float32
    itype: jnp.ndarray     # (B,) int32 primary type per fleet mix
    policy: jnp.ndarray    # (B,) int32 BID_POLICIES id (-1: use config's)
    mix: jnp.ndarray       # (B, T) float32 fleet-membership masks


class RunSummary(NamedTuple):
    """Per-run scalars (each (B,)-shaped after the vmap)."""

    cost: jnp.ndarray          # $ at last completion; full horizon if
                               # submitted work never finished
    cost_horizon: jnp.ndarray  # $ at the end of the simulation window
    violations: jnp.ndarray    # TTC violations (incl. unfinished workloads)
    preemptions: jnp.ndarray   # instances reclaimed by the market
    finished: jnp.ndarray      # workloads completed
    max_committed: jnp.ndarray # peak control-plane fleet, in CUs
    mean_price: jnp.ndarray    # mean $/quantum of the primary type
    max_price: jnp.ndarray     # worst $/quantum seen (primary type)


def summarize(final, ys, schedule: wl.Schedule,
              cfg: runner.SimConfig) -> RunSummary:
    """Collapse one run's scan outputs to scalars, jnp-pure (vmappable)."""
    work = final.work
    finished = work.t_done >= 0
    return RunSummary(
        cost=runner.cost_at_completion(work, ys["cum_cost"]),
        cost_horizon=ys["cum_cost"][-1],
        violations=runner.count_violations(work, schedule, cfg),
        preemptions=ys["n_preempted"][-1],
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=jnp.max(ys["n_committed"]),
        mean_price=jnp.mean(ys["spot_price"]),
        max_price=jnp.max(ys["spot_price"]),
    )


def _as_mix(entry: FleetMix) -> tuple[int, np.ndarray]:
    """Normalize one fleet-mix spec to (primary itype, (T,) mask)."""
    if isinstance(entry, (str, int)):
        entry = (entry,)
    members = [spot.instance_index(m) if isinstance(m, str) else int(m)
               for m in entry]
    if not members:
        raise ValueError("a fleet mix needs at least one instance type")
    mask = np.zeros((spot.N_TYPES,), np.float32)
    mask[members] = 1.0
    return members[0], mask


def make_axes(seeds: Sequence[int],
              bid_mults: Sequence[float],
              instances: Sequence[FleetMix] = ("m3.medium",),
              policies: Sequence[str | int] | None = None) -> SweepAxes:
    """Cartesian-product grid, flattened to (B,) arrays.

    ``instances`` entries are fleet mixes: a single type name/id (the
    classic granularity axis) or a sequence of them (a heterogeneous
    fleet).  ``policies`` are ``spot.BID_POLICIES`` names/ids; the default
    defers to ``cfg.spot.bid_policy`` at sweep time.  Grid order is
    seeds × bid_mults × policies × mixes, so reshaping a summary field to
    ``(len(seeds), len(bid_mults), len(policies), len(instances))``
    recovers the axes.
    """
    primaries, masks = zip(*(_as_mix(e) for e in instances))
    if policies is None:
        pol_ids = [-1]
    else:
        pol_ids = [spot.bid_policy_index(p) if isinstance(p, str) else int(p)
                   for p in policies]
    s, b, p, m = np.meshgrid(np.asarray(seeds),
                             np.asarray(bid_mults, float),
                             np.asarray(pol_ids),
                             np.arange(len(masks)), indexing="ij")
    mix = np.stack(masks)[m.ravel()]
    return SweepAxes(seed=jnp.asarray(s.ravel(), jnp.int32),
                     bid_mult=jnp.asarray(b.ravel(), jnp.float32),
                     itype=jnp.asarray(np.asarray(primaries)[m.ravel()],
                                       jnp.int32),
                     policy=jnp.asarray(p.ravel(), jnp.int32),
                     mix=jnp.asarray(mix, jnp.float32))


def run_sweep(schedule: wl.Schedule, cfg: runner.SimConfig,
              axes: SweepAxes) -> RunSummary:
    """Every grid point as one jitted ``vmap`` of the full simulation.

    The *axes* choose each run's fleet mix, bid policy and bid multiple;
    ``cfg.spot.instance``/``fleet``/``bid_mult`` are not consulted (they
    only apply to single, non-swept runs).  ``cfg.spot.bid_policy`` *is*
    the policy of every grid point whose ``policy`` axis is the -1
    sentinel (the ``make_axes`` default)."""
    assert cfg.spot.enabled, "run_sweep needs SimConfig.spot.enabled=True"
    # Guard a silent trap: a config that names a non-default instance while
    # the axes (which win) never visit it almost certainly means make_axes
    # was left at its m3.medium default.
    cfg_itype = spot.instance_index(cfg.spot.instance)
    if cfg_itype != 0 and not np.any(np.asarray(axes.mix)[:, cfg_itype] > 0):
        raise ValueError(
            f"SpotConfig.instance={cfg.spot.instance!r} never appears in "
            "the sweep axes, which override the config — pass "
            "instances=[...] to make_axes")
    cfg_policy = spot.bid_policy_index(cfg.spot.bid_policy)

    def one(seed, bid_mult, itype, policy, mix):
        policy = jnp.where(policy < 0, cfg_policy, policy)
        rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                               policy=policy, mix=mix)
        final, ys = runner.scan_run(schedule, cfg, seed=seed, spot_rt=rt)
        return summarize(final, ys, schedule, cfg)

    return jax.jit(jax.vmap(one))(axes.seed, axes.bid_mult, axes.itype,
                                  axes.policy, axes.mix)


def run_single(schedule: wl.Schedule, cfg: runner.SimConfig,
               seed: int, bid_mult: float,
               instance: FleetMix = "m3.medium",
               policy: str | int | None = None) -> RunSummary:
    """One grid point as a standalone jitted run — the reference the
    vmapped sweep is tested against (and a handy debug entry point)."""
    itype, mask = _as_mix(instance)
    if policy is None:
        policy = spot.bid_policy_index(cfg.spot.bid_policy)
    rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                           policy=policy, mix=jnp.asarray(mask))
    final, ys = jax.jit(
        lambda s: runner.scan_run(schedule, cfg, seed=s, spot_rt=rt))(seed)
    return summarize(final, ys, schedule, cfg)
