"""Vmapped Monte-Carlo experiment harness over the spot-market simulator.

The entire simulation — market process, billing, preemption, controller,
workload execution — is one pure ``lax.scan`` (``runner.scan_run``), so a
cost sweep over seeds × bid levels × instance granularities is a single
``jax.jit(jax.vmap(...))`` call: one compile, one device dispatch, every
grid point in parallel.  A 3 × 5 × 6 grid of full 130-tick experiments
costs about as much wall-clock as three sequential runs.

Axes:
  * ``seed``      — Monte-Carlo replication (market + execution noise);
  * ``bid_mult``  — bid as a multiple of the instance's base spot price
                    (ignored under the ``on_demand`` bid policy);
  * ``itype``     — instance granularity (Appendix A Table V): many
                    m3.medium vs few m4.10xlarge for the same CU target.

Summaries are per-run scalars, so the vmapped output is a struct of
(B,)-shaped arrays — ready for the preemption/cost frontier plots in
``benchmarks.bench_spot``.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import runner, spot
from . import workloads as wl


class SweepAxes(NamedTuple):
    """The flattened experiment grid (B = len of every field)."""

    seed: jnp.ndarray      # (B,) int32
    bid_mult: jnp.ndarray  # (B,) float32
    itype: jnp.ndarray     # (B,) int32 index into the Table-V arrays


class RunSummary(NamedTuple):
    """Per-run scalars (each (B,)-shaped after the vmap)."""

    cost: jnp.ndarray          # $ at last completion; full horizon if
                               # submitted work never finished
    cost_horizon: jnp.ndarray  # $ at the end of the simulation window
    violations: jnp.ndarray    # TTC violations (incl. unfinished workloads)
    preemptions: jnp.ndarray   # instances reclaimed by the market
    finished: jnp.ndarray      # workloads completed
    max_committed: jnp.ndarray # peak control-plane fleet, in CUs
    mean_price: jnp.ndarray    # mean $/quantum the market charged
    max_price: jnp.ndarray     # worst $/quantum seen


def summarize(final, ys, schedule: wl.Schedule,
              cfg: runner.SimConfig) -> RunSummary:
    """Collapse one run's scan outputs to scalars, jnp-pure (vmappable)."""
    work = final.work
    finished = work.t_done >= 0
    return RunSummary(
        cost=runner.cost_at_completion(work, ys["cum_cost"]),
        cost_horizon=ys["cum_cost"][-1],
        violations=runner.count_violations(work, schedule, cfg),
        preemptions=ys["n_preempted"][-1],
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=jnp.max(ys["n_committed"]),
        mean_price=jnp.mean(ys["spot_price"]),
        max_price=jnp.max(ys["spot_price"]),
    )


def make_axes(seeds: Sequence[int],
              bid_mults: Sequence[float],
              instances: Sequence[str | int] = ("m3.medium",)) -> SweepAxes:
    """Cartesian-product grid, flattened to (B,) arrays."""
    itypes = [spot.instance_index(i) if isinstance(i, str) else int(i)
              for i in instances]
    s, b, i = np.meshgrid(np.asarray(seeds), np.asarray(bid_mults, float),
                          np.asarray(itypes), indexing="ij")
    return SweepAxes(seed=jnp.asarray(s.ravel(), jnp.int32),
                     bid_mult=jnp.asarray(b.ravel(), jnp.float32),
                     itype=jnp.asarray(i.ravel(), jnp.int32))


def run_sweep(schedule: wl.Schedule, cfg: runner.SimConfig,
              axes: SweepAxes) -> RunSummary:
    """Every grid point as one jitted ``vmap`` of the full simulation.

    The *axes* choose each run's instance type and bid multiple;
    ``cfg.spot.instance``/``bid_mult`` are not consulted (they only apply
    to single, non-swept runs)."""
    assert cfg.spot.enabled, "run_sweep needs SimConfig.spot.enabled=True"
    # Guard a silent trap: a config that names a non-default instance while
    # the axes (which win) never visit it almost certainly means make_axes
    # was left at its m3.medium default.
    cfg_itype = spot.instance_index(cfg.spot.instance)
    if cfg_itype != 0 and not np.any(np.asarray(axes.itype) == cfg_itype):
        raise ValueError(
            f"SpotConfig.instance={cfg.spot.instance!r} never appears in "
            "the sweep axes, which override the config — pass "
            "instances=[...] to make_axes")

    def one(seed, bid_mult, itype):
        rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult)
        final, ys = runner.scan_run(schedule, cfg, seed=seed, spot_rt=rt)
        return summarize(final, ys, schedule, cfg)

    return jax.jit(jax.vmap(one))(axes.seed, axes.bid_mult, axes.itype)


def run_single(schedule: wl.Schedule, cfg: runner.SimConfig,
               seed: int, bid_mult: float,
               instance: str | int = "m3.medium") -> RunSummary:
    """One grid point as a standalone jitted run — the reference the
    vmapped sweep is tested against (and a handy debug entry point)."""
    itype = (spot.instance_index(instance) if isinstance(instance, str)
             else int(instance))
    rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult)
    final, ys = jax.jit(
        lambda s: runner.scan_run(schedule, cfg, seed=s, spot_rt=rt))(seed)
    return summarize(final, ys, schedule, cfg)
