"""Mesh-sharded, disk-streaming, resumable sweeps over the simulator.

The entire simulation — correlated multi-type market process, billing,
preemption, controller, workload execution — is one pure ``lax.scan``
(``runner.scan_run``), so a cost sweep over seeds × bid levels × bid
policies × fleet mixes × workload scenarios is a single
``jax.jit(jax.vmap(...))`` call: one compile, one device dispatch, every
grid point in parallel.  Sweeps run the scan in **summary mode**
(``runner.scan_run(trace=False)``): the eight per-run scalars accumulate
inside the scan carry and the scan emits no per-tick outputs, so a B-point
grid moves O(B) floats instead of the O(B·T·W·K) a stacked trace would.

The public entry point is one facade over one frozen spec::

    spec = SweepSpec(axes=make_axes(...), workload=schedule_or_set,
                     chunk_size=1024, devices=4, stream_dir="out/sweep")
    result = sweep(spec, cfg)

``SweepSpec`` bundles the experiment grid (:class:`SweepAxes`), the
workload world (a static schedule, a ``scenarios.ScenarioSet``, or a
``tenants.TenantSet`` for shared-fleet runs) and the execution options —
validated in exactly one place (``SweepSpec.__post_init__``):

  * ``chunk_size`` — micro-batch the B axis: every chunk is padded to one
    shape and pushed through one cached compiled callable (one compile for
    any grid size, live memory bounded by the chunk);
  * ``devices`` / ``mesh`` — shard each chunk's B axis over a 1-D
    ``("batch",)`` device mesh (``launch.mesh.make_sweep_mesh``) with
    ``jax.shard_map``, every device vmapping its shard (no collectives).
    Chunks are padded up to a device multiple — explicitly, and asserted
    never to reach a result;
  * ``stream_dir`` — stream each completed chunk's summaries to disk
    (atomic ``checkpoint.checkpointer`` chunk files + a manifest) instead
    of returning in-memory arrays: ``sweep`` then returns a
    :class:`SweepStream` handle, an interrupted sweep resumes from the
    last committed chunk, and peak host memory stays O(chunk) no matter
    the grid size.

``run_sweep`` / ``tenants.tenant_sweep`` survive as thin deprecated
wrappers that build the equivalent ``SweepSpec``; ``run_single`` is the
loop-of-one reference the vmapped engine is tested against.

Axes:
  * ``seed``      — Monte-Carlo replication (market + execution noise +
                    scenario sampling);
  * ``bid_mult``  — bid as a multiple of the base spot price (the 'ema'
                    policy's EMA multiple and the 'ttc' policy's floor;
                    ignored under 'on_demand');
  * ``policy``    — bid policy (``spot.BID_POLICIES``): static multiple,
                    on-demand cap, TTC-aware, market-aware EMA.  The
                    sentinel -1 defers to ``cfg.spot.bid_policy``;
  * ``itype`` / ``mix`` — fleet mix over the Appendix-A Table V types:
                    ``mix`` is the (T,)-mask of allowed types,  ``itype``
                    the mix's primary type (reported in the trace);
  * ``scenario``  — which workload world the run lives in.  With a
                    ``scenarios.ScenarioSet`` the id picks the generator
                    (``lax.switch``) and each grid point samples its own
                    schedule from (seed, scenario); with a plain
                    ``Schedule`` or a ``TenantSet`` the axis must be
                    all-zero.

Schedules are *traced pytree inputs* of the compiled sweep, not constants
closed over at trace time: compilation caches key on the schedule's shape
(``workloads.schedule_shape``) or on the scenario/tenant specs, so two
schedules of one shape — or any number of generated scenarios — share one
compile.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
import warnings
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from ..checkpoint import checkpointer
from ..core.types import PolicyParams
from ..launch import mesh as mesh_lib
from . import faults as faults_lib
from . import runner, spot
from . import scenarios as scen_lib
from . import workloads as wl

FleetMix = Sequence[str | int] | str | int
ScheduleLike = "wl.Schedule | wl.JaxSchedule | scen_lib.ScenarioSet"


class SweepAxes(NamedTuple):
    """The flattened experiment grid (B = len of every field)."""

    seed: jnp.ndarray      # (B,) int32
    bid_mult: jnp.ndarray  # (B,) float32
    itype: jnp.ndarray     # (B,) int32 primary type per fleet mix
    policy: jnp.ndarray    # (B,) int32 BID_POLICIES id (-1: use config's)
    mix: jnp.ndarray       # (B, T) float32 fleet-membership masks
    scenario: jnp.ndarray  # (B,) int32 scenario id (0 = first/only)


class RunSummary(NamedTuple):
    """Per-run scalars (each (B,)-shaped after the vmap)."""

    cost: jnp.ndarray          # $ at last completion; full horizon if
                               # submitted work never finished
    cost_horizon: jnp.ndarray  # $ at the end of the simulation window
    violations: jnp.ndarray    # TTC violations (incl. unfinished workloads)
    preemptions: jnp.ndarray   # instances reclaimed by the market
    finished: jnp.ndarray      # workloads completed
    max_committed: jnp.ndarray # peak control-plane fleet, in CUs
    mean_price: jnp.ndarray    # mean $/quantum of the primary type
    max_price: jnp.ndarray     # worst $/quantum seen (primary type)
    # Total in-scan detector alerts (obs.detect).  ``None`` — a leafless
    # pytree, absent from compiled programs and chunk files — whenever the
    # config carries no detector spec, so every pre-detector summary
    # consumer (digests, streams, parity tests) is untouched.
    alerts: jnp.ndarray | None = None


def _alert_count(final) -> jnp.ndarray | None:
    """Total detector alerts from the final carry — ``None`` (leafless)
    unless the run carried ``ObsSpec.detect`` registers."""
    obs_c = getattr(final, "obs", None)
    if obs_c is None or getattr(obs_c, "detect", None) is None:
        return None
    return jnp.sum(obs_c.detect.n_alerts).astype(jnp.int32)


def summarize(final, schedule: wl.Schedule | wl.JaxSchedule,
              cfg: runner.SimConfig,
              valid: jnp.ndarray | None = None) -> RunSummary:
    """Read one run's summary out of the final scan carry, jnp-pure.

    Every statistic was accumulated inside the scan (``runner.SummaryCarry``
    plus the cost/preemption registers ``ClusterState`` already carries), so
    this needs no per-tick trace — it is the read-out both trace- and
    summary-mode runs share, which is what makes the two modes bit-identical
    by construction.

    ``valid`` is the explicit workload-valid mask (default: the schedule's
    own): padded rows are excluded from the finished count, the violation
    count and the cost-at-completion endpoint, so a generated scenario's
    padding can never inflate — or deflate — a summary.
    """
    sched = wl.as_jax_schedule(schedule)
    if valid is None:
        valid = sched.valid
    work = final.work
    submitted = (work.t_submit >= 0) & valid
    finished = (work.t_done >= 0) & valid
    unfinished = jnp.any(submitted & ~finished)
    t_end = jnp.max(jnp.where(valid, work.t_done, -1))
    # ``cost_at_done`` is the trace's ``cum_cost[t_end + 1]``; the register
    # never fired when nothing finished, a completion landed on the last
    # tick, or submitted work is still running — all cases the trace-mode
    # ``cost_at_completion`` resolves to the full-horizon bill.  The
    # register tracks the *unmasked* last completion, so if an explicit
    # ``valid`` hides a later-finishing row it holds the wrong endpoint —
    # bill to the horizon then too (conservative; never under-reports).
    # With the default mask this never triggers: padding cannot finish.
    register_stale = t_end != jnp.max(work.t_done)
    use_horizon = (unfinished | (t_end < 0) | (t_end + 1 > cfg.ticks - 1)
                   | register_stale)
    cost = jnp.where(use_horizon, final.cluster.cum_cost,
                     final.summ.cost_at_done)
    return RunSummary(
        cost=cost,
        cost_horizon=final.cluster.cum_cost,
        violations=runner.count_violations(work, sched, cfg, valid=valid),
        preemptions=final.cluster.n_preempt,
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=final.summ.max_committed,
        mean_price=final.summ.price_sum / cfg.ticks,
        max_price=final.summ.price_max,
        alerts=_alert_count(final),
    )


def summarize_trace(final, ys, schedule: wl.Schedule | wl.JaxSchedule,
                    cfg: runner.SimConfig,
                    valid: jnp.ndarray | None = None) -> RunSummary:
    """Collapse a *trace-mode* run's stacked scan outputs to scalars.

    The pre-summary-mode implementation, kept as the independent reference
    the carry registers are tested against (``tests/test_throughput.py``).
    ``mean_price`` is the only field whose reduction order differs from the
    in-carry accumulation (parallel vs sequential float sum); everything
    else is bit-identical.
    """
    sched = wl.as_jax_schedule(schedule)
    if valid is None:
        valid = sched.valid
    work = final.work
    finished = (work.t_done >= 0) & valid
    return RunSummary(
        cost=runner.cost_at_completion(work, ys["cum_cost"], valid=valid),
        cost_horizon=ys["cum_cost"][-1],
        violations=runner.count_violations(work, sched, cfg, valid=valid),
        preemptions=ys["n_preempted"][-1],
        finished=jnp.sum(finished.astype(jnp.int32)),
        max_committed=jnp.max(ys["n_committed"]),
        mean_price=jnp.mean(ys["spot_price"]),
        max_price=jnp.max(ys["spot_price"]),
        alerts=_alert_count(final),
    )


def _as_mix(entry: FleetMix) -> tuple[int, np.ndarray]:
    """Normalize one fleet-mix spec to (primary itype, (T,) mask)."""
    if isinstance(entry, (str, int)):
        entry = (entry,)
    members = [spot.instance_index(m) if isinstance(m, str) else int(m)
               for m in entry]
    if not members:
        raise ValueError("a fleet mix needs at least one instance type")
    mask = np.zeros((spot.N_TYPES,), np.float32)
    mask[members] = 1.0
    return members[0], mask


def _scenario_ids(scenarios) -> list[int]:
    """Normalize the ``scenarios`` argument of ``make_axes`` to id list."""
    if scenarios is None:
        return [0]
    if isinstance(scenarios, int):
        return list(range(scenarios))
    if isinstance(scenarios, scen_lib.ScenarioSet):
        return list(range(len(scenarios)))
    return [int(s) for s in scenarios]


def make_axes(seeds: Sequence[int],
              bid_mults: Sequence[float],
              instances: Sequence[FleetMix] = ("m3.medium",),
              policies: Sequence[str | int] | None = None,
              scenarios=None) -> SweepAxes:
    """Cartesian-product grid, flattened to (B,) arrays.

    ``instances`` entries are fleet mixes: a single type name/id (the
    classic granularity axis) or a sequence of them (a heterogeneous
    fleet).  ``policies`` are ``spot.BID_POLICIES`` names/ids; the default
    defers to ``cfg.spot.bid_policy`` at sweep time.  ``scenarios`` is the
    workload-world axis: a ``scenarios.ScenarioSet`` (enumerated), a count,
    or explicit ids; the default is the single scenario 0.  Grid order is
    seeds × bid_mults × policies × mixes × scenarios, so reshaping a
    summary field to ``(len(seeds), len(bid_mults), len(policies),
    len(instances), n_scenarios)`` recovers the axes.
    """
    primaries, masks = zip(*(_as_mix(e) for e in instances))
    if policies is None:
        pol_ids = [-1]
    else:
        pol_ids = [spot.bid_policy_index(p) if isinstance(p, str) else int(p)
                   for p in policies]
    scen_ids = _scenario_ids(scenarios)
    s, b, p, m, c = np.meshgrid(np.asarray(seeds),
                                np.asarray(bid_mults, float),
                                np.asarray(pol_ids),
                                np.arange(len(masks)),
                                np.asarray(scen_ids), indexing="ij")
    mix = np.stack(masks)[m.ravel()]
    return SweepAxes(seed=jnp.asarray(s.ravel(), jnp.int32),
                     bid_mult=jnp.asarray(b.ravel(), jnp.float32),
                     itype=jnp.asarray(np.asarray(primaries)[m.ravel()],
                                       jnp.int32),
                     policy=jnp.asarray(p.ravel(), jnp.int32),
                     mix=jnp.asarray(mix, jnp.float32),
                     scenario=jnp.asarray(c.ravel(), jnp.int32))


# --------------------------------------------------------------------------
# The unified spec: one frozen object holds the grid, the workload world
# and every execution option, validated in exactly one place.

def _is_tenant_set(workload) -> bool:
    # Lazy import: sim.tenants imports this module.
    from . import tenants as tenants_lib
    return isinstance(workload, tenants_lib.TenantSet)


@dataclasses.dataclass(frozen=True, eq=False)
class SweepSpec:
    """Everything one sweep needs, besides the ``SimConfig``.

    ``axes`` is the flattened grid (``make_axes``); ``workload`` the world
    every grid point runs in — a static ``workloads.Schedule`` /
    ``JaxSchedule``, a ``scenarios.ScenarioSet`` (the ``scenario`` axis
    picks the generator, each point samples its own schedule from (seed,
    scenario)), or a ``tenants.TenantSet`` (shared-fleet runs returning a
    ``TenantRun`` instead of a ``RunSummary``); ``params`` one
    ``PolicyParams`` pytree broadcast to every point (default: the
    config's own coefficients).

    Execution options (keyword-only, validated here and nowhere else):

      * ``chunk_size`` — micro-batch size (≥ 1).  ``None`` = whole grid in
        one batch.  Chunks are padded up to one common, device-divisible
        shape; padded rows are asserted never to reach a result or a chunk
        file.
      * ``devices`` — shard each chunk over this many local devices (≥ 1,
        capped at the host's device count and the grid size) via
        ``jax.shard_map`` on a 1-D ``("batch",)`` mesh.  ``None`` = all
        local devices.  Mutually exclusive with ``mesh``.
      * ``mesh`` — an explicit 1-axis ``jax.sharding.Mesh`` to shard over
        instead (e.g. ``launch.mesh.make_sweep_mesh()``).
      * ``stream_dir`` — stream completed chunks to this directory instead
        of returning in-memory arrays: ``sweep`` returns a
        :class:`SweepStream` handle (call ``.load()`` to materialize), and
        an interrupted sweep re-run with the same spec resumes from the
        last committed chunk.
      * ``resume`` — with ``stream_dir``: reuse committed chunks found in
        the directory (the default).  ``False`` discards them and
        recomputes from scratch.
      * ``profile`` — wrap the result in a :class:`SweepReport` carrying
        per-chunk wall-clock (compile vs execute vs chunk-write), the XLA
        peak-bytes estimate, and a Perfetto trace exporter
        (``report.write_trace``).
    """

    axes: SweepAxes
    workload: object
    params: PolicyParams | None = None
    # Traced fault intensities (``sim.faults``): a ``FaultSpec`` whose
    # leaves are scalars (one chaos world for the whole grid) or
    # (B,)-leading arrays (fault timing/intensity as a first-class sweep
    # axis — chaos sweeps chunk/shard/stream like everything else).
    # Requires ``SimConfig.faults`` to be set; None rides the fault-free
    # spec when the config enables the engine.
    faults: "faults_lib.FaultSpec | None" = None
    chunk_size: int | None = dataclasses.field(default=None, kw_only=True)
    devices: int | None = dataclasses.field(default=None, kw_only=True)
    mesh: Mesh | None = dataclasses.field(default=None, kw_only=True)
    stream_dir: str | os.PathLike | None = dataclasses.field(
        default=None, kw_only=True)
    resume: bool = dataclasses.field(default=True, kw_only=True)
    # Runtime profiling (``repro.obs`` plane iii): per-chunk wall clock
    # with the compile vs execute split (AOT ``lower().compile()`` on the
    # first chunk), XLA peak-bytes estimate, and — when streaming — the
    # chunk-write time.  ``sweep`` then returns a :class:`SweepReport`
    # wrapping the unchanged result; the stream manifest gains a
    # ``"profile"`` record.  Off by default: an unprofiled sweep takes the
    # exact pre-profiling code path (no timing calls around the dispatch).
    profile: bool = dataclasses.field(default=False, kw_only=True)

    def __post_init__(self):
        # THE validation point for every execution option (the per-function
        # ad-hoc checks the old run_sweep grew are all retired into here).
        if not isinstance(self.axes, SweepAxes):
            raise TypeError(
                f"axes must be a SweepAxes (see make_axes), got "
                f"{type(self.axes).__name__}")
        b = int(np.shape(self.axes.seed)[0])
        if b < 1:
            raise ValueError("the sweep grid is empty (B = 0)")
        lens = {f: int(np.shape(getattr(self.axes, f))[0])
                for f in SweepAxes._fields}
        if set(lens.values()) != {b}:
            raise ValueError(
                f"axes fields disagree on the grid size: {lens}")
        if self.faults is not None:
            if not isinstance(self.faults, faults_lib.FaultSpec):
                raise TypeError(
                    f"faults must be a FaultSpec (see "
                    f"sim.faults.make_fault_spec), got "
                    f"{type(self.faults).__name__}")
            for name, leaf in zip(faults_lib.FaultSpec._fields, self.faults):
                shape = np.shape(leaf)
                if shape not in ((), (b,)) and not (
                        len(shape) >= 1 and shape[0] == b):
                    raise ValueError(
                        f"FaultSpec.{name} must be a scalar or lead with "
                        f"the grid axis B={b}, got shape {shape}")
        if self.chunk_size is not None and int(self.chunk_size) < 1:
            raise ValueError(
                f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.devices is not None and int(self.devices) < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.mesh is not None:
            if self.devices is not None:
                raise ValueError(
                    "pass either devices= or mesh=, not both")
            if len(self.mesh.axis_names) != 1:
                raise ValueError(
                    "the sweep mesh must have exactly one (batch) axis, "
                    f"got axes {self.mesh.axis_names} — use "
                    "launch.mesh.make_sweep_mesh")
        if self.stream_dir is not None:
            sd = os.fspath(self.stream_dir)
            if not sd:
                raise ValueError("stream_dir must be a non-empty path")
            if os.path.isfile(sd):
                raise ValueError(f"stream_dir {sd!r} is a file")

    @property
    def n_points(self) -> int:
        return int(np.shape(self.axes.seed)[0])


# --------------------------------------------------------------------------
# Per-point programs (the in-jit surface ``repro.opt`` builds on).

def _check_axes(cfg: runner.SimConfig, axes: SweepAxes,
                workload=None) -> None:
    """Config-dependent grid validation shared by every executor entry."""
    if not cfg.spot.enabled:
        raise ValueError("sweeps need SimConfig.spot.enabled=True")
    # Guard a silent trap: a config that names a non-default instance while
    # the axes (which win) never visit it almost certainly means make_axes
    # was left at its m3.medium default.  Tenant sweeps are exempt — their
    # legacy entry points always defaulted the fleet to m3.medium
    # regardless of the config, and the committed baselines pin that.
    cfg_itype = spot.instance_index(cfg.spot.instance)
    if (cfg_itype != 0 and not _is_tenant_set(workload)
            and not np.any(np.asarray(axes.mix)[:, cfg_itype] > 0)):
        raise ValueError(
            f"SpotConfig.instance={cfg.spot.instance!r} never appears in "
            "the sweep axes, which override the config — pass "
            "instances=[...] to make_axes")
    n_scen = (len(workload)
              if isinstance(workload, scen_lib.ScenarioSet) else 1)
    scen = np.asarray(axes.scenario)
    if scen.size and (scen.min() < 0 or scen.max() >= n_scen):
        raise ValueError(
            f"scenario axis references id {int(scen.max())} but the "
            f"workload provides {n_scen} scenario(s) — pass a ScenarioSet "
            "and scenarios=... to make_axes")


def _point_sched(cfg: runner.SimConfig, trace: bool = False):
    """One grid point with the schedule as an explicit (traced) argument —
    the single definition of what a sweep runs per point (policy-sentinel
    resolution, runtime construction, scan, masked summary).  ``params``
    is the traced ``PolicyParams`` pytree every run consumes (its relative
    ``bid_mult`` multiplies this point's bid-multiple axis).  With the
    chaos engine on (``cfg.faults``) the closure accepts a trailing traced
    ``FaultSpec`` (default: the fault-free spec)."""
    cfg_policy = spot.bid_policy_index(cfg.spot.bid_policy)

    def one(sched, seed, bid_mult, itype, policy, mix, params, fspec=None):
        policy = jnp.where(policy < 0, cfg_policy, policy)
        rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                               policy=policy, mix=mix)
        final, ys = runner.scan_run(sched, cfg, seed=seed, spot_rt=rt,
                                    trace=trace, params=params, fspec=fspec)
        summary = summarize(final, sched, cfg)
        return (summary, ys) if trace else summary

    return one


def point_fn(schedule: ScheduleLike, cfg: runner.SimConfig,
             trace: bool = False):
    """One grid point as a vmappable closure of (seed, bid_mult, itype,
    policy, mix, scenario, params) — the low-level *in-jit* program the
    executor vmaps and ``repro.opt`` builds objectives from (host-side
    callers should go through ``sweep(SweepSpec(...), cfg)`` instead).
    With a ``ScenarioSet`` the scenario id picks the generator and the
    schedule is sampled per (seed, scenario) inside the trace; with a
    plain schedule the id is ignored.  ``params`` is the (traced)
    ``PolicyParams`` pytree — the tuner in ``repro.opt`` vmaps candidate
    populations over exactly this argument.  ``trace=True`` additionally
    returns the per-tick ``ys`` (what ``benchmarks.bench_throughput``
    sizes the trace-mode baseline with)."""
    base = _point_sched(cfg, trace=trace)
    if isinstance(schedule, scen_lib.ScenarioSet):
        sset = schedule

        def one(seed, bid_mult, itype, policy, mix, scenario, params,
                fspec=None):
            sched = sset.sample(scenario,
                                scen_lib.schedule_key(seed, scenario))
            return base(sched, seed, bid_mult, itype, policy, mix, params,
                        fspec)

        return one

    sj = wl.as_jax_schedule(schedule)

    def one(seed, bid_mult, itype, policy, mix, scenario, params,
            fspec=None):
        del scenario
        return base(sj, seed, bid_mult, itype, policy, mix, params, fspec)

    return one


# --------------------------------------------------------------------------
# The compiled chunk program: vmap over the chunk's rows, shard_map over
# the batch mesh when it spans more than one device.

def _sweep_callable(workload, cfg: runner.SimConfig,
                    mesh: Mesh | None, donate: bool = False):
    """Cached compiled sweep over a fixed-shape batch of axes.

    One entry per (scenario set | tenant set | schedule shape, cfg, mesh,
    donation): chunked sweeps reuse it for every micro-batch and *every
    same-shape schedule*, so a 10⁵-point grid — or a loop over many
    schedules — compiles exactly once.  The returned callable takes
    ``(*axes_fields, sched, params)`` (``sched`` ignored under a
    ScenarioSet/TenantSet, whose generators are compiled in).  With
    ``donate=True`` the axis buffers are donated — each chunk's inputs are
    freed the moment the device is done with them (the chunked path passes
    per-chunk copies, never the caller's arrays; donation is a no-op on
    CPU, where XLA ignores it, so it is requested only on accelerator
    backends); the schedule argument is never donated.  With a multi-device
    ``mesh`` the chunk's B axis is partitioned over the mesh's ``batch``
    axis by ``jax.shard_map`` — each device vmaps its shard of full
    simulations, schedule and params fully replicated, no collectives — so
    the same compiled program scales from 1 host CPU to a real accelerator
    mesh.  Results come back as ordinary global (B,)-leading arrays: no
    device-axis reshapes, directly host-transferable.
    """
    donate = donate and jax.default_backend() != "cpu"
    mesh = None if (mesh is not None and mesh.size == 1) else mesh
    # Key on the config with the PolicyParams-traced leaves struck out:
    # the params pytree is a broadcast *argument* of the compiled sweep,
    # so sweeps at different tuned coefficients share one compile.
    # ``cfg.faults`` is part of that key (it survives strip_tuned), and it
    # also decides the callable's arity: with the chaos engine on, the
    # callable takes a trailing (B,)-leaved ``FaultSpec`` batch.
    mesh_key = 1 if mesh is None else mesh
    chaos = cfg.faults is not None
    if isinstance(workload, scen_lib.ScenarioSet):
        cfg_key = runner.strip_tuned(cfg)
        key = ("sweep", workload, cfg_key, mesh_key, donate)
        sched_key_fn = point_fn(workload, cfg)

        def pt(seed, bid_mult, itype, policy, mix, scenario, sched, params,
               *fs):
            del sched
            return sched_key_fn(seed, bid_mult, itype, policy, mix, scenario,
                                params, *fs)
    elif _is_tenant_set(workload):
        from . import tenants as tenants_lib
        scfg = workload.sim_config(cfg)
        cfg_key = runner.strip_tuned(scfg)
        key = ("sweep", workload, cfg_key, mesh_key, donate)
        tenant_fn = tenants_lib.point_fn(workload, cfg)

        def pt(seed, bid_mult, itype, policy, mix, scenario, sched, params,
               *fs):
            del sched
            return tenant_fn(seed, bid_mult, itype, policy, mix, scenario,
                             params, *fs)
    else:
        cfg_key = runner.strip_tuned(cfg)
        key = ("sweep", wl.schedule_shape(workload), cfg_key, mesh_key,
               donate)
        base = _point_sched(cfg)

        def pt(seed, bid_mult, itype, policy, mix, scenario, sched, params,
               *fs):
            del scenario
            return base(sched, seed, bid_mult, itype, policy, mix, params,
                        *fs)

    fn = runner._JIT_CACHE.get(key)
    if fn is not None:
        return fn
    in_axes = (0, 0, 0, 0, 0, 0, None, None) + ((0,) if chaos else ())
    batched = jax.vmap(pt, in_axes=in_axes)
    if mesh is not None:
        p_b = PartitionSpec(mesh.axis_names[0])
        p_r = PartitionSpec()
        batched = shard_map(
            batched, mesh=mesh,
            in_specs=(p_b,) * 6 + (p_r, p_r) + ((p_b,) if chaos else ()),
            out_specs=p_b, check_rep=False)
    donate_kw = dict(donate_argnums=(0, 1, 2, 3, 4, 5)) if donate else {}
    fn = jax.jit(batched, **donate_kw)
    runner._cache_put(key, fn)
    return fn


def _pad_axes(axes: SweepAxes, n: int) -> SweepAxes:
    """Pad the B axis up to ``n`` rows by repeating the last row (the
    padded results are sliced off — and asserted gone — before any result
    or chunk file is produced)."""
    b = axes.seed.shape[0]
    if b == n:
        return axes
    return SweepAxes(*(jnp.pad(f, [(0, n - b)] + [(0, 0)] * (f.ndim - 1),
                               mode="edge") for f in axes))


def _slice_axes(axes: SweepAxes, lo: int, hi: int,
                copy: bool = True) -> SweepAxes:
    # With ``copy`` (accelerator backends) the slices are fresh buffers,
    # never views of the caller's arrays: the chunked path donates its
    # input buffers to the compiled sweep.  On CPU donation is off, so the
    # defensive copy would be pure waste — plain slices suffice.
    if not copy:
        return SweepAxes(*(f[lo:hi] for f in axes))
    return SweepAxes(*(jnp.array(f[lo:hi], copy=True) for f in axes))


def _norm_faults(spec: SweepSpec, cfg: runner.SimConfig, b: int):
    """Resolve the spec's fault axis against the config's chaos switch.

    Returns ``None`` when the engine is off, else a ``FaultSpec`` whose
    every leaf is (B,)-leading float32 — scalars broadcast so the fault
    axis chunks/shards/pads exactly like the other sweep axes."""
    if cfg.faults is None:
        if spec.faults is not None:
            raise ValueError(
                "SweepSpec.faults is set but SimConfig.faults is None — "
                "the chaos engine compiles in via the config (set "
                "cfg.faults=FaultConfig()), the spec only carries the "
                "traced intensities")
        return None
    fs = (faults_lib.make_fault_spec() if spec.faults is None
          else spec.faults)
    return faults_lib.FaultSpec(*(
        jnp.broadcast_to(jnp.asarray(f, jnp.float32), (b,) + np.shape(f))
        if np.ndim(f) == 0 else jnp.asarray(f, jnp.float32) for f in fs))


def _pad_fspec(fspec, b: int, n: int):
    """Pad a (B,)-leading ``FaultSpec`` batch to ``n`` rows (edge mode,
    mirroring ``_pad_axes``; padded rows never reach a result)."""
    if fspec is None or b == n:
        return fspec
    return jax.tree.map(
        lambda f: jnp.pad(f, [(0, n - b)] + [(0, 0)] * (f.ndim - 1),
                          mode="edge"), fspec)


def _slice_fspec(fspec, lo: int, hi: int):
    # The fault batch is never donated (donate_argnums stops at the axes),
    # so plain slices suffice on every backend.
    if fspec is None:
        return None
    return jax.tree.map(lambda f: f[lo:hi], fspec)


def _take_rows(host_tree, rows: int, chunk: int, where: str):
    """Slice one computed chunk down to its live rows, asserting that the
    compiled call produced exactly the padded chunk shape — the guarantee
    that ``_pad_axes``'s repeated rows can never leak into a summary or a
    written chunk file."""
    def cut(leaf):
        if leaf.shape[0] != chunk:
            raise AssertionError(
                f"sweep chunk produced {leaf.shape[0]} rows where the "
                f"padded chunk shape is {chunk} — padded points would leak "
                f"into {where}")
        return leaf[:rows] if rows != chunk else leaf

    return jax.tree.map(cut, host_tree)


# --------------------------------------------------------------------------
# Runtime profiling (SweepSpec.profile): per-chunk timings + memory.

@dataclasses.dataclass(frozen=True)
class ChunkProfile:
    """One micro-batch's runtime profile (``SweepSpec.profile=True``).

    ``compile_s`` is non-zero only on the chunk that triggered the AOT
    compile (all chunks share one padded shape, hence one executable);
    ``write_s`` only on streamed sweeps (the atomic chunk-file commit);
    ``resumed`` marks chunks a streamed sweep found already committed —
    their timings are zero because no work was re-done.
    """

    chunk: int
    rows: int
    compile_s: float = 0.0
    execute_s: float = 0.0
    write_s: float = 0.0
    peak_bytes: int | None = None   # XLA memory_analysis (temp+out+args)
    resumed: bool = False


@dataclasses.dataclass(frozen=True)
class SweepReport:
    """A profiled sweep's result plus its per-chunk runtime profile.

    ``result`` is exactly what the unprofiled ``sweep`` call would have
    returned (a summary pytree, or a :class:`SweepStream` handle when
    streaming) — profiling wraps, never alters.
    """

    result: object
    chunks: list          # [ChunkProfile] in chunk order
    total_s: float        # executor wall clock, compile + dispatch + I/O

    def write_trace(self, path) -> None:
        """Render the chunk timeline as Chrome/Perfetto trace-event JSON
        (one complete span per chunk; open in ui.perfetto.dev)."""
        from ..obs import export
        export.write_trace(path, export.sweep_trace_events(self.chunks))


def _peak_bytes(compiled) -> int | None:
    """XLA's peak-memory estimate for one compiled chunk executable
    (temp + output + argument bytes; None where the backend offers no
    analysis) — same convention as ``benchmarks.bench_throughput``."""
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    sizes = [getattr(ma, k, None) for k in
             ("temp_size_in_bytes", "output_size_in_bytes",
              "argument_size_in_bytes")]
    if any(s is None for s in sizes):
        return None
    return int(sum(sizes))


# --------------------------------------------------------------------------
# Streaming executor: chunk files + manifest, resumable after a kill.

_MANIFEST = "sweep_manifest.json"
_STREAM_SCHEMA = 1


def _workload_token(workload) -> str:
    """A process-stable identity string for the manifest (guards a
    stream_dir against being resumed with a different sweep)."""
    if isinstance(workload, scen_lib.ScenarioSet):
        return f"scenarios:{','.join(workload.names)}:{workload.max_w}"
    if _is_tenant_set(workload):
        return (f"tenants:{','.join(workload.names)}:"
                f"{workload.n}x{workload.max_w}")
    sched = wl.as_jax_schedule(workload)
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(sched):
        h.update(np.asarray(leaf).tobytes())
    return f"schedule:{h.hexdigest()[:16]}"


def _spec_digest(axes: SweepAxes, b: int, chunk: int, cfg_token: str,
                 workload_token: str, pp, fspec=None) -> str:
    h = hashlib.sha256()
    h.update(f"{b}:{chunk}:{cfg_token}:{workload_token}".encode())
    for f in axes:
        h.update(np.asarray(f).tobytes())
    for leaf in jax.tree.leaves(pp):
        h.update(np.asarray(leaf).tobytes())
    if fspec is not None:
        # The fault axis is part of the sweep's identity: resuming a chaos
        # stream with different fault intensities must be refused.
        for leaf in jax.tree.leaves(fspec):
            h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True, eq=False)
class SweepStream:
    """Handle to a streamed sweep's on-disk result.

    The executor wrote one atomic chunk file per micro-batch
    (``checkpoint.checkpointer`` layout: ``step_<i>/`` + ``.done``
    marker); this handle reads them back.  ``load()`` concatenates every
    chunk into the exact pytree the in-memory path would have returned —
    bit-identical, the contract ``tests/test_sweepspec.py`` pins —
    while ``load_chunk(i)`` keeps peak memory at one chunk for
    reduce-style consumers.
    """

    directory: str
    n_points: int
    chunk_size: int      # padded rows per full chunk
    n_chunks: int
    manifest: dict = dataclasses.field(repr=False)
    _struct: object = dataclasses.field(repr=False)   # padded-chunk shapes

    def rows(self, i: int) -> int:
        """Live (un-padded) rows of chunk ``i``."""
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range [0, {self.n_chunks})")
        return min(self.chunk_size, self.n_points - i * self.chunk_size)

    def completed(self) -> list[int]:
        """Committed chunk ids present on disk (sorted)."""
        return [s for s in checkpointer.committed_steps(self.directory)
                if s < self.n_chunks]

    def load_chunk(self, i: int):
        """One chunk's summaries as a (rows(i),)-leading pytree."""
        rows = self.rows(i)
        like = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((rows,) + s.shape[1:], s.dtype),
            self._struct)
        return checkpointer.restore(self.directory, i, like)

    def load(self):
        """Every chunk, concatenated — the in-memory path's return value."""
        chunks = [jax.tree.map(np.asarray, self.load_chunk(i))
                  for i in range(self.n_chunks)]
        cat = (chunks[0] if len(chunks) == 1 else
               jax.tree.map(lambda *xs: np.concatenate(xs), *chunks))
        return jax.tree.map(jnp.asarray, cat)


def _stream_init(directory: str, digest: str, b: int, chunk: int,
                 n_chunks: int, resume: bool) -> dict:
    """Create or validate the stream manifest; returns it.  A directory
    holding a *different* sweep's manifest is refused outright; with
    ``resume=False`` any previous chunks (and manifest) are discarded."""
    path = os.path.join(directory, _MANIFEST)
    manifest = {"schema": _STREAM_SCHEMA, "digest": digest, "n_points": b,
                "chunk": chunk, "n_chunks": n_chunks}
    os.makedirs(directory, exist_ok=True)
    if not resume:
        for name in os.listdir(directory):
            if name == _MANIFEST or name.startswith("step_"):
                full = os.path.join(directory, name)
                if os.path.isdir(full):
                    shutil.rmtree(full)
                else:
                    os.remove(full)
    elif os.path.exists(path):
        with open(path) as f:
            prev = json.load(f)
        # A previous profiled run annotates the manifest with its timings;
        # identity is everything *but* that record.
        prev = {k: v for k, v in prev.items() if k != "profile"}
        if prev != manifest:
            raise ValueError(
                f"stream_dir {directory!r} holds a different sweep "
                f"(manifest {prev} != {manifest}) — point stream_dir at a "
                "fresh directory or pass resume=False to discard it")
        return manifest
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, path)
    return manifest


# --------------------------------------------------------------------------
# The facade.

def sweep(spec: SweepSpec, cfg: runner.SimConfig):
    """Run every grid point of ``spec`` under ``cfg`` — THE sweep entry
    point (summary mode, chunked, mesh-sharded, optionally streamed).

    Returns a :class:`RunSummary` of (B,)-shaped arrays — or a
    ``tenants.TenantRun`` when ``spec.workload`` is a ``TenantSet`` — in
    grid order, or a :class:`SweepStream` handle when ``spec.stream_dir``
    is set (the streamed path never materializes the full grid in memory;
    call ``.load()`` to do that explicitly).

    The *axes* choose each run's fleet mix, bid policy, bid multiple and
    scenario; ``cfg.spot.instance``/``fleet``/``bid_mult`` are not
    consulted (they only apply to single, non-swept runs).
    ``cfg.spot.bid_policy`` *is* the policy of every grid point whose
    ``policy`` axis is the -1 sentinel (the ``make_axes`` default).

    Execution options live on the spec (see :class:`SweepSpec`); an
    interrupted streamed sweep resumes from its last committed chunk when
    re-invoked with the same spec and ``stream_dir``.
    """
    workload = spec.workload
    is_set = isinstance(workload, scen_lib.ScenarioSet)
    is_tenants = _is_tenant_set(workload)
    check_cfg = workload.sim_config(cfg) if is_tenants else cfg
    _check_axes(check_cfg, spec.axes, workload)
    pp = (runner.default_params(check_cfg) if spec.params is None
          else spec.params)
    # The dummy stands in for the (unused) schedule argument when the
    # scenario set / tenant set generates schedules internally.
    sched = (jnp.zeros((0,)) if (is_set or is_tenants)
             else wl.as_jax_schedule(workload))
    axes = spec.axes
    b = spec.n_points
    fspec = _norm_faults(spec, check_cfg, b)

    avail = len(jax.devices())
    if spec.mesh is not None:
        n_dev = min(spec.mesh.size, b)
        mesh = spec.mesh if n_dev == spec.mesh.size else None
    else:
        n_dev = avail if spec.devices is None else min(int(spec.devices),
                                                       avail)
        n_dev = min(n_dev, b)
        mesh = None
    if n_dev > 1 and mesh is None:
        mesh = mesh_lib.make_sweep_mesh(n_dev)

    ftail = () if fspec is None else (fspec,)
    if (spec.chunk_size is None and n_dev == 1 and spec.stream_dir is None
            and not spec.profile):
        return _sweep_callable(workload, cfg, None)(*axes, sched, pp, *ftail)

    chunk = b if spec.chunk_size is None else min(int(spec.chunk_size), b)
    # Each compiled chunk covers a device multiple of runs (the explicit
    # padding policy: the grid never has to divide the device count).
    chunk = -(-chunk // n_dev) * n_dev
    donating = jax.default_backend() != "cpu"
    fn = _sweep_callable(workload, cfg, mesh, donate=True)
    n_chunks = -(-b // chunk)
    t_sweep = time.perf_counter()

    if spec.stream_dir is not None:
        stream, profiles = _run_streamed(
            fn, axes, sched, pp, b, chunk, n_chunks,
            os.fspath(spec.stream_dir), spec.resume,
            donating, workload, check_cfg, fspec=fspec,
            profile=spec.profile)
        if not spec.profile:
            return stream
        return SweepReport(result=stream, chunks=profiles,
                           total_s=time.perf_counter() - t_sweep)

    outs = []
    profiles: list[ChunkProfile] = []
    compiled = None
    peak = None
    for i in range(n_chunks):
        lo = i * chunk
        hi = min(lo + chunk, b)
        part = _pad_axes(_slice_axes(axes, lo, hi, copy=donating), chunk)
        fpart = (() if fspec is None else
                 (_pad_fspec(_slice_fspec(fspec, lo, hi), hi - lo, chunk),))
        if spec.profile:
            # Compile-vs-execute split via the AOT path: every chunk is
            # padded to one shape, so the first chunk's executable serves
            # them all and only it pays (and reports) the compile.
            compile_s = 0.0
            if compiled is None:
                t0 = time.perf_counter()
                compiled = fn.lower(*part, sched, pp, *fpart).compile()
                compile_s = time.perf_counter() - t0
                peak = _peak_bytes(compiled)
            t0 = time.perf_counter()
            res = jax.block_until_ready(compiled(*part, sched, pp, *fpart))
            profiles.append(ChunkProfile(
                chunk=i, rows=hi - lo, compile_s=compile_s,
                execute_s=time.perf_counter() - t0, peak_bytes=peak))
        else:
            res = fn(*part, sched, pp, *fpart)
        # Off-device before the next chunk so live bytes stay O(chunk);
        # summaries are plain pytrees of dense arrays, so the transfer is
        # reformat-free.
        host = jax.tree.map(np.asarray, res)
        outs.append(_take_rows(host, hi - lo, chunk, "the summary"))
    cat = (outs[0] if len(outs) == 1 else
           jax.tree.map(lambda *xs: np.concatenate(xs), *outs))
    for leaf in jax.tree.leaves(cat):
        if leaf.shape[0] != b:
            raise AssertionError(
                f"chunked sweep produced {leaf.shape[0]} rows for {b} grid "
                "points — padded points would leak into the summary")
    result = jax.tree.map(jnp.asarray, cat)
    if not spec.profile:
        return result
    return SweepReport(result=result, chunks=profiles,
                       total_s=time.perf_counter() - t_sweep)


def _run_streamed(fn, axes: SweepAxes, sched, pp, b: int, chunk: int,
                  n_chunks: int, directory: str, resume: bool,
                  donating: bool, workload, check_cfg,
                  fspec=None, profile: bool = False,
                  ) -> "tuple[SweepStream, list[ChunkProfile] | None]":
    """Stream each completed chunk's summaries to disk; resumable.

    Chunk ``i`` is written atomically as ``step_<i>`` via the
    checkpointer (a crash mid-write leaves no ``.done`` marker, so the
    chunk is simply recomputed on resume), *already sliced to its live
    rows* — padded rows never reach a chunk file.  A manifest pins the
    sweep's identity (axes/config/workload/params/faults digest +
    chunking), so a directory can only ever be resumed with the sweep
    that started it.  Committed chunks are integrity-checked against the
    per-file sha256 digests in their chunk manifests; a corrupted or
    truncated chunk is silently recomputed instead of resumed.
    """
    cfg_token = repr(runner.strip_tuned(check_cfg))
    digest = _spec_digest(axes, b, chunk, cfg_token,
                          _workload_token(workload), pp, fspec=fspec)
    manifest = _stream_init(directory, digest, b, chunk, n_chunks, resume)
    done = {s for s in checkpointer.committed_steps(directory)
            if checkpointer.verify(directory, s)}

    part0 = _pad_axes(_slice_axes(axes, 0, min(chunk, b), copy=False), chunk)
    ftail0 = (() if fspec is None else
              (_pad_fspec(_slice_fspec(fspec, 0, min(chunk, b)),
                          min(chunk, b), chunk),))
    struct = jax.eval_shape(fn, *part0, sched, pp, *ftail0)

    profiles: list[ChunkProfile] | None = [] if profile else None
    compiled = None
    peak = None
    for i in range(n_chunks):
        rows = min(chunk, b - i * chunk)
        if i in done:
            if profile:
                # Committed on a previous run — no work re-done, so the
                # span is zero-length but still present in the timeline.
                profiles.append(ChunkProfile(chunk=i, rows=rows,
                                             resumed=True))
            continue
        lo = i * chunk
        hi = min(lo + chunk, b)
        part = _pad_axes(_slice_axes(axes, lo, hi, copy=donating), chunk)
        fpart = (() if fspec is None else
                 (_pad_fspec(_slice_fspec(fspec, lo, hi), hi - lo, chunk),))
        if profile:
            compile_s = 0.0
            if compiled is None:
                t0 = time.perf_counter()
                compiled = fn.lower(*part, sched, pp, *fpart).compile()
                compile_s = time.perf_counter() - t0
                peak = _peak_bytes(compiled)
            t0 = time.perf_counter()
            res = jax.block_until_ready(compiled(*part, sched, pp, *fpart))
            execute_s = time.perf_counter() - t0
        else:
            res = fn(*part, sched, pp, *fpart)
        host = jax.tree.map(np.asarray, res)
        host = _take_rows(host, hi - lo, chunk, "a written chunk file")
        t0 = time.perf_counter()
        checkpointer.save(directory, i, host)
        if profile:
            profiles.append(ChunkProfile(
                chunk=i, rows=hi - lo, compile_s=compile_s,
                execute_s=execute_s, write_s=time.perf_counter() - t0,
                peak_bytes=peak))
        del res, host   # live bytes stay O(chunk) no matter the grid

    if profile:
        # Persist the run's profile next to the sweep identity.  The
        # manifest comparison on resume strips this key: profiling a
        # sweep must never un-resume its own stream_dir.
        manifest = dict(manifest,
                        profile=[dataclasses.asdict(p) for p in profiles])
        path = os.path.join(directory, _MANIFEST)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, path)

    stream = SweepStream(directory=directory, n_points=b, chunk_size=chunk,
                         n_chunks=n_chunks, manifest=manifest,
                         _struct=struct)
    return stream, profiles


# --------------------------------------------------------------------------
# Deprecated wrappers (PR-3-era entry points) and the loop-of-one reference.

_WARNED_RUN_SWEEP = False  # deprecation fires once per process, not per call


def run_sweep(schedule: ScheduleLike, cfg: runner.SimConfig,
              axes: SweepAxes, *,
              chunk_size: int | None = None,
              devices: int | None = None,
              params: PolicyParams | None = None) -> RunSummary:
    """Deprecated: build a :class:`SweepSpec` and call :func:`sweep`.

    Thin keyword-only wrapper kept so PR-3..6 callers keep working; the
    execution is byte-for-byte the new engine's (same compile cache, same
    chunk padding, same results)."""
    global _WARNED_RUN_SWEEP
    if not _WARNED_RUN_SWEEP:
        _WARNED_RUN_SWEEP = True
        warnings.warn(
            "run_sweep is deprecated — build a SweepSpec and call "
            "repro.sim.sweep.sweep(spec, cfg)", DeprecationWarning,
            stacklevel=2)
    return sweep(SweepSpec(axes=axes, workload=schedule, params=params,
                           chunk_size=chunk_size, devices=devices), cfg)


def run_single(schedule: ScheduleLike, cfg: runner.SimConfig, *,
               seed: int, bid_mult: float,
               instance: FleetMix = "m3.medium",
               policy: str | int | None = None,
               scenario: int = 0,
               params: PolicyParams | None = None) -> RunSummary:
    """One grid point as a standalone jitted run — the reference the
    vmapped sweep is tested against (and a handy debug entry point).
    With a ``ScenarioSet`` the point's schedule is sampled exactly as the
    sweep would (same per-(seed, scenario) key).  Runs through the cached
    summary-mode entry point: repeated calls with different seeds / bids /
    mixes / same-shape schedules reuse one compiled simulation."""
    itype, mask = _as_mix(instance)
    if policy is None:
        policy = spot.bid_policy_index(cfg.spot.bid_policy)
    if isinstance(schedule, scen_lib.ScenarioSet):
        if not 0 <= int(scenario) < len(schedule):
            raise ValueError(
                f"scenario id {scenario} out of range for the "
                f"{len(schedule)}-scenario set {schedule.names}")
        sched = schedule.sample(scenario,
                                scen_lib.schedule_key(seed, scenario))
    else:
        if int(scenario) != 0:
            raise ValueError(
                f"scenario id {scenario} given, but a plain schedule "
                "provides only scenario 0 — pass a ScenarioSet")
        sched = wl.as_jax_schedule(schedule)
    rt = spot.make_runtime(cfg.spot, itype=itype, bid_mult=bid_mult,
                           policy=policy, mix=jnp.asarray(mask))
    pp = runner.default_params(cfg) if params is None else params
    final, _ = runner.cached_scan(sched, cfg, trace=False,
                                  with_rt=True)(sched, seed, rt, pp)
    return summarize(final, sched, cfg)
