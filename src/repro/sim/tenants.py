"""Multi-tenant CaaS: several tenants, one shared spot fleet.

The paper's platform serves one owner; a real Computation-as-a-Service
provider consolidates many.  This module turns the single-owner simulator
into a shared-fleet one without touching its scan shape:

  * a :class:`TenantSpec` bundles one tenant's contract — their workload
    *scenario* (any ``sim.scenarios`` spec, which carries the TTC SLO in
    its task model), the $/CU-hour price they pay, the $ credited back
    per TTC violation, their fair-share weight, and an optional budget
    cap;
  * a :class:`TenantSet` concatenates the tenants' schedules into one
    ``n·max_w``-row schedule (row ``w`` belongs to tenant ``w // max_w``)
    and stamps the matching ``core.types.TenantConfig`` onto the
    ``SimConfig`` — the switch that makes ``runner.make_step`` arbitrate
    allocation hierarchically (``fairshare.allocate_tenants``), gate
    admission per tenant, and attribute every billed cent to a tenant in
    the scan carry;
  * :func:`point_fn` exposes one shared-fleet run as the same vmappable
    ``(seed, bid_mult, itype, policy, mix, scenario, params)`` closure the
    single-owner sweep uses, so a ``TenantSet`` rides the unified sweep
    executor unchanged — ``sweep(SweepSpec(axes=..., workload=tset), cfg)``
    is THE entry point (chunked, mesh-sharded, streamable, resumable),
    returning a :class:`TenantRun` of (B,)-leading fields;
  * :func:`run_tenants` runs one seed through it; :func:`tenant_sweep` is
    the deprecated PR-6-era wrapper.

Tenant ``i``'s schedule is sampled under ``scenarios.schedule_key(seed,
i)`` — the *same* key ``run_sweep``/``run_single`` would use for scenario
``i`` of a ``ScenarioSet`` — so the isolated-fleet baseline (one
single-owner run per tenant, via ``TenantSet.scenario_set()``) replays
bit-identical workloads, and a one-tenant set *is* the single-owner
simulation (``tests/test_tenants.py`` pins this bit for bit).

Attribution is exact by construction: the carry splits each tick's billed
delta in integer units of ``1/runner._COST_UNIT`` dollars (largest
remainder), so the per-tenant bills sum to the fleet bill at every tick,
preemption or not, and padded tenants (no valid rows) can never bill.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.types import PolicyParams, TenantConfig
from . import runner
from . import scenarios as scen_lib
from . import spot, sweep
from . import workloads as wl


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's contract with the provider (hashable).

    ``price`` is what the tenant pays per CU-hour of *delivered* service;
    ``slo_penalty`` is the $ the provider credits back per TTC violation;
    ``weight`` the contracted fair-share weight; ``budget`` an optional $
    cap after which the tenant's new arrivals are refused.  The TTC each
    workload requests lives in the scenario's task model, exactly as in
    the single-owner world.
    """

    scenario: object                 # a sim.scenarios spec (sample() hook)
    price: float = 0.35              # $ per delivered CU-hour
    slo_penalty: float = 0.5         # $ credited per TTC violation
    weight: float = 1.0              # fair-share weight
    budget: float = float("inf")     # $ admission cap (inf = uncapped)
    name: str | None = None

    def __post_init__(self):
        if not hasattr(self.scenario, "sample"):
            raise TypeError(
                f"scenario {self.scenario!r} has no sample() hook — pass a "
                "sim.scenarios spec")
        if self.price < 0.0:
            raise ValueError(f"price must be >= 0, got {self.price}")
        if self.slo_penalty < 0.0:
            raise ValueError(
                f"slo_penalty must be >= 0, got {self.slo_penalty}")
        if not self.weight > 0.0:
            raise ValueError(f"weight must be positive, got {self.weight}")
        if not self.budget > 0.0:
            raise ValueError(f"budget must be positive, got {self.budget}")
        if self.name is None:
            object.__setattr__(self, "name",
                               getattr(self.scenario, "name", "tenant"))


@dataclasses.dataclass(frozen=True)
class TenantSet:
    """An ordered bundle of tenants sharing one fleet (hashable — the
    compile caches key on it).  All scenarios must share one ``max_w`` so
    the concatenated schedule has a static ``n·max_w`` row shape."""

    specs: tuple

    def __post_init__(self):
        specs = tuple(self.specs)
        object.__setattr__(self, "specs", specs)
        if not specs:
            raise ValueError("a TenantSet needs at least one tenant")
        widths = {s.scenario.max_w for s in specs}
        if len(widths) > 1:
            raise ValueError(
                "all tenant scenarios must share one max_w so the "
                f"concatenated schedule is static; got {sorted(widths)}")

    @property
    def n(self) -> int:
        return len(self.specs)

    @property
    def max_w(self) -> int:
        return self.specs[0].scenario.max_w

    @property
    def names(self) -> tuple:
        return tuple(s.name for s in self.specs)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self):
        return iter(self.specs)

    def __getitem__(self, i) -> TenantSpec:
        return self.specs[i]

    def tenant_config(self) -> TenantConfig:
        return TenantConfig(
            n=self.n, max_w=self.max_w,
            weights=tuple(s.weight for s in self.specs),
            budgets=(tuple(s.budget for s in self.specs)
                     if any(s.budget != float("inf") for s in self.specs)
                     else ()),
        )

    def sim_config(self, cfg: runner.SimConfig) -> runner.SimConfig:
        """``cfg`` with this set's tenant layout stamped on."""
        return dataclasses.replace(cfg, tenants=self.tenant_config())

    def sample(self, seed):
        """The shared-fleet schedule for ``seed`` (traced ok): tenant
        ``i``'s block is their scenario sampled under
        ``scenarios.schedule_key(seed, i)`` — the key scenario ``i`` of a
        ``ScenarioSet`` gets, so isolated baselines replay identical
        workloads."""
        scheds = [self.sample_one(seed, i) for i in range(self.n)]
        if len(scheds) == 1:
            return scheds[0]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                            *scheds)

    def sample_one(self, seed, i: int):
        """Tenant ``i``'s own ``max_w``-row schedule for ``seed``."""
        return self.specs[i].scenario.sample(
            scen_lib.schedule_key(seed, i))

    def scenario_set(self) -> scen_lib.ScenarioSet:
        """The tenants' scenarios as a ``ScenarioSet`` whose scenario ids
        line up with tenant ids — the isolated-fleet baseline axis
        (``run_single(set, scenario=i)`` replays tenant ``i``'s exact
        workload).  Duplicate scenario names are suffixed per tenant."""
        specs, seen = [], set()
        for i, s in enumerate(self.specs):
            spec = s.scenario
            if spec.name in seen:
                spec = dataclasses.replace(spec, name=f"{spec.name}.{i}")
            seen.add(spec.name)
            specs.append(spec)
        return scen_lib.ScenarioSet(tuple(specs))


class TenantSummary(NamedTuple):
    """Per-tenant read-out of one shared-fleet run (each field (N,))."""

    cost: jnp.ndarray        # $ attributed (sums exactly to the fleet bill)
    cost_units: jnp.ndarray  # the same, in exact 1/_COST_UNIT $ integers
    service: jnp.ndarray     # delivered CU-seconds
    violations: jnp.ndarray  # TTC violations among the tenant's rows
    finished: jnp.ndarray    # workloads completed
    submitted: jnp.ndarray   # workloads admitted
    rejected: jnp.ndarray    # arrivals refused by admission control


class TenantRun(NamedTuple):
    """One shared-fleet run: fleet-level and per-tenant summaries."""

    fleet: sweep.RunSummary
    tenants: TenantSummary


def summarize_tenants(final, schedule, cfg: runner.SimConfig
                      ) -> TenantSummary:
    """Per-tenant registers out of a final scan carry, jnp-pure."""
    tcfg = cfg.tenants
    if tcfg is None:
        raise ValueError("config has no tenants — use sweep.summarize")
    sched = wl.as_jax_schedule(schedule)
    tid = tcfg.tenant_ids()
    work = final.work
    valid = sched.valid

    def seg(rows):
        return jax.ops.segment_sum(rows.astype(jnp.int32), tid,
                                   num_segments=tcfg.n)

    submitted = (work.t_submit >= 0) & valid
    finished = (work.t_done >= 0) & valid
    arrived = valid & (sched.t_arrive >= 0) & (sched.t_arrive < cfg.ticks)
    tc = final.summ.tenant
    return TenantSummary(
        cost=tc.cost_u.astype(jnp.float32) / runner._COST_UNIT,
        cost_units=tc.cost_u,
        service=tc.service,
        violations=seg(runner.violation_rows(work, sched, cfg)),
        finished=seg(finished),
        submitted=seg(submitted),
        rejected=seg(arrived & ~submitted),
    )


def point_fn(tset: TenantSet, cfg: runner.SimConfig):
    """One shared-fleet run as the sweep executor's vmappable closure of
    ``(seed, bid_mult, itype, policy, mix, scenario, params)`` — the
    tenant twin of ``sweep.point_fn`` (``scenario`` is ignored: the tenant
    set *is* the workload world; schedules are sampled per (seed, tenant)
    inside the trace).  ``cfg`` is the caller's plain config; the tenant
    layout is stamped on here, in one place.  ``repro.opt``'s profit
    objective builds on exactly this closure."""
    scfg = tset.sim_config(cfg)
    cfg_policy = spot.bid_policy_index(scfg.spot.bid_policy)

    def one(seed, bid_mult, itype, policy, mix, scenario, params,
            fspec=None):
        del scenario
        policy = jnp.where(policy < 0, cfg_policy, policy)
        sched = tset.sample(seed)
        rt = spot.make_runtime(scfg.spot, itype=itype, bid_mult=bid_mult,
                               policy=policy, mix=mix)
        final, _ = runner.scan_run(sched, scfg, seed=seed, spot_rt=rt,
                                   trace=False, params=params, fspec=fspec)
        return TenantRun(fleet=sweep.summarize(final, sched, scfg),
                         tenants=summarize_tenants(final, sched, scfg))

    return one


def _tenant_axes(tset: TenantSet, seeds, bid_mult, instance,
                 policy) -> sweep.SweepAxes:
    """The (S,)-row grid the legacy per-seed entry points map onto."""
    return sweep.make_axes(list(seeds), [bid_mult], instances=[instance],
                           policies=None if policy is None else [policy])


_WARNED_TENANT_SWEEP = False  # deprecation fires once per process


def tenant_sweep(tset: TenantSet, cfg: runner.SimConfig, seeds, *,
                 bid_mult: float = 1.0, instance="m3.medium",
                 policy=None,
                 params: PolicyParams | None = None) -> TenantRun:
    """Deprecated: build a :class:`sweep.SweepSpec` with the ``TenantSet``
    as the workload and call ``sweep.sweep(spec, cfg)`` — which also
    unlocks the chunked / mesh-sharded / streamed execution options this
    per-seed wrapper never had."""
    global _WARNED_TENANT_SWEEP
    if not _WARNED_TENANT_SWEEP:
        _WARNED_TENANT_SWEEP = True
        warnings.warn(
            "tenant_sweep is deprecated — build a SweepSpec(workload=tset) "
            "and call repro.sim.sweep.sweep(spec, cfg)", DeprecationWarning,
            stacklevel=2)
    axes = _tenant_axes(tset, seeds, bid_mult, instance, policy)
    return sweep.sweep(sweep.SweepSpec(axes=axes, workload=tset,
                                       params=params), cfg)


def run_tenants(tset: TenantSet, cfg: runner.SimConfig, seed: int, *,
                bid_mult: float = 1.0, instance="m3.medium",
                policy=None,
                params: PolicyParams | None = None) -> TenantRun:
    """One shared-fleet run — a one-point sweep, squeezed to scalars."""
    axes = _tenant_axes(tset, [seed], bid_mult, instance, policy)
    out = sweep.sweep(sweep.SweepSpec(axes=axes, workload=tset,
                                      params=params), cfg)
    return jax.tree.map(lambda x: x[0], out)


def isolated_runs(tset: TenantSet, cfg: runner.SimConfig, seed: int, *,
                  bid_mult: float = 1.0, instance="m3.medium",
                  policy=None,
                  params: PolicyParams | None = None) -> sweep.RunSummary:
    """The no-consolidation baseline: each tenant on their own dedicated
    fleet (one single-owner run per tenant, identical workloads), stacked
    to (N,)-leading ``RunSummary`` fields.  Sum costs across tenants to
    compare against one shared fleet's bill."""
    sset = tset.scenario_set()
    outs = [sweep.run_single(sset, cfg, seed=seed, bid_mult=bid_mult,
                             instance=instance, policy=policy, scenario=i,
                             params=params)
            for i in range(tset.n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
