"""Chaos engine: traced fault injection for the simulated CaaS platform.

The paper's platform is sold on surviving a hostile market, yet the base
simulator only models the benign adversity of being outbid.  This module
injects four fault families *inside* the jitted scan, driven by a
``FaultSpec`` pytree of traced scalars (so fault timing/intensity can ride
a sweep axis, be searched by the CEM adversary, and differentiate where
the underlying arithmetic does):

  (i)  capacity outages — per-type availability masks (random per-type
       dry-ups plus a deterministic full-market window whose *start tick*
       is itself traced), and correlated "preemption storms" that reclaim
       a fraction of the live fleet regardless of bid;
  (ii) independent slot failures — per-slot Poisson hard-kills mid
       quantum, billed exactly like mid-quantum preemption (the paid
       remainder is forfeited, the in-flight work of the killed slots
       re-enters the queue exactly once);
 (iii) telemetry dropouts and delays — fresh Kalman measurements are
       lost, or held one monitoring instant and delivered stale (the
       lagged-measurement form of eq. 8 makes one-tick staleness a
       first-class citizen);
  (iv) stragglers — per-slot service-rate slowdown: the slot stays
       billed at full price but delivers ``1/straggle_factor`` of its
       nominal CU capacity while the episode lasts.

Static gating contract: ``SimConfig.faults`` is ``None`` by default and
every fault branch in the step function is a *trace-time* conditional on
it, so a fault-free config compiles a program structurally identical to
the pre-chaos simulator — zero-fault runs stay bit-identical to the
committed baselines.  ``FaultConfig(hardened=...)`` selects between the
hardened control plane (hedged type selection, bounded jittered backoff,
AIMD anti-windup, covariance inflation on dropped measurements,
deadline-aware load shedding) and an unhardened comparator that suffers
the same physics blind.

The fault PRNG chain is ``fold_in(PRNGKey(seed), FAULT_SALT)`` — separate
from the execution-noise chain (``PRNGKey(seed)``), the market chain
(``PRNGKey(seed + 7919)``) and the schedule chain, so enabling faults
never perturbs workload, prices, or execution noise.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import billing

# Salt for the dedicated fault PRNG chain (a prime, like the schedule
# salt 104729 and the market offset 7919).
FAULT_SALT = 15485863


def fault_key(seed) -> jax.Array:
    """Root key of the fault chain for ``seed`` (traced or static)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), FAULT_SALT)


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static (trace-time) chaos switches; part of the jit cache key.

    ``hardened`` toggles every graceful-degradation response at once so a
    single flag flip produces the unhardened comparator used by
    ``bench_chaos``.  The remaining fields parameterise the hardened
    responses and are deliberately static: they are operator policy, not
    world state, so they do not belong on the traced ``FaultSpec`` axis.
    """

    hardened: bool = True
    # Bounded exponential backoff: retry delay after the k-th consecutive
    # failed acquisition is min(2**k, backoff_cap) ticks, jittered to
    # [0.5x, 1.5x] to de-synchronise recovering fleets.
    backoff_cap: float = 8.0
    # Deadline-aware shedding: once the fail streak reaches ``shed_after``
    # ticks, refuse arrivals whose requested deadline is tighter than
    # ``shed_slack * streak`` monitoring intervals — during a sustained
    # outage they could not be finished anyway and would only convert
    # admission into SLA violations.
    shed_after: float = 4.0
    shed_slack: float = 2.0


class FaultSpec(NamedTuple):
    """Traced fault intensities — () f32 leaves (or a batch axis on each).

    Rates are per *hour* (the paper's billing quantum) and are converted
    to per-tick probabilities with the monitoring interval, so the same
    spec means the same world at any ``monitor_dt``.
    """

    p_outage: jnp.ndarray  # per-hour prob a type enters a random outage
    outage_hours: jnp.ndarray  # mean duration of a random outage (hours)
    outage_start: jnp.ndarray  # tick a full-market outage opens (<0: off)
    outage_ticks: jnp.ndarray  # length of that deterministic window
    p_storm: jnp.ndarray  # per-hour prob of a preemption storm
    storm_frac: jnp.ndarray  # fraction of live slots a storm reclaims
    p_slot_fail: jnp.ndarray  # per-hour per-slot hard-kill probability
    p_meas_drop: jnp.ndarray  # prob a fresh measurement is lost
    p_meas_delay: jnp.ndarray  # prob a fresh measurement arrives stale
    p_straggle: jnp.ndarray  # per-hour per-slot straggle-onset prob
    straggle_ticks: jnp.ndarray  # straggle episode length (ticks)
    straggle_factor: jnp.ndarray  # service-rate divisor while straggling


def make_fault_spec(
    p_outage=0.0,
    outage_hours=1.0,
    outage_start=-1.0,
    outage_ticks=0.0,
    p_storm=0.0,
    storm_frac=0.0,
    p_slot_fail=0.0,
    p_meas_drop=0.0,
    p_meas_delay=0.0,
    p_straggle=0.0,
    straggle_ticks=0.0,
    straggle_factor=1.0,
) -> FaultSpec:
    """Build a ``FaultSpec`` of f32 scalars; the default is fault-free."""
    as_f32 = lambda v: jnp.asarray(v, dtype=jnp.float32)  # noqa: E731
    return FaultSpec(
        p_outage=as_f32(p_outage),
        outage_hours=as_f32(outage_hours),
        outage_start=as_f32(outage_start),
        outage_ticks=as_f32(outage_ticks),
        p_storm=as_f32(p_storm),
        storm_frac=as_f32(storm_frac),
        p_slot_fail=as_f32(p_slot_fail),
        p_meas_drop=as_f32(p_meas_drop),
        p_meas_delay=as_f32(p_meas_delay),
        p_straggle=as_f32(p_straggle),
        straggle_ticks=as_f32(straggle_ticks),
        straggle_factor=as_f32(straggle_factor),
    )


class FaultState(NamedTuple):
    """Per-run fault registers carried through the scan."""

    key: jax.Array  # fault PRNG chain
    out_left: jnp.ndarray  # (T,) remaining random-outage ticks per type
    straggle_left: jnp.ndarray  # (I,) remaining straggle ticks per slot
    pend_meas: jnp.ndarray  # (W, K) measurement values held one tick
    pend_mask: jnp.ndarray  # (W, K) bool: a stale delivery is pending
    fail_streak: jnp.ndarray  # () consecutive failed-acquisition ticks
    backoff_left: jnp.ndarray  # () ticks until the next retry is allowed
    n_killed: jnp.ndarray  # () slots hard-killed (storms + Poisson)
    n_dropped: jnp.ndarray  # () measurements lost to dropouts
    n_delayed: jnp.ndarray  # () measurements delivered one tick stale
    n_shed: jnp.ndarray  # () arrivals refused by the shedding gate
    unavail_ticks: jnp.ndarray  # () Σ over ticks of #unavailable types


def init_state(seed, n_types: int, w: int, k: int, pool: int) -> FaultState:
    """Fresh fault registers for a run of ``seed``."""
    z = jnp.zeros((), dtype=jnp.float32)
    return FaultState(
        key=fault_key(seed),
        out_left=jnp.zeros((n_types,), dtype=jnp.float32),
        straggle_left=jnp.zeros((pool,), dtype=jnp.float32),
        pend_meas=jnp.zeros((w, k), dtype=jnp.float32),
        pend_mask=jnp.zeros((w, k), dtype=bool),
        fail_streak=z,
        backoff_left=z,
        n_killed=z,
        n_dropped=z,
        n_delayed=z,
        n_shed=z,
        unavail_ticks=z,
    )


class FaultTick(NamedTuple):
    """One tick's fault draws, consumed by the step function."""

    avail: jnp.ndarray  # (T,) bool: type has spot capacity this tick
    kill: jnp.ndarray  # (I,) bool: slot is hard-killed this tick
    slow: jnp.ndarray  # (I,) f32: service-capacity multiplier (<= 1)
    drop_u: jnp.ndarray  # (W, K) uniforms for measurement dropouts
    delay_u: jnp.ndarray  # (W, K) uniforms for measurement delays
    jitter_u: jnp.ndarray  # () uniform for backoff jitter


def tick(fs: FaultState, spec: FaultSpec, dt, t) -> tuple[FaultTick, FaultState]:
    """Advance the fault processes one monitoring instant.

    Draws all of this tick's fault randomness from the dedicated chain
    and updates the outage / straggler registers.  Everything that needs
    fleet state (masking kills to live slots, the backoff bookkeeping)
    stays in the step function.
    """
    h = dt / 3600.0
    n_types = fs.out_left.shape[0]
    pool = fs.straggle_left.shape[0]
    w, k = fs.pend_mask.shape
    key, k_out, k_dur, k_storm, k_su, k_fail, k_str, k_drop, k_del, k_jit = (
        jax.random.split(fs.key, 10)
    )

    # (i) capacity outages: random per-type dry-ups with ~Exp durations,
    # plus the deterministic traced full-market window.
    p_out = jnp.clip(spec.p_outage * h, 0.0, 1.0)
    enter = jax.random.uniform(k_out, (n_types,)) < p_out
    dur = jax.random.exponential(k_dur, (n_types,)) * spec.outage_hours / h
    idle = fs.out_left <= 0.0
    out_left = jnp.where(
        idle & enter,
        jnp.maximum(dur, 1.0),
        jnp.maximum(fs.out_left - 1.0, 0.0),
    )
    t_f = jnp.asarray(t, dtype=jnp.float32)
    in_window = (
        (spec.outage_start >= 0.0)
        & (t_f >= spec.outage_start)
        & (t_f < spec.outage_start + spec.outage_ticks)
    )
    avail = (out_left <= 0.0) & ~in_window

    # (ii) correlated storms + independent Poisson hard-kills.
    storm = jax.random.uniform(k_storm, ()) < jnp.clip(spec.p_storm * h, 0.0, 1.0)
    storm_hit = storm & (jax.random.uniform(k_su, (pool,)) < spec.storm_frac)
    fail_hit = jax.random.uniform(k_fail, (pool,)) < jnp.clip(
        spec.p_slot_fail * h, 0.0, 1.0
    )
    kill = storm_hit | fail_hit

    # (iv) stragglers: onset draws refresh the per-slot episode clock.
    onset = jax.random.uniform(k_str, (pool,)) < jnp.clip(
        spec.p_straggle * h, 0.0, 1.0
    )
    decayed = jnp.maximum(fs.straggle_left - 1.0, 0.0)
    straggle_left = jnp.where(onset, jnp.maximum(spec.straggle_ticks, decayed), decayed)
    slow = jnp.where(
        straggle_left > 0.0, 1.0 / jnp.maximum(spec.straggle_factor, 1.0), 1.0
    )

    ft = FaultTick(
        avail=avail,
        kill=kill,
        slow=slow,
        drop_u=jax.random.uniform(k_drop, (w, k)),
        delay_u=jax.random.uniform(k_del, (w, k)),
        jitter_u=jax.random.uniform(k_jit, ()),
    )
    fs = fs._replace(
        key=key,
        out_left=out_left,
        straggle_left=straggle_left,
        unavail_ticks=fs.unavail_ticks + jnp.sum((~avail).astype(jnp.float32)),
    )
    return ft, fs


def kill_slots(cluster, kill):
    """Hard-kill ``kill``-masked slots, billed like mid-quantum preemption.

    Mirrors ``billing.preempt``: the paid remainder of the running hour is
    forfeited (``cum_cost`` keeps the already-charged quantum), the slot
    drops to OFF and its bid is retired.  Kills count into ``n_preempt``
    (to the controller they *are* reclamations) and are returned so the
    fault registers can keep the fine-grained tally.
    """
    hit = (cluster.phase >= billing.BOOTING) & kill
    n_hit = jnp.sum(hit.astype(jnp.float32))
    inf = jnp.float32(jnp.inf)
    return (
        cluster._replace(
            phase=jnp.where(hit, billing.OFF, cluster.phase),
            a=jnp.where(hit, 0.0, cluster.a),
            boot_left=jnp.where(hit, 0.0, cluster.boot_left),
            draining=cluster.draining & ~hit,
            bid=jnp.where(hit, inf, cluster.bid),
            n_preempt=cluster.n_preempt + n_hit,
        ),
        n_hit,
    )


def filter_telemetry(fs, ft, spec, b_meas, meas_mask, arrive):
    """Apply dropouts and one-tick delays to fresh Kalman measurements.

    Returns ``(b_meas_out, meas_mask_out, dropped, fs)`` where ``dropped``
    marks filters whose fresh measurement was lost this tick (the
    hardened Kalman bank inflates covariance there).  Delayed
    measurements are held in the pending registers and delivered on the
    next instant — the bank's lagged-measurement update (eq. 8) makes a
    one-tick-stale value a perfectly well-formed input.  When a pending
    delivery collides with a fresh one, the fresh value wins and the
    stale one is discarded.  Rows that (re-)arrive this tick clear their
    pending registers: a stale measurement of the previous occupant must
    not leak into the new workload's filter.
    """
    fresh = meas_mask
    dropped = fresh & (ft.drop_u < spec.p_meas_drop)
    delayed = fresh & ~dropped & (ft.delay_u < spec.p_meas_delay)
    now = fresh & ~dropped & ~delayed
    pending = fs.pend_mask & ~arrive[:, None]
    out_mask = now | pending
    out_meas = jnp.where(now, b_meas, fs.pend_meas)
    fs = fs._replace(
        pend_meas=jnp.where(delayed, b_meas, 0.0),
        pend_mask=delayed,
        n_dropped=fs.n_dropped + jnp.sum(dropped.astype(jnp.float32)),
        n_delayed=fs.n_delayed + jnp.sum(delayed.astype(jnp.float32)),
    )
    return out_meas, out_mask, dropped, fs


def fault_timeline(seed, spec: FaultSpec, steps: int, pool: int,
                   dt: float = 3600.0):
    """Precompute ``steps`` ticks of kill / straggle draws, host-side.

    One jitted ``lax.scan`` over :func:`tick` — the *same* kernel the
    simulator advances inside its scan — so host-side consumers (the
    elastic runtime's ``ft.failures.FailureInjector``) draw their events
    from the identical PRNG chain and episode model.  With the default
    ``dt=3600`` one tick is one hour, so per-hour spec rates read as
    per-step probabilities.  Returns ``(kill, straggling)``: two
    ``(steps, pool)`` bool arrays (``straggling`` marks slots inside a
    straggle episode; the caller applies its own slowdown factor).
    """
    fs0 = init_state(seed, 1, 1, 1, pool)

    def body(fs, t):
        ft, fs = tick(fs, spec, dt, t)
        return fs, (ft.kill, ft.slow < 1.0)

    _, (kill, straggling) = jax.lax.scan(
        body, fs0, jnp.arange(steps, dtype=jnp.int32))
    return kill, straggling


# ---------------------------------------------------------------------------
# Adversarial exposure: FaultSpec bounds through ``opt.scenario_space``.


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Hashable host-side mirror of ``FaultSpec`` with searchable bounds.

    ``ChaosScenario`` composes one of these with a workload generator so
    the CEM adversary (``opt.attack_policy``) can search fault timing and
    intensity alongside workload shape.  ``bounds`` names the attackable
    fields; everything else stays pinned at its nominal value.
    """

    p_outage: float = 0.0
    outage_hours: float = 1.0
    outage_start: float = -1.0
    outage_ticks: float = 0.0
    p_storm: float = 0.0
    storm_frac: float = 0.0
    p_slot_fail: float = 0.0
    p_meas_drop: float = 0.0
    p_meas_delay: float = 0.0
    p_straggle: float = 0.0
    straggle_ticks: float = 0.0
    straggle_factor: float = 1.0
    bounds: tuple = ()  # ((field, lo, hi), ...) — the attackable box

    _FIELDS = (
        "p_outage",
        "outage_hours",
        "outage_start",
        "outage_ticks",
        "p_storm",
        "storm_frac",
        "p_slot_fail",
        "p_meas_drop",
        "p_meas_delay",
        "p_straggle",
        "straggle_ticks",
        "straggle_factor",
    )

    def params_pytree(self):
        return {f"fault_{name}": getattr(self, name) for name, _, _ in self.bounds}

    def param_bounds(self):
        return {f"fault_{name}": (lo, hi) for name, lo, hi in self.bounds}

    def spec(self, params=None) -> FaultSpec:
        """Concrete (possibly traced) ``FaultSpec`` under overrides."""
        kw = {name: getattr(self, name) for name in self._FIELDS}
        if params is not None:
            for key, value in params.items():
                if key.startswith("fault_"):
                    kw[key[len("fault_") :]] = value
        return make_fault_spec(**kw)


@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """A workload generator wearing a searchable fault model.

    Quacks like a ``sim.scenarios`` spec (``sample`` / ``params_pytree``
    / ``param_bounds`` / ``max_w``) but merges the fault model's bounds
    into the searchable box under a ``fault_`` prefix, so
    ``opt.scenario_space`` exposes them to ``attack_policy`` unchanged —
    the worst-case world now includes *when* the outage hits.
    ``ScenarioObjective`` detects the ``fault_spec`` method and threads
    the attacked spec into the fault-aware point program.
    """

    base: object  # a sim.scenarios generator spec
    faults: FaultModel = FaultModel()

    @property
    def name(self):
        return f"chaos_{self.base.name}"

    @property
    def max_w(self):
        return self.base.max_w

    def params_pytree(self):
        return {**self.base.params_pytree(), **self.faults.params_pytree()}

    def param_bounds(self):
        return {**self.base.param_bounds(), **self.faults.param_bounds()}

    def sample(self, key, params=None):
        if params is not None:
            params = {
                k: v for k, v in params.items() if not k.startswith("fault_")
            }
        return self.base.sample(key, params=params)

    def fault_spec(self, params=None) -> FaultSpec:
        return self.faults.spec(params)
