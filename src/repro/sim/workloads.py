"""The paper's §V.A workload suite, as a deterministic synthetic schedule.

Thirty workloads, submitted one every five minutes, in four families:

  * 8 × Viola-Jones face detection  — 1..1000 images
  * 8 × FFMPEG transcoding          — 1..20 videos, plus TWO large spikes
                                      (200 and 300 videos) inside the eight
  * 7 × OpenCV BRISK features       — images
  * 7 × SIFT (compiled Matlab)      — images

We cannot run FFMPEG/SIFT binaries here, so each family gets a calibrated
per-item CUS model (see DESIGN.md §7).  The controller only ever observes
noisy window-averaged measurements, exactly as the real platform would.

Measurement/progress model: items inside a workload are heterogeneous and
the cheap ones complete first (download-then-process pipelines drain small
files early), so the window-averaged measured CUS *ramps up* with completed
fraction p, mildly overshoots, then settles on the true mean with noise
whose std shrinks with the number of completions in the window.  This is
what produces the underdamped estimator trajectories of the paper's Fig. 3
and the minutes-scale time-to-reliable-prediction of Table II.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

FAMILIES = ("face", "transcode", "brisk", "sift")
FACE, TRANSCODE, BRISK, SIFT = range(4)

# Per-family calibration: (mean item CUS, item lognormal σ, ramp floor c0,
# ramp knee p_r, overshoot).  Means chosen so Σ CUS over the 30 workloads
# ≈ 97e3 CU-s → LB ≈ $0.22 at the 2015 m3.medium spot price (paper Table III).
FAMILY_PARAMS = {
    # σ is the *per-item* lognormal spread: images vary in size (face/brisk/
    # sift) and videos vary enormously in length/codec (transcode), which is
    # what makes window-averaged CUS measurements noisy in the real platform.
    FACE:      dict(mean_cus=1.5, sigma=0.35, c0=0.45, p_r=0.25, overshoot=0.12),
    TRANSCODE: dict(mean_cus=130.0, sigma=1.00, c0=0.40, p_r=0.20, overshoot=0.15),
    BRISK:     dict(mean_cus=2.0, sigma=0.30, c0=0.50, p_r=0.25, overshoot=0.10),
    SIFT:      dict(mean_cus=3.0, sigma=0.35, c0=0.45, p_r=0.30, overshoot=0.12),
}


class JaxSchedule(NamedTuple):
    """A workload schedule as a JAX pytree — the form the simulator scans.

    Unlike the static numpy ``Schedule``, every field may be a *traced*
    value: ``sim.runner`` takes the schedule as an input of its jitted scan
    (compiles are keyed on this pytree's shapes, not its bytes) and
    ``sim.scenarios`` generators emit it from inside ``jit``/``vmap``, which
    is what makes "which workload world are we in" a sweep axis.

    The row count W is a *capacity*, not a workload count: generators pad to
    a fixed ``max_w`` and mark real rows in ``valid``.  Padded rows carry
    ``t_arrive = -1`` so they never arrive, and every consumer of final
    workload state (violation counts, cost-at-completion, finished counts)
    masks by ``valid`` so padding can neither bill nor violate.
    """

    t_arrive: jnp.ndarray     # (W,) int32 arrival tick (-1 = never arrives)
    family: jnp.ndarray       # (W,) int32 family id
    m0: jnp.ndarray           # (W, K) f32 items per type (K=1 here)
    b_true: jnp.ndarray       # (W, K) f32 true mean CUS per item
    sigma: jnp.ndarray        # (W,) f32 per-item measurement noise σ
    c0: jnp.ndarray           # (W,) f32 ramp floor
    p_r: jnp.ndarray          # (W,) f32 ramp knee (completed fraction)
    overshoot: jnp.ndarray    # (W,) f32
    d_requested: jnp.ndarray  # (W,) f32 requested TTC (s)
    valid: jnp.ndarray        # (W,) bool — False rows are padding

    @property
    def n(self) -> int:
        """Row capacity W (== workload count when ``valid`` is all-True)."""
        return self.t_arrive.shape[0]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Static (numpy) description of a workload arrival schedule."""

    t_arrive: np.ndarray   # (W,) arrival tick per workload
    family: np.ndarray     # (W,) family id
    m0: np.ndarray         # (W, K) items per type (K=1 here)
    b_true: np.ndarray     # (W, K) true mean CUS per item
    sigma: np.ndarray      # (W,) per-item measurement noise σ
    c0: np.ndarray         # (W,) ramp floor
    p_r: np.ndarray        # (W,) ramp knee (completed fraction)
    overshoot: np.ndarray  # (W,)
    d_requested: np.ndarray  # (W,) requested TTC (s)

    @property
    def n(self) -> int:
        return len(self.t_arrive)

    @property
    def total_cus(self) -> float:
        return float(np.sum(self.m0[:, 0] * self.b_true[:, 0]))

    def as_jax(self) -> JaxSchedule:
        return JaxSchedule(
            t_arrive=jnp.asarray(self.t_arrive, jnp.int32),
            family=jnp.asarray(self.family, jnp.int32),
            m0=jnp.asarray(self.m0, jnp.float32),
            b_true=jnp.asarray(self.b_true, jnp.float32),
            sigma=jnp.asarray(self.sigma, jnp.float32),
            c0=jnp.asarray(self.c0, jnp.float32),
            p_r=jnp.asarray(self.p_r, jnp.float32),
            overshoot=jnp.asarray(self.overshoot, jnp.float32),
            d_requested=jnp.asarray(self.d_requested, jnp.float32),
            valid=jnp.ones((self.n,), bool),
        )


def as_jax_schedule(schedule: Schedule | JaxSchedule) -> JaxSchedule:
    """Normalize either schedule form to the ``JaxSchedule`` pytree."""
    if isinstance(schedule, JaxSchedule):
        return schedule
    if isinstance(schedule, Schedule):
        return schedule.as_jax()
    raise TypeError(
        f"expected a Schedule or JaxSchedule, got {type(schedule).__name__}")


def schedule_shape(schedule: Schedule | JaxSchedule) -> tuple:
    """Hashable (field, dtype, shape) signature — the *scenario shape* the
    compilation caches key on (two schedules of one shape share a compile)."""
    sj = as_jax_schedule(schedule)
    return tuple((name, str(arr.dtype), tuple(arr.shape))
                 for name, arr in zip(sj._fields, sj))


def schedule_digest(schedule: Schedule) -> str:
    """Content hash of a static numpy ``Schedule`` (used to make replay
    scenario specs hashable without comparing arrays elementwise)."""
    h = hashlib.sha256()
    for f in dataclasses.fields(schedule):
        arr = np.asarray(getattr(schedule, f.name))
        h.update(f.name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def pad_schedule(sched: JaxSchedule, max_w: int) -> JaxSchedule:
    """Pad a schedule's W axis up to ``max_w`` rows of inert padding:
    ``t_arrive = -1`` (never arrives), zero work, ``valid = False``."""
    w = sched.n
    if max_w < w:
        raise ValueError(f"cannot pad {w} workloads down to max_w={max_w}")
    if max_w == w:
        return sched
    pad = max_w - w

    def pad1(arr, fill):
        widths = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
        return jnp.pad(arr, widths, constant_values=fill)

    return JaxSchedule(
        t_arrive=pad1(sched.t_arrive, -1),
        family=pad1(sched.family, 0),
        m0=pad1(sched.m0, 0.0),
        b_true=pad1(sched.b_true, 0.0),
        sigma=pad1(sched.sigma, 0.0),
        c0=pad1(sched.c0, 0.0),
        p_r=pad1(sched.p_r, 1.0),
        overshoot=pad1(sched.overshoot, 0.0),
        # A real-looking TTC keeps deadline arithmetic finite; the valid
        # mask keeps padded rows out of every violation/cost statistic.
        d_requested=pad1(sched.d_requested, 1.0),
        valid=pad1(sched.valid, False),
    )


def paper_schedule(ttc: float = 7620.0,
                   arrival_gap_ticks: int = 5,
                   seed: int = 0) -> Schedule:
    """The 30-workload §V.A suite.

    ttc: fixed TTC per workload in seconds (paper: 2h07m = 7620 s, or
         1h37m = 5820 s).
    arrival_gap_ticks: one workload every 5 monitoring ticks (= 5 min at
         1-min monitoring, as in the paper).
    """
    rng = np.random.default_rng(seed)
    fam, counts = [], []
    # 8 face-detection workloads, 1..1000 images.
    for c in [40, 120, 300, 500, 700, 850, 950, 1000]:
        fam.append(FACE); counts.append(c)
    # 8 transcodes: six small (1..20 videos) + the 200/300-video spikes.
    for c in [3, 8, 12, 20, 200, 15, 300, 6]:
        fam.append(TRANSCODE); counts.append(c)
    # 7 BRISK + 7 SIFT feature-extraction workloads.
    for c in [80, 150, 260, 420, 600, 380, 220]:
        fam.append(BRISK); counts.append(c)
    for c in [60, 120, 350, 500, 280, 170, 90]:
        fam.append(SIFT); counts.append(c)

    # Interleave families like the paper's Fig. 2 (mixed order, spikes at
    # submissions #11 and #17 to probe responsiveness mid-experiment).
    order = [0, 8, 16, 23, 1, 9, 17, 24, 2, 10, 12, 18, 25, 3, 11, 19, 14,
             26, 4, 13, 20, 27, 5, 21, 28, 6, 15, 22, 29, 7]
    fam = [fam[i] for i in order]
    counts = [counts[i] for i in order]

    w = len(fam)
    b_true = np.zeros((w, 1))
    sigma = np.zeros(w)
    c0 = np.zeros(w)
    p_r = np.zeros(w)
    ov = np.zeros(w)
    for i, f in enumerate(fam):
        prm = FAMILY_PARAMS[f]
        # Per-workload mean CUS jitters around the family mean (different
        # codecs / image sizes across workloads of the same family).
        b_true[i, 0] = prm["mean_cus"] * float(rng.lognormal(0.0, 0.15))
        sigma[i] = prm["sigma"]
        c0[i] = prm["c0"]
        p_r[i] = prm["p_r"]
        ov[i] = prm["overshoot"]
        # The two spike workloads are long-form video (paper Fig. 2: 5.5 GB
        # and 8 GB inputs — far heavier per item than the small transcodes).
        # Their demand r/d rides the per-workload cap N_{w,max} for most of
        # their TTC, which is what paces the experiment tail.
        if f == TRANSCODE and counts[i] == 200:
            b_true[i, 0] = 150.0
        elif f == TRANSCODE and counts[i] == 300:
            b_true[i, 0] = 150.0

    return Schedule(
        t_arrive=np.arange(w) * arrival_gap_ticks,
        family=np.asarray(fam),
        m0=np.asarray(counts, np.float64).reshape(w, 1),
        b_true=b_true,
        sigma=sigma, c0=c0, p_r=p_r, overshoot=ov,
        d_requested=np.full(w, ttc),
    )


def uniform_schedule(n: int, family: int, items: int, item_cus: float,
                     ttc: float, arrival_gap_ticks: int = 0,
                     seed: int = 0) -> Schedule:
    """N identical workloads of one family (Lambda comparison, unit tests)."""
    prm = FAMILY_PARAMS[family]
    return Schedule(
        t_arrive=np.arange(n) * arrival_gap_ticks,
        family=np.full(n, family),
        m0=np.full((n, 1), float(items)),
        b_true=np.full((n, 1), item_cus),
        sigma=np.full(n, prm["sigma"]),
        c0=np.full(n, prm["c0"]),
        p_r=np.full(n, prm["p_r"]),
        overshoot=np.full(n, prm["overshoot"]),
        d_requested=np.full(n, ttc),
    )


def ramp(p: jnp.ndarray, c0: jnp.ndarray, p_r: jnp.ndarray,
         overshoot: jnp.ndarray) -> jnp.ndarray:
    """Measured-CUS bias vs completed fraction p (rise → overshoot → settle)."""
    rising = c0 + (1.0 - c0 + overshoot) * jnp.minimum(
        p / jnp.maximum(p_r, 1e-6), 1.0)
    settled = 1.0 + overshoot * jnp.exp(-(p - p_r) / 0.15)
    return jnp.where(p <= p_r, rising, settled)
