# §V testbed: discrete-time cloud simulator, the 30-workload suite, the
# stochastic workload scenario generators, the Lambda billing model, the
# JAX spot market and its vmapped sweep harness, and the chaos engine
# (``faults``: traced fault injection across market, fleet and telemetry).
# ``tenants`` extends the testbed to a multi-tenant shared fleet with
# attributed billing.  The old ``market`` numpy facade is gone: its one
# consumer (``ft.failures``) now rides ``spot``/``faults`` directly.
from ..core.types import PolicyParams, TenantConfig, make_policy_params
from . import (faults, lambda_model, runner, scenarios, spot, sweep,
               tenants, workloads)
from .faults import ChaosScenario, FaultConfig, FaultModel, FaultSpec
from .runner import SimConfig, SimTrace, default_params, run, run_obs
from .scenarios import ScenarioSet, default_set, paper_scenario
from .spot import SpotConfig
from .sweep import (ChunkProfile, SweepAxes, SweepReport, SweepSpec,
                    SweepStream, make_axes, run_single, run_sweep)
from .tenants import (TenantRun, TenantSet, TenantSpec, TenantSummary,
                      isolated_runs, run_tenants, tenant_sweep)
from .workloads import (JaxSchedule, Schedule, paper_schedule,
                        uniform_schedule)

__all__ = ["faults", "lambda_model", "runner", "scenarios", "spot", "sweep",
           "tenants", "workloads", "SimConfig", "SimTrace", "run",
           "ChaosScenario", "FaultConfig", "FaultModel", "FaultSpec",
           "ScenarioSet", "default_set", "paper_scenario", "SpotConfig",
           "ChunkProfile", "SweepAxes", "SweepReport", "SweepSpec",
           "SweepStream", "make_axes", "run_single", "run_sweep", "run_obs",
           "JaxSchedule", "Schedule", "paper_schedule", "uniform_schedule",
           "PolicyParams", "TenantConfig", "make_policy_params",
           "default_params", "TenantRun", "TenantSet", "TenantSpec",
           "TenantSummary", "isolated_runs", "run_tenants", "tenant_sweep"]
