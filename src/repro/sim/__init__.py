# §V testbed: discrete-time cloud simulator, the 30-workload suite, the
# stochastic workload scenario generators, the Lambda billing model, the
# JAX spot market and its vmapped sweep harness (``market`` is the numpy
# facade kept for ft/failures compat).  ``tenants`` extends the testbed to
# a multi-tenant shared fleet with attributed billing.
from ..core.types import PolicyParams, TenantConfig, make_policy_params
from . import (lambda_model, market, runner, scenarios, spot, sweep,
               tenants, workloads)
from .runner import SimConfig, SimTrace, default_params, run
from .scenarios import ScenarioSet, default_set, paper_scenario
from .spot import SpotConfig
from .sweep import (SweepAxes, SweepSpec, SweepStream, make_axes,
                    run_single, run_sweep)
from .tenants import (TenantRun, TenantSet, TenantSpec, TenantSummary,
                      isolated_runs, run_tenants, tenant_sweep)
from .workloads import (JaxSchedule, Schedule, paper_schedule,
                        uniform_schedule)

__all__ = ["lambda_model", "market", "runner", "scenarios", "spot", "sweep",
           "tenants", "workloads", "SimConfig", "SimTrace", "run",
           "ScenarioSet", "default_set", "paper_scenario", "SpotConfig",
           "SweepAxes", "SweepSpec", "SweepStream", "make_axes",
           "run_single", "run_sweep",
           "JaxSchedule", "Schedule", "paper_schedule", "uniform_schedule",
           "PolicyParams", "TenantConfig", "make_policy_params",
           "default_params", "TenantRun", "TenantSet", "TenantSpec",
           "TenantSummary", "isolated_runs", "run_tenants", "tenant_sweep"]
