# §V testbed: discrete-time cloud simulator, the 30-workload suite,
# Lambda billing model and the spot-market trace generator.
from . import lambda_model, market, runner, workloads
from .runner import SimConfig, SimTrace, run
from .workloads import Schedule, paper_schedule, uniform_schedule

__all__ = ["lambda_model", "market", "runner", "workloads", "SimConfig",
           "SimTrace", "run", "Schedule", "paper_schedule",
           "uniform_schedule"]
