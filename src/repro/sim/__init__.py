# §V testbed: discrete-time cloud simulator, the 30-workload suite, the
# stochastic workload scenario generators, the Lambda billing model, the
# JAX spot market and its vmapped sweep harness (``market`` is the numpy
# facade kept for ft/failures compat).
from ..core.types import PolicyParams, make_policy_params
from . import (lambda_model, market, runner, scenarios, spot, sweep,
               workloads)
from .runner import SimConfig, SimTrace, default_params, run
from .scenarios import ScenarioSet, default_set, paper_scenario
from .spot import SpotConfig
from .sweep import SweepAxes, make_axes, run_single, run_sweep
from .workloads import (JaxSchedule, Schedule, paper_schedule,
                        uniform_schedule)

__all__ = ["lambda_model", "market", "runner", "scenarios", "spot", "sweep",
           "workloads", "SimConfig", "SimTrace", "run", "ScenarioSet",
           "default_set", "paper_scenario", "SpotConfig", "SweepAxes",
           "make_axes", "run_single", "run_sweep", "JaxSchedule",
           "Schedule", "paper_schedule", "uniform_schedule",
           "PolicyParams", "make_policy_params", "default_params"]
