"""JAX-native spot-market dynamics (paper Appendix A, Fig. 6 / Table V).

The paper's empirical findings, as a generative price process:

  * spot price scales ~linearly with the CU count of the instance type;
  * price *volatility* also grows with CU count — the single-CU m3.medium
    never exceeded $0.01 over three months, while m4.10xlarge spiked hard;
  * sparse demand spikes multiply the price several-fold, increasingly
    often for large instances;
  * the Table-V types live in one region and co-move: a demand shock that
    lifts m3.xlarge lifts its neighbours too.

Price model: *all* Table-V types evolve together as one correlated
log-AR(1) system around their base prices.  Each type's log-deviation is
driven by a shared market factor plus idiosyncratic noise,

    eps_i = sqrt(corr) * eps_market + sqrt(1 - corr) * eps_i_own,

so the cross-type correlation of log-price increments is ``corr`` while
every marginal remains exactly the single-type process of the original
model (eps_i is still N(0, 1)).  The AR coefficient and innovation are
rescaled with the step size so the stationary log-price distribution is
invariant to the monitoring interval, and demand spikes are a per-type
two-state process — arriving at ``p_spike`` per hour, lasting one hour in
expectation — so the spiked-time fraction is interval-invariant too.

Everything here is pure jnp on fixed shapes: a full multi-type price path
is one ``lax.scan``, and every function is ``vmap``-able over
``SpotRuntime`` — which is how ``sim.sweep`` batches Monte-Carlo sweeps
over seeds × bid policies × fleet mixes in a single jitted call.

Bid semantics (EC2 2015): while spot price ≤ bid you hold the instance and
pay the *current* spot price per started quantum; the instant price > bid
the instance is reclaimed (``core.billing.preempt``) and new requests at
that bid go unfulfilled until the price falls back.  A request's bid is
fixed at request time — dynamic policies change the bid attached to *new*
requests, never to running instances.

Bid policies (``BID_POLICIES``, evaluated per scan step by
``current_bids``):

  * ``multiple``   — static ``bid_mult`` × base spot price (the paper's
                     fixed-bid setting);
  * ``on_demand``  — bid the on-demand price: the classic
                     never-lose-capacity cap;
  * ``ttc``        — TTC-aware: start at the static bid and raise it
                     toward the on-demand cap as workloads fall behind
                     schedule (urgency = ttc_gain × max over active
                     workloads of time-fraction-used − work-fraction-done,
                     so an on-track fleet keeps bidding the cheap floor);
  * ``ema``        — market-aware: bid ``bid_mult`` × a running price EMA
                     (capped at on-demand), so the fleet tracks the calm
                     price level, sheds during spikes, and re-acquires the
                     moment the market falls back.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

# Appendix A, Table V (North Virginia, 2015-07-10).
#                  cores  on_demand   spot
INSTANCE_TYPES = {
    "m3.medium":    (1,     0.067,      0.0081),
    "m3.large":     (2,     0.133,      0.0173),
    "m3.xlarge":    (4,     0.266,      0.0333),
    "m3.2xlarge":   (8,     0.532,      0.0660),
    "m4.4xlarge":   (16,    1.008,      0.1097),
    "m4.10xlarge":  (40,    2.520,      0.5655),
}
INSTANCE_NAMES = tuple(INSTANCE_TYPES)
N_TYPES = len(INSTANCE_NAMES)

# Same table as jnp constants, indexable by a *traced* instance-type id —
# the axis sim.sweep vmaps over.
CORES_TABLE = jnp.asarray([v[0] for v in INSTANCE_TYPES.values()],
                          jnp.float32)
ON_DEMAND_TABLE = jnp.asarray([v[1] for v in INSTANCE_TYPES.values()],
                              jnp.float32)
SPOT_BASE_TABLE = jnp.asarray([v[2] for v in INSTANCE_TYPES.values()],
                              jnp.float32)

BID_POLICIES = ("multiple", "on_demand", "ttc", "ema")


def instance_index(instance: str) -> int:
    if instance not in INSTANCE_TYPES:
        raise ValueError(f"unknown instance type {instance!r}; "
                         f"Table V has {INSTANCE_NAMES}")
    return INSTANCE_NAMES.index(instance)


def bid_policy_index(policy: str) -> int:
    if policy not in BID_POLICIES:
        raise ValueError(f"unknown bid policy {policy!r}; "
                         f"choose one of {BID_POLICIES}")
    return BID_POLICIES.index(policy)


def fleet_mask(fleet: Sequence[str | int]) -> jnp.ndarray:
    """(T,) float32 membership mask of a fleet mix over the Table-V types."""
    mask = [0.0] * N_TYPES
    for member in fleet:
        idx = (instance_index(member) if isinstance(member, str)
               else int(member))
        mask[idx] = 1.0
    return jnp.asarray(mask, jnp.float32)


@dataclasses.dataclass(frozen=True)
class SpotConfig:
    """Static knobs of the market process (closed over at trace time)."""

    enabled: bool = False
    instance: str = "m3.medium"   # primary instance type (granularity axis)
    # Allowed Table-V types of the fleet; None = single-type (``instance``).
    # With more than one member, every acquisition picks the
    # cheapest-per-CU type whose current price is at or below our bid.
    fleet: tuple[str, ...] | None = None
    bid_policy: str = "multiple"  # one of BID_POLICIES
    bid_mult: float = 1.5         # bid = bid_mult × base (or × EMA) price
    rho: float = 0.97             # hourly AR(1) coefficient (market.py legacy)
    vol0: float = 0.01            # hourly log-volatility floor ...
    vol_scale: float = 0.035      # ... + vol_scale · log2(cores + 1)
    p_spike_per_core: float = 0.002   # hourly demand-spike probability / core
    spike_lo: float = 2.0         # spike multiplier ~ U[spike_lo, spike_hi]
    spike_hi: float = 8.0
    spike_hours: float = 1.0      # mean spike duration (hours); >1 makes
                                  # holding through a spike renew several
                                  # quanta at the spiked price, so
                                  # shedding-and-rebuying can pay off
    # Cross-type coupling: correlation of log-price increments between any
    # two Table-V types (0 = independent markets, →1 = one shared market).
    corr: float = 0.6
    # Per-hour weight of the running price EMA the 'ema' policy bids on.
    ema_alpha: float = 0.3
    # TTC-aware escalation gain: urgency = ttc_gain × how far the most
    # behind-schedule active workload has fallen (time fraction used minus
    # work fraction done), clipped to [0, 1].  An on-track fleet keeps the
    # floor bid; one knocked behind by preemptions escalates toward the
    # on-demand cap.
    ttc_gain: float = 4.0

    def __post_init__(self):
        # ValueError (not assert) so misconfigured sweeps fail identically
        # under ``python -O`` — same path as ``instance_index``.
        bid_policy_index(self.bid_policy)
        instance_index(self.instance)
        for member in self.fleet or ():
            instance_index(member)
        if not 0.0 <= self.corr < 1.0:
            raise ValueError(f"corr must be in [0, 1), got {self.corr}")
        if not self.spike_hours > 0.0:
            raise ValueError(
                f"spike_hours must be positive, got {self.spike_hours}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(
                f"ema_alpha must be in (0, 1], got {self.ema_alpha}")


class SpotRuntime(NamedTuple):
    """Per-run fleet constants as traced values (the vmap axes).

    ``itype``/``cores``/``base_price``/``on_demand``/``bid`` describe the
    *primary* type — the single-type view legacy callers and the trace
    outputs use.  ``mix`` is the fleet-membership mask the acquisition
    step chooses from, ``policy`` the BID_POLICIES id, ``bid_mult`` the
    static multiple (also the EMA multiple and the TTC floor).
    """

    itype: jnp.ndarray       # () int32 primary index into the Table-V arrays
    cores: jnp.ndarray       # () CUs per primary instance
    base_price: jnp.ndarray  # () $ / instance-quantum, primary spot baseline
    on_demand: jnp.ndarray   # () $ / instance-quantum, primary on-demand
    bid: jnp.ndarray         # () static $ bid of the primary type (info)
    bid_mult: jnp.ndarray    # () bid as a multiple of base (or EMA) price
    policy: jnp.ndarray      # () int32 index into BID_POLICIES
    mix: jnp.ndarray         # (T,) float32 fleet-membership mask


class SpotState(NamedTuple):
    """Multi-type market state carried through the simulator scan."""

    x: jnp.ndarray           # (T,) log-deviations of the correlated AR(1)
    prices: jnp.ndarray      # (T,) current $ / instance-quantum per type
    spike_mult: jnp.ndarray  # (T,) active demand-spike multiplier (1 = calm)
    ema: jnp.ndarray         # (T,) running price EMA (the 'ema' bid policy)
    key: jax.Array           # market-private PRNG chain (keeps the
                             # simulator's execution-noise stream untouched)
    rt: SpotRuntime

    @property
    def price(self) -> jnp.ndarray:
        """() current price of the run's *primary* instance type."""
        return self.prices[self.rt.itype]


def _vol_table(cfg: SpotConfig) -> jnp.ndarray:
    """(T,) hourly log-volatility per type (CU-proportional, Fig. 6)."""
    return cfg.vol0 + cfg.vol_scale * jnp.log2(CORES_TABLE + 1.0)


def _p_spike_table(cfg: SpotConfig) -> jnp.ndarray:
    """(T,) hourly demand-spike probability per type."""
    return cfg.p_spike_per_core * CORES_TABLE


def make_runtime(cfg: SpotConfig,
                 itype: jnp.ndarray | int | None = None,
                 bid_mult: jnp.ndarray | float | None = None,
                 policy: jnp.ndarray | int | str | None = None,
                 mix: jnp.ndarray | None = None) -> SpotRuntime:
    """Resolve the fleet constants for one run.

    ``itype``, ``bid_mult``, ``policy`` and ``mix`` may be traced — these
    are the hooks ``sim.sweep`` uses to vmap one jitted simulation over
    instance granularities, bid levels, bid policies and fleet mixes.
    """
    if itype is None:
        itype = instance_index(cfg.fleet[0] if cfg.fleet else cfg.instance)
    itype = jnp.asarray(itype, jnp.int32)
    if mix is None:
        if cfg.fleet:
            mix = fleet_mask(cfg.fleet)
        else:
            mix = (jnp.arange(N_TYPES) == itype).astype(jnp.float32)
    mix = jnp.asarray(mix, jnp.float32)
    if policy is None:
        policy = bid_policy_index(cfg.bid_policy)
    elif isinstance(policy, str):
        policy = bid_policy_index(policy)
    policy = jnp.asarray(policy, jnp.int32)
    if bid_mult is None:
        bid_mult = cfg.bid_mult
    bid_mult = jnp.asarray(bid_mult, jnp.float32)

    cores = CORES_TABLE[itype]
    base = SPOT_BASE_TABLE[itype]
    on_demand = ON_DEMAND_TABLE[itype]
    # Informational static bid of the primary type under the *config's*
    # policy (dynamic policies start here at t=0, urgency 0, EMA = base).
    if cfg.bid_policy == "on_demand":
        bid = on_demand * jnp.ones_like(base)
    else:
        bid = bid_mult * base
    return SpotRuntime(itype=itype, cores=cores, base_price=base,
                       on_demand=on_demand, bid=bid, bid_mult=bid_mult,
                       policy=policy, mix=mix)


def init(rt: SpotRuntime, key: jax.Array) -> SpotState:
    """Market at its baseline: zero log-deviations, prices = Table-V base."""
    return SpotState(x=jnp.zeros((N_TYPES,)),
                     prices=SPOT_BASE_TABLE * 1.0,
                     spike_mult=jnp.ones((N_TYPES,)),
                     ema=SPOT_BASE_TABLE * 1.0,
                     key=key, rt=rt)


def step(state: SpotState, cfg: SpotConfig, dt: float,
         ema_alpha: jnp.ndarray | float | None = None) -> SpotState:
    """Advance all Table-V prices one monitoring interval of ``dt`` seconds.

    ``ema_alpha`` optionally overrides ``cfg.ema_alpha`` with a *traced*
    per-hour EMA weight (``core.types.PolicyParams.ema_alpha``) — the hook
    that makes the market-aware bid policy's smoothing coefficient tunable
    inside one compiled sweep.  Either path runs the same f32 arithmetic,
    so the default-valued override is bit-identical to no override.

    The hourly AR(1) (rho, vol) is rescaled so each type's stationary
    log-price variance vol²/(1-rho²) is preserved at any dt.  Innovations
    share a market factor: eps_i = √corr·eps_mkt + √(1−corr)·eps_own, so
    increments correlate at ``corr`` across types while every marginal is
    exactly the single-type process (eps_i ~ N(0,1)).  Demand spikes are a
    per-type two-state process: from calm, one arrives with probability
    p_spike·h; once active it ends with probability h per step (mean
    duration one hour).  Both the spiked-time fraction and the marginal
    price distribution are therefore invariant to dt, and at an hourly
    step with ``spike_hours = 1`` the process reduces exactly to the
    legacy per-hour Bernoulli spike.  A spike ends with probability
    ``h / spike_hours`` per step (mean duration ``spike_hours``).
    """
    key, k_mkt, k_eps, k_enter, k_exit, k_mult = jax.random.split(
        state.key, 6)
    h = dt / 3600.0
    rho_dt = cfg.rho ** h
    vol = _vol_table(cfg)
    vol_dt = vol * jnp.sqrt((1.0 - rho_dt ** 2) / (1.0 - cfg.rho ** 2))
    eps = (jnp.sqrt(cfg.corr) * jax.random.normal(k_mkt)
           + jnp.sqrt(1.0 - cfg.corr) * jax.random.normal(k_eps, (N_TYPES,)))
    x = rho_dt * state.x + vol_dt * eps

    p_spike = _p_spike_table(cfg)
    in_spike = state.spike_mult > 1.0
    ends = (jax.random.uniform(k_exit, (N_TYPES,))
            < jnp.minimum(h / cfg.spike_hours, 1.0))
    arrives = (jax.random.uniform(k_enter, (N_TYPES,))
               < jnp.minimum(p_spike * h, 1.0))
    fresh = jax.random.uniform(k_mult, (N_TYPES,), minval=cfg.spike_lo,
                               maxval=cfg.spike_hi)
    # A step that is calm — or whose spike just ended — may see a fresh
    # arrival, so at h = 1 every hour is an independent Bernoulli(p_spike)
    # draw, exactly the legacy hourly generator.
    calm = ~in_spike | ends
    spike_mult = jnp.where(calm, jnp.where(arrives, fresh, 1.0),
                           state.spike_mult)
    prices = SPOT_BASE_TABLE * jnp.exp(x) * spike_mult
    # Running price EMA for the market-aware bid policy, rescaled so its
    # per-hour weight is ``ema_alpha`` at any monitoring interval.
    a_hr = jnp.asarray(cfg.ema_alpha if ema_alpha is None else ema_alpha,
                       jnp.float32)
    a_dt = 1.0 - (1.0 - a_hr) ** h
    ema = (1.0 - a_dt) * state.ema + a_dt * prices
    return SpotState(x=x, prices=prices, spike_mult=spike_mult, ema=ema,
                     key=key, rt=state.rt)


def current_bids(cfg: SpotConfig, rt: SpotRuntime, state: SpotState,
                 urgency: jnp.ndarray | float = 0.0) -> jnp.ndarray:
    """(T,) $ bid per type attached to *new* requests this instant.

    All BID_POLICIES are evaluated and the runtime's (possibly traced)
    ``policy`` id selects one — which is what lets ``sim.sweep`` vmap the
    bid policy as an experiment axis.  ``urgency`` ∈ [0, 1] is the
    TTC-aware signal: 0 = every active workload on schedule, 1 = some
    deadline is at risk (the fleet fell far enough behind).
    """
    urgency = jnp.clip(jnp.asarray(urgency, jnp.float32), 0.0, 1.0)
    static = rt.bid_mult * SPOT_BASE_TABLE
    on_demand = ON_DEMAND_TABLE * jnp.ones_like(static)
    # TTC-aware: interpolate from the static bid up to the never-lose-
    # capacity cap as deadline slack shrinks.
    cap = jnp.maximum(on_demand, static)
    ttc = static + urgency * (cap - static)
    # Market-aware: track the calm price level, never pay above on-demand.
    ema = jnp.minimum(rt.bid_mult * state.ema, on_demand)
    return jnp.stack([static, on_demand, ttc, ema])[rt.policy]


def select_type(prices: jnp.ndarray, bids: jnp.ndarray, mix: jnp.ndarray,
                avail: jnp.ndarray | None = None,
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pick the acquisition type: cheapest-per-CU currently-available.

    A type is available when it is in the fleet ``mix`` and the market
    currently clears at or below our bid for it (an EC2 request above the
    clearing price is simply not fulfilled).  Returns ``(itype, any)``;
    when no type is available ``any`` is False and the caller must not
    start instances (``itype`` is then arbitrary).

    ``avail`` optionally supplies a (T,) capacity mask from the chaos
    engine (``sim.faults``): a hardened controller passes it so selection
    hedges across the types that still *have* capacity instead of
    queueing on a dried-up one.  ``None`` compiles the exact historical
    selection.
    """
    ok = (prices <= bids) & (mix > 0.0)
    if avail is not None:
        ok = ok & avail
    per_cu = prices / CORES_TABLE
    score = jnp.where(ok, per_cu, jnp.inf)
    return jnp.argmin(score).astype(jnp.int32), jnp.any(ok)


def price_trace(rt: SpotRuntime, steps: int, key: jax.Array,
                cfg: SpotConfig = SpotConfig(), dt: float = 3600.0
                ) -> jnp.ndarray:
    """A (steps,)-shaped price path of the primary type in one ``lax.scan``.

    vmap over ``rt`` (and/or ``key``) for batched traces.
    """
    return price_traces(rt, steps, key, cfg, dt)[:, rt.itype]


def price_traces(rt: SpotRuntime, steps: int, key: jax.Array,
                 cfg: SpotConfig = SpotConfig(), dt: float = 3600.0
                 ) -> jnp.ndarray:
    """(steps, T) correlated price paths of *all* Table-V types."""
    def body(s, _):
        s = step(s, cfg, dt)
        return s, s.prices

    _, prices = jax.lax.scan(body, init(rt, key), None, length=steps)
    return prices


def preemptions(trace: jnp.ndarray, bid: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask of steps in which a bid at ``bid`` is outbid."""
    return trace > bid
