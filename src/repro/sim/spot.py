"""JAX-native spot-market dynamics (paper Appendix A, Fig. 6 / Table V).

The paper's empirical findings, as a generative price process:

  * spot price scales ~linearly with the CU count of the instance type;
  * price *volatility* also grows with CU count — the single-CU m3.medium
    never exceeded $0.01 over three months, while m4.10xlarge spiked hard;
  * sparse demand spikes multiply the price several-fold, increasingly
    often for large instances.

Price model: log-AR(1) around the Table-V base price, advanced one
monitoring interval per step under ``lax.scan``.  The AR coefficient and
innovation are rescaled with the step size so the stationary log-price
distribution is invariant to the monitoring interval, and demand spikes
are a two-state process — arriving at ``p_spike`` per hour, lasting one
hour in expectation — so the spiked-time fraction is interval-invariant
too (at an hourly step it degenerates to the original per-hour Bernoulli
draw).  An hourly trace and a 1-minute trace therefore agree in marginal
distribution, which keeps the hourly numpy wrapper in ``sim.market`` and
the per-tick simulator consistent.

Everything here is pure jnp on fixed shapes: a full price path is one
``lax.scan``, and every function is ``vmap``-able over ``SpotRuntime`` —
which is how ``sim.sweep`` batches Monte-Carlo sweeps over seeds × bids ×
instance granularities in a single jitted call.

Bid semantics (EC2 2015): while spot price ≤ bid you hold the instance and
pay the *current* spot price per started quantum; the instant price > bid
the instance is reclaimed (``core.billing.preempt``) and new requests at
that bid go unfulfilled until the price falls back.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Appendix A, Table V (North Virginia, 2015-07-10).
#                  cores  on_demand   spot
INSTANCE_TYPES = {
    "m3.medium":    (1,     0.067,      0.0081),
    "m3.large":     (2,     0.133,      0.0173),
    "m3.xlarge":    (4,     0.266,      0.0333),
    "m3.2xlarge":   (8,     0.532,      0.0660),
    "m4.4xlarge":   (16,    1.008,      0.1097),
    "m4.10xlarge":  (40,    2.520,      0.5655),
}
INSTANCE_NAMES = tuple(INSTANCE_TYPES)

# Same table as jnp constants, indexable by a *traced* instance-type id —
# the axis sim.sweep vmaps over.
CORES_TABLE = jnp.asarray([v[0] for v in INSTANCE_TYPES.values()],
                          jnp.float32)
ON_DEMAND_TABLE = jnp.asarray([v[1] for v in INSTANCE_TYPES.values()],
                              jnp.float32)
SPOT_BASE_TABLE = jnp.asarray([v[2] for v in INSTANCE_TYPES.values()],
                              jnp.float32)

BID_POLICIES = ("multiple", "on_demand")


@dataclasses.dataclass(frozen=True)
class SpotConfig:
    """Static knobs of the market process (closed over at trace time)."""

    enabled: bool = False
    instance: str = "m3.medium"   # fleet instance type (granularity axis)
    bid_policy: str = "multiple"  # 'multiple' of spot base, or 'on_demand'
    bid_mult: float = 1.5         # bid = bid_mult × base spot price
    rho: float = 0.97             # hourly AR(1) coefficient (market.py legacy)
    vol0: float = 0.01            # hourly log-volatility floor ...
    vol_scale: float = 0.035      # ... + vol_scale · log2(cores + 1)
    p_spike_per_core: float = 0.002   # hourly demand-spike probability / core
    spike_lo: float = 2.0         # spike multiplier ~ U[spike_lo, spike_hi]
    spike_hi: float = 8.0

    def __post_init__(self):
        assert self.bid_policy in BID_POLICIES, self.bid_policy
        assert self.instance in INSTANCE_TYPES, self.instance


class SpotRuntime(NamedTuple):
    """Per-run market constants as traced scalars (the vmap axes)."""

    itype: jnp.ndarray       # () int32 index into the Table-V arrays
    cores: jnp.ndarray       # () CUs per instance
    base_price: jnp.ndarray  # () $ / instance-quantum, spot baseline
    on_demand: jnp.ndarray   # () $ / instance-quantum, on-demand
    vol: jnp.ndarray         # () hourly log-volatility
    p_spike: jnp.ndarray     # () hourly spike probability
    bid: jnp.ndarray         # () $ / instance-quantum the fleet bids


class SpotState(NamedTuple):
    """Market state carried through the simulator scan."""

    x: jnp.ndarray           # () log-deviation of the AR(1)
    price: jnp.ndarray       # () current $ / instance-quantum
    spike_mult: jnp.ndarray  # () active demand-spike multiplier (1 = calm)
    key: jax.Array           # market-private PRNG chain (keeps the
                             # simulator's execution-noise stream untouched)
    rt: SpotRuntime


def instance_index(instance: str) -> int:
    if instance not in INSTANCE_TYPES:
        raise ValueError(f"unknown instance type {instance!r}; "
                         f"Table V has {INSTANCE_NAMES}")
    return INSTANCE_NAMES.index(instance)


def make_runtime(cfg: SpotConfig,
                 itype: jnp.ndarray | int | None = None,
                 bid_mult: jnp.ndarray | float | None = None) -> SpotRuntime:
    """Resolve the market constants for one run.

    ``itype`` and ``bid_mult`` may be traced scalars — this is the hook
    ``sim.sweep`` uses to vmap one jitted simulation over instance
    granularities and bid levels.
    """
    if itype is None:
        itype = instance_index(cfg.instance)
    itype = jnp.asarray(itype, jnp.int32)
    cores = CORES_TABLE[itype]
    base = SPOT_BASE_TABLE[itype]
    on_demand = ON_DEMAND_TABLE[itype]
    vol = cfg.vol0 + cfg.vol_scale * jnp.log2(cores + 1.0)
    p_spike = cfg.p_spike_per_core * cores
    if cfg.bid_policy == "on_demand":
        bid = on_demand * jnp.ones_like(base)
    else:
        if bid_mult is None:
            bid_mult = cfg.bid_mult
        bid = jnp.asarray(bid_mult, jnp.float32) * base
    return SpotRuntime(itype=itype, cores=cores, base_price=base,
                       on_demand=on_demand, vol=vol, p_spike=p_spike,
                       bid=bid)


def init(rt: SpotRuntime, key: jax.Array) -> SpotState:
    """Market at its baseline: zero log-deviation, price = Table-V base."""
    return SpotState(x=jnp.zeros(()), price=rt.base_price * 1.0,
                     spike_mult=jnp.ones(()), key=key, rt=rt)


def step(state: SpotState, cfg: SpotConfig, dt: float) -> SpotState:
    """Advance the price one monitoring interval of ``dt`` seconds.

    The hourly AR(1) (rho, vol) is rescaled so the stationary log-price
    variance vol²/(1-rho²) is preserved at any dt.  Demand spikes are a
    two-state process: from calm, one arrives with probability p_spike·h;
    once active it ends with probability h per step (mean duration one
    hour).  Both the spiked-time fraction and the marginal price
    distribution are therefore invariant to dt, and at an hourly step the
    process reduces exactly to the legacy per-hour Bernoulli spike.
    """
    key, k_eps, k_enter, k_exit, k_mult = jax.random.split(state.key, 5)
    rt = state.rt
    h = dt / 3600.0
    rho_dt = cfg.rho ** h
    vol_dt = rt.vol * jnp.sqrt((1.0 - rho_dt ** 2) /
                               (1.0 - cfg.rho ** 2))
    x = rho_dt * state.x + vol_dt * jax.random.normal(k_eps)

    in_spike = state.spike_mult > 1.0
    ends = jax.random.uniform(k_exit) < jnp.minimum(h, 1.0)
    arrives = jax.random.uniform(k_enter) < jnp.minimum(rt.p_spike * h, 1.0)
    fresh = jax.random.uniform(k_mult, minval=cfg.spike_lo,
                               maxval=cfg.spike_hi)
    # A step that is calm — or whose spike just ended — may see a fresh
    # arrival, so at h = 1 every hour is an independent Bernoulli(p_spike)
    # draw, exactly the legacy hourly generator.
    calm = ~in_spike | ends
    spike_mult = jnp.where(calm, jnp.where(arrives, fresh, 1.0),
                           state.spike_mult)
    price = rt.base_price * jnp.exp(x) * spike_mult
    return SpotState(x=x, price=price, spike_mult=spike_mult, key=key, rt=rt)


def price_trace(rt: SpotRuntime, steps: int, key: jax.Array,
                cfg: SpotConfig = SpotConfig(), dt: float = 3600.0
                ) -> jnp.ndarray:
    """A full (steps,)-shaped price path in one ``lax.scan``.

    vmap over ``rt`` (and/or ``key``) for batched multi-type traces.
    """
    def body(s, _):
        s = step(s, cfg, dt)
        return s, s.price

    _, prices = jax.lax.scan(body, init(rt, key), None, length=steps)
    return prices


def preemptions(trace: jnp.ndarray, bid: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask of steps in which a bid at ``bid`` is outbid."""
    return trace > bid
