"""AWS Lambda billing comparator (paper §V.D, Table IV).

2015 Lambda pricing: $0.00001667 per GB-second, billed in 100 ms increments,
plus $0.20 per 1M requests.  The paper uses the 1024 MB configuration for
every function, so GB-s == wall-seconds.
"""

from __future__ import annotations

import math

GBS_RATE = 1.667e-5        # $ per GB-second
REQUEST_RATE = 2.0e-7      # $ per invocation
BILL_INCREMENT = 0.1       # seconds
MEM_GB = 1.0               # paper: 1024 MB for all functions


def lambda_cost_per_item(item_seconds: float, mem_gb: float = MEM_GB) -> float:
    """Billed cost of one Lambda invocation of the given duration."""
    billed = math.ceil(item_seconds / BILL_INCREMENT) * BILL_INCREMENT
    return billed * mem_gb * GBS_RATE + REQUEST_RATE


# The three ImageMagick functions of Table IV with calibrated mean runtimes
# (chosen to land on the paper's reported Lambda unit costs; the *platform*
# side is simulated end-to-end, not assumed).
IMAGEMAGICK = {
    "blur": 2.80,        # most compute-intensive
    "convolve": 0.98,
    "rotate": 0.31,      # fastest
}
N_IMAGES = 25_000
