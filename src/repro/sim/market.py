"""Spot-market price traces (paper Appendix A, Fig. 6 / Table V).

The paper's empirical findings, encoded as a generative trace model:
  * spot price scales ~linearly with the CU count of the instance type;
  * price *volatility* also grows with CU count — the single-CU m3.medium
    never exceeded $0.01 over three months, while m4.10xlarge spiked hard.

The model supports the paper's design decision (use many single-CU
instances) and the simulator's optional preemption ablation: when the
bid < spot price, instances are reclaimed (the same event the elastic
runtime in ``repro.ft`` treats as a node failure).
"""

from __future__ import annotations

import numpy as np

# Appendix A, Table V (North Virginia, 2015-07-10).
INSTANCE_TYPES = {
    #                cores  on_demand   spot
    "m3.medium":    (1,     0.067,      0.0081),
    "m3.large":     (2,     0.133,      0.0173),
    "m3.xlarge":    (4,     0.266,      0.0333),
    "m3.2xlarge":   (8,     0.532,      0.0660),
    "m4.4xlarge":   (16,    1.008,      0.1097),
    "m4.10xlarge":  (40,    2.520,      0.5655),
}


def spot_trace(instance: str, hours: int, seed: int = 0) -> np.ndarray:
    """Hourly spot-price trace with CU-proportional volatility (Fig. 6)."""
    cores, _, base = INSTANCE_TYPES[instance]
    rng = np.random.default_rng(seed + cores)
    # Log-AR(1) around the base price; volatility grows with core count.
    vol = 0.01 + 0.035 * np.log2(max(cores, 1) + 1)
    x = np.zeros(hours)
    for t in range(1, hours):
        x[t] = 0.97 * x[t - 1] + vol * rng.standard_normal()
    # Sparse demand spikes, increasingly frequent for big instances.
    p_spike = 0.002 * cores
    spikes = rng.random(hours) < p_spike
    mult = np.where(spikes, rng.uniform(2.0, 8.0, hours), 1.0)
    return base * np.exp(x) * mult


def preemptions(trace: np.ndarray, bid: float) -> np.ndarray:
    """Boolean mask of hours in which a bid at ``bid`` would be reclaimed."""
    return trace > bid
