"""Numpy compatibility facade over the JAX spot market (``sim.spot``).

The hourly Appendix-A trace generator used by ``ft.failures`` lives on,
but the Python AR(1) loop is gone: traces are produced by the jitted
``lax.scan`` process in :mod:`repro.sim.spot` and materialised to numpy
here.  Anything new should use ``sim.spot`` directly — this module exists
so host-side consumers (the failure injector, notebooks) keep a plain
numpy API and so the historical ``INSTANCE_TYPES`` import path survives.
"""

from __future__ import annotations

import jax
import numpy as np

from . import spot

# Re-exported: Appendix A, Table V (North Virginia, 2015-07-10).
INSTANCE_TYPES = spot.INSTANCE_TYPES


def spot_trace(instance: str, hours: int, seed: int = 0) -> np.ndarray:
    """Hourly spot-price trace with CU-proportional volatility (Fig. 6)."""
    cores, _, _ = INSTANCE_TYPES[instance]
    rt = spot.make_runtime(spot.SpotConfig(instance=instance))
    # Fold the core count into the key (rather than the legacy seed+cores
    # offset, where (seed=1, 1-core) and (seed=0, 2-core) collided) so every
    # (seed, instance type) pair gets an independent noise stream.
    key = jax.random.fold_in(jax.random.PRNGKey(seed), cores)
    return np.asarray(spot.price_trace(rt, hours, key))


def preemptions(trace: np.ndarray, bid: float) -> np.ndarray:
    """Boolean mask of hours in which a bid at ``bid`` would be reclaimed."""
    return np.asarray(trace) > bid
