"""Architecture configuration schema for the model zoo.

One frozen dataclass describes every assigned architecture; family-specific
fields are zero/None when unused.  ``reduced()`` derives the CPU smoke-test
variant of the same family (small widths, few layers/experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | hybrid | audio | vlm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None        # default d_model // n_heads
    qkv_bias: bool = False                # Qwen1.5-style QKV bias
    tie_embeddings: bool = False
    mlp: str = "swiglu"                   # swiglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False           # Llama-4 shared expert path
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256                  # SSD chunk length

    # --- hybrid (Zamba-2): shared attention block every k SSM layers --------
    attn_every: int = 0

    # --- attention locality --------------------------------------------------
    sliding_window: Optional[int] = None  # Mixtral SWA
    attn_chunk: Optional[int] = None      # Llama-4 chunked-local attention
    global_every: int = 0                 # Llama-4: every Nth layer is global

    # --- encoder-decoder (Whisper) -------------------------------------------
    enc_layers: int = 0
    enc_len: int = 1500                   # fixed audio frame count (stub)

    # --- VLM (LLaVA): stub patch-embedding frontend --------------------------
    n_patches: int = 0                    # patches prepended to the text seq

    # --- §Perf variants (hillclimb switches; defaults = paper-faithful) ------
    parallel_block: bool = False          # PaLM-style fused attn+MLP residual
    kv_dtype: str = "bf16"                # "int8": quantized KV cache

    # -------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §Arch-applicability)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None
                or self.attn_chunk is not None)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * d
        per_mlp = (3 if self.mlp == "swiglu" else 2) * d * f
        if self.family == "ssm":
            per_block = self._ssm_block_params()
            return emb + self.n_layers * per_block
        if self.family == "hybrid":
            n_attn = self.n_layers // max(self.attn_every, 1)
            return (emb + self.n_layers * (self._ssm_block_params())
                    + (per_attn + per_mlp))  # one *shared* attention block
        if self.family == "moe":
            experts = self.n_experts * per_mlp
            shared = per_mlp if self.shared_expert else 0
            router = d * self.n_experts
            return emb + self.n_layers * (per_attn + experts + shared + router)
        if self.family == "audio":
            cross = per_attn
            return emb + self.enc_layers * (per_attn + per_mlp) \
                + self.n_layers * (per_attn + per_mlp + cross)
        return emb + self.n_layers * (per_attn + per_mlp)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts + shared)."""
        if self.family != "moe":
            return self.n_params()
        d, f = self.d_model, self.d_ff
        per_attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * d
        per_mlp = 3 * d * f
        act = self.top_k * per_mlp + (per_mlp if self.shared_expert else 0)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + self.n_layers * (per_attn + act + d * self.n_experts)

    def _ssm_block_params(self) -> int:
        # Mamba-2 block, ngroups=1 (B and C shared across heads).
        d, di = self.d_model, self.d_inner
        in_proj = d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
        conv = self.ssm_conv * (di + 2 * self.ssm_state)
        return in_proj + conv + self.ssm_heads * 2 + di * d

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if self.attn_every == 0
                         else self.attn_every + 1),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            d_ff=256,
            vocab=256,
            head_dim=32 if self.head_dim else None,
            n_experts=min(self.n_experts, 4),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=16,
            enc_layers=min(self.enc_layers, 2),
            enc_len=24,
            n_patches=min(self.n_patches, 8),
            sliding_window=64 if self.sliding_window else None,
            attn_chunk=32 if self.attn_chunk else None,
        )


# Input-shape cells assigned to every LM-family architecture.
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}
