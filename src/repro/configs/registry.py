"""The 10 assigned architectures (exact public configs) + the paper's own
CaaS control-plane config.  Select with ``--arch <id>``.

Sources per the assignment sheet; ``head_dim = d_model // n_heads`` unless
the source specifies otherwise.
"""

from __future__ import annotations

from .base import ArchConfig

# [arXiv:2403.17297; hf] — dense GQA
INTERNLM2_20B = ArchConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92544, rope_theta=1e6)

# [hf:ibm-granite/granite-3.0-2b-base; hf] — dense GQA
GRANITE_3_2B = ArchConfig(
    name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, tie_embeddings=True)

# [hf:stabilityai/stablelm-2-1_6b; unverified] — dense, MHA (kv == heads)
STABLELM_3B = ArchConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304)

# [hf:Qwen/Qwen1.5-0.5B; hf] — dense, QKV bias, tied embeddings
QWEN15_05B = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936, qkv_bias=True,
    tie_embeddings=True)

# [arXiv:2405.21060; unverified] — Mamba-2, SSD (state-space duality)
MAMBA2_780M = ArchConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280, ssm_state=128)

# [arXiv:2411.15242; hf] — Mamba-2 backbone + shared attention block
ZAMBA2_1_2B = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, ssm_state=64,
    attn_every=6, sliding_window=4096)

# [arXiv:2212.04356; unverified] — enc-dec; conv frontend is a STUB
WHISPER_BASE = ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865, mlp="gelu",
    enc_layers=6, enc_len=1500)

# [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — anyres tiling STUB
LLAVA_NEXT_34B = ArchConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, n_patches=2880)

# [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attention
MIXTRAL_8X7B = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    n_experts=8, top_k=2, sliding_window=4096)

# [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — 16e top-1, chunked attn
LLAMA4_SCOUT = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, shared_expert=True,
    attn_chunk=8192, global_every=4, rope_theta=5e5)

ARCHS = {
    a.name: a for a in [
        INTERNLM2_20B, GRANITE_3_2B, STABLELM_3B, QWEN15_05B, MAMBA2_780M,
        ZAMBA2_1_2B, WHISPER_BASE, LLAVA_NEXT_34B, MIXTRAL_8X7B, LLAMA4_SCOUT,
    ]
}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]
