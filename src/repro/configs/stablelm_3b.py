"""`--arch` config module (see registry.py for the source).

Exact architecture hyper-parameters plus the reduced smoke variant.
"""

from .registry import STABLELM_3B as CONFIG

SMOKE = CONFIG.reduced()
