# One module per assigned architecture (+ the paper's own control-plane
# defaults); ``registry.ARCHS`` maps --arch ids to ArchConfig.
from .base import ArchConfig, ShapeConfig, SHAPES
from .registry import ARCHS, get

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "get"]
