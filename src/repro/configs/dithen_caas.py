"""The paper's own platform configuration (§V defaults)."""

from ..core.controller import ControllerConfig
from ..core.types import BillingParams, ControlParams

CONTROL = ControlParams(alpha=5.0, beta=0.9, n_min=10.0, n_max=100.0,
                        n_w_max=10.0, sigma_z2=0.5, sigma_v2=0.5,
                        monitor_dt=60.0)
BILLING = BillingParams(price_per_quantum=0.0081, quantum=3600.0,
                        boot_delay=300.0, terminate="boundary")
CONTROLLER = ControllerConfig(predictor="kalman", policy="aimd",
                              params=CONTROL, billing=BILLING)
