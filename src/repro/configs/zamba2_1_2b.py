"""`--arch` config module (see registry.py for the source).

Exact architecture hyper-parameters plus the reduced smoke variant.
"""

from .registry import ZAMBA2_1_2B as CONFIG

SMOKE = CONFIG.reduced()
