"""`--arch` config module (see registry.py for the source).

Exact architecture hyper-parameters plus the reduced smoke variant.
"""

from .registry import QWEN15_05B as CONFIG

SMOKE = CONFIG.reduced()
