"""`--arch` config module (see registry.py for the source).

Exact architecture hyper-parameters plus the reduced smoke variant.
"""

from .registry import WHISPER_BASE as CONFIG

SMOKE = CONFIG.reduced()
