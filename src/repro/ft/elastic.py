"""Elastic training runtime: the paper's control plane driving data-parallel
replica count, with checkpoint/restart fault tolerance and straggler
mitigation.

Mapping (DESIGN.md §2): a training job is a CaaS *workload* whose items are
steps; the Kalman filter (§II.A) predicts chip-seconds per step from noisy
measurements; proportional fairness (§III) turns the job's TTC (deadline
for the remaining steps) into a replica demand; AIMD (§IV) grows the fleet
additively and sheds it multiplicatively.  Replica granules are whole DP
slices (Appendix A's many-small-granules argument), so a scale event is:
checkpoint → re-form mesh with R' replicas → restore (topology-agnostic) →
continue.  Preempted/failed replicas shrink R the same way; stragglers are
detected by per-replica step-time ratios and replaced rather than waited on.
Failure, preemption and straggler events come from ``ft.failures`` — since
PR 8 a shim over the simulator's chaos engine (``sim.faults``), so the
trainer rehearses against the *same* fault processes the cost simulator
injects and the adversarial search attacks.

In this container replicas are logical (single CPU device); on a pod the
same class drives ``jax.distributed`` re-initialization.  Everything
observable (step times, events, scale decisions) is recorded for the
benchmarks and the example driver.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import checkpointer
from ..core import aimd as aimd_lib
from ..core import kalman
from ..core.types import ControlParams
from .failures import FailureConfig, FailureInjector


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    total_steps: int = 200
    ttc_seconds: float = 3600.0      # deadline for the whole job
    min_replicas: int = 1
    max_replicas: int = 64
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_elastic_ckpt"
    straggle_ratio: float = 2.0      # replace replicas slower than 2x median
    control: ControlParams = ControlParams(alpha=2.0, beta=0.9, n_min=1.0,
                                           n_max=64.0)
    # Simulated per-replica step time model (CPU container): base seconds
    # for R=1; an R-replica fleet runs a step in base/R + comm overhead.
    sim_base_step: float = 1.0
    sim_comm_overhead: float = 0.01  # per-step, grows log2(R)


@dataclasses.dataclass
class ElasticRecord:
    step: int
    replicas: int
    step_time: float
    n_star: float
    b_hat: float
    event: str = ""


class ElasticTrainer:
    """Drives (train_step, state) under the paper's controller."""

    def __init__(self, cfg: ElasticConfig, train_step: Callable,
                 state, batch_fn: Callable[[int], dict],
                 failures: Optional[FailureInjector] = None,
                 wall_clock: bool = False):
        self.cfg = cfg
        self.train_step = train_step
        self.state = state
        self.batch_fn = batch_fn
        self.failures = failures or FailureInjector(FailureConfig())
        self.wall_clock = wall_clock

        self.kf = kalman.init(1, 1)
        self.aimd = aimd_lib.aimd_init(cfg.min_replicas)
        self.replicas = list(range(cfg.min_replicas))
        self._next_id = cfg.min_replicas
        self.records: list[ElasticRecord] = []
        self.sim_time = 0.0
        self.restarts = 0

    # ---- step-time model -----------------------------------------------------
    def _measure_step(self, step: int) -> float:
        r = len(self.replicas)
        if self.wall_clock:
            t0 = time.perf_counter()
            self.state, _ = self.train_step(self.state,
                                            self.batch_fn(step))
            jax.block_until_ready(jax.tree.leaves(self.state.params)[0])
            return time.perf_counter() - t0
        # Simulated fleet: slowest replica paces the step (synchronous DP).
        self.state, _ = self.train_step(self.state, self.batch_fn(step))
        slow = max(self.failures.slowdown(rep, step)
                   for rep in self.replicas)
        comm = self.cfg.sim_comm_overhead * max(np.log2(max(r, 2)), 1.0)
        noise = float(np.random.default_rng(step).lognormal(0.0, 0.08))
        return (self.cfg.sim_base_step / r) * slow * noise + comm

    # ---- control -------------------------------------------------------------
    def _control(self, step: int, step_time: float) -> tuple[float, float]:
        r = len(self.replicas)
        # Measurement: chip-seconds per step (the job's CUS per item).
        b_meas = jnp.asarray([[step_time * r]], jnp.float32)
        self.kf = kalman.step(self.kf, b_meas,
                              jnp.asarray([[True]]), self.cfg.control)
        b_hat = float(self.kf.b_hat[0, 0])

        remaining = self.cfg.total_steps - (step + 1)
        deadline_left = max(self.cfg.ttc_seconds - self.sim_time, 1.0)
        r_cus = remaining * b_hat                      # eq. 1
        n_star = r_cus / deadline_left                 # eq. 11: s* = r/d
        self.aimd = aimd_lib.aimd_step(
            self.aimd, jnp.asarray(float(r)), jnp.asarray(n_star),
            self.cfg.control)
        return n_star, b_hat

    def _resize(self, target: int, reason: str) -> None:
        target = int(np.clip(target, self.cfg.min_replicas,
                             self.cfg.max_replicas))
        r = len(self.replicas)
        if target == r:
            return
        # Topology change: checkpoint → re-form → restore.
        step = int(self.state.opt.step)
        checkpointer.save(self.cfg.checkpoint_dir, step, self.state._asdict())
        if target > r:
            self.replicas += [self._next_id + i for i in range(target - r)]
            self._next_id += target - r
        else:
            self.replicas = self.replicas[:target]
        restored = checkpointer.restore(self.cfg.checkpoint_dir, step,
                                        self.state._asdict())
        self.state = type(self.state)(**restored)
        self.restarts += 1
        if self.records:
            self.records[-1].event += f" resize:{r}->{target}({reason})"

    # ---- main loop -----------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> list[ElasticRecord]:
        steps = steps or self.cfg.total_steps
        for step in range(steps):
            event = ""
            failed, stragglers, reclaimed = self.failures.step_events(
                step, self.sim_time / 3600.0, self.replicas)
            if reclaimed and len(self.replicas) > self.cfg.min_replicas:
                event += " spot-reclaim"
                self._resize(max(self.cfg.min_replicas,
                                 len(self.replicas) // 2), "reclaim")
            if failed:
                event += f" fail:{len(failed)}"
                keep = [r for r in self.replicas if r not in failed]
                self.replicas = keep or self.replicas[:1]
                self._resize(len(self.replicas), "failure")

            step_time = self._measure_step(step)
            self.sim_time += step_time

            # Straggler mitigation: replace, don't wait.
            slow = [r for r in self.replicas
                    if self.failures.slowdown(r, step)
                    >= self.cfg.straggle_ratio]
            if slow:
                event += f" straggle:{len(slow)}"
                for r in slow:
                    self.replicas.remove(r)
                    self.replicas.append(self._next_id)
                    self._next_id += 1

            n_star, b_hat = self._control(step, step_time)
            target = int(round(float(self.aimd.n_target)))
            self.records.append(ElasticRecord(
                step=step, replicas=len(self.replicas),
                step_time=step_time, n_star=n_star, b_hat=b_hat,
                event=event.strip()))
            if target != len(self.replicas):
                self._resize(target, "aimd")

            if (step + 1) % self.cfg.checkpoint_every == 0:
                checkpointer.save(self.cfg.checkpoint_dir,
                                  int(self.state.opt.step),
                                  self.state._asdict())
                checkpointer.prune(self.cfg.checkpoint_dir)
        return self.records
