"""Failure / preemption / straggler injection for the elastic runtime.

Spot reclamations are drawn from the Appendix-A market model (bid vs. price
trace); stragglers and hard failures are Poisson events.  At 1000+ nodes the
per-step event probabilities here are the design point: with p_fail ≈ 1e-4
per node-step, a 4096-chip job sees an event every ~2.4 steps — which is why
the runtime treats topology change as the *common case*.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..sim import market


@dataclasses.dataclass(frozen=True)
class FailureConfig:
    p_fail: float = 5e-4          # hard failure per replica-step
    p_straggle: float = 2e-3      # transient slowdown per replica-step
    straggle_factor: float = 3.0  # slowdown multiple while straggling
    straggle_steps: int = 5
    spot_instance: str = "m3.medium"
    spot_bid: float = 0.0095
    seed: int = 0


class FailureInjector:
    def __init__(self, cfg: FailureConfig, horizon_hours: int = 48):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        trace = market.spot_trace(cfg.spot_instance, horizon_hours,
                                  seed=cfg.seed)
        self.reclaim_hours = set(
            np.nonzero(market.preemptions(trace, cfg.spot_bid))[0].tolist())
        self._straggle_until: dict[int, int] = {}

    def step_events(self, step: int, hour: float, replicas: list[int]):
        """Returns (failed_ids, straggler_ids, reclaimed_all: bool)."""
        reclaimed = int(hour) in self.reclaim_hours
        failed = [r for r in replicas
                  if self.rng.random() < self.cfg.p_fail]
        for r in replicas:
            if self.rng.random() < self.cfg.p_straggle:
                self._straggle_until[r] = step + self.cfg.straggle_steps
        stragglers = [r for r in replicas
                      if self._straggle_until.get(r, -1) >= step]
        return failed, stragglers, reclaimed

    def slowdown(self, replica: int, step: int) -> float:
        if self._straggle_until.get(replica, -1) >= step:
            return self.cfg.straggle_factor
        return 1.0
