"""Failure / preemption / straggler injection for the elastic runtime.

A thin host-side shim over the shared chaos engine (``sim.faults``): the
same jitted ``tick`` kernel the simulator advances inside its scan
precomputes this injector's per-step kill and straggle masks
(``faults.fault_timeline``), so the elastic trainer and the simulator
draw faults from one PRNG discipline and one episode model.  Spot
reclamations come straight from the Appendix-A market process
(``sim.spot.price_trace``): an hour whose price exceeds the bid reclaims
the fleet, the same predicate the simulator's ``billing.preempt``
applies per quantum.

At 1000+ nodes the per-step event probabilities here are the design
point: with p_fail ≈ 1e-4 per node-step, a 4096-chip job sees an event
every ~2.4 steps — which is why the runtime treats topology change as
the *common case*.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from ..sim import faults, spot


@dataclasses.dataclass(frozen=True)
class FailureConfig:
    p_fail: float = 5e-4          # hard failure per replica-step
    p_straggle: float = 2e-3      # transient slowdown per replica-step
    straggle_factor: float = 3.0  # slowdown multiple while straggling
    straggle_steps: int = 5
    spot_instance: str = "m3.medium"
    spot_bid: float = 0.0095
    seed: int = 0


# Replica ids map onto the precomputed timeline by modulo: large enough
# that distinct live replicas virtually never alias, small enough that
# the host-side precompute stays trivial.
_POOL = 256


class FailureInjector:
    """Precomputed fault timeline for one elastic run.

    Keeps the original interface — ``step_events(step, hour, replicas)``
    returning ``(failed_ids, straggler_ids, reclaimed: bool)`` and
    ``slowdown(replica, step)`` — but the events behind it come from the
    chaos engine: a neutral-outage ``FaultSpec`` whose per-hour rates are
    scanned at ``dt=3600`` (one tick per step), so ``p_fail`` /
    ``p_straggle`` stay per-replica-step probabilities exactly as before.
    """

    def __init__(self, cfg: FailureConfig, horizon_hours: int = 48,
                 horizon_steps: int = 4096):
        self.cfg = cfg
        # Market reclaims: hour h reclaims iff its spot price exceeds the
        # bid.  The trace key folds in the instance's core count so every
        # (seed, type) pair gets an independent noise stream.
        cores, _, _ = spot.INSTANCE_TYPES[cfg.spot_instance]
        rt = spot.make_runtime(spot.SpotConfig(instance=cfg.spot_instance))
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), cores)
        trace = np.asarray(spot.price_trace(rt, horizon_hours, key))
        self.reclaim_hours = set(
            np.nonzero(trace > cfg.spot_bid)[0].tolist())
        spec = faults.make_fault_spec(
            p_slot_fail=cfg.p_fail,
            p_straggle=cfg.p_straggle,
            straggle_ticks=float(cfg.straggle_steps),
            straggle_factor=float(cfg.straggle_factor))
        kill, straggling = faults.fault_timeline(cfg.seed, spec,
                                                 horizon_steps, _POOL)
        self._kill = np.asarray(kill)
        self._straggling = np.asarray(straggling)
        self._steps = int(horizon_steps)

    def step_events(self, step: int, hour: float, replicas: list[int]):
        """Returns (failed_ids, straggler_ids, reclaimed_all: bool)."""
        reclaimed = int(hour) in self.reclaim_hours
        row = self._kill[step % self._steps]
        failed = [r for r in replicas if row[r % _POOL]]
        stragglers = [r for r in replicas if self.slowdown(r, step) > 1.0]
        return failed, stragglers, reclaimed

    def slowdown(self, replica: int, step: int) -> float:
        if self._straggling[step % self._steps, replica % _POOL]:
            return self.cfg.straggle_factor
        return 1.0
