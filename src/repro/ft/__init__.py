from .elastic import ElasticConfig, ElasticTrainer
from .failures import FailureConfig, FailureInjector

__all__ = ["ElasticConfig", "ElasticTrainer", "FailureConfig",
           "FailureInjector"]
