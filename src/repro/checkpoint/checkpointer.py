"""Fault-tolerant checkpointing: atomic, topology-agnostic, restart-safe.

Layout: <dir>/step_<N>/   (one .npy per flattened pytree leaf + manifest)
        <dir>/step_<N>.done  (commit marker — a crash mid-write leaves no
                              marker, so restore never sees a torn state)

Leaves are saved by *path* (e.g. "params/blocks/attn/wq"), so a checkpoint
written on one mesh restores onto any other topology — the elastic runtime
re-sharding after an AIMD scale event is just restore-with-new-shardings.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _path_part(p) -> str:
    # DictKey carries .key, SequenceKey .idx, GetAttrKey (NamedTuples,
    # dataclass pytrees) .name; anything else falls back to its repr.
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_part(p) for p in path)
        out[key] = leaf
    return out, treedef


def save(directory: str, step: int, tree) -> str:
    """Write checkpoint for ``step``; atomic via the .done marker."""
    d = os.path.join(directory, f"step_{step:08d}")
    tmp = d + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    flat, _ = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype not in ("float32", "float64", "int32", "int64", "uint32",
                         "uint64", "int8", "uint8", "bool", "int16",
                         "uint16", "float16"):
            arr = arr.astype(np.float32)     # bf16 etc.: store widened
        fname = key.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        # Integrity digest of the *file bytes*: verify() recomputes it to
        # catch bit-flips and truncation that the .done marker (which only
        # proves the write completed) cannot.
        manifest[key] = {"file": fname, "shape": list(arr.shape),
                         "dtype": dtype, "sha256": _file_sha256(fpath)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)

    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    open(d + ".done", "w").close()
    return d


def committed_steps(directory: str) -> list[int]:
    """All steps with a commit marker, sorted.  A crash mid-save leaves a
    ``.tmp`` (or renamed-but-unmarked) directory and no ``.done`` file, so
    torn writes never appear here — the resume contract of both the elastic
    trainer and the streaming sweep executor (``sim.sweep``)."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(n[len("step_"):-len(".done")])
                  for n in os.listdir(directory)
                  if n.startswith("step_") and n.endswith(".done"))


def latest_step(directory: str) -> int | None:
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def verify(directory: str, step: int) -> bool:
    """True iff every leaf file of ``step`` matches its manifest sha256.

    The ``.done`` marker proves the write *completed*; this proves the
    bytes on disk are still the bytes that were written — a corrupted,
    truncated or missing leaf file returns False so resume paths
    (``sim.sweep._run_streamed``) silently recompute the chunk instead of
    restoring garbage.  Manifests written before the digest existed carry
    no ``sha256`` entries; those leaves are accepted as-is (nothing to
    check against), so old checkpoints stay restorable.
    """
    d = os.path.join(directory, f"step_{step:08d}")
    mpath = os.path.join(d, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)["leaves"]
    except (OSError, ValueError, KeyError):
        return False
    for key, meta in manifest.items():
        fpath = os.path.join(d, meta["file"])
        if not os.path.isfile(fpath):
            return False
        want = meta.get("sha256")
        if want is not None and _file_sha256(fpath) != want:
            return False
    return True


def restore(directory: str, step: int, like):
    """Restore into the structure (and shardings) of ``like``.

    ``like`` can be a pytree of arrays or ShapeDtypeStructs; device layout
    follows each leaf's sharding when present (topology-agnostic).
    """
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]

    flat_like, treedef = _flatten(like)
    out = {}
    for key, leaf in flat_like.items():
        if key not in manifest:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(d, manifest[key]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} != model {leaf.shape}")
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            out[key] = jax.device_put(arr.astype(leaf.dtype), sharding)
        else:
            out[key] = jax.numpy.asarray(arr, leaf.dtype)
        del arr

    leaves_in_order = [out[k] for k in flat_like.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)


def prune(directory: str, keep: int = 3) -> None:
    """Retain only the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n[len("step_"):-len(".done")]) for n in os.listdir(directory)
        if n.startswith("step_") and n.endswith(".done"))
    for s in steps[:-keep]:
        d = os.path.join(directory, f"step_{s:08d}")
        if os.path.isdir(d):
            shutil.rmtree(d)
        marker = d + ".done"
        if os.path.exists(marker):
            os.remove(marker)
