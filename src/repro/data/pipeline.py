"""Deterministic synthetic token pipeline.

Produces shardable (global_batch, seq) int32 batches with a fixed PRNG
stream per (step, host) — restart-safe (the checkpoint stores the step, the
pipeline regenerates the identical batch) and elastic-safe (batch content
depends only on the global step, not on the number of participating hosts).
A markov-ish structure keeps the loss signal non-trivial for the examples.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at(cfg: DataConfig, step: int) -> dict:
    """The unique batch for ``step`` — identical on every host/restart."""
    rng = np.random.default_rng((cfg.seed << 32) ^ step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    # Order-2 structure: token ~ f(prev) with noise, so models can learn.
    base = rng.integers(0, v, size=(b, 1), dtype=np.int64)
    steps = rng.integers(1, 17, size=(b, s), dtype=np.int64)
    noise = rng.integers(0, 3, size=(b, s), dtype=np.int64)
    toks = (base + np.cumsum(steps, axis=1) * 31 + noise) % v
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def host_shard(batch: dict, host_index: int, n_hosts: int) -> dict:
    """Slice the per-host rows of a global batch (data-parallel input)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per:(host_index + 1) * per]
    return jax.tree.map(slc, batch)
