import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell this produces:
  * the FULL-DEPTH compile (scan-over-layers): proves the sharding config
    is coherent at 256/512 devices, and yields memory_analysis() (fits?)
    plus the compiled collective schedule;
  * two DEPTH-PROBE compiles (scan unrolled at depths L1 < L2): XLA's
    cost_analysis does NOT scale while-loop bodies by trip count (verified
    empirically — see DESIGN.md §5), so per-layer FLOPs/bytes/collectives
    come from the probes and extrapolate linearly:
        total(L) = probe(L1) + (L - L1)/(L2 - L1) · (probe(L2) - probe(L1)).

Each invocation handles one cell (clean device state per process); the
sweep driver fans processes out.  Results land in JSON for §Roofline.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES
from ..configs.base import ShapeConfig
from ..models import sharding as sh_cfg
from ..models.model import Model
from ..training import optimizer
from ..training.train_loop import TrainState
from . import shardings as shr
from .mesh import make_production_mesh

# Microbatching for activation memory.  llama4-scout needs 16 (its (E,C,D)
# MoE dispatch buffers dominate temp memory — §Perf iteration 5).
GRAD_ACCUM = {"train_4k": 8}
GRAD_ACCUM_ARCH = {("llama4-scout-17b-a16e", "train_4k"): 16}

_COLL_RE = re.compile(
    r"(\w+)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*?replica_groups=(\{[^}]*\}|\[[^\]]*\])", re.S)

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def parse_collectives(hlo: str) -> list[dict]:
    """Extract collective ops: kind, result bytes, group size.

    Handles tuple-result collectives (XLA fuses co-located reductions into
    one op over several tensors) and skips the -done halves of async pairs.
    """
    out = []
    for line in hlo.splitlines():
        m = re.search(
            r"= (\(?[a-z0-9]+\[[^=]*?)\s*"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all"
            r"|collective-permute)(-start)?\(", line)
        if not m or "-done" in line:
            continue
        result_seg, kind = m.group(1), m.group(2)
        nbytes = 0
        for dtype, shape_s in re.findall(r"([a-z0-9]+)\[([\d,]*)\]",
                                         result_seg):
            size = 1
            for d in [int(x) for x in shape_s.split(",") if x] or [1]:
                size *= d
            nbytes += size * _DTYPE_BYTES.get(dtype, 4)
        gm = re.search(r"replica_groups=\{\{([^}]*)\}", line)
        gsize = None
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if gm2:
                gsize = int(gm2.group(2))
        out.append({"kind": kind, "bytes": nbytes, "group": gsize or 1})
    return out


def collective_link_seconds(colls: list[dict], link_bw: float = 50e9) -> float:
    """Per-chip link-time estimate under ring algorithms.

    Factors applied to the op's RESULT bytes (what the HLO shape reports):
      all-reduce        2(g-1)/g   (reduce-scatter + all-gather rings)
      all-gather        (g-1)/g    (result is the gathered tensor)
      reduce-scatter    (g-1)      (result is 1/g of the logical tensor)
      all-to-all        (g-1)/g
      collective-permute 1
    """
    t = 0.0
    for c in colls:
        g = max(c["group"], 1)
        if g == 1:
            continue
        frac = (g - 1) / g
        factor = {"all-reduce": 2.0 * frac,
                  "all-gather": frac,
                  "reduce-scatter": float(g - 1),
                  "all-to-all": frac,
                  "collective-permute": 1.0}[c["kind"]]
        t += factor * c["bytes"] / link_bw
    return t


def _shape_cfg(name: str) -> ShapeConfig:
    return SHAPES[name]


VARIANTS = ("parallel_block", "kv_int8", "accum2", "accum16", "remat_dots")


def build_step(arch: str, shape_name: str, mesh, depth: int | None = None,
               unroll: bool = False, variant: str | None = None):
    """Build (fn, args_specs, in_shardings, out_shardings, donate) for a cell."""
    cfg = ARCHS[arch]
    if variant == "parallel_block":
        cfg = dataclasses.replace(cfg, parallel_block=True)
    elif variant == "kv_int8":
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    if depth is not None:
        kw = {"n_layers": depth}
        if cfg.family == "audio":
            kw["enc_layers"] = min(cfg.enc_layers, depth)
        cfg = dataclasses.replace(cfg, **kw)
    shape = _shape_cfg(shape_name)
    model_size = dict(mesh.shape)["model"]
    model = Model(cfg, model_size=model_size)

    seq_shard = (shape.kind == "decode"
                 and shape.global_batch < dict(mesh.shape)["data"])
    sh_cfg.configure(enabled=True, seq_sharded=seq_shard,
                     scan_unroll=True if unroll else False,
                     remat="dots" if variant == "remat_dots" else "nothing")

    batch_specs = model.input_specs(shape)
    params_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    param_sh = shr.param_shardings(params_shapes, mesh)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        opt_sh = shr.tree_shardings(opt_shapes, mesh, shr.opt_spec)
        state_specs = TrainState(params=params_shapes, opt=opt_shapes)
        state_sh = TrainState(params=param_sh, opt=opt_sh)
        opt_cfg = optimizer.OptConfig()
        accum = GRAD_ACCUM_ARCH.get((arch, shape_name),
                                    GRAD_ACCUM.get(shape_name, 1))
        if variant == "accum2":
            accum = 2
        elif variant == "accum16":
            accum = 16

        from ..training.train_loop import make_train_step
        step_fn = make_train_step(model, opt_cfg, grad_accum=accum)
        in_sh = (state_sh, shr.batch_shardings(batch_specs, mesh))
        metrics_specs = {"grad_norm": jax.ShapeDtypeStruct((), jnp.float32),
                         "lr": jax.ShapeDtypeStruct((), jnp.float32),
                         "loss": jax.ShapeDtypeStruct((), jnp.float32)}
        out_sh = (state_sh, shr.replicated(metrics_specs, mesh))
        return (step_fn, (state_specs, batch_specs), in_sh, out_sh, (0,))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.forward(params, batch, remat=False)
        in_sh = (param_sh, shr.batch_shardings(batch_specs, mesh))
        return (prefill_step, (params_shapes, batch_specs), in_sh, None, ())

    # decode
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    dummy_batch = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "audio":
        dummy_batch["frames"] = batch_specs["frames"]
    cache_shapes = jax.eval_shape(
        lambda p, bt: model.init_decode_state(p, bt, shape.seq_len),
        params_shapes, dummy_batch)
    cache_sh = shr.cache_shardings(cache_shapes, mesh, seq_shard=seq_shard)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)

    tok_sh = shr.tree_shardings({"token": tok}, mesh, shr.batch_spec)["token"]
    in_sh = (param_sh, tok_sh, cache_sh,
             jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    return (serve_step, (params_shapes, tok, cache_shapes, pos), in_sh,
            None, (2,))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True, variant: str | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = ARCHS[arch]
    result = {"arch": arch, "shape": shape_name, "variant": variant,
              "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        # ---- full-depth compile: proof + memory + schedule ------------------
        fn, args, in_sh, out_sh, donate = build_step(arch, shape_name, mesh,
                                                     variant=variant)
        jit_kw = dict(in_shardings=in_sh)
        if out_sh is not None:
            jit_kw["out_shardings"] = out_sh
        if donate:
            jit_kw["donate_argnums"] = donate
        lowered = jax.jit(fn, **jit_kw).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        if mem is not None:
            result["memory"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        ca = compiled.cost_analysis() or {}
        result["full_cost"] = {k: float(ca[k]) for k in
                               ("flops", "bytes accessed") if k in ca}
        colls = parse_collectives(compiled.as_text())
        result["full_collectives"] = {
            "count": len(colls),
            "bytes": float(sum(c["bytes"] for c in colls)),
            "by_kind": _by_kind(colls),
        }
        result["compile_s"] = round(time.time() - t0, 1)

        # ---- depth probes (single-pod roofline only) -------------------------
        if probes:
            l1, l2 = _probe_depths(cfg)
            probe = {}
            for tag, depth in (("l1", l1), ("l2", l2)):
                fn, args, in_sh, out_sh, _ = build_step(
                    arch, shape_name, mesh, depth=depth, unroll=True,
                    variant=variant)
                jit_kw = dict(in_shardings=in_sh)
                if out_sh is not None:
                    jit_kw["out_shardings"] = out_sh
                plow = jax.jit(fn, **jit_kw).lower(*args)
                pcomp = plow.compile()
                pca = pcomp.cost_analysis() or {}
                pcolls = parse_collectives(pcomp.as_text())
                probe[tag] = {
                    "depth": depth,
                    "flops": float(pca.get("flops", 0.0)),
                    "bytes": float(pca.get("bytes accessed", 0.0)),
                    "coll_bytes": float(sum(c["bytes"] for c in pcolls)),
                    "coll_link_s": collective_link_seconds(pcolls),
                    "colls": _by_kind(pcolls),
                }
            result["probe"] = probe
            result["probe_depths"] = [l1, l2]

    result["ok"] = True
    result["total_s"] = round(time.time() - t0, 1)
    return result


def _by_kind(colls: list[dict]) -> dict:
    agg: dict = {}
    for c in colls:
        k = c["kind"]
        a = agg.setdefault(k, {"count": 0, "bytes": 0.0})
        a["count"] += 1
        a["bytes"] += c["bytes"]
    return agg


def _probe_depths(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    if cfg.family == "moe" and cfg.global_every:
        return cfg.global_every, 2 * cfg.global_every
    return 1, 2


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--variant", default=None, choices=VARIANTS,
                    help="§Perf hillclimb variant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    model = Model(cfg, model_size=16)
    if not model.supports(shape):
        res = {"arch": args.arch, "shape": args.shape, "ok": True,
               "skipped": "quadratic attention at 500k (DESIGN.md)",
               "mesh": "2x16x16" if args.multipod else "16x16"}
    else:
        try:
            res = run_cell(args.arch, args.shape, args.multipod,
                           probes=not args.no_probes, variant=args.variant)
        except Exception as e:  # noqa: BLE001 — report, don't crash sweep
            res = {"arch": args.arch, "shape": args.shape, "ok": False,
                   "mesh": "2x16x16" if args.multipod else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}

    js = json.dumps(res, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    print(js if len(js) < 4000 else js[:4000])
    if res.get("memory"):
        print("memory_analysis:", res["memory"], file=sys.stderr)
    sys.exit(0 if res.get("ok") else 1)


if __name__ == "__main__":
    main()
