"""Sharding rules: parameter, optimizer, batch and cache PartitionSpecs.

One table maps leaf *names* to the tensor axis that shards over 'model';
everything else is replicated across 'model'.  Parameters are replicated
across 'data'/'pod' in the baseline (pure DP+TP); ZeRO-1 optimizer-state
sharding is a §Perf variant.  Every spec is divisibility-guarded: an axis
that does not divide the mesh factor falls back to replication rather than
failing to lower.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# leaf name -> axis index (negative, from the right) sharded over 'model'
_MODEL_AXIS = {
    "embedding": -2,
    "wq": -2, "wk": -2, "wv": -2, "bq": -2, "bk": -2, "bv": -2,
    "wo": -3,
    "w_gate": -1, "w_up": -1, "b_up": -1,
    "w_down": -2,
    "z_proj": -1, "x_proj": -1, "dt_proj": -1,
    "conv_x": -1, "conv_bias_x": -1,
    "a_log": -1, "dt_bias": -1, "d_skip": -1,
    "out_proj": -2,
}
# parents whose "scale" leaf shards on 'model' (inner-dim norms)
_SHARDED_NORM_PARENTS = {"gnorm"}


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _guard(spec_axes, shape, mesh) -> P:
    """Drop mesh axes that do not divide the tensor axis."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        factor = int(np.prod([sizes[n] for n in names]))
        out.append(ax if dim % factor == 0 and dim > 0 else None)
    return P(*out)


def param_spec(path, leaf, mesh) -> P:
    names = _path_names(path)
    leaf_name = names[-1]
    axes = [None] * leaf.ndim
    if leaf_name in _MODEL_AXIS:
        axes[_MODEL_AXIS[leaf_name]] = "model"
    elif leaf_name == "scale" and len(names) >= 2 \
            and names[-2] in _SHARDED_NORM_PARENTS:
        axes[-1] = "model"
    # MoE expert weights additionally FSDP-shard over 'data' (tens of
    # billions of expert params cannot be replicated across the data axis).
    # Prefer the expert axis; fall back to d_model if E doesn't divide.
    if len(names) >= 2 and names[-2] == "moe" and leaf.ndim >= 3 \
            and leaf_name in ("w_gate", "w_up", "w_down"):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        d = sizes.get("data", 1)
        e_ax = leaf.ndim - 3
        d_ax = leaf.ndim - 2 if leaf_name != "w_down" else leaf.ndim - 1
        if d > 1 and leaf.shape[e_ax] % d == 0:
            axes[e_ax] = "data"
        elif d > 1 and leaf.shape[d_ax] % d == 0:
            axes[d_ax] = "data"
    return _guard(axes, leaf.shape, mesh)


def opt_spec(path, leaf, mesh) -> P:
    """ZeRO-1: optimizer moments additionally shard over 'data' on the
    first free axis that divides it (≥1 MiB leaves only).  At 16×16 this
    cuts per-chip f32 moment storage 16× — required to fit the 20B+ models."""
    base = tuple(param_spec(path, leaf, mesh))
    axes = list(base) + [None] * (leaf.ndim - len(base))
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d = sizes.get("data", 1)
    name = _path_names(path)[-1]
    already_data = any(ax == "data" or (isinstance(ax, tuple)
                                        and "data" in ax) for ax in axes)
    if d > 1 and leaf.size >= (1 << 20) and not already_data \
            and name not in ("step",):
        for i, ax in enumerate(axes):
            if ax is None and leaf.shape[i] % d == 0 and leaf.shape[i] > 0:
                axes[i] = "data"
                break
    return P(*axes)


def tree_shardings(tree, mesh, spec_fn):
    """Map a pytree of arrays/ShapeDtypeStructs to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [NamedSharding(mesh, spec_fn(path, leaf, mesh))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh):
    return tree_shardings(params, mesh, param_spec)


def _batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(path, leaf, mesh, *, seq_shard: bool = False) -> P:
    """Model inputs: batch on ('pod','data'); optionally sequence on 'data'
    when the batch axis cannot shard (long-context decode)."""
    name = _path_names(path)[-1]
    b_ax = _batch_axes(mesh)
    axes: list = [None] * leaf.ndim
    if leaf.ndim >= 1 and name in ("tokens", "labels", "token",
                                   "patch_embeds", "frames"):
        axes[0] = b_ax
        if name in ("tokens", "labels") and seq_shard and leaf.ndim >= 2:
            axes[1] = "data"
    return _guard(axes, leaf.shape, mesh)


def batch_shardings(batch, mesh, seq_shard: bool = False):
    return tree_shardings(
        batch, mesh,
        lambda p, leaf, m: batch_spec(p, leaf, m, seq_shard=seq_shard))


def cache_spec(path, leaf, mesh, *, seq_shard: bool = False) -> P:
    """Decode caches.  Conventions (leading L/group axis unsharded):
      k/v/xk/xv/attn_k/attn_v: (L, B, S, KV, hd) — batch on data, KV on
        model; S on 'data' instead when seq_shard (batch=1 long decode).
      conv_x: (L,B,k,di) di on model;  conv_bc: replicated channels;
      state: (L,B,H,N,P) H on model.
    """
    name = _path_names(path)[-1]
    b_ax = _batch_axes(mesh)
    axes: list = [None] * leaf.ndim
    if name in ("k", "v", "xk", "xv", "attn_k", "attn_v"):
        axes[1] = b_ax
        axes[3] = "model"
        if seq_shard:
            axes[2] = "data"
    elif name == "conv_x":
        axes[1] = b_ax
        axes[-1] = "model"
    elif name == "conv_bc":
        axes[1] = b_ax
    elif name == "state":
        axes[1] = b_ax
        axes[2] = "model"
    return _guard(axes, leaf.shape, mesh)


def cache_shardings(cache, mesh, seq_shard: bool = False):
    return tree_shardings(
        cache, mesh,
        lambda p, leaf, m: cache_spec(p, leaf, m, seq_shard=seq_shard))


def replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
