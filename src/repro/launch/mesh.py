"""Production meshes for the dry-run, launchers and the sweep engine.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests import this
module under a single CPU device without side effects).

``make_sweep_mesh`` is the mesh the sweep executor (``sim.sweep``) shards
grid chunks over: one flat ``"batch"`` axis across the host's local
devices.  On CPU CI, multi-device meshes come from the
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` idiom (set in the
environment *before* the first jax import) — the forced host devices are
real XLA devices, so a ``shard_map`` over them exercises the exact
partitioning a TPU/GPU fleet would see.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def _mk_mesh(shape: tuple, axes: tuple) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where the jax version has
    them; plain device-grid ``Mesh`` otherwise (jax < 0.5 has no
    ``jax.sharding.AxisType``)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16×16 (data, model).  Multi-pod: 2×16×16 (pod, data,
    model) — the 'pod' axis composes with 'data' for gradient reduction and
    carries the lowest-frequency collectives across the DCI/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host offers (CPU smoke / examples): 1×N (data, model)."""
    return _mk_mesh((1, len(jax.devices())), ("data", "model"))


# The axis name every batch-sharded sweep partitions over.
SWEEP_AXIS = "batch"


def make_sweep_mesh(devices: int | None = None) -> Mesh:
    """A 1-D ``("batch",)`` mesh over up to ``devices`` local devices.

    This is the mesh ``sim.sweep`` shard_maps grid chunks over: the B axis
    of a chunk is partitioned across ``batch``, every device vmapping its
    shard of full simulations (embarrassingly parallel — no collectives).
    ``devices=None`` takes every local device.
    """
    avail = jax.devices()
    n = len(avail) if devices is None else int(devices)
    if not 1 <= n <= len(avail):
        raise ValueError(
            f"devices must be in [1, {len(avail)}] (local devices), got "
            f"{devices}")
    return Mesh(np.asarray(avail[:n]), (SWEEP_AXIS,))
