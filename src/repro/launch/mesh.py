"""Production meshes for the dry-run and launchers.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests import this
module under a single CPU device without side effects).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 (data, model).  Multi-pod: 2×16×16 (pod, data,
    model) — the 'pod' axis composes with 'data' for gradient reduction and
    carries the lowest-frequency collectives across the DCI/ICI boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host offers (CPU smoke / examples): 1×N (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
