"""Serving launcher: batched decode with TTC-aware admission.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --requests 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS
from ..models import Model
from ..serving.engine import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, slots=args.slots,
                           max_len=args.max_len, eos_id=-1)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        r = Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4),
                    max_new_tokens=int(rng.integers(8, 32)),
                    ttc=float(rng.uniform(5, 60)))
        reqs.append(r)
        engine.submit(r)

    stats = engine.run_until_drained()
    print(f"served {sum(r.done for r in reqs)}/{len(reqs)} requests "
          f"in {len(stats)} steps; ttc violations: "
          f"{engine.ttc_violations(reqs)}")


if __name__ == "__main__":
    main()
