"""Roofline analysis over dry-run JSON results (§Roofline of EXPERIMENTS.md).

Three terms per (arch × shape) cell on the single-pod 16×16 mesh, all in
seconds-per-step on TPU v5e constants:

  compute    = HLO_FLOPs / (chips · 197e12)        [bf16 MXU peak]
  memory     = HLO_bytes / (chips · 819e9)         [HBM bandwidth]
  collective = Σ ring-model link-seconds / 50e9    [per-link ICI]

HLO_FLOPs/bytes come from the depth-probe extrapolation (dryrun.py §doc);
collective link-seconds likewise.  MODEL_FLOPS = 6·N·D (dense) or
6·N_active·D (MoE) exposes remat/dispatch/padding waste as a ratio.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from ..configs import ARCHS, SHAPES

CHIPS = 256
PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


GRAD_ACCUM = {"train_4k": 8}  # must match dryrun.GRAD_ACCUM


def extrapolate(res: dict, key: str) -> float:
    """total(L) = p1 + (L-L1)/(L2-L1) · (p2-p1), over the probe depths.

    cost_analysis is PER-DEVICE on the SPMD-partitioned module; probes
    unroll both the layer scan and the grad-accum scan, so the value is
    per-device per-step directly.
    """
    p = res["probe"]
    l1, l2 = res["probe_depths"]
    cfg = ARCHS[res["arch"]]
    depth = cfg.n_layers
    v1, v2 = p["l1"][key], p["l2"][key]
    return v1 + (depth - l1) / (l2 - l1) * (v2 - v1)


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D where D = tokens processed by the step."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token per seq


def analyze(res: dict) -> dict | None:
    if not res.get("ok") or "probe" not in res:
        return None
    arch, shape = res["arch"], res["shape"]
    flops = extrapolate(res, "flops")
    bytes_ = extrapolate(res, "bytes")
    coll_s = extrapolate(res, "coll_link_s")

    # Per-device quantities (SPMD module) → per-chip time directly.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll_s                      # already per-chip link seconds
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(arch, shape) / CHIPS     # per-chip share
    return {
        "arch": arch, "shape": shape,
        "t_compute": t_compute, "t_memory": t_memory, "t_collective": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_compute / bound if bound else 0.0,
        "hlo_bytes": bytes_,
        "mem_temp_bytes": res.get("memory", {}).get("temp_size_in_bytes"),
        "mem_arg_bytes": res.get("memory", {}).get("argument_size_in_bytes"),
    }


def load_dir(d: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def table(results: list[dict]) -> str:
    rows = []
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dom':>8s} {'useful':>7s} {'roofl%':>7s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for res in results:
        if res.get("skipped"):
            rows.append(f"{res['arch']:24s} {res['shape']:12s} "
                        f"{'— skipped: ' + res['skipped']}")
            continue
        a = analyze(res)
        if a is None:
            rows.append(f"{res['arch']:24s} {res['shape']:12s} FAILED: "
                        f"{res.get('error', '?')[:60]}")
            continue
        rows.append(
            f"{a['arch']:24s} {a['shape']:12s} "
            f"{a['t_compute']:10.4f} {a['t_memory']:10.4f} "
            f"{a['t_collective']:10.4f} {a['dominant']:>8s} "
            f"{a['useful_ratio']:7.2f} {100 * a['roofline_fraction']:6.1f}%")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    results = [r for r in load_dir(args.dir) if r.get("mesh") == "16x16"]
    print(table(results))
    if args.json_out:
        rows = [analyze(r) for r in results]
        with open(args.json_out, "w") as f:
            json.dump([r for r in rows if r], f, indent=1)


if __name__ == "__main__":
    main()
