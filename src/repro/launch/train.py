"""Training launcher.

On a pod: `python -m repro.launch.train --arch <id> --prod` builds the
16×16 production mesh and the sharded train step exactly as the dry-run
proves out.  On this CPU host: runs a reduced config end-to-end (real
optimizer steps, checkpointing, restart).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --ckpt /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax

from ..checkpoint import checkpointer
from ..configs import ARCHS
from ..data.pipeline import DataConfig, batch_at
from ..models import Model
from ..models import sharding as sh_cfg
from ..training import optimizer
from ..training.train_loop import TrainState, init_state, make_train_step
from .mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--prod", action="store_true",
                    help="full config on the 16x16 production mesh")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.prod:
        mesh = make_production_mesh()
        cfg = ARCHS[args.arch]
        sh_cfg.configure(enabled=True)
    else:
        mesh = make_host_mesh()
        cfg = ARCHS[args.arch].reduced()

    model = Model(cfg, model_size=dict(mesh.shape).get("model", 1))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)
    opt_cfg = optimizer.OptConfig(lr=3e-3, warmup_steps=10,
                                  total_steps=args.steps)

    with jax.sharding.set_mesh(mesh):
        state = init_state(model, jax.random.PRNGKey(0))
        start = 0
        if args.resume:
            latest = checkpointer.latest_step(args.ckpt)
            if latest is not None:
                restored = checkpointer.restore(args.ckpt, latest,
                                                state._asdict())
                state = TrainState(**restored)
                start = latest
                print(f"resumed from step {latest}")

        step_fn = jax.jit(make_train_step(model, opt_cfg),
                          donate_argnums=(0,))
        t0 = time.time()
        for step in range(start, args.steps):
            state, metrics = step_fn(state, batch_at(data, step))
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}")
            if (step + 1) % args.ckpt_every == 0:
                checkpointer.save(args.ckpt, step + 1, state._asdict())
                checkpointer.prune(args.ckpt)
        dt = time.time() - t0
        toks = args.steps * args.batch * args.seq
        print(f"{args.steps} steps in {dt:.1f}s "
              f"({toks / max(dt, 1e-9):,.0f} tok/s on this host)")


if __name__ == "__main__":
    main()
