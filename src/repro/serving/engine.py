"""Batched serving engine with TTC-aware admission (continuous batching).

Requests are CaaS workloads: items = tokens to generate, TTC = the SLA
deadline.  The engine holds a fixed number of decode slots; admission and
slot allocation follow the paper's proportional fairness — each pending
request's service demand is r/d (remaining tokens over remaining deadline),
and slots go to the highest-demand requests first.  The Kalman filter
predicts per-token cost from measured step times, which feeds the AIMD
autoscaler when the engine runs under ``repro.ft.elastic``.
"""

from __future__ import annotations

import dataclasses
import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import kalman
from ..core.types import ControlParams
from ..models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    ttc: float                    # seconds from submission
    submitted: float = 0.0
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


class ServingEngine:
    def __init__(self, model: Model, params, slots: int = 8,
                 max_len: int = 512, eos_id: int = 1,
                 control: ControlParams = ControlParams()):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.control = control

        self.queue: list[tuple[float, int, Request]] = []   # demand heap
        self.active: dict[int, Request] = {}
        self.slot_of: dict[int, int] = {}
        self.free_slots = list(range(slots))
        self.clock = 0.0
        self.kf = kalman.init(1, 1)

        dummy = {"tokens": jnp.zeros((slots, 1), jnp.int32)}
        self.cache = model.init_decode_state(params, dummy, max_len)
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.pos = jnp.zeros((), jnp.int32)
        self._step = jax.jit(model.decode_step)

    # ---- admission (proportional fairness, §III) ---------------------------
    def submit(self, req: Request) -> None:
        req.submitted = self.clock
        d = max(req.ttc, 1e-3)
        demand = req.max_new_tokens / d          # s* = r/d
        heapq.heappush(self.queue, (-demand, req.rid, req))

    def _admit(self) -> None:
        while self.free_slots and self.queue:
            _, _, req = heapq.heappop(self.queue)
            slot = self.free_slots.pop()
            self.slot_of[req.rid] = slot
            self.active[req.rid] = req
            # Prefill is approximated token-by-token for engine simplicity;
            # dedicated prefill lowering exists in launch/dryrun.py.
            self.tokens = self.tokens.at[slot].set(
                int(req.prompt[-1]) if len(req.prompt) else 0)

    # ---- decode loop ----------------------------------------------------------
    def step(self) -> dict:
        """One synchronous decode step across all active slots."""
        self._admit()
        if not self.active:
            return {"active": 0}
        t0 = time.perf_counter()
        logits, self.cache = self._step(self.params, self.tokens,
                                        self.cache, self.pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(next_tok)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.pos = self.pos + 1

        self.kf = kalman.step(
            self.kf, jnp.asarray([[dt / max(len(self.active), 1)]]),
            jnp.asarray([[True]]), self.control)

        done_now = []
        toks = np.asarray(next_tok)
        for rid, req in list(self.active.items()):
            slot = self.slot_of[rid]
            tok = int(toks[slot])
            req.generated.append(tok)
            if tok == self.eos_id or req.remaining <= 0 \
                    or int(self.pos) >= self.max_len - 1:
                req.done = True
                done_now.append(rid)
        for rid in done_now:
            slot = self.slot_of.pop(rid)
            self.free_slots.append(slot)
            del self.active[rid]
        self.tokens = jnp.asarray(
            [toks[s] for s in range(self.slots)], jnp.int32)
        return {"active": len(self.active), "step_time": dt,
                "per_token_cost": float(self.kf.b_hat[0, 0]),
                "completed": len(done_now)}

    def run_until_drained(self, max_steps: int = 10_000) -> list[dict]:
        stats = []
        for _ in range(max_steps):
            s = self.step()
            stats.append(s)
            if not self.active and not self.queue:
                break
        return stats

    def ttc_violations(self, requests: list[Request]) -> int:
        return sum(1 for r in requests
                   if r.done and (self.clock - r.submitted) > r.ttc)
