"""Zamba2-style hybrid: a Mamba-2 backbone with ONE shared attention block
applied every ``attn_every`` layers (parameter sharing across invocations).

Simplification vs. the released Zamba2 (noted in DESIGN.md): the shared
block consumes the running hidden state directly (no concat-with-embeddings
projector).  The shared block uses sliding-window attention so the
``long_500k`` cell stays sub-quadratic — long-range state is carried by the
SSM path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import sharding as sh
from . import ssm
from .attention import AttnSpec
from .dims import Dims
from .layers import DTYPE, embed, embed_init, mlp, mlp_init, rmsnorm, \
    rmsnorm_init, unembed


def _attn_spec(dims: Dims) -> AttnSpec:
    cfg = dims.cfg
    return AttnSpec(n_heads=dims.n_heads, n_kv=dims.n_kv, hd=dims.hd,
                    causal=True, window=cfg.sliding_window,
                    rope_theta=cfg.rope_theta)


def init_params(key, dims: Dims) -> dict:
    cfg = dims.cfg
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = [ssm.init(keys[i], dims) for i in range(cfg.n_layers)]
    ka, km = keys[-4], keys[-3]
    return {
        "embed": embed_init(keys[-1], dims.vocab, cfg.d_model),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "shared_attn": {
            "ln_attn": rmsnorm_init(cfg.d_model),
            "attn": attn.init(ka, cfg.d_model, _attn_spec(dims)),
            "ln_mlp": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(km, cfg.d_model, dims.d_ff, cfg.mlp),
        },
        "ln_f": rmsnorm_init(cfg.d_model),
    }


def _shared_attn_apply(p, dims, x, positions):
    cfg = dims.cfg
    spec = _attn_spec(dims)
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(p["attn"], h, spec, positions)
    o = attn.flash_attention(q, k, v, spec, q_pos=positions, k_pos=positions)
    x = x + attn.output_proj(p["attn"], o)
    h = rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg.mlp)


def forward(params, dims: Dims, tokens, remat: bool = True):
    cfg = dims.cfg
    k = cfg.attn_every
    x = embed(params["embed"], tokens).astype(DTYPE)
    x = sh.shard(x, sh.BATCH, sh.SEQ, None)
    positions = jnp.arange(x.shape[1])

    n_groups, tail = divmod(cfg.n_layers, k)
    grouped = jax.tree.map(
        lambda a: a[: n_groups * k].reshape(k, n_groups, *a.shape[1:]),
        params["blocks"])
    tail_p = jax.tree.map(lambda a: a[n_groups * k:], params["blocks"])

    def inner(x, layer):
        return ssm.block_apply(layer, dims, x) + x, None

    inner_r = jax.checkpoint(inner, policy=sh.remat_policy()) \
        if remat else inner

    def group(x, gparams):
        x, _ = jax.lax.scan(inner_r, x, gparams, unroll=sh.scan_unroll())
        x = _shared_attn_apply(params["shared_attn"], dims, x, positions)
        return x, None

    # grouped leaves are (k, n_groups, ...): scan over groups (axis 1).
    gsw = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), grouped)
    x, _ = jax.lax.scan(group, x, gsw, unroll=sh.scan_unroll())
    for i in range(tail):
        layer = jax.tree.map(lambda a: a[i], tail_p)
        x, _ = inner(x, layer)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return unembed_padded(params, dims, x)


def unembed_padded(params, dims, x):
    logits = unembed(params["embed"], x)
    if dims.vocab != dims.cfg.vocab:
        logits = jnp.where(jnp.arange(dims.vocab) < dims.cfg.vocab,
                           logits, -1e9)
    return logits


def init_cache(dims: Dims, batch: int, max_len: int) -> dict:
    cfg = dims.cfg
    w = min(max_len, cfg.sliding_window or max_len)
    n_groups = cfg.n_layers // cfg.attn_every
    return {
        "ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)),
            ssm.init_ssm_cache(dims, batch))._asdict(),
        "attn_k": jnp.zeros((n_groups, batch, w, dims.n_kv, dims.hd), DTYPE),
        "attn_v": jnp.zeros((n_groups, batch, w, dims.n_kv, dims.hd), DTYPE),
    }


def decode_step(params, dims: Dims, token, cache, pos):
    cfg = dims.cfg
    k = cfg.attn_every
    x = embed(params["embed"], token[:, None]).astype(DTYPE)
    n_groups, tail = divmod(cfg.n_layers, k)
    spec = _attn_spec(dims)
    sp = params["shared_attn"]

    ssm_cache = cache["ssm"]
    new_ssm = []
    ak, av = cache["attn_k"], cache["attn_v"]
    new_ak, new_av = [], []
    for li in range(cfg.n_layers):
        layer = jax.tree.map(lambda a: a[li], params["blocks"])
        lc = ssm.SsmCache(**{k: ssm_cache[k][li] for k in
                             ("conv_x", "conv_bc", "state")})
        y, nc = ssm.block_decode(layer, dims, x, lc)
        x = x + y
        new_ssm.append(nc)
        g, r = divmod(li + 1, k)
        if r == 0 and g <= n_groups:
            gi = g - 1
            h = rmsnorm(sp["ln_attn"], x, cfg.norm_eps)
            q, kk_, vv = attn.project_qkv(sp["attn"], h, spec, pos[None])
            ck, cv = attn.update_cache(ak[gi], av[gi], kk_, vv, pos,
                                       ring_size=ak.shape[2])
            o = attn.decode_attention(q, ck, cv, pos + 1, spec, ring=True)
            x = x + attn.output_proj(sp["attn"], o)
            h = rmsnorm(sp["ln_mlp"], x, cfg.norm_eps)
            x = x + mlp(sp["mlp"], h, cfg.mlp)
            new_ak.append(ck)
            new_av.append(cv)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed_padded(params, dims, x)[:, 0]
    new_cache = {
        "ssm": {k: jnp.stack([getattr(c, k) for c in new_ssm])
                for k in ("conv_x", "conv_bc", "state")},
        "attn_k": jnp.stack(new_ak), "attn_v": jnp.stack(new_av),
    }
    return logits, new_cache
