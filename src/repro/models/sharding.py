"""Activation-sharding annotations for the model zoo.

Models call ``shard(x, *axes)`` at key activation boundaries; outside a mesh
context (CPU smoke tests) this is the identity, and inside the dry-run /
launcher meshes it becomes ``with_sharding_constraint``.

Logical axes (resolved against the ambient mesh's axis names):
  BATCH  -> ('pod', 'data') if the mesh has a 'pod' axis, else ('data',)
  MODEL  -> ('model',)
  SEQ    -> sequence-parallel axis; the perf pass maps it to ('data',) for
            long-context decode where batch cannot shard.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH, MODEL, SEQ, NONE = "batch", "model", "seq", None

# Module-level switches, configured by the launcher (default: smoke mode).
_ENABLED = False
_SEQ_SHARDED = False
_SCAN_UNROLL: int | bool = False
_REMAT = "nothing"


def configure(enabled: bool, seq_sharded: bool = False,
              scan_unroll: int | bool = False,
              remat: str = "nothing") -> None:
    global _ENABLED, _SEQ_SHARDED, _SCAN_UNROLL, _REMAT
    _ENABLED = enabled
    _SEQ_SHARDED = seq_sharded
    _SCAN_UNROLL = scan_unroll
    _REMAT = remat


def scan_unroll() -> int | bool:
    """Scan unroll factor (True for the dry-run's depth probes, where the
    unrolled HLO makes cost_analysis count every layer)."""
    return _SCAN_UNROLL


def remat_policy():
    """Activation-checkpoint policy for the layer scan.

    'nothing' (default): recompute the whole block in backward — only the
    residual-stream carry is live per layer (memory-optimal; ~+fwd FLOPs).
    'dots': save dot outputs — faster backward, but with blocked flash
    attention this also pins every score tile, which blows past HBM on the
    4k-train cells (the §Perf log quantifies the trade).
    """
    if _REMAT == "dots":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def _mesh_axes():
    mesh = jax.sharding.get_abstract_mesh()
    return mesh.axis_names if mesh is not None else ()


def resolve(axis):
    names = _mesh_axes()
    if axis == BATCH:
        return tuple(a for a in ("pod", "data") if a in names) or None
    if axis == MODEL:
        return "model" if "model" in names else None
    if axis == SEQ:
        return "data" if (_SEQ_SHARDED and "data" in names) else None
    return None


def spec(*axes) -> P:
    return P(*[resolve(a) for a in axes])


def shard(x, *axes):
    """Constrain activation ``x`` (one logical axis name per dim).

    Divisibility-guarded: any tensor axis that does not divide its mesh
    factor falls back to replication instead of failing to lower.
    """
    if not _ENABLED:
        return x
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    sizes = dict(mesh.shape)
    resolved = []
    for dim, ax in zip(x.shape, [resolve(a) for a in axes]):
        if ax is None:
            resolved.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        factor = 1
        for n in names:
            factor *= sizes[n]
        resolved.append(ax if dim % factor == 0 and dim > 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
