"""Unified model API over the 10-architecture zoo.

``Model(cfg, model_size)`` dispatches on the family and exposes:
  init_params / loss / forward / init_decode_state / decode_step /
  input_specs(shape) — ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, hybrid, ssm, transformer
from .dims import Dims
from .layers import DTYPE, cross_entropy, embed, rmsnorm, rmsnorm_init
from . import sharding as sh


class Model:
    def __init__(self, cfg: ArchConfig, model_size: int = 1):
        self.cfg = cfg
        self.dims = Dims(cfg, model_size)
        self.dims.check()

    # --- parameters ----------------------------------------------------------
    def init_params(self, key) -> dict:
        f = self.cfg.family
        if f in ("dense", "vlm", "moe"):
            return transformer.init_params(key, self.dims)
        if f == "ssm":
            return self._ssm_init(key)
        if f == "hybrid":
            return hybrid.init_params(key, self.dims)
        if f == "audio":
            return encdec.init_params(key, self.dims)
        raise ValueError(f)

    def _ssm_init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, cfg.n_layers + 2)
        blocks = [ssm.init(keys[i], self.dims) for i in range(cfg.n_layers)]
        from .layers import embed_init
        return {
            "embed": embed_init(keys[-1], self.dims.vocab, cfg.d_model),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "ln_f": rmsnorm_init(cfg.d_model),
        }

    # --- training / prefill ---------------------------------------------------
    def forward(self, params, batch: dict, remat: bool = True) -> jnp.ndarray:
        f = self.cfg.family
        if f in ("dense", "moe"):
            return transformer.forward(params, self.dims, batch["tokens"],
                                       remat=remat)
        if f == "vlm":
            return transformer.forward(params, self.dims, batch["tokens"],
                                       extra_embeds=batch["patch_embeds"],
                                       remat=remat)
        if f == "ssm":
            return self._ssm_forward(params, batch["tokens"], remat)
        if f == "hybrid":
            return hybrid.forward(params, self.dims, batch["tokens"], remat)
        if f == "audio":
            return encdec.forward(params, self.dims, batch["tokens"],
                                  batch["frames"], remat)
        raise ValueError(f)

    def _ssm_forward(self, params, tokens, remat=True):
        cfg = self.cfg
        x = embed(params["embed"], tokens).astype(DTYPE)
        x = sh.shard(x, sh.BATCH, sh.SEQ, None)

        def body(x, layer):
            return x + ssm.block_apply(layer, self.dims, x), None

        body = jax.checkpoint(body, policy=sh.remat_policy()) \
            if remat else body
        x, _ = jax.lax.scan(body, x, params["blocks"],
                            unroll=sh.scan_unroll())
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        return hybrid.unembed_padded(params, self.dims, x)

    def loss(self, params, batch: dict) -> jnp.ndarray:
        logits = self.forward(params, batch)
        if self.cfg.family == "vlm":
            logits = logits[:, batch["patch_embeds"].shape[1]:]
        return cross_entropy(logits, batch["labels"])

    # --- decode -----------------------------------------------------------------
    def init_decode_state(self, params, batch: dict, max_len: int) -> dict:
        f = self.cfg.family
        b = batch["tokens"].shape[0]
        if f in ("dense", "moe", "vlm"):
            return transformer.init_cache(self.dims, b, max_len)
        if f == "ssm":
            c = ssm.init_ssm_cache(self.dims, b)
            return {k: jnp.broadcast_to(v, (self.cfg.n_layers, *v.shape))
                    for k, v in c._asdict().items()}
        if f == "hybrid":
            return hybrid.init_cache(self.dims, b, max_len)
        if f == "audio":
            return encdec.init_cache(params, self.dims, batch["frames"],
                                     max_len)
        raise ValueError(f)

    def decode_step(self, params, token, cache, pos):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.decode_step(params, self.dims, token, cache,
                                           pos)
        if f == "ssm":
            return self._ssm_decode(params, token, cache, pos)
        if f == "hybrid":
            return hybrid.decode_step(params, self.dims, token, cache, pos)
        if f == "audio":
            return encdec.decode_step(params, self.dims, token, cache, pos)
        raise ValueError(f)

    def _ssm_decode(self, params, token, cache, pos):
        cfg = self.cfg
        x = embed(params["embed"], token[:, None]).astype(DTYPE)

        def body(x, layer):
            lc = ssm.SsmCache(conv_x=layer["conv_x"],
                              conv_bc=layer["conv_bc"],
                              state=layer["state"])
            y, nc = ssm.block_decode(layer["p"], self.dims, x, lc)
            return x + y, nc._asdict()

        xs = {"p": params["blocks"], **{k: cache[k] for k in
                                        ("conv_x", "conv_bc", "state")}}
        x, new = jax.lax.scan(body, x, xs, unroll=sh.scan_unroll())
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = hybrid.unembed_padded(params, self.dims, x)[:, 0]
        return logits, new

    # --- dry-run inputs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        bf16 = functools.partial(jax.ShapeDtypeStruct, dtype=DTYPE)
        if shape.kind in ("train", "prefill"):
            if cfg.family == "vlm":
                p = cfg.n_patches
                return {"tokens": i32((b, s - p)),
                        "labels": i32((b, s - p)),
                        "patch_embeds": bf16((b, p, cfg.d_model))}
            if cfg.family == "audio":
                return {"tokens": i32((b, s)), "labels": i32((b, s)),
                        "frames": bf16((b, cfg.enc_len, cfg.d_model))}
            return {"tokens": i32((b, s)), "labels": i32((b, s))}
        # decode: one new token against a cache of length s
        spec = {"token": i32((b,)), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if cfg.family == "audio":
            spec["frames"] = bf16((b, cfg.enc_len, cfg.d_model))
        if cfg.family == "vlm":
            spec["patch_embeds"] = bf16((b, cfg.n_patches, cfg.d_model))
        return spec

    def supports(self, shape: ShapeConfig) -> bool:
        """Shape applicability (see DESIGN.md §Arch-applicability)."""
        if shape.name == "long_500k":
            return self.cfg.sub_quadratic
        return True
