"""Mixture-of-Experts FFN (Mixtral 8e top-2, Llama-4-Scout 16e top-1).

Dispatch is gather-based (sort-free): for each expert we build a (C,) index
vector of the tokens routed to it (capacity C = cf·T·k/E), gather, run the
expert FFN as one batched einsum over the expert dimension (MXU-friendly
(E,C,D)×(E,D,F)), and scatter-add back weighted by the router gates.
Overflowed tokens are dropped (standard capacity-factor semantics); the
shared expert (Llama-4) is a plain dense SwiGLU applied to every token.

Baseline sharding is tensor-parallel experts: expert weights (E, D, F) with
F on the model axis, routing entirely local.  Expert-parallel (E on the
model axis + all-to-all) is evaluated in the §Perf pass.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as sh
from .dims import Dims
from .layers import _normal


def init(key, dims: Dims) -> dict:
    cfg = dims.cfg
    d, f, e = cfg.d_model, dims.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": _normal(ks[1], (e, d, f), d ** -0.5),
        "w_up": _normal(ks[2], (e, d, f), d ** -0.5),
        "w_down": _normal(ks[3], (e, f, d), f ** -0.5),
    }
    if cfg.shared_expert:
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": _normal(sk[0], (d, f), d ** -0.5),
                       "w_up": _normal(sk[1], (d, f), d ** -0.5),
                       "w_down": _normal(sk[2], (f, d), f ** -0.5)}
    return p


def _dispatch_indices(expert_of: jnp.ndarray, e: int, cap: int):
    """expert_of: (A,) assignment per (token, k-slot).  Returns
    idx (E, C) positions into the flat assignment array and valid (E, C)."""
    a = expert_of.shape[0]
    onehot = jax.nn.one_hot(expert_of, e, dtype=jnp.int32)       # (A, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1           # (A, E)
    slot = jnp.sum(pos_in_e * onehot, axis=1)                    # (A,)
    keep = (slot >= 0) & (slot < cap)
    # Scatter flat positions into the (E, C) table.
    flat = jnp.full((e * cap,), a, jnp.int32)                    # a == OOB
    tgt = jnp.where(keep, expert_of * cap + slot, e * cap)
    flat = flat.at[tgt.clip(0, e * cap)].set(
        jnp.where(keep, jnp.arange(a, dtype=jnp.int32), a),
        mode="drop")
    idx = flat.reshape(e, cap)
    return idx, idx < a


def _row_moe(p, cfg, xt, logits, cap):
    """MoE over one token group.  xt: (T,D); logits: (T,E)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    gates, exp_idx = jax.lax.top_k(logits, k)                    # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)
    expert_of = exp_idx.reshape(-1)                              # (T*k,)
    idx, valid = _dispatch_indices(expert_of, e, cap)            # (E, C)

    token_of = idx // k                                          # (E, C)
    xe = jnp.take(xt, token_of.clip(0, t - 1).reshape(-1),
                  axis=0).reshape(e, cap, d)
    xe = jnp.where(valid[..., None], xe, 0.0)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = sh.shard(h, None, None, sh.MODEL)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, D)

    gate_of = jnp.take(gates.reshape(-1),
                       idx.clip(0, t * k - 1).reshape(-1)).reshape(e, cap)
    gate_of = jnp.where(valid, gate_of, 0.0)
    out = jnp.zeros((t, d), jnp.float32).at[token_of.reshape(-1)].add(
        (ye * gate_of[..., None]).reshape(-1, d).astype(jnp.float32),
        mode="drop")
    return out


def apply(p: dict, dims: Dims, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,D) -> (B,S,D).

    Training/prefill dispatches PER SEQUENCE (vmap over the batch row):
    capacity counts, cumsums and gathers stay local to the data shard that
    owns the row, so routing needs no cross-device traffic under the
    batch-over-'data' sharding.  Decode (S == 1) dispatches globally over
    the tiny token batch instead — per-row capacity would degenerate to
    all-experts-per-token compute.
    """
    cfg = dims.cfg
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])

    if s > 1:
        cap = int(cfg.capacity_factor * s * k / e)
        cap = max(8, min(cap, s * k))
        out = jax.vmap(lambda xt, lg: _row_moe(p, cfg, xt, lg, cap))(
            x, logits)
        out = out.reshape(b, s, d)
    else:
        t = b * s
        cap = max(1, min(int(cfg.capacity_factor * t * k / e), t))
        out = _row_moe(p, cfg, x.reshape(t, d),
                       logits.reshape(t, e), cap).reshape(b, s, d)

    if cfg.shared_expert:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        hs = sh.shard(hs, None, None, sh.MODEL)
        out = out + (hs @ sp["w_down"]).astype(jnp.float32)

    return out.astype(x.dtype)


def aux_loss(logits: jnp.ndarray, exp_idx: jnp.ndarray, e: int):
    """Standard load-balancing auxiliary loss (not used by dry-run)."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(exp_idx[..., 0], e), axis=0)
    return e * jnp.sum(frac * jnp.mean(probs, axis=0))