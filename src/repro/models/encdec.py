"""Whisper-style encoder-decoder backbone (audio frontend is a STUB:
``input_specs`` provides precomputed log-mel frame embeddings).

Whisper specifics kept: LayerNorm (with bias), GELU MLPs, learned positional
embeddings, no rope; decoder blocks add cross-attention over the encoder
output.  Decode caches: per-layer self KV plus precomputed cross KV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import sharding as sh
from .attention import AttnSpec
from .dims import Dims
from .layers import (DTYPE, _normal, embed, embed_init, layernorm,
                     layernorm_init, mlp, mlp_init, unembed)

MAX_DEC_POS = 32768  # learned decoder positions (Whisper's real ceiling is
                     # 448; extended so the assigned 32k backbone shapes are
                     # exercisable — see DESIGN.md §Arch-applicability)


def _spec(dims: Dims, causal: bool) -> AttnSpec:
    return AttnSpec(n_heads=dims.n_heads, n_kv=dims.n_kv, hd=dims.hd,
                    causal=causal, use_rope=False)


def _attn_block_init(key, d, dims, cross: bool) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln": layernorm_init(d),
         "attn": attn.init(ks[0], d, _spec(dims, True))}
    if cross:
        p["ln_x"] = layernorm_init(d)
        p["xattn"] = attn.init(ks[1], d, _spec(dims, False))
    p["ln_mlp"] = layernorm_init(d)
    p["mlp"] = mlp_init(ks[2], d, dims.d_ff, "gelu")
    return p


def init_params(key, dims: Dims) -> dict:
    cfg = dims.cfg
    keys = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 4)
    enc = [_attn_block_init(keys[i], cfg.d_model, dims, cross=False)
           for i in range(cfg.enc_layers)]
    dec = [_attn_block_init(keys[cfg.enc_layers + i], cfg.d_model, dims,
                            cross=True) for i in range(cfg.n_layers)]
    return {
        "enc_pos": _normal(keys[-1], (cfg.enc_len, cfg.d_model), 0.02),
        "dec_pos": _normal(keys[-2], (MAX_DEC_POS, cfg.d_model), 0.02),
        "embed": embed_init(keys[-3], dims.vocab, cfg.d_model),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "ln_enc": layernorm_init(cfg.d_model),
        "ln_f": layernorm_init(cfg.d_model),
    }


def encode(params, dims: Dims, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, D) stub embeddings -> encoder states."""
    cfg = dims.cfg
    spec = _spec(dims, causal=False)
    x = frames.astype(DTYPE) + params["enc_pos"][None]
    x = sh.shard(x, sh.BATCH, None, None)
    positions = jnp.arange(x.shape[1])

    def body(x, p):
        h = layernorm(p["ln"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], h, spec, positions)
        x = x + attn.output_proj(
            p["attn"], attn.flash_attention(q, k, v, spec,
                                            q_pos=positions, k_pos=positions))
        h = layernorm(p["ln_mlp"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, "gelu"), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"],
                        unroll=sh.scan_unroll())
    return layernorm(params["ln_enc"], x, cfg.norm_eps)


def forward(params, dims: Dims, tokens: jnp.ndarray, frames: jnp.ndarray,
            remat: bool = True):
    """Teacher-forced training/prefill: returns decoder logits (B,S,V)."""
    cfg = dims.cfg
    enc_out = encode(params, dims, frames)
    self_spec = _spec(dims, causal=True)
    cross_spec = _spec(dims, causal=False)

    s = tokens.shape[1]
    x = embed(params["embed"], tokens).astype(DTYPE) + params["dec_pos"][:s]
    x = sh.shard(x, sh.BATCH, sh.SEQ, None)
    positions = jnp.arange(s)
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(x, p):
        h = layernorm(p["ln"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], h, self_spec, positions)
        x = x + attn.output_proj(
            p["attn"], attn.flash_attention(q, k, v, self_spec,
                                            q_pos=positions, k_pos=positions))
        h = layernorm(p["ln_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, p["xattn"]["wq"])
        xk = jnp.einsum("bsd,dke->bske", enc_out, p["xattn"]["wk"])
        xv = jnp.einsum("bsd,dke->bske", enc_out, p["xattn"]["wv"])
        x = x + attn.output_proj(
            p["xattn"], attn.flash_attention(q, xk, xv, cross_spec,
                                             q_pos=positions, k_pos=enc_pos))
        h = layernorm(p["ln_mlp"], x, cfg.norm_eps)
        return x + mlp(p["mlp"], h, "gelu"), None

    body = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=sh.scan_unroll())
    x = layernorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    if dims.vocab != cfg.vocab:
        logits = jnp.where(jnp.arange(dims.vocab) < cfg.vocab, logits, -1e9)
    return logits


def init_cache(params, dims: Dims, frames: jnp.ndarray, max_len: int) -> dict:
    """Run the encoder once; precompute per-layer cross K/V."""
    cfg = dims.cfg
    enc_out = encode(params, dims, frames)
    b = frames.shape[0]

    def one(p):
        xk = jnp.einsum("bsd,dke->bske", enc_out, p["wk"])
        xv = jnp.einsum("bsd,dke->bske", enc_out, p["wv"])
        return xk, xv

    xks, xvs = jax.vmap(one)(params["dec"]["xattn"])
    shape = (cfg.n_layers, b, max_len, dims.n_kv, dims.hd)
    return {"k": jnp.zeros(shape, DTYPE), "v": jnp.zeros(shape, DTYPE),
            "xk": xks, "xv": xvs}


def decode_step(params, dims: Dims, token: jnp.ndarray, cache: dict,
                pos: jnp.ndarray):
    cfg = dims.cfg
    self_spec = _spec(dims, causal=True)
    cross_spec = _spec(dims, causal=False)
    x = embed(params["embed"], token[:, None]).astype(DTYPE)
    x = x + jnp.take(params["dec_pos"], pos[None].clip(0, MAX_DEC_POS - 1),
                     axis=0)[None]

    def body(x, layer):
        p = layer["p"]
        h = layernorm(p["ln"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], h, self_spec, pos[None])
        ck, cv = attn.update_cache(layer["k"], layer["v"], k, v, pos)
        x = x + attn.output_proj(
            p["attn"], attn.decode_attention(q, ck, cv, pos + 1, self_spec))
        h = layernorm(p["ln_x"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhe->bshe", h, p["xattn"]["wq"])
        n_enc = layer["xk"].shape[1]
        o = attn.decode_attention(q, layer["xk"], layer["xv"],
                                  jnp.asarray(n_enc), cross_spec)
        x = x + attn.output_proj(p["xattn"], o)
        h = layernorm(p["ln_mlp"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h, "gelu")
        return x, {"k": ck, "v": cv}

    xs = {"p": params["dec"], "k": cache["k"], "v": cache["v"],
          "xk": cache["xk"], "xv": cache["xv"]}
    x, new_kv = jax.lax.scan(body, x, xs, unroll=sh.scan_unroll())
    x = layernorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)[:, 0]
    if dims.vocab != cfg.vocab:
        logits = jnp.where(jnp.arange(dims.vocab) < cfg.vocab, logits, -1e9)
    return logits, {"k": new_kv["k"], "v": new_kv["v"],
                    "xk": cache["xk"], "xv": cache["xv"]}
