"""Padded model dimensions for tensor-parallel sharding.

The production mesh has a 16-way 'model' axis.  Heads/vocab that do not
divide it are padded, and GQA KV heads with n_kv < model_size are
*replicated* up to the axis size (each KV head stored model_size/n_kv
times) so the KV cache shards cleanly — the standard Megatron treatment.
Padding waste is reported by the roofline's useful-FLOPs ratio.
"""

from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class Dims:
    cfg: ArchConfig
    model_size: int = 1          # size of the 'model' mesh axis

    @property
    def n_heads(self) -> int:
        if self.cfg.n_heads == 0:
            return 0
        return _pad_to(self.cfg.n_heads, self.model_size)

    @property
    def n_kv(self) -> int:
        """KV heads after replication/padding (divides n_heads, shards)."""
        kv = self.cfg.n_kv_heads
        if kv == 0:
            return 0
        if kv >= self.model_size:
            return kv            # already shards (kv % model checked below)
        # Replicate KV heads up to the model axis; n_heads padding keeps
        # q-groups aligned (n_heads % n_kv == 0 by construction).
        return self.model_size

    @property
    def kv_repeat(self) -> int:
        return self.n_kv // max(self.cfg.n_kv_heads, 1) if self.cfg.n_kv_heads else 1

    @property
    def vocab(self) -> int:
        return _pad_to(self.cfg.vocab, self.model_size)

    @property
    def hd(self) -> int:
        return self.cfg.hd

    @property
    def d_ff(self) -> int:
        return _pad_to(self.cfg.d_ff, self.model_size) if self.cfg.d_ff else 0

    @property
    def ssm_heads(self) -> int:
        return self.cfg.ssm_heads

    def check(self) -> None:
        m = self.model_size
        if self.n_heads and self.n_heads % m:
            raise ValueError(f"heads {self.n_heads} !% model {m}")
        if self.n_kv and self.n_kv % min(m, self.n_kv):
            raise ValueError(f"kv {self.n_kv} vs model {m}")
        if self.n_kv and self.n_heads % self.n_kv:
            raise ValueError(f"heads {self.n_heads} !% kv {self.n_kv}")
