"""Dense decoder-only transformer (internlm2 / granite / stablelm / qwen /
llava backbone) with scan-over-layers, remat, KV-cache decode, and MoE hooks.

Layout: block params are stacked along a leading L axis and consumed by
``lax.scan`` — one compiled block regardless of depth (fast compiles at 512
devices, and the idiomatic TPU training structure).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_lib
from . import sharding as sh
from .attention import AttnSpec
from .dims import Dims
from .layers import (DTYPE, cross_entropy, embed, embed_init, mlp, mlp_init,
                     rmsnorm, rmsnorm_init, unembed)


def attn_spec(dims: Dims) -> AttnSpec:
    cfg = dims.cfg
    return AttnSpec(
        n_heads=dims.n_heads, n_kv=dims.n_kv, hd=dims.hd,
        causal=True,
        window=cfg.sliding_window,
        chunk=cfg.attn_chunk,
        use_rope=True,
        rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias)


# --- one dense block ---------------------------------------------------------

def block_init(key, dims: Dims) -> dict:
    cfg = dims.cfg
    ka, km = jax.random.split(key)
    p = {
        "ln_attn": rmsnorm_init(cfg.d_model),
        "attn": attn.init(ka, cfg.d_model, attn_spec(dims)),
        "ln_mlp": rmsnorm_init(cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.init(km, dims)
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, dims.d_ff, cfg.mlp)
    return p


def block_apply(p: dict, dims: Dims, x: jnp.ndarray, positions: jnp.ndarray,
                is_global=None) -> jnp.ndarray:
    cfg = dims.cfg
    spec = attn_spec(dims)
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(p["attn"], h, spec, positions, is_global)
    o = attn.flash_attention(q, k, v, spec, q_pos=positions, k_pos=positions,
                             is_global=is_global)
    attn_out = attn.output_proj(p["attn"], o)

    if cfg.parallel_block:
        # §Perf variant (PaLM): attention and MLP read the same normed
        # input and their outputs sum into ONE residual add — the two
        # row-parallel all-reduces per layer fuse into one.
        m = (moe_lib.apply(p["moe"], dims, h) if cfg.family == "moe"
             else mlp(p["mlp"], h, cfg.mlp))
        x = x + attn_out + m
    else:
        x = x + attn_out
        h = rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
        if cfg.family == "moe":
            x = x + moe_lib.apply(p["moe"], dims, h)
        else:
            x = x + mlp(p["mlp"], h, cfg.mlp)
    return sh.shard(x, sh.BATCH, sh.SEQ, None)


def block_decode(p: dict, dims: Dims, x: jnp.ndarray, cache: dict,
                 pos: jnp.ndarray, is_global=None):
    """x: (B,1,D); cache: {'k','v'} (B,S_c,KV,hd).  Returns (x, cache)."""
    cfg = dims.cfg
    spec = attn_spec(dims)
    ring = is_ring(cfg)
    h = rmsnorm(p["ln_attn"], x, cfg.norm_eps)
    q, k, v = attn.project_qkv(p["attn"], h, spec, pos[None], is_global)
    ring_size = cache["k"].shape[1] if ring else None
    ck, cv = attn.update_cache(cache["k"], cache["v"], k, v, pos,
                               ring_size=ring_size)
    o = attn.decode_attention(q, ck, cv, pos + 1, spec, ring=ring,
                              is_global=is_global)
    x = x + attn.output_proj(p["attn"], o)
    h = rmsnorm(p["ln_mlp"], x, cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_lib.apply(p["moe"], dims, h)
    else:
        x = x + mlp(p["mlp"], h, cfg.mlp)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ck, cv
    return x, new_cache


# --- full model ---------------------------------------------------------------

def init_params(key, dims: Dims) -> dict:
    cfg = dims.cfg
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [block_init(keys[i], dims) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    p = {
        "embed": embed_init(keys[-1], dims.vocab, cfg.d_model),
        "blocks": stacked,
        "ln_f": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(keys[-2], dims.vocab, cfg.d_model)
    return p


def layer_kinds(cfg) -> Optional[jnp.ndarray]:
    """Per-layer kind ids for heterogeneous stacks (Llama-4): 0=causal/local,
    1=global-NoPE.  None for homogeneous stacks."""
    if cfg.attn_chunk and cfg.global_every:
        ids = [(1 if (i + 1) % cfg.global_every == 0 else 0)
               for i in range(cfg.n_layers)]
        return jnp.asarray(ids)
    return None


def forward(params: dict, dims: Dims, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            remat: bool = True) -> jnp.ndarray:
    """Training/prefill forward: tokens (B,S[-P]) -> logits (B,S,V)."""
    cfg = dims.cfg
    x = embed(params["embed"], tokens).astype(DTYPE)
    if extra_embeds is not None:          # VLM: prepend stub patch embeds
        x = jnp.concatenate([extra_embeds.astype(DTYPE), x], axis=1)
    x = sh.shard(x, sh.BATCH, sh.SEQ, None)
    s = x.shape[1]
    positions = jnp.arange(s)
    kinds = layer_kinds(cfg)

    def body(x, layer):
        is_g = (layer["kind"] == 1) if kinds is not None else None
        y = block_apply(layer["p"], dims, x, positions, is_g)
        return y, None

    body = jax.checkpoint(body, policy=sh.remat_policy()) if remat else body
    xs = {"p": params["blocks"]}
    if kinds is not None:
        xs["kind"] = kinds
    x, _ = jax.lax.scan(body, x, xs, unroll=sh.scan_unroll())

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params.get("head", params["embed"])
    logits = unembed(head, x)
    if dims.vocab != cfg.vocab:           # mask padded vocab columns
        pad_mask = jnp.arange(dims.vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e9)
    return logits


def is_ring(cfg) -> bool:
    """Window archs keep a ring-buffer cache (static per architecture)."""
    return cfg.sliding_window is not None and cfg.attn_chunk is None


def init_cache(dims: Dims, batch: int, max_len: int) -> dict:
    """Stacked (L-leading) KV caches.  Window archs get ring buffers;
    kv_dtype == 'int8' stores quantized K/V (§Perf variant)."""
    cfg = dims.cfg
    s_c = min(max_len, cfg.sliding_window) if is_ring(cfg) else max_len
    shape = (cfg.n_layers, batch, s_c, dims.n_kv, dims.hd)
    dt = jnp.int8 if cfg.kv_dtype == "int8" else DTYPE
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def decode_step(params: dict, dims: Dims, token: jnp.ndarray,
                cache: dict, pos: jnp.ndarray):
    """One decode step: token (B,) int32 -> logits (B,V), updated cache."""
    cfg = dims.cfg
    x = embed(params["embed"], token[:, None]).astype(DTYPE)
    x = sh.shard(x, sh.BATCH, None, None)
    kinds = layer_kinds(cfg)

    def body(x, layer):
        lc = {"k": layer["k"], "v": layer["v"]}
        is_g = (layer["kind"] == 1) if kinds is not None else None
        y, nc = block_decode(layer["p"], dims, x, lc, pos, is_g)
        return y, {"k": nc["k"], "v": nc["v"]}

    xs = {"p": params["blocks"], "k": cache["k"], "v": cache["v"]}
    if kinds is not None:
        xs["kind"] = kinds
    x, new_kv = jax.lax.scan(body, x, xs, unroll=sh.scan_unroll())

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params.get("head", params["embed"])
    logits = unembed(head, x)[:, 0]
    if dims.vocab != cfg.vocab:
        logits = jnp.where(jnp.arange(dims.vocab) < cfg.vocab, logits, -1e9)
    return logits, {"k": new_kv["k"], "v": new_kv["v"]}


def loss_fn(params, dims, tokens, labels, extra_embeds=None):
    logits = forward(params, dims, tokens, extra_embeds)
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    return cross_entropy(logits, labels)
