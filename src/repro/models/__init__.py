# 10-architecture model zoo: dense GQA transformers, Mamba-2 SSD, Zamba-2
# hybrid, Whisper enc-dec, LLaVA backbone, Mixtral / Llama-4 MoE.
from .dims import Dims
from .model import Model

__all__ = ["Dims", "Model"]
