"""GQA attention: training/prefill (blocked flash, memory-bounded) + decode
(KV-cache, full or ring-buffer).

Locality variants cover the whole zoo:
  * causal                — dense LMs
  * sliding window (W)    — Mixtral, Zamba2 shared block
  * chunked-local (C)     — Llama-4 local layers (iRoPE: global layers NoPE)
  * bidirectional / cross — Whisper encoder / decoder cross-attention

The full-sequence path is a streaming-softmax (flash) formulation scanned
over KV blocks, so the 32k prefill never materializes an S×S score matrix.
On TPU the Pallas kernel in ``repro.kernels.flash_attention`` implements the
same tiling in VMEM; this pure-JAX path is the oracle and the dry-run path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import sharding as sh
from .layers import DTYPE, _normal, apply_rope

K_BLOCK = 1024


class AttnSpec(NamedTuple):
    n_heads: int
    n_kv: int
    hd: int
    causal: bool = True
    window: Optional[int] = None     # sliding-window size
    chunk: Optional[int] = None      # chunked-local size
    use_rope: bool = True
    rope_theta: float = 1e4
    qkv_bias: bool = False


def init(key, d: int, spec: AttnSpec) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _normal(ks[0], (d, spec.n_heads, spec.hd), d ** -0.5),
        "wk": _normal(ks[1], (d, spec.n_kv, spec.hd), d ** -0.5),
        "wv": _normal(ks[2], (d, spec.n_kv, spec.hd), d ** -0.5),
        "wo": _normal(ks[3], (spec.n_heads, spec.hd, d),
                      (spec.n_heads * spec.hd) ** -0.5),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((spec.n_heads, spec.hd), DTYPE)
        p["bk"] = jnp.zeros((spec.n_kv, spec.hd), DTYPE)
        p["bv"] = jnp.zeros((spec.n_kv, spec.hd), DTYPE)
    return p


def project_qkv(p: dict, x: jnp.ndarray, spec: AttnSpec,
                positions: jnp.ndarray, is_global=None):
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope applied.

    ``is_global`` (traced bool or None) implements Llama-4 iRoPE: global
    layers skip rope (NoPE) — selected at runtime so a heterogeneous layer
    stack still scans as one compiled block.
    """
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if spec.use_rope:
        qr = apply_rope(q, positions, spec.rope_theta)
        kr = apply_rope(k, positions, spec.rope_theta)
        if is_global is None:
            q, k = qr, kr
        else:
            q = jnp.where(is_global, q, qr)
            k = jnp.where(is_global, k, kr)
    q = sh.shard(q, sh.BATCH, None, sh.MODEL, None)
    k = sh.shard(k, sh.BATCH, None, sh.MODEL if spec.n_kv > 1 else None, None)
    v = sh.shard(v, sh.BATCH, None, sh.MODEL if spec.n_kv > 1 else None, None)
    return q, k, v


def _tile_mask(q_pos, k_pos, spec: AttnSpec, is_global=None):
    """Validity mask for a (q_block, k_block) tile from position vectors.

    ``is_global`` (traced bool): lifts the chunk-locality constraint for
    Llama-4 global layers at runtime.
    """
    d = q_pos[:, None] - k_pos[None, :]
    m = k_pos[None, :] >= 0          # padded key slots carry position -1
    if spec.causal:
        m &= d >= 0
    if spec.window is not None:
        m &= d < spec.window
    if spec.chunk is not None:
        same = (q_pos[:, None] // spec.chunk) == (k_pos[None, :] // spec.chunk)
        m &= same if is_global is None else (same | is_global)
    return m


def flash_attention(q, k, v, spec: AttnSpec,
                    q_pos=None, k_pos=None, k_block: int = K_BLOCK,
                    is_global=None):
    """Streaming-softmax attention scanned over KV blocks.

    q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd).  Returns (B,Sq,H,hd).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(sk)

    kb = min(k_block, sk)
    n_blocks = (sk + kb - 1) // kb
    pad = n_blocks * kb - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-1)

    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    k = k.reshape(b, n_blocks, kb, kv, hd)
    v = v.reshape(b, n_blocks, kb, kv, hd)
    k_posb = k_pos.reshape(n_blocks, kb)
    scale = hd ** -0.5

    def step(carry, xs):
        m_run, l_run, acc = carry
        kblk, vblk, kp = xs                       # (B,kb,KV,hd) x2, (kb,)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kblk.astype(jnp.float32))
        s = s * scale
        mask = _tile_mask(q_pos, kp, spec, is_global)    # (Sq, kb)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        # Guard fully-masked rows (m_new == -inf).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p_ = jnp.exp(s - m_safe[..., None])
        p_ = jnp.where(mask[None, :, None, None, :], p_, 0.0)
        alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
        l_new = l_run * alpha + jnp.sum(p_, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p_, vblk.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    init_carry = (
        jnp.full((b, sq, kv, g), -jnp.inf, jnp.float32),
        jnp.zeros((b, sq, kv, g), jnp.float32),
        jnp.zeros((b, sq, kv, g, hd), jnp.float32),
    )
    (m_f, l_f, acc), _ = jax.lax.scan(
        step, init_carry,
        (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0), k_posb))
    out = acc / jnp.maximum(l_f[..., None], 1e-20)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def reference_attention(q, k, v, spec: AttnSpec, q_pos=None, k_pos=None):
    """O(S²)-memory oracle for tests (small shapes only)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(k.shape[1])
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(jnp.float32)) * hd ** -0.5
    mask = _tile_mask(q_pos, k_pos, spec)
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q, cache_k, cache_v, pos, spec: AttnSpec,
                     ring: bool = False, is_global=None):
    """Single-token attention against a KV cache.

    q: (B,1,H,hd); cache_k/v: (B,S_cache,KV,hd); pos: scalar current index
    (number of tokens already in the cache, including this one at pos-1).
    For ring caches, slot validity covers the whole buffer once warm.
    """
    b, _, h, hd = q.shape
    s_cache, kv = cache_k.shape[1], cache_k.shape[2]
    g = h // kv
    if cache_k.dtype == jnp.int8:
        cache_k = dequantize_kv(cache_k)
        cache_v = dequantize_kv(cache_v)
    qg = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   cache_k.astype(jnp.float32)) * hd ** -0.5
    idx = jnp.arange(s_cache)
    if ring:
        valid = idx < jnp.minimum(pos, s_cache)
    else:
        valid = idx < pos
        if spec.window is not None:
            valid &= idx >= pos - spec.window
    if spec.chunk is not None:
        cur = (pos - 1) // spec.chunk
        same = (idx // spec.chunk) == cur
        valid &= same if is_global is None else (same | is_global)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


KV_QUANT_SCALE = 32.0     # symmetric int8 KV quantization (§Perf variant)


def quantize_kv(x: jnp.ndarray) -> jnp.ndarray:
    q = jnp.round(x.astype(jnp.float32) * KV_QUANT_SCALE)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize_kv(q: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * (1.0 / KV_QUANT_SCALE)


def update_cache(cache_k, cache_v, k_new, v_new, pos, ring_size=None):
    """Write one step's K/V at position ``pos`` (mod ring_size if ring).
    Quantizes the incoming K/V when the cache is int8."""
    if cache_k.dtype == jnp.int8:
        k_new, v_new = quantize_kv(k_new), quantize_kv(v_new)
    slot = pos if ring_size is None else pos % ring_size
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, axis=1)
    return ck, cv


def output_proj(p: dict, attn_out: jnp.ndarray) -> jnp.ndarray:
    out = jnp.einsum("bshe,hed->bsd", attn_out, p["wo"])
    return sh.shard(out, sh.BATCH, None, None)
