"""Shared building blocks: norms, MLPs, embeddings, rotary positions.

Parameters are plain dicts of jnp arrays; every init function is
shape-deterministic so the dry-run can ``eval_shape`` it without allocating.
Compute dtype is bf16 with f32 accumulation in norms/softmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as sh

DTYPE = jnp.bfloat16


def _normal(key, shape, scale, dtype=DTYPE):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# --- RMSNorm ---------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), DTYPE)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


# --- LayerNorm (Whisper) -----------------------------------------------------

def layernorm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), DTYPE), "bias": jnp.zeros((d,), DTYPE)}


def layernorm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"] + p["bias"]


# --- MLPs -------------------------------------------------------------------

def mlp_init(key, d: int, f: int, kind: str) -> dict:
    ks = jax.random.split(key, 3)
    scale_in, scale_out = d ** -0.5, f ** -0.5
    if kind == "swiglu":
        return {"w_gate": _normal(ks[0], (d, f), scale_in),
                "w_up": _normal(ks[1], (d, f), scale_in),
                "w_down": _normal(ks[2], (f, d), scale_out)}
    return {"w_up": _normal(ks[0], (d, f), scale_in),
            "b_up": jnp.zeros((f,), DTYPE),
            "w_down": _normal(ks[1], (f, d), scale_out),
            "b_down": jnp.zeros((d,), DTYPE)}


def mlp(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = sh.shard(h, sh.BATCH, None, sh.MODEL)
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"])
    h = sh.shard(h, sh.BATCH, None, sh.MODEL)
    return h @ p["w_down"] + p["b_down"]


# --- Embedding / LM head -----------------------------------------------------

def embed_init(key, vocab: int, d: int) -> dict:
    return {"embedding": _normal(key, (vocab, d), d ** -0.5)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    logits = x @ p["embedding"].T
    return sh.shard(logits, sh.BATCH, None, sh.MODEL)


# --- Rotary position embedding ----------------------------------------------

def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                       # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean next-token cross entropy; logits (B,S,V), labels (B,S).

    Written as fusable reductions over the (sharded) vocab axis: no f32
    logits materialization, and the gold-logit pick is a masked sum (a
    local reduce + tiny all-reduce) instead of take_along_axis (which would
    all-gather a vocab-sharded tensor).
    """
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    mx = jnp.max(lf, axis=-1)
    logz = mx + jnp.log(jnp.sum(jnp.exp(lf - mx[..., None]), axis=-1))
    onehot = (jnp.arange(v)[None, None, :] == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
