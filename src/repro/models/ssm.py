"""Mamba-2 (SSD — state-space duality) blocks, TPU-adapted.

The GPU reference implements SSD with a warp-level associative scan; the
TPU-native formulation is the *chunked block decomposition* (the paper's own
"matmul form"): within chunks of length Q the recurrence is a dense
(Q×Q)-masked matmul that maps onto the MXU, and across chunks a short
`lax.scan` carries the (H, d_state, head_dim) state.  The Pallas kernel in
``repro.kernels.ssd_scan`` tiles the same decomposition into VMEM.

Sharding: projections are stored *split* (z/x/dt head-sharded on the model
axis; the shared B/C projections replicated — they are (d_state,)-sized and
every head needs them), so the whole SSD scan is local per device and the
block needs exactly one all-reduce (the row-parallel out_proj), mirroring
the attention block's communication pattern.

Decode is the O(1) recurrence: h ← h·exp(Δ·A) + Δ·B⊗x, y = C·h + D·x.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import sharding as sh
from .dims import Dims
from .layers import DTYPE, _normal, rmsnorm, rmsnorm_init


def init(key, dims: Dims) -> dict:
    cfg = dims.cfg
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "z_proj": _normal(ks[0], (d, di), d ** -0.5),
        "x_proj": _normal(ks[1], (d, di), d ** -0.5),
        "b_proj": _normal(ks[2], (d, n), d ** -0.5),
        "c_proj": _normal(ks[3], (d, n), d ** -0.5),
        "dt_proj": _normal(ks[4], (d, h), d ** -0.5),
        "conv_x": _normal(ks[5], (cfg.ssm_conv, di), 0.3),
        "conv_bc": _normal(ks[6], (cfg.ssm_conv, 2 * n), 0.3),
        "conv_bias_x": jnp.zeros((di,), DTYPE),
        "conv_bias_bc": jnp.zeros((2 * n,), DTYPE),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gnorm": rmsnorm_init(di),
        "out_proj": _normal(ks[5], (di, d), di ** -0.5),
    }


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray):
    """seq: (B,S,C); w: (k,C) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:pad.shape[1] - (k - 1 - i)] * w[i] for i in range(k))
    return out + b


def _segsum(a):
    """a: (..., Q).  L[i,j] = Σ_{j<m<=i} a[m] for i ≥ j else -inf."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """SSD in chunked (matmul) form.

    x:  (B, S, H, P) values;  dt: (B, S, H) positive steps
    a_log: (H,) so A = -exp(a_log) < 0;  b, c: (B, S, N) shared (ngroups=1)
    Returns y: (B, S, H, P), final_state: (B, H, N, P).
    """
    bsz, s, h, p_ = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    nc = s // q
    assert s % q == 0, (s, q)

    a = (-jnp.exp(a_log))[None, None, :] * dt                  # (B,S,H) ≤ 0
    xc = x.reshape(bsz, nc, q, h, p_).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h)
    ac = a.reshape(bsz, nc, q, h)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    # ---- intra-chunk (dense, MXU): Y_ij = C_i·B_j · exp(Σa) · dt_j · X_j
    lmat = _segsum(jnp.moveaxis(ac, -1, -2))                   # (B,nc,H,Q,Q)
    lmat = jnp.exp(lmat)
    cb = jnp.einsum("bnqs,bnks->bnqk", cc, bc)                 # (B,nc,Q,Q)
    w = cb[:, :, None] * lmat                                  # (B,nc,H,Q,Q)
    y_intra = jnp.einsum("bnhqk,bnkh,bnkhp->bnqhp", w, dtc, xc)

    # ---- chunk states: S_c = Σ_j exp(a_end - a_j) dt_j B_j ⊗ X_j
    a_cum = jnp.cumsum(ac, axis=2)
    a_end = a_cum[:, :, -1:]                                   # (B,nc,1,H)
    decay_to_end = jnp.exp(a_end - a_cum)                      # (B,nc,Q,H)
    sc = jnp.einsum("bnqm,bnqh,bnqhp->bnhmp",
                    bc, dtc * decay_to_end, xc)                # (B,nc,H,N,P)

    # ---- inter-chunk recurrence over nc
    lam = jnp.exp(a_end[:, :, 0])                              # (B,nc,H)

    def step(state, inp):
        lam_c, sc_c = inp
        new = state * lam_c[..., None, None] + sc_c
        return new, state                                       # emit S_{c-1}

    init_s = jnp.zeros((bsz, h, n, p_), jnp.float32)
    final, s_prev = jax.lax.scan(
        step, init_s,
        (jnp.moveaxis(lam, 1, 0), jnp.moveaxis(sc, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                        # (B,nc,H,N,P)

    # ---- inter-chunk output: Y_i += C_i · S_prev · exp(a_cum_i)
    y_inter = jnp.einsum("bnqm,bnhmp->bnqhp", cc, s_prev) \
        * jnp.exp(a_cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p_)
    return y.astype(x.dtype), final


def ssd_reference(x, dt, a_log, b, c):
    """O(S) sequential-scan oracle for tests."""
    bsz, s, h, p_ = x.shape
    a = (-jnp.exp(a_log))[None, :]                              # (1,H)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        lam = jnp.exp(a * dtt)                                  # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bt, dtt, xt)
        state = state * lam[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    init_s = jnp.zeros((bsz, h, b.shape[-1], p_), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, init_s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


class SsmCache(NamedTuple):
    conv_x: jnp.ndarray   # (B, k-1, di)   head-sharded
    conv_bc: jnp.ndarray  # (B, k-1, 2N)   replicated
    state: jnp.ndarray    # (B, H, N, P)   head-sharded


def _project(p, cfg, x):
    """x: (..., D) -> z, xin, b, c, dt (pre-conv)."""
    z = x @ p["z_proj"]
    xin = x @ p["x_proj"]
    b = x @ p["b_proj"]
    c = x @ p["c_proj"]
    dt = x @ p["dt_proj"]
    return z, xin, b, c, dt


def block_apply(p: dict, dims: Dims, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence Mamba-2 block (training/prefill).  x: (B,S,D)."""
    cfg = dims.cfg
    z, xin, b, c, dt = _project(p, cfg, x)
    xin = sh.shard(xin, sh.BATCH, sh.SEQ, sh.MODEL)
    z = sh.shard(z, sh.BATCH, sh.SEQ, sh.MODEL)

    xin = jax.nn.silu(_causal_conv(xin, p["conv_x"], p["conv_bias_x"]))
    bc = jax.nn.silu(_causal_conv(jnp.concatenate([b, c], -1),
                                  p["conv_bc"], p["conv_bias_bc"]))
    n = cfg.ssm_state
    b, c = bc[..., :n], bc[..., n:]

    xh = xin.reshape(*x.shape[:2], cfg.ssm_heads, cfg.ssm_head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    y, _ = ssd_chunked(xh, dtp, p["a_log"], b, c, cfg.ssm_chunk)
    y = y + xh * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(*x.shape[:2], cfg.d_inner)
    y = rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    return sh.shard(out, sh.BATCH, sh.SEQ, None)


def init_ssm_cache(dims: Dims, batch: int) -> SsmCache:
    cfg = dims.cfg
    return SsmCache(
        conv_x=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), DTYPE),
        conv_bc=jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.ssm_state),
                          DTYPE),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                         cfg.ssm_head_dim), jnp.float32))


def block_decode(p: dict, dims: Dims, x: jnp.ndarray, cache: SsmCache):
    """One-token step.  x: (B,1,D) -> (B,1,D), new cache."""
    cfg = dims.cfg
    z, xin, b, c, dt = _project(p, cfg, x[:, 0])

    hist_x = jnp.concatenate([cache.conv_x, xin[:, None]], axis=1)
    hist_bc = jnp.concatenate(
        [cache.conv_bc, jnp.concatenate([b, c], -1)[:, None]], axis=1)
    conv_x = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist_x, p["conv_x"]) + p["conv_bias_x"])
    conv_bc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist_bc, p["conv_bc"]) + p["conv_bias_bc"])

    n = cfg.ssm_state
    bb = conv_bc[:, :n].astype(jnp.float32)
    cc = conv_bc[:, n:].astype(jnp.float32)
    xh = conv_x.reshape(-1, cfg.ssm_heads, cfg.ssm_head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)

    lam = jnp.exp((-jnp.exp(p["a_log"]))[None] * dtp)             # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", bb, dtp, xh.astype(jnp.float32))
    state = cache.state * lam[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cc, state).astype(x.dtype)
    y = y + xh * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(-1, cfg.d_inner)
    y = rmsnorm(p["gnorm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, SsmCache(conv_x=hist_x[:, 1:], conv_bc=hist_bc[:, 1:],
                         state=state)
