"""In-scan metric probes: the ``ObsSpec`` catalog and its carry registers.

``ObsSpec`` is a *static* frozen dataclass riding ``SimConfig.obs``
(default ``None``), hashable and therefore part of every jit cache key —
the same contract as ``SimConfig.faults``.  Each probe *family* gates its
own sub-carry of :class:`ObsCarry` behind a trace-time conditional, so
enabling the Kalman innovation probe never pays for histograms and a
``obs=None`` config compiles a step structurally identical to the
probe-free simulator (the kind="obs" bench gate pins this with a sha256
digest over the committed baselines).

The probe catalog (one fixed register set per family, all O(W·K) or
smaller, accumulated inside the scan carry):

  * ``aimd``      — additive-increase vs multiplicative-backoff tick
                    counts and the deepest acquisition fail-streak seen;
  * ``kalman``    — per-bank innovation sum / sum-of-squares, NIS sum and
                    update count (from ``core.kalman.probe``);
  * ``preempt``   — market preemptions and chaos hard-kills per instance
                    type;
  * ``fairshare`` — the eq. 13-14 water level (Σ and running min of the
                    multiplicative rescale), per-tenant admission rejects,
                    and queue-depth sum/max;
  * ``queue_hist``— a fixed-bin in-carry histogram of per-tick queue
                    depth, from which :func:`drain` reads percentiles;
  * ``ledger``    — the bounded decision ring (``obs.ledger``).

:func:`update` is the single carry-threading hook ``sim.runner`` calls
once per tick; :func:`drain` converts the final carry into a host-side
:class:`ObsReport` of plain numpy values, typed ledger records and a
``to_dataframe()``/``to_jsonl()`` exporter.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax.numpy as jnp

from . import detect as detect_lib
from . import ledger as ledger_lib


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Static probe selection; part of the jit cache key via SimConfig.

    Each flag enables one metric family (its registers join the scan
    carry and its update ops compile in); ``ledger`` is the decision-ring
    capacity, 0 = off.  The default enables the cheap counter families
    and leaves the histogram and the ledger off; ``ObsSpec.full()`` is
    the everything-on configuration benchmarks use for the overhead gate.
    """

    aimd: bool = True
    kalman: bool = True
    preempt: bool = True
    fairshare: bool = True
    queue_hist: bool = False
    queue_bins: int = 16
    ledger: int = 0
    detect: "detect_lib.DetectSpec | None" = None

    def __post_init__(self):
        if self.queue_bins < 1:
            raise ValueError(f"queue_bins must be >= 1, got {self.queue_bins}")
        if self.ledger < 0:
            raise ValueError(f"ledger capacity must be >= 0, got {self.ledger}")
        if not (self.aimd or self.kalman or self.preempt or self.fairshare
                or self.queue_hist or self.ledger
                or self.detect is not None):
            raise ValueError(
                "ObsSpec with every family off observes nothing — use "
                "SimConfig.obs=None for the probe-free program")

    @classmethod
    def full(cls, ledger: int = 256,
             detect: "bool | detect_lib.DetectSpec" = False) -> "ObsSpec":
        """Every probe family on — the overhead-gate configuration.
        ``detect=True`` adds the default detector catalog (``detect`` may
        also be a ready ``DetectSpec``)."""
        if detect is True:
            detect = detect_lib.DetectSpec()
        elif detect is False:
            detect = None
        return cls(aimd=True, kalman=True, preempt=True, fairshare=True,
                   queue_hist=True, ledger=ledger, detect=detect)

    # The ledger's transition detectors need the AIMD branch / water-level
    # signals even when the corresponding metric family is off, so the
    # emission hooks key on these.
    @property
    def want_aimd(self) -> bool:
        return self.aimd or self.ledger > 0

    @property
    def want_fairshare(self) -> bool:
        return self.fairshare

    @property
    def want_preempt(self) -> bool:
        # The detectors' disruption signal sums the same per-type
        # preemption/kill vectors the ledger events use.
        return self.preempt or self.ledger > 0 or self.detect is not None

    # The NIS band test consumes the per-bank Kalman innovation probe, so
    # the controller must emit it even when the metric family is off.
    @property
    def want_kalman(self) -> bool:
        return self.kalman or (self.detect is not None and self.detect.nis)


class AimdMetrics(NamedTuple):
    n_incr: jnp.ndarray      # () f32 ticks on the additive-increase branch
    n_backoff: jnp.ndarray   # () f32 ticks on the multiplicative branch
    streak_max: jnp.ndarray  # () f32 deepest acquisition fail-streak


class KalmanMetrics(NamedTuple):
    innov_sum: jnp.ndarray     # (W, K) Σ innovation
    innov_sq_sum: jnp.ndarray  # (W, K) Σ innovation²
    nis_sum: jnp.ndarray       # (W, K) Σ normalized innovation squared
    n_upd: jnp.ndarray         # (W, K) measurement updates absorbed


class PreemptMetrics(NamedTuple):
    preempt_by_type: jnp.ndarray  # (T,) market preemptions per type
    kill_by_type: jnp.ndarray     # (T,) chaos hard-kills per type


class FairshareMetrics(NamedTuple):
    water_sum: jnp.ndarray   # () Σ of the eq. 13-14 rescale factor
    water_min: jnp.ndarray   # () running min of that factor
    rejects: jnp.ndarray     # (N,) admission rejects per tenant
    queue_sum: jnp.ndarray   # () Σ active workloads per tick
    queue_max: jnp.ndarray   # () peak active workloads


class QueueHist(NamedTuple):
    counts: jnp.ndarray      # (bins,) int32 ticks per queue-depth bin


class ObsCarry(NamedTuple):
    """Per-run probe registers carried through the scan; every family is
    ``None`` when its ``ObsSpec`` flag is off, so the carry — and the
    compiled scan — only ever holds what was asked for."""

    aimd: AimdMetrics | None = None
    kalman: KalmanMetrics | None = None
    preempt: PreemptMetrics | None = None
    fair: FairshareMetrics | None = None
    qhist: QueueHist | None = None
    ledger: "ledger_lib.Ledger | None" = None
    detect: "detect_lib.DetectCarry | None" = None


def init_carry(spec: ObsSpec, *, w: int, k: int, n_types: int,
               n_tenants: int = 1) -> ObsCarry:
    z = jnp.asarray(0.0, jnp.float32)
    aimd = kalman = preempt = fair = qhist = led = det = None
    if spec.aimd:
        aimd = AimdMetrics(n_incr=z, n_backoff=z, streak_max=z)
    if spec.kalman:
        zwk = jnp.zeros((w, k), jnp.float32)
        kalman = KalmanMetrics(innov_sum=zwk, innov_sq_sum=zwk,
                               nis_sum=zwk, n_upd=zwk)
    if spec.preempt:
        zt = jnp.zeros((n_types,), jnp.float32)
        preempt = PreemptMetrics(preempt_by_type=zt, kill_by_type=zt)
    if spec.fairshare:
        fair = FairshareMetrics(
            water_sum=z, water_min=jnp.asarray(jnp.inf, jnp.float32),
            rejects=jnp.zeros((n_tenants,), jnp.float32),
            queue_sum=z, queue_max=z)
    if spec.queue_hist:
        qhist = QueueHist(counts=jnp.zeros((spec.queue_bins,), jnp.int32))
    if spec.ledger > 0:
        led = ledger_lib.init(spec.ledger)
    if spec.detect is not None:
        det = detect_lib.init(spec.detect, w=w, k=k)
    return ObsCarry(aimd=aimd, kalman=kalman, preempt=preempt, fair=fair,
                    qhist=qhist, ledger=led, detect=det)


class TickSignals(NamedTuple):
    """One tick's raw probe signals, assembled by the step function.

    Every field is optional: ``None`` means the signal does not exist
    under this configuration (no spot market → no preemptions, no chaos
    engine → no fail-streak, no tenants → no admission gate) and the
    corresponding register simply stays at its initial value.
    """

    aimd_incr: Any = None        # () bool  additive-increase branch taken
    water_scale: Any = None      # () f32   eq. 13-14 rescale factor
    kalman: Any = None           # core.kalman.KalmanProbe (innov/nis/upd)
    n_target: Any = None         # () f32   this tick's CU target
    preempt_by_type: Any = None  # (T,) f32 market preemptions
    kill_by_type: Any = None     # (T,) f32 chaos hard-kills
    adm_rejects: Any = None      # (N,) f32 per-tenant admission rejects
    queue_depth: Any = None      # () f32   active workloads after arrivals
    fail_streak: Any = None      # () f32   consecutive failed acquisitions
    n_shed: Any = None           # () f32   arrivals shed this tick
    spot_price: Any = None       # () f32   primary type's $/quantum
    viol_now: Any = None         # () f32   TTC violations judged this tick
    cost_delta: Any = None       # () f32   $ billed this tick (fleet)
    n_committed: Any = None      # () f32   booting+active CUs this tick
    n_unavail: Any = None        # () f32   instance types with no capacity


def update(oc: ObsCarry, spec: ObsSpec, t, sig: TickSignals, *,
           q_cap: int) -> ObsCarry:
    """One tick of register accumulation — the carry-threading hook.

    Purely read-only with respect to the simulation: every input is a
    value the step already computed, no PRNG is consumed, and nothing
    flows back, so enabling probes cannot perturb a run's results.
    ``q_cap`` is the (static) workload-row count the queue-depth
    histogram bins span.
    """
    aimd, kalman, preempt, fair, qhist, led, det = oc

    if spec.aimd:
        incr = sig.aimd_incr
        streak = (aimd.streak_max if sig.fail_streak is None
                  else jnp.maximum(aimd.streak_max, sig.fail_streak))
        aimd = AimdMetrics(
            n_incr=aimd.n_incr + incr.astype(jnp.float32),
            n_backoff=aimd.n_backoff + (~incr).astype(jnp.float32),
            streak_max=streak)

    if spec.kalman and sig.kalman is not None:
        kp = sig.kalman
        kalman = KalmanMetrics(
            innov_sum=kalman.innov_sum + kp.innov,
            innov_sq_sum=kalman.innov_sq_sum + kp.innov * kp.innov,
            nis_sum=kalman.nis_sum + kp.nis,
            n_upd=kalman.n_upd + kp.upd.astype(jnp.float32))

    if spec.preempt:
        pre = preempt.preempt_by_type
        kil = preempt.kill_by_type
        if sig.preempt_by_type is not None:
            pre = pre + sig.preempt_by_type
        if sig.kill_by_type is not None:
            kil = kil + sig.kill_by_type
        preempt = PreemptMetrics(preempt_by_type=pre, kill_by_type=kil)

    if spec.fairshare:
        rej = fair.rejects
        if sig.adm_rejects is not None:
            rej = rej + sig.adm_rejects
        fair = FairshareMetrics(
            water_sum=fair.water_sum + sig.water_scale,
            water_min=jnp.minimum(fair.water_min, sig.water_scale),
            rejects=rej,
            queue_sum=fair.queue_sum + sig.queue_depth,
            queue_max=jnp.maximum(fair.queue_max, sig.queue_depth))

    if spec.queue_hist:
        # Fixed bins over [0, q_cap] queue depth; integer arithmetic so
        # the bin index is exact for every representable depth.
        depth = sig.queue_depth.astype(jnp.int32)
        idx = jnp.clip((depth * spec.queue_bins) // (q_cap + 1),
                       0, spec.queue_bins - 1)
        qhist = QueueHist(counts=qhist.counts.at[idx].add(1))

    if spec.ledger > 0:
        incr = sig.aimd_incr
        streak = (jnp.asarray(0.0, jnp.float32) if sig.fail_streak is None
                  else sig.fail_streak)
        led = ledger_lib.push(
            led, led.prev_incr & ~incr, t, ledger_lib.KIND_AIMD_BACKOFF,
            sig.n_target)
        led = ledger_lib.push(
            led, (led.prev_streak <= 0.0) & (streak > 0.0), t,
            ledger_lib.KIND_BACKOFF_ENTER, streak)
        if sig.preempt_by_type is not None:
            n_pre = jnp.sum(sig.preempt_by_type)
            led = ledger_lib.push(led, n_pre > 0.0, t,
                                  ledger_lib.KIND_PREEMPT, n_pre)
        if sig.kill_by_type is not None:
            n_kill = jnp.sum(sig.kill_by_type)
            led = ledger_lib.push(led, n_kill > 0.0, t,
                                  ledger_lib.KIND_KILL, n_kill)
        if sig.adm_rejects is not None:
            n_rej = jnp.sum(sig.adm_rejects)
            led = ledger_lib.push(led, n_rej > 0.0, t,
                                  ledger_lib.KIND_ADM_REJECT, n_rej,
                                  tenant=jnp.argmax(sig.adm_rejects)
                                  .astype(jnp.int32))
        if sig.n_shed is not None:
            led = ledger_lib.push(led, sig.n_shed > 0.0, t,
                                  ledger_lib.KIND_SHED, sig.n_shed)
        led = led._replace(prev_incr=incr, prev_streak=streak)

    if spec.detect is not None:
        # Monitored-signal vector, detect.SIGNAL_NAMES order; a plane
        # that does not exist under this config reads as a constant 0.
        z = jnp.asarray(0.0, jnp.float32)
        # Capacity gap: the target the scaler asked for minus what the
        # market actually committed — the shortfall signal a gracefully
        # absorbed outage still cannot hide (see detect module doc).
        gap = (z if (sig.n_target is None or sig.n_committed is None)
               else jnp.maximum(
                   0.0, jnp.asarray(sig.n_target - sig.n_committed,
                                    jnp.float32)))
        disrupt = z
        if sig.preempt_by_type is not None:
            disrupt = disrupt + jnp.sum(sig.preempt_by_type)
        if sig.kill_by_type is not None:
            disrupt = disrupt + jnp.sum(sig.kill_by_type)
        sigs = jnp.stack([
            z if sig.queue_depth is None else jnp.asarray(
                sig.queue_depth, jnp.float32),
            z if sig.spot_price is None else jnp.asarray(
                sig.spot_price, jnp.float32),
            z if sig.viol_now is None else jnp.asarray(
                sig.viol_now, jnp.float32),
            z if sig.fail_streak is None else jnp.asarray(
                sig.fail_streak, jnp.float32),
            gap,
            disrupt,
            z if sig.n_unavail is None else jnp.asarray(
                sig.n_unavail, jnp.float32),
        ])
        det, led = detect_lib.update(
            det, spec.detect, t, signals=sigs, kalman=sig.kalman,
            cost_delta=sig.cost_delta, led=led)

    return ObsCarry(aimd=aimd, kalman=kalman, preempt=preempt, fair=fair,
                    qhist=qhist, ledger=led, detect=det)


# --------------------------------------------------------------------------
# Host-side drain.

def hist_percentile(counts, q: float, q_cap: int) -> float:
    """Percentile ``q`` in [0, 1] of the binned queue-depth distribution
    (bin-midpoint convention; NaN for an empty histogram)."""
    import numpy as np

    counts = np.asarray(counts, np.float64)
    total = counts.sum()
    if total <= 0:
        return float("nan")
    bins = counts.shape[0]
    cdf = np.cumsum(counts)
    idx = int(np.searchsorted(cdf, q * total, side="left"))
    idx = min(idx, bins - 1)
    width = (q_cap + 1) / bins
    return (idx + 0.5) * width


@dataclasses.dataclass
class ObsReport:
    """A run's drained observability state, host-side numpy throughout."""

    spec: ObsSpec | None
    counters: dict                       # scalar gauges/counters by name
    kalman: dict | None                  # per-bank arrays + fleet scalars
    preempt_by_type: Any | None          # (T,) numpy
    kill_by_type: Any | None             # (T,) numpy
    rejects: Any | None                  # (N,) numpy
    queue_hist: Any | None               # (bins,) numpy
    queue_percentiles: dict | None       # {0.5/0.9/0.99: depth}
    ledger: list                         # [LedgerRecord] chronological
    ledger_dropped: int                  # exact overwritten-event count
    detect: dict | None = None           # alert counts/first-ticks/stats

    def to_dataframe(self):
        """Ledger records as a pandas DataFrame.

        pandas is an *optional* dependency: without it this raises a
        clear ImportError naming it — use :meth:`to_jsonl` or iterate
        ``report.ledger`` for the dependency-free paths.
        """
        try:
            import pandas as pd
        except ImportError as e:
            raise ImportError(
                "ObsReport.to_dataframe() needs the optional dependency "
                "'pandas', which is not installed — use to_jsonl() or the "
                "report.ledger record list instead") from e
        return pd.DataFrame(
            [r.to_dict() for r in self.ledger],
            columns=["tick", "kind", "kind_name", "tenant", "value",
                     "severity"])

    def to_jsonl(self, path) -> None:
        from . import export
        export.report_jsonl(self, path)


def drain(oc: ObsCarry, spec: ObsSpec, *, q_cap: int) -> ObsReport:
    """Convert the final scan carry's probe registers to an ObsReport."""
    import numpy as np

    counters: dict = {}
    kalman = preempt_t = kill_t = rejects = qh = qp = None

    if spec.aimd:
        counters["aimd_incr_ticks"] = float(oc.aimd.n_incr)
        counters["aimd_backoff_ticks"] = float(oc.aimd.n_backoff)
        counters["fail_streak_max"] = float(oc.aimd.streak_max)
    if spec.kalman:
        n_upd = np.asarray(oc.kalman.n_upd, np.float64)
        innov = np.asarray(oc.kalman.innov_sum, np.float64)
        innov_sq = np.asarray(oc.kalman.innov_sq_sum, np.float64)
        nis = np.asarray(oc.kalman.nis_sum, np.float64)
        safe = np.maximum(n_upd, 1.0)
        kalman = dict(
            n_upd=n_upd,
            innov_mean=np.where(n_upd > 0, innov / safe, np.nan),
            innov_rms=np.where(n_upd > 0, np.sqrt(innov_sq / safe), np.nan),
            nis_mean=np.where(n_upd > 0, nis / safe, np.nan),
        )
        tot = n_upd.sum()
        counters["kalman_updates"] = float(tot)
        counters["kalman_nis_mean"] = (
            float(nis.sum() / tot) if tot > 0 else float("nan"))
    if spec.preempt:
        preempt_t = np.asarray(oc.preempt.preempt_by_type)
        kill_t = np.asarray(oc.preempt.kill_by_type)
        counters["preemptions"] = float(preempt_t.sum())
        counters["hard_kills"] = float(kill_t.sum())
    if spec.fairshare:
        rejects = np.asarray(oc.fair.rejects)
        wmin = float(oc.fair.water_min)
        counters["water_sum"] = float(oc.fair.water_sum)
        counters["water_min"] = wmin if math.isfinite(wmin) else float("nan")
        counters["adm_rejects"] = float(rejects.sum())
        counters["queue_depth_sum"] = float(oc.fair.queue_sum)
        counters["queue_depth_max"] = float(oc.fair.queue_max)
    if spec.queue_hist:
        qh = np.asarray(oc.qhist.counts)
        qp = {q: hist_percentile(qh, q, q_cap) for q in (0.5, 0.9, 0.99)}

    recs: list = []
    dropped = 0
    if spec.ledger > 0:
        recs, dropped = ledger_lib.drain(oc.ledger)
        counters["ledger_events"] = float(len(recs) + dropped)
        counters["ledger_dropped"] = float(dropped)

    det = None
    if spec.detect is not None:
        det = detect_lib.drain(oc.detect, spec.detect)
        counters["alerts_total"] = det["alerts_total"]
        for name, n in det["alerts_by_family"].items():
            counters[f"alerts_{name}"] = n

    return ObsReport(spec=spec, counters=counters, kalman=kalman,
                     preempt_by_type=preempt_t, kill_by_type=kill_t,
                     rejects=rejects, queue_hist=qh, queue_percentiles=qp,
                     ledger=recs, ledger_dropped=dropped, detect=det)
