"""OpenMetrics exposition and live sweep tailing.

Two host-side read paths over artifacts the jitted code already produces
— nothing here touches the scan:

  * :func:`to_openmetrics` renders an :class:`~repro.obs.probes.ObsReport`
    as OpenMetrics text (the Prometheus exposition format): every probe
    counter becomes a gauge, detector alert counts/first-ticks get
    ``family`` labels, ledger events are bucketed by ``kind``.  Write it
    behind any HTTP handler — or just to a file a node exporter scrapes —
    and a standard dashboard stack reads the simulator like production
    infrastructure.
  * :func:`watch` tails a *streamed sweep directory* while (or after) the
    executor runs: the manifest gives the chunk plan, the atomic
    ``step_<i>.done`` markers give progress and an ETA, and the chunk
    files' per-field ``.npy`` leaves give running violation/alert totals
    — all without loading whole chunks or knowing the summary pytree,
    so a live sweep can be monitored from a second process with nothing
    but the directory path.

Pure stdlib + numpy; safe to import where no jax runtime exists.
"""

from __future__ import annotations

import json
import os
import re
import time
from typing import Callable

_MANIFEST = "sweep_manifest.json"

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _metric(name: str, prefix: str) -> str:
    name = _NAME_OK.sub("_", f"{prefix}_{name}")
    return name if re.match(r"[a-zA-Z_:]", name) else f"_{name}"


def _fmt(value) -> str:
    v = float(value)
    return str(int(v)) if v == int(v) and abs(v) < 1e15 else repr(v)


def to_openmetrics(report, prefix: str = "repro") -> str:
    """Render an ObsReport as OpenMetrics text exposition.

    Scalar probe counters map to gauges named ``<prefix>_<counter>``;
    detector alerts expose ``<prefix>_alerts_total`` plus per-``family``
    labelled counts and first-firing ticks; ledger events are counted per
    ``kind`` label.  Ends with the mandatory ``# EOF`` terminator.
    """
    lines: list[str] = []

    def gauge(name: str, value, labels: dict | None = None,
              help_: str | None = None) -> None:
        m = _metric(name, prefix)
        if help_ is not None:
            lines.append(f"# HELP {m} {help_}")
            lines.append(f"# TYPE {m} gauge")
        if labels:
            lab = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
            lines.append(f"{m}{{{lab}}} {_fmt(value)}")
        else:
            lines.append(f"{m} {_fmt(value)}")

    for name in sorted(report.counters):
        # The drained report mirrors ledger/alert totals into counters;
        # the labelled sections below are their canonical exposition —
        # emitting both would duplicate metric families.
        if name.startswith(("ledger_", "alerts_")):
            continue
        gauge(name, report.counters[name],
              help_=f"probe counter {name}")

    if report.queue_percentiles:
        first = True
        for q in sorted(report.queue_percentiles):
            m = _metric("queue_depth", prefix)
            if first:
                lines.append(f"# HELP {m} queue depth percentile")
                lines.append(f"# TYPE {m} gauge")
                first = False
            lines.append(
                f'{m}{{quantile="{q}"}} '
                f"{_fmt(report.queue_percentiles[q])}")

    kinds: dict[str, int] = {}
    for rec in report.ledger:
        kinds[rec.kind_name] = kinds.get(rec.kind_name, 0) + 1
    if report.ledger or report.ledger_dropped:
        first = True
        for kind in sorted(kinds):
            m = _metric("ledger_events", prefix)
            if first:
                lines.append(f"# HELP {m} decision-ledger events by kind")
                lines.append(f"# TYPE {m} gauge")
                first = False
            lines.append(f'{m}{{kind="{kind}"}} {kinds[kind]}')
        gauge("ledger_dropped", report.ledger_dropped,
              help_="ledger events overwritten by ring overflow")

    det = report.detect
    if det is not None:
        gauge("alerts_total", det["alerts_total"],
              help_="detector alerts fired, all families")
        first = True
        for fam in sorted(det["alerts_by_family"]):
            m = _metric("alerts", prefix)
            if first:
                lines.append(f"# HELP {m} detector alerts by family")
                lines.append(f"# TYPE {m} gauge")
                first = False
            lines.append(
                f'{m}{{family="{fam}"}} '
                f"{_fmt(det['alerts_by_family'][fam])}")
        first = True
        for fam in sorted(det["first_tick_by_family"]):
            m = _metric("alert_first_tick", prefix)
            if first:
                lines.append(f"# HELP {m} first firing tick per family "
                             "(-1 = never fired)")
                lines.append(f"# TYPE {m} gauge")
                first = False
            lines.append(
                f'{m}{{family="{fam}"}} '
                f"{det['first_tick_by_family'][fam]}")

    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_openmetrics(report, path, prefix: str = "repro") -> None:
    """Atomic file form of :func:`to_openmetrics` (scrape-safe)."""
    text = to_openmetrics(report, prefix=prefix)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


def _chunk_leaf_sums(step_dir: str, leaves: dict,
                     names: tuple[str, ...]) -> dict[str, float]:
    """Sum the named 1-d leaf files of one committed chunk (missing
    leaves — e.g. ``alerts`` on a detector-free sweep — read as absent)."""
    import numpy as np

    out = {}
    for name in names:
        meta = leaves.get(name)
        if meta is None:
            continue
        try:
            out[name] = float(
                np.load(os.path.join(step_dir, meta["file"])).sum())
        except (OSError, ValueError):
            continue
    return out


def snapshot(stream_dir: str,
             leaf_names: tuple[str, ...] = ("violations", "alerts",
                                            "preemptions")) -> dict:
    """One observation of a streamed sweep directory.

    Returns progress (chunks/rows done), throughput and ETA derived from
    the ``.done`` commit-marker mtimes, and running totals of the named
    summary leaves over every committed chunk.
    """
    with open(os.path.join(stream_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    n_chunks = int(manifest["n_chunks"])
    n_points = int(manifest["n_points"])
    chunk = int(manifest["chunk"])

    done: list[int] = []
    mtimes: list[float] = []
    for name in os.listdir(stream_dir):
        if name.startswith("step_") and name.endswith(".done"):
            i = int(name[len("step_"):-len(".done")])
            if i < n_chunks:
                done.append(i)
                mtimes.append(os.path.getmtime(os.path.join(stream_dir,
                                                            name)))
    done.sort()
    rows_done = sum(min(chunk, n_points - i * chunk) for i in done)

    rate = eta_s = None
    if len(mtimes) >= 2:
        span = max(mtimes) - min(mtimes)
        if span > 0:
            rate = (len(mtimes) - 1) / span          # chunks per second
            eta_s = (n_chunks - len(done)) / rate

    totals: dict[str, float] = {}
    for i in done:
        step_dir = os.path.join(stream_dir, f"step_{i:08d}")
        try:
            with open(os.path.join(step_dir, "manifest.json")) as f:
                leaves = json.load(f)["leaves"]
        except (OSError, ValueError, KeyError):
            continue
        for name, v in _chunk_leaf_sums(step_dir, leaves,
                                        leaf_names).items():
            totals[name] = totals.get(name, 0.0) + v

    return {
        "n_chunks": n_chunks,
        "n_points": n_points,
        "chunks_done": len(done),
        "rows_done": rows_done,
        "complete": len(done) >= n_chunks,
        "progress": len(done) / max(n_chunks, 1),
        "chunks_per_s": rate,
        "eta_s": eta_s,
        "totals": totals,
    }


def format_snapshot(s: dict) -> str:
    eta = "--" if s["eta_s"] is None else f"{s['eta_s']:.0f}s"
    totals = " ".join(f"{k}={int(v)}" for k, v in sorted(s["totals"].items()))
    return (f"[{s['chunks_done']}/{s['n_chunks']} chunks] "
            f"{s['rows_done']}/{s['n_points']} runs "
            f"({100.0 * s['progress']:.0f}%) eta={eta}"
            + (f" {totals}" if totals else ""))


def watch(stream_dir: str, interval: float = 2.0,
          emit: Callable[[str], None] = print,
          max_updates: int | None = None) -> dict:
    """Live-tail a streamed sweep: emit one progress line per interval
    until every chunk is committed (or ``max_updates`` observations have
    been made — the bound tests and impatient callers use).  Returns the
    final snapshot.  Point it at a directory another process is writing;
    only the manifest, commit markers and leaf files are read, so the
    tail never races the executor's atomic renames.
    """
    n = 0
    while True:
        s = snapshot(stream_dir)
        emit(format_snapshot(s))
        n += 1
        if s["complete"] or (max_updates is not None and n >= max_updates):
            return s
        time.sleep(interval)
