"""Exporters: JSONL records and Chrome/Perfetto ``trace_event`` JSON.

Two renderers over the observability planes:

  * :func:`run_trace_events` — a drained run's decision ledger as instant
    events on named tracks (one thread per event kind), plus a metadata
    header, so a single run's control-plane story opens in
    ``chrome://tracing`` / https://ui.perfetto.dev;
  * :func:`sweep_trace_events` — a sweep's per-chunk profile (from
    ``sim.sweep.SweepReport`` or a stream manifest's ``profile`` list) as
    one complete-event span per chunk whose args carry the
    compile/execute/write split and the XLA peak-bytes estimate.

Both emit plain lists of ``trace_event`` dicts; :func:`write_trace` wraps
them in the ``{"traceEvents": [...]}`` envelope trace viewers expect.
Timestamps are microseconds (the format's unit): run events use
``tick * dt`` seconds of simulated time, sweep spans use wall-clock
offsets from the first chunk.
"""

from __future__ import annotations

import json

_US = 1e6  # trace_event timestamps are microseconds


def _meta(pid: int, tid: int, name: str) -> dict:
    return {"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


_SEVERITY_NAMES = {0: "info", 1: "warn", 2: "page"}


def _subject_name(kind: int, subject: int) -> str | None:
    """Resolve an alert record's tenant column to its subject label: the
    monitored-signal name for CUSUM/EWMA, the burn window name, the
    flattened Kalman bank for the NIS band test.  ``None`` for non-alert
    kinds (there the column really is a tenant id)."""
    from . import detect as detect_lib
    from . import ledger as ledger_lib

    if kind in (ledger_lib.KIND_ALERT_CUSUM, ledger_lib.KIND_ALERT_EWMA):
        if 0 <= subject < len(detect_lib.SIGNAL_NAMES):
            return detect_lib.SIGNAL_NAMES[subject]
    elif kind == ledger_lib.KIND_ALERT_BURN:
        if 0 <= subject < len(detect_lib.BURN_NAMES):
            return detect_lib.BURN_NAMES[subject]
    elif kind == ledger_lib.KIND_ALERT_NIS:
        return f"bank_{subject}"
    return None


def _track(kind: int, tenant: int, kind_name: str) -> tuple[int, str]:
    """The (tid, thread label) a record renders on: fleet-level events
    share the per-kind track (tid = kind code); tenant- or
    subject-scoped events each get their own labelled sub-track so the
    viewer separates ``alert_cusum/market_unavail`` from
    ``alert_cusum/spot_price`` and tenant 0's rejects from tenant 3's."""
    subject = _subject_name(kind, tenant)
    if subject is not None:
        return kind * 1000 + tenant + 1, f"{kind_name}/{subject}"
    if tenant is not None and tenant >= 0:
        return kind * 1000 + tenant + 1, f"{kind_name}/tenant{tenant}"
    return kind, kind_name


def run_trace_events(report, dt: float = 1.0, pid: int = 1) -> list[dict]:
    """A drained :class:`~repro.obs.probes.ObsReport` as trace events.

    Each ledger kind gets its own track, and tenant- or subject-scoped
    records (admission rejects per tenant, detector alerts per monitored
    signal / burn window / Kalman bank) fan out onto labelled sub-tracks
    — so the Perfetto timeline reads ``alert_burn/unavail`` next to
    ``alert_cusum/market_unavail``.  Every record becomes an instant
    event at its tick's simulated time, args carrying the value, tenant,
    resolved subject and severity.  The report's scalar counters ride a
    process metadata event so they show up in the viewer's process pane.
    """
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": "sim-run"}},
        {"name": "counters", "ph": "M", "pid": pid, "tid": 0,
         "args": {k: v for k, v in report.counters.items()}},
    ]
    tracks: dict[int, str] = {}
    for rec in report.ledger:
        tid, label = _track(rec.kind, rec.tenant, rec.kind_name)
        tracks.setdefault(tid, label)
    for tid in sorted(tracks):
        events.append(_meta(pid, tid, tracks[tid]))
    for rec in report.ledger:
        tid, _ = _track(rec.kind, rec.tenant, rec.kind_name)
        args = {"value": rec.value, "tenant": rec.tenant,
                "severity": _SEVERITY_NAMES.get(rec.severity,
                                                str(rec.severity))}
        subject = _subject_name(rec.kind, rec.tenant)
        if subject is not None:
            args["subject"] = subject
        events.append({
            "name": rec.kind_name, "ph": "i", "s": "t",
            "pid": pid, "tid": tid,
            "ts": rec.tick * dt * _US,
            "args": args,
        })
    return events


def _chunk_field(chunk, name, default=None):
    """Read a field off a ChunkProfile dataclass or a manifest dict."""
    if isinstance(chunk, dict):
        return chunk.get(name, default)
    return getattr(chunk, name, default)


def sweep_trace_events(chunks, pid: int = 1) -> list[dict]:
    """Per-chunk sweep profile as one complete-event span per chunk.

    ``chunks`` is ``SweepReport.chunks`` (ChunkProfile dataclasses) or a
    stream manifest's ``profile`` list (plain dicts).  Chunks are laid
    end-to-end on one wall-clock axis: each span's duration is its
    compile + execute + write time and its args carry the split plus the
    XLA ``memory_analysis`` peak-bytes estimate.  Resumed chunks (loaded
    from a previous run's committed files) appear as zero-length spans
    flagged ``resumed``.
    """
    events: list[dict] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": "sweep"}},
        _meta(pid, 0, "chunks"),
    ]
    ts = 0.0
    for chunk in chunks:
        idx = _chunk_field(chunk, "chunk", 0)
        compile_s = float(_chunk_field(chunk, "compile_s", 0.0) or 0.0)
        execute_s = float(_chunk_field(chunk, "execute_s", 0.0) or 0.0)
        write_s = float(_chunk_field(chunk, "write_s", 0.0) or 0.0)
        dur = (compile_s + execute_s + write_s) * _US
        events.append({
            "name": f"chunk {idx}", "ph": "X", "pid": pid, "tid": 0,
            "ts": ts, "dur": dur,
            "args": {
                "rows": _chunk_field(chunk, "rows"),
                "compile_s": compile_s,
                "execute_s": execute_s,
                "write_s": write_s,
                "peak_bytes": _chunk_field(chunk, "peak_bytes"),
                "resumed": bool(_chunk_field(chunk, "resumed", False)),
            },
        })
        ts += dur
    return events


def write_trace(path, events: list[dict]) -> None:
    """Write events in the ``{"traceEvents": [...]}`` envelope."""
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)


def report_jsonl(report, path) -> None:
    """One JSON object per line: a ``counters`` header, then every ledger
    record in chronological order — greppable, streamable, schema-stable."""
    with open(path, "w") as f:
        header = {"record": "counters", **report.counters,
                  "ledger_dropped": report.ledger_dropped}
        f.write(json.dumps(header) + "\n")
        for rec in report.ledger:
            f.write(json.dumps({"record": "event", **rec.to_dict()}) + "\n")
