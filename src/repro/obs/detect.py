"""In-scan anomaly detectors: the active half of the observability stack.

Where ``probes`` *records*, this module *judges*: a ``DetectSpec`` riding
``ObsSpec.detect`` (default ``None`` — the detector-free program, digest-
pinned like every other probe family) compiles a set of online statistical
tests into the scan, each firing fixed-shape alert events with severity
into the decision ledger:

  * **CUSUM** — two-sided tabular CUSUM over each monitored signal's
    standardized residual against a slow exponentially-weighted baseline
    (mean + variance learned online, armed after ``warmup`` ticks).
    Catches small-but-sustained mean shifts; the statistic resets on
    alarm so one regime change fires one event, not a storm.
  * **EWMA** — an exponentially-weighted moving average of the same
    standardized residual with ±``ewma_L``·σ_ewma control limits
    (σ_ewma = √(α/(2−α)), the stationary EWMA sd under unit-variance
    noise).  Catches faster drifts than CUSUM's slack lets through.
  * **NIS band** (model-mismatch alarm) — the per-bank Kalman innovation
    probes accumulate normalized innovation squared over
    ``nis_window``-tick windows and the fleet window mean is tested
    against the run's own learned NIS level (a geometric EW baseline:
    the sim's multiplicative lognormal measurement noise makes raw NIS
    heavy-tailed and workload-phase-dependent, so the level is learned
    in the log domain).  The band is two-sided: the high edge is
    ``base × max(nis_ratio, WH_hi)`` and the low edge
    ``base × min(1/nis_ratio, WH_lo)``, where WH is the Wilson–Hilferty
    χ²(n) ``nis_z``-sigma band a *consistent* unit-χ² filter would obey
    — for well-modeled filters the χ² band binds, for this sim's
    mismatched one the wide ratio band does, and either way a window
    outside it means the filter's error model newly stopped matching
    reality (high = innovation blow-up, low = covariance over-inflation,
    e.g. sustained telemetry dropouts).  The alert's subject column
    carries the worst (w·K + k) bank.
  * **SLO burn rate** — multi-window error-budget tracking à la SRE
    practice: violation, disruption (preemptions + hard-kills, an error
    budget a mean-shift test cannot see because each event is a sparse
    single-tick blip), market availability (unavailable-type count — a
    market that *ramps* into a dried-up regime from t=0 never presents
    a change-point, but steadily burns this budget) and optionally
    spend rates over a fast and a slow ring-buffered window, compared
    against the budget rates ``slo_viol_per_tick`` /
    ``slo_disrupt_per_tick`` / ``slo_unavail_per_tick`` /
    ``slo_cost_per_tick``.  Both windows over ``burn_page_mult`` ×
    budget pages (severity 2); the slow window alone over
    ``burn_warn_mult`` × budget warns (severity 1); events fire on
    level *transitions* only.

Monitored signals (``SIGNAL_NAMES`` order — the subject id CUSUM/EWMA
alerts carry): queue depth (first-differenced: arrival/completion balance
is the stationary quantity, the level ramps through every normal run),
spot price, the per-tick TTC-violation count (completion-time judgments;
never-finished work is only judged at the horizon), the acquisition
fail-streak (zero on every healthy tick), the capacity gap
(relu(n_target − committed) — the control plane asking for capacity the
market will not deliver, which is how a *gracefully absorbed* outage
shows up when hardened backoff keeps every other signal flat), the
disruption count (market preemptions + chaos hard-kills per tick), and
the market-unavailability count (instance types currently selling no
capacity — what hedged acquisition observes as per-type API failures;
sustained dry-ups are invisible to every fleet-level signal precisely
*because* hedging routes around them, but not to this one).

Everything is fixed-shape jnp: the registers ride :class:`DetectCarry`
inside ``ObsCarry``, updates are `where`-gated, no PRNG is drawn and
nothing feeds back into the simulation — enabling detectors keeps every
run bit-identical (the detect=None digest gate in ``bench_obs`` pins the
compiled-out program, and the calibration gates pin zero alerts on clean
runs / ≥1 in-window alert per committed chaos scenario).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from . import ledger as ledger_lib

SIGNAL_NAMES = ("queue_depth", "spot_price", "viol_rate", "fail_streak",
                "capacity_gap", "disruption", "market_unavail")
N_SIGNALS = len(SIGNAL_NAMES)
# Which signals are first-differenced before detection (see module doc).
DIFFERENCED = (True, False, False, False, False, False, False)

FAMILY_NAMES = ("cusum", "ewma", "nis", "burn")
N_FAMILIES = len(FAMILY_NAMES)
# FAMILY_NAMES[i] fires ledger kind FAMILY_KINDS[i].
FAMILY_KINDS = ledger_lib.ALERT_KINDS

# Burn-rate window subjects (the alert's tenant column).
BURN_VIOL, BURN_COST, BURN_DISRUPT, BURN_UNAVAIL = 0, 1, 2, 3
BURN_NAMES = ("viol", "cost", "disrupt", "unavail")
N_BURN = len(BURN_NAMES)


@dataclasses.dataclass(frozen=True)
class DetectSpec:
    """Static detector selection + thresholds; hashable, rides
    ``ObsSpec.detect`` and therefore every jit cache key.

    Defaults are calibrated against the committed benchmark worlds: zero
    alerts on the clean (spike-free) paper replay and the fault-free
    chaos-scenario markets, at least one in-window alert under every
    committed chaos scenario (``benchmarks/bench_obs.py`` gates both).
    """

    cusum: bool = True
    ewma: bool = True
    nis: bool = True
    burn: bool = True

    # Shared baseline: slow EW mean/variance of each signal, armed after
    # ``warmup`` ticks.  ``sigma_floor`` (per SIGNAL_NAMES) and
    # ``sigma_rel`` (fraction of |mean|) bound the standardization scale
    # from below so near-constant clean signals cannot make noise look
    # like a 100σ shift.
    warmup: int = 12
    baseline_alpha: float = 0.05
    sigma_rel: float = 0.05
    sigma_floor: tuple = (2.0, 0.02, 1.0, 1.0, 1.0, 1.0, 1.0)
    # Baseline updates are Winsorized: residuals are clipped to
    # ±winsor_z·σ before feeding the EW mean/variance, so an
    # out-of-control excursion cannot teach the baseline to accept it
    # (unclipped, a large sustained shift inflates the learned variance
    # faster than the CUSUM accumulates and the alarm never lands).
    winsor_z: float = 4.0

    # CUSUM: slack and alarm threshold, in σ units.
    cusum_k: float = 1.0
    cusum_h: float = 12.0

    # EWMA: smoothing and control-limit width (in σ_ewma units).
    ewma_alpha: float = 0.2
    ewma_L: float = 8.0

    # NIS band test.  ``nis_ratio`` widens the χ² band to a minimum
    # multiplicative margin around the learned level — clean windows of
    # this sim differ by up to ~7× from the learned base (lognormal
    # measurement noise), so the default keeps ~9× headroom while a
    # genuine filter breakdown (orders of magnitude) still lands outside.
    nis_window: int = 16
    nis_z: float = 6.0
    nis_ratio: float = 64.0
    nis_alpha: float = 0.25
    nis_min_updates: int = 8
    nis_warmup_windows: int = 1

    # Burn-rate windows (ticks) and thresholds (multiples of budget).
    burn_fast: int = 8
    burn_slow: int = 32
    burn_page_mult: float = 8.0
    burn_warn_mult: float = 4.0
    slo_viol_per_tick: float = 0.05
    slo_disrupt_per_tick: float = 0.01  # 0 = disruption window off
    slo_unavail_per_tick: float = 0.5   # 0 = availability window off
    slo_cost_per_tick: float = 0.0      # 0 = spend window not tracked

    def __post_init__(self):
        if not (self.cusum or self.ewma or self.nis or self.burn):
            raise ValueError(
                "DetectSpec with every detector off detects nothing — use "
                "ObsSpec.detect=None for the detector-free program")
        if len(self.sigma_floor) != N_SIGNALS:
            raise ValueError(
                f"sigma_floor needs one entry per monitored signal "
                f"({N_SIGNALS}), got {len(self.sigma_floor)}")
        if not isinstance(self.sigma_floor, tuple):
            raise ValueError("sigma_floor must be a tuple (hashability)")
        if self.warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {self.warmup}")
        if self.winsor_z <= 0.0:
            raise ValueError("winsor_z must be > 0")
        if not 0.0 < self.baseline_alpha <= 1.0:
            raise ValueError("baseline_alpha must be in (0, 1]")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.cusum_h <= self.cusum_k:
            raise ValueError("cusum_h must exceed the slack cusum_k")
        if self.nis_window < 1 or self.nis_min_updates < 1:
            raise ValueError("nis_window / nis_min_updates must be >= 1")
        if self.nis_ratio <= 1.0:
            raise ValueError("nis_ratio must exceed 1 (a multiplicative "
                             "band narrower than the level is always out)")
        if not 0.0 < self.nis_alpha <= 1.0:
            raise ValueError("nis_alpha must be in (0, 1]")
        if not 0 < self.burn_fast < self.burn_slow:
            raise ValueError(
                f"need 0 < burn_fast < burn_slow, got "
                f"{self.burn_fast} / {self.burn_slow}")
        if self.slo_viol_per_tick <= 0.0:
            raise ValueError("slo_viol_per_tick must be > 0")
        if self.burn_warn_mult > self.burn_page_mult:
            raise ValueError("burn_warn_mult must not exceed burn_page_mult")


class DetectCarry(NamedTuple):
    """Detector registers; one fixed-shape block inside ``ObsCarry``."""

    prev_raw: jnp.ndarray    # (S,) last raw values (differencing memory)
    mu: jnp.ndarray          # (S,) EW baseline mean of the detected signal
    var: jnp.ndarray         # (S,) EW baseline variance
    s_pos: jnp.ndarray       # (S,) upper CUSUM statistic
    s_neg: jnp.ndarray       # (S,) lower CUSUM statistic
    ewma: jnp.ndarray        # (S,) EWMA of the standardized residual
    n_seen: jnp.ndarray      # ()   ticks absorbed (warmup clock)
    nis_sum: jnp.ndarray     # (W, K) window NIS sum per bank
    nis_cnt: jnp.ndarray     # (W, K) window update count per bank
    nis_base: jnp.ndarray    # ()   EW baseline of fleet window-mean NIS
    nis_nwin: jnp.ndarray    # ()   windows absorbed into the baseline
    viol_ring: jnp.ndarray   # (burn_slow,) per-tick violation counts
    cost_ring: jnp.ndarray   # (burn_slow,) per-tick spend deltas
    dis_ring: jnp.ndarray    # (burn_slow,) per-tick disruption counts
    viol_fast: jnp.ndarray   # ()   running fast-window violation sum
    viol_slow: jnp.ndarray   # ()   running slow-window violation sum
    cost_fast: jnp.ndarray   # ()   running fast-window spend sum
    cost_slow: jnp.ndarray   # ()   running slow-window spend sum
    dis_fast: jnp.ndarray    # ()   running fast-window disruption sum
    dis_slow: jnp.ndarray    # ()   running slow-window disruption sum
    una_ring: jnp.ndarray    # (burn_slow,) per-tick unavailable-type counts
    una_fast: jnp.ndarray    # ()   running fast-window unavailability sum
    una_slow: jnp.ndarray    # ()   running slow-window unavailability sum
    burn_prev: jnp.ndarray   # (N_BURN,) last burn severity per subject
    n_alerts: jnp.ndarray    # (F,) alerts fired per family
    first_tick: jnp.ndarray  # (F,) first firing tick per family (-1 = none)


def init(spec: DetectSpec, *, w: int, k: int) -> DetectCarry:
    zs = jnp.zeros((N_SIGNALS,), jnp.float32)
    zwk = jnp.zeros((w, k), jnp.float32)
    zring = jnp.zeros((spec.burn_slow,), jnp.float32)
    z = jnp.asarray(0.0, jnp.float32)
    return DetectCarry(
        prev_raw=zs, mu=zs, var=zs, s_pos=zs, s_neg=zs, ewma=zs,
        n_seen=z,
        nis_sum=zwk, nis_cnt=zwk,
        nis_base=jnp.asarray(1.0, jnp.float32), nis_nwin=z,
        viol_ring=zring, cost_ring=zring, dis_ring=zring,
        viol_fast=z, viol_slow=z, cost_fast=z, cost_slow=z,
        dis_fast=z, dis_slow=z,
        una_ring=zring, una_fast=z, una_slow=z,
        burn_prev=jnp.zeros((N_BURN,), jnp.int32),
        n_alerts=jnp.zeros((N_FAMILIES,), jnp.float32),
        first_tick=jnp.full((N_FAMILIES,), -1, jnp.int32),
    )


def _wh_factor(n, z: float, side: int):
    """Wilson–Hilferty χ²(n) quantile over n: the band edge for a window
    mean of ``n`` unit-χ² terms at ``z`` normal sigmas (``side`` ±1).
    Cheap, smooth in ``n`` and jit-friendly — exact inverse-CDF lookups
    have no business inside a scan."""
    n = jnp.maximum(n, 1.0)
    c = 2.0 / (9.0 * n)
    edge = (1.0 - c + side * z * jnp.sqrt(c)) ** 3
    return jnp.maximum(edge, 0.0)


def _fire(dc: DetectCarry, led, cond, t, family: int, value, subject,
          severity: int):
    """Record one alert: family counters always, a ledger event when a
    ring is carried.  ``cond`` is a traced () bool."""
    f = jnp.asarray(cond).astype(jnp.float32)
    n_alerts = dc.n_alerts.at[family].add(f)
    first = dc.first_tick.at[family].set(
        jnp.where(cond & (dc.first_tick[family] < 0),
                  jnp.asarray(t, jnp.int32), dc.first_tick[family]))
    dc = dc._replace(n_alerts=n_alerts, first_tick=first)
    if led is not None:
        led = ledger_lib.push(led, cond, t, FAMILY_KINDS[family], value,
                              tenant=jnp.asarray(subject, jnp.int32),
                              severity=severity)
    return dc, led


def update(dc: DetectCarry, spec: DetectSpec, t, *, signals, kalman,
           cost_delta, led):
    """One tick of every enabled detector.  ``signals`` is the (S,) raw
    monitored vector (SIGNAL_NAMES order), ``kalman`` the tick's
    ``core.kalman.KalmanProbe`` (required when ``spec.nis``), ``cost_delta``
    this tick's billed spend, ``led`` the decision ring (or None).
    Returns the advanced ``(DetectCarry, Ledger | None)``."""
    armed = dc.n_seen >= spec.warmup

    # --- shared baseline over the detected (possibly differenced) signal
    diff_mask = jnp.asarray(DIFFERENCED)
    x = jnp.where(diff_mask, signals - dc.prev_raw, signals)
    # First tick: a differenced signal's prev is meaningless; treat the
    # delta as zero so t=0 cannot seed the baseline with the raw level.
    x = jnp.where(diff_mask & (dc.n_seen < 1), 0.0, x)
    resid = x - dc.mu
    a = spec.baseline_alpha
    floor = jnp.asarray(spec.sigma_floor, jnp.float32)
    # Winsorized learning (see DetectSpec.winsor_z): the baseline only
    # absorbs residuals plausible under the in-control model.
    sigma_prev = jnp.maximum(jnp.sqrt(dc.var),
                             floor + spec.sigma_rel * jnp.abs(dc.mu))
    resid_w = jnp.clip(resid, -spec.winsor_z * sigma_prev,
                       spec.winsor_z * sigma_prev)
    mu = dc.mu + a * resid_w
    var = (1.0 - a) * dc.var + a * resid_w * resid_w
    sigma = jnp.maximum(jnp.sqrt(var),
                        floor + spec.sigma_rel * jnp.abs(mu))
    zscore = jnp.where(armed, resid / sigma, 0.0)
    alarmed = jnp.zeros((N_SIGNALS,), bool)

    if spec.cusum:
        s_pos = jnp.maximum(0.0, dc.s_pos + zscore - spec.cusum_k)
        s_neg = jnp.maximum(0.0, dc.s_neg - zscore - spec.cusum_k)
        stat = jnp.maximum(s_pos, s_neg)
        over = armed & (stat > spec.cusum_h)
        any_over = jnp.any(over)
        worst = jnp.argmax(jnp.where(over, stat, -jnp.inf))
        dc, led = _fire(dc, led, any_over, t, 0, stat[worst], worst,
                        ledger_lib.SEV_PAGE)
        # Reset the alarmed statistic: one shift, one event.
        dc = dc._replace(s_pos=jnp.where(over, 0.0, s_pos),
                         s_neg=jnp.where(over, 0.0, s_neg))
        alarmed = alarmed | over

    if spec.ewma:
        ae = spec.ewma_alpha
        ew = (1.0 - ae) * dc.ewma + ae * zscore
        limit = spec.ewma_L * jnp.sqrt(ae / (2.0 - ae))
        over = armed & (jnp.abs(ew) > limit)
        any_over = jnp.any(over)
        worst = jnp.argmax(jnp.where(over, jnp.abs(ew), -jnp.inf))
        dc, led = _fire(dc, led, any_over, t, 1, ew[worst], worst,
                        ledger_lib.SEV_WARN)
        dc = dc._replace(ewma=jnp.where(over, 0.0, ew))
        alarmed = alarmed | over

    # Re-anchor an alarmed signal's baseline at the observed level: the
    # shift has been reported, so the new regime is the reference from
    # here on — one regime change fires one event (and the return to
    # normal fires the opposite-side shift), not a storm for the whole
    # excursion.  Variance restarts at zero and the floor rules until
    # the new regime's spread is re-learned.
    mu = jnp.where(alarmed, x, mu)
    var = jnp.where(alarmed, 0.0, var)
    dc = dc._replace(prev_raw=signals, mu=mu, var=var,
                     n_seen=dc.n_seen + 1.0)

    if spec.nis:
        if kalman is None:
            raise ValueError(
                "DetectSpec.nis needs the Kalman innovation probe — "
                "runner must thread TickSignals.kalman (ObsSpec."
                "want_kalman)")
        nis_sum = dc.nis_sum + kalman.nis
        nis_cnt = dc.nis_cnt + kalman.upd.astype(jnp.float32)
        window_end = (t + 1) % spec.nis_window == 0
        n_tot = jnp.sum(nis_cnt)
        testable = window_end & (n_tot >= spec.nis_min_updates)
        fleet_mean = jnp.sum(nis_sum) / jnp.maximum(n_tot, 1.0)
        in_warmup = dc.nis_nwin < spec.nis_warmup_windows
        base = jnp.maximum(dc.nis_base, 1.0)
        # χ² band a consistent filter would obey, widened to at least a
        # ``nis_ratio`` multiplicative margin (see module doc).
        hi = base * jnp.maximum(_wh_factor(n_tot, spec.nis_z, +1),
                                spec.nis_ratio)
        lo = base * jnp.minimum(_wh_factor(n_tot, spec.nis_z, -1),
                                1.0 / spec.nis_ratio)
        over = testable & ~in_warmup & (
            (fleet_mean > hi) | (fleet_mean < lo))
        bank_mean = nis_sum / jnp.maximum(nis_cnt, 1.0)
        worst = jnp.argmax(jnp.where(nis_cnt > 0, bank_mean, -jnp.inf))
        dc, led = _fire(dc, led, over, t, 2, fleet_mean, worst,
                        ledger_lib.SEV_PAGE)
        # Fold healthy windows into the learned NIS level (geometric EW:
        # the level drifts multiplicatively with workload phase) and
        # reset the window accumulators; alarmed windows are excluded so
        # a broken filter cannot teach the test to accept itself.
        absorb = testable & ~over
        an = spec.nis_alpha
        geo = jnp.exp((1.0 - an) * jnp.log(base)
                      + an * jnp.log(jnp.maximum(fleet_mean, 1e-12)))
        nb = jnp.where(
            absorb,
            jnp.where(dc.nis_nwin > 0, geo, fleet_mean),
            dc.nis_base)
        dc = dc._replace(
            nis_sum=jnp.where(window_end, 0.0, nis_sum),
            nis_cnt=jnp.where(window_end, 0.0, nis_cnt),
            nis_base=nb,
            nis_nwin=dc.nis_nwin + jnp.asarray(absorb).astype(jnp.float32))

    if spec.burn:
        slow, fast = spec.burn_slow, spec.burn_fast
        i_slow = jnp.mod(jnp.asarray(t, jnp.int32), slow)
        i_fast = jnp.mod(jnp.asarray(t, jnp.int32) - fast, slow)

        def advance(ring, fsum, ssum, x):
            """Slide both running window sums one tick: add the new
            sample, retire the one aging out of each window."""
            x = jnp.asarray(x, jnp.float32)
            fsum = fsum + x - ring[i_fast]
            ssum = ssum + x - ring[i_slow]
            return ring.at[i_slow].set(x), fsum, ssum

        def level(fsum, ssum, budget):
            fast_mult = fsum / (fast * budget)
            slow_mult = ssum / (slow * budget)
            page = (fast_mult >= spec.burn_page_mult) & (
                slow_mult >= spec.burn_page_mult)
            warn = slow_mult >= spec.burn_warn_mult
            lvl = jnp.where(page, ledger_lib.SEV_PAGE,
                            jnp.where(warn, ledger_lib.SEV_WARN, 0))
            return lvl.astype(jnp.int32), jnp.maximum(fast_mult, slow_mult)

        def judge(dc, led, burn_prev, fsum, ssum, budget, subject):
            lvl, mult = level(fsum, ssum, budget)
            lvl = jnp.where(armed, lvl, 0)
            rising = lvl > burn_prev[subject]
            dc, led = _fire(dc, led, rising & (lvl == ledger_lib.SEV_PAGE),
                            t, 3, mult, subject, ledger_lib.SEV_PAGE)
            dc, led = _fire(dc, led, rising & (lvl == ledger_lib.SEV_WARN),
                            t, 3, mult, subject, ledger_lib.SEV_WARN)
            return dc, led, burn_prev.at[subject].set(lvl)

        burn_prev = dc.burn_prev
        viol_ring, viol_fast, viol_slow = advance(
            dc.viol_ring, dc.viol_fast, dc.viol_slow, signals[2])
        dc, led, burn_prev = judge(dc, led, burn_prev, viol_fast,
                                   viol_slow, spec.slo_viol_per_tick,
                                   BURN_VIOL)

        cost_fast, cost_slow, cost_ring = (dc.cost_fast, dc.cost_slow,
                                           dc.cost_ring)
        if spec.slo_cost_per_tick > 0.0:
            cost_ring, cost_fast, cost_slow = advance(
                dc.cost_ring, dc.cost_fast, dc.cost_slow,
                0.0 if cost_delta is None else cost_delta)
            dc, led, burn_prev = judge(dc, led, burn_prev, cost_fast,
                                       cost_slow, spec.slo_cost_per_tick,
                                       BURN_COST)

        dis_fast, dis_slow, dis_ring = (dc.dis_fast, dc.dis_slow,
                                        dc.dis_ring)
        if spec.slo_disrupt_per_tick > 0.0:
            dis_ring, dis_fast, dis_slow = advance(
                dc.dis_ring, dc.dis_fast, dc.dis_slow, signals[5])
            dc, led, burn_prev = judge(dc, led, burn_prev, dis_fast,
                                       dis_slow, spec.slo_disrupt_per_tick,
                                       BURN_DISRUPT)

        una_fast, una_slow, una_ring = (dc.una_fast, dc.una_slow,
                                        dc.una_ring)
        if spec.slo_unavail_per_tick > 0.0:
            una_ring, una_fast, una_slow = advance(
                dc.una_ring, dc.una_fast, dc.una_slow, signals[6])
            dc, led, burn_prev = judge(dc, led, burn_prev, una_fast,
                                       una_slow, spec.slo_unavail_per_tick,
                                       BURN_UNAVAIL)

        dc = dc._replace(viol_ring=viol_ring, viol_fast=viol_fast,
                         viol_slow=viol_slow, cost_ring=cost_ring,
                         cost_fast=cost_fast, cost_slow=cost_slow,
                         dis_ring=dis_ring, dis_fast=dis_fast,
                         dis_slow=dis_slow, una_ring=una_ring,
                         una_fast=una_fast, una_slow=una_slow,
                         burn_prev=burn_prev)

    return dc, led


def drain(dc: DetectCarry, spec: DetectSpec) -> dict:
    """Host-side read-out: per-family alert counts and first-firing
    ticks, plus final detector state, plain numpy throughout."""
    import numpy as np

    n_alerts = np.asarray(dc.n_alerts, np.float64)
    first = np.asarray(dc.first_tick, np.int64)
    return {
        "alerts_total": float(n_alerts.sum()),
        "alerts_by_family": {
            name: float(n_alerts[i]) for i, name in enumerate(FAMILY_NAMES)},
        "first_tick_by_family": {
            name: int(first[i]) for i, name in enumerate(FAMILY_NAMES)},
        "cusum_stat": np.maximum(np.asarray(dc.s_pos),
                                 np.asarray(dc.s_neg)),
        "ewma_stat": np.asarray(dc.ewma),
        "baseline_mu": np.asarray(dc.mu),
        "baseline_sigma": np.sqrt(np.asarray(dc.var)),
        "nis_base": float(dc.nis_base),
        "signal_names": list(SIGNAL_NAMES),
    }
