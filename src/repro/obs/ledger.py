"""Bounded in-carry decision ledger: a ring buffer of structured events.

The ledger answers *why* a run cost what it did without a full per-tick
trace: each time the control plane makes a notable decision — the AIMD
loop flips into multiplicative backoff, the market reclaims slots, the
chaos engine hard-kills capacity, the admission gate rejects arrivals —
one fixed-layout event ``(tick, kind, tenant, value)`` is pushed into a
fixed-capacity ring carried through the scan.  Everything is fixed-shape:
a push is one dynamic-index update per buffer, conditioned on the event
predicate, so an event-free tick writes each slot back to itself and the
compiled step never branches.

Overflow semantics are *oldest-dropped*: ``head`` counts every event ever
pushed, the slot written is ``head % capacity``, so once the ring wraps
the surviving window is the most recent ``capacity`` events and exactly
``head - capacity`` old ones were overwritten.  :func:`records` decodes a
drained ring back into typed, chronologically ordered records plus that
exact dropped count — the contract ``tests/test_obs.py`` pins.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

# Event kinds.  Codes are part of the drained-record schema (JSONL /
# dataframe exports carry both the code and the name), so new kinds are
# appended, never renumbered.
KIND_AIMD_BACKOFF = 1   # AIMD flipped increase -> decrease; value = n_target
KIND_PREEMPT = 2        # market reclaimed slots this tick; value = count
KIND_KILL = 3           # chaos hard-kills this tick; value = count
KIND_BACKOFF_ENTER = 4  # acquisition fail-streak left 0; value = streak
KIND_ADM_REJECT = 5     # admission-gate rejects; value = count
KIND_SHED = 6           # deadline-aware shed arrivals; value = count
# Alert kinds (obs.detect).  The tenant column carries the *subject* id —
# the monitored-signal index for CUSUM/EWMA (detect.SIGNAL_NAMES), the
# flattened worst (w, k) bank for the NIS band test, the burn-rate window
# id (0 = violations, 1 = spend) — and ``severity`` is 1 (warn) or 2
# (page).
KIND_ALERT_CUSUM = 7    # sustained mean shift; value = CUSUM statistic
KIND_ALERT_EWMA = 8     # smoothed drift out of band; value = EWMA stat
KIND_ALERT_NIS = 9      # Kalman NIS out of chi-square band; value = mean NIS
KIND_ALERT_BURN = 10    # SLO burn rate over budget; value = burn multiple
# Optimizer telemetry kinds (opt.cem / opt.es); tick = generation index.
KIND_OPT_IMPROVE = 11   # incumbent replaced; value = new best score
KIND_OPT_STALL = 12     # convergence stall detected; value = stalled gens

KIND_NAMES = {
    KIND_AIMD_BACKOFF: "aimd_backoff",
    KIND_PREEMPT: "preempt",
    KIND_KILL: "kill",
    KIND_BACKOFF_ENTER: "backoff_enter",
    KIND_ADM_REJECT: "adm_reject",
    KIND_SHED: "shed",
    KIND_ALERT_CUSUM: "alert_cusum",
    KIND_ALERT_EWMA: "alert_ewma",
    KIND_ALERT_NIS: "alert_nis",
    KIND_ALERT_BURN: "alert_burn",
    KIND_OPT_IMPROVE: "opt_improve",
    KIND_OPT_STALL: "opt_stall",
}

# Every alert kind, in code order — the detect calibration gates count
# ledger events against this set.
ALERT_KINDS = (KIND_ALERT_CUSUM, KIND_ALERT_EWMA, KIND_ALERT_NIS,
               KIND_ALERT_BURN)

# Severity levels carried by alert events (0 = informational event).
SEV_WARN = 1
SEV_PAGE = 2

# Fleet-level events carry this sentinel in the tenant column.
NO_TENANT = -1


class Ledger(NamedTuple):
    """The in-carry ring.  ``head`` is the total number of events ever
    pushed (not the write position — that is ``head % capacity``).  The
    two ``prev_*`` registers are the one-tick memories the transition
    detectors (AIMD flip, backoff entry) need; they live here so the
    ledger works even when the ``aimd`` metric family is switched off."""

    tick: jnp.ndarray         # (cap,) int32
    kind: jnp.ndarray         # (cap,) int32
    tenant: jnp.ndarray       # (cap,) int32 (NO_TENANT = fleet-level)
    value: jnp.ndarray        # (cap,) float32
    severity: jnp.ndarray     # (cap,) int32 (0 = event, 1 = warn, 2 = page)
    head: jnp.ndarray         # ()     int32 total events ever pushed
    prev_incr: jnp.ndarray    # ()     bool  last tick's AIMD branch
    prev_streak: jnp.ndarray  # ()     f32   last tick's fail-streak


def init(capacity: int) -> Ledger:
    return Ledger(
        tick=jnp.zeros((capacity,), jnp.int32),
        kind=jnp.zeros((capacity,), jnp.int32),
        tenant=jnp.full((capacity,), NO_TENANT, jnp.int32),
        value=jnp.zeros((capacity,), jnp.float32),
        severity=jnp.zeros((capacity,), jnp.int32),
        head=jnp.asarray(0, jnp.int32),
        prev_incr=jnp.asarray(True),
        prev_streak=jnp.asarray(0.0, jnp.float32),
    )


def push(led: Ledger, cond, t, kind: int, value,
         tenant=NO_TENANT, severity=0) -> Ledger:
    """Conditionally append one event.  ``cond`` is a traced () bool: when
    False every buffer writes its current slot value back (a no-op), and
    ``head`` does not advance — so the ring only ever holds real events."""
    cap = led.tick.shape[0]
    idx = led.head % cap
    keep = lambda buf, v: buf.at[idx].set(  # noqa: E731
        jnp.where(cond, v, buf[idx]))
    return led._replace(
        tick=keep(led.tick, jnp.asarray(t, jnp.int32)),
        kind=keep(led.kind, jnp.asarray(kind, jnp.int32)),
        tenant=keep(led.tenant, jnp.asarray(tenant, jnp.int32)),
        value=keep(led.value, jnp.asarray(value, jnp.float32)),
        severity=keep(led.severity, jnp.asarray(severity, jnp.int32)),
        head=led.head + cond.astype(jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class LedgerRecord:
    """One drained event, host-side."""

    tick: int
    kind: int
    kind_name: str
    tenant: int
    value: float
    severity: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def drain(led: Ledger) -> tuple[list[LedgerRecord], int]:
    """Decode a drained ring: (chronological records, exact dropped count).

    With ``head <= capacity`` the ring never wrapped and slots ``[0, head)``
    are already in push order.  After a wrap the oldest surviving event
    sits at ``head % capacity`` and the window reads circularly from
    there; everything pushed before it — exactly ``head - capacity``
    events — was overwritten (oldest-dropped).  Either way the returned
    list is in push order, so ticks are monotonically non-decreasing —
    the exactness contract ``tests/test_obs.py`` overflows a ring to pin.
    """
    import numpy as np

    tick = np.asarray(led.tick)
    kind = np.asarray(led.kind)
    tenant = np.asarray(led.tenant)
    value = np.asarray(led.value)
    severity = np.asarray(led.severity)
    cap = tick.shape[0]
    head = int(led.head)
    n = min(head, cap)
    dropped = head - n
    start = head % cap if head > cap else 0
    order = [(start + i) % cap for i in range(n)]
    recs = [LedgerRecord(tick=int(tick[i]), kind=int(kind[i]),
                         kind_name=KIND_NAMES.get(int(kind[i]),
                                                  f"kind_{int(kind[i])}"),
                         tenant=int(tenant[i]), value=float(value[i]),
                         severity=int(severity[i]))
            for i in order]
    return recs, dropped


# Backwards-compatible alias: ``drain`` is the canonical decode.
records = drain
