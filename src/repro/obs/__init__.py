"""Observability layer: in-scan probes, ledger, detectors, attribution.

Five planes, all zero-cost when off:

  1. **In-scan metric probes** (``probes``): ``ObsSpec`` rides
     ``SimConfig.obs`` (default ``None``) and selects per-family counter /
     gauge / histogram registers that accumulate *inside* the scan carry —
     AIMD branch counts, Kalman innovation/NIS per bank, preemptions and
     hard-kills per instance type, the fairshare water level, admission
     rejects and queue-depth percentiles.
  2. **Decision ledger** (``ledger``): a bounded ring buffer in the carry
     recording structured ``(tick, kind, tenant, value)`` events for
     controller decisions, fault injections and backoff transitions,
     drained post-run into typed records.
  3. **Sweep/runtime profiling** (``sim.sweep`` + ``export``): per-chunk
     wall-clock, compile-vs-execute split and XLA peak-bytes land in the
     stream manifest and a ``SweepReport``; ``export`` renders a run's
     ledger or a sweep's chunk timeline as Chrome/Perfetto trace JSON.
  4. **In-scan anomaly detection** (``detect``): CUSUM/EWMA change-point
     detectors, a chi-square NIS band test over the Kalman innovation
     probes and multi-window SLO burn-rate tracking ride
     ``ObsSpec.detect`` (default ``None``, compiled out) and fire
     fixed-shape alert events — with severity and subject — into the
     ledger ring; ``metrics`` exposes any report as OpenMetrics text and
     live-tails streamed sweep directories.
  5. **Cross-run attribution** (``compare``): diff two ObsReports family
     by family — or two benchmark JSON artifacts leaf by leaf — and
     localize the first divergence; the CI bench gate prints and uploads
     that localization whenever it fails.

Carry-threading contract (what ``sim.runner`` guarantees):

  * ``SimConfig.obs`` is *static* (hashable, part of every jit cache key,
    surviving ``strip_tuned``) and ``None`` by default.  Every probe site
    in the step function is a trace-time conditional on it, and the
    ``SimState.obs`` carry field defaults to ``None`` — a leafless pytree
    — so an ``obs=None`` config compiles a scan structurally identical to
    the pre-obs simulator.  The kind="obs" bench gate pins this with a
    sha256 digest over the default sweep, exactly like ``faults=None``.
  * Probes are *read-only*: they consume values the step already
    computed, draw no PRNG, and feed nothing back, so enabling any probe
    subset leaves the simulation's own results bit-identical.
  * Families are independent: each ``ObsSpec`` flag gates its own carry
    registers and update ops, so enabling one family never pays for —
    or perturbs — another (``tests/test_obs.py`` pins both properties).

This package deliberately imports nothing from ``repro.sim`` or
``repro.core`` (the emission hooks live *there* and hand plain arrays in),
so the core control plane can type against ``ObsSpec`` without an import
cycle.
"""

from . import compare, detect, export, ledger, metrics, probes
from .compare import Divergence, attribution, diff_bench, diff_reports
from .detect import BURN_NAMES, SIGNAL_NAMES, DetectCarry, DetectSpec
from .ledger import KIND_NAMES, Ledger, LedgerRecord
from .metrics import to_openmetrics, watch
from .probes import (ObsCarry, ObsReport, ObsSpec, TickSignals, drain,
                     hist_percentile, init_carry, update)

__all__ = ["compare", "detect", "export", "ledger", "metrics", "probes",
           "BURN_NAMES", "SIGNAL_NAMES", "KIND_NAMES", "Divergence",
           "DetectCarry", "DetectSpec", "Ledger", "LedgerRecord",
           "ObsCarry", "ObsReport", "ObsSpec", "TickSignals",
           "attribution", "diff_bench", "diff_reports", "drain",
           "hist_percentile", "init_carry", "to_openmetrics", "update",
           "watch"]
