"""Cross-run regression attribution: localize *where* two runs diverged.

The bench gate (``benchmarks/check_bench_regression.py``) can tell you
*that* a trajectory regressed; this module tells you *where first*.  Two
entry points share one divergence record:

  * :func:`diff_reports` — compare two :class:`~repro.obs.probes.ObsReport`
    objects probe family by probe family (counters, Kalman banks, per-type
    preempt/kill series, rejects, queue histogram, ledger, detectors) and
    return every divergence, ordered so the **first diverging family at
    the earliest tick** leads.  Tick-indexed families resolve the
    divergence to a tick; the ledger resolves it to the first differing
    event; scalar families carry ``tick=None``.
  * :func:`diff_bench` — compare two benchmark JSON trees (a CI result vs
    the committed ``benchmarks/baselines/`` artifact) leaf by leaf.
    Wall-clock leaves (``*_s``, ``*per_s`` …) never reproduce across
    machines, so they are classified as *noise* and kept out of the
    headline ordering; digests and acceptance flags rank first because
    one flipped bit there explains every numeric drift below it.

:func:`attribution` wraps ``diff_bench`` into the JSON-serializable
report the gate prints and uploads (``results/bench_attribution.json``)
whenever it fails — the point is that a red CI job leads with "first
divergence: ``neutrality.digest``" instead of a wall of numbers.

Pure host-side ``numpy``/stdlib — nothing here touches jax, so the gate
can import it in environments where no accelerator runtime exists.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

# Probe families in report order: a divergence in an earlier family is
# reported first — the ledger and detect stats are downstream of the raw
# counters, so the earliest family is the closest to the root cause.
FAMILY_ORDER = ("counters", "kalman", "preempt_by_type", "kill_by_type",
                "rejects", "queue_hist", "queue_percentiles", "ledger",
                "detect")

# Benchmark-JSON leaves that legitimately differ run to run (wall-clock
# and derived rates) — classified as noise, never the headline.
_NOISE_LEAF = re.compile(r"(_s|_sec|per_s|wall|peak_bytes)$")

# Leaves whose divergence explains everything downstream, in rank order.
_ROOT_CAUSE_RANK = ("digest", "exact", "ok", "parity")


@dataclasses.dataclass(frozen=True)
class Divergence:
    """One localized difference between two runs."""

    family: str           # probe family / top-level JSON section
    path: str             # dotted path to the diverging leaf
    tick: int | None      # first diverging tick where the family has one
    a: Any                # current value (scalar or short repr)
    b: Any                # baseline value
    detail: str = ""      # one-line human summary

    def to_dict(self) -> dict:
        return {"family": self.family, "path": self.path, "tick": self.tick,
                "current": self.a, "baseline": self.b, "detail": self.detail}


def _neq(a, b, rtol: float) -> bool:
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) and math.isnan(b):
            return False
        if rtol > 0.0:
            return not math.isclose(a, b, rel_tol=rtol, abs_tol=0.0)
    return a != b


def _scalar(x):
    """A JSON-friendly rendering of a numpy scalar / small value."""
    try:
        return x.item()
    except AttributeError:
        return x


def _diff_arrays(family: str, path: str, a, b, *, tick_axis: bool,
                 out: list[Divergence]) -> None:
    import numpy as np

    if a is None and b is None:
        return
    if (a is None) != (b is None):
        out.append(Divergence(family, path, None,
                              None if a is None else "present",
                              None if b is None else "present",
                              "family enabled in one run only"))
        return
    a, b = np.asarray(a), np.asarray(b)
    if a.shape != b.shape:
        out.append(Divergence(family, path, None, list(a.shape),
                              list(b.shape), "shape mismatch"))
        return
    neq = a != b
    both_nan = np.zeros_like(neq) if a.dtype.kind not in "fc" else (
        np.isnan(a) & np.isnan(b))
    neq = neq & ~both_nan
    if not bool(neq.any()):
        return
    idx = tuple(int(i) for i in np.argwhere(neq)[0])
    tick = idx[0] if tick_axis and a.ndim >= 1 else None
    out.append(Divergence(
        family, f"{path}[{','.join(map(str, idx))}]", tick,
        _scalar(a[idx]), _scalar(b[idx]),
        f"first of {int(neq.sum())} differing element(s)"))


def _diff_mapping(family: str, a: dict | None, b: dict | None, *,
                  tick_axis: bool, out: list[Divergence]) -> None:
    import numpy as np

    if a is None and b is None:
        return
    if (a is None) != (b is None):
        out.append(Divergence(family, family, None,
                              None if a is None else "present",
                              None if b is None else "present",
                              "family enabled in one run only"))
        return
    for key in sorted(set(a) | set(b)):
        if key not in a or key not in b:
            out.append(Divergence(family, f"{family}.{key}", None,
                                  a.get(key, "<missing>"),
                                  b.get(key, "<missing>"),
                                  "key present in one run only"))
            continue
        va, vb = a[key], b[key]
        if isinstance(va, (list, tuple, np.ndarray)) or hasattr(va, "shape"):
            _diff_arrays(family, f"{family}.{key}", va, vb,
                         tick_axis=tick_axis, out=out)
        elif _neq(_scalar(va), _scalar(vb), 0.0):
            out.append(Divergence(family, f"{family}.{key}", None,
                                  _scalar(va), _scalar(vb), ""))


def _diff_ledgers(a: list, b: list, out: list[Divergence]) -> None:
    for i, (ra, rb) in enumerate(zip(a, b)):
        if ra != rb:
            field = next(f for f in ("tick", "kind", "tenant", "value",
                                     "severity")
                         if getattr(ra, f) != getattr(rb, f))
            out.append(Divergence(
                "ledger", f"ledger[{i}].{field}", int(ra.tick),
                _scalar(getattr(ra, field)), _scalar(getattr(rb, field)),
                f"event {i}: {ra.kind_name} vs {rb.kind_name}"))
            return
    if len(a) != len(b):
        extra = a[len(b):] if len(a) > len(b) else b[len(a):]
        out.append(Divergence(
            "ledger", f"ledger[{min(len(a), len(b))}]",
            int(extra[0].tick), len(a), len(b),
            f"event counts differ; first unmatched: {extra[0].kind_name}"))


def diff_reports(current, baseline) -> list[Divergence]:
    """Every divergence between two ObsReports, first family / earliest
    tick leading.  Empty list = the runs are observationally identical."""
    out: list[Divergence] = []
    _diff_mapping("counters", current.counters, baseline.counters,
                  tick_axis=False, out=out)
    _diff_mapping("kalman", current.kalman, baseline.kalman,
                  tick_axis=False, out=out)
    for fam in ("preempt_by_type", "kill_by_type", "rejects", "queue_hist"):
        _diff_arrays(fam, fam, getattr(current, fam), getattr(baseline, fam),
                     tick_axis=fam in ("preempt_by_type", "kill_by_type"),
                     out=out)
    _diff_mapping("queue_percentiles", current.queue_percentiles,
                  baseline.queue_percentiles, tick_axis=False, out=out)
    _diff_ledgers(current.ledger, baseline.ledger, out)
    if current.ledger_dropped != baseline.ledger_dropped:
        out.append(Divergence("ledger", "ledger_dropped", None,
                              current.ledger_dropped,
                              baseline.ledger_dropped, ""))
    _diff_mapping("detect", current.detect, baseline.detect,
                  tick_axis=False, out=out)
    rank = {f: i for i, f in enumerate(FAMILY_ORDER)}
    out.sort(key=lambda d: (rank.get(d.family, len(rank)),
                            math.inf if d.tick is None else d.tick, d.path))
    return out


def first_divergence(divs: list[Divergence]) -> Divergence | None:
    return divs[0] if divs else None


def _walk(prefix: str, a, b, signal: list[Divergence],
          noise: list[Divergence]) -> None:
    if isinstance(a, dict) and isinstance(b, dict):
        for key in sorted(set(a) | set(b)):
            path = f"{prefix}.{key}" if prefix else str(key)
            if key not in a or key not in b:
                signal.append(Divergence(path.split(".")[0], path, None,
                                         a.get(key, "<missing>"),
                                         b.get(key, "<missing>"),
                                         "key present in one report only"))
                continue
            _walk(path, a[key], b[key], signal, noise)
        return
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            signal.append(Divergence(prefix.split(".")[0], prefix, None,
                                     len(a), len(b), "length mismatch"))
            return
        for i, (va, vb) in enumerate(zip(a, b)):
            _walk(f"{prefix}[{i}]", va, vb, signal, noise)
        return
    a, b = _scalar(a), _scalar(b)
    if not _neq(a, b, 0.0):
        return
    leaf = prefix.rsplit(".", 1)[-1]
    d = Divergence(prefix.split(".")[0], prefix, None, a, b, "")
    (noise if _NOISE_LEAF.search(leaf) else signal).append(d)


def _bench_rank(d: Divergence) -> tuple:
    leaf = d.path.rsplit(".", 1)[-1]
    for i, marker in enumerate(_ROOT_CAUSE_RANK):
        if marker in leaf:
            return (i, d.path)
    return (len(_ROOT_CAUSE_RANK), d.path)


def diff_bench(current: dict, baseline: dict) -> tuple[list[Divergence],
                                                       list[Divergence]]:
    """Leaf-by-leaf diff of two benchmark JSON reports.

    Returns ``(signal, noise)``: *signal* holds deterministic leaves
    (digests and flags ranked first — one flipped digest explains every
    numeric drift below it), *noise* holds wall-clock/rate leaves that
    never reproduce across machines.
    """
    signal: list[Divergence] = []
    noise: list[Divergence] = []
    _walk("", current, baseline, signal, noise)
    signal.sort(key=_bench_rank)
    noise.sort(key=lambda d: d.path)
    return signal, noise


def attribution(current: dict, baseline: dict,
                gate_errors: list[str] | None = None,
                max_leaves: int = 32) -> dict:
    """The JSON-serializable attribution report the bench gate emits on
    failure: the first diverging deterministic leaf, the full (bounded)
    divergence list, and the gate errors it explains."""
    signal, noise = diff_bench(current, baseline)
    first = first_divergence(signal)
    return {
        "kind": current.get("kind", baseline.get("kind", "spot")),
        "first_divergence": None if first is None else first.to_dict(),
        "n_divergences": len(signal),
        "divergences": [d.to_dict() for d in signal[:max_leaves]],
        "n_noise": len(noise),
        "noise": [d.to_dict() for d in noise[:max_leaves]],
        "gate_errors": list(gate_errors or []),
    }
