"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import time

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import SimConfig, paper_schedule, run
from repro.sim.runner import total_cost

SPOT = 0.0081

# The two TTC settings, derived exactly as the paper derives them (§V.C):
# the longest workload completion time under Autoscale with 1-instance and
# 10-instance steps respectively (measured in our testbed; paper: 2h07/1h37).
TTC_CONSERVATIVE = 7500.0    # AS-1:  125 min in our calibration
TTC_FAST = 6300.0            # AS-10: 105 min


def make_cfg(policy="aimd", predictor="kalman", monitor_dt=300.0,
             terminate="boundary", as_step=10.0, ticks=130,
             seed=0) -> SimConfig:
    # paper §V.B: ARMA reliability window = 3 measurements at 5-min
    # monitoring, 10 at 1-min.
    params = ControlParams(monitor_dt=monitor_dt,
                           arma_window=10 if monitor_dt <= 60.0 else 3)
    bill = BillingParams(terminate=terminate)
    return SimConfig(
        ctrl=ControllerConfig(policy=policy, predictor=predictor,
                              params=params, billing=bill, as_step=as_step),
        ticks=ticks, seed=seed)


def run_policy(policy, ttc, seed=0, **kw):
    sched = paper_schedule(ttc=ttc, arrival_gap_ticks=1, seed=seed)
    cfg = make_cfg(policy=policy, seed=seed, **kw)
    t0 = time.time()
    tr = run(sched, cfg)
    return {
        "trace": tr,
        "cost": total_cost(tr),
        "max_n": float(np.asarray(tr.n_committed).max()),
        "violations": int(tr.violations),
        "lb": sched.total_cus / 3600 * SPOT,
        "wall_s": time.time() - t0,
    }


def time_to_reliable_minutes(trace, schedule, monitor_dt) -> np.ndarray:
    """Per-workload minutes from submission to the predictor's t_init."""
    rel = np.asarray(trace.reliable[:, :, 0])        # (T, W)
    sub = np.asarray(trace.work_final.t_submit).astype(float)
    t_rel = np.argmax(rel, axis=0).astype(float)
    ok = rel.any(axis=0) & (sub >= 0)
    out = np.full(rel.shape[1], np.nan)
    out[ok] = (t_rel[ok] - sub[ok]) * monitor_dt / 60.0
    return out


def mae_at_reliable(trace, schedule) -> np.ndarray:
    """Mean |b̂ - b_inst| / b_inst over the post-t_init life of each
    workload, where b_inst is the *instantaneous* true per-item cost (the
    cheap-items-first completion bias makes the contemporaneous cost the
    quantity the estimator is actually filtering — see workloads.ramp)."""
    from repro.sim.workloads import ramp
    import jax.numpy as jnp

    rel = np.asarray(trace.reliable[:, :, 0])        # (T, W)
    act = np.asarray(trace.active)                   # (T, W)
    b_hat = np.asarray(trace.b_hat[:, :, 0])
    remaining = np.asarray(trace.remaining)          # (T, W)
    m0 = np.maximum(schedule.m0[:, 0], 1.0)
    p = 1.0 - remaining / m0[None, :]
    bias = np.asarray(ramp(jnp.asarray(p), jnp.asarray(schedule.c0),
                           jnp.asarray(schedule.p_r),
                           jnp.asarray(schedule.overshoot)))
    b_inst = schedule.b_true[None, :, 0] * bias
    out = np.full(rel.shape[1], np.nan)
    for w in range(rel.shape[1]):
        sel = rel[:, w] & act[:, w]
        if sel.any():
            out[w] = float(np.mean(
                np.abs(b_hat[sel, w] - b_inst[sel, w]) / b_inst[sel, w]))
    return out
