"""Dynamic-bidding + mixed-granularity fleet benchmarks (policy frontier).

The paper's spot experiments (Appendix A) fix one bid and one instance type
per run.  This benchmark treats both as first-class axes on a *correlated*
multi-type market (all Table-V types co-move through a shared factor), in
two single ``jax.jit(jax.vmap(...))`` calls over full simulations:

  * policy frontier — seeds x bid multiples x bid policies on a spiky
    m3.xlarge market.  Static bids face the classic dilemma: bid low and
    lose the fleet to drift/spikes (deadline violations), or bid high and
    renew quanta at spiked prices.  The TTC-aware and market-aware (EMA)
    policies resolve it state-dependently, and the acceptance check
    requires one of them to reach the best static bid's violation level at
    equal or lower cost.
  * mix frontier — the same CU demand served by a fine fleet (m3.medium),
    a coarse fleet (m4.10xlarge), and a heterogeneous fleet over all six
    types in which every acquisition picks the cheapest-per-CU type the
    market currently sells under our bid.

Also re-runs the paper-headline AIMD-vs-Reactive comparison (via
``bench_spot``) so one machine-readable artifact carries the whole story:
``results/BENCH_spot.json``, the file the CI benchmark-regression gate
(``benchmarks/check_bench_regression.py``) diffs against the committed
baseline in ``benchmarks/baselines/``.

CLI:  PYTHONPATH=src python -m benchmarks.bench_bidding [--smoke]
"""

from __future__ import annotations

import argparse
import csv
import json
import os

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, make_axes,
                       paper_schedule)
from repro.sim.sweep import sweep

try:  # package-relative when run via ``-m benchmarks...``; standalone too
    from . import bench_spot
except ImportError:  # pragma: no cover
    import bench_spot

SCHEMA_VERSION = 1

# A market where the bid actually matters: mid-size type (real volatility),
# frequent multi-hour spikes (holding through one renews several quanta at
# the spiked price), types coupled through the default shared factor.
MARKET = dict(
    instance="m3.xlarge",
    p_spike_per_core=0.02,
    spike_hours=3.0,
    ema_alpha=0.15,
)
POLICIES = ("multiple", "ttc", "ema", "on_demand")
STATIC_MULTS = (1.02, 1.1, 1.2, 1.5, 2.5, 4.0, 8.0)
SMOKE_MULTS = (1.02, 1.5, 2.5, 8.0)
MIXES = {
    "fine": ("m3.medium",),
    "coarse": ("m4.10xlarge",),
    "mixed-all": (
        "m3.medium",
        "m3.large",
        "m3.xlarge",
        "m3.2xlarge",
        "m4.4xlarge",
        "m4.10xlarge",
    ),
}
TICKS = 130
MONITOR_DT = 300.0


def _cfg(policy: str = "aimd", **spot_kw) -> SimConfig:
    params = ControlParams(monitor_dt=MONITOR_DT)
    return SimConfig(
        ctrl=ControllerConfig(
            policy=policy,
            params=params,
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=TICKS,
        spot=SpotConfig(enabled=True, **{**MARKET, **spot_kw}),
    )


def _lex_best(cost: np.ndarray, viol: np.ndarray) -> int:
    """Index of the (violations, cost)-lexicographically best column."""
    order = sorted(range(cost.shape[0]), key=lambda j: (viol[j], cost[j]))
    return order[0]


def run_policy_frontier(seeds, bid_mults) -> dict:
    """seeds x bid multiples x bid policies, one jitted vmap."""
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    cfg = _cfg()
    axes = make_axes(
        seeds=list(seeds),
        bid_mults=list(bid_mults),
        instances=[MARKET["instance"]],
        policies=list(POLICIES),
    )
    s = sweep(SweepSpec(axes=axes, workload=sched), cfg)
    shape = (len(seeds), len(bid_mults), len(POLICIES))
    out = {
        "bid_mults": list(bid_mults),
        "cost": np.asarray(s.cost).reshape(shape),
        "violations": np.asarray(s.violations).reshape(shape),
        "preemptions": np.asarray(s.preemptions).reshape(shape),
    }

    # Reactive scaling at the never-preempted bid: the cost-delta reference.
    r = sweep(
        SweepSpec(
            axes=make_axes(seeds=list(seeds), bid_mults=[1.0],
                           instances=[MARKET["instance"]]),
            workload=sched,
        ),
        _cfg(policy="reactive", bid_policy="on_demand"),
    )
    out["reactive_cost"] = float(np.mean(np.asarray(r.cost)))
    out["reactive_violations"] = int(np.sum(np.asarray(r.violations)))
    return out


def run_mix_frontier(seeds) -> dict:
    """Fleet granularity on the correlated market, never-preempted bid."""
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    cfg = _cfg(bid_policy="on_demand", instance="m3.medium")
    axes = make_axes(
        seeds=list(seeds),
        bid_mults=[1.5],
        instances=list(MIXES.values()),
        policies=["on_demand"],
    )
    s = sweep(SweepSpec(axes=axes, workload=sched), cfg)
    shape = (len(seeds), len(MIXES))
    return {
        "names": list(MIXES),
        "cost": np.asarray(s.cost).reshape(shape),
        "violations": np.asarray(s.violations).reshape(shape),
        "preemptions": np.asarray(s.preemptions).reshape(shape),
    }


def summarize_policies(front: dict) -> dict:
    """Per-policy lexicographic-best point + cost delta vs Reactive."""
    policies = {}
    for k, name in enumerate(POLICIES):
        cost = front["cost"][:, :, k].mean(axis=0)
        viol = front["violations"][:, :, k].sum(axis=0)
        pre = front["preemptions"][:, :, k].sum(axis=0)
        j = _lex_best(cost, viol)
        policies[name] = {
            "best_bid_mult": float(front["bid_mults"][j]),
            "cost": float(cost[j]),
            "violations": int(viol[j]),
            "preemptions": float(pre[j]),
            "delta_vs_reactive_pct": float(
                100.0 * (front["reactive_cost"] - cost[j]) / front["reactive_cost"]
            ),
        }
    return policies


def acceptance(policies: dict) -> dict:
    """ISSUE 2 criterion: a dynamic policy matches the best static bid's
    violation level at equal or lower total billing cost."""
    static = policies["multiple"]
    dyn_name = min(
        ("ttc", "ema"),
        key=lambda n: (policies[n]["violations"], policies[n]["cost"]),
    )
    dyn = policies[dyn_name]
    ok = dyn["violations"] <= static["violations"] and dyn["cost"] <= static["cost"]
    return {
        "dynamic_beats_static": bool(ok),
        "best_dynamic_policy": dyn_name,
        "best_static": static,
        "best_dynamic": dyn,
    }


def write_outputs(report: dict, front: dict, outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "bidding_frontier.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["policy", "bid_mult", "mean_cost", "violations", "preemptions"])
        for k, name in enumerate(POLICIES):
            for j, mult in enumerate(front["bid_mults"]):
                w.writerow(
                    [
                        name,
                        mult,
                        f"{front['cost'][:, j, k].mean():.4f}",
                        int(front["violations"][:, j, k].sum()),
                        f"{front['preemptions'][:, j, k].sum():.0f}",
                    ]
                )
    with open(os.path.join(outdir, "BENCH_spot.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")


def main(emit, smoke: bool = False) -> dict:
    seeds = tuple(range(6))
    bid_mults = SMOKE_MULTS if smoke else STATIC_MULTS

    hl = bench_spot.run_headline(seeds=(0, 1) if smoke else (0, 1, 2))
    emit("bidding_headline_saving_pct", hl["saving_pct"], "target>=27")

    front = run_policy_frontier(seeds, bid_mults)
    policies = summarize_policies(front)
    for name, p in policies.items():
        emit(
            f"bidding_{name}_best_cost",
            p["cost"],
            f"mult={p['best_bid_mult']};viol={p['violations']};"
            f"delta_vs_reactive={p['delta_vs_reactive_pct']:.1f}%",
        )

    mixes = run_mix_frontier(seeds)
    mix_report = {}
    for j, name in enumerate(mixes["names"]):
        mix_report[name] = {
            "cost": float(mixes["cost"][:, j].mean()),
            "violations": int(mixes["violations"][:, j].sum()),
            "preemptions": float(mixes["preemptions"][:, j].sum()),
        }
        emit(
            f"bidding_mix_{name}_cost",
            mix_report[name]["cost"],
            f"viol={mix_report[name]['violations']};"
            f"preempt={mix_report[name]['preemptions']:.0f}",
        )

    acc = acceptance(policies)
    emit(
        "bidding_acceptance_dynamic_beats_static",
        float(acc["dynamic_beats_static"]),
        "bool",
    )

    report = {
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "market": dict(MARKET),
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "seeds": list(seeds),
            "bid_mults": list(bid_mults),
        },
        "headline": {
            "aimd_cost": hl["aimd"]["cost"],
            "reactive_cost": hl["reactive"]["cost"],
            "saving_pct": hl["saving_pct"],
            "aimd_violations": hl["aimd"]["violations"],
            "reactive_violations": hl["reactive"]["violations"],
        },
        "reactive_ref": {
            "cost": front["reactive_cost"],
            "violations": front["reactive_violations"],
        },
        "policies": policies,
        "mixes": mix_report,
        "acceptance": acc,
    }
    write_outputs(report, front)

    if not acc["dynamic_beats_static"]:
        raise SystemExit(
            "bidding acceptance not met: best dynamic "
            f"{acc['best_dynamic']} vs best static {acc['best_static']}"
        )
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced bid grid for CI; same acceptance checks",
    )
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
