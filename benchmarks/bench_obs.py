"""Observability benchmark: probe neutrality, digest pin, overhead, traces.

The telemetry subsystem (``repro.obs``) rides the scan carry behind the
same static-gating contract as the chaos engine: ``SimConfig.obs=None``
compiles the exact probe-free program, and every probe is read-only —
enabling the full catalog must not perturb a single result bit.  This
benchmark commits those claims:

  1. **obs=None bit-identity** — a sweep with the probes compiled out is
     digest-pinned (sha256 over every summary field) against the
     committed baseline, so *any* PR that perturbs the probe-free program
     is caught — the observability twin of ``bench_chaos``'s zero-fault
     digest;
  2. **probe neutrality** — the full probe catalog (every family on +
     ledger + histogram) reproduces the probe-free results bit for bit,
     in both trace mode (``runner.run``) and summary mode (the sweep);
  3. **bounded overhead** — the full-catalog run costs at most
     ``OBS_OVERHEAD_CEILING`` × the probe-free runtime on the frontier
     grid (steady-state, AOT-compiled, best-of-``STEADY_ITERS``);
  4. **working exporters** — a profiled, streamed sweep's Perfetto export
     (``results/obs_sweep_trace.json``) carries one complete span per
     chunk with compile/execute/write timings, and a full-probe run's
     ledger drains into typed records + a trace-event file CI uploads;
  5. **detector calibration** — with the in-scan detector catalog
     (``ObsSpec.detect``) armed: a clean paper replay and the fault-free
     variants of every committed chaos scenario fire **zero** alerts
     (false-positive gate), while every *faulted* chaos scenario from
     ``bench_chaos.SCENARIOS`` fires at least one alert whose tick lands
     inside the injected fault window (true-positive gate); the
     ``detect=None`` program stays bit-identical to the PR-9 probe
     catalog, and armed detectors perturb nothing but the summary's
     ``alerts`` field.

Emits ``results/BENCH_obs.json`` (``kind: "obs"``), gated in CI by
``benchmarks/check_bench_regression.py`` against
``benchmarks/baselines/``.

CLI:  PYTHONPATH=src python -m benchmarks.bench_obs [--smoke]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.obs import ObsSpec, export
from repro.obs import ledger as ledger_lib
from repro.sim import (SimConfig, SpotConfig, SweepSpec, faults, make_axes,
                       paper_schedule, runner, sweep)

try:
    from . import bench_chaos
except ImportError:          # direct script execution
    import bench_chaos

SCHEMA_VERSION = 2
# Full-catalog probes must stay within this multiple of the probe-free
# steady-state runtime on the frontier grid (hard, baseline-independent).
OBS_OVERHEAD_CEILING = 1.25

# The PR-2 policy-frontier market and grid (bench_throughput.MARKET) —
# the committed overhead reference point.
MARKET = dict(instance="m3.xlarge", p_spike_per_core=0.02, spike_hours=3.0,
              ema_alpha=0.15)
POLICIES = ("multiple", "ttc", "ema", "on_demand")
FULL_MULTS = (1.02, 1.1, 1.2, 1.5, 2.5, 4.0, 8.0)
SMOKE_MULTS = (1.02, 1.5, 2.5, 8.0)
TICKS = 130
MONITOR_DT = 300.0
# Best-of iterations for the steady-state timing: the frontier grid runs
# ~0.4s on CPU, so best-of-3 leaves enough scheduler noise to swing the
# overhead ratio across the gate ceiling; 7 keeps the minimum stable.
STEADY_ITERS = 7
LEDGER_CAP = 256


def _sched():
    return paper_schedule(ttc=7500.0, arrival_gap_ticks=1)


def _cfg(obs: ObsSpec | None = None) -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=MONITOR_DT),
                              billing=BillingParams(terminate="immediate")),
        ticks=TICKS, spot=SpotConfig(enabled=True, **MARKET), obs=obs)


def _axes(seeds, mults):
    return make_axes(seeds=list(seeds), bid_mults=list(mults),
                     instances=[MARKET["instance"]], policies=list(POLICIES))


def _chaos_cfg(obs, fault_cfg=None, **kw):
    """The bench_chaos simulator config with an ObsSpec attached — same
    ticks/market/schedule as the committed chaos scenarios, so the
    calibration gate measures the detectors on exactly the trajectories
    the chaos benchmark already pins."""
    return SimConfig(
        ctrl=ControllerConfig(
            params=ControlParams(monitor_dt=bench_chaos.MONITOR_DT)),
        ticks=bench_chaos.TICKS,
        spot=SpotConfig(enabled=True, **kw),
        faults=fault_cfg,
        obs=obs)


def _summary_digest(summary) -> str:
    h = hashlib.sha256()
    for f in type(summary)._fields:
        v = getattr(summary, f)
        if v is None:   # leafless fields (alerts without obs.detect)
            continue    # contribute nothing, keeping old digests stable
        h.update(np.ascontiguousarray(np.asarray(v)).tobytes())
    return h.hexdigest()


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def run_neutrality(seeds, mults) -> dict:
    """Bit-identity of the probe-free program, three ways (cf. the chaos
    zero-fault check): the full catalog against probes compiled out, the
    armed detector catalog against both (modulo the summary's ``alerts``
    field, the only thing detectors are allowed to add), and the
    compiled-out sweep's digest against the committed baseline."""
    sched = _sched()
    axes = _axes(seeds, mults)
    off = sweep.sweep(SweepSpec(axes=axes, workload=sched), _cfg())
    on = sweep.sweep(SweepSpec(axes=axes, workload=sched),
                     _cfg(ObsSpec.full(ledger=LEDGER_CAP)))
    sweep_exact = _trees_equal(off, on)

    det = sweep.sweep(SweepSpec(axes=axes, workload=sched),
                      _cfg(ObsSpec.full(ledger=LEDGER_CAP, detect=True)))
    detect_exact = _trees_equal(det._replace(alerts=None), off)

    tr_off = runner.run(sched, _cfg(), seed=0)
    tr_on, report = runner.run_obs(
        sched, _cfg(ObsSpec.full(ledger=LEDGER_CAP)), seed=0)
    run_exact = _trees_equal(tr_off, tr_on)

    return {
        "sweep_exact": bool(sweep_exact),
        "detect_exact": bool(detect_exact),
        "run_exact": bool(run_exact),
        "digest": _summary_digest(off),
        # detect=None must be the same *program* as the PR-9 catalog —
        # pinned separately so a probe that drifts only under the armed
        # spec's sibling path cannot hide behind sweep_exact.
        "digest_detect_none": _summary_digest(on),
        # A handful of drained gauges so the probe catalog's output stays
        # visible in the committed trajectory (informational, ungated).
        "probe_counters": {k: round(v, 4)
                           for k, v in sorted(report.counters.items())},
    }


def _best_of(compiled, axes, pp, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*axes, pp))
        best = min(best, time.perf_counter() - t0)
    return best


def run_overhead(seeds, mults) -> dict:
    """Steady-state full-catalog (probes + ledger + armed detectors) vs
    probe-free runtime on the frontier grid (one AOT compile each;
    best-of-``STEADY_ITERS`` to shed scheduler noise)."""
    sched = _sched()
    axes = _axes(seeds, mults)
    out = {}
    for name, cfg in (("base", _cfg()),
                      ("obs", _cfg(ObsSpec.full(ledger=LEDGER_CAP,
                                                detect=True)))):
        pp = runner.default_params(cfg)
        fn = jax.jit(jax.vmap(sweep.point_fn(sched, cfg, trace=False),
                              in_axes=(0, 0, 0, 0, 0, 0, None)))
        t0 = time.perf_counter()
        compiled = fn.lower(*axes, pp).compile()
        compile_s = time.perf_counter() - t0
        jax.block_until_ready(compiled(*axes, pp))   # warm dispatch
        out[name] = {
            "compile_s": round(compile_s, 4),
            "steady_s": round(_best_of(compiled, axes, pp, STEADY_ITERS), 4),
        }
    ratio = out["obs"]["steady_s"] / max(out["base"]["steady_s"], 1e-9)
    return {
        "points": int(axes.seed.shape[0]),
        "base": out["base"],
        "obs": out["obs"],
        "overhead_ratio": round(ratio, 3),
    }


def run_exports(seeds, mults) -> dict:
    """Profiled streamed sweep → Perfetto chunk timeline, and a
    full-probe run's ledger → trace events (both land in ``results/``,
    which CI uploads)."""
    import shutil
    import tempfile

    sched = _sched()
    axes = _axes(seeds, mults)
    b = int(axes.seed.shape[0])
    chunk = max(1, b // 4)
    os.makedirs("results", exist_ok=True)

    scratch = tempfile.mkdtemp(prefix="bench_obs_stream_")
    try:
        rep = sweep.sweep(
            SweepSpec(axes=axes, workload=sched, chunk_size=chunk,
                      stream_dir=scratch, profile=True), _cfg())
        trace_path = os.path.join("results", "obs_sweep_trace.json")
        rep.write_trace(trace_path)
        with open(trace_path) as f:
            events = json.load(f)["traceEvents"]
        spans = [e for e in events if e.get("ph") == "X"]
        span_keys = {"compile_s", "execute_s", "write_s"}
        spans_ok = (len(spans) == len(rep.chunks) > 0 and all(
            span_keys <= set(e.get("args", {})) for e in spans))
        manifest_ok = "profile" in rep.result.manifest
    finally:
        shutil.rmtree(scratch, ignore_errors=True)

    _, report = runner.run_obs(
        _sched(), _cfg(ObsSpec.full(ledger=LEDGER_CAP)), seed=0)
    run_trace = os.path.join("results", "obs_run_trace.json")
    export.write_trace(run_trace, export.run_trace_events(
        report, dt=MONITOR_DT))
    report.to_jsonl(os.path.join("results", "obs_run_ledger.jsonl"))

    return {
        "n_chunks": len(rep.chunks),
        "total_s": round(rep.total_s, 4),
        "compile_s": round(sum(c.compile_s for c in rep.chunks), 4),
        "execute_s": round(sum(c.execute_s for c in rep.chunks), 4),
        "write_s": round(sum(c.write_s for c in rep.chunks), 4),
        "peak_bytes": rep.chunks[0].peak_bytes,
        "spans_ok": bool(spans_ok),
        "manifest_profile_ok": bool(manifest_ok),
        "ledger_events": len(report.ledger),
        "ledger_dropped": report.ledger_dropped,
    }


# Tick window the true-positive gate requires each scenario's *first*
# alert to land in: the blackout's deterministic outage window plus
# detector latency; the stochastic scenarios inject from tick 0, so
# their whole run is a legitimate firing window.
ALERT_WINDOWS = {"blackout": (16.0, 40.0)}


def _alert_records(report):
    return [r for r in report.ledger if r.kind in ledger_lib.ALERT_KINDS]


def run_calibration(seeds) -> dict:
    """Detector calibration against the committed chaos scenarios.

    False-positive gate: the clean paper replay (spike-free frontier
    market) and the fault-free variant of every chaos scenario fire zero
    alerts.  True-positive gate: every *faulted* scenario under the
    hardened plane fires at least one alert, and each seed's first alert
    lands inside that scenario's fault window — so the detectors don't
    just fire, they localize the injected fault in time.
    """
    det = ObsSpec.full(ledger=LEDGER_CAP, detect=True)
    sched = _sched()

    clean_market = dict(MARKET, p_spike_per_core=0.0)
    clean_cfg = SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=MONITOR_DT),
                              billing=BillingParams(terminate="immediate")),
        ticks=TICKS, spot=SpotConfig(enabled=True, **clean_market), obs=det)
    clean_alerts = 0
    for s in seeds:
        _, rep = runner.run_obs(sched, clean_cfg, seed=s)
        clean_alerts += len(_alert_records(rep))

    chaos_sched = bench_chaos._sched()
    scenarios = {}
    for name, sc in bench_chaos.SCENARIOS.items():
        fs = faults.make_fault_spec(**sc["spec"])
        cfg = _chaos_cfg(det, faults.FaultConfig(hardened=True),
                         **sc["market"])
        free_cfg = _chaos_cfg(det, **sc["market"])
        lo, hi = ALERT_WINDOWS.get(name, (0.0, float(bench_chaos.TICKS)))

        free_alerts = 0
        per_seed = []
        first_ticks = []
        families: dict[str, int] = {}
        for s in seeds:
            _, free_rep = runner.run_obs(chaos_sched, free_cfg, seed=s)
            free_alerts += len(_alert_records(free_rep))
            _, rep = runner.run_obs(chaos_sched, cfg, seed=s, fspec=fs)
            recs = _alert_records(rep)
            per_seed.append(len(recs))
            if recs:
                first_ticks.append(min(r.tick for r in recs))
                for r in recs:
                    families[r.kind_name] = families.get(r.kind_name, 0) + 1

        scenarios[name] = {
            "fault_free_alerts": int(free_alerts),
            "alerts_per_seed": per_seed,
            "alerts_total": int(sum(per_seed)),
            "first_ticks": [int(t) for t in first_ticks],
            "families": families,
            "window": [lo, hi],
            "first_in_window": bool(first_ticks) and all(
                lo <= t <= hi for t in first_ticks),
        }

    return {
        "clean": {"seeds": list(seeds), "alerts": int(clean_alerts)},
        "scenarios": scenarios,
    }


def calibration_ok(cal: dict) -> bool:
    return (cal["clean"]["alerts"] == 0 and all(
        sc["fault_free_alerts"] == 0
        and min(sc["alerts_per_seed"], default=0) >= 1
        and sc["first_in_window"]
        for sc in cal["scenarios"].values()))


def main(emit, smoke: bool = False) -> dict:
    seeds = tuple(range(2 if smoke else 4))
    mults = SMOKE_MULTS if smoke else FULL_MULTS

    neutral = run_neutrality(seeds, mults)
    emit("obs_neutral_sweep_exact", float(neutral["sweep_exact"]), "bool")
    emit("obs_neutral_detect_exact", float(neutral["detect_exact"]), "bool")
    emit("obs_neutral_run_exact", float(neutral["run_exact"]), "bool")

    overhead = run_overhead(seeds, mults)
    emit("obs_overhead_ratio", overhead["overhead_ratio"],
         f"ceiling<={OBS_OVERHEAD_CEILING};"
         f"base={overhead['base']['steady_s']};"
         f"obs={overhead['obs']['steady_s']}")

    exports = run_exports(seeds, mults)
    emit("obs_trace_spans_ok", float(exports["spans_ok"]),
         f"chunks={exports['n_chunks']}")
    emit("obs_ledger_events", float(exports["ledger_events"]),
         f"dropped={exports['ledger_dropped']}")

    cal = run_calibration(seeds)
    emit("obs_cal_clean_alerts", float(cal["clean"]["alerts"]), "gate==0")
    for name, sc in cal["scenarios"].items():
        emit(f"obs_cal_{name}_alerts", float(sc["alerts_total"]),
             f"free={sc['fault_free_alerts']};"
             f"first={sc['first_ticks']};"
             f"window={sc['window']};"
             f"in_window={sc['first_in_window']}")

    neutral_ok = (neutral["sweep_exact"] and neutral["detect_exact"]
                  and neutral["run_exact"])
    overhead_ok = overhead["overhead_ratio"] <= OBS_OVERHEAD_CEILING
    exports_ok = exports["spans_ok"] and exports["manifest_profile_ok"]
    cal_ok = calibration_ok(cal)
    emit("obs_acceptance_neutral", float(neutral_ok), "bool")
    emit("obs_acceptance_overhead", float(overhead_ok), "bool")
    emit("obs_acceptance_calibration", float(cal_ok), "bool")

    report = {
        "kind": "obs",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "seeds": list(seeds),
            "bid_mults": list(mults),
            "policies": list(POLICIES),
            "ledger_cap": LEDGER_CAP,
            "overhead_ceiling": OBS_OVERHEAD_CEILING,
        },
        "neutrality": neutral,
        "overhead": overhead,
        "exports": exports,
        "calibration": cal,
        "acceptance": {
            "neutral_exact": bool(neutral_ok),
            "overhead_bounded": bool(overhead_ok),
            "exports_ok": bool(exports_ok),
            "calibration_ok": bool(cal_ok),
        },
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_obs.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not (neutral_ok and overhead_ok and exports_ok and cal_ok):
        raise SystemExit(
            "obs acceptance not met: "
            f"neutral={neutral_ok} "
            f"overhead_ratio={overhead['overhead_ratio']} "
            f"exports_ok={exports_ok} "
            f"calibration={cal_ok}")
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI; same acceptance checks")
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
