"""Paper Figs. 4-5 + Table III: cumulative billing cost of AIMD vs
Reactive / MWA / LR / Amazon-Autoscale vs the 100%-utilization LB, under
both TTC settings; plus the termination-semantics ablation (beyond paper).
"""

from __future__ import annotations

import csv
import os

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import SimConfig, paper_schedule, run
from repro.sim.runner import total_cost as _total_cost

from .common import TTC_CONSERVATIVE, TTC_FAST, run_policy

POLICIES = ("aimd", "reactive", "mwa", "lr", "autoscale")


def run_per_second_billing(seeds=(0, 1)) -> dict:
    """Beyond-paper ablation: post-2017 per-second billing (60 s quantum).
    §II.C predicts the quantized-billing penalty drives the policy gaps;
    with fine-grained billing every policy should approach LB."""
    out = {}
    params = ControlParams(monitor_dt=300.0)
    # same hourly RATE, 60 s billing quanta
    bill = BillingParams(quantum=60.0, price_per_quantum=0.0081 * 60 / 3600,
                         terminate="immediate")
    for policy in POLICIES:
        costs = []
        for seed in seeds:
            sched = paper_schedule(ttc=TTC_CONSERVATIVE,
                                   arrival_gap_ticks=1, seed=seed)
            cfg = SimConfig(ctrl=ControllerConfig(
                policy=policy, params=params, billing=bill, as_step=10.0),
                ticks=140, seed=seed)
            costs.append(_total_cost(run(sched, cfg)))
        out[policy] = float(np.mean(costs))
    return out


def run_table3(seeds=(0, 1, 2), terminate="immediate") -> dict:
    """Paper-faithful termination is 'immediate' (release now, forfeit the
    rest of the quantum — §IV minimizes but cannot avoid the forfeit);
    'boundary' is this framework's beyond-paper improvement."""
    return _run_table3(seeds, terminate)


def _run_table3(seeds, terminate) -> dict:
    out = {}
    for ttc, as_step, tag in ((TTC_CONSERVATIVE, 1.0, "conservative"),
                              (TTC_FAST, 10.0, "fast")):
        rows = {}
        for policy in POLICIES:
            costs, max_ns, viols, lbs = [], [], [], []
            for seed in seeds:
                r = run_policy(policy, ttc, seed=seed, as_step=as_step,
                               terminate=terminate)
                costs.append(r["cost"])
                max_ns.append(r["max_n"])
                viols.append(r["violations"])
                lbs.append(r["lb"])
            rows[policy] = {
                "cost": float(np.mean(costs)),
                "max_n": float(np.max(max_ns)),
                "violations": int(np.sum(viols)),
                "over_lb_pct": float(100 * (np.mean(costs) - np.mean(lbs))
                                     / np.mean(lbs)),
            }
        a = rows["aimd"]["cost"]
        for policy in POLICIES:
            c = rows[policy]["cost"]
            rows[policy]["aimd_saving_pct"] = float(100 * (c - a) / c) \
                if policy != "aimd" else 0.0
        rows["lb"] = {"cost": float(np.mean(lbs))}
        out[tag] = rows
    return out


def write_curves(path: str, seeds=(0,)) -> None:
    """Fig. 4/5-style cumulative-cost curves (CSV per TTC), plus a summary
    CSV carrying each policy's final cost *and TTC violation count* — a run
    that never finishes its workloads must read as broken, not as cheap."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    for ttc, as_step, tag in ((TTC_CONSERVATIVE, 1.0, "fig4"),
                              (TTC_FAST, 10.0, "fig5")):
        rows, summary = {}, {}
        for policy in POLICIES:
            r = run_policy(policy, ttc, seed=seeds[0], as_step=as_step)
            rows[policy] = np.asarray(r["trace"].cum_cost)
            summary[policy] = (r["cost"], r["violations"])
        with open(f"{path}_{tag}.csv", "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["tick"] + list(POLICIES))
            for t in range(len(rows["aimd"])):
                w.writerow([t] + [f"{rows[p][t]:.4f}" for p in POLICIES])
        with open(f"{path}_{tag}_summary.csv", "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["policy", "cost", "violations"])
            for policy in POLICIES:
                cost, viol = summary[policy]
                w.writerow([policy, f"{cost:.4f}", viol])


def main(emit) -> None:
    t3 = run_table3()
    for tag, rows in t3.items():
        for policy in POLICIES:
            r = rows[policy]
            emit(f"tab3_{tag}_{policy}_cost", r["cost"],
                 f"maxN={r['max_n']:.0f};viol={r['violations']};"
                 f"overLB={r['over_lb_pct']:.0f}%;"
                 f"aimd_saves={r['aimd_saving_pct']:.0f}%")
        emit(f"tab3_{tag}_lb", rows["lb"]["cost"], "lower_bound_usd")
    # Beyond-paper improvement: boundary-drain termination (reclaim exactly
    # at the quantum boundary; nothing paid is forfeited) — for ALL policies.
    bnd = run_table3(seeds=(0, 1), terminate="boundary")
    for tag in ("conservative", "fast"):
        for policy in POLICIES:
            base = t3[tag][policy]["cost"]
            impr = bnd[tag][policy]["cost"]
            emit(f"beyond_boundary_{tag}_{policy}_cost", impr,
                 f"vs_immediate=${base:.3f};saves="
                 f"{100 * (base - impr) / base:.0f}%")
    # Beyond-paper ablation: per-second (60 s quantum) billing.
    ps = run_per_second_billing()
    for policy, c in ps.items():
        emit(f"ablate_per_second_{policy}_cost", c, "quantum=60s")
    write_curves("results/curves")
