"""Per-scenario cost frontier: the workload world as a sweep axis.

Every earlier result is conditioned on the single deterministic §V.A
schedule, so the >27% spot-saving headline is a one-scenario claim.  This
benchmark evaluates the AIMD-vs-Reactive comparison across the stochastic
scenario families of ``sim.scenarios`` (Poisson, bursty MMPP, diurnal,
flash-crowd, heavy-tailed Pareto sizes) — each grid point samples its own
workload world from (seed, scenario) *inside* one jitted
``sweep(SweepSpec(workload=ScenarioSet, ...))`` call — and re-runs the
paper headline
through the scenario engine's replay path, asserting the result is
**bit-for-bit identical** to today's static-schedule path
(``bench_spot.run_headline``).

Emits ``results/BENCH_scenarios.json`` (``kind: "scenarios"``), gated in
CI by ``benchmarks/check_bench_regression.py`` against
``benchmarks/baselines/``: the paper replay must stay exactly equal to the
legacy path and above the 27% floor, and the AIMD saving must stay
positive on every stochastic scenario.

CLI:  PYTHONPATH=src python -m benchmarks.bench_scenarios [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.sim import (
    ScenarioSet,
    SimConfig,
    SpotConfig,
    SweepSpec,
    default_set,
    make_axes,
    paper_schedule,
)
from repro.sim.sweep import sweep
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim.scenarios import Replay

try:  # package-relative when run via ``-m benchmarks...``; standalone too
    from . import bench_spot
    from .common import TTC_FAST
except ImportError:  # pragma: no cover
    import bench_spot

    TTC_FAST = 6300.0

SCHEMA_VERSION = 1
SAVING_FLOOR_PCT = 27.0
# Scenario-frontier settings: 5-min monitoring over a 60-tick window, the
# never-preempted bid, m3.medium fleet — isolating the *workload world* as
# the only thing that changes between grid columns.
TICKS = 60
MONITOR_DT = 300.0


def _cfg(policy: str) -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(
            policy=policy,
            params=ControlParams(monitor_dt=MONITOR_DT),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=TICKS,
        spot=SpotConfig(enabled=True, bid_policy="on_demand"),
    )


def run_paper_replay(seeds) -> dict:
    """The paper headline through the scenario engine's replay path, and
    the exact-match check against the legacy static-schedule path."""
    ref = bench_spot.run_headline(seeds=seeds)
    sched = paper_schedule(ttc=TTC_FAST, arrival_gap_ticks=5)
    sset = ScenarioSet((Replay(sched, name="paper"),))
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0], scenarios=sset)
    out = {}
    exact = True
    for policy in ("aimd", "reactive"):
        # The *same* config builder run_headline used, so the replay and
        # the legacy path cannot silently desynchronize.
        cfg = bench_spot._spot_cfg(
            policy, monitor_dt=60.0, ticks=650, bid_policy="on_demand"
        )
        s = sweep(SweepSpec(axes=axes, workload=sset), cfg)
        cost = float(np.mean(np.asarray(s.cost)))
        viol = int(np.sum(np.asarray(s.violations)))
        same = cost == ref[policy]["cost"] and viol == ref[policy]["violations"]
        exact = exact and same
        out[policy] = {"cost": cost, "violations": viol}
    a, r = out["aimd"]["cost"], out["reactive"]["cost"]
    return {
        "aimd_cost": a,
        "reactive_cost": r,
        "saving_pct": float(100.0 * (r - a) / r),
        "aimd_violations": out["aimd"]["violations"],
        "reactive_violations": out["reactive"]["violations"],
        "exact_match": bool(exact),
    }


def run_scenario_frontier(seeds) -> dict:
    """AIMD vs Reactive across every stochastic scenario family — one
    jitted seeds × scenarios sweep per controller policy."""
    sset = default_set()
    axes = make_axes(
        seeds=list(seeds),
        bid_mults=[1.0],
        policies=["on_demand"],
        scenarios=sset,
    )
    shape = (len(list(seeds)), len(sset))
    per_policy = {}
    for policy in ("aimd", "reactive"):
        s = sweep(SweepSpec(axes=axes, workload=sset), _cfg(policy))
        per_policy[policy] = {
            "cost": np.asarray(s.cost).reshape(shape),
            "violations": np.asarray(s.violations).reshape(shape),
            "finished": np.asarray(s.finished).reshape(shape),
            "max_committed": np.asarray(s.max_committed).reshape(shape),
        }
    scenarios = {}
    aimd, reactive = per_policy["aimd"], per_policy["reactive"]
    for j, name in enumerate(sset.names):
        a = float(aimd["cost"][:, j].mean())
        r = float(reactive["cost"][:, j].mean())
        scenarios[name] = {
            "aimd_cost": a,
            "reactive_cost": r,
            "saving_pct": float(100.0 * (r - a) / r),
            "aimd_violations": int(aimd["violations"][:, j].sum()),
            "reactive_violations": int(reactive["violations"][:, j].sum()),
            "finished": int(aimd["finished"][:, j].sum()),
            "peak_cus": float(aimd["max_committed"][:, j].max()),
        }
    return scenarios


def main(emit, smoke: bool = False) -> dict:
    hl_seeds = (0, 1) if smoke else (0, 1, 2)
    seeds = tuple(range(2 if smoke else 6))

    paper = run_paper_replay(hl_seeds)
    emit(
        "scen_paper_saving_pct",
        paper["saving_pct"],
        f"target>={SAVING_FLOOR_PCT};exact={paper['exact_match']}",
    )

    scenarios = run_scenario_frontier(seeds)
    for name, sc in scenarios.items():
        emit(
            f"scen_{name}_saving_pct",
            sc["saving_pct"],
            f"aimd={sc['aimd_cost']:.3f};reactive={sc['reactive_cost']:.3f};"
            f"aviol={sc['aimd_violations']};rviol={sc['reactive_violations']}",
        )

    all_positive = all(sc["saving_pct"] > 0.0 for sc in scenarios.values())
    paper_ok = paper["exact_match"] and paper["saving_pct"] >= SAVING_FLOOR_PCT
    emit("scen_acceptance_paper_exact", float(paper["exact_match"]), "bool")
    emit("scen_acceptance_all_savings_positive", float(all_positive), "bool")

    report = {
        "kind": "scenarios",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "seeds": list(seeds),
            "headline_seeds": list(hl_seeds),
            "scenario_names": list(default_set().names),
        },
        "paper": paper,
        "scenarios": scenarios,
        "acceptance": {
            "paper_exact": bool(paper["exact_match"]),
            "paper_saving_ge_floor": bool(paper["saving_pct"] >= SAVING_FLOOR_PCT),
            "all_savings_positive": bool(all_positive),
            "saving_floor_pct": SAVING_FLOOR_PCT,
        },
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_scenarios.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not (paper_ok and all_positive):
        raise SystemExit(
            "scenario acceptance not met: "
            f"paper_exact={paper['exact_match']} "
            f"paper_saving={paper['saving_pct']:.1f}% "
            f"all_savings_positive={all_positive}"
        )
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced seed count for CI; same acceptance checks",
    )
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
