"""Spot-market fleet benchmarks (paper Appendix A + headline cost claim).

Three experiments, each a *single* ``jax.jit(jax.vmap(...))`` call over the
full simulation (``sim.sweep``):

  * headline  — AIMD-on-spot vs the Reactive baseline on the same live
                market (paper schedule, 1-min monitoring, fast TTC,
                paper-faithful immediate termination, on-demand bid).  The
                paper reports >27% spot-cost reduction; this testbed's gap
                at the same settings is far wider because Reactive's churn
                forfeits paid quanta every cycle.
  * bid sweep — seeds × bid levels at 5-min monitoring: cost, TTC
                violations and preemption count per bid.  Preemptions must
                occur at the lowest bid and vanish as the bid rises.
  * granularity frontier — Appendix A Table V: the same CU demand served
                by many m3.medium vs few m4.10xlarge; per-CU price and
                volatility both grow with instance size, so coarse fleets
                pay more and get preempted more.

CSVs land in ``results/`` and always carry the violation counts, so a run
that quietly failed its SLAs can never masquerade as a cheap one.

CLI:  PYTHONPATH=src python -m benchmarks.bench_spot [--smoke]
"""

from __future__ import annotations

import argparse
import csv
import os

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, make_axes,
                       paper_schedule)
from repro.sim.sweep import sweep
from repro.sim.spot import INSTANCE_NAMES

try:  # package-relative when run via ``-m benchmarks...``; standalone too
    from .common import TTC_FAST
except ImportError:  # pragma: no cover
    TTC_FAST = 6300.0

BID_LEVELS = (1.02, 1.2, 1.5, 2.5)


def _spot_cfg(policy: str, *, monitor_dt: float, ticks: int,
              terminate: str = "immediate", **spot_kw) -> SimConfig:
    params = ControlParams(monitor_dt=monitor_dt,
                           arma_window=10 if monitor_dt <= 60.0 else 3)
    return SimConfig(
        ctrl=ControllerConfig(policy=policy, params=params,
                              billing=BillingParams(terminate=terminate)),
        ticks=ticks, spot=SpotConfig(enabled=True, **spot_kw))


def run_headline(seeds=(0, 1, 2)) -> dict:
    """AIMD vs Reactive on the same spot market, paper headline settings:
    1-min monitoring, fast TTC, immediate (paper-faithful) termination,
    bidding the on-demand price (the classic never-lose-capacity bid)."""
    sched = paper_schedule(ttc=TTC_FAST, arrival_gap_ticks=5)
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0])
    out = {}
    for policy in ("aimd", "reactive"):
        cfg = _spot_cfg(policy, monitor_dt=60.0, ticks=650,
                        bid_policy="on_demand")
        s = sweep(SweepSpec(axes=axes, workload=sched), cfg)
        out[policy] = {
            "cost": float(np.mean(s.cost)),
            "violations": int(np.sum(s.violations)),
            "preemptions": float(np.sum(s.preemptions)),
        }
    a, r = out["aimd"]["cost"], out["reactive"]["cost"]
    out["saving_pct"] = float(100.0 * (r - a) / r)
    return out


def run_bid_sweep(seeds=(0, 1, 2), bid_mults=BID_LEVELS) -> dict:
    """seeds × bid levels in one jitted vmap; cost/violations/preemptions
    per bid level (mean/sum over seeds)."""
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    cfg = _spot_cfg("aimd", monitor_dt=300.0, ticks=130)
    axes = make_axes(seeds=list(seeds), bid_mults=list(bid_mults))
    s = sweep(SweepSpec(axes=axes, workload=sched), cfg)
    shape = (len(seeds), len(bid_mults))
    return {
        "axes": axes,
        "summary": s,
        "bid_mults": list(bid_mults),
        "cost": np.asarray(s.cost).reshape(shape),
        "violations": np.asarray(s.violations).reshape(shape),
        "preemptions": np.asarray(s.preemptions).reshape(shape),
    }


def run_granularity(seeds=(0, 1, 2), instances=INSTANCE_NAMES) -> dict:
    """Instance-granularity frontier at the on-demand bid: cost and
    preemption rate per Appendix-A instance type."""
    sched = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    cfg = _spot_cfg("aimd", monitor_dt=300.0, ticks=130,
                    bid_policy="on_demand")
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0],
                     instances=list(instances))
    s = sweep(SweepSpec(axes=axes, workload=sched), cfg)
    shape = (len(seeds), len(instances))
    return {
        "instances": list(instances),
        "cost": np.asarray(s.cost).reshape(shape),
        "violations": np.asarray(s.violations).reshape(shape),
        "preemptions": np.asarray(s.preemptions).reshape(shape),
        "mean_price": np.asarray(s.mean_price).reshape(shape),
    }


def write_csvs(bid: dict, gran: dict, outdir: str = "results") -> None:
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "spot_bid_sweep.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["bid_mult", "mean_cost", "violations", "preemptions"])
        for j, b in enumerate(bid["bid_mults"]):
            w.writerow([b, f"{bid['cost'][:, j].mean():.4f}",
                        int(bid["violations"][:, j].sum()),
                        f"{bid['preemptions'][:, j].sum():.0f}"])
    with open(os.path.join(outdir, "spot_granularity.csv"), "w",
              newline="") as f:
        w = csv.writer(f)
        w.writerow(["instance", "mean_cost", "violations", "preemptions",
                    "mean_price"])
        for j, name in enumerate(gran["instances"]):
            w.writerow([name, f"{gran['cost'][:, j].mean():.4f}",
                        int(gran["violations"][:, j].sum()),
                        f"{gran['preemptions'][:, j].sum():.0f}",
                        f"{gran['mean_price'][:, j].mean():.4f}"])


def main(emit, smoke: bool = False) -> None:
    seeds = (0, 1) if smoke else (0, 1, 2)
    hl = run_headline(seeds=seeds)
    for policy in ("aimd", "reactive"):
        r = hl[policy]
        emit(f"spot_headline_{policy}_cost", r["cost"],
             f"viol={r['violations']};preempt={r['preemptions']:.0f}")
    emit("spot_headline_aimd_saving_pct", hl["saving_pct"],
         "target>=25;paper>27")

    # The acceptance sweep: >= 3 seeds x >= 3 bid levels, one jitted vmap.
    bid = run_bid_sweep(seeds=(0, 1, 2),
                        bid_mults=BID_LEVELS[:3] if smoke else BID_LEVELS)
    for j, b in enumerate(bid["bid_mults"]):
        emit(f"spot_bid_{b}_cost", float(bid["cost"][:, j].mean()),
             f"viol={int(bid['violations'][:, j].sum())};"
             f"preempt={bid['preemptions'][:, j].sum():.0f}")

    gran = run_granularity(
        seeds=seeds,
        instances=("m3.medium", "m4.10xlarge") if smoke else INSTANCE_NAMES)
    for j, name in enumerate(gran["instances"]):
        emit(f"spot_gran_{name}_cost", float(gran["cost"][:, j].mean()),
             f"viol={int(gran['violations'][:, j].sum())};"
             f"preempt={gran['preemptions'][:, j].sum():.0f};"
             f"mean_price={gran['mean_price'][:, j].mean():.4f}")
    write_csvs(bid, gran)

    saving_ok = hl["saving_pct"] >= 25.0
    lowest_bid_preempted = bid["preemptions"][:, 0].sum() > 0
    emit("spot_acceptance_saving_ge_25pct", float(saving_ok), "bool")
    emit("spot_acceptance_lowest_bid_preempts", float(lowest_bid_preempted),
         "bool")
    if not (saving_ok and lowest_bid_preempted):
        raise SystemExit("spot acceptance criteria not met: "
                         f"saving={hl['saving_pct']:.1f}% "
                         f"preempt@low_bid={bid['preemptions'][:, 0].sum()}")


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced seed count for CI; same acceptance checks")
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
