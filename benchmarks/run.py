"""Benchmark suite — one module per paper table/figure.

Emits ``name,value,derived`` CSV rows (value is the headline number of the
artifact; ``derived`` packs the secondary columns).

  bench_prediction   -> Table II   (time-to-reliable + MAE per estimator)
  bench_convergence  -> Fig. 3     (estimator traces; CSV artifact)
  bench_cost         -> Figs. 4-5 + Table III (cumulative cost, 5 policies)
  bench_lambda       -> Table IV   (per-image cost vs AWS Lambda)
  bench_kernels      -> kernel micro-benchmarks (host timings)
  bench_roofline     -> §Roofline summary over the dry-run sweep
  bench_spot         -> Appendix A (spot market: headline saving, bid sweep,
                        instance-granularity frontier)
  bench_throughput   -> sweep-engine throughput: summary vs trace mode,
                        chunked 100x grid (BENCH_throughput.json)
"""

import sys
import time


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    from . import (bench_convergence, bench_cost, bench_kernels,
                   bench_lambda, bench_prediction, bench_roofline,
                   bench_spot, bench_throughput)
    suites = {
        "prediction": bench_prediction,
        "convergence": bench_convergence,
        "cost": bench_cost,
        "lambda": bench_lambda,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
        "spot": bench_spot,
        "throughput": bench_throughput,
    }
    print("name,value,derived")

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    for name, mod in suites.items():
        if only and only != name:
            continue
        t0 = time.time()
        try:
            mod.main(emit)
            emit(f"_suite_{name}_wall_s", time.time() - t0, "ok")
        except Exception as e:  # noqa: BLE001 — a failed suite must not
            emit(f"_suite_{name}_wall_s", time.time() - t0,  # hide others
                 f"FAILED:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
