"""Benchmark suite driver — auto-discovers every ``benchmarks/bench_*.py``.

Emits ``name,value,derived`` CSV rows (value is the headline number of the
artifact; ``derived`` packs the secondary columns).

Discovery replaces the old hand-maintained suite table: any module named
``bench_<suite>.py`` in this directory is picked up automatically, so a
newly added benchmark can never silently miss CI — the CI bench job runs
``python -m benchmarks.run --smoke`` instead of hand-listing steps, then
gates every ``results/BENCH_*.json`` against ``benchmarks/baselines/``
via ``check_bench_regression.py --auto``.

Each suite module exposes ``main(emit)`` — or ``main(emit, smoke=...)``
for the suites with a reduced CI mode; ``--smoke`` is forwarded to those
that accept it.  A failing suite (exception *or* a ``SystemExit`` from an
acceptance check) is reported in its ``_suite_*`` row and turns the exit
code non-zero, but never hides the remaining suites.

``--json PATH`` additionally writes a machine-readable report — one
record per suite (name, ok, wall_s, error, and ``gate``: the regression
verdict of the suite's ``results/BENCH_*.json`` against its committed
baseline, via ``check_bench_regression.gate_errors``) plus the overall
verdict — for CI artifact upload and downstream dashboards; the CSV on
stdout is unchanged.  The gate column is advisory inside this report
(CI still runs ``check_bench_regression --auto`` as its own failing
step, with attribution); suites whose artifact has no committed
baseline report ``gate: null``.

CLI:  PYTHONPATH=src python -m benchmarks.run [--smoke] [--json PATH] [suite]
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import json
import pathlib
import sys
import time

BASELINES_DIR = pathlib.Path(__file__).resolve().parent / "baselines"


def discover() -> dict:
    """suite name → module *name*, for every ``bench_*.py`` beside this
    file.  Import happens lazily inside each suite's try/except, so one
    module with an import-time error cannot hide the remaining suites."""
    here = pathlib.Path(__file__).resolve().parent
    package = __package__ or "benchmarks"
    return {path.stem[len("bench_"):]: f"{package}.{path.stem}"
            for path in sorted(here.glob("bench_*.py"))}


def _suite_gate(started: float) -> tuple[bool | None, list[str]]:
    """Regression-gate every ``results/BENCH_*.json`` the suite that just
    ran (re)wrote, against its committed baseline.  Returns the combined
    verdict (``None`` when no refreshed artifact has a baseline) and the
    per-artifact failure messages."""
    try:
        from .check_bench_regression import gate_errors
    except ImportError:          # direct script execution
        from check_bench_regression import gate_errors
    verdict: bool | None = None
    errors: list[str] = []
    for artifact in sorted(pathlib.Path("results").glob("BENCH_*.json")):
        if artifact.stat().st_mtime < started:
            continue             # stale: written by an earlier suite/run
        baseline = BASELINES_DIR / artifact.name
        if not baseline.exists():
            continue
        try:
            current = json.loads(artifact.read_text())
            base = json.loads(baseline.read_text())
            errs = gate_errors(current, base)
        except (OSError, ValueError) as e:
            errs = [f"unreadable ({e})"]
        verdict = (verdict is not False) and not errs
        errors.extend(f"{artifact.name}: {e}" for e in errs)
    return verdict, errors


def _call_suite(module_name: str, emit, smoke: bool) -> None:
    """Import and run one suite's ``main``, forwarding ``smoke`` when it
    accepts it."""
    mod = importlib.import_module(module_name)
    sig = inspect.signature(mod.main)
    if "smoke" in sig.parameters:
        mod.main(emit, smoke=smoke)
    else:
        mod.main(emit)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="run a single suite (e.g. 'spot', 'tuning')")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI on suites that support it")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write a machine-readable per-suite report "
                         "(pass/fail + wall clock) to this path")
    args = ap.parse_args(argv)

    suites = discover()
    if args.only is not None and args.only not in suites:
        print(f"unknown suite {args.only!r}; discovered: "
              f"{', '.join(suites)}", file=sys.stderr)
        return 2
    print("name,value,derived")

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    failures: list[str] = []
    records: list[dict] = []
    for name, module_name in suites.items():
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            _call_suite(module_name, emit, args.smoke)
            wall = time.time() - t0
            gate, gate_errs = _suite_gate(t0)
            status = "ok" if gate is None else f"ok;gate={'pass' if gate else 'FAIL'}"
            emit(f"_suite_{name}_wall_s", wall, status)
            records.append({"suite": name, "ok": True,
                            "wall_s": round(wall, 3), "error": None,
                            "gate": gate, "gate_errors": gate_errs})
        except (Exception, SystemExit) as e:  # a failed suite (even at
            wall = time.time() - t0           # import) must not hide the
            err = f"{type(e).__name__}:{e}"   # others
            emit(f"_suite_{name}_wall_s", wall, f"FAILED:{err}")
            records.append({"suite": name, "ok": False,
                            "wall_s": round(wall, 3), "error": err,
                            "gate": None, "gate_errors": []})
            failures.append(name)
    if args.json:
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        gates_ok = all(r["gate"] is not False for r in records)
        path.write_text(json.dumps(
            {"smoke": bool(args.smoke), "ok": not failures,
             "gates_ok": gates_ok, "suites": records}, indent=2) + "\n")
    if failures:
        print(f"benchmark suites failed: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
