"""Kernel micro-benchmarks: pure-JAX reference timings under jit on this
host (CPU), plus interpret-mode correctness deltas for the Pallas kernels.
(TPU wall-times are not measurable here; §Roofline covers the lowered
performance model.)"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.kalman_update.ops import kalman_update, resolve_interpret
from repro.kernels.kalman_update.ref import kalman_fused_ref
from repro.models.attention import AttnSpec, flash_attention
from repro.models.ssm import ssd_chunked


def _bench(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(emit) -> None:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    # flash attention (jnp blocked path — the dry-run lowering)
    b, s, h, kv, hd = 1, 2048, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.bfloat16)
    spec = AttnSpec(n_heads=h, n_kv=kv, hd=hd)
    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, spec))
    us = _bench(flash, q, k, v)
    flops = 4 * b * h * s * s * hd / 2   # causal
    emit("kern_flash_2k_us", us, f"gflops_cpu={flops / us / 1e3:.1f}")

    # SSD chunked scan
    bs, ss, hh, pp, nn = 1, 2048, 8, 64, 128
    x = jax.random.normal(ks[0], (bs, ss, hh, pp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, ss, hh)))
    a_log = jax.random.normal(ks[2], (hh,)) * 0.5
    bb = jax.random.normal(ks[3], (bs, ss, nn))
    cc = jax.random.normal(ks[4], (bs, ss, nn))
    ssd = jax.jit(lambda *a: ssd_chunked(*a, 128)[0])
    emit("kern_ssd_2k_us", _bench(ssd, x, dt, a_log, bb, cc), "chunk=128")

    # fused Kalman fleet update at 1M estimators
    w, kk = 4096, 256
    b_hat = jax.random.normal(ks[0], (w, kk)) ** 2
    pi = jax.random.normal(ks[1], (w, kk)) ** 2
    meas = jax.random.normal(ks[2], (w, kk)) ** 2
    mask = jax.random.bernoulli(ks[3], 0.5, (w, kk))
    fused = jax.jit(lambda *a: kalman_fused_ref(*a, 0.5, 0.5))
    us = _bench(fused, b_hat, pi, meas, mask)
    emit("kern_kalman_1M_us", us,
         f"estimators_per_s={w * kk / us * 1e6 / 1e9:.2f}B")

    # Pallas kernel vs the jnp reference: platform-aware interpret mode
    # (compiled on TPU, emulated here), correctness delta + timing.
    pallas = jax.jit(lambda *a: kalman_update(*a))
    us_p = _bench(pallas, b_hat, pi, meas, mask)
    b_p, pi_p = pallas(b_hat, pi, meas, mask)
    b_r, pi_r = fused(b_hat, pi, meas, mask)
    delta = max(float(np.abs(np.asarray(b_p) - np.asarray(b_r)).max()),
                float(np.abs(np.asarray(pi_p) - np.asarray(pi_r)).max()))
    emit("kern_kalman_pallas_1M_us", us_p,
         f"max_abs_delta_vs_ref={delta:.3g};"
         f"interpret={resolve_interpret(None)};"
         f"speedup_vs_ref={us / us_p:.2f}x")
