"""Chaos frontier: bounded degradation under traced fault injection.

The chaos engine (``sim.faults``) injects capacity outages, preemption
storms, Poisson mid-quantum hard-kills, telemetry dropouts/delays and
stragglers *inside* the jitted scan, and ``FaultConfig(hardened=...)``
flips every graceful-degradation response of the control plane at once
(hedged type selection, bounded jittered backoff, AIMD anti-windup,
Kalman covariance inflation, deadline-aware shedding).  This benchmark
commits the robustness claims of that machinery:

  1. **zero-fault bit-identity** — a neutral ``FaultSpec`` under the
     engine reproduces the engine-compiled-out bits exactly, and a
     fault-free sweep's result digest is pinned against the committed
     baseline so *any* PR that perturbs the no-chaos program is caught;
  2. **bounded inflation** — on every committed chaos scenario the
     hardened plane's score (mean cost + penalty × violations) stays
     within ``INFLATION_CEILING`` × its fault-free score;
  3. **hardening pays** — the hardened plane *strictly* beats the
     unhardened comparator (same physics, blind responses) on every
     committed scenario;
  4. **bounded recovery** — after a deterministic full-market outage
     clears, the faulted fleet re-reaches the fault-free trajectory's
     committed capacity within ``RECOVERY_CEILING`` ticks (the market
     PRNG chain is fault-independent, so the two traces genuinely
     reconverge rather than merely resembling each other).

Emits ``results/BENCH_chaos.json`` (``kind: "chaos"``), gated in CI by
``benchmarks/check_bench_regression.py`` against
``benchmarks/baselines/``.

CLI:  PYTHONPATH=src python -m benchmarks.bench_chaos [--smoke]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import ControlParams
from repro.sim import (
    SimConfig,
    SpotConfig,
    SweepSpec,
    faults,
    make_axes,
    paper_schedule,
    runner,
)
from repro.sim.spot import INSTANCE_NAMES
from repro.sim.sweep import sweep

SCHEMA_VERSION = 1
MONITOR_DT = 300.0
TICKS = 80
# Score: mean $ cost + PENALTY × mean TTC violations — violations must
# carry weight or a plane that sheds everything would look "cheap".
PENALTY = 2.0
# Gate ceilings (hard, baseline-independent).  Chaos scenarios are
# *supposed* to hurt; the claim is the hurt is bounded and recovery fast.
INFLATION_CEILING = 8.0
RECOVERY_CEILING = 24
# The deterministic full-market outage window of the recovery probe.
OUTAGE_START, OUTAGE_TICKS = 16.0, 14.0

# Tight deadlines + arrivals every other tick keep work arriving *during*
# outages, so admission control and hedged acquisition have something to
# decide (a pre-loaded queue makes every plane look the same).
TTC_TIGHT = 5820.0


def _sched():
    return paper_schedule(ttc=TTC_TIGHT, arrival_gap_ticks=2)


def _cfg(fault_cfg=None, **kw):
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=MONITOR_DT)),
        ticks=TICKS,
        spot=SpotConfig(enabled=True, **kw),
        faults=fault_cfg,
    )


# The committed chaos scenarios.  Each pairs market knobs with a
# ``FaultSpec``; every scenario keeps an availability component (random
# per-type dry-ups or the deterministic window) because that is where the
# hardened plane's hedging/backoff/shedding can act — pure slot noise
# degrades both planes identically by construction.
SCENARIOS = {
    # Random per-type dry-ups on a mixed fleet: the hardened plane hedges
    # acquisition across the remaining types, the blind plane keeps
    # bidding into the dried-up best-price type.
    "dryups": {
        "market": {"fleet": INSTANCE_NAMES, "instance": "m3.medium"},
        "spec": {
            "p_outage": 2.0,
            "outage_hours": 1.5,
            "p_meas_drop": 0.3,
        },
    },
    # A sustained full-market blackout with arrivals still landing:
    # deadline-aware shedding and AIMD anti-windup are the only levers.
    "blackout": {
        "market": {"instance": "m3.medium"},
        "spec": {
            "outage_start": OUTAGE_START,
            "outage_ticks": 18.0,
            "p_meas_drop": 0.3,
        },
    },
    # Correlated preemption storms + Poisson hard-kills + degraded
    # telemetry, with moderate dry-ups so reacquisition is contested.
    "storm_kills": {
        "market": {"fleet": INSTANCE_NAMES, "instance": "m3.medium"},
        "spec": {
            "p_storm": 0.5,
            "storm_frac": 0.3,
            "p_slot_fail": 0.3,
            "p_outage": 1.0,
            "outage_hours": 0.5,
            "p_meas_drop": 0.4,
            "p_meas_delay": 0.2,
            "p_straggle": 0.5,
            "straggle_ticks": 4.0,
            "straggle_factor": 3.0,
        },
    },
}


def _score(s, n_seeds: int) -> tuple[float, float, int]:
    cost = float(np.mean(np.asarray(s.cost)))
    viol = int(np.sum(np.asarray(s.violations)))
    return cost + PENALTY * viol / n_seeds, cost, viol


def run_zero_fault(seeds) -> dict:
    """Bit-identity of the no-chaos program, two ways.

    ``neutral_exact``: the engine compiled *in* but fed a neutral spec
    reproduces the engine-compiled-out bits (pinned on an on-demand,
    spike-free market where the hardened backoff has nothing to react
    to).  ``digest``: sha256 over every summary field of an engine-off
    sweep — the regression gate compares it against the committed
    baseline, so zero-fault runs stay bit-identical *across PRs*.
    """
    sched = _sched()
    base = _cfg(bid_policy="on_demand", p_spike_per_core=0.0)
    chaos = _cfg(faults.FaultConfig(), bid_policy="on_demand",
                 p_spike_per_core=0.0)
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0])
    a = sweep(SweepSpec(axes=axes, workload=sched), base)
    b = sweep(SweepSpec(axes=axes, workload=sched), chaos)
    neutral_exact = all(
        np.array_equal(np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))
        for f in type(a)._fields
    )

    off = sweep(SweepSpec(axes=axes, workload=sched), _cfg())
    h = hashlib.sha256()
    for f in type(off)._fields:
        v = getattr(off, f)
        if v is None:   # leafless fields (alerts without obs.detect)
            continue    # contribute nothing, keeping old digests stable
        h.update(np.ascontiguousarray(np.asarray(v)).tobytes())
    return {"neutral_exact": bool(neutral_exact), "digest": h.hexdigest()}


def run_scenarios(seeds) -> dict:
    """Fault-free / hardened / unhardened scores per chaos scenario."""
    sched = _sched()
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0])
    n = len(list(seeds))
    out = {}
    for name, sc in SCENARIOS.items():
        mkw = sc["market"]
        fs = faults.make_fault_spec(**sc["spec"])
        free = sweep(SweepSpec(axes=axes, workload=sched), _cfg(**mkw))
        hard = sweep(
            SweepSpec(axes=axes, workload=sched, faults=fs),
            _cfg(faults.FaultConfig(hardened=True), **mkw),
        )
        blind = sweep(
            SweepSpec(axes=axes, workload=sched, faults=fs),
            _cfg(faults.FaultConfig(hardened=False), **mkw),
        )
        f_score, f_cost, f_viol = _score(free, n)
        h_score, h_cost, h_viol = _score(hard, n)
        u_score, u_cost, u_viol = _score(blind, n)
        out[name] = {
            "fault_free_score": f_score,
            "hardened_score": h_score,
            "unhardened_score": u_score,
            "fault_free_cost": f_cost,
            "hardened_cost": h_cost,
            "unhardened_cost": u_cost,
            "fault_free_violations": f_viol,
            "hardened_violations": h_viol,
            "unhardened_violations": u_viol,
            "inflation": h_score / max(f_score, 1e-9),
            "margin_pct": 100.0 * (u_score - h_score) / max(u_score, 1e-9),
        }
    return out


def run_recovery(seed: int = 0) -> dict:
    """Ticks after a blackout clears until the faulted fleet re-reaches
    the fault-free trajectory's committed capacity at the same tick.

    Both traces share the seed; the fault PRNG chain is salted separately
    from the market/execution chains, so outside the window the two runs
    see the *identical* world and the comparison is tick-for-tick fair.
    """
    sched = _sched()
    spec = faults.make_fault_spec(outage_start=OUTAGE_START,
                                  outage_ticks=OUTAGE_TICKS)
    tr_free = runner.run(sched, _cfg(), seed=seed)
    tr_fault = runner.run(sched, _cfg(faults.FaultConfig()), seed=seed,
                          fspec=spec)
    free_c = np.asarray(tr_free.n_committed)
    fault_c = np.asarray(tr_fault.n_committed)
    end = int(OUTAGE_START + OUTAGE_TICKS)
    recovered = np.nonzero(fault_c[end:] >= free_c[end:] - 1e-6)[0]
    ticks = int(recovered[0]) if recovered.size else TICKS
    return {
        "outage_start": int(OUTAGE_START),
        "outage_end": end,
        "recovery_ticks": ticks,
        "committed_at_recovery": float(fault_c[min(end + ticks, TICKS - 1)]),
    }


def main(emit, smoke: bool = False) -> dict:
    seeds = tuple(range(2 if smoke else 4))

    zero = run_zero_fault(seeds)
    emit("chaos_zero_fault_neutral_exact", float(zero["neutral_exact"]),
         "bool")

    scenarios = run_scenarios(seeds)
    for name, sc in scenarios.items():
        emit(
            f"chaos_{name}_margin_pct",
            sc["margin_pct"],
            f"hard={sc['hardened_score']:.3f};"
            f"blind={sc['unhardened_score']:.3f};"
            f"inflation={sc['inflation']:.2f}",
        )

    recovery = run_recovery()
    emit("chaos_recovery_ticks", float(recovery["recovery_ticks"]),
         f"ceiling<={RECOVERY_CEILING}")

    bounded = all(sc["inflation"] <= INFLATION_CEILING
                  for sc in scenarios.values())
    hardened_wins = all(sc["margin_pct"] > 0.0 for sc in scenarios.values())
    recovered = recovery["recovery_ticks"] <= RECOVERY_CEILING
    emit("chaos_acceptance_bounded_inflation", float(bounded), "bool")
    emit("chaos_acceptance_hardened_wins", float(hardened_wins), "bool")

    report = {
        "kind": "chaos",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "seeds": list(seeds),
            "penalty": PENALTY,
            "ttc": TTC_TIGHT,
            "inflation_ceiling": INFLATION_CEILING,
            "recovery_ceiling": RECOVERY_CEILING,
            "scenario_names": list(SCENARIOS),
        },
        "zero_fault": zero,
        "scenarios": scenarios,
        "recovery": recovery,
        "acceptance": {
            "zero_fault_exact": bool(zero["neutral_exact"]),
            "bounded_inflation_all": bool(bounded),
            "hardened_beats_unhardened_all": bool(hardened_wins),
            "recovery_bounded": bool(recovered),
        },
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_chaos.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not (zero["neutral_exact"] and bounded and hardened_wins
            and recovered):
        raise SystemExit(
            "chaos acceptance not met: "
            f"zero_fault_exact={zero['neutral_exact']} "
            f"bounded_inflation={bounded} "
            f"hardened_wins={hardened_wins} "
            f"recovery_ticks={recovery['recovery_ticks']}"
        )
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced seed count for CI; same acceptance checks",
    )
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
