"""Paper Table II: average time to a reliable CUS prediction + MAE, per
workload family, per estimator, at 5-min and 1-min monitoring."""

from __future__ import annotations

import numpy as np

from repro.sim import paper_schedule, run
from repro.sim.workloads import FAMILIES

from .common import (TTC_CONSERVATIVE, make_cfg, mae_at_reliable,
                     time_to_reliable_minutes)


def run_table(seeds=(0, 1, 2)) -> dict:
    out = {}
    for dt, ticks, gap in ((300.0, 130, 1), (60.0, 620, 5)):
        for pred in ("kalman", "adhoc", "arma"):
            times, maes, fams = [], [], []
            for seed in seeds:
                sched = paper_schedule(ttc=TTC_CONSERVATIVE,
                                       arrival_gap_ticks=gap, seed=seed)
                cfg = make_cfg(predictor=pred, monitor_dt=dt, ticks=ticks,
                               seed=seed)
                tr = run(sched, cfg)
                times.append(time_to_reliable_minutes(tr, sched, dt))
                maes.append(mae_at_reliable(tr, sched))
                fams.append(sched.family)
            t = np.concatenate(times)
            m = np.concatenate(maes)
            f = np.concatenate(fams)
            per_family = {}
            for fid, fname in enumerate(FAMILIES):
                sel = (f == fid) & ~np.isnan(t)
                per_family[fname] = {
                    "time_min": float(np.mean(t[sel])) if sel.any() else None,
                    "mae_pct": float(100 * np.nanmean(m[sel]))
                    if sel.any() else None,
                }
            sel = ~np.isnan(t)
            out[(int(dt), pred)] = {
                "per_family": per_family,
                "overall_time_min": float(np.mean(t[sel])),
                "overall_mae_pct": float(100 * np.nanmean(m[sel])),
                "reliable_frac": float(sel.mean()),
            }
    return out


def main(emit) -> None:
    table = run_table()
    for (dt, pred), row in table.items():
        emit(f"tab2_time_{dt // 60}min_{pred}", row["overall_time_min"],
             f"min_to_reliable;mae={row['overall_mae_pct']:.1f}%")
    # headline: Kalman faster than ad-hoc and ARMA at both intervals
    for dt in (300, 60):
        k = table[(dt, "kalman")]["overall_time_min"]
        a = table[(dt, "adhoc")]["overall_time_min"]
        r = table[(dt, "arma")]["overall_time_min"]
        emit(f"tab2_kalman_speedup_vs_adhoc_{dt // 60}min",
             100 * (a - k) / a, "pct_time_reduction")
        emit(f"tab2_kalman_speedup_vs_arma_{dt // 60}min",
             100 * (r - k) / r, "pct_time_reduction")
