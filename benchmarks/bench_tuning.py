"""Policy auto-tuning benchmark: in-jit CEM over the sweep engine.

The paper hand-sets its AIMD gains, bid multiple and bid-policy
coefficients and evaluates them on one workload; the PR-4 scenario engine
showed the AIMD-vs-Reactive saving swings 13–41% across workload worlds.
This benchmark exercises the ``repro.opt`` tuner subsystem end to end:

  * **joint tuning** — one jitted CEM run (≥8 generations × ≥32
    candidates × ≥4 seeds × ≥3 scenarios of full simulations) tunes the
    five ``PolicyParams`` coefficients across the stochastic scenario
    batch; the objective's trace counter proves the whole run compiled
    the sweep objective exactly once;
  * **per-scenario tuning** — the same machinery per workload world; the
    tuned parameters must *strictly* beat the hand-set defaults on every
    stochastic scenario (mean cost + violation penalty, identical batch);
  * **paper replay** — the §V.A headline re-run with the default
    ``PolicyParams`` passed explicitly must be bit-identical to
    ``bench_spot.run_headline`` (the refactor is a no-op at defaults);
  * **adversarial search** — the worst world of the MMPP family for the
    default policy, within the generator's parameter bounds;
  * **robust min–max** — alternating tune/attack; reports how much of the
    default policy's worst-case score the robust policy recovers on the
    final adversarial world (gap closure).

Emits ``results/BENCH_tuning.json`` (``kind: "tuning"``), gated in CI by
``check_bench_regression.py`` against ``benchmarks/baselines/``.

CLI:  PYTHONPATH=src python -m benchmarks.bench_tuning [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro import opt
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (
    ScenarioSet,
    SimConfig,
    SpotConfig,
    default_set,
    make_axes,
    SweepSpec,
    paper_schedule,
    runner,
)
from repro.sim.sweep import sweep
from repro.sim.scenarios import Replay

try:  # package-relative when run via ``-m benchmarks...``; standalone too
    from . import bench_spot
    from .common import TTC_FAST
except ImportError:  # pragma: no cover
    import bench_spot

    TTC_FAST = 6300.0

SCHEMA_VERSION = 1
TICKS = 60
MONITOR_DT = 300.0
PENALTY = 1.0  # $ per TTC violation in the tuning score
# The tuned scenarios: three distinct stochastic worlds of the PR-4 set.
SCENARIO_NAMES = ("poisson", "mmpp", "flash")
# A market where every tuned coefficient can matter: mid-size type with
# real volatility, frequent multi-hour spikes, TTC-aware bidding whose
# floor the market actually clears above.
MARKET = dict(
    instance="m3.xlarge",
    bid_policy="ttc",
    bid_mult=1.5,
    p_spike_per_core=0.02,
    spike_hours=3.0,
)


def _cfg(policy: str = "aimd") -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(
            policy=policy,
            params=ControlParams(monitor_dt=MONITOR_DT),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=TICKS,
        spot=SpotConfig(enabled=True, **MARKET),
    )


def run_paper_replay(seeds) -> dict:
    """The paper headline with the default ``PolicyParams`` passed
    *explicitly*, against ``bench_spot.run_headline`` (which never mentions
    them) — the promotion of the coefficients to traced inputs must be a
    bit-exact no-op at the defaults."""
    ref = bench_spot.run_headline(seeds=seeds)
    sched = paper_schedule(ttc=TTC_FAST, arrival_gap_ticks=5)
    sset = ScenarioSet((Replay(sched, name="paper"),))
    axes = make_axes(seeds=list(seeds), bid_mults=[1.0], scenarios=sset)
    out = {}
    exact = True
    for policy in ("aimd", "reactive"):
        cfg = bench_spot._spot_cfg(
            policy, monitor_dt=60.0, ticks=650, bid_policy="on_demand"
        )
        s = sweep(SweepSpec(axes=axes, workload=sset,
                            params=runner.default_params(cfg)), cfg)
        cost = float(np.mean(np.asarray(s.cost)))
        viol = int(np.sum(np.asarray(s.violations)))
        same = cost == ref[policy]["cost"] and viol == ref[policy]["violations"]
        exact = exact and same
        out[policy] = {"cost": cost, "violations": viol}
    return {
        "aimd_cost": out["aimd"]["cost"],
        "reactive_cost": out["reactive"]["cost"],
        "saving_pct": ref["saving_pct"],
        "exact_match": bool(exact),
    }


def _summary_stats(summary, penalty: float) -> dict:
    cost = np.asarray(summary.cost)
    viol = np.asarray(summary.violations)
    return {
        "mean_cost": float(cost.mean()),
        "violations": int(viol.sum()),
        "score": float((cost + penalty * viol.astype(np.float32)).mean()),
    }


def run_joint_tuning(sset, scen_ids, seeds, pop_size, generations) -> dict:
    """The headline one-jit tuning run over the full seeds × scenarios
    batch — sized to the acceptance floor (≥8 × ≥32 × ≥4 × ≥3)."""
    tuning = opt.tune_policy(
        _cfg(),
        sset,
        seeds=seeds,
        key=jax.random.PRNGKey(0),
        scenarios=scen_ids,
        method="cem",
        pop_size=pop_size,
        generations=generations,
        penalty=PENALTY,
    )
    return {
        "pop_size": pop_size,
        "generations": generations,
        "n_seeds": len(list(seeds)),
        "n_scenarios": len(scen_ids),
        "default_score": float(tuning.default_score),
        "tuned_score": float(tuning.result.best_score),
        "improvement_pct": tuning.improvement_pct,
        "objective_traces": int(tuning.objective.n_traces),
        "tuned_params": {
            n: float(np.asarray(tuning.result.best_vec)[i])
            for i, n in enumerate(opt.policy_space().names)
        },
        "history_best": [float(v) for v in np.asarray(tuning.result.history_best)],
    }


def run_per_scenario_tuning(sset, scen_ids, seeds, pop_size, generations) -> dict:
    """Tune each stochastic world separately; tuned must strictly beat the
    hand-set defaults on its own world (same batch, same penalty)."""
    scenarios = {}
    for idx in scen_ids:
        name = sset.names[idx]
        tuning = opt.tune_policy(
            _cfg(),
            sset,
            seeds=seeds,
            key=jax.random.PRNGKey(100 + idx),
            scenarios=[idx],
            method="cem",
            pop_size=pop_size,
            generations=generations,
            penalty=PENALTY,
        )
        tuned_eval = _summary_stats(
            tuning.objective.evaluate(tuning.result.best_vec), PENALTY
        )
        default_eval = _summary_stats(
            tuning.objective.evaluate(tuning.default_vec), PENALTY
        )
        scenarios[name] = {
            "default_score": float(tuning.default_score),
            "tuned_score": float(tuning.result.best_score),
            "improvement_pct": tuning.improvement_pct,
            "tuned_violations": tuned_eval["violations"],
            "default_violations": default_eval["violations"],
            "tuned_cost": tuned_eval["mean_cost"],
            "default_cost": default_eval["mean_cost"],
            "tuned_params": {
                n: float(np.asarray(tuning.result.best_vec)[i])
                for i, n in enumerate(opt.policy_space().names)
            },
        }
    return scenarios


def run_adversarial(sset, seeds, pop_size, generations) -> dict:
    """Worst-case MMPP world for the hand-set default policy.  The spec's
    id in the set seeds the sampling keys, so the nominal world here is
    the very world the tuning sections evaluate."""
    spec = sset[sset.index("mmpp")]
    att = opt.attack_policy(
        _cfg(),
        spec,
        None,
        seeds=seeds,
        key=jax.random.PRNGKey(1),
        pop_size=pop_size,
        generations=generations,
        penalty=PENALTY,
        scenario_id=sset.index("mmpp"),
    )
    return {
        "scenario": spec.name,
        "nominal_score": float(att.nominal_score),
        "worst_score": float(att.worst_score),
        "damage": att.damage,
        "worst_params": att.worst_params,
        "within_bounds": bool(att.space.contains(att.worst_vec)),
        "_attack": att,
    }


def run_robust(sset, seeds, adversarial, rounds, pop_size, generations) -> dict:
    """Min–max alternation on MMPP.

    Gap closure: the adversarial section found the default policy's worst
    world; that world seeds the robust pool, and the robust policy is
    scored *on that same world* — the metric is the share of the
    default's score there that robustification removed (both policies,
    identical world and seeds — an apples-to-apples read of how much of
    the discovered hole the min–max game closed)."""
    spec = sset[sset.index("mmpp")]
    cfg = _cfg()
    rob = opt.robust_tune(
        cfg,
        spec,
        seeds=seeds,
        key=jax.random.PRNGKey(2),
        rounds=rounds,
        pop_size=pop_size,
        generations=generations,
        penalty=PENALTY,
        scenario_id=sset.index("mmpp"),
        initial_worlds=[adversarial["_attack"].worst_vec],
    )
    space = opt.scenario_space(spec)
    robust_obj = opt.ScenarioObjective(
        cfg, spec, rob.params, space, seeds, penalty=PENALTY,
        scenario_id=sset.index("mmpp"),
    )
    default_worst_vec = adversarial["_attack"].worst_vec
    robust_on_default_worst = _summary_stats(
        robust_obj.evaluate(default_worst_vec), PENALTY
    )["score"]
    default_on_default_worst = adversarial["worst_score"]
    closure = (
        100.0
        * (default_on_default_worst - robust_on_default_worst)
        / max(default_on_default_worst, 1e-9)
    )
    return {
        "rounds": list(rob.rounds),
        "default_worst_score": default_on_default_worst,
        "robust_on_default_worst": robust_on_default_worst,
        # Best-response attack against the robust policy itself (its own
        # residual worst case, not directly comparable across policies).
        "robust_worst_score": float(rob.worst_score),
        "gap_closure_pct": closure,
        "robust_params": {
            n: float(np.asarray(rob.vec)[i])
            for i, n in enumerate(opt.policy_space().names)
        },
    }


def main(emit, smoke: bool = False) -> dict:
    hl_seeds = (0, 1) if smoke else (0, 1, 2)
    tune_seeds = tuple(range(4 if smoke else 6))
    adv_seeds = tuple(range(3 if smoke else 4))
    joint_pop, joint_gens = (32, 8) if smoke else (48, 10)
    per_pop, per_gens = (16, 6) if smoke else (24, 8)
    adv_pop, adv_gens = (16, 6) if smoke else (24, 8)
    rob_rounds, rob_pop, rob_gens = (2, 12, 4) if smoke else (3, 16, 6)

    sset = default_set()
    scen_ids = [sset.index(n) for n in SCENARIO_NAMES]

    paper = run_paper_replay(hl_seeds)
    emit(
        "tune_paper_saving_pct",
        paper["saving_pct"],
        f"exact={paper['exact_match']}",
    )

    joint = run_joint_tuning(sset, scen_ids, tune_seeds, joint_pop, joint_gens)
    emit(
        "tune_joint_improvement_pct",
        joint["improvement_pct"],
        f"default={joint['default_score']:.4f};tuned={joint['tuned_score']:.4f};"
        f"traces={joint['objective_traces']}",
    )

    scenarios = run_per_scenario_tuning(
        sset, scen_ids, tune_seeds, per_pop, per_gens
    )
    for name, sc in scenarios.items():
        emit(
            f"tune_{name}_improvement_pct",
            sc["improvement_pct"],
            f"default={sc['default_score']:.4f};tuned={sc['tuned_score']:.4f};"
            f"tviol={sc['tuned_violations']};dviol={sc['default_violations']}",
        )

    adversarial = run_adversarial(sset, adv_seeds, adv_pop, adv_gens)
    emit(
        "tune_adversarial_damage",
        adversarial["damage"],
        f"nominal={adversarial['nominal_score']:.4f};"
        f"worst={adversarial['worst_score']:.4f};"
        f"bounds_ok={adversarial['within_bounds']}",
    )

    robust = run_robust(
        sset, adv_seeds, adversarial, rob_rounds, rob_pop, rob_gens
    )
    emit(
        "tune_robust_gap_closure_pct",
        robust["gap_closure_pct"],
        f"default_on_worst={robust['default_worst_score']:.4f};"
        f"robust_on_worst={robust['robust_on_default_worst']:.4f}",
    )
    adversarial.pop("_attack", None)

    beats_all = all(sc["improvement_pct"] > 0.0 for sc in scenarios.values())
    single_compile = joint["objective_traces"] == 1
    acceptance = {
        "tuned_beats_default_all": bool(beats_all),
        "paper_exact": bool(paper["exact_match"]),
        "single_compile": bool(single_compile),
        "adversarial_within_bounds": bool(adversarial["within_bounds"]),
    }
    for flag, value in acceptance.items():
        emit(f"tune_acceptance_{flag}", float(value), "bool")

    report = {
        "kind": "tuning",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "market": dict(MARKET),
            "penalty": PENALTY,
            "scenario_names": list(SCENARIO_NAMES),
            "tune_seeds": list(tune_seeds),
            "adv_seeds": list(adv_seeds),
            "headline_seeds": list(hl_seeds),
        },
        "paper": paper,
        "joint": joint,
        "scenarios": scenarios,
        "adversarial": adversarial,
        "robust": robust,
        "acceptance": acceptance,
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_tuning.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not all(acceptance.values()):
        raise SystemExit(f"tuning acceptance not met: {acceptance}")
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="reduced budgets for CI; same acceptance checks",
    )
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
