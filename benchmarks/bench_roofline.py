"""§Roofline summary from the dry-run sweep results (results/dryrun)."""

from __future__ import annotations

import os

from repro.launch import roofline


def main(emit) -> None:
    d = "results/dryrun"
    if not os.path.isdir(d):
        emit("roofline_cells", 0.0, "run scripts/dryrun_sweep.sh first")
        return
    results = roofline.load_dir(d)
    single = [r for r in results if r.get("mesh") == "16x16"]
    multi = [r for r in results if r.get("mesh") == "2x16x16"]
    ok_s = sum(bool(r.get("ok")) for r in single)
    ok_m = sum(bool(r.get("ok")) for r in multi)
    emit("dryrun_cells_16x16_ok", float(ok_s), f"of {len(single)}")
    emit("dryrun_cells_2x16x16_ok", float(ok_m), f"of {len(multi)}")
    for r in single:
        a = roofline.analyze(r)
        if a is None:
            continue
        emit(f"roofline_{a['arch']}_{a['shape']}",
             a["step_lower_bound_s"],
             f"dom={a['dominant']};compute={a['t_compute']:.4g};"
             f"mem={a['t_memory']:.4g};coll={a['t_collective']:.4g};"
             f"useful={a['useful_ratio']:.2f};"
             f"roofl={100 * a['roofline_fraction']:.0f}%")
