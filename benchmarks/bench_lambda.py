"""Paper Table IV: per-image cost of ImageMagick functions — AWS Lambda
billing model vs our platform.

Each function is one 25k-image workload, run SEPARATELY (as the paper did),
with the TTC tuned to the Lambda execution time of the same workload
(§V.D: "our platform was tuned to match the execution time of each
workload in Lambda").  This is exactly what makes short functions
Lambda-friendly: a brief burst on the platform still pays full billing
quanta, so per-image cost rises as function runtime falls.
"""

from __future__ import annotations

import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import SimConfig, run
from repro.sim.lambda_model import IMAGEMAGICK, N_IMAGES, lambda_cost_per_item
from repro.sim.workloads import Schedule, FAMILY_PARAMS, FACE

LAMBDA_CONCURRENCY = 30     # effective parallel invocations via the CLI
IO_OVERHEAD = 0.25          # download/store seconds per image on a CU


def _one(fname: str) -> Schedule:
    prm = FAMILY_PARAMS[FACE]
    t = IMAGEMAGICK[fname]
    lambda_runtime = N_IMAGES * t / LAMBDA_CONCURRENCY
    return Schedule(
        t_arrive=np.zeros(1, int),
        family=np.asarray([FACE]),
        m0=np.asarray([[float(N_IMAGES)]]),
        b_true=np.asarray([[t + IO_OVERHEAD]]),
        sigma=np.asarray([0.35]),
        c0=np.asarray([prm["c0"]]),
        p_r=np.asarray([prm["p_r"]]),
        overshoot=np.asarray([prm["overshoot"]]),
        d_requested=np.asarray([lambda_runtime]),
    )


def run_table4() -> dict:
    out = {}
    for fname in IMAGEMAGICK:
        sched = _one(fname)
        cfg = SimConfig(
            ctrl=ControllerConfig(policy="aimd",
                                  params=ControlParams(monitor_dt=60.0),
                                  billing=BillingParams()),
            ticks=400)
        tr = run(sched, cfg)
        t_end = int(np.asarray(tr.work_final.t_done).max())
        if t_end < 0:
            t_end = tr.cum_cost.shape[0] - 1
        plat = float(tr.cum_cost[min(t_end + 1, tr.cum_cost.shape[0] - 1)]) \
            / N_IMAGES
        lam = lambda_cost_per_item(IMAGEMAGICK[fname])
        out[fname] = {"lambda": lam, "platform": plat,
                      "ratio": float(lam / plat)}
    lam_avg = float(np.mean([v["lambda"] for v in out.values()]))
    plat_avg = float(np.mean([v["platform"] for v in out.values()]))
    out["overall"] = {"lambda": lam_avg, "platform": plat_avg,
                      "ratio": lam_avg / plat_avg}
    return out


def main(emit) -> None:
    t4 = run_table4()
    for fn, row in t4.items():
        emit(f"tab4_{fn}_ratio", row["ratio"],
             f"lambda=${row['lambda']:.2e};platform=${row['platform']:.2e}")
