"""CI benchmark-regression gate for the spot/bidding/throughput benchmarks.

Compares the JSON a CI run just produced against the committed baseline in
``benchmarks/baselines/`` and fails the job when the trajectory regresses.
The report's ``kind`` field picks the rule set (missing = the original
spot/bidding report).

``BENCH_spot.json`` (``bench_bidding --smoke``):

  * the AIMD-vs-Reactive headline saving drops below the paper's 27%
    floor (hard threshold, independent of the baseline);
  * any tracked violation count grows beyond its baseline value
    (headline AIMD, per-policy best points, per-mix points);
  * the dynamic-beats-static acceptance flag flips to false;
  * a best-policy cost inflates beyond ``COST_TOLERANCE`` x baseline
    (loose on purpose: CI floats drift, regressions explode).

``BENCH_throughput.json`` (``bench_throughput --smoke``):

  * the summary-mode acceptance flag flips (summary mode no longer shows
    ≥5× lower bytes or ≥3× the runs/sec of trace mode — hard floors,
    baseline-independent);
  * a deterministic byte count (returned bytes per grid) grows beyond
    ``BYTES_TOLERANCE`` × baseline — the scan carry picked up per-tick
    payload again;
  * summary-mode runs/sec falls below baseline / ``SPEED_TOLERANCE``
    (very loose: CI machines differ by a few x, order-of-magnitude
    cliffs — e.g. a reintroduced per-chunk recompile — don't);
  * the streamed executor loses bit-parity with the in-memory path,
    fails its kill-and-resume round-trip, or lets the grid-to-live-bytes
    ratio fall below ``STREAM_RATIO_FLOOR`` (hard floor: the whole point
    of streaming is a grid ≥10× larger than peak host live bytes);
  * the sharded sweep reports non-null parity that is false (null is
    fine — single-device CI hosts cannot exercise the mesh).

``BENCH_scenarios.json`` (``bench_scenarios --smoke``):

  * the ``paper_exact`` acceptance flag flips — the scenario engine's
    replay of the §V.A suite is no longer bit-for-bit identical to the
    static-schedule path;
  * the paper replay's headline saving drops below the 27% floor;
  * any stochastic scenario's AIMD-vs-Reactive saving goes non-positive
    (hard floor, baseline-independent);
  * a scenario's AIMD violation count grows beyond its baseline, or its
    AIMD cost inflates beyond ``COST_TOLERANCE`` × baseline.

``BENCH_tuning.json`` (``bench_tuning --smoke``):

  * an acceptance flag flips: ``tuned_beats_default_all`` (the in-jit
    tuner no longer strictly beats the hand-set defaults on every
    stochastic scenario), ``paper_exact`` (the default-``PolicyParams``
    paper replay is no longer bit-identical to ``bench_spot``'s headline),
    ``single_compile`` (the joint tuning run traced its sweep objective
    more than once), or ``adversarial_within_bounds``;
  * a scenario's *tuned* violation count grows beyond its baseline, or
    its tuned score inflates beyond ``COST_TOLERANCE`` × baseline;
  * a scenario's tuned-vs-default improvement goes negative.

``BENCH_chaos.json`` (``bench_chaos --smoke``):

  * an acceptance flag flips: ``zero_fault_exact`` (a neutral
    ``FaultSpec`` under the chaos engine is no longer bit-identical to
    the engine compiled out), ``bounded_inflation_all``,
    ``hardened_beats_unhardened_all``, or ``recovery_bounded``;
  * the zero-fault sweep digest differs from the baseline's — some PR
    perturbed the no-chaos program's bits (the static-gating contract);
  * any chaos scenario's hardened-vs-unhardened margin goes
    non-positive, its hardened score inflates beyond
    ``CHAOS_INFLATION_CEILING`` × its fault-free score (hard ceiling,
    baseline-independent) or beyond ``COST_TOLERANCE`` × its baseline
    score;
  * post-outage recovery takes more than ``CHAOS_RECOVERY_CEILING``
    ticks (hard ceiling, baseline-independent).

``BENCH_obs.json`` (``bench_obs --smoke``):

  * an acceptance flag flips: ``neutral_exact`` (the full probe catalog
    no longer reproduces the probe-free program bit for bit, or the
    compiled-out path changed), ``overhead_bounded``, ``exports_ok``
    (the Perfetto chunk timeline / ledger exporters broke), or
    ``calibration_ok``;
  * either neutrality digest — ``obs=None`` probes compiled out, or
    ``detect=None`` full probes with detectors compiled out — differs
    from the baseline's: some PR perturbed those programs' bits (the
    static-gating contract, the observability twin of the chaos
    zero-fault digest);
  * the full-catalog (probes + armed detectors) overhead ratio exceeds
    ``OBS_OVERHEAD_CEILING`` (hard ceiling, baseline-independent);
  * detector calibration regresses: the clean paper replay or any
    fault-free chaos-scenario variant fires an alert (false positive),
    or a committed chaos scenario stops firing at least one alert per
    seed with the first tick inside its fault window (missed / mis-
    localized fault).

``BENCH_tenants.json`` (``bench_tenants --smoke``):

  * an acceptance flag flips: ``single_owner_exact`` (a one-tenant set is
    no longer bit-identical to the single-owner path),
    ``attribution_exact_all`` (per-tenant billed cost stopped summing
    exactly to the fleet bill), ``consolidation_saves`` /
    ``consolidation_viol_ok`` (the shared fleet stopped dominating N
    dedicated fleets), ``tuned_ge_uniform`` or ``single_compile`` (the
    profit tuner regressed);
  * any tracked tenant level's consolidation saving goes non-positive, or
    its shared-fleet violation count grows beyond baseline.

On any gate failure the checker additionally runs the cross-run
attribution diff (``repro.obs.compare``): it prints the **first diverging
deterministic leaf** between the current report and the baseline (digests
and acceptance flags rank first — one flipped digest explains every
numeric drift below it) and writes the full divergence list to
``results/bench_attribution.json`` so the artifact upload carries the
localization, not just the red flag.

Exit code 0 = gate passed.  Anything else fails the job; the JSON is
uploaded as an artifact either way so the trajectory stays inspectable.

CLI:  python benchmarks/check_bench_regression.py \
          results/BENCH_spot.json benchmarks/baselines/BENCH_spot.json
      python benchmarks/check_bench_regression.py --auto
          # every benchmarks/baselines/BENCH_*.json vs results/ — the
          # form CI uses, so a new benchmark's committed baseline is
          # gated automatically
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

ATTRIBUTION_PATH = os.path.join("results", "bench_attribution.json")

SAVING_FLOOR_PCT = 27.0
COST_TOLERANCE = 1.5
BYTES_TOLERANCE = 1.05
# Wall-clock only catches order-of-magnitude cliffs (e.g. a per-chunk
# recompile): CI runner generations legitimately differ by a few x.
SPEED_TOLERANCE = 5.0
# Summary mode must stay within noise of trace-mode speed (the register
# carry reached parity in PR 6; the ratio is machine-relative, so the
# floor leaves slack for scheduler jitter while catching a reintroduced
# per-tick select chain).
SPEED_PARITY_FLOOR = 0.85
# The streamed sweep must keep the full grid of summaries at least this
# many times larger than the live bytes of one padded chunk.
STREAM_RATIO_FLOOR = 10.0
# Chaos scenarios are allowed to hurt, but the hardened plane's score
# must stay within this multiple of its fault-free score, and the fleet
# must re-reach the fault-free trajectory within this many ticks of a
# blackout clearing (both hard, baseline-independent).
CHAOS_INFLATION_CEILING = 8.0
CHAOS_RECOVERY_CEILING = 24
# Full-catalog probes must stay within this multiple of the probe-free
# steady-state runtime (hard, baseline-independent — bench_obs).
OBS_OVERHEAD_CEILING = 1.25


def _schema_smoke_errors(current: dict, baseline: dict) -> list[str]:
    """The version/smoke preflight every report kind shares."""
    errors: list[str] = []
    if current.get("schema_version") != baseline.get("schema_version"):
        errors.append(
            f"schema_version mismatch: current {current.get('schema_version')} "
            f"vs baseline {baseline.get('schema_version')}"
        )
        return errors
    if bool(current.get("smoke")) != bool(baseline.get("smoke")):
        errors.append(
            "smoke flag mismatch: gate must compare like with like "
            f"(current smoke={current.get('smoke')}, "
            f"baseline smoke={baseline.get('smoke')})"
        )
    return errors


def check(current: dict, baseline: dict) -> list[str]:
    """Return a list of human-readable gate failures (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    saving = current["headline"]["saving_pct"]
    if saving < SAVING_FLOOR_PCT:
        errors.append(
            f"headline AIMD-vs-Reactive saving {saving:.1f}% fell below the "
            f"paper's {SAVING_FLOOR_PCT}% floor"
        )

    cur_hl_viol = current["headline"]["aimd_violations"]
    base_hl_viol = baseline["headline"]["aimd_violations"]
    if cur_hl_viol > base_hl_viol:
        errors.append(
            f"headline AIMD violations grew: {cur_hl_viol} > baseline {base_hl_viol}"
        )

    if not current["acceptance"]["dynamic_beats_static"]:
        errors.append(
            "acceptance flag dynamic_beats_static is false: no dynamic bid "
            "policy matches the best static bid"
        )

    for section in ("policies", "mixes"):
        for name, base_entry in baseline.get(section, {}).items():
            cur_entry = current.get(section, {}).get(name)
            if cur_entry is None:
                errors.append(f"{section}[{name}] missing from current results")
                continue
            if cur_entry["violations"] > base_entry["violations"]:
                errors.append(
                    f"{section}[{name}] violations grew: "
                    f"{cur_entry['violations']} > baseline {base_entry['violations']}"
                )
            if cur_entry["cost"] > COST_TOLERANCE * base_entry["cost"]:
                errors.append(
                    f"{section}[{name}] cost {cur_entry['cost']:.4f} exceeds "
                    f"{COST_TOLERANCE}x baseline {base_entry['cost']:.4f}"
                )
    return errors


def check_throughput(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: throughput`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    if not current.get("acceptance", {}).get("summary_mode_ok"):
        errors.append(
            "acceptance flag summary_mode_ok is false: summary mode no "
            "longer beats trace mode on memory or throughput"
        )

    for grid, base_grid in baseline.get("grids", {}).items():
        cur_grid = current.get("grids", {}).get(grid)
        if cur_grid is None:
            errors.append(f"grids[{grid}] missing from current results")
            continue
        cur_b = cur_grid.get("summary", {}).get("output_bytes")
        base_b = base_grid.get("summary", {}).get("output_bytes")
        if cur_b is not None and base_b and cur_b > BYTES_TOLERANCE * base_b:
            errors.append(
                f"grids[{grid}] summary output bytes grew: {cur_b} > "
                f"{BYTES_TOLERANCE}x baseline {base_b} — the summary scan "
                "is emitting per-tick payload again"
            )
        cur_r = cur_grid.get("summary", {}).get("runs_per_s")
        base_r = base_grid.get("summary", {}).get("runs_per_s")
        if cur_r is not None and base_r and cur_r < base_r / SPEED_TOLERANCE:
            errors.append(
                f"grids[{grid}] summary runs/sec collapsed: {cur_r} < "
                f"baseline {base_r} / {SPEED_TOLERANCE}"
            )
    ratio = current.get("grids", {}).get("frontier", {}).get("speed_ratio")
    if ratio is not None and ratio < SPEED_PARITY_FLOOR:
        errors.append(
            f"frontier summary/trace speed ratio {ratio} fell below the "
            f"{SPEED_PARITY_FLOOR} parity floor — the summary scan is "
            "paying per-tick overhead again"
        )

    streamed = current.get("grids", {}).get("streamed")
    if streamed is None:
        if "streamed" in baseline.get("grids", {}):
            errors.append("grids[streamed] missing from current results")
    else:
        if not streamed.get("parity"):
            errors.append(
                "streamed sweep lost bit-parity with the in-memory path"
            )
        if not streamed.get("resume_ok"):
            errors.append(
                "streamed sweep failed its kill-and-resume round-trip"
            )
        s_ratio = streamed.get("stream_ratio")
        if s_ratio is None or s_ratio < STREAM_RATIO_FLOOR:
            errors.append(
                f"streamed grid/live-bytes ratio {s_ratio} fell below the "
                f"{STREAM_RATIO_FLOOR} floor — streaming no longer bounds "
                "host memory"
            )

    # Single-device hosts report null sharded parity; a non-null false
    # means shard_map diverged from the single-device program.
    sharded_parity = current.get("grids", {}).get("sharded", {}).get("parity")
    if sharded_parity is False:
        errors.append(
            "sharded sweep is no longer bit-identical to the "
            "single-device path"
        )
    return errors


def check_scenarios(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: scenarios`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    acc = current.get("acceptance", {})
    if not acc.get("paper_exact"):
        errors.append(
            "acceptance flag paper_exact is false: the scenario engine's "
            "paper replay no longer reproduces the static-schedule path "
            "bit for bit"
        )
    paper_saving = current.get("paper", {}).get("saving_pct", float("-inf"))
    if paper_saving < SAVING_FLOOR_PCT:
        errors.append(
            f"paper-replay headline saving {paper_saving:.1f}% fell below "
            f"the {SAVING_FLOOR_PCT}% floor"
        )

    for name, base_sc in baseline.get("scenarios", {}).items():
        cur_sc = current.get("scenarios", {}).get(name)
        if cur_sc is None:
            errors.append(f"scenarios[{name}] missing from current results")
            continue
        if cur_sc["saving_pct"] <= 0.0:
            errors.append(
                f"scenarios[{name}] AIMD saving went non-positive: "
                f"{cur_sc['saving_pct']:.1f}%"
            )
        if cur_sc["aimd_violations"] > base_sc["aimd_violations"]:
            errors.append(
                f"scenarios[{name}] AIMD violations grew: "
                f"{cur_sc['aimd_violations']} > baseline "
                f"{base_sc['aimd_violations']}"
            )
        if cur_sc["aimd_cost"] > COST_TOLERANCE * base_sc["aimd_cost"]:
            errors.append(
                f"scenarios[{name}] AIMD cost {cur_sc['aimd_cost']:.4f} "
                f"exceeds {COST_TOLERANCE}x baseline "
                f"{base_sc['aimd_cost']:.4f}"
            )
    return errors


def check_tuning(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: tuning`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    acc = current.get("acceptance", {})
    for flag, why in (
        ("tuned_beats_default_all",
         "tuned params no longer strictly beat the hand-set defaults on "
         "every stochastic scenario"),
        ("paper_exact",
         "the default-PolicyParams paper replay is no longer bit-identical "
         "to bench_spot.run_headline"),
        ("single_compile",
         "the joint tuning run traced its sweep objective more than once "
         "— candidate evaluation is recompiling"),
        ("adversarial_within_bounds",
         "the adversarial search reported a world outside the generator's "
         "parameter bounds"),
    ):
        if not acc.get(flag):
            errors.append(f"acceptance flag {flag} is false: {why}")

    for name, base_sc in baseline.get("scenarios", {}).items():
        cur_sc = current.get("scenarios", {}).get(name)
        if cur_sc is None:
            errors.append(f"scenarios[{name}] missing from current results")
            continue
        if cur_sc["improvement_pct"] < 0.0:
            errors.append(
                f"scenarios[{name}] tuned-vs-default improvement went "
                f"negative: {cur_sc['improvement_pct']:.2f}%"
            )
        if cur_sc["tuned_violations"] > base_sc["tuned_violations"]:
            errors.append(
                f"scenarios[{name}] tuned violations grew: "
                f"{cur_sc['tuned_violations']} > baseline "
                f"{base_sc['tuned_violations']}"
            )
        if cur_sc["tuned_score"] > COST_TOLERANCE * base_sc["tuned_score"]:
            errors.append(
                f"scenarios[{name}] tuned score {cur_sc['tuned_score']:.4f} "
                f"exceeds {COST_TOLERANCE}x baseline "
                f"{base_sc['tuned_score']:.4f}"
            )
    return errors


def check_chaos(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: chaos`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    acc = current.get("acceptance", {})
    for flag, why in (
        (
            "zero_fault_exact",
            "a neutral FaultSpec under the chaos engine no longer "
            "reproduces the engine-compiled-out bits",
        ),
        (
            "bounded_inflation_all",
            "some chaos scenario's hardened score inflated beyond the "
            "ceiling over its fault-free score",
        ),
        (
            "hardened_beats_unhardened_all",
            "the hardened control plane no longer strictly beats the "
            "unhardened comparator on every chaos scenario",
        ),
        (
            "recovery_bounded",
            "the fleet no longer re-reaches the fault-free trajectory "
            "within the recovery ceiling after a blackout clears",
        ),
    ):
        if not acc.get(flag):
            errors.append(f"acceptance flag {flag} is false: {why}")

    cur_digest = current.get("zero_fault", {}).get("digest")
    base_digest = baseline.get("zero_fault", {}).get("digest")
    if cur_digest != base_digest:
        errors.append(
            "zero-fault sweep digest changed: the no-chaos program is no "
            f"longer bit-identical to the baseline ({cur_digest} vs "
            f"{base_digest})"
        )

    for name, base_sc in baseline.get("scenarios", {}).items():
        cur_sc = current.get("scenarios", {}).get(name)
        if cur_sc is None:
            errors.append(f"scenarios[{name}] missing from current results")
            continue
        if cur_sc["margin_pct"] <= 0.0:
            errors.append(
                f"scenarios[{name}] hardened-vs-unhardened margin went "
                f"non-positive: {cur_sc['margin_pct']:.2f}%"
            )
        if cur_sc["inflation"] > CHAOS_INFLATION_CEILING:
            errors.append(
                f"scenarios[{name}] hardened/fault-free inflation "
                f"{cur_sc['inflation']:.2f} exceeds the "
                f"{CHAOS_INFLATION_CEILING} ceiling"
            )
        if cur_sc["hardened_score"] > COST_TOLERANCE * base_sc["hardened_score"]:
            errors.append(
                f"scenarios[{name}] hardened score "
                f"{cur_sc['hardened_score']:.4f} exceeds {COST_TOLERANCE}x "
                f"baseline {base_sc['hardened_score']:.4f}"
            )

    ticks = current.get("recovery", {}).get("recovery_ticks")
    if ticks is None or ticks > CHAOS_RECOVERY_CEILING:
        errors.append(
            f"post-outage recovery took {ticks} ticks, beyond the "
            f"{CHAOS_RECOVERY_CEILING}-tick ceiling"
        )
    return errors


def check_obs(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: obs`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    acc = current.get("acceptance", {})
    for flag, why in (
        (
            "neutral_exact",
            "the full probe catalog no longer reproduces the probe-free "
            "program bit for bit",
        ),
        (
            "overhead_bounded",
            "the full catalog (probes + armed detectors) exceeded the "
            "overhead ceiling over the probe-free runtime",
        ),
        (
            "exports_ok",
            "the Perfetto chunk-timeline / ledger exporters no longer "
            "produce well-formed traces",
        ),
        (
            "calibration_ok",
            "detector calibration broke — false positives on a clean "
            "replay, or a chaos scenario whose fault the detectors miss "
            "or mislocalize",
        ),
    ):
        if not acc.get(flag):
            errors.append(f"acceptance flag {flag} is false: {why}")

    for key, what in (("digest", "obs=None"),
                      ("digest_detect_none", "detect=None")):
        cur_digest = current.get("neutrality", {}).get(key)
        base_digest = baseline.get("neutrality", {}).get(key)
        if cur_digest != base_digest:
            errors.append(
                f"probe-free sweep {key} changed: the {what} program is no "
                f"longer bit-identical to the baseline ({cur_digest} vs "
                f"{base_digest})"
            )

    ratio = current.get("overhead", {}).get("overhead_ratio")
    if ratio is None or ratio > OBS_OVERHEAD_CEILING:
        errors.append(
            f"full-probe overhead ratio {ratio} exceeds the "
            f"{OBS_OVERHEAD_CEILING} ceiling over the probe-free runtime"
        )

    cal = current.get("calibration", {})
    clean = cal.get("clean", {}).get("alerts")
    if clean is None or clean > 0:
        errors.append(
            f"detector false-positive gate: clean paper replay fired "
            f"{clean} alert(s), expected 0"
        )
    for name in baseline.get("calibration", {}).get("scenarios", {}):
        cur_sc = cal.get("scenarios", {}).get(name)
        if cur_sc is None:
            errors.append(
                f"calibration.scenarios[{name}] missing from current "
                "results")
            continue
        if cur_sc.get("fault_free_alerts", 1) > 0:
            errors.append(
                f"calibration.scenarios[{name}] fault-free variant fired "
                f"{cur_sc['fault_free_alerts']} alert(s), expected 0"
            )
        if min(cur_sc.get("alerts_per_seed", []), default=0) < 1:
            errors.append(
                f"calibration.scenarios[{name}] detectors missed the "
                f"injected fault on some seed "
                f"(alerts_per_seed={cur_sc.get('alerts_per_seed')})"
            )
        elif not cur_sc.get("first_in_window"):
            errors.append(
                f"calibration.scenarios[{name}] first alert tick(s) "
                f"{cur_sc.get('first_ticks')} fell outside the fault "
                f"window {cur_sc.get('window')}"
            )
    return errors


def check_tenants(current: dict, baseline: dict) -> list[str]:
    """Gate failures for the ``kind: tenants`` report (empty = pass)."""
    errors = _schema_smoke_errors(current, baseline)
    if errors:
        return errors

    acc = current.get("acceptance", {})
    for flag, why in (
        (
            "single_owner_exact",
            "a one-tenant set no longer reproduces the single-owner "
            "simulation bit for bit",
        ),
        (
            "attribution_exact_all",
            "per-tenant attributed cost no longer sums exactly to the fleet "
            "bill on some tenant count",
        ),
        (
            "consolidation_saves",
            "the shared fleet stopped beating N dedicated fleets on cost",
        ),
        (
            "consolidation_viol_ok",
            "consolidation now violates more TTCs than the dedicated fleets",
        ),
        (
            "tuned_ge_uniform",
            "profit tuning returned worse-than-uniform provider profit — the "
            "incumbent injection guarantee broke",
        ),
        (
            "single_compile",
            "the profit tuning run traced its objective more than once",
        ),
    ):
        if not acc.get(flag):
            errors.append(f"acceptance flag {flag} is false: {why}")

    for n, base_row in baseline.get("consolidation", {}).items():
        cur_row = current.get("consolidation", {}).get(n)
        if cur_row is None:
            errors.append(f"consolidation[{n}] missing from current results")
            continue
        # N=1 is the identity case: one tenant's "shared" fleet IS its
        # dedicated fleet, so the saving is definitionally zero there.
        if int(n) > 1 and cur_row["saving_pct"] <= 0.0:
            errors.append(
                f"consolidation[{n}] shared-fleet saving went non-positive: "
                f"{cur_row['saving_pct']:.2f}%"
            )
        if cur_row["shared_violations"] > base_row["shared_violations"]:
            errors.append(
                f"consolidation[{n}] shared violations grew: "
                f"{cur_row['shared_violations']} > baseline "
                f"{base_row['shared_violations']}"
            )
    return errors


_CHECKERS = {
    "spot": check,
    "throughput": check_throughput,
    "scenarios": check_scenarios,
    "tuning": check_tuning,
    "chaos": check_chaos,
    "obs": check_obs,
    "tenants": check_tenants,
}


def gate_errors(current: dict, baseline: dict) -> list[str]:
    """Dispatch a (current, baseline) report pair to its ``kind``'s rule
    set and return the gate failures (empty = pass).  The embeddable form
    of :func:`check_pair` — ``benchmarks/run.py`` uses it to fold gate
    status into its ``--json`` machine summary without re-running this
    script as a subprocess."""
    kind_cur = current.get("kind", "spot")
    kind_base = baseline.get("kind", "spot")
    if kind_cur != kind_base:
        return [f"report kind mismatch: current {kind_cur!r} vs "
                f"baseline {kind_base!r}"]
    checker = _CHECKERS.get(kind_cur)
    if checker is None:
        return [f"unknown report kind {kind_cur!r}"]
    return checker(current, baseline)


def _attribute(current: dict, baseline: dict, errors: list[str],
               name: str) -> dict | None:
    """First-divergence attribution for a failed pair (None if the
    compare module is unavailable — the gate itself never depends on it)."""
    try:
        from repro.obs import compare
    except ImportError:
        here = os.path.dirname(os.path.abspath(__file__))
        sys.path.insert(0, os.path.join(here, os.pardir, "src"))
        try:
            from repro.obs import compare
        except ImportError:
            print("attribution skipped: repro.obs.compare not importable",
                  file=sys.stderr)
            return None
    report = compare.attribution(current, baseline, gate_errors=errors)
    report["baseline"] = name
    first = report["first_divergence"]
    if first is None:
        print("ATTRIBUTION: no deterministic leaf diverged — the failure "
              "is a hard floor/ceiling breach, not a baseline drift",
              file=sys.stderr)
    else:
        print(f"ATTRIBUTION: first divergence at {first['path']}: "
              f"current={first['current']} vs baseline={first['baseline']}"
              + (f" ({first['detail']})" if first.get("detail") else ""),
              file=sys.stderr)
        print(f"ATTRIBUTION: {report['n_divergences']} deterministic "
              f"leaf(s) diverged, {report['n_noise']} wall-clock leaf(s) "
              f"classified as noise", file=sys.stderr)
    return report


def write_attribution(reports: list[dict],
                      path: str = ATTRIBUTION_PATH) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"attributions": reports}, f, indent=2, sort_keys=True)
    print(f"attribution report written to {path}", file=sys.stderr)


def check_pair(current_path: str, baseline_path: str,
               attributions: list[dict] | None = None) -> int:
    """Gate one (current, baseline) JSON pair; returns the exit code.

    On failure, appends the first-divergence attribution report to
    ``attributions`` (when given) after printing its headline."""
    with open(current_path) as f:
        current = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)

    kind_cur = current.get("kind", "spot")
    kind_base = baseline.get("kind", "spot")
    if kind_cur != kind_base:
        print(
            f"REGRESSION: report kind mismatch: current {kind_cur!r} vs "
            f"baseline {kind_base!r}",
            file=sys.stderr,
        )
        return 1

    errors = gate_errors(current, baseline)
    if kind_cur == "throughput":
        front = current.get("grids", {}).get("frontier", {})
        streamed = current.get("grids", {}).get("streamed", {})
        print(
            f"bench gate [throughput]: memory_ratio={front.get('memory_ratio')} "
            f"speed_ratio={front.get('speed_ratio')} "
            f"summary_mode_ok={current.get('acceptance', {}).get('summary_mode_ok')} "
            f"stream_ratio={streamed.get('stream_ratio')} "
            f"streamed_ok={current.get('acceptance', {}).get('streamed_ok')}"
        )
    elif kind_cur == "scenarios":
        savings = {
            name: round(sc.get("saving_pct", float("nan")), 1)
            for name, sc in current.get("scenarios", {}).items()
        }
        print(
            f"bench gate [scenarios]: paper_exact="
            f"{current.get('acceptance', {}).get('paper_exact')} "
            f"paper_saving={current.get('paper', {}).get('saving_pct', 0):.1f}% "
            f"scenario_savings={savings}"
        )
    elif kind_cur == "tuning":
        improvements = {
            name: round(sc.get("improvement_pct", float("nan")), 1)
            for name, sc in current.get("scenarios", {}).items()
        }
        acc = current.get("acceptance", {})
        print(
            f"bench gate [tuning]: tuned_beats_default_all="
            f"{acc.get('tuned_beats_default_all')} "
            f"paper_exact={acc.get('paper_exact')} "
            f"single_compile={acc.get('single_compile')} "
            f"improvements_pct={improvements}"
        )
    elif kind_cur == "chaos":
        margins = {
            name: round(sc.get("margin_pct", float("nan")), 1)
            for name, sc in current.get("scenarios", {}).items()
        }
        acc = current.get("acceptance", {})
        print(
            f"bench gate [chaos]: zero_fault_exact="
            f"{acc.get('zero_fault_exact')} "
            f"hardened_beats_unhardened_all="
            f"{acc.get('hardened_beats_unhardened_all')} "
            f"recovery_ticks="
            f"{current.get('recovery', {}).get('recovery_ticks')} "
            f"margins_pct={margins}"
        )
    elif kind_cur == "obs":
        acc = current.get("acceptance", {})
        print(
            f"bench gate [obs]: neutral_exact={acc.get('neutral_exact')} "
            f"overhead_ratio="
            f"{current.get('overhead', {}).get('overhead_ratio')} "
            f"(ceiling {OBS_OVERHEAD_CEILING}) "
            f"exports_ok={acc.get('exports_ok')}"
        )
    elif kind_cur == "tenants":
        savings = {
            n: round(row.get("saving_pct", float("nan")), 1)
            for n, row in current.get("consolidation", {}).items()
        }
        acc = current.get("acceptance", {})
        print(
            f"bench gate [tenants]: single_owner_exact="
            f"{acc.get('single_owner_exact')} "
            f"attribution_exact_all={acc.get('attribution_exact_all')} "
            f"tuned_ge_uniform={acc.get('tuned_ge_uniform')} "
            f"consolidation_savings_pct={savings}"
        )
    else:
        saving = current.get("headline", {}).get("saving_pct", float("nan"))
        accepted = current.get("acceptance", {}).get("dynamic_beats_static")
        print(
            f"bench gate: saving={saving:.1f}% "
            f"(floor {SAVING_FLOOR_PCT}%), "
            f"dynamic_beats_static={accepted}"
        )
    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        report = _attribute(current, baseline, errors,
                            os.path.basename(baseline_path))
        if report is not None and attributions is not None:
            attributions.append(report)
        return 1
    print("bench gate passed: no benchmark regressions vs baseline")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?",
                    help="benchmark JSON produced by this run")
    ap.add_argument("baseline", nargs="?", help="committed baseline JSON")
    ap.add_argument("--auto", action="store_true",
                    help="gate every baselines/BENCH_*.json against the "
                    "matching results/ file (the CI form)")
    ap.add_argument("--results-dir", default="results")
    ap.add_argument("--baselines-dir", default="benchmarks/baselines")
    args = ap.parse_args(argv)

    attributions: list[dict] = []
    if not args.auto:
        if not (args.current and args.baseline):
            ap.error("need CURRENT and BASELINE paths (or --auto)")
        rc = check_pair(args.current, args.baseline, attributions)
        if attributions:
            write_attribution(attributions)
        return rc

    baselines = sorted(glob.glob(os.path.join(args.baselines_dir,
                                              "BENCH_*.json")))
    if not baselines:
        print(f"REGRESSION: no baselines under {args.baselines_dir}",
              file=sys.stderr)
        return 1
    rc = 0
    for baseline in baselines:
        current = os.path.join(args.results_dir, os.path.basename(baseline))
        if not os.path.exists(current):
            print(f"REGRESSION: {current} missing — the benchmark that "
                  f"produces it did not run", file=sys.stderr)
            rc = 1
            continue
        print(f"--- {os.path.basename(baseline)}")
        rc = max(rc, check_pair(current, baseline, attributions))
    if attributions:
        write_attribution(attributions,
                          os.path.join(args.results_dir,
                                       "bench_attribution.json"))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
