"""CI benchmark-regression gate for the spot/bidding benchmarks.

Compares the ``results/BENCH_spot.json`` a CI run just produced (via
``bench_bidding --smoke``) against the committed baseline in
``benchmarks/baselines/BENCH_spot.json`` and fails the job when the
trajectory regresses:

  * the AIMD-vs-Reactive headline saving drops below the paper's 27%
    floor (hard threshold, independent of the baseline);
  * any tracked violation count grows beyond its baseline value
    (headline AIMD, per-policy best points, per-mix points);
  * the dynamic-beats-static acceptance flag flips to false;
  * a best-policy cost inflates beyond ``COST_TOLERANCE`` x baseline
    (loose on purpose: CI floats drift, regressions explode).

Exit code 0 = gate passed.  Anything else fails the job; the JSON is
uploaded as an artifact either way so the trajectory stays inspectable.

CLI:  python benchmarks/check_bench_regression.py \
          results/BENCH_spot.json benchmarks/baselines/BENCH_spot.json
"""

from __future__ import annotations

import argparse
import json
import sys

SAVING_FLOOR_PCT = 27.0
COST_TOLERANCE = 1.5


def check(current: dict, baseline: dict) -> list[str]:
    """Return a list of human-readable gate failures (empty = pass)."""
    errors: list[str] = []

    if current.get("schema_version") != baseline.get("schema_version"):
        errors.append(
            f"schema_version mismatch: current {current.get('schema_version')} "
            f"vs baseline {baseline.get('schema_version')}"
        )
        return errors
    if bool(current.get("smoke")) != bool(baseline.get("smoke")):
        errors.append(
            "smoke flag mismatch: gate must compare like with like "
            f"(current smoke={current.get('smoke')}, "
            f"baseline smoke={baseline.get('smoke')})"
        )
        return errors

    saving = current["headline"]["saving_pct"]
    if saving < SAVING_FLOOR_PCT:
        errors.append(
            f"headline AIMD-vs-Reactive saving {saving:.1f}% fell below the "
            f"paper's {SAVING_FLOOR_PCT}% floor"
        )

    cur_hl_viol = current["headline"]["aimd_violations"]
    base_hl_viol = baseline["headline"]["aimd_violations"]
    if cur_hl_viol > base_hl_viol:
        errors.append(
            f"headline AIMD violations grew: {cur_hl_viol} > baseline {base_hl_viol}"
        )

    if not current["acceptance"]["dynamic_beats_static"]:
        errors.append(
            "acceptance flag dynamic_beats_static is false: no dynamic bid "
            "policy matches the best static bid"
        )

    for section in ("policies", "mixes"):
        for name, base_entry in baseline.get(section, {}).items():
            cur_entry = current.get(section, {}).get(name)
            if cur_entry is None:
                errors.append(f"{section}[{name}] missing from current results")
                continue
            if cur_entry["violations"] > base_entry["violations"]:
                errors.append(
                    f"{section}[{name}] violations grew: "
                    f"{cur_entry['violations']} > baseline {base_entry['violations']}"
                )
            if cur_entry["cost"] > COST_TOLERANCE * base_entry["cost"]:
                errors.append(
                    f"{section}[{name}] cost {cur_entry['cost']:.4f} exceeds "
                    f"{COST_TOLERANCE}x baseline {base_entry['cost']:.4f}"
                )
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="BENCH_spot.json produced by this run")
    ap.add_argument("baseline", help="committed baseline BENCH_spot.json")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    errors = check(current, baseline)
    saving = current.get("headline", {}).get("saving_pct", float("nan"))
    accepted = current.get("acceptance", {}).get("dynamic_beats_static")
    print(
        f"bench gate: saving={saving:.1f}% "
        f"(floor {SAVING_FLOOR_PCT}%), "
        f"dynamic_beats_static={accepted}"
    )
    if errors:
        for e in errors:
            print(f"REGRESSION: {e}", file=sys.stderr)
        return 1
    print("bench gate passed: no benchmark regressions vs baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
