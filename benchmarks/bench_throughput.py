"""Sweep-engine throughput benchmark: summary-mode vs trace-mode scans.

The paper's headline results rest on Monte-Carlo sweeps over market
scenarios (Figs. 4-5), and the sweep engine's cost model is simple: a
trace-mode sweep stacks the full per-tick trace — six ``(T,)`` series plus
three ``(T, W, K)`` arrays — for *every* grid point, moving O(B·T·W·K)
floats to produce O(B) summary numbers; a summary-mode sweep accumulates
the eight per-run scalars inside the scan carry and moves O(B).

This benchmark times both modes on two fixed grids:

  * ``frontier`` — the PR-2 policy-frontier shape (seeds × bid multiples ×
    bid policies on the spiky m3.xlarge market of ``bench_bidding``);
  * ``large``    — the same frontier scaled 100× (10× under ``--smoke``)
    along the seed axis, run through the unified executor's chunked path
    (``sweep(SweepSpec(chunk_size=...))``, one cached compile for every
    micro-batch); trace mode at this size is *not executed* — its output
    bytes are derived analytically via ``jax.eval_shape`` to show what the
    old engine would have streamed;
  * ``streamed`` — the large grid again through the disk-streaming
    executor (``stream_dir=``): chunks land on disk, peak host live bytes
    stay at one padded chunk (grid ≥10× larger, CI-gated), the loaded
    result is bit-checked against the in-memory path, and a
    kill-and-resume round-trip recomputes exactly the discarded chunk;
  * ``sharded``  — shard_map over every local device vs a single device
    (bit-parity + speedup; null on single-device hosts).

Per mode it records compile seconds, steady-state runs/sec, the bytes the
call returns (``jax.eval_shape``, deterministic across hosts) and XLA's
peak live bytes (``compiled.memory_analysis()``: temp + output + args;
None where the backend reports nothing).  Acceptance (gated in CI by
``check_bench_regression.py`` against ``benchmarks/baselines/``):
summary mode must show ≥5× lower returned/peak bytes or ≥3× the runs/sec
of trace mode on the frontier grid.

Emits ``results/BENCH_throughput.json`` (``kind: "throughput"``).

CLI:  PYTHONPATH=src python -m benchmarks.bench_throughput [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (SimConfig, SpotConfig, SweepSpec, make_axes,
                       paper_schedule, runner, sweep)

SCHEMA_VERSION = 2
MEM_RATIO_FLOOR = 5.0
SPEED_RATIO_FLOOR = 3.0
# The streamed path must keep the grid at least this many times larger
# than peak host live bytes (one padded chunk of summaries).
STREAM_RATIO_FLOOR = 10.0

# PR-2 policy-frontier market (bench_bidding.MARKET) and grid shape.
MARKET = dict(instance="m3.xlarge", p_spike_per_core=0.02, spike_hours=3.0,
              ema_alpha=0.15)
POLICIES = ("multiple", "ttc", "ema", "on_demand")
FULL_MULTS = (1.02, 1.1, 1.2, 1.5, 2.5, 4.0, 8.0)
SMOKE_MULTS = (1.02, 1.5, 2.5, 8.0)
TICKS = 130
MONITOR_DT = 300.0
STEADY_ITERS = 3


def _cfg() -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(params=ControlParams(monitor_dt=MONITOR_DT),
                              billing=BillingParams(terminate="immediate")),
        ticks=TICKS, spot=SpotConfig(enabled=True, **MARKET))


def _axes(seeds, mults):
    return make_axes(seeds=list(seeds), bid_mults=list(mults),
                     instances=[MARKET["instance"]], policies=list(POLICIES))


def _mode_fn(schedule, cfg, trace: bool):
    """The jitted sweep of one mode — ``sweep.point_fn``, the exact
    per-point program the unified executor runs (at the config's default
    ``PolicyParams``, broadcast exactly as ``sweep.sweep`` broadcasts
    them).
    Trace mode returns what trace mode is *for*: the full per-tick ys of
    every grid point (the PR-2 baseline's memory shape); summary mode the
    eight scalars."""
    pp = runner.default_params(cfg)
    fn = jax.vmap(sweep.point_fn(schedule, cfg, trace=trace),
                  in_axes=(0, 0, 0, 0, 0, 0, None))
    return jax.jit(lambda *axes: fn(*axes, pp))


def _tree_bytes(tree) -> int:
    return int(sum(np.dtype(leaf.dtype).itemsize * int(np.prod(leaf.shape))
                   for leaf in jax.tree.leaves(tree)))


def _peak_bytes(compiled) -> int | None:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    sizes = [getattr(ma, k, None) for k in
             ("temp_size_in_bytes", "output_size_in_bytes",
              "argument_size_in_bytes")]
    if any(s is None for s in sizes):
        return None
    return int(sum(sizes))


def _measure(fn, axes) -> dict:
    """Compile + steady-state timings and byte counts for one sweep mode.

    Compiles once via the AOT path and times the *compiled* executable, so
    the XLA memory analysis and the timing loop share one compilation.
    """
    b = int(axes.seed.shape[0])
    out_bytes = _tree_bytes(jax.eval_shape(fn, *axes))
    t0 = time.perf_counter()
    compiled = fn.lower(*axes).compile()
    compile_s = time.perf_counter() - t0
    peak = _peak_bytes(compiled)
    jax.block_until_ready(compiled(*axes))   # warm dispatch
    t0 = time.perf_counter()
    for _ in range(STEADY_ITERS):
        jax.block_until_ready(compiled(*axes))
    steady_s = (time.perf_counter() - t0) / STEADY_ITERS
    return {
        "points": b,
        "compile_s": round(compile_s, 4),
        "steady_s": round(steady_s, 4),
        "runs_per_s": round(b / steady_s, 2),
        "output_bytes": out_bytes,
        "peak_bytes": peak,
    }


def run_frontier(schedule, cfg, seeds, mults) -> dict:
    axes = _axes(seeds, mults)
    trace = _measure(_mode_fn(schedule, cfg, trace=True), axes)
    summary = _measure(_mode_fn(schedule, cfg, trace=False), axes)

    def ratio(num, den):
        return round(num / den, 2) if num and den else None

    peak_ratio = ratio(trace["peak_bytes"], summary["peak_bytes"])
    return {
        "points": trace["points"],
        "trace": trace,
        "summary": summary,
        # trace-vs-summary, >1 = summary wins
        "memory_ratio": ratio(trace["output_bytes"],
                              summary["output_bytes"]),
        "peak_ratio": peak_ratio,
        "speed_ratio": ratio(summary["runs_per_s"], trace["runs_per_s"]),
    }


def run_large(schedule, cfg, axes, chunk_size) -> tuple:
    """The frontier grid scaled along the seed axis, summary mode through
    the chunked executor; trace mode sized but never executed
    (``jax.eval_shape`` only — the point is that it need not fit).
    Returns ``(report_dict, in_memory_result)`` so the streamed section
    can verify bit-parity without a third full sweep."""
    b = int(axes.seed.shape[0])

    trace_bytes = _tree_bytes(
        jax.eval_shape(_mode_fn(schedule, cfg, trace=True), *axes))
    summary_bytes = _tree_bytes(
        jax.eval_shape(_mode_fn(schedule, cfg, trace=False), *axes))

    # Warm the chunk cache, then time the whole chunked sweep end to end
    # (per-chunk dispatch + host concatenation included).
    spec = SweepSpec(axes=axes, workload=schedule, chunk_size=chunk_size)
    sweep.sweep(spec, cfg)
    t0 = time.perf_counter()
    result = sweep.sweep(spec, cfg)
    wall = time.perf_counter() - t0
    report = {
        "points": b,
        "chunk_size": chunk_size,
        "summary": {
            "points": b,
            "runs_per_s": round(b / wall, 2),
            "steady_s": round(wall, 4),
            "output_bytes": summary_bytes,
        },
        "trace_output_bytes_analytic": trace_bytes,
        "memory_ratio": round(trace_bytes / summary_bytes, 2),
    }
    return report, result


def _trees_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def run_streamed(schedule, cfg, axes, reference) -> dict:
    """Disk-streaming executor on the large grid: write chunks to a
    scratch directory, check the loaded result is bit-identical to the
    in-memory path, then delete the last committed chunk and resume.

    The chunk size is picked so the full grid of summaries is well over
    ``STREAM_RATIO_FLOOR``× the live bytes of one padded chunk — the
    bounded-memory contract CI gates.
    """
    import shutil
    import tempfile

    b = int(axes.seed.shape[0])
    stream_chunk = max(1, b // 16)
    grid_bytes = _tree_bytes(
        jax.eval_shape(_mode_fn(schedule, cfg, trace=False), *axes))
    live_bytes = int(round(grid_bytes * stream_chunk / b))
    scratch = tempfile.mkdtemp(prefix="bench_stream_")
    try:
        spec = SweepSpec(axes=axes, workload=schedule,
                         chunk_size=stream_chunk, stream_dir=scratch)
        t0 = time.perf_counter()
        handle = sweep.sweep(spec, cfg)
        wall = time.perf_counter() - t0
        n_chunks = handle.n_chunks
        parity = _trees_equal(handle.load(), reference)

        # Kill-and-resume: discard the last committed chunk, re-invoke the
        # same spec, and check only that chunk was recomputed.
        last = handle.completed()[-1]
        shutil.rmtree(os.path.join(scratch, f"step_{last:08d}"))
        os.remove(os.path.join(scratch, f"step_{last:08d}.done"))
        before = set(handle.completed())
        resumed = sweep.sweep(spec, cfg)
        resume_ok = (_trees_equal(resumed.load(), reference)
                     and len(before) == n_chunks - 1)
    finally:
        shutil.rmtree(scratch, ignore_errors=True)
    return {
        "points": b,
        "chunk_size": stream_chunk,
        "n_chunks": n_chunks,
        "wall_s": round(wall, 4),
        "grid_bytes": grid_bytes,
        "live_bytes": live_bytes,
        "stream_ratio": round(grid_bytes / live_bytes, 2),
        "parity": bool(parity),
        "resume_ok": bool(resume_ok),
    }


def run_sharded(schedule, cfg, axes) -> dict:
    """shard_map over every local device vs a single device on the
    frontier grid: wall-clock ratio and bit-parity.  On a single-device
    host the fields are null — the gate tolerates that; the multi-device
    CI job exercises the parity contract through the test suite."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        return {"devices": n_dev, "parity": None, "speedup": None}
    b = int(axes.seed.shape[0])

    def timed(devices):
        spec = SweepSpec(axes=axes, workload=schedule, chunk_size=b,
                         devices=devices)
        sweep.sweep(spec, cfg)  # warm the compile cache
        t0 = time.perf_counter()
        out = sweep.sweep(spec, cfg)
        return out, time.perf_counter() - t0

    single, t1 = timed(1)
    sharded, tn = timed(None)
    return {
        "devices": n_dev,
        "parity": _trees_equal(single, sharded),
        "single_s": round(t1, 4),
        "sharded_s": round(tn, 4),
        "speedup": round(t1 / tn, 2) if tn > 0 else None,
    }


def main(emit, smoke: bool = False) -> dict:
    seeds = tuple(range(2 if smoke else 6))
    mults = SMOKE_MULTS if smoke else FULL_MULTS
    factor = 10 if smoke else 100
    chunk_size = 128 if smoke else 1024
    schedule = paper_schedule(ttc=7500.0, arrival_gap_ticks=1)
    cfg = _cfg()

    front = run_frontier(schedule, cfg, seeds, mults)
    for mode in ("trace", "summary"):
        m = front[mode]
        emit(f"thru_frontier_{mode}_runs_per_s", m["runs_per_s"],
             f"compile={m['compile_s']}s;out_bytes={m['output_bytes']};"
             f"peak={m['peak_bytes']}")
    emit("thru_frontier_memory_ratio", front["memory_ratio"],
         f"target>={MEM_RATIO_FLOOR};peak_ratio={front['peak_ratio']}")
    emit("thru_frontier_speed_ratio", front["speed_ratio"],
         f"alt_target>={SPEED_RATIO_FLOOR}")

    big_seeds = range(len(list(seeds)) * factor)
    big_axes = _axes(big_seeds, mults)
    large, in_memory = run_large(schedule, cfg, big_axes, chunk_size)
    large["factor"] = factor
    emit("thru_large_summary_runs_per_s", large["summary"]["runs_per_s"],
         f"points={large['points']};chunk={chunk_size}")
    emit("thru_large_memory_ratio", large["memory_ratio"],
         f"trace_bytes={large['trace_output_bytes_analytic']}")

    streamed = run_streamed(schedule, cfg, big_axes, in_memory)
    emit("thru_streamed_ratio", streamed["stream_ratio"],
         f"target>={STREAM_RATIO_FLOOR};live_bytes={streamed['live_bytes']}")
    emit("thru_streamed_parity", float(streamed["parity"]), "bool")
    emit("thru_streamed_resume_ok", float(streamed["resume_ok"]), "bool")

    sharded = run_sharded(schedule, cfg, _axes(seeds, mults))
    if sharded["parity"] is not None:
        emit("thru_sharded_parity", float(sharded["parity"]),
             f"devices={sharded['devices']};speedup={sharded['speedup']}")

    ok = (front["memory_ratio"] is not None
          and front["memory_ratio"] >= MEM_RATIO_FLOOR) or \
         (front["speed_ratio"] is not None
          and front["speed_ratio"] >= SPEED_RATIO_FLOOR)
    emit("thru_acceptance_summary_mode_ok", float(ok), "bool")
    streamed_ok = (streamed["parity"] and streamed["resume_ok"]
                   and streamed["stream_ratio"] >= STREAM_RATIO_FLOOR)
    emit("thru_acceptance_streamed_ok", float(streamed_ok), "bool")

    report = {
        "kind": "throughput",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "market": dict(MARKET),
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "seeds": list(seeds),
            "bid_mults": list(mults),
            "policies": list(POLICIES),
            "large_factor": factor,
            "chunk_size": chunk_size,
            "devices": len(jax.devices()),
            "backend": jax.default_backend(),
        },
        "grids": {"frontier": front, "large": large,
                  "streamed": streamed, "sharded": sharded},
        "acceptance": {
            "summary_mode_ok": bool(ok),
            "streamed_ok": bool(streamed_ok),
            "sharded_parity": sharded["parity"],
            "memory_ratio_floor": MEM_RATIO_FLOOR,
            "speed_ratio_floor": SPEED_RATIO_FLOOR,
            "stream_ratio_floor": STREAM_RATIO_FLOOR,
        },
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_throughput.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not ok:
        raise SystemExit(
            "throughput acceptance not met: summary mode shows "
            f"memory_ratio={front['memory_ratio']} (floor "
            f"{MEM_RATIO_FLOOR}) and speed_ratio={front['speed_ratio']} "
            f"(floor {SPEED_RATIO_FLOOR})")
    if not streamed_ok:
        raise SystemExit(
            "streamed acceptance not met: parity="
            f"{streamed['parity']} resume_ok={streamed['resume_ok']} "
            f"stream_ratio={streamed['stream_ratio']} (floor "
            f"{STREAM_RATIO_FLOOR})")
    if sharded["parity"] is False:
        raise SystemExit(
            "sharded sweep is not bit-identical to the single-device path")
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grids for CI; same acceptance checks")
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
