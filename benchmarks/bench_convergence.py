"""Paper Fig. 3: CUS-prediction convergence trace for an FFMPEG workload
under 1-min monitoring, for Kalman / ad-hoc / ARMA (CSV artifact)."""

from __future__ import annotations

import csv
import os

import numpy as np

from repro.sim import paper_schedule, run
from repro.sim.workloads import TRANSCODE

from .common import TTC_CONSERVATIVE, make_cfg


def trace_workload(pred: str, seed=0):
    sched = paper_schedule(ttc=TTC_CONSERVATIVE, arrival_gap_ticks=5,
                           seed=seed)
    cfg = make_cfg(predictor=pred, monitor_dt=60.0, ticks=620, seed=seed)
    tr = run(sched, cfg)
    # largest transcode workload (paper Fig. 3 uses an FFMPEG workload)
    tmask = sched.family == TRANSCODE
    wid = int(np.argmax(np.where(tmask, sched.m0[:, 0], -1)))
    b_hat = np.asarray(tr.b_hat[:, wid, 0])
    rel = np.asarray(tr.reliable[:, wid, 0])
    t_init = int(np.argmax(rel)) if rel.any() else -1
    return b_hat, t_init, float(sched.b_true[wid, 0])


def main(emit) -> None:
    os.makedirs("results", exist_ok=True)
    traces = {}
    for pred in ("kalman", "adhoc", "arma"):
        b_hat, t_init, b_true = trace_workload(pred)
        traces[pred] = (b_hat, t_init)
        emit(f"fig3_{pred}_t_init_min", float(t_init),
             f"b_true={b_true:.1f};b_hat_at_init="
             f"{b_hat[t_init] if t_init >= 0 else -1:.1f}")
    with open("results/fig3_convergence.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["tick_min", "kalman", "adhoc", "arma", "b_true"])
        n = min(240, len(traces["kalman"][0]))
        for t in range(n):
            w.writerow([t] + [f"{traces[p][0][t]:.3f}"
                              for p in ("kalman", "adhoc", "arma")]
                       + [f"{b_true:.3f}"])
