"""Multi-tenant shared-fleet benchmark: consolidation, attribution, profit.

The paper's platform serves one owner; ``sim.tenants`` shares one spot
fleet across N of them with hierarchical fair-share, per-tenant admission
and exactly-attributed billing.  This benchmark pins the three claims the
subsystem makes:

  * **identity** — a one-tenant set is the single-owner simulation bit
    for bit (every ``RunSummary`` field), and the whole fleet bill lands
    on that tenant to the last 0.1 m$ unit;
  * **consolidation** — one shared fleet is cheaper than N dedicated
    fleets running the *identical* per-tenant workloads (the N_min idle
    floor and the burst headroom amortize), at an equal-or-better
    violation count; swept over N ∈ {1, 4, 16, 64} tenants;
  * **provider profit** — tuning the admission / cross-tenant weight /
    list-price knobs (``ProfitObjective`` through the stock
    ``tune_policy`` CEM) strictly improves provider profit over the
    uniform-price admit-all defaults, in one compile.

Emits ``results/BENCH_tenants.json`` (``kind: "tenants"``), gated in CI
by ``check_bench_regression.py`` against ``benchmarks/baselines/``.

CLI:  PYTHONPATH=src python -m benchmarks.bench_tenants [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import opt
from repro.core.controller import ControllerConfig
from repro.core.types import BillingParams, ControlParams
from repro.sim import (ScenarioSet, SimConfig, SpotConfig, SweepSpec,
                       TenantSet, TenantSpec, make_axes, run_single,
                       run_tenants, runner)
from repro.sim.sweep import sweep
from repro.sim import scenarios as scen
from repro.sim import tenants as tnt

SCHEMA_VERSION = 1
TICKS = 60
MONITOR_DT = 300.0
MAX_W = 16          # workload rows per tenant
HORIZON = 20        # arrival window (ticks)
TTC = 4500.0
N_LEVELS = (1, 4, 16, 64)
N_LEVELS_SMOKE = (1, 4)
ELASTICITY = 0.5    # linear demand shed per unit of price_mult above 1
MARKET = dict(
    instance="m3.xlarge",
    bid_policy="ttc",
    bid_mult=1.5,
    p_spike_per_core=0.02,
    spike_hours=3.0,
)


def _cfg() -> SimConfig:
    return SimConfig(
        ctrl=ControllerConfig(
            params=ControlParams(monitor_dt=MONITOR_DT),
            billing=BillingParams(terminate="immediate"),
        ),
        ticks=TICKS,
        spot=SpotConfig(enabled=True, **MARKET),
    )


def _tenant_kinds() -> tuple:
    """The four stochastic workload kinds a tenant mix cycles through —
    per-tenant load light enough that consolidation (not raw capacity) is
    what the shared fleet exploits."""
    tm = scen.TaskModel(mean_items=(150.0, 15.0, 100.0, 80.0),
                        items_sigma=0.8, ttc=TTC)
    common = dict(horizon=HORIZON, max_w=MAX_W, tasks=tm)
    return (
        scen.Poisson(rate=0.3, **common),
        scen.MMPP(rate_lo=0.1, rate_hi=1.0, p_up=0.1, p_down=0.25,
                  **common),
        scen.Diurnal(rate=0.3, amp=0.8, period=24, **common),
        scen.FlashCrowd(rate=0.15, spike_rate=2.0, spike_ticks=4,
                        **common),
    )


# Per-kind contract terms: $/CU-hour list price and $/violation credit.
KIND_PRICE = (0.45, 0.60, 0.45, 0.75)
KIND_PENALTY = (0.25, 0.50, 0.25, 0.75)


def make_mix(n: int) -> TenantSet:
    """An N-tenant mix cycling through the four workload kinds."""
    kinds = _tenant_kinds()
    return TenantSet(tuple(
        TenantSpec(kinds[i % len(kinds)],
                   price=KIND_PRICE[i % len(kinds)],
                   slo_penalty=KIND_PENALTY[i % len(kinds)],
                   name=f"t{i:02d}_{kinds[i % len(kinds)].name}")
        for i in range(n)))


def run_identity(seeds) -> dict:
    """One-tenant set vs the single-owner path, bit for bit.

    ``mean_price`` is the one summary field the repo does not promise bit
    for bit (float accumulation order differs under vmap); every other
    field must match exactly — the same contract ``tests/test_throughput``
    pins between trace and summary mode."""
    cfg = _cfg()
    spec = _tenant_kinds()[0]
    ts = TenantSet((TenantSpec(spec),))
    sset = ScenarioSet((spec,))
    exact = True
    attributed = True
    for seed in seeds:
        shared = run_tenants(ts, cfg, seed=seed)
        alone = run_single(sset, cfg, seed=seed, bid_mult=1.0)
        for f in type(alone)._fields:
            a = np.asarray(getattr(shared.fleet, f))
            b = np.asarray(getattr(alone, f))
            same = (np.allclose(a, b, rtol=1e-6) if f == "mean_price"
                    else np.array_equal(a, b))
            exact = exact and bool(same)
        attributed = attributed and (
            int(shared.tenants.cost_units[0])
            == int(np.round(float(alone.cost_horizon)
                            * runner._COST_UNIT)))
    return {"n_seeds": len(list(seeds)), "exact_match": bool(exact),
            "attribution_exact": bool(attributed)}


def run_consolidation(n_levels, seeds) -> dict:
    """Shared fleet vs N dedicated fleets on identical workloads."""
    cfg = _cfg()
    out = {}
    for n in n_levels:
        ts = make_mix(n)
        t0 = time.perf_counter()
        spec = SweepSpec(axes=make_axes(list(seeds), [1.0]), workload=ts)
        shared = jax.block_until_ready(sweep(spec, cfg))
        wall = time.perf_counter() - t0
        sh_cost = float(np.mean(np.asarray(shared.fleet.cost_horizon)))
        sh_viol = int(np.sum(np.asarray(shared.fleet.violations)))
        att_ok = bool(np.all(
            np.sum(np.asarray(shared.tenants.cost_units), axis=-1)
            == np.round(np.asarray(shared.fleet.cost_horizon)
                        * runner._COST_UNIT).astype(np.int64)))
        iso_cost, iso_viol = 0.0, 0
        for seed in seeds:
            iso = tnt.isolated_runs(ts, cfg, seed=seed)
            iso_cost += float(np.sum(np.asarray(iso.cost_horizon)))
            iso_viol += int(np.sum(np.asarray(iso.violations)))
        iso_cost /= len(list(seeds))
        saving = 100.0 * (iso_cost - sh_cost) / max(iso_cost, 1e-9)
        out[str(n)] = {
            "n_tenants": n,
            "shared_cost": sh_cost,
            "isolated_cost": iso_cost,
            "saving_pct": saving,
            "shared_violations": sh_viol,
            "isolated_violations": iso_viol,
            "attribution_exact": att_ok,
            "shared_runs_per_s": len(list(seeds)) / wall,
        }
    return out


def run_profit(seeds, pop_size, generations) -> dict:
    """Tuned admission/weights/pricing vs uniform defaults, one compile."""
    cfg = _cfg()
    ts = make_mix(4)
    obj = opt.ProfitObjective(cfg, ts, seeds=seeds, elasticity=ELASTICITY)
    tuning = opt.tune_policy(cfg, None, None, jax.random.PRNGKey(7),
                             objective=obj, pop_size=pop_size,
                             generations=generations)
    uniform_profit = -float(tuning.default_score)
    tuned_profit = -float(tuning.result.best_score)
    return {
        "n_tenants": ts.n,
        "n_seeds": len(list(seeds)),
        "pop_size": pop_size,
        "generations": generations,
        "elasticity": ELASTICITY,
        "uniform_profit": uniform_profit,
        "tuned_profit": tuned_profit,
        "improvement_pct": 100.0 * (tuned_profit - uniform_profit)
                           / max(abs(uniform_profit), 1e-9),
        "objective_traces": int(obj.n_traces),
        "tuned_params": {
            n: float(np.asarray(tuning.result.best_vec)[i])
            for i, n in enumerate(obj.space.names)
        },
    }


def main(emit, smoke: bool = False) -> dict:
    n_levels = N_LEVELS_SMOKE if smoke else N_LEVELS
    id_seeds = (0, 1) if smoke else (0, 1, 2)
    con_seeds = tuple(range(2 if smoke else 4))
    prof_seeds = tuple(range(3 if smoke else 4))
    pop, gens = (8, 4) if smoke else (16, 6)

    identity = run_identity(id_seeds)
    emit("ten_identity_exact", float(identity["exact_match"]),
         f"attribution={identity['attribution_exact']}")

    consolidation = run_consolidation(n_levels, con_seeds)
    for n, row in consolidation.items():
        emit(f"ten_consolidation_n{n}_saving_pct", row["saving_pct"],
             f"shared={row['shared_cost']:.4f};iso={row['isolated_cost']:.4f};"
             f"sviol={row['shared_violations']};iviol={row['isolated_violations']};"
             f"runs_per_s={row['shared_runs_per_s']:.2f}")

    profit = run_profit(prof_seeds, pop, gens)
    emit("ten_profit_improvement_pct", profit["improvement_pct"],
         f"uniform={profit['uniform_profit']:.4f};"
         f"tuned={profit['tuned_profit']:.4f};"
         f"traces={profit['objective_traces']}")

    # The acceptance N: the headline 4-tenant mix (present in both modes).
    head = consolidation["4"]
    acceptance = {
        "single_owner_exact": bool(identity["exact_match"]
                                   and identity["attribution_exact"]),
        "attribution_exact_all": bool(all(
            r["attribution_exact"] for r in consolidation.values())),
        "consolidation_saves": bool(head["saving_pct"] > 0.0),
        "consolidation_viol_ok": bool(head["shared_violations"]
                                      <= head["isolated_violations"]),
        "tuned_ge_uniform": bool(profit["tuned_profit"]
                                 >= profit["uniform_profit"] - 1e-6),
        "single_compile": bool(profit["objective_traces"] == 1),
    }
    for flag, value in acceptance.items():
        emit(f"ten_acceptance_{flag}", float(value), "bool")

    report = {
        "kind": "tenants",
        "schema_version": SCHEMA_VERSION,
        "smoke": bool(smoke),
        "config": {
            "ticks": TICKS,
            "monitor_dt": MONITOR_DT,
            "max_w": MAX_W,
            "horizon": HORIZON,
            "ttc": TTC,
            "market": dict(MARKET),
            "n_levels": list(n_levels),
            "identity_seeds": list(id_seeds),
            "consolidation_seeds": list(con_seeds),
            "profit_seeds": list(prof_seeds),
        },
        "identity": identity,
        "consolidation": consolidation,
        "profit": profit,
        "acceptance": acceptance,
    }
    os.makedirs("results", exist_ok=True)
    with open(os.path.join("results", "BENCH_tenants.json"), "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")

    if not all(acceptance.values()):
        raise SystemExit(f"tenants acceptance not met: {acceptance}")
    return report


def _cli() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI; same acceptance checks")
    args = ap.parse_args()

    def emit(name, value, derived=""):
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,value,derived")
    main(emit, smoke=args.smoke)


if __name__ == "__main__":
    _cli()
