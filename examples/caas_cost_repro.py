"""Reproduce the paper's headline cost experiment (Figs. 4-5, Table III)
and write the cumulative-cost curves to CSV.

    PYTHONPATH=src python examples/caas_cost_repro.py
"""

import sys


def emit(name, value, derived=""):
    print(f"{name},{value:.6g},{derived}")


def main() -> None:
    sys.path.insert(0, ".")
    from benchmarks import bench_cost
    t3 = bench_cost.run_table3(seeds=(0, 1, 2))
    print("== Table III reproduction (mean of 3 seeds) ==")
    for tag, rows in t3.items():
        print(f"-- TTC setting: {tag}")
        for policy in ("aimd", "reactive", "mwa", "lr", "autoscale"):
            r = rows[policy]
            print(f"  {policy:10s} ${r['cost']:.3f}  maxN={r['max_n']:.0f} "
                  f" +LB {r['over_lb_pct']:.0f}%  "
                  f"(AIMD saves {r['aimd_saving_pct']:.0f}%)")
        print(f"  {'LB':10s} ${rows['lb']['cost']:.3f}")
    bench_cost.write_curves("results/curves")
    print("curves written to results/curves_fig4.csv / _fig5.csv")


if __name__ == "__main__":
    main()
